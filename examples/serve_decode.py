"""Batched serving example: cache-building prefill + decode loop with a
sharded KV cache (flash-decode logsumexp merge over the model axis) on 8
emulated devices, through the continuous-batching engine's static path.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_decode.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

from repro.launch.serve import generate

generate("tinyllama-1.1b", reduced=True, batch=4, prompt_len=4,
         gen_tokens=24, mesh_shape=(2, 4))
generate("falcon-mamba-7b", reduced=True, batch=4, prompt_len=4,
         gen_tokens=24, mesh_shape=None)
