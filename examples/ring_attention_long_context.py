"""Sequence parallelism example (paper §4.2): ring attention vs the bulk
all-gather baseline on a sequence sharded across 8 emulated devices, plus
the SSM state-ring analogue used by the Mamba archs.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/ring_attention_long_context.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import (pk_ring_attention, ring_attention_baseline,
                        pk_ulysses_attention, ssm_entry_states)
from repro.launch.mesh import make_mesh

mesh = make_mesh((8,), ("sp",))
sm = partial(compat.shard_map, mesh=mesh, check_vma=False)
B, Hq, Hkv, S, D = 1, 8, 2, 8 * 512, 64
q = jax.random.normal(jax.random.PRNGKey(0), (B, Hq, S, D), jnp.bfloat16)
k = jax.random.normal(jax.random.PRNGKey(1), (B, Hkv, S, D), jnp.bfloat16)
v = jax.random.normal(jax.random.PRNGKey(2), (B, Hkv, S, D), jnp.bfloat16)
specs = (P(None, None, "sp"),) * 3

for name, fn in [("pk_ring", pk_ring_attention),
                 ("bulk_allgather", ring_attention_baseline),
                 ("pk_ulysses", pk_ulysses_attention)]:
    f = jax.jit(sm(lambda q, k, v, fn=fn: fn(q, k, v, "sp", causal=True),
                   in_specs=specs, out_specs=P(None, None, "sp")))
    out = jax.block_until_ready(f(q, k, v))
    t0 = time.perf_counter()
    for _ in range(3):
        out = f(q, k, v)
    jax.block_until_ready(out)
    print(f"{name:16s} out={out.shape}  {(time.perf_counter()-t0)/3*1e3:.1f} ms/call")

# SSM analogue: exchange chunk-boundary states around the ring
A = jax.random.uniform(jax.random.PRNGKey(3), (8, 64, 16), minval=0.5, maxval=0.99)
Sx = jax.random.normal(jax.random.PRNGKey(4), (8, 64, 16))
f = jax.jit(sm(lambda a, s: ssm_entry_states(a[0], s[0], "sp")[None],
               in_specs=(P("sp"), P("sp")), out_specs=P("sp")))
print("ssm entry states:", f(A, Sx).shape)
