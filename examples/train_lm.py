"""End-to-end driver: train a ~100M-param TinyLlama-family model for a few
hundred steps on a data x model mesh with the full production substrate —
FSDP+TP sharding, PK overlapped collectives, microbatching, async
checkpointing, auto-resume, straggler watchdog.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import specs as SP
from repro.launch.mesh import make_mesh
from repro.models import transformer as T
from repro.models.sharding import ShardingRules
from repro.optim.adamw import AdamW, warmup_cosine
from repro.runtime.driver import DriverConfig, TrainDriver
from repro.train.step import TrainState, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/train_lm_ckpt")
    ap.add_argument("--vocab", type=int, default=32000)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    # ~100M-param model: tinyllama dims scaled to d=768/12L
    cfg = dataclasses.replace(
        get_config("tinyllama-1.1b"), name="tinyllama-100m",
        n_layers=14, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab_size=args.vocab)
    print(f"params ~{cfg.param_count()/1e6:.0f}M")

    mesh = make_mesh((2, 4), ("data", "model"))
    run = RunConfig(dp_axes=("data",), fsdp=True, pk_overlap=True,
                    microbatches=2)
    rules = ShardingRules(mesh, run)
    tmpl = T.param_template(cfg, run, rules)
    params = T.init_params(tmpl, jax.random.PRNGKey(0), cfg.d_model)
    params = jax.tree.map(jax.device_put, params,
                          SP.named(mesh, T.param_specs(tmpl)))
    opt = AdamW(lr=warmup_cosine(args.lr, 20, args.steps), weight_decay=0.01)
    state = TrainState(params=params, opt=opt.init(params))
    step = jax.jit(make_train_step(cfg, run, rules, opt), donate_argnums=(0,))
    data = SyntheticLM(DataConfig(cfg.vocab_size, args.seq, args.batch),
                       mesh=mesh, dp_axes=run.dp_axes)
    driver = TrainDriver(train_step=step, state=state, data=data,
                         ckpt_dir=args.ckpt_dir,
                         cfg=DriverConfig(total_steps=args.steps,
                                          ckpt_every=100, log_every=10))
    _, log = driver.run()
    print("first/last logged loss:", log[0]["loss"], "->", log[-1]["loss"])


if __name__ == "__main__":
    main()
