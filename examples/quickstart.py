"""Quickstart: the ParallelKittens-on-TPU public API in 60 lines.

Runs on CPU with 8 emulated devices: builds a (2, 4) data x model mesh, runs
the PK overlapped GEMM collectives against their bulk baselines, a ring
attention island, and one train step of a tiny assigned-architecture model.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import (pk_all_gather_matmul, all_gather_matmul_baseline,
                        pk_matmul_reduce_scatter, pk_ring_attention,
                        choose_gemm_collective)
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
sm = partial(jax.shard_map, mesh=mesh, check_vma=False)

# --- 1. overlapped AG+GEMM (paper Fig. 7) vs bulk baseline ---
x = jax.random.normal(jax.random.PRNGKey(0), (32, 64))
w = jax.random.normal(jax.random.PRNGKey(1), (64, 48))
pk = jax.jit(sm(lambda x, w: pk_all_gather_matmul(x, w, "model"),
                in_specs=(P("model"), P()), out_specs=P()))
base = jax.jit(sm(lambda x, w: all_gather_matmul_baseline(x, w, "model"),
                  in_specs=(P("model"), P()), out_specs=P()))
print("AG+GEMM max |pk - baseline| =",
      jnp.abs(pk(x, w) - base(x, w)).max())

# --- 2. the schedule chooser (paper §3.1.3 hiding condition) ---
policy = choose_gemm_collective(8192, 8192, 4096, axis_size=16,
                                kind="reduce_scatter")
print("GEMM+RS schedule:", policy.strategy, "—", policy.reason)

# --- 3. ring attention island (paper §4.2) ---
q = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 32, 16))
k = jax.random.normal(jax.random.PRNGKey(3), (2, 2, 32, 16))
v = jax.random.normal(jax.random.PRNGKey(4), (2, 2, 32, 16))
ring = jax.jit(sm(lambda q, k, v: pk_ring_attention(q, k, v, "model"),
                  in_specs=(P(None, None, "model"),) * 3,
                  out_specs=P(None, None, "model")))
print("ring attention out:", ring(q, k, v).shape)

# --- 4. one real train step of an assigned arch (reduced config) ---
from repro.launch.train import build_and_train
state, log = build_and_train(
    "tinyllama-1.1b", steps=3, reduced=True, mesh_shape=(2, 4),
    mesh_axes=("data", "model"), batch=4, seq=64,
    ckpt_dir="/tmp/quickstart_ckpt", log_every=1)
print("3-step train loss:", [round(m["loss"], 3) for m in log])
