"""Quickstart: the ParallelKittens-on-TPU public API in 60 lines.

Runs on CPU with 8 emulated devices: builds a (2, 4) data x model mesh,
constructs ONE CommContext, runs the overlapped GEMM collectives against
their bulk baselines through it, a ring attention island, and one train step
of a tiny assigned-architecture model.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import pk_ring_attention
from repro.core.comms import CommContext
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
sm = partial(compat.shard_map, mesh=mesh, check_vma=False)

# --- 1. ONE context for every collective on the model axis ---
ctx = CommContext(axis_name="model", mesh=mesh)

# overlapped AG+GEMM (paper Fig. 7): backend="ring" pins the PK schedule,
# backend="bulk" the non-overlapped baseline; backend=None lets the §3.1.1
# cost model decide per shape.
x = jax.random.normal(jax.random.PRNGKey(0), (32, 64))
w = jax.random.normal(jax.random.PRNGKey(1), (64, 48))
pk = jax.jit(sm(lambda x, w: ctx.all_gather_matmul(x, w, backend="ring"),
                in_specs=(P("model"), P()), out_specs=P()))
base = jax.jit(sm(lambda x, w: ctx.all_gather_matmul(x, w, backend="bulk"),
                  in_specs=(P("model"), P()), out_specs=P()))
print("AG+GEMM max |pk - baseline| =",
      jnp.abs(pk(x, w) - base(x, w)).max())

# --- 2. the policy the context routes through (paper §3.1.3) ---
big = CommContext(axis_name="model", mesh=make_mesh((8,), ("model",)))
policy = big.gemm_policy(8192, 8192, 4096, kind="reduce_scatter")
print("GEMM+RS schedule:", policy.strategy, "—", policy.reason)
print("auto backend at that shape:",
      big.auto_gemm_backend("matmul_reduce_scatter", 8192, 8192, 4096))

# --- 3. ring attention island (paper §4.2) — ring_shift via the context ---
q = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 32, 16))
k = jax.random.normal(jax.random.PRNGKey(3), (2, 2, 32, 16))
v = jax.random.normal(jax.random.PRNGKey(4), (2, 2, 32, 16))
ring = jax.jit(sm(lambda q, k, v: pk_ring_attention(q, k, v, "model",
                                                    ctx=ctx),
                  in_specs=(P(None, None, "model"),) * 3,
                  out_specs=P(None, None, "model")))
print("ring attention out:", ring(q, k, v).shape)

# --- 4. one real train step of an assigned arch (reduced config) ---
import tempfile
from repro.launch.train import build_and_train
state, log = build_and_train(
    "tinyllama-1.1b", steps=3, reduced=True, mesh_shape=(2, 4),
    mesh_axes=("data", "model"), batch=4, seq=64,
    ckpt_dir=tempfile.mkdtemp(prefix="quickstart_ckpt_"), log_every=1)
print("3-step train loss:", [round(m["loss"], 3) for m in log])
