"""Deterministic sharded synthetic token pipeline.

Stateless-by-construction: batch(step) is a pure function of (seed, step), so
the checkpointable cursor is just the step integer — restart/elastic-resize
resume produces bit-identical batches regardless of host count (the property
a real distributed loader needs; here the "index shuffle" is a PRNG fold).

Sequences follow a learnable rule (per-sequence stride r: x_{t+1} = (x_t + r)
mod V with light noise) so example trainings show real loss descent.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_rules: int = 8
    noise: float = 0.02


class SyntheticLM:
    def __init__(self, cfg: DataConfig, mesh: Mesh | None = None,
                 dp_axes=("data",)):
        self.cfg = cfg
        self.mesh = mesh
        self.dp = dp_axes[0] if len(dp_axes) == 1 else tuple(dp_axes)

    def batch(self, step: int):
        c = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(c.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        start = jax.random.randint(k1, (c.global_batch, 1), 0, c.vocab_size)
        rule = jax.random.randint(k2, (c.global_batch, 1), 1, c.n_rules + 1)
        t = jnp.arange(c.seq_len + 1)[None, :]
        toks = (start + rule * t) % c.vocab_size
        noise_mask = jax.random.bernoulli(k3, c.noise,
                                          (c.global_batch, c.seq_len + 1))
        noise_tok = jax.random.randint(k3, (c.global_batch, c.seq_len + 1),
                                       0, c.vocab_size)
        toks = jnp.where(noise_mask, noise_tok, toks).astype(jnp.int32)
        batch = {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
            "weights": jnp.ones((c.global_batch, c.seq_len), jnp.float32),
        }
        if self.mesh is not None:
            sh = NamedSharding(self.mesh, P(self.dp, None))
            batch = {k: jax.device_put(v, sh) for k, v in batch.items()}
        return batch
