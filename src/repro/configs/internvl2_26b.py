"""InternVL2-26B — InternViT frontend (STUB: precomputed patch embeddings)
+ InternLM2-20B language backbone. [arXiv:2404.16821; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab_size=92553, head_dim=128, rope_theta=1e6,
    frontend="vision", n_frontend_tokens=256,
)
