"""Architecture + run configuration dataclasses.

`ArchConfig` describes the model (one file per assigned architecture in this
package); `RunConfig` describes how it is executed (mesh axes, PK overlap
flags, remat/microbatching, dtypes). The same ArchConfig drives the smoke
test (via `.reduced()`), the dry-run (full shapes, ShapeDtypeStruct only) and
training/serving.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One position in the repeating layer pattern."""
    mixer: Literal["attn", "mamba"] = "attn"
    mlp: Literal["dense", "moe", "none"] = "dense"


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_every: int = 1               # pattern: layer i is MoE iff i % moe_every == (moe_every-1)

    # SSM / hybrid
    ssm_state: int = 16
    d_inner_mult: int = 2
    conv_kernel: int = 4
    dt_rank: int | None = None
    attn_every: int = 0              # hybrid: layer i is attention iff i % attn_every == 0

    # attention
    rope_theta: float = 10000.0
    sliding_window: int | None = None

    # encoder-decoder
    encoder_decoder: bool = False
    n_encoder_layers: int = 0

    # modality frontend (STUB — input_specs provides precomputed embeddings)
    frontend: Literal[None, "audio", "vision"] = None
    n_frontend_tokens: int = 0

    act: str = "silu"
    gated_mlp: bool = True
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # --- derived ---
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.d_inner_mult * self.d_model

    @property
    def dtr(self) -> int:
        return self.dt_rank if self.dt_rank is not None else math.ceil(self.d_model / 16)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_ssm_only(self) -> bool:
        return self.family == "ssm"

    @property
    def is_hybrid(self) -> bool:
        return self.attn_every > 0

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (sub-quadratic sequence mixing)."""
        return self.is_ssm_only or self.is_hybrid or self.sliding_window is not None

    def padded_vocab(self, multiple: int = 16) -> int:
        return _round_up(self.vocab_size, multiple)

    def layer_pattern(self) -> tuple[LayerSpec, ...]:
        """The repeating period of layer types.

        Hybrid (jamba): period = attn_every layers, attention at position 0,
        mamba elsewhere; MoE every `moe_every` positions. Pure archs: period
        length lcm(moe_every, 1) so the scan body stays small.
        """
        if self.is_hybrid:
            period = self.attn_every
        elif self.is_moe:
            period = self.moe_every
        else:
            period = 1
        specs = []
        for i in range(period):
            mixer = "mamba" if (self.is_ssm_only or
                                (self.is_hybrid and i % self.attn_every != 0)) else "attn"
            if self.is_ssm_only:
                mlp = "none"          # mamba-1 blocks have no separate MLP
                mixer = "mamba"
            else:
                mlp = "moe" if (self.is_moe and i % self.moe_every ==
                                (self.moe_every - 1)) else "dense"
            specs.append(LayerSpec(mixer=mixer, mlp=mlp))
        assert self.n_layers % len(specs) == 0, (self.name, self.n_layers, len(specs))
        return tuple(specs)

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.layer_pattern())

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, hd = self.d_model, self.hd
        per_period = 0
        for spec in self.layer_pattern():
            if spec.mixer == "attn":
                per_period += d * (self.n_heads * hd) * 2      # wq, wo
                per_period += d * (self.n_kv_heads * hd) * 2   # wk, wv
            else:
                di, ns, dtr = self.d_inner, self.ssm_state, self.dtr
                per_period += (d * 2 * di + di * self.conv_kernel
                               + di * (dtr + 2 * ns) + dtr * di
                               + di * ns + di + di * d)
            n_proj = 3 if self.gated_mlp else 2
            if spec.mlp == "dense":
                per_period += n_proj * d * self.d_ff
            elif spec.mlp == "moe":
                per_period += (self.n_experts * n_proj * d * self.d_ff
                               + d * self.n_experts)
            per_period += 2 * d                                # norms
        n = per_period * self.n_periods
        v = self.padded_vocab()
        n += v * d * (1 if self.tie_embeddings else 2)
        if self.encoder_decoder:
            n += (d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
                  + (3 if self.gated_mlp else 2) * d * self.d_ff
                  + 2 * d) * self.n_encoder_layers
            # decoder cross-attention blocks
            n += (d * (self.n_heads * hd) * 2 +
                  d * (self.n_kv_heads * hd) * 2 + d) * self.n_layers
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        moe_layers = sum(1 for s in self.layer_pattern()
                         if s.mlp == "moe") * self.n_periods
        inactive = (moe_layers * (self.n_experts - self.top_k)
                    * (3 if self.gated_mlp else 2) * d * self.d_ff)
        return total - inactive

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        pattern = len(self.layer_pattern())
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=max(pattern, 2 if pattern == 1 else pattern),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            n_experts=min(self.n_experts, 4) if self.is_moe else 0,
            top_k=min(self.top_k, 2) if self.is_moe else 0,
            n_encoder_layers=2 if self.encoder_decoder else 0,
            n_frontend_tokens=8 if self.frontend else 0,
            sliding_window=16 if self.sliding_window else None,
            d_inner_mult=2,
            ssm_state=8,
            dt_rank=8,
        )


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""
    name: str                       # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Execution / distribution knobs."""
    # mesh
    dp_axes: tuple[str, ...] = ("data",)    # ("pod","data") multi-pod
    tp_axis: str = "model"
    fsdp: bool = True                        # shard params over dp_axes too

    # PK overlap features (paper technique on/off per site)
    pk_overlap: bool = True                  # use pk_* overlapped collectives
    reference_mode: bool = False             # force EVERY core.template
                                             # Island to its dense reference
                                             # path (stronger than
                                             # pk_overlap=False: also covers
                                             # embed/loss/decode/gpipe
                                             # islands) — debugging oracle
    pk_bidirectional: bool = False           # 2-link bidirectional rings
    comm_backend: str | None = None          # pin one CommContext backend
                                             # ("bulk"/"ring"/...; None=policy)
    comm_policy: Literal["analytic", "measured", "auto"] = "analytic"
                                             # cost source for backend=None
                                             # dispatch (core/autotune.py)
    calibration_path: str | None = None      # explicit calibration table for
                                             # comm_policy="measured"; None =
                                             # user cache then in-repo seeds
    sp_attention: Literal["ring", "ulysses", "none"] = "ring"
    moe_strategy: Literal["replicated", "a2a"] = "replicated"
    moe_chunks: int = 1                      # MoE dispatch/combine chunks;
                                             # 0 = auto (measured a2a island
                                             # rows first, analytic policy
                                             # otherwise)
    ulysses_chunks: int = 1                  # a2a chunk count for the Ulysses
                                             # island (paper Fig. 11: attention
                                             # on early head chunks overlaps
                                             # later chunks' transfer);
                                             # 0 = auto (plan override >
                                             # measured a2a rows > analytic)
    comm_chunks: int | None = None           # force the sub-chunk count of
                                             # every chunk-pipelined ring
                                             # GEMM×collective (None = per-call
                                             # kwarg > measured table > the
                                             # analytic chunk scheduler)
    comm_wire: str | None = None             # on-wire format for ring
                                             # GEMM×collectives: None/"bf16" =
                                             # full precision, "int8" =
                                             # per-block int8 + f32 scales,
                                             # "int8_sr" = int8 + stochastic
                                             # rounding (core/quant.py);
                                             # threaded to CommContext.wire

    # compute
    attention_impl: Literal["xla", "pallas"] = "xla"
    remat: bool = True
    microbatches: int = 1
    optimizer_moment_dtype: str = "float32"  # bf16 for the >=300B archs
    logits_fp32: bool = True
    loss_chunk: int = 512                    # CE loss sequence chunking
    ssm_chunk: int = 256                     # mamba scan chunk
    scan_layers: bool = True                 # False: python-unroll periods
                                             # (cost-calibration mode)
    # §Perf hillclimb knobs (EXPERIMENTS.md)
    bf16_backward_ars: bool = False          # cast residual-stream cotangents
                                             # to bf16 (halves backward ARs)
    save_collectives: bool = False           # remat policy: save sub-block
                                             # outputs so fwd psums are not
                                             # recomputed in the backward
    ssm_scan_dtype: str = "float32"          # mamba chunk-scan accum dtype
    pk_ring_psum: bool = False               # MoE combine via ppermute ring
                                             # (bf16 payload, overlappable)
    pk_attn_out_island: bool = False         # attention out-proj through the
                                             # PK GEMM+AR island

    # serving
    decode_seq_shard: bool = True            # shard KV cache seq over tp axis
    serve_moe_tp_data: bool = False          # resident 2D-TP expert weights
                                             # (ff over dp as TP, not FSDP):
                                             # no per-token weight gathers
    # per-island plan overrides: frozen ((island_name, backend, chunks), ...)
    # entries produced by core.template.plan_overrides() from resolved
    # Island.plan() reports. The serving engine evaluates island_plans() per
    # shape bucket at startup and threads the chosen backend / sub-chunk
    # count back into each bucket's CommContext through this field, so the
    # decode bucket can run a different schedule than the prefill bucket.
    # () = no overrides (policy dispatch, the default everywhere else).
    # Entries may also be 4-tuples carrying a source tag (e.g. "health" for
    # runtime demotions layered above the measured plan by the
    # runtime.health.HealthMonitor); later entries win.
    island_overrides: tuple = ()

    # runtime health (runtime/health.py)
    island_guards: bool = False              # jit-compatible finite-checks on
                                             # island inputs/outputs; trips are
                                             # logged per island (core.template
                                             # guard registry) and drained by
                                             # the serving engine each step
    comm_fault: tuple | None = None          # scripted comms-level fault for
                                             # THIS trace: (kind, island, hop)
                                             # with kind "corrupt"|"bitflip",
                                             # island name or "*"; consumed by
                                             # Island.make_context -> the ring
                                             # collectives corrupt hop's
                                             # payload. Test-only seam: set by
                                             # the serving engine when a
                                             # CommFaultPlan event is active,
                                             # never in production configs.


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Continuous-batching serving knobs (runtime/serving.py engine).

    ``bucket_edges`` are the padded prompt lengths the engine jits prefill
    steps for: a request is admitted into the smallest bucket >= its prompt
    length (strictly increasing edges). ``max_batch`` is the decode pool
    size (slots); ``prefill_batch`` the fixed prefill group size (groups are
    padded with inert slots so every bucket compiles exactly one program).
    ``queue_policy``:

    * ``"fcfs"`` — admit the queue head's bucket, taking only the contiguous
      prefix of same-bucket requests behind it (strict arrival order);
    * ``"bucket-greedy"`` — scan the whole queue for requests in the head's
      bucket to fill the group (better bucket occupancy, may reorder).

    ``exact_buckets`` disables padding (each distinct prompt length is its
    own bucket) — required for SSM/hybrid architectures, whose recurrent
    state cannot mask right-padding the way attention masks stale cache.

    ``cache_layout`` selects the KV-cache memory layout:

    * ``"slab"`` — one dense ``padded_s_max`` slab per slot (the default);
    * ``"paged"`` — a fixed global page pool (``runtime/paging.py``) with
      per-slot block tables, refcounted copy-on-write prefix sharing, and
      admission backpressure when the pool is exhausted. Attention-only
      architectures only (SSM state has no paged equivalent here).

    ``kv_dtype`` selects the stored KV-cache element type: ``"bf16"`` (the
    default — byte-identical to the historical layout) or ``"int8"``, which
    stores K/V as int8 with one f32 scale per (token, head) plane —
    quantize-on-write, dequantize-on-read — roughly halving cache HBM.

    ``page_size`` is the tokens-per-page granularity of the paged layout
    (rounded up to a multiple of the tp axis size so pages stripe evenly
    over shards). ``n_pages`` sizes the pool; 0 = auto (slab-equivalent:
    ``max_batch`` slots' worth of pages). ``prefill_chunk`` > 0 splits
    prefill across engine steps in chunks of that many tokens (page-aligned;
    must be a positive multiple of ``page_size``) so decode ticks interleave
    mid-prefill; 0 = single-shot prefill per bucket.
    """

    max_batch: int = 8
    prefill_batch: int = 4
    bucket_edges: tuple[int, ...] = (16, 32, 64)
    max_new_tokens: int = 16
    queue_policy: Literal["fcfs", "bucket-greedy"] = "fcfs"
    exact_buckets: bool = False
    cache_layout: Literal["slab", "paged"] = "slab"
    kv_dtype: Literal["bf16", "int8"] = "bf16"
    page_size: int = 16
    n_pages: int = 0
    prefill_chunk: int = 0
    # request-level robustness (runtime/health.py + engine poison handling):
    # a request whose prefill yields non-finite logits is re-queued up to
    # max_retries times with exponential backoff (retry_backoff * 2**attempt
    # engine steps) before being quarantined; deadline_steps > 0 expires
    # requests (queued or in-slot) that many steps after submission.
    max_retries: int = 1
    retry_backoff: int = 1
    deadline_steps: int = 0                  # 0 = no deadline
    # island health monitoring: when True the engine runs a
    # runtime.health.HealthMonitor over per-island step timings and demotes
    # a drifting island's backend (ring_bidir -> ring -> bulk) with
    # hysteresis through RunConfig.island_overrides, re-promoting after
    # health_probation consecutive clean samples (doubled per demotion).
    health_monitor: bool = False
    health_factor: float = 3.0
    health_demote_after: int = 2
    health_probation: int = 6

    def __post_init__(self):
        if not self.bucket_edges or \
                list(self.bucket_edges) != sorted(set(self.bucket_edges)):
            raise ValueError(
                f"bucket_edges must be strictly increasing, got "
                f"{self.bucket_edges}")
        if self.prefill_batch > self.max_batch:
            raise ValueError("prefill_batch cannot exceed max_batch")
        if self.cache_layout not in ("slab", "paged"):
            raise ValueError(f"unknown cache_layout {self.cache_layout!r}")
        if self.kv_dtype not in ("bf16", "int8"):
            raise ValueError(f"unknown kv_dtype {self.kv_dtype!r}")
        if self.page_size < 1:
            raise ValueError("page_size must be >= 1")
        if self.n_pages < 0:
            raise ValueError("n_pages must be >= 0 (0 = auto)")
        if self.prefill_chunk:
            if self.cache_layout != "paged":
                raise ValueError(
                    "prefill_chunk requires cache_layout='paged'")
            if self.prefill_chunk % self.page_size:
                raise ValueError(
                    f"prefill_chunk ({self.prefill_chunk}) must be a "
                    f"multiple of page_size ({self.page_size})")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.retry_backoff < 1:
            raise ValueError("retry_backoff must be >= 1")
        if self.deadline_steps < 0:
            raise ValueError("deadline_steps must be >= 0 (0 = no deadline)")
        if self.health_factor <= 1.0:
            raise ValueError("health_factor must be > 1")
        if self.health_demote_after < 1:
            raise ValueError("health_demote_after must be >= 1")
        if self.health_probation < 1:
            raise ValueError("health_probation must be >= 1")

    @property
    def s_max(self) -> int:
        """Cache length every bucket shares: worst prompt + generation."""
        return self.bucket_edges[-1] + self.max_new_tokens

    def bucket_for(self, prompt_len: int) -> int:
        """Padded length of the bucket admitting a prompt of this length."""
        if self.exact_buckets:
            if prompt_len > self.bucket_edges[-1]:
                raise ValueError(
                    f"prompt length {prompt_len} exceeds the largest bucket "
                    f"edge {self.bucket_edges[-1]}")
            return prompt_len
        for edge in self.bucket_edges:
            if prompt_len <= edge:
                return edge
        raise ValueError(
            f"prompt length {prompt_len} exceeds the largest bucket edge "
            f"{self.bucket_edges[-1]}")


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Multi-replica serving fleet knobs (runtime/fleet.py).

    ``router`` is the admission-steering policy the fleet's deterministic
    router runs over per-replica feedback (queue depth, live slots,
    mid-prefill rows, tokens/s, cache occupancy):

    * ``"fcfs"`` — fixed rotation over the admitting replicas in request
      order (no feedback; the baseline);
    * ``"least-loaded"`` — argmin of (queued + in-flight + prefill rows),
      lowest replica index breaks ties;
    * ``"cache-affinity"`` — paged engines only: route to the replica whose
      ``PrefixCache`` holds the longest prefix of the prompt (ties and
      misses fall back to least-loaded).

    ``step_budget`` is how many engine steps each live replica runs per
    fleet step (the cooperative interleave quantum). ``steal`` enables
    straggler-aware request stealing: queued (never in-flight) requests are
    pulled back from a replica the ``FleetWatchdog`` flags — EMA above
    ``steal_factor`` x the live-median, a blown per-replica deadline, or a
    scripted stall — and rerouted. ``stall_dt`` is the synthetic step time
    a stalled (fault-injected ``delay``) tick records into that replica's
    watchdog feed, so scripted faults drive the same signal real slowness
    would."""

    n_replicas: int = 2
    router: Literal["fcfs", "least-loaded", "cache-affinity"] = \
        "least-loaded"
    step_budget: int = 1
    steal: bool = True
    steal_factor: float = 3.0
    stall_dt: float = 1.0

    def __post_init__(self):
        if self.n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        if self.router not in ("fcfs", "least-loaded", "cache-affinity"):
            raise ValueError(f"unknown router {self.router!r}")
        if self.step_budget < 1:
            raise ValueError("step_budget must be >= 1")
        if self.steal_factor <= 1.0:
            raise ValueError("steal_factor must exceed 1.0")
