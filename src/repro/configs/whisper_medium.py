"""Whisper-medium — encoder-decoder audio backbone; conv frontend is a STUB
(input_specs provides precomputed frame embeddings). [arXiv:2212.04356]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab_size=51865, head_dim=64, act="gelu", gated_mlp=False,
    encoder_decoder=True, n_encoder_layers=24, frontend="audio",
)
