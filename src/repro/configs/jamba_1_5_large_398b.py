"""Jamba-1.5-Large 398B — hybrid Mamba+attention 1:7, MoE 16e top-2.
[arXiv:2403.19887; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
    vocab_size=65536, head_dim=128,
    n_experts=16, top_k=2, moe_every=2,
    attn_every=8, ssm_state=16, d_inner_mult=2, conv_kernel=4,
)
