"""Architecture registry — `--arch <id>` selects one of the 10 assigned
configs (DESIGN.md §6). Each module exposes CONFIG: ArchConfig."""

from repro.configs.base import ArchConfig, RunConfig, ShapeCell, SHAPES, LayerSpec

_MODULES = {
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "internlm2-20b": "internlm2_20b",
    "starcoder2-15b": "starcoder2_15b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "whisper-medium": "whisper_medium",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "grok-1-314b": "grok_1_314b",
    "internvl2-26b": "internvl2_26b",
    "falcon-mamba-7b": "falcon_mamba_7b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    import importlib
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}").CONFIG


def cells_for(arch_id: str) -> list[str]:
    """Shape cells this arch runs (DESIGN.md §6 skips)."""
    cfg = get_config(arch_id)
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        cells.append("long_500k")
    return cells
