"""StarCoder2-15B — dense GQA, RoPE. [arXiv:2402.19173; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, d_ff=24576,
    vocab_size=49152, head_dim=128, act="gelu", gated_mlp=False, rope_theta=1e5,
)
