"""Model layers: norms, RoPE, GQA attention (causal/SWA/cross/decode), MLP,
MoE wrapper, vocab-parallel embedding and loss.

Everything is functional: `fn(params_subtree, x, ...)`. Activation sharding
is maintained with with_sharding_constraint (XLA Auto skeleton); every
PK-overlapped path is declared as a ``repro.core.template.Island`` — the
paper's unified §3.2 template — switched by RunConfig (DESIGN.md §3). The
``*_island`` builders below are trace-free: constructing one costs nothing,
so ``island_plans()`` can report the whole forward pass's overlap schedule
(backend / chunks / hidden fraction per island) without running the model.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, RunConfig
from repro.core import moe as pk_moe
from repro.core import pk_ring_attention, pk_ulysses_attention
from repro.core.autotune import island_key
from repro.core.quant import resolve_wire
from repro.core.template import (Comm, Gather, Island, IslandPlan,
                                 comm_context, island_override)
from repro.models.sharding import ShardingRules

NEG_INF = -1e30


def constrain(x, rules: ShardingRules | None, spec: P):
    if rules is None:
        return x
    return lax.with_sharding_constraint(x, rules.named(spec))


def _dtype_bytes(cfg: ArchConfig) -> int:
    return 2 if cfg.dtype == "bfloat16" else 4


def _wire_bytes(cfg: ArchConfig, run: RunConfig) -> int:
    """Element width a GEMM island's ``Comm`` declares. Under a quantized
    ``RunConfig.comm_wire`` this is the *wire* width (1 for int8) — the
    island key becomes ``...|b1``, so ``calibrate --per-island`` rows and
    measured dispatch both resolve at the width the ring actually ships —
    else the tensor dtype's own width."""
    fmt = resolve_wire(getattr(run, "comm_wire", None))
    return fmt.dtype_bytes if fmt is not None else _dtype_bytes(cfg)


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------

def rms_norm(w, x, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def get_act(name: str):
    return {"silu": jax.nn.silu, "gelu": functools.partial(jax.nn.gelu, approximate=True),
            "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (B, H, S, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[:, None] * inv[None, :]   # (S, hd/2)
        cos, sin = jnp.cos(ang)[None, None], jnp.sin(ang)[None, None]
    else:
        ang = positions.astype(jnp.float32)[..., None] * inv
        cos, sin = jnp.cos(ang)[:, None], jnp.sin(ang)[:, None]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _full_attention(q, k, v, *, causal, window, q_offset=0, kv_len=None,
                    scale=None):
    """q: (B,Hq,Sq,hd); k,v: (B,Hkv,Skv,hd). fp32 softmax, GQA grouped.

    ``kv_len`` (valid cache prefix) may be a scalar or a per-slot ``(B,)``
    vector — the continuous-batching decode pool holds sequences at
    different positions in one batch."""
    b, hq, sq, hd = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else hd ** -0.5
    qg = q.reshape(b, hkv, g, sq, hd)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    qi = q_offset + jnp.arange(sq)[:, None]
    ki = jnp.arange(skv)[None, :]
    keep = jnp.ones((sq, skv), bool)
    if causal:
        keep &= ki <= qi
    if window is not None:
        keep &= ki > qi - window
    if kv_len is not None:                      # decode: valid cache prefix
        if jnp.ndim(kv_len):                    # per-slot (B,) prefix
            keep = keep[None] & (ki[None] < kv_len[:, None, None])
        else:
            keep = keep & (ki < kv_len)
    mask = keep if keep.ndim == 2 else keep[:, None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, hd).astype(q.dtype)


def _chunked_attention(q, k, v, *, causal, window, scale=None,
                       qc: int = 512, kc: int = 1024):
    """Memory-bounded XLA attention (flash-style online softmax over kv
    chunks inside a scan over q chunks) — the jnp twin of
    kernels/flash_attention.py, used when S·Skv would blow HBM (32k+ prefill).
    Fully-masked kv blocks are skipped via lax.cond (real compute savings,
    same as the kernel's block schedule)."""
    b, hq, s, hd = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else hd ** -0.5
    qc = min(qc, s)
    kc = min(kc, skv)
    assert s % qc == 0 and skv % kc == 0, (s, qc, skv, kc)
    nq, nk = s // qc, skv // kc
    qg = q.reshape(b, hkv, g, nq, qc, hd).transpose(3, 0, 1, 2, 4, 5)
    kb = k.reshape(b, hkv, nk, kc, hd)
    vb = v.reshape(b, hkv, nk, kc, hd)

    def one_q_block(args):
        qi, qblk = args                                  # (b,hkv,g,qc,hd)
        m0 = jnp.full((b, hkv, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, qc), jnp.float32)
        o0 = jnp.zeros((b, hkv, g, qc, hd), jnp.float32)

        def kv_step(carry, ki):
            m, l, o = carry
            k_i = lax.dynamic_index_in_dim(kb, ki, 2, keepdims=False)
            v_i = lax.dynamic_index_in_dim(vb, ki, 2, keepdims=False)
            q_lo = qi * qc
            k_lo = ki * kc
            run_blk = jnp.bool_(True)
            if causal:
                run_blk &= k_lo <= q_lo + qc - 1
            if window is not None:
                run_blk &= k_lo + kc - 1 > q_lo - window

            def do(args):
                m_, l_, o_ = args
                sc = jnp.einsum("bkgqd,bksd->bkgqs", qblk, k_i,
                                preferred_element_type=jnp.float32) * scale
                rows = q_lo + jnp.arange(qc)[:, None]
                cols = k_lo + jnp.arange(kc)[None, :]
                keep = jnp.ones((qc, kc), bool)
                if causal:
                    keep &= cols <= rows
                if window is not None:
                    keep &= cols > rows - window
                sc = jnp.where(keep, sc, NEG_INF)
                m_new = jnp.maximum(m_, sc.max(axis=-1))
                p_ = jnp.exp(sc - m_new[..., None])
                alpha = jnp.exp(m_ - m_new)
                l_new = l_ * alpha + p_.sum(axis=-1)
                o_new = o_ * alpha[..., None] + jnp.einsum(
                    "bkgqs,bksd->bkgqd", p_, v_i.astype(jnp.float32))
                return m_new, l_new, o_new

            return lax.cond(run_blk, do, lambda a: a, (m, l, o)), None

        (m, l, o), _ = lax.scan(kv_step, (m0, l0, o0), jnp.arange(nk))
        return o / jnp.maximum(l, 1e-30)[..., None]

    out = lax.map(one_q_block, (jnp.arange(nq), qg))     # (nq,b,hkv,g,qc,hd)
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, s, hd)
    return out.astype(q.dtype)


# kv lengths at/above this use the chunked path (HBM budget, DESIGN §5)
XLA_ATTN_CHUNK_THRESHOLD = 8192


def sp_attention_island(cfg: ArchConfig, run: RunConfig,
                        rules: ShardingRules | None, b: int, s: int, *,
                        causal: bool = True, reference=None) -> Island:
    """Sequence-parallel attention island: ring attention (paper §4.2) or
    Ulysses a2a attention over the tp axis, q/k/v seq-sharded on dim 2."""
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    if rules is None:
        return Island("attn_sp", run=run, reference=reference)
    axis = rules.tp
    tp_size = rules.mesh.shape[axis]
    ulysses = run.sp_attention == "ulysses"
    fn = pk_ulysses_attention if ulysses else pk_ring_attention
    bspec = rules.dim(b, rules.dp)
    spec = P(bspec, None, axis, None)
    b_loc = rules.local_batch(b)
    s_loc = max(s // tp_size, 1)
    dtb = _dtype_bytes(cfg)
    divisible = [(s, axis)] + ([(hq, axis)] if ulysses else [])
    if ulysses:
        # RunConfig.ulysses_chunks reaches the paper-Fig. 11 chunked-a2a
        # overlap: attention on early head chunks hides later chunks' a2a.
        # The local payload shape lets plan() fit the count to the
        # splittable bystander dims exactly like the runtime a2a will.
        # ulysses_chunks=0 means AUTO: a frozen serving-bucket plan
        # (RunConfig.island_overrides) wins, then measured a2a rows from
        # `calibrate --per-island` (island-keyed first), then the analytic
        # chunk policy — the a2a twin of the GEMM chunk-schedule precedence.
        shape = (b_loc, hq, s_loc, hd)
        ov = island_override(run, "attn_ulysses")
        source = None
        if ov is not None and ov[1] is not None:
            a2a_chunks = max(1, ov[1])
            source = ov[2]          # "plan", or "health" for a demotion
        elif run.ulysses_chunks > 0:
            a2a_chunks = run.ulysses_chunks
        else:
            ctx = comm_context(run, axis, mesh=rules.mesh,
                               island=island_key("attn_ulysses",
                                                 "all_to_all", dtb))
            sched = ctx.a2a_chunk_schedule(shape, 1, 2, dtype_bytes=dtb)
            a2a_chunks, source = sched.n_chunks, sched.source
        comm = Comm("all_to_all", n_chunks=a2a_chunks,
                    backend="chunked" if a2a_chunks > 1 else "bulk",
                    payload_bytes=b_loc * hq * s_loc * hd * dtb,
                    shape=shape, split_axis=1, concat_axis=2,
                    source=source)
    else:
        comm = Comm("ring_shift", backend="bulk", n_chunks=tp_size,
                    payload_bytes=2 * b_loc * hkv * s_loc * hd * dtb)

    def body(ctx, q, k, v):
        kw = {"n_chunks": comm.n_chunks} if ulysses else {}
        return fn(q, k, v, axis, causal=causal, window=cfg.sliding_window,
                  ctx=ctx, **kw)

    return Island(f"attn_{run.sp_attention}", rules=rules, run=run,
                  inputs={"q": spec, "k": spec, "v": spec}, out_specs=spec,
                  body=body, reference=reference, divisible=divisible,
                  comm=comm)


def attn_out_island(cfg: ArchConfig, run: RunConfig,
                    rules: ShardingRules | None, b: int, s: int) -> Island:
    """Attention out-projection as the PK GEMM+AR island (paper Fig. 9): the
    head-sharded context × row-sharded wo, ring-overlapped all-reduce."""
    hq, hd, d = cfg.n_heads, cfg.hd, cfg.d_model
    h_full = hq * hd

    def reference(o, wo):
        return jnp.einsum("bsh,hd->bsd", o, wo)

    if rules is None:
        return Island("attn_out", run=run, reference=reference)
    tp = rules.tp
    tp_size = rules.mesh.shape[tp]
    bspec = rules.dim(b, rules.dp)
    b_loc = rules.local_batch(b)

    def body(ctx, o, wo):
        t = o.reshape(-1, o.shape[-1])
        out = ctx.matmul_all_reduce(t, wo)
        return out.reshape(o.shape[0], s, d)

    return Island(
        "attn_out", rules=rules, run=run,
        inputs={"o": P(bspec, None, rules.dim(h_full, tp)),
                "wo": rules.w2d(h_full, d, tp_dim=0)},
        out_specs=P(bspec, None, None),
        body=body, reference=reference,
        gathers={"wo": Gather(dim=1, size=d)},
        enable=run.pk_attn_out_island,
        divisible=((h_full, tp), (b * s, tp)),
        comm=Comm("matmul_all_reduce", m=b_loc * s, n=d,
                  k=h_full // tp_size if h_full % tp_size == 0 else h_full,
                  dtype_bytes=_wire_bytes(cfg, run)))


def attention_block(p, x, cfg: ArchConfig, run: RunConfig,
                    rules: ShardingRules | None, *, causal=True,
                    positions=None, cross_kv=None, seq_sharded=False):
    """Full attention sub-layer (projections + mixing + out-proj).

    p: {"wq","wk","wv","wo"}; x: (B, S, d) [if seq_sharded: ring/ulysses
    attention runs over the tp axis via the SP island].
    cross_kv: precomputed (k, v) for cross-attention (enc-dec decoder).
    """
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, s, hq, hd)
    q = q.transpose(0, 2, 1, 3)
    if cross_kv is None:
        k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
        v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    else:
        k, v = cross_kv
    if positions is None:
        positions = jnp.arange(s)
    if cross_kv is None:                         # RoPE on self-attention only
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    win = cfg.sliding_window if cross_kv is None else None

    def dense_mix(q, k, v):
        if rules is not None:
            q = constrain(q, rules, rules.act_bhsd(hq))
        if k.shape[2] >= XLA_ATTN_CHUNK_THRESHOLD:
            return _chunked_attention(q, k, v, causal=causal, window=win)
        return _full_attention(q, k, v, causal=causal, window=win)

    if seq_sharded and rules is not None:
        island = sp_attention_island(cfg, run, rules, b, s, causal=causal,
                                     reference=dense_mix)
        o = island(q=q, k=k, v=v)
    else:
        o = dense_mix(q, k, v)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    out = attn_out_island(cfg, run, rules, b, s)(o=o, wo=p["wo"])
    if rules is not None:
        out = constrain(out, rules, rules.act_btd())
    return out


def _cache_write(cache, new, pos):
    """Write a one-token K/V block into the cache's seq dim at ``pos``.

    cache: (B, H, S, hd); new: (B, H, 1, hd). Scalar ``pos`` is the classic
    lockstep decode (dynamic_update_slice); a ``(B,)`` vector writes each
    slot at its own position via a one-hot select — the continuous-batching
    pool's slots sit at different depths. Out-of-range vector positions
    write nothing (the engine parks inactive slots past their cache).
    Rank-3 ``(B, H, S)`` caches (the int8 mode's per-token scale planes,
    seq still dim 2) take the same write."""
    if jnp.ndim(pos) == 0:
        return lax.dynamic_update_slice(
            cache, new.astype(cache.dtype),
            (0, 0, pos) + (0,) * (cache.ndim - 3))
    oh = jnp.arange(cache.shape[2])[None, :] == pos[:, None]       # (B, S)
    mask = oh[:, None, :, None] if cache.ndim == 4 else oh[:, None, :]
    return jnp.where(mask, new.astype(cache.dtype), cache)


# int8 KV cache (ServeConfig.kv_dtype="int8"): K/V stored as int8 with ONE
# f32 scale per (token, head) — quantize on write, dequantize on read. The
# scale planes are cache-shaped minus the hd dim and ride in the cache tree
# as "k_scale"/"v_scale" leaves; quantization is detected from the cache
# dtype, so kv_dtype="bf16" trees never touch this path.

KV_SCALE_EPS = 1e-12


def _kv_quantize(new):
    """Symmetric per-(token, head) int8: ``new (..., hd)`` ->
    ``(q int8, scale f32 of shape new.shape[:-1])``."""
    f = new.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(f), axis=-1), KV_SCALE_EPS) / 127.0
    q = jnp.clip(jnp.round(f / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def _kv_dequantize(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def decode_island(cfg: ArchConfig, run: RunConfig,
                  rules: ShardingRules | None, b: int, s_max: int, *,
                  long_ctx: bool, pos, kv_len, window,
                  quant: bool = False) -> Island:
    """One-token decode over the sequence-sharded KV cache: shard-local slot
    write + flash-decode logsumexp merge over the tp axis (DESIGN §4). The
    cache write happens INSIDE the island — a dynamic_update_slice on a
    seq-sharded array at the jit level would force XLA to all-gather the
    whole cache (GBs per token). ``pos``/``kv_len`` may be scalars (lockstep
    decode) or per-slot ``(B,)`` vectors (the serving engine's mixed pool).
    ``quant``: the cache is int8 with per-(token, head) f32 scale planes
    (``cache_ks``/``cache_vs`` inputs) — the new token is quantized before
    its write and the whole cache dequantized for the mix."""
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    vec = jnp.ndim(pos) > 0

    def _mix(q, k_, v_, offset, s_loc, axis, pos_in):
        """Local partial attention + logsumexp merge over the axis.
        ``pos_in`` scalar or (B_loc,) — the shard-local slice of pos."""
        g = hq // hkv
        qg = q.reshape(q.shape[0], hkv, g, 1, hd)
        s_ = jnp.einsum("bkgqd,bksd->bkgqs", qg, k_,
                        preferred_element_type=jnp.float32) * hd ** -0.5
        ki = offset + jnp.arange(s_loc)[None, None, None, None, :]
        kvl = pos_in[:, None, None, None, None] + 1 if vec else kv_len
        keep = ki < kvl
        if window is not None:
            keep &= ki > (kvl - 1) - window
        s_ = jnp.where(keep, s_, NEG_INF)
        m_loc = s_.max(axis=-1)                                # (b,k,g,1)
        m_glob = lax.pmax(m_loc, axis)
        p_ = jnp.exp(s_ - m_glob[..., None])
        l_loc = p_.sum(axis=-1)
        o_loc = jnp.einsum("bkgqs,bksd->bkgqd", p_, v_.astype(jnp.float32))
        l_glob = lax.psum(l_loc, axis)
        o_glob = lax.psum(o_loc, axis)
        o = o_glob / jnp.maximum(l_glob, 1e-30)[..., None]
        return o.reshape(q.shape[0], hq, 1, hd).astype(q.dtype)

    # Per-slot (vector) pos is an ISLAND INPUT sharded like the batch — a
    # closure capture would hand every shard the global-batch vector while
    # its arrays are dp-local. The scalar (lockstep) form keeps the closure.
    def reference(q, cache_k, cache_v, k_new, v_new, **kw):
        p_ = kw.get("pos", pos)
        if quant:
            qk, sk = _kv_quantize(k_new)
            qv, sv = _kv_quantize(v_new)
            ck = _cache_write(cache_k, qk, p_)
            cv = _cache_write(cache_v, qv, p_)
            ks = _cache_write(kw["cache_ks"], sk, p_)
            vs = _cache_write(kw["cache_vs"], sv, p_)
            o = _full_attention(q, _kv_dequantize(ck, ks, q.dtype),
                                _kv_dequantize(cv, vs, q.dtype),
                                causal=False, window=window, q_offset=0,
                                kv_len=p_ + 1 if vec else kv_len)
            return o, ck, cv, ks, vs
        ck = _cache_write(cache_k, k_new, p_)
        cv = _cache_write(cache_v, v_new, p_)
        o = _full_attention(q, ck, cv, causal=False, window=window,
                            q_offset=0,
                            kv_len=p_ + 1 if vec else kv_len)
        return o, ck, cv

    if rules is None:
        return Island("decode_attn", run=run, reference=reference)
    tp = rules.tp
    axis = (tuple(run.dp_axes) + (tp,)) if long_ctx else tp
    cache_spec = rules.kv_cache(hkv, b, long_ctx=long_ctx)
    scale_spec = P(*cache_spec[:3])
    bspec = None if long_ctx else rules.dim(b, rules.dp)
    qspec = P(bspec, None, None, None)

    def body(ctx, q, cache_k, cache_v, k_new, v_new, **kw):
        p_ = kw.get("pos", pos)
        ax_idx = lax.axis_index(axis)
        s_loc = cache_k.shape[2]
        offset = ax_idx * s_loc
        # shard-local cache update (one-sided, pre-allocated slot — the
        # PK §3.1.4 principle applied to the KV cache)
        local_pos = p_ - offset
        hit = (local_pos >= 0) & (local_pos < s_loc)
        lp = jnp.clip(local_pos, 0, s_loc - 1)

        if vec:
            def upd(c, n):
                oh = (jnp.arange(s_loc)[None, :] == lp[:, None]) \
                    & hit[:, None]                             # (B, s_loc)
                mask = oh[:, None, :, None] if c.ndim == 4 else oh[:, None, :]
                return jnp.where(mask, n.astype(c.dtype), c)
        else:
            def upd(c, n):
                new = lax.dynamic_update_slice(
                    c, n.astype(c.dtype), (0, 0, lp) + (0,) * (c.ndim - 3))
                return lax.cond(hit, lambda: new, lambda: c)

        if quant:
            qk, sk = _kv_quantize(k_new)
            qv, sv = _kv_quantize(v_new)
            ck, cv = upd(cache_k, qk), upd(cache_v, qv)
            ks, vs = upd(kw["cache_ks"], sk), upd(kw["cache_vs"], sv)
            k_ = _kv_dequantize(ck, ks, q.dtype)
            v_ = _kv_dequantize(cv, vs, q.dtype)
            return (_mix(q, k_, v_, offset, s_loc, axis, p_),
                    ck, cv, ks, vs)
        k_ = upd(cache_k, k_new)
        v_ = upd(cache_v, v_new)
        return (_mix(q, k_, v_, offset, s_loc, axis, p_), k_, v_)

    inputs = {"q": qspec, "cache_k": cache_spec, "cache_v": cache_spec,
              "k_new": qspec, "v_new": qspec}
    if quant:
        inputs["cache_ks"] = scale_spec
        inputs["cache_vs"] = scale_spec
    if vec:
        inputs["pos"] = P(bspec)
    return Island(
        "decode_attn", rules=rules, run=run, axis=tp, fallback_axes=axis,
        inputs=inputs,
        out_specs=((qspec, cache_spec, cache_spec, scale_spec, scale_spec)
                   if quant else (qspec, cache_spec, cache_spec)),
        body=body, reference=reference,
        enable=run.decode_seq_shard,
        divisible=((s_max, axis),),
        comm=Comm("psum", backend="bulk", n_chunks=1,
                  payload_bytes=2 * b * hq * hd * 4))


def decode_attention(p, x, cache_k, cache_v, pos, cfg: ArchConfig,
                     run: RunConfig, rules: ShardingRules | None, *,
                     cross_kv=None, long_ctx=False, k_scale=None,
                     v_scale=None):
    """One-token decode with KV cache.

    x: (B, 1, d); cache_k/v: (B, Hkv, S_max, hd); pos: scalar current index.
    Returns (out (B,1,d), new_k, new_v). If run.decode_seq_shard, attention
    over the sharded cache runs through the decode Island — the SP serving
    path (DESIGN §4); the template's fallback predicate routes single-device
    or indivisible meshes to the dense cache path.

    int8 mode: ``cache_k`` is int8 and ``k_scale``/``v_scale`` carry the
    per-(token, head) f32 scale planes — the new token is quantized on
    write, the cache dequantized on read, and the return grows to
    (out, new_k, new_v, new_k_scale, new_v_scale).
    """
    b, _, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    quant = k_scale is not None
    cache_k_in, cache_v_in = cache_k, cache_v
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, 1, hq, hd).transpose(0, 2, 1, 3)
    if cross_kv is None:
        k_new = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(b, 1, hkv, hd).transpose(0, 2, 1, 3)
        v_new = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(b, 1, hkv, hd).transpose(0, 2, 1, 3)
        # scalar pos = lockstep decode; (B,) pos = per-slot positions (the
        # serving engine's mixed pool) — RoPE takes the (B, 1) form directly
        positions = pos[:, None] if jnp.ndim(pos) else jnp.full((1,), pos)
        q = apply_rope(q, positions, cfg.rope_theta)
        k_new = apply_rope(k_new, positions, cfg.rope_theta)
        kv_len = pos + 1          # cache write is deferred (see below)
    else:
        k_att, v_att = cross_kv
        kv_len = k_att.shape[2]

    window = cfg.sliding_window if cross_kv is None else None
    if rules is not None and run.decode_seq_shard and cross_kv is None:
        island = decode_island(cfg, run, rules, b, cache_k_in.shape[2],
                               long_ctx=long_ctx, pos=pos, kv_len=kv_len,
                               window=window, quant=quant)
        kw = {"pos": pos} if jnp.ndim(pos) else {}
        if quant:
            kw["cache_ks"], kw["cache_vs"] = k_scale, v_scale
            o, cache_k, cache_v, k_scale, v_scale = island(
                q=q, cache_k=cache_k_in, cache_v=cache_v_in, k_new=k_new,
                v_new=v_new, **kw)
        else:
            o, cache_k, cache_v = island(q=q, cache_k=cache_k_in,
                                         cache_v=cache_v_in, k_new=k_new,
                                         v_new=v_new, **kw)
    else:
        if cross_kv is None:
            if quant:
                qk, sk = _kv_quantize(k_new)
                qv, sv = _kv_quantize(v_new)
                cache_k = _cache_write(cache_k_in, qk, pos)
                cache_v = _cache_write(cache_v_in, qv, pos)
                k_scale = _cache_write(k_scale, sk, pos)
                v_scale = _cache_write(v_scale, sv, pos)
                k_att = _kv_dequantize(cache_k, k_scale, q.dtype)
                v_att = _kv_dequantize(cache_v, v_scale, q.dtype)
            else:
                cache_k = _cache_write(cache_k_in, k_new, pos)
                cache_v = _cache_write(cache_v_in, v_new, pos)
                k_att, v_att = cache_k, cache_v
        o = _full_attention(q, k_att, v_att, causal=False, window=window,
                            q_offset=0, kv_len=kv_len)
        # causal handled via kv_len (all cached positions <= pos are visible);
        # SWA via window against kv_len-1.
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, hq * hd)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"])
    if cross_kv is not None:
        return out, None, None
    if quant:
        return out, cache_k, cache_v, k_scale, v_scale
    return out, cache_k, cache_v


def prefill_write_island(cfg: ArchConfig, run: RunConfig,
                         rules: ShardingRules | None, b: int,
                         L: int, *, quant: bool = False) -> Island:
    """Shard-local write of a prompt's K/V block into the sequence-sharded
    cache: each tp shard takes its own [off, off+s_loc) window of the
    (replicated, activation-sized) new K/V. A ``dynamic_update_slice`` on
    the sharded cache at the jit level would make XLA re-shard /
    all-gather the whole cache per layer — the same trap decode_island's
    in-island write avoids for the one-token case. ``quant``: ``new`` is
    the pre-quantized int8 block and ``new_s`` its per-(token, head) scale
    plane; both land in the sharded (cache, scale) pair."""
    hkv = cfg.n_kv_heads

    if quant:
        def reference(cache, scale, new, new_s):
            cache = lax.dynamic_update_slice(cache, new.astype(cache.dtype),
                                             (0, 0, 0, 0))
            scale = lax.dynamic_update_slice(scale, new_s, (0, 0, 0))
            return cache, scale
    else:
        def reference(cache, new):
            return lax.dynamic_update_slice(cache, new.astype(cache.dtype),
                                            (0, 0, 0, 0))

    if rules is None:
        return Island("prefill_write", run=run, reference=reference)
    tp = rules.tp
    cache_spec = rules.kv_cache(hkv, b)
    scale_spec = P(*cache_spec[:3])
    bspec = rules.dim(b, rules.dp)

    if quant:
        def body(ctx, cache, scale, new, new_s):
            s_loc = cache.shape[2]
            off = lax.axis_index(tp) * s_loc
            idx = off + jnp.arange(s_loc)              # global positions
            window = jnp.take(new, jnp.clip(idx, 0, L - 1), axis=2)
            swin = jnp.take(new_s, jnp.clip(idx, 0, L - 1), axis=2)
            hit = idx < L
            cache = jnp.where(hit[None, None, :, None],
                              window.astype(cache.dtype), cache)
            scale = jnp.where(hit[None, None, :], swin, scale)
            return cache, scale

        return Island(
            "prefill_write", rules=rules, run=run,
            inputs={"cache": cache_spec, "scale": scale_spec,
                    "new": P(bspec, None, None, None),
                    "new_s": P(bspec, None, None)},
            out_specs=(cache_spec, scale_spec),
            body=body, reference=reference,
            enable=run.decode_seq_shard)

    def body(ctx, cache, new):
        s_loc = cache.shape[2]
        off = lax.axis_index(tp) * s_loc
        idx = off + jnp.arange(s_loc)                  # global positions
        window = jnp.take(new, jnp.clip(idx, 0, L - 1), axis=2)
        hit = (idx < L)[None, None, :, None]
        return jnp.where(hit, window.astype(cache.dtype), cache)

    return Island(
        "prefill_write", rules=rules, run=run,
        inputs={"cache": cache_spec, "new": P(bspec, None, None, None)},
        out_specs=cache_spec,
        body=body, reference=reference,
        enable=run.decode_seq_shard)


def prefill_attention_block(p, x, cache_k, cache_v, cfg: ArchConfig,
                            run: RunConfig, rules: ShardingRules | None,
                            *, k_scale=None, v_scale=None):
    """Batched prefill: causal attention over the whole (padded) prompt with
    the K/V written into the decode cache at positions [0, L).

    The serving engine's prefill bucket runs this at the bucket's (B, L) —
    so the attention out-projection island here sees m = B_loc·L and can
    resolve to a *different* backend/chunk schedule than the decode bucket's
    m = B_loc·1 call (the whole point of per-bucket plans). Right-padding is
    safe: rows past a slot's real length are causal-masked garbage the
    caller discards, and the padded cache tail is never attended because
    decode masks ``ki < kv_len`` with ``kv_len`` the slot's real position.
    Returns (out (B, L, d), new_cache_k, new_cache_v) — plus the updated
    ``k_scale``/``v_scale`` planes in int8 mode, where the prompt's K/V is
    quantized once and the prefill attends over the dequantized values so
    prefill logits see exactly what later decode steps will read.
    """
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    quant = k_scale is not None
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, s, hq, hd).transpose(0, 2, 1, 3)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    positions = jnp.arange(s)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if rules is not None:
        q = constrain(q, rules, rules.act_bhsd(hq))
    if quant:
        qk, sk = _kv_quantize(k)
        qv, sv = _kv_quantize(v)
        k_att = _kv_dequantize(qk, sk, q.dtype)
        v_att = _kv_dequantize(qv, sv, q.dtype)
    else:
        k_att, v_att = k, v
    win = cfg.sliding_window
    if s >= XLA_ATTN_CHUNK_THRESHOLD:
        o = _chunked_attention(q, k_att, v_att, causal=True, window=win)
    else:
        o = _full_attention(q, k_att, v_att, causal=True, window=win)
    write = prefill_write_island(cfg, run, rules, b, s, quant=quant)
    if quant:
        new_k, k_scale = write(cache=cache_k, scale=k_scale, new=qk,
                               new_s=sk)
        new_v, v_scale = write(cache=cache_v, scale=v_scale, new=qv,
                               new_s=sv)
    else:
        new_k = write(cache=cache_k, new=k)
        new_v = write(cache=cache_v, new=v)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    out = attn_out_island(cfg, run, rules, b, s)(o=o, wo=p["wo"])
    if rules is not None:
        out = constrain(out, rules, rules.act_btd())
    if quant:
        return out, new_k, new_v, k_scale, v_scale
    return out, new_k, new_v


# ---------------------------------------------------------------------------
# Paged KV cache (runtime/paging.py pool + block tables)
# ---------------------------------------------------------------------------
#
# The paged islands are the block-table twins of decode_island /
# prefill_write_island: the page *interior* is striped over the tp axis
# exactly like the slab's sequence dim, so each shard writes its own stripe
# of every page and attention keeps the flash-decode logsumexp merge. The
# only new machinery is indexing: reads gather pages through the block
# table, writes scatter with mode="drop" so rows whose block table is the
# engine's -1 sentinel (free slots, slots mid-prefill) write nothing — that
# is what makes interleaving decode ticks between prefill chunks safe.


def _paged_gather(pool, bt):
    """pool (N, Hkv, s[, hd]); bt (B, P) ids (clipped) -> (B, Hkv, P*s[, hd]).
    Rank-3 pools are the int8 mode's per-(token, head) scale planes."""
    g = pool[jnp.clip(bt, 0, pool.shape[0] - 1)]       # (B, P, Hkv, s[, hd])
    g = jnp.moveaxis(g, 1, 2)                          # (B, Hkv, P, s[, hd])
    b, hk, pm, s = g.shape[:4]
    return g.reshape(b, hk, pm * s, *g.shape[4:])


def _page_positions(pmax: int, ps: int, off, s_loc: int):
    """Global cache position of every gathered cell: (P*s_loc,)."""
    return (jnp.arange(pmax)[:, None] * ps
            + off + jnp.arange(s_loc)[None, :]).reshape(-1)


def _paged_mix(q, gk, gv, ki, *, kv_len=None, q_pos=None, window, axis):
    """Attention over gathered pages. ``ki`` maps each gathered cell to its
    global cache position; masking is ``ki < kv_len`` (decode, per-slot) or
    ``ki <= q_pos`` (prefill chunk, causal vs. global query positions), so
    allocated-but-unwritten page tails are never attended. ``axis`` None =
    dense (full pages); else shard-local partials + logsumexp merge."""
    b, hq, sq, hd = q.shape
    hkv = gk.shape[1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, sq, hd)
    s_ = jnp.einsum("bkgqd,bksd->bkgqs", qg, gk,
                    preferred_element_type=jnp.float32) * hd ** -0.5
    kib = ki[None, None, None, None, :]
    if kv_len is not None:
        keep = kib < kv_len[:, None, None, None, None]
        lim = kv_len[:, None, None, None, None] - 1
    else:
        qp = q_pos[None, None, None, :, None]
        keep = kib <= qp
        lim = qp
    if window is not None:
        keep = keep & (kib > lim - window)
    s_ = jnp.where(keep, s_, NEG_INF)
    m_loc = s_.max(axis=-1)
    if axis is None:
        p_ = jnp.exp(s_ - m_loc[..., None])
        l_ = p_.sum(axis=-1)
        o = jnp.einsum("bkgqs,bksd->bkgqd", p_, gv.astype(jnp.float32))
    else:
        m_glob = lax.pmax(m_loc, axis)
        p_ = jnp.exp(s_ - m_glob[..., None])
        l_ = lax.psum(p_.sum(axis=-1), axis)
        o = lax.psum(
            jnp.einsum("bkgqs,bksd->bkgqd", p_, gv.astype(jnp.float32)),
            axis)
    o = o / jnp.maximum(l_, 1e-30)[..., None]
    return o.reshape(b, hq, sq, hd).astype(q.dtype)


def _paged_decode_write(pool, new, bt, pos, ps: int, off, s_loc: int):
    """Scatter one token per slot into its block-table page at ``pos``.
    Misses (position outside this shard's stripe, unmapped page) drop."""
    n = pool.shape[0]
    pmax = bt.shape[1]
    lp = jnp.clip(pos // ps, 0, pmax - 1)
    pid = jnp.take_along_axis(bt, lp[:, None], axis=1)[:, 0]
    r = pos % ps
    hit = (r >= off) & (r < off + s_loc) & (pid >= 0)
    rl = jnp.clip(r - off, 0, s_loc - 1)
    pid_safe = jnp.where(hit, pid, n)
    return pool.at[pid_safe, :, rl].set(
        new[:, :, 0].astype(pool.dtype), mode="drop")


def _paged_chunk_write(pool, new, bt, c0, wf, ps: int, off, s_loc: int):
    """Write one prefill chunk's K/V (``new``, positions [c0, c0+sq)) into
    block-table pages: gather the touched pages, select per cell between the
    chunk value and the current content, scatter whole pages back. The
    per-cell select is what makes copy-on-write prefix resume sound —
    positions below ``wf`` (per-slot ``write_from``) keep the donor pages'
    values byte-for-byte even though the boundary chunk recomputes them.
    Rank-3 (pool, new) pairs — the int8 scale planes — take the same
    write with the hd dim absent."""
    n = pool.shape[0]
    b, hk, sq = new.shape[:3]
    pmax = bt.shape[1]
    npg = -(-sq // ps)
    pgs = c0 // ps + jnp.arange(npg)
    pid = jnp.take(bt, jnp.clip(pgs, 0, pmax - 1), axis=1)     # (B, npg)
    pid = jnp.where((pgs < pmax)[None, :], pid, -1)
    tt = jnp.arange(npg)[:, None] * ps + off + jnp.arange(s_loc)[None, :]
    src = jnp.take(new, jnp.clip(tt.reshape(-1), 0, sq - 1), axis=2)
    src = jnp.moveaxis(src.reshape(b, hk, npg, s_loc, *new.shape[3:]), 1, 2)
    cur = pool[jnp.clip(pid, 0, n - 1)]            # (B, npg, hk, s_loc[, hd])
    t_glob = c0 + tt                                 # (npg, s_loc) global pos
    cell = ((tt < sq)[None, :, None, :]
            & (t_glob[None, :, None, :] >= wf[:, None, None, None]))
    if pool.ndim == 4:           # value pool (…, hd); scale planes are rank 3
        cell = cell[..., None]
    vals = jnp.where(cell, src.astype(pool.dtype), cur)
    pid_safe = jnp.where(pid >= 0, pid, n)
    return pool.at[pid_safe].set(vals, mode="drop")


def _dp_pool_base(rules: ShardingRules, partitioned: bool):
    """Global id of this shard's first pool page (0 when un-partitioned),
    as a traced scalar factory for use inside shard_map bodies."""
    if not partitioned:
        return lambda n_loc: 0
    axes = rules.run.dp_axes

    def base(n_loc):
        idx = jnp.int32(0)
        for a in axes:
            idx = idx * rules.mesh.shape[a] + lax.axis_index(a)
        return idx * n_loc
    return base


def paged_decode_island(cfg: ArchConfig, run: RunConfig,
                        rules: ShardingRules | None, b: int, page_size: int,
                        *, window, quant: bool = False) -> Island:
    """One-token decode over the paged pool: block-table page write + gather
    + flash-decode logsumexp merge over the tp axis. Declares the same name
    and ``Comm`` coordinates as the slab ``decode_island`` — the merge
    collective is identical — so frozen per-bucket plans and overrides apply
    unchanged to the paged layout. ``quant``: int8 pools with per-(token,
    head) f32 scale pools (``pool_ks``/``pool_vs``) — the token is
    quantized before its page write, gathers dequantize."""
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    def _write_gather(pool_k, pool_v, pool_ks, pool_vs, k_new, v_new, bt,
                     pos, ps, off, s_loc, qdt):
        """Shared write+gather: returns (gk, gv, new pools tuple)."""
        if quant:
            qk, sk = _kv_quantize(k_new)
            qv, sv = _kv_quantize(v_new)
            pk = _paged_decode_write(pool_k, qk, bt, pos, ps, off, s_loc)
            pv = _paged_decode_write(pool_v, qv, bt, pos, ps, off, s_loc)
            pks = _paged_decode_write(pool_ks, sk, bt, pos, ps, off, s_loc)
            pvs = _paged_decode_write(pool_vs, sv, bt, pos, ps, off, s_loc)
            gk = _kv_dequantize(_paged_gather(pk, bt),
                                _paged_gather(pks, bt), qdt)
            gv = _kv_dequantize(_paged_gather(pv, bt),
                                _paged_gather(pvs, bt), qdt)
            return gk, gv, (pk, pv, pks, pvs)
        pk = _paged_decode_write(pool_k, k_new, bt, pos, ps, off, s_loc)
        pv = _paged_decode_write(pool_v, v_new, bt, pos, ps, off, s_loc)
        return _paged_gather(pk, bt), _paged_gather(pv, bt), (pk, pv)

    def reference(q, pool_k, pool_v, k_new, v_new, bt, pos, **kw):
        ps = pool_k.shape[2]
        gk, gv, pools = _write_gather(
            pool_k, pool_v, kw.get("pool_ks"), kw.get("pool_vs"),
            k_new, v_new, bt, pos, ps, 0, ps, q.dtype)
        ki = _page_positions(bt.shape[1], ps, 0, ps)
        o = _paged_mix(q, gk, gv, ki, kv_len=pos + 1, window=window,
                       axis=None)
        return (o, *pools)

    if rules is None:
        return Island("decode_attn", run=run, reference=reference)
    tp = rules.tp
    bspec = rules.dim(b, rules.dp)
    partitioned = bspec is not None
    pool_spec = P(rules.dp if partitioned else None, None, tp, None)
    scale_spec = P(*pool_spec[:3])
    qspec = P(bspec, None, None, None)
    base_fn = _dp_pool_base(rules, partitioned)

    def body(ctx, q, pool_k, pool_v, k_new, v_new, bt, pos, **kw):
        n_loc, _, s_loc = pool_k.shape[:3]
        off = lax.axis_index(tp) * s_loc
        bt_l = jnp.where(bt >= 0, bt - base_fn(n_loc), -1)
        gk, gv, pools = _write_gather(
            pool_k, pool_v, kw.get("pool_ks"), kw.get("pool_vs"),
            k_new, v_new, bt_l, pos, page_size, off, s_loc, q.dtype)
        ki = _page_positions(bt.shape[1], page_size, off, s_loc)
        o = _paged_mix(q, gk, gv, ki, kv_len=pos + 1, window=window,
                       axis=tp)
        return (o, *pools)

    inputs = {"q": qspec, "pool_k": pool_spec, "pool_v": pool_spec,
              "k_new": qspec, "v_new": qspec, "bt": P(bspec, None),
              "pos": P(bspec)}
    if quant:
        inputs["pool_ks"] = scale_spec
        inputs["pool_vs"] = scale_spec
    return Island(
        "decode_attn", rules=rules, run=run, axis=tp, fallback_axes=tp,
        inputs=inputs,
        out_specs=((qspec, pool_spec, pool_spec, scale_spec, scale_spec)
                   if quant else (qspec, pool_spec, pool_spec)),
        body=body, reference=reference,
        enable=run.decode_seq_shard,
        divisible=((page_size, tp),),
        comm=Comm("psum", backend="bulk", n_chunks=1,
                  payload_bytes=2 * b * hq * hd * 4))


def paged_prefill_island(cfg: ArchConfig, run: RunConfig,
                         rules: ShardingRules | None, b: int, s: int,
                         page_size: int, *, window,
                         quant: bool = False) -> Island:
    """One prefill chunk over the paged pool: chunk K/V written into the
    group's block-table pages (shard-local stripes), then causal attention
    of the chunk's queries against every mapped page — donor prefix, earlier
    chunks, and the chunk itself — with the tp logsumexp merge. ``c0`` is
    the chunk's global start position, ``wf`` the per-slot write_from floor
    below which writes are suppressed (copy-on-write prefix resume).
    ``quant``: int8 pools + scale pools; the chunk's K/V is quantized once
    before the write and the queries attend over dequantized pages — so
    even the chunk's own K/V is seen at cache precision, keeping chunked
    and single-shot schedules token-identical."""
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    def _write_gather(pool_k, pool_v, pool_ks, pool_vs, k_new, v_new, bt,
                     c0, wf, ps, off, s_loc, qdt):
        if quant:
            qk, sk = _kv_quantize(k_new)
            qv, sv = _kv_quantize(v_new)
            pk = _paged_chunk_write(pool_k, qk, bt, c0, wf, ps, off, s_loc)
            pv = _paged_chunk_write(pool_v, qv, bt, c0, wf, ps, off, s_loc)
            pks = _paged_chunk_write(pool_ks, sk, bt, c0, wf, ps, off, s_loc)
            pvs = _paged_chunk_write(pool_vs, sv, bt, c0, wf, ps, off, s_loc)
            gk = _kv_dequantize(_paged_gather(pk, bt),
                                _paged_gather(pks, bt), qdt)
            gv = _kv_dequantize(_paged_gather(pv, bt),
                                _paged_gather(pvs, bt), qdt)
            return gk, gv, (pk, pv, pks, pvs)
        pk = _paged_chunk_write(pool_k, k_new, bt, c0, wf, ps, off, s_loc)
        pv = _paged_chunk_write(pool_v, v_new, bt, c0, wf, ps, off, s_loc)
        return _paged_gather(pk, bt), _paged_gather(pv, bt), (pk, pv)

    def reference(q, pool_k, pool_v, k_new, v_new, bt, c0, wf, **kw):
        ps = pool_k.shape[2]
        gk, gv, pools = _write_gather(
            pool_k, pool_v, kw.get("pool_ks"), kw.get("pool_vs"),
            k_new, v_new, bt, c0, wf, ps, 0, ps, q.dtype)
        ki = _page_positions(bt.shape[1], ps, 0, ps)
        o = _paged_mix(q, gk, gv, ki, q_pos=c0 + jnp.arange(s),
                       window=window, axis=None)
        return (o, *pools)

    if rules is None:
        return Island("paged_prefill_attn", run=run, reference=reference)
    tp = rules.tp
    bspec = rules.dim(b, rules.dp)
    partitioned = bspec is not None
    pool_spec = P(rules.dp if partitioned else None, None, tp, None)
    scale_spec = P(*pool_spec[:3])
    qspec = P(bspec, None, None, None)
    base_fn = _dp_pool_base(rules, partitioned)

    def body(ctx, q, pool_k, pool_v, k_new, v_new, bt, c0, wf, **kw):
        n_loc, _, s_loc = pool_k.shape[:3]
        off = lax.axis_index(tp) * s_loc
        bt_l = jnp.where(bt >= 0, bt - base_fn(n_loc), -1)
        gk, gv, pools = _write_gather(
            pool_k, pool_v, kw.get("pool_ks"), kw.get("pool_vs"),
            k_new, v_new, bt_l, c0, wf, page_size, off, s_loc, q.dtype)
        ki = _page_positions(bt.shape[1], page_size, off, s_loc)
        o = _paged_mix(q, gk, gv, ki, q_pos=c0 + jnp.arange(s),
                       window=window, axis=tp)
        return (o, *pools)

    inputs = {"q": qspec, "pool_k": pool_spec, "pool_v": pool_spec,
              "k_new": qspec, "v_new": qspec, "bt": P(bspec, None),
              "c0": P(), "wf": P(bspec)}
    if quant:
        inputs["pool_ks"] = scale_spec
        inputs["pool_vs"] = scale_spec
    return Island(
        "paged_prefill_attn", rules=rules, run=run, axis=tp,
        fallback_axes=tp,
        inputs=inputs,
        out_specs=((qspec, pool_spec, pool_spec, scale_spec, scale_spec)
                   if quant else (qspec, pool_spec, pool_spec)),
        body=body, reference=reference,
        enable=run.decode_seq_shard,
        divisible=((page_size, tp),),
        comm=Comm("psum", backend="bulk", n_chunks=1,
                  payload_bytes=2 * b * hq * s * hd * 4))


def paged_decode_attention(p, x, pool_k, pool_v, bt, pos, cfg: ArchConfig,
                           run: RunConfig, rules: ShardingRules | None, *,
                           k_scale=None, v_scale=None):
    """One-token decode against the paged pool (the block-table twin of
    ``decode_attention``). x: (B, 1, d); pool_k/v: (N_pages, Hkv, page, hd);
    bt: (B, P) block table (−1 = unmapped — the write drops, so free and
    mid-prefill slots are inert); pos: per-slot (B,) positions.
    Returns (out (B,1,d), new_pool_k, new_pool_v) — plus the updated scale
    pools in int8 mode (``k_scale``/``v_scale`` given)."""
    b, _, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    quant = k_scale is not None
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, 1, hq, hd).transpose(0, 2, 1, 3)
    k_new = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(b, 1, hkv, hd).transpose(0, 2, 1, 3)
    v_new = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(b, 1, hkv, hd).transpose(0, 2, 1, 3)
    positions = pos[:, None]
    q = apply_rope(q, positions, cfg.rope_theta)
    k_new = apply_rope(k_new, positions, cfg.rope_theta)
    island = paged_decode_island(cfg, run, rules, b, pool_k.shape[2],
                                 window=cfg.sliding_window, quant=quant)
    kw = {"pool_ks": k_scale, "pool_vs": v_scale} if quant else {}
    res = island(q=q, pool_k=pool_k, pool_v=pool_v, k_new=k_new,
                 v_new=v_new, bt=bt, pos=pos, **kw)
    o = res[0].transpose(0, 2, 1, 3).reshape(b, 1, hq * hd)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"])
    return (out, *res[1:])


def paged_prefill_attention_block(p, x, pool_k, pool_v, bt, chunk_start,
                                  write_from, cfg: ArchConfig,
                                  run: RunConfig,
                                  rules: ShardingRules | None, *,
                                  k_scale=None, v_scale=None):
    """One chunk of paged prefill attention: x (B, cl, d) are the chunk's
    hidden states (global positions [chunk_start, chunk_start+cl)); K/V land
    in the block table's pages and the queries attend over every mapped
    page. Returns (out (B, cl, d), new_pool_k, new_pool_v) — plus the
    updated scale pools in int8 mode."""
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    quant = k_scale is not None
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, s, hq, hd).transpose(0, 2, 1, 3)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    positions = chunk_start + jnp.arange(s)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if rules is not None:
        q = constrain(q, rules, rules.act_bhsd(hq))
    island = paged_prefill_island(cfg, run, rules, b, s, pool_k.shape[2],
                                  window=cfg.sliding_window, quant=quant)
    kw = {"pool_ks": k_scale, "pool_vs": v_scale} if quant else {}
    res = island(q=q, pool_k=pool_k, pool_v=pool_v, k_new=k, v_new=v,
                 bt=bt, c0=jnp.asarray(chunk_start, jnp.int32),
                 wf=write_from, **kw)
    o = res[0].transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    out = attn_out_island(cfg, run, rules, b, s)(o=o, wo=p["wo"])
    if rules is not None:
        out = constrain(out, rules, rules.act_btd())
    return (out, *res[1:])


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------

def mlp_island(cfg: ArchConfig, run: RunConfig,
               rules: ShardingRules | None, b: int, s: int) -> Island:
    """Megatron MLP as the PK GEMM+AR island (paper §4.1): x (replicated over
    tp) × w1 (col-shard) -> act -> × w2 (row-shard) -> overlapped GEMM+AR via
    CommContext (the policy picks bulk for tiny token counts — decode — and
    the ring schedule otherwise). FSDP gathers of the weight shards run
    inside the island so XLA overlaps them with the previous chunk's
    compute."""
    act = get_act(cfg.act)
    d, ff = cfg.d_model, cfg.d_ff
    gated = cfg.gated_mlp

    def reference(x, w1, w3, w2):
        h = jnp.einsum("bsd,df->bsf", x, w1)
        if gated:
            h = act(h) * jnp.einsum("bsd,df->bsf", x, w3)
        else:
            h = act(h)
        out = jnp.einsum("bsf,fd->bsd", h, w2)
        if rules is not None:
            out = constrain(out, rules, rules.act_btd())
        return out

    if rules is None:
        return Island("mlp", run=run, reference=reference)
    tp = rules.tp
    tp_size = rules.mesh.shape[tp]
    bspec = rules.dim(b, rules.dp)
    b_loc = rules.local_batch(b)
    w1s = rules.w2d(d, ff, tp_dim=1)
    w2s = rules.w2d(ff, d, tp_dim=0)

    def body(ctx, x, w1, w3, w2):
        t = x.reshape(-1, d)
        h = jnp.einsum("td,df->tf", t, w1)
        if gated:
            h = act(h) * jnp.einsum("td,df->tf", t, w3)
        else:
            h = act(h)
        out = ctx.matmul_all_reduce(h.astype(x.dtype), w2)
        return out.reshape(x.shape[0], s, d)

    gathers = {"w1": Gather(dim=0, size=d), "w2": Gather(dim=1, size=d)}
    if gated:
        gathers["w3"] = Gather(dim=0, size=d)
    return Island(
        "mlp", rules=rules, run=run,
        inputs={"x": P(bspec, None, None), "w1": w1s,
                "w3": w1s if gated else P(), "w2": w2s},
        out_specs=P(bspec, None, None),
        body=body, reference=reference, gathers=gathers,
        enable=run.pk_overlap,
        divisible=((ff, tp),),
        comm=Comm("matmul_all_reduce", m=b_loc * s, n=d,
                  k=ff // tp_size if ff % tp_size == 0 else ff,
                  dtype_bytes=_wire_bytes(cfg, run)))


def mlp_block(p, x, cfg: ArchConfig, run: RunConfig,
              rules: ShardingRules | None):
    """Dense (optionally gated) MLP with TP. PK mode: the two GEMMs run as
    one Island with overlapped AG+GEMM / GEMM+AR rings (paper §4.1)."""
    b, s, _ = x.shape
    island = mlp_island(cfg, run, rules, b, s)
    w3 = p["w3"] if cfg.gated_mlp else jnp.zeros((), x.dtype)
    return island(x=x, w1=p["w1"], w3=w3, w2=p["w2"])


def moe_island(cfg: ArchConfig, run: RunConfig,
               rules: ShardingRules | None, b: int, s: int) -> Island:
    """MoE island over the tp axis with device-major expert weights
    (core/moe.py). Both variants — resident 2D-TP serving and the default
    EP×TP — share one gating/capacity plan (``pk_moe.dispatch_plan``), so
    trace- and serve-path chunking can never diverge."""
    d = cfg.d_model
    gated = cfg.gated_mlp

    def _undo_device_major(w, *, ff_axis):
        # (M, E_loc, ...) device-major PGL -> (E, ...) with the full ff:
        # rank r = g*tp_ff + j holds expert group g's ff slice j, so regroup
        # to (ep, tp_ff, E_loc, ...), move the tp_ff axis next to its ff_loc
        # slice (`ff_axis` is ff_loc's absolute axis in w) and merge both
        # pairs. tp_ff == 1 (the common case) reduces to a plain reshape.
        m_dev, e_loc = w.shape[0], w.shape[1]
        ep = cfg.n_experts // e_loc
        tp_ff = m_dev // ep
        assert ep * e_loc == cfg.n_experts and tp_ff * ep == m_dev, w.shape
        w = w.reshape(ep, tp_ff, e_loc, *w.shape[2:])
        w = jnp.moveaxis(w, 1, ff_axis)    # -> (ep, e_loc, ..., tp_ff, ff_loc, ...)
        shape = [ep * e_loc] + list(w.shape[2:])
        shape[ff_axis - 1:ff_axis + 1] = [shape[ff_axis - 1] * shape[ff_axis]]
        return w.reshape(shape)

    def reference(x, router, w1, w3, w2):
        # dense oracle: every expert on every token, no capacity drop; the
        # device-major (M, E_loc, d, ff/tp_ff) layout is reconstructed to
        # (E, d, ff) exactly (elementwise act + ff-sliced GEMMs commute with
        # the concat), so reference_mode works for every EP×TP split
        y, aux = pk_moe.moe_reference_dense(
            x.reshape(-1, d), router, _undo_device_major(w1, ff_axis=3),
            _undo_device_major(w3, ff_axis=3) if gated else None,
            _undo_device_major(w2, ff_axis=2),
            n_experts=cfg.n_experts, top_k=cfg.top_k)
        return y.reshape(x.shape), jnp.asarray(aux)[None]

    if rules is None:
        return Island("moe", run=run, reference=reference)
    tp = rules.tp
    f = rules.fsdp_axes
    bspec = rules.dim(b, rules.dp)
    b_loc = rules.local_batch(b)
    # ONE gating/capacity plan for every variant: the serve path sees the
    # dp-gathered token count, the train path the local count.
    n_tok = b * s if run.serve_moe_tp_data else b_loc * s
    moe_chunks = run.moe_chunks
    if moe_chunks == 0:
        # AUTO: measured-first off the `calibrate --per-island` MoE-dispatch
        # a2a rows (island "moe_dispatch"), analytic a2a chunk policy
        # otherwise — the same resolution order as the Ulysses island.
        n_dev = rules.mesh.shape[tp]
        base = pk_moe.dispatch_plan(n_tok, n_experts=cfg.n_experts,
                                    top_k=cfg.top_k,
                                    capacity_factor=cfg.capacity_factor)
        shape = (n_dev, max(cfg.n_experts // max(n_dev, 1), 1), base.cap,
                 cfg.d_model)
        ctx = comm_context(run, tp, mesh=rules.mesh,
                           island=island_key("moe_dispatch", "all_to_all",
                                             _dtype_bytes(cfg)))
        moe_chunks = ctx.a2a_chunk_schedule(
            shape, 0, 0, dtype_bytes=_dtype_bytes(cfg)).n_chunks
    plan = pk_moe.dispatch_plan(n_tok, n_experts=cfg.n_experts,
                                top_k=cfg.top_k,
                                capacity_factor=cfg.capacity_factor,
                                n_chunks=moe_chunks)
    gathers: dict[str, Gather] = {}

    if run.serve_moe_tp_data:
        # resident 2D-TP: weights stay put (ff sliced over dp); tokens are
        # all-gathered over dp (activation-sized), expert partials are
        # psum_scatter'd back — O(T*d) traffic instead of O(W) per step.
        def body(ctx, x, router, w1, w3, w2):
            w1, w2 = w1[0], w2[0]
            w3 = w3[0] if gated else None
            t = x.reshape(-1, d)
            if bspec is not None:
                names = (rules.dp,) if isinstance(rules.dp, str) \
                    else tuple(rules.dp)
                for a in names:
                    t = lax.all_gather(t, a, axis=0, tiled=True)
            y, aux = pk_moe.pk_moe_replicated(
                t, router, w1, w3, w2, axis_name=tp,
                n_experts=cfg.n_experts, top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor, plan=plan, ctx=ctx)
            if bspec is not None:
                y = lax.psum_scatter(y.astype(jnp.float32), rules.dp,
                                     scatter_dimension=0, tiled=True)
            else:
                y = lax.psum(y.astype(jnp.float32), rules.dp)
            return y.astype(x.dtype).reshape(x.shape), \
                lax.pmean(aux, tp)[None]

        dpff = rules.dim(cfg.d_ff // (rules.mesh.shape[tp] //
                                      pk_moe.ep_tp_split(cfg.n_experts,
                                                         rules.mesh.shape[tp])[0]),
                         rules.dp)
        wspec = P(tp, None, None, dpff)
        w2spec = P(tp, None, dpff, None)
    else:
        def body(ctx, x, router, w1, w3, w2):
            w1, w2 = w1[0], w2[0]
            w3 = w3[0] if gated else None
            t = x.reshape(-1, d)
            y, aux = pk_moe.pk_moe_replicated(
                t, router, w1, w3, w2, axis_name=tp, n_experts=cfg.n_experts,
                top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                plan=plan, ring_combine=run.pk_ring_psum, ctx=ctx)
            return y.reshape(x.shape), lax.pmean(aux, tp)[None]

        # device-major PGL weights: (M, E_loc, d[, /fsdp], ff_loc)
        wspec = P(tp, None, rules.dim(d, f), None)
        w2spec = P(tp, None, None, rules.dim(d, f))
        gathers = {"w1": Gather(dim=2, size=d), "w2": Gather(dim=3, size=d)}
        if gated:
            gathers["w3"] = Gather(dim=2, size=d)

    return Island(
        "moe", rules=rules, run=run,
        inputs={"x": P(bspec, None, None), "router": P(), "w1": wspec,
                "w3": wspec if gated else P(), "w2": w2spec},
        out_specs=(P(bspec, None, None), P(bspec)),
        body=body, reference=reference, gathers=gathers,
        comm=Comm("psum", backend="ring" if run.pk_ring_psum else "bulk",
                  n_chunks=plan.n_chunks,
                  payload_bytes=n_tok * d * _dtype_bytes(cfg)))


def moe_block(p, x, cfg: ArchConfig, run: RunConfig,
              rules: ShardingRules | None):
    """MoE sub-layer; returns (out, aux_loss)."""
    b, s, _ = x.shape
    island = moe_island(cfg, run, rules, b, s)
    w3 = p["w3"] if cfg.gated_mlp else jnp.zeros((), x.dtype)
    out, aux = island(x=x, router=p["router"], w1=p["w1"], w3=w3, w2=p["w2"])
    return out, jnp.mean(aux.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Vocab-parallel embedding + loss
# ---------------------------------------------------------------------------

def embed_island(run: RunConfig, rules: ShardingRules | None, v: int,
                 d_model: int, b: int) -> Island:
    """Megatron vocab-parallel embedding island: gather from the LOCAL
    (V_loc, d_loc) shard, combine with activation-sized collectives — never
    all-gather the table itself (a (B,S)-token lookup must move O(B·S·d),
    not O(V·d))."""

    def reference(emb, tok):
        return jnp.take(emb, tok, axis=0)

    if rules is None:
        return Island("embed", run=run, reference=reference)
    tp = rules.tp
    f = rules.fsdp_axes

    def body(ctx, emb, tok):
        v_loc = emb.shape[0]
        v0 = lax.axis_index(tp) * v_loc
        local = tok - v0
        ok = (local >= 0) & (local < v_loc)
        x = jnp.take(emb, jnp.clip(local, 0, v_loc - 1), axis=0)
        x = jnp.where(ok[..., None], x, 0)
        x = lax.psum(x, tp)                      # combine vocab shards
        if f is not None and x.shape[-1] < d_model:
            names = (f,) if isinstance(f, str) else tuple(f)
            for a in names:                      # gather the d shards
                x = lax.all_gather(x, a, axis=-1, tiled=True)
        return x

    bspec = rules.dim(b, rules.dp)
    return Island(
        "embed", rules=rules, run=run,
        inputs={"emb": P(tp, rules.dim(d_model, f)), "tok": P(bspec, None)},
        out_specs=P(bspec, None, None),
        body=body, reference=reference,
        divisible=((v, tp),),
        comm=Comm("psum", backend="bulk", n_chunks=1))


def embed_tokens(p, tokens, rules: ShardingRules | None,
                 run: RunConfig | None = None):
    """tokens (B, S) -> (B, S, d). Vocab-parallel island when sharded;
    plain take otherwise (the island's fallback)."""
    emb = p["embed"]
    v, d_model = emb.shape
    b = tokens.shape[0]
    island = embed_island(run if run is not None else RunConfig(),
                          rules, v, d_model, b)
    return island(emb=emb, tok=tokens)


def lm_loss_island(run: RunConfig, rules: ShardingRules | None, b: int,
                   d: int, v: int) -> Island:
    """Chunked vocab-parallel cross-entropy island: softmax statistics are
    psum-merged over the vocab (tp) shard; never materializes (B,S,V)."""

    def scan_body_dense(head):
        def body(carry, args):
            xi, ti, wi = args
            logits = jnp.einsum("bsd,dv->bsv", xi, head).astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            tgt = jnp.take_along_axis(logits, ti[..., None], axis=-1)[..., 0]
            return (carry[0] + jnp.sum((lse - tgt) * wi),
                    carry[1] + jnp.sum(wi)), None
        return body

    def reference(xc, tc, wc, head):
        (tot, cnt), _ = lax.scan(scan_body_dense(head),
                                 (jnp.zeros(()), jnp.zeros(())),
                                 (xc, tc, wc))
        return tot, cnt

    if rules is None:
        return Island("lm_loss", run=run, reference=reference)
    tp = rules.tp
    hspec = rules.w2d(d, v, tp_dim=1)

    def body(ctx, xc, tc, wc, head):
        v_loc = head.shape[1]
        v0 = lax.axis_index(tp) * v_loc

        def step(carry, args):
            xi, ti, wi = args
            logits = jnp.einsum("bsd,dv->bsv", xi, head).astype(jnp.float32)
            # global max is for numerical stability only — no gradient needed
            m_loc = lax.stop_gradient(logits).max(axis=-1)
            m = lax.pmax(m_loc, tp)
            se = lax.psum(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1), tp)
            lse = m + jnp.log(se)
            loc = ti - v0
            ok = (loc >= 0) & (loc < v_loc)
            tgt = jnp.take_along_axis(logits, jnp.clip(loc, 0, v_loc - 1)[..., None],
                                      axis=-1)[..., 0]
            tgt = lax.psum(jnp.where(ok, tgt, 0.0), tp)
            # rank-1 carries: legacy shard_map cannot transpose rank-0
            # residuals crossing the island boundary
            return (carry[0] + jnp.sum((lse - tgt) * wi)[None],
                    carry[1] + jnp.sum(wi)[None]), None

        (tot, cnt), _ = lax.scan(step, (jnp.zeros((1,)), jnp.zeros((1,))),
                                 (xc, tc, wc))
        return tot, cnt

    bspec = rules.dim(b, rules.dp)
    return Island(
        "lm_loss", rules=rules, run=run,
        inputs={"xc": P(None, bspec, None, None), "tc": P(None, bspec),
                "wc": P(None, bspec), "head": hspec},
        out_specs=(P(bspec), P(bspec)),
        body=body, reference=reference,
        gathers={"head": Gather(dim=0, size=d)},
        divisible=((v, tp),),
        comm=Comm("psum", backend="bulk", n_chunks=1))


def lm_loss(p, x, targets, weights, cfg: ArchConfig, run: RunConfig,
            rules: ShardingRules | None, *, chunk: int = 512):
    """Chunked vocab-parallel cross-entropy. x: (B,S,d); targets (B,S)."""
    head = p["lm_head"]
    b, s, d = x.shape
    v = head.shape[1]
    n_chunks = max(1, s // chunk) if s % chunk == 0 else 1
    xc = x.reshape(b, n_chunks, s // n_chunks, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(b, n_chunks, s // n_chunks).transpose(1, 0, 2)
    wc = weights.reshape(b, n_chunks, s // n_chunks).transpose(1, 0, 2)
    island = lm_loss_island(run, rules, b, d, v)
    tot, cnt = island(xc=xc, tc=tc, wc=wc, head=head)
    return jnp.sum(tot) / jnp.maximum(jnp.sum(cnt), 1.0)


def lm_logits(p, x, rules: ShardingRules | None):
    """Full logits for serving (B, S, V)."""
    logits = jnp.einsum("bsd,dv->bsv", x, p["lm_head"]).astype(jnp.float32)
    if rules is not None:
        logits = constrain(logits, rules,
                           P(rules.dp, None,
                             rules.dim(logits.shape[-1], rules.tp)))
    return logits


# ---------------------------------------------------------------------------
# Plan report: the whole forward pass's overlap schedule from one object
# ---------------------------------------------------------------------------

def _forward_islands(cfg: ArchConfig, run: RunConfig,
                     rules: ShardingRules | None, *, batch: int = 8,
                     seq: int = 128, phase: str = "all",
                     page_size: int = 0) -> list:
    """Every PK island a forward pass (and a decode step) of this
    (cfg, run, mesh) will build — the single island inventory behind both
    ``island_plans`` and ``island_comm_sweeps``.

    ``phase`` narrows the inventory to one serving bucket's step program:
    ``"prefill"`` is the full-sequence cache-building forward (GEMM islands
    at m = B_loc·seq, no decode or loss islands), ``"decode"`` the one-token
    step (GEMM islands at m = B_loc·1 plus the decode-attention island);
    ``"all"`` (default) is the historical union every launcher prints.

    ``page_size`` > 0 switches the serving phases to the paged-cache island
    set: decode keeps the ``decode_attn`` name and Comm coordinates (frozen
    plans apply unchanged) and prefill gains the ``paged_prefill_attn``
    merge island the chunk program runs.
    """
    if phase not in ("all", "prefill", "decode"):
        raise ValueError(f"unknown island phase {phase!r}")
    b = batch
    s = 1 if phase == "decode" else seq
    pattern = cfg.layer_pattern()
    v = cfg.padded_vocab(rules.mesh.shape[rules.tp] if rules else 16)
    islands = [embed_island(run, rules, v, cfg.d_model, b)]
    if any(sp.mixer == "attn" for sp in pattern):
        if run.sp_attention != "none" and phase == "all":
            islands.append(
                sp_attention_island(cfg, run, rules, b, s, causal=True))
        islands.append(attn_out_island(cfg, run, rules, b, s))
        if page_size and phase == "prefill":
            islands.append(paged_prefill_island(
                cfg, run, rules, b, s, page_size,
                window=cfg.sliding_window))
        if phase in ("all", "decode"):
            if page_size and phase == "decode":
                islands.append(paged_decode_island(
                    cfg, run, rules, b, page_size,
                    window=cfg.sliding_window))
            else:
                islands.append(decode_island(
                    cfg, run, rules, b, seq, long_ctx=False, pos=0,
                    kv_len=1, window=cfg.sliding_window))
    if any(sp.mlp == "dense" for sp in pattern):
        islands.append(mlp_island(cfg, run, rules, b, s))
    if any(sp.mlp == "moe" for sp in pattern):
        islands.append(moe_island(cfg, run, rules, b, s))
    if phase == "all":
        islands.append(lm_loss_island(run, rules, b, cfg.d_model, v))
    return islands


def island_plans(cfg: ArchConfig, run: RunConfig,
                 rules: ShardingRules | None, *, batch: int = 8,
                 seq: int = 128, phase: str = "all",
                 page_size: int = 0) -> list[IslandPlan]:
    """Trace-free overlap schedule for every PK island a forward pass (and a
    decode step) of this (cfg, run, mesh) will build: chosen backend, chunk
    count, hidden fraction (measured on a calibrated mesh, else predicted)
    — or the fallback reason. Launchers print this via
    ``repro.core.template.render_plans``; the dry-run records it in its JSON
    artifact. ``phase`` narrows to one serving bucket's step program (see
    ``_forward_islands``) — the serving engine resolves a plan table per
    shape bucket this way; ``page_size`` > 0 swaps in the paged-cache
    serving islands."""
    return [i.plan() for i in _forward_islands(cfg, run, rules,
                                               batch=batch, seq=seq,
                                               phase=phase,
                                               page_size=page_size)]


def island_comm_sweeps(cfg: ArchConfig, run: RunConfig,
                       rules: ShardingRules | None, *, batch: int = 8,
                       seq: int = 128, phase: str = "all"):
    """Per-island calibration sweep specs (``autotune.IslandSweep``) for
    every active GEMM-collective *and all-to-all* island of this forward
    pass — the driver behind ``python -m repro.autotune calibrate
    --per-island``. GEMM islands carry the exact (op, m, n, k, dtype)
    coordinates their ``CommContext`` dispatch queries with; a2a islands
    (Ulysses re-sharding, MoE dispatch) carry the local payload shape and
    split/concat axes, stored under ``CommContext.a2a_coords``. ``phase``
    narrows to one serving bucket's inventory, so the serving buckets can
    be calibrated at their exact shapes."""
    from repro.core.autotune import IslandSweep
    from repro.core.comms import GEMM_OP_KIND, CommContext
    sweeps = []
    for isl in _forward_islands(cfg, run, rules, batch=batch, seq=seq,
                                phase=phase):
        c = isl.comm
        if c is None or isl.fallback_reason() is not None:
            continue
        if c.op in GEMM_OP_KIND:
            sweeps.append(IslandSweep(island=isl.island_key, op=c.op,
                                      m=c.m, n=c.n, k=c.k,
                                      dtype_bytes=c.dtype_bytes))
        elif c.op == "all_to_all" and c.shape is not None:
            m, n, k = CommContext.a2a_coords(c.shape, c.split_axis,
                                             c.concat_axis)
            sweeps.append(IslandSweep(
                island=isl.island_key, op="all_to_all", m=m, n=n, k=k,
                dtype_bytes=c.dtype_bytes, shape=tuple(c.shape),
                split_axis=c.split_axis, concat_axis=c.concat_axis))
    if any(sp.mlp == "moe" for sp in cfg.layer_pattern()) \
            and rules is not None:
        # MoE a2a dispatch (pk_moe_a2a): the destination-major payload
        # (n_dev, E_loc, capacity, d) transposed with split==concat==0.
        # Not a declared island Comm (the moe island's dominant collective
        # is the combine psum), but the chunk policy dispatches off these
        # rows when RunConfig.moe_chunks = 0 (auto).
        n_dev = rules.mesh.shape[rules.tp]
        if cfg.n_experts % n_dev == 0:
            b_loc = rules.local_batch(batch)
            s = 1 if phase == "decode" else seq     # the bucket's token count
            n_tok = batch * s if run.serve_moe_tp_data else b_loc * s
            plan = pk_moe.dispatch_plan(n_tok, n_experts=cfg.n_experts,
                                        top_k=cfg.top_k,
                                        capacity_factor=cfg.capacity_factor)
            shape = (n_dev, cfg.n_experts // n_dev, plan.cap, cfg.d_model)
            m, n, k = CommContext.a2a_coords(shape, 0, 0)
            sweeps.append(IslandSweep(
                island=island_key("moe_dispatch", "all_to_all",
                                  _dtype_bytes(cfg)),
                op="all_to_all", m=m, n=n, k=k,
                dtype_bytes=_dtype_bytes(cfg), shape=shape,
                split_axis=0, concat_axis=0))
    return sweeps
