"""Model layers: norms, RoPE, GQA attention (causal/SWA/cross/decode), MLP,
MoE wrapper, vocab-parallel embedding and loss.

Everything is functional: `fn(params_subtree, x, ...)`. Activation sharding
is maintained with with_sharding_constraint (XLA Auto skeleton); the
PK-overlapped paths are shard_map islands from repro.core, switched by
RunConfig (DESIGN.md §3).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ArchConfig, RunConfig
from repro.core import moe as pk_moe
from repro.core import pk_ring_attention, pk_ulysses_attention
from repro.core.comms import CommContext
from repro.models.sharding import ShardingRules

NEG_INF = -1e30


def constrain(x, rules: ShardingRules | None, spec: P):
    if rules is None:
        return x
    return lax.with_sharding_constraint(x, rules.named(spec))


def _comm_ctx(run: RunConfig, rules: ShardingRules) -> CommContext:
    """The single communication entry point for every PK island in this
    module (DESIGN §3): collectives are policy-routed by the cost model;
    ``run.comm_backend`` pins one backend for A/B runs, and
    ``run.comm_policy="measured"`` prices the routed schedules from a
    ``repro.core.autotune`` calibration table instead of the analytic
    datasheet constants."""
    return CommContext(axis_name=rules.tp, backend=run.comm_backend,
                       allow_bidir=run.pk_bidirectional,
                       policy=run.comm_policy,
                       calibration=run.calibration_path)


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------

def rms_norm(w, x, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def get_act(name: str):
    return {"silu": jax.nn.silu, "gelu": functools.partial(jax.nn.gelu, approximate=True),
            "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (B, H, S, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[:, None] * inv[None, :]   # (S, hd/2)
        cos, sin = jnp.cos(ang)[None, None], jnp.sin(ang)[None, None]
    else:
        ang = positions.astype(jnp.float32)[..., None] * inv
        cos, sin = jnp.cos(ang)[:, None], jnp.sin(ang)[:, None]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _full_attention(q, k, v, *, causal, window, q_offset=0, kv_len=None,
                    scale=None):
    """q: (B,Hq,Sq,hd); k,v: (B,Hkv,Skv,hd). fp32 softmax, GQA grouped."""
    b, hq, sq, hd = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else hd ** -0.5
    qg = q.reshape(b, hkv, g, sq, hd)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    qi = q_offset + jnp.arange(sq)[:, None]
    ki = jnp.arange(skv)[None, :]
    keep = jnp.ones((sq, skv), bool)
    if causal:
        keep &= ki <= qi
    if window is not None:
        keep &= ki > qi - window
    if kv_len is not None:                      # decode: valid cache prefix
        keep &= ki < kv_len
    s = jnp.where(keep, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, hd).astype(q.dtype)


def _chunked_attention(q, k, v, *, causal, window, scale=None,
                       qc: int = 512, kc: int = 1024):
    """Memory-bounded XLA attention (flash-style online softmax over kv
    chunks inside a scan over q chunks) — the jnp twin of
    kernels/flash_attention.py, used when S·Skv would blow HBM (32k+ prefill).
    Fully-masked kv blocks are skipped via lax.cond (real compute savings,
    same as the kernel's block schedule)."""
    b, hq, s, hd = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else hd ** -0.5
    qc = min(qc, s)
    kc = min(kc, skv)
    assert s % qc == 0 and skv % kc == 0, (s, qc, skv, kc)
    nq, nk = s // qc, skv // kc
    qg = q.reshape(b, hkv, g, nq, qc, hd).transpose(3, 0, 1, 2, 4, 5)
    kb = k.reshape(b, hkv, nk, kc, hd)
    vb = v.reshape(b, hkv, nk, kc, hd)

    def one_q_block(args):
        qi, qblk = args                                  # (b,hkv,g,qc,hd)
        m0 = jnp.full((b, hkv, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, qc), jnp.float32)
        o0 = jnp.zeros((b, hkv, g, qc, hd), jnp.float32)

        def kv_step(carry, ki):
            m, l, o = carry
            k_i = lax.dynamic_index_in_dim(kb, ki, 2, keepdims=False)
            v_i = lax.dynamic_index_in_dim(vb, ki, 2, keepdims=False)
            q_lo = qi * qc
            k_lo = ki * kc
            run_blk = jnp.bool_(True)
            if causal:
                run_blk &= k_lo <= q_lo + qc - 1
            if window is not None:
                run_blk &= k_lo + kc - 1 > q_lo - window

            def do(args):
                m_, l_, o_ = args
                sc = jnp.einsum("bkgqd,bksd->bkgqs", qblk, k_i,
                                preferred_element_type=jnp.float32) * scale
                rows = q_lo + jnp.arange(qc)[:, None]
                cols = k_lo + jnp.arange(kc)[None, :]
                keep = jnp.ones((qc, kc), bool)
                if causal:
                    keep &= cols <= rows
                if window is not None:
                    keep &= cols > rows - window
                sc = jnp.where(keep, sc, NEG_INF)
                m_new = jnp.maximum(m_, sc.max(axis=-1))
                p_ = jnp.exp(sc - m_new[..., None])
                alpha = jnp.exp(m_ - m_new)
                l_new = l_ * alpha + p_.sum(axis=-1)
                o_new = o_ * alpha[..., None] + jnp.einsum(
                    "bkgqs,bksd->bkgqd", p_, v_i.astype(jnp.float32))
                return m_new, l_new, o_new

            return lax.cond(run_blk, do, lambda a: a, (m, l, o)), None

        (m, l, o), _ = lax.scan(kv_step, (m0, l0, o0), jnp.arange(nk))
        return o / jnp.maximum(l, 1e-30)[..., None]

    out = lax.map(one_q_block, (jnp.arange(nq), qg))     # (nq,b,hkv,g,qc,hd)
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, s, hd)
    return out.astype(q.dtype)


# kv lengths at/above this use the chunked path (HBM budget, DESIGN §5)
XLA_ATTN_CHUNK_THRESHOLD = 8192


def attention_block(p, x, cfg: ArchConfig, run: RunConfig,
                    rules: ShardingRules | None, *, causal=True,
                    positions=None, cross_kv=None, seq_sharded=False):
    """Full attention sub-layer (projections + mixing + out-proj).

    p: {"wq","wk","wv","wo"}; x: (B, S, d) [if seq_sharded: S is the local
    shard and ring/ulysses attention runs over the tp axis].
    cross_kv: precomputed (k, v) for cross-attention (enc-dec decoder).
    """
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, s, hq, hd)
    q = q.transpose(0, 2, 1, 3)
    if cross_kv is None:
        k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
        v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    else:
        k, v = cross_kv
    if positions is None:
        positions = jnp.arange(s)
    if cross_kv is None:                         # RoPE on self-attention only
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    if seq_sharded and rules is not None:
        # Sequence parallelism: ring attention over the tp axis (PK §4.2).
        axis = rules.tp
        fn = {"ring": pk_ring_attention, "ulysses": pk_ulysses_attention,
              }.get(run.sp_attention, pk_ring_attention)
        bspec = rules.dim(b, rules.dp)
        attn = compat.shard_map(
            lambda q_, k_, v_: fn(q_, k_, v_, axis, causal=causal,
                                  window=cfg.sliding_window),
            mesh=rules.mesh,
            in_specs=(P(bspec, None, axis, None),) * 3,
            out_specs=P(bspec, None, axis, None),
            check_vma=False)
        o = attn(q, k, v)
    else:
        if rules is not None:
            q = constrain(q, rules, rules.act_bhsd(hq))
        win = cfg.sliding_window if cross_kv is None else None
        if k.shape[2] >= XLA_ATTN_CHUNK_THRESHOLD:
            o = _chunked_attention(q, k, v, causal=causal, window=win)
        else:
            o = _full_attention(q, k, v, causal=causal, window=win)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    if (rules is not None and run.pk_attn_out_island
            and (hq * hd) % rules.mesh.shape[rules.tp] == 0
            and (b * s) % rules.mesh.shape[rules.tp] == 0):
        # out-projection as the PK GEMM+AR island (paper Fig. 9 position):
        # ring permutes keep bf16 payloads and overlap with the block GEMMs.
        out = _pk_attn_out_island(p["wo"], o, cfg, run, rules, b, s)
    else:
        out = jnp.einsum("bsh,hd->bsd", o, p["wo"])
    if rules is not None:
        out = constrain(out, rules, rules.act_btd())
    return out


def _pk_attn_out_island(wo, o, cfg, run, rules, b, s):
    tp = rules.tp
    f = rules.fsdp_axes
    d = cfg.d_model
    h_full = o.shape[-1]
    ctx = _comm_ctx(run, rules)

    def island(o_, wo_):
        if f is not None:
            wo_ = _maybe_allgather(wo_, f, 1, d)
        t = o_.reshape(-1, o_.shape[-1])
        out = ctx.matmul_all_reduce(t, wo_)
        return out.reshape(o_.shape[0], s, d)

    bspec = rules.dim(b, rules.dp)
    wspec = rules.w2d(h_full, d, tp_dim=0)
    return compat.shard_map(
        island, mesh=rules.mesh,
        in_specs=(P(bspec, None, rules.dim(h_full, tp)), wspec),
        out_specs=P(bspec, None, None), check_vma=False)(o, wo)


def decode_attention(p, x, cache_k, cache_v, pos, cfg: ArchConfig,
                     run: RunConfig, rules: ShardingRules | None, *,
                     cross_kv=None, long_ctx=False):
    """One-token decode with KV cache.

    x: (B, 1, d); cache_k/v: (B, Hkv, S_max, hd); pos: scalar current index.
    Returns (out (B,1,d), new_k, new_v). If run.decode_seq_shard, attention
    over the sharded cache uses the flash-decode logsumexp merge over the tp
    axis (shard_map island) — the SP serving path (DESIGN §4).
    """
    b, _, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    cache_k_in, cache_v_in = cache_k, cache_v
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, 1, hq, hd).transpose(0, 2, 1, 3)
    if cross_kv is None:
        k_new = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(b, 1, hkv, hd).transpose(0, 2, 1, 3)
        v_new = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(b, 1, hkv, hd).transpose(0, 2, 1, 3)
        q = apply_rope(q, jnp.full((1,), pos), cfg.rope_theta)
        k_new = apply_rope(k_new, jnp.full((1,), pos), cfg.rope_theta)
        kv_len = pos + 1          # cache write is deferred (see below)
    else:
        k_att, v_att = cross_kv
        kv_len = k_att.shape[2]

    window = cfg.sliding_window if cross_kv is None else None
    if rules is not None and run.decode_seq_shard and cross_kv is None:
        # The cache slot write happens INSIDE the island, shard-locally:
        # a dynamic_update_slice on a seq-sharded array at the jit level
        # would force XLA to all-gather the whole cache (GBs per token).
        axis = (tuple(run.dp_axes) + (rules.tp,)) if long_ctx else rules.tp
        cache_spec = rules.kv_cache(hkv, b, long_ctx=long_ctx)
        bspec = None if long_ctx else rules.dim(b, rules.dp)

        def island(q_, k_old, v_old, kn, vn):
            ax_idx = lax.axis_index(axis)
            s_loc = k_old.shape[2]
            offset = ax_idx * s_loc
            # shard-local cache update (one-sided, pre-allocated slot — the
            # PK §3.1.4 principle applied to the KV cache)
            local_pos = pos - offset
            hit = (local_pos >= 0) & (local_pos < s_loc)
            lp = jnp.clip(local_pos, 0, s_loc - 1)

            def upd(c, n):
                new = lax.dynamic_update_slice(c, n.astype(c.dtype),
                                               (0, 0, lp, 0))
                return lax.cond(hit, lambda: new, lambda: c)

            k_ = upd(k_old, kn)
            v_ = upd(v_old, vn)
            # local partial attention + logsumexp merge over the axis
            g = hq // hkv
            qg = q_.reshape(q_.shape[0], hkv, g, 1, hd)
            s_ = jnp.einsum("bkgqd,bksd->bkgqs", qg, k_,
                            preferred_element_type=jnp.float32) * hd ** -0.5
            ki = offset + jnp.arange(s_loc)[None, None, None, None, :]
            keep = ki < kv_len
            if window is not None:
                keep &= ki > (kv_len - 1) - window
            s_ = jnp.where(keep, s_, NEG_INF)
            m_loc = s_.max(axis=-1)                                # (b,k,g,1)
            m_glob = lax.pmax(m_loc, axis)
            p_ = jnp.exp(s_ - m_glob[..., None])
            l_loc = p_.sum(axis=-1)
            o_loc = jnp.einsum("bkgqs,bksd->bkgqd", p_, v_.astype(jnp.float32))
            l_glob = lax.psum(l_loc, axis)
            o_glob = lax.psum(o_loc, axis)
            o = o_glob / jnp.maximum(l_glob, 1e-30)[..., None]
            return (o.reshape(q_.shape[0], hq, 1, hd).astype(q_.dtype),
                    k_, v_)

        qspec = P(bspec, None, None, None)
        o, cache_k, cache_v = compat.shard_map(
            island, mesh=rules.mesh,
            in_specs=(qspec, cache_spec, cache_spec, qspec, qspec),
            out_specs=(qspec, cache_spec, cache_spec),
            check_vma=False)(q, cache_k_in, cache_v_in, k_new, v_new)
    else:
        if cross_kv is None:
            cache_k = lax.dynamic_update_slice(
                cache_k_in, k_new.astype(cache_k_in.dtype), (0, 0, pos, 0))
            cache_v = lax.dynamic_update_slice(
                cache_v_in, v_new.astype(cache_v_in.dtype), (0, 0, pos, 0))
            k_att, v_att = cache_k, cache_v
        o = _full_attention(q, k_att, v_att, causal=False, window=window,
                            q_offset=0, kv_len=kv_len)
        # causal handled via kv_len (all cached positions <= pos are visible);
        # SWA via window against kv_len-1.
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, hq * hd)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"])
    if cross_kv is None:
        return out, cache_k, cache_v
    return out, None, None


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------

def mlp_block(p, x, cfg: ArchConfig, run: RunConfig,
              rules: ShardingRules | None):
    """Dense (optionally gated) MLP with TP. PK mode: the two GEMMs run as a
    shard_map island with overlapped AG+GEMM / GEMM+AR rings (paper §4.1)."""
    act = get_act(cfg.act)
    if rules is not None and run.pk_overlap and _tp_divides(cfg, rules):
        return _pk_mlp_island(p, x, cfg, run, rules)
    h = jnp.einsum("bsd,df->bsf", x, p["w1"])
    if cfg.gated_mlp:
        h = act(h) * jnp.einsum("bsd,df->bsf", x, p["w3"])
    else:
        h = act(h)
    out = jnp.einsum("bsf,fd->bsd", h, p["w2"])
    if rules is not None:
        out = constrain(out, rules, rules.act_btd())
    return out


def _tp_divides(cfg: ArchConfig, rules: ShardingRules) -> bool:
    tp = rules.mesh.shape[rules.tp]
    return cfg.d_ff % tp == 0


def _pk_mlp_island(p, x, cfg: ArchConfig, run: RunConfig, rules: ShardingRules):
    """Megatron MLP as explicit PK collectives: x (replicated over tp)
    × w1 (col-shard) -> h (ff-sharded, local) -> act -> × w2 (row-shard)
    -> overlapped GEMM+AR via CommContext (the policy picks bulk for tiny
    token counts — decode — and the ring schedule otherwise). FSDP gathers
    of weights happen inside so XLA overlaps them with the previous chunk's
    compute."""
    act = get_act(cfg.act)
    b, s, d = x.shape
    f = rules.fsdp_axes
    ctx = _comm_ctx(run, rules)

    def island(x_, w1, w3, w2):
        if f is not None:  # FSDP all-gather (ZeRO-3) of the weight shards
            w1 = _maybe_allgather(w1, f, 0, cfg.d_model)
            w3 = _maybe_allgather(w3, f, 0, cfg.d_model) if cfg.gated_mlp else w3
            w2 = _maybe_allgather(w2, f, 1, cfg.d_model)
        t = x_.reshape(-1, d)
        h = jnp.einsum("td,df->tf", t, w1)
        if cfg.gated_mlp:
            h = act(h) * jnp.einsum("td,df->tf", t, w3)
        else:
            h = act(h)
        out = ctx.matmul_all_reduce(h.astype(x_.dtype), w2)
        return out.reshape(x_.shape[0], s, d)

    w1s = rules.w2d(cfg.d_model, cfg.d_ff, tp_dim=1)
    w2s = rules.w2d(cfg.d_ff, cfg.d_model, tp_dim=0)
    w3 = p["w3"] if cfg.gated_mlp else jnp.zeros((), x.dtype)
    bspec = rules.dim(b, rules.dp)
    in_specs = (P(bspec, None, None), w1s, w1s if cfg.gated_mlp else P(),
                w2s)
    out = compat.shard_map(island, mesh=rules.mesh, in_specs=in_specs,
                           out_specs=P(bspec, None, None),
                           check_vma=False)(x, p["w1"], w3, p["w2"])
    return out


def _maybe_allgather(w, axes, dim: int, full_size: int):
    if w is None:
        return None
    names = (axes,) if isinstance(axes, str) else tuple(axes)
    for a in names:
        if w.shape[dim] < full_size:
            w = lax.all_gather(w, a, axis=dim, tiled=True)
    return w


def moe_block(p, x, cfg: ArchConfig, run: RunConfig,
              rules: ShardingRules | None):
    """MoE sub-layer; returns (out, aux_loss). shard_map island over the tp
    axis with device-major expert weights (core/moe.py)."""
    b, s, d = x.shape
    if rules is None:
        # single-device reference path (smoke tests): dense oracle
        y, aux = pk_moe.moe_reference_dense(
            x.reshape(-1, d), p["router"], p["w1"].reshape(-1, *p["w1"].shape[2:]),
            p["w3"].reshape(-1, *p["w3"].shape[2:]) if cfg.gated_mlp else None,
            p["w2"].reshape(-1, *p["w2"].shape[2:]),
            n_experts=cfg.n_experts, top_k=cfg.top_k)
        return y.reshape(b, s, d), aux

    tp = rules.tp
    f = rules.fsdp_axes
    bspec = rules.dim(b, rules.dp)

    if run.serve_moe_tp_data:
        # resident 2D-TP: weights stay put (ff sliced over dp); tokens are
        # all-gathered over dp (activation-sized), expert partials are
        # psum_scatter'd back — O(T*d) traffic instead of O(W) per step.
        def island(x_, router, w1, w3, w2):
            w1, w2 = w1[0], w2[0]
            w3 = w3[0] if cfg.gated_mlp else None
            t = x_.reshape(-1, d)
            if bspec is not None:
                names = (rules.dp,) if isinstance(rules.dp, str) \
                    else tuple(rules.dp)
                for a in names:
                    t = lax.all_gather(t, a, axis=0, tiled=True)
            y, aux = pk_moe.pk_moe_replicated(
                t, router, w1, w3, w2, axis_name=tp,
                n_experts=cfg.n_experts, top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor,
                n_chunks=run.moe_chunks)
            if bspec is not None:
                y = lax.psum_scatter(y.astype(jnp.float32), rules.dp,
                                     scatter_dimension=0, tiled=True)
            else:
                y = lax.psum(y.astype(jnp.float32), rules.dp)
            return y.astype(x_.dtype).reshape(x_.shape), \
                lax.pmean(aux, tp)[None]

        dpff = rules.dim(cfg.d_ff // (rules.mesh.shape[tp] //
                                      pk_moe.ep_tp_split(cfg.n_experts,
                                                         rules.mesh.shape[tp])[0]),
                         rules.dp)
        wspec = P(tp, None, None, dpff)
        w2spec = P(tp, None, dpff, None)
    else:
        def island(x_, router, w1, w3, w2):
            w1, w2 = w1[0], w2[0]
            w3 = w3[0] if cfg.gated_mlp else None
            if f is not None:
                w1 = _maybe_allgather(w1, f, 1, cfg.d_model)
                w3 = _maybe_allgather(w3, f, 1, cfg.d_model)
                w2 = _maybe_allgather(w2, f, 2, cfg.d_model)
            t = x_.reshape(-1, d)
            y, aux = pk_moe.pk_moe_replicated(
                t, router, w1, w3, w2, axis_name=tp, n_experts=cfg.n_experts,
                top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                n_chunks=run.moe_chunks, ring_combine=run.pk_ring_psum)
            return y.reshape(x_.shape), lax.pmean(aux, tp)[None]

        # device-major PGL weights: (M, E_loc, d[, /fsdp], ff_loc)
        wspec = P(tp, None, rules.dim(cfg.d_model, f), None)
        w2spec = P(tp, None, None, rules.dim(cfg.d_model, f))

    out, aux = compat.shard_map(
        island, mesh=rules.mesh,
        in_specs=(P(bspec, None, None), P(), wspec,
                  wspec if cfg.gated_mlp else P(), w2spec),
        out_specs=(P(bspec, None, None), P(bspec)),
        check_vma=False)(x, p["router"], p["w1"],
                         p["w3"] if cfg.gated_mlp else jnp.zeros((), x.dtype),
                         p["w2"])
    return out, jnp.mean(aux.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Vocab-parallel embedding + loss
# ---------------------------------------------------------------------------

def embed_tokens(p, tokens, rules: ShardingRules | None):
    """tokens (B, S) -> (B, S, d). Megatron vocab-parallel gather+psum island
    when sharded; plain take otherwise."""
    emb = p["embed"]
    if rules is None:
        return jnp.take(emb, tokens, axis=0)
    v, d_model = emb.shape
    tp = rules.tp
    f = rules.fsdp_axes
    if v % rules.mesh.shape[tp] != 0:
        return jnp.take(emb, tokens, axis=0)

    def island(emb_, tok):
        # gather from the LOCAL (V_loc, d_loc) shard, then combine with
        # activation-sized collectives — never all-gather the table itself
        # (a (B,S)-token lookup must move O(B·S·d), not O(V·d)).
        v_loc = emb_.shape[0]
        v0 = lax.axis_index(tp) * v_loc
        local = tok - v0
        ok = (local >= 0) & (local < v_loc)
        x = jnp.take(emb_, jnp.clip(local, 0, v_loc - 1), axis=0)
        x = jnp.where(ok[..., None], x, 0)
        x = lax.psum(x, tp)                      # combine vocab shards
        if f is not None and x.shape[-1] < d_model:
            names = (f,) if isinstance(f, str) else tuple(f)
            for a in names:                      # gather the d shards
                x = lax.all_gather(x, a, axis=-1, tiled=True)
        return x

    bspec = rules.dim(tokens.shape[0], rules.dp)
    return compat.shard_map(
        island, mesh=rules.mesh,
        in_specs=(P(tp, rules.dim(emb.shape[1], rules.fsdp_axes)),
                  P(bspec, None)),
        out_specs=P(bspec, None, None), check_vma=False)(emb, tokens)


def lm_loss(p, x, targets, weights, cfg: ArchConfig, run: RunConfig,
            rules: ShardingRules | None, *, chunk: int = 512):
    """Chunked vocab-parallel cross-entropy. x: (B,S,d); targets (B,S).
    Never materializes the full (B,S,V) logits: sequence is chunked and the
    softmax statistics are psum-merged over the vocab (tp) shard."""
    head = p["lm_head"]
    b, s, d = x.shape
    v = head.shape[1]
    tp = rules.tp if rules is not None else None
    sharded = rules is not None and v % rules.mesh.shape[tp] == 0
    n_chunks = max(1, s // chunk) if s % chunk == 0 else 1
    xc = x.reshape(b, n_chunks, s // n_chunks, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(b, n_chunks, s // n_chunks).transpose(1, 0, 2)
    wc = weights.reshape(b, n_chunks, s // n_chunks).transpose(1, 0, 2)

    if not sharded:
        def body(carry, args):
            xi, ti, wi = args
            logits = jnp.einsum("bsd,dv->bsv", xi, head).astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            tgt = jnp.take_along_axis(logits, ti[..., None], axis=-1)[..., 0]
            return (carry[0] + jnp.sum((lse - tgt) * wi),
                    carry[1] + jnp.sum(wi)), None
        (tot, cnt), _ = lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (xc, tc, wc))
        return tot / jnp.maximum(cnt, 1.0)

    hspec = rules.w2d(d, v, tp_dim=1)
    f = rules.fsdp_axes

    def island(xc_, tc_, wc_, head_):
        head_ = _maybe_allgather(head_, f, 0, d)      # FSDP gather of d
        v_loc = head_.shape[1]
        v0 = lax.axis_index(tp) * v_loc

        def body(carry, args):
            xi, ti, wi = args
            logits = jnp.einsum("bsd,dv->bsv", xi, head_).astype(jnp.float32)
            # global max is for numerical stability only — no gradient needed
            m_loc = lax.stop_gradient(logits).max(axis=-1)
            m = lax.pmax(m_loc, tp)
            se = lax.psum(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1), tp)
            lse = m + jnp.log(se)
            loc = ti - v0
            ok = (loc >= 0) & (loc < v_loc)
            tgt = jnp.take_along_axis(logits, jnp.clip(loc, 0, v_loc - 1)[..., None],
                                      axis=-1)[..., 0]
            tgt = lax.psum(jnp.where(ok, tgt, 0.0), tp)
            # rank-1 carries: legacy shard_map cannot transpose rank-0
            # residuals crossing the island boundary
            return (carry[0] + jnp.sum((lse - tgt) * wi)[None],
                    carry[1] + jnp.sum(wi)[None]), None

        (tot, cnt), _ = lax.scan(body, (jnp.zeros((1,)), jnp.zeros((1,))),
                                 (xc_, tc_, wc_))
        return tot, cnt

    bspec = rules.dim(b, rules.dp)
    tot, cnt = compat.shard_map(
        island, mesh=rules.mesh,
        in_specs=(P(None, bspec, None, None), P(None, bspec),
                  P(None, bspec), hspec),
        out_specs=(P(bspec), P(bspec)), check_vma=False)(xc, tc, wc, head)
    return jnp.sum(tot) / jnp.maximum(jnp.sum(cnt), 1.0)


def lm_logits(p, x, rules: ShardingRules | None):
    """Full logits for serving (B, S, V)."""
    logits = jnp.einsum("bsd,dv->bsv", x, p["lm_head"]).astype(jnp.float32)
    if rules is not None:
        logits = constrain(logits, rules,
                           P(rules.dp, None,
                             rules.dim(logits.shape[-1], rules.tp)))
    return logits
