"""Model assembly: parameter templates (shape+sharding+init in one source of
truth), scan-over-periods forward passes for train / prefill / decode, for all
assigned families (dense, MoE, SSM, hybrid, enc-dec, VLM/audio-stub).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ArchConfig, LayerSpec, RunConfig
from repro.core.moe import ep_tp_split
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.sharding import ShardingRules

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


@dataclasses.dataclass(frozen=True)
class PD:
    """Parameter definition: shape + sharding spec + init rule."""
    shape: tuple[int, ...]
    spec: P
    init: str = "normal"          # normal | zeros | ones | a_log | dt_bias
    dtype: Any = jnp.bfloat16

    def stacked(self, n: int) -> "PD":
        return dataclasses.replace(self, shape=(n, *self.shape),
                                   spec=P(None, *self.spec))


def _is_pd(x):
    return isinstance(x, PD)


# ---------------------------------------------------------------------------
# Templates
# ---------------------------------------------------------------------------

def _attn_pds(cfg: ArchConfig, r: ShardingRules | None, dt) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    sp = (lambda i, o, tp_dim: r.w2d(i, o, tp_dim=tp_dim)) if r else \
        (lambda i, o, tp_dim: P(None, None))
    return {
        "norm": PD((d,), P(None), "ones", dt),
        "wq": PD((d, hq * hd), sp(d, hq * hd, 1), "normal", dt),
        "wk": PD((d, hkv * hd), sp(d, hkv * hd, 1), "normal", dt),
        "wv": PD((d, hkv * hd), sp(d, hkv * hd, 1), "normal", dt),
        "wo": PD((hq * hd, d), sp(hq * hd, d, 0), "normal", dt),
    }


def _mlp_pds(cfg: ArchConfig, r: ShardingRules | None, dt) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    sp = (lambda i, o, tp_dim: r.w2d(i, o, tp_dim=tp_dim)) if r else \
        (lambda i, o, tp_dim: P(None, None))
    out = {
        "norm": PD((d,), P(None), "ones", dt),
        "w1": PD((d, ff), sp(d, ff, 1), "normal", dt),
        "w2": PD((ff, d), sp(ff, d, 0), "normal", dt),
    }
    if cfg.gated_mlp:
        out["w3"] = PD((d, ff), sp(d, ff, 1), "normal", dt)
    return out


def _moe_pds(cfg: ArchConfig, r: ShardingRules | None, dt) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    m = r.mesh.shape[r.tp] if r is not None else 1
    ep, tp_ff = ep_tp_split(e, m)
    e_loc, ff_loc = e // ep, ff // tp_ff
    fs = r.dim(d, r.fsdp_axes) if r is not None else None
    tp = r.tp if r is not None else None
    if r is not None and r.run.serve_moe_tp_data:
        # resident 2D TP: ff_loc sharded over the dp axes as tensor
        # parallelism — serving never all-gathers expert weights
        dpff = r.dim(ff_loc, r.dp)
        w1s = P(tp, None, None, dpff)
        w2s = P(tp, None, dpff, None)
    else:
        w1s = P(tp, None, fs, None)
        w2s = P(tp, None, None, fs)
    out = {
        "norm": PD((d,), P(None), "ones", dt),
        "router": PD((d, e), P(None, None), "normal", jnp.float32),
        # device-major PGL layout over the tp axis (DESIGN §4 EP×TP)
        "w1": PD((m, e_loc, d, ff_loc), w1s, "normal", dt),
        "w2": PD((m, e_loc, ff_loc, d), w2s, "normal", dt),
    }
    if cfg.gated_mlp:
        out["w3"] = PD((m, e_loc, d, ff_loc), w1s, "normal", dt)
    return out


def _mamba_pds(cfg: ArchConfig, r: ShardingRules | None, dt) -> dict:
    d, di, n, ck, dtr = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                         cfg.conv_kernel, cfg.dtr)
    tp = r.tp if r is not None else None
    fs = r.dim(d, r.fsdp_axes) if r is not None else None
    tpd = (lambda s: r.dim(s, tp)) if r is not None else (lambda s: None)
    return {
        "norm": PD((d,), P(None), "ones", dt),
        "in_proj": PD((d, 2 * di), P(fs, tpd(2 * di)), "normal", dt),
        "conv_w": PD((di, ck), P(tpd(di), None), "normal", dt),
        "conv_b": PD((di,), P(tpd(di)), "zeros", dt),
        "x_proj": PD((di, dtr + 2 * n), P(tpd(di), None), "normal", dt),
        "dt_proj": PD((dtr, di), P(None, tpd(di)), "normal", dt),
        "dt_bias": PD((di,), P(tpd(di)), "dt_bias", jnp.float32),
        "A_log": PD((di, n), P(tpd(di), None), "a_log", jnp.float32),
        "D": PD((di,), P(tpd(di)), "ones", jnp.float32),
        "out_proj": PD((di, d), P(tpd(di), fs), "normal", dt),
    }


def _block_pds(spec: LayerSpec, cfg: ArchConfig, r, dt, *, cross: bool) -> dict:
    out = {}
    if spec.mixer == "attn":
        out["attn"] = _attn_pds(cfg, r, dt)
    else:
        out["mamba"] = _mamba_pds(cfg, r, dt)
    if cross:
        out["cross"] = _attn_pds(cfg, r, dt)
    if spec.mlp == "dense":
        out["mlp"] = _mlp_pds(cfg, r, dt)
    elif spec.mlp == "moe":
        out["moe"] = _moe_pds(cfg, r, dt)
    return out


def param_template(cfg: ArchConfig, run: RunConfig,
                   rules: ShardingRules | None) -> dict:
    """The full parameter tree as PDs (single source of truth for shapes,
    shardings and init)."""
    dt = DTYPES[cfg.dtype]
    d = cfg.d_model
    v = cfg.padded_vocab(rules.mesh.shape[rules.tp] if rules else 16)
    fs = rules.dim(d, rules.fsdp_axes) if rules is not None else None
    tpv = rules.dim(v, rules.tp) if rules is not None else None
    tree: dict[str, Any] = {
        "embed": PD((v, d), P(tpv, fs), "normal", dt),
        "final_norm": PD((d,), P(None), "ones", dt),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = PD((d, v), P(fs, tpv), "normal", dt)
    pattern = cfg.layer_pattern()
    blocks = {}
    for i, spec in enumerate(pattern):
        pds = _block_pds(spec, cfg, rules, dt, cross=cfg.encoder_decoder)
        blocks[f"pos{i}"] = jax.tree.map(
            lambda pd: pd.stacked(cfg.n_periods), pds, is_leaf=_is_pd)
    tree["blocks"] = blocks
    if cfg.encoder_decoder:
        enc_pds = _block_pds(LayerSpec("attn", "dense"), cfg, rules, dt,
                             cross=False)
        tree["enc_blocks"] = jax.tree.map(
            lambda pd: pd.stacked(cfg.n_encoder_layers), enc_pds,
            is_leaf=_is_pd)
        tree["enc_final_norm"] = PD((d,), P(None), "ones", dt)
    return tree


def param_specs(template) -> Any:
    return jax.tree.map(lambda pd: pd.spec, template, is_leaf=_is_pd)


def abstract_params(template) -> Any:
    return jax.tree.map(lambda pd: jax.ShapeDtypeStruct(pd.shape, pd.dtype),
                        template, is_leaf=_is_pd)


def init_params(template, key, d_model: int) -> Any:
    leaves, treedef = jax.tree.flatten(template, is_leaf=_is_pd)
    keys = jax.random.split(key, len(leaves))
    scale = d_model ** -0.5

    def mk(pd: PD, k):
        if pd.init == "zeros":
            return jnp.zeros(pd.shape, pd.dtype)
        if pd.init == "ones":
            return jnp.ones(pd.shape, pd.dtype)
        if pd.init == "a_log":
            n = pd.shape[-1]
            return jnp.broadcast_to(jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)),
                                    pd.shape).astype(pd.dtype)
        if pd.init == "dt_bias":
            u = jax.random.uniform(k, pd.shape, jnp.float32,
                                   minval=1e-3, maxval=1e-1)
            return (u + jnp.log(-jnp.expm1(-u))).astype(pd.dtype)  # inv softplus
        return (jax.random.normal(k, pd.shape, jnp.float32)
                * scale).astype(pd.dtype)

    return jax.tree.unflatten(treedef, [mk(pd, k) for pd, k in
                                        zip(leaves, keys)])


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _bf16_grad_barrier(x):
    return x


def _bgb_fwd(x):
    return x, None


def _bgb_bwd(_, g):
    # Cast the residual-stream cotangent to bf16: the backward Megatron
    # all-reduces inherit this dtype — 2x less AR traffic, and bf16 is the
    # standard production choice for activation grads (§Perf G2).
    return (g.astype(jnp.bfloat16).astype(g.dtype) if g.dtype == jnp.float32
            else g,)


_bf16_grad_barrier.defvjp(_bgb_fwd, _bgb_bwd)


def _apply_block(bp, spec: LayerSpec, x, cfg, run, rules, *, causal=True,
                 enc_out=None, seq_sharded=False):
    """One layer, pre-norm residual. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if run.bf16_backward_ars:
        x = _bf16_grad_barrier(x)
    if spec.mixer == "attn":
        a = bp["attn"]
        h = L.attention_block(a, L.rms_norm(a["norm"], x, cfg.norm_eps),
                              cfg, run, rules, causal=causal,
                              seq_sharded=seq_sharded)
        x = x + checkpoint_name(h, "subblock_out")
    else:
        mp = bp["mamba"]
        h, _ = S.mamba_block(mp, L.rms_norm(mp["norm"], x, cfg.norm_eps),
                             cfg, run, rules)
        x = x + checkpoint_name(h, "subblock_out")
    if enc_out is not None and "cross" in bp:
        cp = bp["cross"]
        hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        b, se, _ = enc_out.shape
        k = jnp.einsum("bsd,dh->bsh", enc_out, cp["wk"]).reshape(
            b, se, hkv, hd).transpose(0, 2, 1, 3)
        v = jnp.einsum("bsd,dh->bsh", enc_out, cp["wv"]).reshape(
            b, se, hkv, hd).transpose(0, 2, 1, 3)
        h = L.attention_block(cp, L.rms_norm(cp["norm"], x, cfg.norm_eps),
                              cfg, run, rules, causal=False, cross_kv=(k, v))
        x = x + h
    if spec.mlp == "dense":
        mp = bp["mlp"]
        h = L.mlp_block(mp, L.rms_norm(mp["norm"], x, cfg.norm_eps),
                        cfg, run, rules)
        x = x + checkpoint_name(h, "subblock_out")
    elif spec.mlp == "moe":
        mp = bp["moe"]
        h, a_l = L.moe_block(mp, L.rms_norm(mp["norm"], x, cfg.norm_eps),
                             cfg, run, rules)
        x = x + checkpoint_name(h, "subblock_out")
        aux = aux + a_l
    return x, aux


def _scan_blocks(blocks, x, cfg: ArchConfig, run: RunConfig, rules, *,
                 causal=True, enc_out=None, seq_sharded=False):
    """lax.scan over n_periods; each step applies the full layer pattern."""
    pattern = cfg.layer_pattern()

    def body(carry, period_params):
        x, aux = carry
        for i, spec in enumerate(pattern):
            x, a = _apply_block(period_params[f"pos{i}"], spec, x, cfg, run,
                                rules, causal=causal, enc_out=enc_out,
                                seq_sharded=seq_sharded)
            aux = aux + a
        return (x, aux), None

    if run.remat:
        policy = (jax.checkpoint_policies.save_only_these_names(
            "subblock_out") if run.save_collectives else None)
        body = jax.checkpoint(body, prevent_cse=False, policy=policy)
    if not run.scan_layers:                  # cost-calibration: no while loop
        carry = (x, jnp.zeros((), jnp.float32))
        for i in range(cfg.n_periods):
            carry, _ = body(carry, jax.tree.map(lambda a: a[i], blocks))
        return carry
    (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)), blocks)
    return x, aux


def _scan_encoder(enc_blocks, x, cfg, run, rules):
    def body(carry, lp):
        x = carry
        x, _ = _apply_block(lp, LayerSpec("attn", "dense"), x, cfg, run,
                            rules, causal=False)
        return x, None

    if run.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    if not run.scan_layers:
        n_enc = jax.tree.leaves(enc_blocks)[0].shape[0]
        for i in range(n_enc):
            x, _ = body(x, jax.tree.map(lambda a: a[i], enc_blocks))
        return x
    x, _ = lax.scan(body, x, enc_blocks)
    return x


def _merge_frontend(x_tok, frontend_embeds, cfg: ArchConfig):
    """VLM: replace the first n_frontend_tokens embeddings with the stub
    patch embeddings (precomputed by input_specs)."""
    if frontend_embeds is None or cfg.frontend != "vision":
        return x_tok
    n = cfg.n_frontend_tokens
    return jnp.concatenate([frontend_embeds.astype(x_tok.dtype),
                            x_tok[:, n:]], axis=1)


def forward_train(params, batch, cfg: ArchConfig, run: RunConfig,
                  rules: ShardingRules | None, *, seq_sharded=False):
    """Returns (loss, metrics). batch keys: tokens (B,S), targets (B,S),
    weights (B,S) [+ frontend_embeds (B,n,d) | enc_embeds (B,Se,d)]."""
    tokens = batch["tokens"]
    x = L.embed_tokens(params, tokens, rules, run)
    x = _merge_frontend(x, batch.get("frontend_embeds"), cfg)
    if rules is not None:
        x = L.constrain(x, rules, rules.act_btd())

    enc_out = None
    if cfg.encoder_decoder:
        enc_x = batch["enc_embeds"].astype(x.dtype)
        enc_out = _scan_encoder(params["enc_blocks"], enc_x, cfg, run, rules)
        enc_out = L.rms_norm(params["enc_final_norm"], enc_out, cfg.norm_eps)

    x, aux = _scan_blocks(params["blocks"], x, cfg, run, rules,
                          causal=True, enc_out=enc_out,
                          seq_sharded=seq_sharded)
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    loss = L.lm_loss({"lm_head": head}, x, batch["targets"], batch["weights"],
                     cfg, run, rules, chunk=run.loss_chunk)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux_loss": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode with caches
# ---------------------------------------------------------------------------

def cache_template(cfg: ArchConfig, run: RunConfig, rules: ShardingRules | None,
                   *, batch: int, s_max: int, enc_len: int = 0,
                   long_ctx: bool = False, slot_pos: bool = False,
                   kv_dtype: str = "bf16") -> dict:
    """ShapeDtypeStruct+spec tree for the decode cache (PD-style).

    ``slot_pos=True`` gives the cache a per-slot ``(batch,)`` position
    vector instead of the lockstep scalar — the continuous-batching engine's
    decode pool holds sequences admitted at different times.

    ``kv_dtype="int8"`` stores the K/V slabs as int8 and adds per-(token,
    head) f32 scale planes (``k_scale``/``v_scale``, the slab shape minus
    hd) — the layers quantize on write and dequantize on read, roughly
    halving cache HBM. ``"bf16"`` (default) is byte-for-byte today's tree."""
    dt = DTYPES[cfg.dtype]
    hkv, hd, di, n, ck = (cfg.n_kv_heads, cfg.hd, cfg.d_inner, cfg.ssm_state,
                          cfg.conv_kernel)
    np_ = cfg.n_periods
    kv_spec = rules.kv_cache(hkv, batch, long_ctx=long_ctx) if rules else \
        P(None, None, None, None)
    ssm_spec = rules.ssm_cache(batch) if rules else P(None, None)
    bspec = rules.dim(batch, rules.dp) if rules else None
    pos_pd = PD((batch,), P(bspec), "zeros", jnp.int32) if slot_pos \
        else PD((), P(), "zeros", jnp.int32)
    tree: dict[str, Any] = {"pos": pos_pd, "blocks": {}}
    kv_dt = {"bf16": dt, "int8": jnp.int8}[kv_dtype]
    for i, spec in enumerate(cfg.layer_pattern()):
        if spec.mixer == "attn":
            kv = {
                "k": PD((np_, batch, hkv, s_max, hd), P(None, *kv_spec), "zeros", kv_dt),
                "v": PD((np_, batch, hkv, s_max, hd), P(None, *kv_spec), "zeros", kv_dt),
            }
            if kv_dtype == "int8":
                sspec = P(None, *kv_spec[:3])
                kv["k_scale"] = PD((np_, batch, hkv, s_max), sspec, "zeros",
                                   jnp.float32)
                kv["v_scale"] = PD((np_, batch, hkv, s_max), sspec, "zeros",
                                   jnp.float32)
            tree["blocks"][f"pos{i}"] = kv
        else:
            tree["blocks"][f"pos{i}"] = {
                "h": PD((np_, batch, di, n), P(None, *ssm_spec), "zeros", jnp.float32),
                "conv": PD((np_, batch, ck - 1, di),
                           P(None, bspec, None,
                             rules.dim(di, rules.tp) if rules else None),
                           "zeros", dt),
            }
    if cfg.encoder_decoder and enc_len:
        # cross K/V must be sequence-sharded too — replicated over the tp
        # axis it would cost O(B*Henc*Senc*hd) per device (27 GB observed)
        enc_sp = rules.dim(enc_len, rules.tp) if rules else None
        cross_spec = P(None, bspec, None, enc_sp, None)
        tree["cross"] = {
            "k": PD((np_ * len(cfg.layer_pattern()), batch, hkv, enc_len, hd),
                    cross_spec, "zeros", dt),
            "v": PD((np_ * len(cfg.layer_pattern()), batch, hkv, enc_len, hd),
                    cross_spec, "zeros", dt),
        }
    return tree


def decode_step(params, cache, tokens, cfg: ArchConfig, run: RunConfig,
                rules: ShardingRules | None, *, long_ctx: bool = False):
    """One decode step. tokens: (B, 1) int32. Returns (logits, new_cache).

    Scans over periods with the per-period cache slices threaded as scan
    inputs/outputs. RoPE position = cache["pos"]. A cache carrying
    ``block_tables`` routes attention through the paged-pool islands
    (``runtime/paging.py`` layout) — same step signature, so
    ``make_serve_step`` and the engine's jit/donation story are unchanged.
    """
    pos = cache["pos"]
    bt = cache.get("block_tables")
    x = L.embed_tokens(params, tokens, rules, run)
    pattern = cfg.layer_pattern()

    def body(x, args):
        period_params, period_cache = args
        new_cache = {}
        for i, spec in enumerate(pattern):
            bp = period_params[f"pos{i}"]
            cp = period_cache[f"pos{i}"]
            if spec.mixer == "attn":
                a = bp["attn"]
                scales = ({"k_scale": cp["k_scale"], "v_scale": cp["v_scale"]}
                          if "k_scale" in cp else {})
                if bt is not None:
                    h, nk, nv, *ns = L.paged_decode_attention(
                        a, L.rms_norm(a["norm"], x, cfg.norm_eps), cp["k"],
                        cp["v"], bt, pos, cfg, run, rules, **scales)
                else:
                    h, nk, nv, *ns = L.decode_attention(
                        a, L.rms_norm(a["norm"], x, cfg.norm_eps), cp["k"],
                        cp["v"], pos, cfg, run, rules, long_ctx=long_ctx,
                        **scales)
                x = x + h
                nc = {"k": nk, "v": nv}
                if scales:
                    nc["k_scale"], nc["v_scale"] = ns
                new_cache[f"pos{i}"] = nc
            else:
                mp = bp["mamba"]
                h, (nh, nconv) = S.mamba_block(
                    mp, L.rms_norm(mp["norm"], x, cfg.norm_eps), cfg, run,
                    rules, cache=(cp["h"], cp["conv"]))
                x = x + h
                new_cache[f"pos{i}"] = {"h": nh, "conv": nconv}
            if spec.mlp == "dense":
                mp = bp["mlp"]
                x = x + L.mlp_block(mp, L.rms_norm(mp["norm"], x, cfg.norm_eps),
                                    cfg, run, rules)
            elif spec.mlp == "moe":
                mp = bp["moe"]
                h, _ = L.moe_block(mp, L.rms_norm(mp["norm"], x, cfg.norm_eps),
                                   cfg, run, rules)
                x = x + h
        return x, new_cache

    if not run.scan_layers:
        new_list = []
        for i in range(cfg.n_periods):
            x, nc = body(x, jax.tree.map(lambda a: a[i],
                                         (params["blocks"], cache["blocks"])))
            new_list.append(nc)
        new_blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *new_list)
    else:
        x, new_blocks = lax.scan(body, x, (params["blocks"], cache["blocks"]))
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    logits = L.lm_logits({"lm_head": head}, x, rules)
    new_cache = {"pos": pos + 1, "blocks": new_blocks}
    if bt is not None:
        new_cache["block_tables"] = bt
    if "cross" in cache:
        new_cache["cross"] = cache["cross"]
    return logits, new_cache


def decode_step_encdec(params, cache, tokens, cfg: ArchConfig, run: RunConfig,
                       rules: ShardingRules | None):
    """Whisper decode: self-attention cache + precomputed cross K/V."""
    pos = cache["pos"]
    x = L.embed_tokens(params, tokens, rules, run)
    pattern = cfg.layer_pattern()
    ck, cv = cache["cross"]["k"], cache["cross"]["v"]

    def body(carry, args):
        x, li = carry
        period_params, period_cache = args
        new_cache = {}
        for i, spec in enumerate(pattern):
            bp = period_params[f"pos{i}"]
            cp = period_cache[f"pos{i}"]
            a = bp["attn"]
            h, nk, nv = L.decode_attention(
                a, L.rms_norm(a["norm"], x, cfg.norm_eps), cp["k"], cp["v"],
                pos, cfg, run, rules)
            x = x + h
            new_cache[f"pos{i}"] = {"k": nk, "v": nv}
            crp = bp["cross"]
            h, _, _ = L.decode_attention(
                crp, L.rms_norm(crp["norm"], x, cfg.norm_eps), None, None,
                pos, cfg, run, rules, cross_kv=(ck[li], cv[li]))
            x = x + h
            mp = bp["mlp"]
            x = x + L.mlp_block(mp, L.rms_norm(mp["norm"], x, cfg.norm_eps),
                                cfg, run, rules)
        return (x, li + 1), new_cache

    if not run.scan_layers:
        carry = (x, 0)
        new_list = []
        for i in range(cfg.n_periods):
            carry, nc = body(carry, jax.tree.map(
                lambda a: a[i], (params["blocks"], cache["blocks"])))
            new_list.append(nc)
        x, _ = carry
        new_blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *new_list)
    else:
        (x, _), new_blocks = lax.scan(body, (x, 0),
                                      (params["blocks"], cache["blocks"]))
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    logits = L.lm_logits({"lm_head": head}, x, rules)
    return logits, {"pos": pos + 1, "blocks": new_blocks,
                    "cross": cache["cross"]}


def prefill_step(params, cache, tokens, prompt_lens, cfg: ArchConfig,
                 run: RunConfig, rules: ShardingRules | None):
    """Batched cache-building prefill: ONE full-sequence forward over the
    (right-padded) prompts writes every layer's K/V — and SSM state — into
    the decode cache and returns each slot's next-token logits.

    tokens: (B, L) int32 right-padded prompts; prompt_lens: (B,) real
    lengths (or a scalar for uniform lockstep prefill). Returns
    (logits (B, 1, V) at each slot's last real position, cache) with
    ``cache["pos"]`` set to the prompt lengths. Right-padding is masked for
    attention (decode attends ``ki < pos``); SSM state cannot mask pads, so
    SSM/hybrid callers must prefill at exact prompt length (the serving
    engine's ``exact_buckets``). The GEMM islands inside run at the bucket's
    (B, L) coordinates — the prefill half of the per-bucket plan story.
    """
    if cfg.encoder_decoder:
        raise NotImplementedError(
            "batched cache prefill covers decoder-only models; the enc-dec "
            "path precomputes cross K/V separately (decode_step_encdec)")
    b, s = tokens.shape
    x = L.embed_tokens(params, tokens, rules, run)
    if rules is not None:
        x = L.constrain(x, rules, rules.act_btd())
    pattern = cfg.layer_pattern()

    def body(x, args):
        period_params, period_cache = args
        new_cache = {}
        for i, spec in enumerate(pattern):
            bp = period_params[f"pos{i}"]
            cp = period_cache[f"pos{i}"]
            if spec.mixer == "attn":
                a = bp["attn"]
                scales = ({"k_scale": cp["k_scale"], "v_scale": cp["v_scale"]}
                          if "k_scale" in cp else {})
                h, nk, nv, *ns = L.prefill_attention_block(
                    a, L.rms_norm(a["norm"], x, cfg.norm_eps), cp["k"],
                    cp["v"], cfg, run, rules, **scales)
                x = x + h
                nc = {"k": nk, "v": nv}
                if scales:
                    nc["k_scale"], nc["v_scale"] = ns
                new_cache[f"pos{i}"] = nc
            else:
                mp = bp["mamba"]
                h, (nh, nconv) = S.mamba_block(
                    mp, L.rms_norm(mp["norm"], x, cfg.norm_eps), cfg, run,
                    rules, cache=(cp["h"], cp["conv"]))
                x = x + h
                new_cache[f"pos{i}"] = {"h": nh, "conv": nconv}
            if spec.mlp == "dense":
                mp = bp["mlp"]
                x = x + L.mlp_block(mp, L.rms_norm(mp["norm"], x,
                                                   cfg.norm_eps),
                                    cfg, run, rules)
            elif spec.mlp == "moe":
                mp = bp["moe"]
                h, _ = L.moe_block(mp, L.rms_norm(mp["norm"], x,
                                                  cfg.norm_eps),
                                   cfg, run, rules)
                x = x + h
        return x, new_cache

    if not run.scan_layers:
        new_list = []
        for i in range(cfg.n_periods):
            x, nc = body(x, jax.tree.map(lambda a: a[i],
                                         (params["blocks"], cache["blocks"])))
            new_list.append(nc)
        new_blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *new_list)
    else:
        x, new_blocks = lax.scan(body, x, (params["blocks"], cache["blocks"]))
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    # per-slot last REAL position only — never the (B, L, V) logits
    idx = jnp.reshape(jnp.asarray(prompt_lens) - 1, (-1, 1, 1))
    x_last = jnp.take_along_axis(
        x, jnp.broadcast_to(idx, (b, 1, x.shape[-1])), axis=1)
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    logits = L.lm_logits({"lm_head": head}, x_last, rules)
    new_pos = (jnp.broadcast_to(jnp.asarray(prompt_lens), (b,))
               if jnp.ndim(cache["pos"]) else
               jnp.asarray(prompt_lens).reshape(()).astype(jnp.int32))
    new_cache = {"pos": new_pos.astype(jnp.int32), "blocks": new_blocks}
    if "cross" in cache:
        new_cache["cross"] = cache["cross"]
    return logits, new_cache


def prefill_paged_step(params, cache, tokens, block_tables, prompt_lens,
                       chunk_start, write_from, cfg: ArchConfig,
                       run: RunConfig, rules: ShardingRules | None):
    """One chunk of paged cache-building prefill.

    tokens: (G, cl) — the chunk's token window, global positions
    [chunk_start, chunk_start+cl); block_tables: (G, P) the *group's* page
    mapping (NOT the live cache rows — those stay at the −1 sentinel until
    the final chunk commits, so interleaved decode ticks cannot touch
    half-built pages); prompt_lens: (G,) real lengths; write_from: (G,)
    per-slot floor below which K/V writes are suppressed (positions already
    covered by shared prefix pages). Returns (logits (G, 1, V) at each
    slot's last real position *clamped into this chunk* — the engine keeps
    the logits from the chunk containing L−1 — and the cache with updated
    pools). Attention-only architectures (paged_cache_template validates).
    """
    b, s = tokens.shape
    x = L.embed_tokens(params, tokens, rules, run)
    if rules is not None:
        x = L.constrain(x, rules, rules.act_btd())
    pattern = cfg.layer_pattern()
    c0 = jnp.asarray(chunk_start, jnp.int32)
    wf = jnp.asarray(write_from, jnp.int32)

    def body(x, args):
        period_params, period_cache = args
        new_cache = {}
        for i, spec in enumerate(pattern):
            bp = period_params[f"pos{i}"]
            cp = period_cache[f"pos{i}"]
            assert spec.mixer == "attn", "paged prefill is attention-only"
            a = bp["attn"]
            scales = ({"k_scale": cp["k_scale"], "v_scale": cp["v_scale"]}
                      if "k_scale" in cp else {})
            h, nk, nv, *ns = L.paged_prefill_attention_block(
                a, L.rms_norm(a["norm"], x, cfg.norm_eps), cp["k"],
                cp["v"], block_tables, c0, wf, cfg, run, rules, **scales)
            x = x + h
            nc = {"k": nk, "v": nv}
            if scales:
                nc["k_scale"], nc["v_scale"] = ns
            new_cache[f"pos{i}"] = nc
            if spec.mlp == "dense":
                mp = bp["mlp"]
                x = x + L.mlp_block(mp, L.rms_norm(mp["norm"], x,
                                                   cfg.norm_eps),
                                    cfg, run, rules)
            elif spec.mlp == "moe":
                mp = bp["moe"]
                h, _ = L.moe_block(mp, L.rms_norm(mp["norm"], x,
                                                  cfg.norm_eps),
                                   cfg, run, rules)
                x = x + h
        return x, new_cache

    if not run.scan_layers:
        new_list = []
        for i in range(cfg.n_periods):
            x, nc = body(x, jax.tree.map(lambda a: a[i],
                                         (params["blocks"], cache["blocks"])))
            new_list.append(nc)
        new_blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *new_list)
    else:
        x, new_blocks = lax.scan(body, x, (params["blocks"], cache["blocks"]))
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    # each slot's last real position clamped into this chunk's window — the
    # engine keeps the logits row from the chunk that contains L−1
    idx = jnp.clip(jnp.asarray(prompt_lens) - 1 - c0, 0, s - 1)
    idx = jnp.reshape(idx, (-1, 1, 1))
    x_last = jnp.take_along_axis(
        x, jnp.broadcast_to(idx, (b, 1, x.shape[-1])), axis=1)
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    logits = L.lm_logits({"lm_head": head}, x_last, rules)
    # pos and the live block tables pass through untouched: the engine
    # commits both host-side only after the final chunk
    return logits, {"pos": cache["pos"], "blocks": new_blocks,
                    "block_tables": cache["block_tables"]}


def forward_prefill(params, batch, cfg: ArchConfig, run: RunConfig,
                    rules: ShardingRules | None):
    """Prefill forward: full-sequence logits for the last position.

    For the dry-run's prefill cells this is the train forward without the
    loss (cache building is exercised by the serving example; the dominant
    cost — the full forward — is identical)."""
    tokens = batch["tokens"]
    x = L.embed_tokens(params, tokens, rules, run)
    x = _merge_frontend(x, batch.get("frontend_embeds"), cfg)
    if rules is not None:
        x = L.constrain(x, rules, rules.act_btd())
    enc_out = None
    if cfg.encoder_decoder:
        enc_x = batch["enc_embeds"].astype(x.dtype)
        enc_out = _scan_encoder(params["enc_blocks"], enc_x, cfg, run, rules)
        enc_out = L.rms_norm(params["enc_final_norm"], enc_out, cfg.norm_eps)
    x, _ = _scan_blocks(params["blocks"], x, cfg, run, rules, causal=True,
                        enc_out=enc_out)
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    return L.lm_logits({"lm_head": head}, x[:, -1:], rules)
