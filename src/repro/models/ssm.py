"""Mamba-1 selective SSM block (falcon-mamba, jamba mamba layers).

Training/prefill uses a chunked state-passing scan: within-chunk parallel
(associative affine composition), across-chunk sequential (lax.scan carry).
All (B, chunk, d_inner, N) tensors are materialized only inside the chunk
body, bounding peak memory to one chunk — the same blocking the Pallas kernel
(kernels/mamba_scan.py) uses on TPU VMEM. Decode is the O(1) recurrence.

Sequence parallelism across devices reuses core.ring_attention.ssm_entry_states
(chunk decay/exit composition around the ring).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, RunConfig
from repro.models.sharding import ShardingRules


def _causal_conv1d(x, w, b):
    """Depthwise causal conv. x: (B, S, di); w: (di, ck); b: (di,)."""
    ck = w.shape[1]
    pad = jnp.pad(x, ((0, 0), (ck - 1, 0), (0, 0)))
    views = [pad[:, i:i + x.shape[1], :] * w[:, i][None, None, :]
             for i in range(ck)]
    return sum(views) + b[None, None, :]


def selective_scan_chunked(dt, b_ssm, c_ssm, x_conv, a, d_skip, h0, *,
                           chunk: int = 256, scan_dtype=jnp.float32):
    """Chunked selective scan.

    dt, x_conv: (B, S, di); b_ssm, c_ssm: (B, S, N); a: (di, N); h0: (B, di, N).
    Returns (y (B, S, di) f32, h_last). Nothing of size (B, S, di, N) is ever
    materialized — only (B, chunk, di, N) inside the scan body.
    """
    b, s, di = dt.shape
    n = a.shape[1]
    if s % chunk != 0:
        chunk = s
    nc = s // chunk

    def to_chunks(t):
        return t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    dt_c, b_c, c_c, x_c = map(to_chunks, (dt, b_ssm, c_ssm, x_conv))

    def chunk_body(h, args):
        dt_i, b_i, c_i, x_i = args                     # (B, chunk, ...)
        # scan_dtype=bf16 halves the dominant (B,chunk,di,N) HBM traffic
        # (§Perf C1); the cross-chunk carry stays f32 for stability.
        a_bar = jnp.exp(dt_i.astype(jnp.float32)[..., None]
                        * a[None, None]).astype(scan_dtype)
        bx = (dt_i[..., None] * b_i[:, :, None, :]
              * x_i[..., None]).astype(scan_dtype)     # (B, chunk, di, N)

        def comb(u, v):  # affine composition (a,b)∘(a',b') = (a'a, a'b+b')
            return (u[0] * v[0], v[0] * u[1] + v[1])

        aa, bb = lax.associative_scan(comb, (a_bar, bx), axis=1)
        h_all = (aa.astype(jnp.float32) * h[:, None]
                 + bb.astype(jnp.float32))             # (B, chunk, di, N)
        y_i = jnp.einsum("bsdn,bsn->bsd", h_all.astype(scan_dtype),
                         c_i.astype(scan_dtype),
                         preferred_element_type=jnp.float32)
        y_i = y_i + x_i.astype(jnp.float32) * d_skip[None, None, :]
        return h_all[:, -1], y_i

    if nc == 1:
        h_last, y_c = chunk_body(h0, (dt_c[0], b_c[0], c_c[0], x_c[0]))
        return y_c.reshape(b, s, di), h_last
    h_last, y_c = lax.scan(chunk_body, h0, (dt_c, b_c, c_c, x_c))
    y = y_c.swapaxes(0, 1).reshape(b, s, di)
    return y, h_last


def mamba_mix(p, x, cfg: ArchConfig, *, h0=None, conv_state=None,
              chunk: int = 256, return_state: bool = False,
              scan_dtype=jnp.float32):
    """Core mamba mixing. x: (B, S, di) (post in_proj split, pre conv).

    p: {"conv_w","conv_b","x_proj","dt_proj","dt_bias","A_log","D"}.
    Returns y (B, S, di) [+ (h_last, conv_tail) if return_state].
    """
    b, s, di = x.shape
    n = cfg.ssm_state
    if conv_state is not None:  # decode/continuation: prepend cached tail
        xin = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
        x_conv = _causal_conv1d(xin, p["conv_w"], p["conv_b"])[:, -s:]
    else:
        xin = x
        x_conv = _causal_conv1d(x, p["conv_w"], p["conv_b"])
    x_conv = jax.nn.silu(x_conv)

    proj = jnp.einsum("bsd,dr->bsr", x_conv, p["x_proj"])
    dt, b_ssm, c_ssm = jnp.split(proj, [cfg.dtr, cfg.dtr + n], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,rd->bsd", dt, p["dt_proj"])
                         + p["dt_bias"][None, None, :])          # (B,S,di)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))                 # (di, N)

    h0 = jnp.zeros((b, di, n), jnp.float32) if h0 is None else h0
    y, h_last = selective_scan_chunked(dt, b_ssm, c_ssm, x_conv, a,
                                       p["D"].astype(jnp.float32), h0,
                                       chunk=chunk, scan_dtype=scan_dtype)
    y = y.astype(x.dtype)
    if return_state:
        ck = p["conv_w"].shape[1]
        conv_tail = xin[:, -(ck - 1):, :] if ck > 1 else \
            jnp.zeros((b, 0, di), x.dtype)
        return y, (h_last, conv_tail)
    return y


def mamba_block(p, x, cfg: ArchConfig, run: RunConfig,
                rules: ShardingRules | None, *, cache=None):
    """Full mamba block: in_proj -> conv/SSM mix -> gate -> out_proj.

    Train/prefill: cache=None. Decode: cache=(h, conv_tail) (di sharded over
    tp), returns (out, new_cache).
    """
    b, s, d = x.shape
    di = cfg.d_inner
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    x_ssm, z = jnp.split(xz, 2, axis=-1)
    if rules is not None:
        spec = P(rules.dp, None, rules.dim(di, rules.tp))
        x_ssm = lax.with_sharding_constraint(x_ssm, rules.named(spec))
        z = lax.with_sharding_constraint(z, rules.named(spec))

    if cache is None:
        y = mamba_mix(p, x_ssm, cfg, chunk=run.ssm_chunk,
                      scan_dtype=(jnp.bfloat16 if run.ssm_scan_dtype ==
                                  "bfloat16" else jnp.float32))
        new_cache = None
    else:
        h, conv_tail = cache
        y, new_cache = mamba_mix(p, x_ssm, cfg, h0=h, conv_state=conv_tail,
                                 return_state=True)

    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    if rules is not None:
        out = lax.with_sharding_constraint(out, rules.named(rules.act_btd()))
    return out, new_cache


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16):
    di, n, ck = cfg.d_inner, cfg.ssm_state, cfg.conv_kernel
    return (jnp.zeros((batch, di, n), jnp.float32),
            jnp.zeros((batch, ck - 1, di), dtype))
