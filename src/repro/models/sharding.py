"""Sharding rules: DP / FSDP(ZeRO-3) / TP / EP×TP / SP (DESIGN.md §4).

Builds PartitionSpec trees for params, activations and KV caches given the
mesh and RunConfig. Dims are sharded only when divisible by the axis-product;
otherwise replicated (e.g. KV heads when kv < model — Megatron-style
replication).
"""

from __future__ import annotations

import dataclasses
import math

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import RunConfig


def axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return math.prod(mesh.shape[a] for a in axes)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    run: RunConfig

    @property
    def dp(self):  # batch axes
        a = self.run.dp_axes
        return a[0] if len(a) == 1 else a

    @property
    def tp(self) -> str:
        return self.run.tp_axis

    @property
    def fsdp_axes(self):
        return self.dp if self.run.fsdp else None

    def dim(self, size: int, axes):
        """Shard `size` over `axes` iff divisible, else replicate."""
        if axes is None:
            return None
        if size % axes_size(self.mesh, axes) == 0:
            return axes
        return None

    def local_batch(self, b: int) -> int:
        """Per-dp-rank batch under act_btd sharding: b/|dp| when the batch
        shards, b when it replicates (the same rule dim() applies). The
        island builders price their Comm coordinates on this."""
        if self.dim(b, self.dp) is None:
            return b
        return b // axes_size(self.mesh, self.dp)

    def spec(self, *entries) -> P:
        return P(*entries)

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    # --- common param specs (sizes needed for divisibility checks) ---

    def w2d(self, d_in: int, d_out: int, *, tp_dim: int | None) -> P:
        """(d_in, d_out) weight; tp_dim says which dim (0/1/None) is TP."""
        f = self.fsdp_axes
        if tp_dim == 0:
            return P(self.dim(d_in, self.tp), self.dim(d_out, f))
        if tp_dim == 1:
            return P(self.dim(d_in, f), self.dim(d_out, self.tp))
        return P(self.dim(d_in, f), None)

    def stacked(self, spec: P) -> P:
        """Prepend the n_periods scan dim (replicated)."""
        return P(None, *spec)

    # --- activations ---

    def act_btd(self) -> P:           # (B, S, d) residual stream
        return P(self.dp, None, None)

    def act_bhsd(self, n_heads: int) -> P:  # (B, H, S, hd) head-sharded
        return P(self.dp, self.dim(n_heads, self.tp), None, None)

    def act_seq_sharded(self) -> P:   # (B, S, d) sequence-parallel
        return P(self.dp, self.tp, None)

    def kv_cache(self, n_kv: int, batch: int, *, long_ctx: bool = False) -> P:
        """(B, Hkv, S_max, hd). decode_32k: batch over dp, seq over tp.
        long_500k (batch=1): seq over (dp×tp) combined."""
        if not self.run.decode_seq_shard:
            return P(self.dim(batch, self.dp), self.dim(n_kv, self.tp), None, None)
        if long_ctx:
            flat = (tuple(self.run.dp_axes) + (self.tp,))
            return P(None, None, flat, None)
        return P(self.dim(batch, self.dp), None, self.tp, None)

    def ssm_cache(self, batch: int) -> P:
        """(B, d_inner, N) + conv (B, d_inner, ck-1): d_inner over tp."""
        return P(self.dim(batch, self.dp), self.tp, None)
