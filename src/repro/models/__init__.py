from repro.models import layers, ssm, transformer
from repro.models.sharding import ShardingRules
from repro.models.transformer import (
    param_template, param_specs, abstract_params, init_params,
    forward_train, forward_prefill, decode_step, decode_step_encdec,
    cache_template,
)
