"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from results/dryrun.

`python -m repro.roofline.report [--dir results/dryrun]` prints markdown.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

CELL_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirpath: str):
    rows = []
    for fn in sorted(Path(dirpath).glob("*.json")):
        parts = fn.stem.split("__")
        # baseline files only: arch__cell__mesh.json (no experiment tags)
        if len(parts) != 3 or parts[2] not in ("16x16", "2x16x16"):
            continue
        rows.append(json.loads(fn.read_text()))
    rows.sort(key=lambda r: (r["arch"], CELL_ORDER.index(r["cell"]),
                             r["mesh"]))
    return rows


def dryrun_table(rows) -> str:
    out = ["| arch | cell | mesh | compile s | args GB/dev | temp GB/dev | "
           "HLO flops/dev | HBM bytes/dev | ICI bytes/dev | collective mix |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        m = r["memory"]
        rf = recompute(r)
        out.append(
            f"| {r['arch']} | {r['cell']} | {r['mesh']} "
            f"| {r['t_compile_s']} | {m['argument_bytes']/1e9:.2f} "
            f"| {m['temp_bytes']/1e9:.2f} | {r['cost']['flops']:.2e} "
            f"| {r['cost']['bytes_accessed']:.2e} "
            f"| {rf.coll_bytes:.2e} "
            f"| {rf.coll_detail[:90]} |")
    return "\n".join(out)


def recompute(r, ici_links: int = 1):
    """Rebuild roofline terms from the raw stored fields (robust to JSON
    vintage; single source of truth = roofline.model).

    parser_version < 2 JSONs counted bf16 reductions at the XLA:CPU-promoted
    f32 width; the TPU target reduces in bf16, so AR/RS bytes are halved
    here (uniform — the non-bf16 reductions are scalar-sized)."""
    from repro.roofline.hlo import CollectiveStats
    from repro.roofline.model import build
    kinds = {k: [v["bytes"], v["ops"]] for k, v in r["collectives"].items()}
    if r.get("parser_version", 1) < 2:
        for k in ("all-reduce", "reduce-scatter"):
            if k in kinds:
                kinds[k][0] *= 0.5
    coll = CollectiveStats(
        by_kind={k: tuple(v) for k, v in kinds.items()},
        total_bytes=sum(v[0] for v in kinds.values()),
        op_count=sum(v[1] for v in kinds.values()))
    n_chips = 512 if r["mesh"] == "2x16x16" else 256
    mf = r["roofline"]["model_flops_per_device"] * n_chips
    return build(r["arch"], r["cell"], r["mesh"], flops=r["cost"]["flops"],
                 hbm_bytes=r["cost"]["bytes_accessed"], coll=coll,
                 model_flops_total=mf, n_chips=n_chips, ici_links=ici_links,
                 args_bytes=r["memory"]["argument_bytes"])


def roofline_table(rows) -> str:
    out = ["| arch | cell | mesh | T_comp ms | T_mem ms | T_coll ms | "
           "bottleneck | 6ND/HLO | roofline frac | what would move the "
           "dominant term |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        rf = recompute(r)
        out.append(
            f"| {r['arch']} | {r['cell']} | {r['mesh']} "
            f"| {rf.t_compute*1e3:.2f} | {rf.t_memory*1e3:.2f} "
            f"| {rf.t_collective*1e3:.2f} | {rf.bottleneck} "
            f"| {rf.useful_ratio:.2f} | {rf.roofline_fraction:.3f} "
            f"| {suggestion(r)} |")
    return "\n".join(out)


def suggestion(r) -> str:
    b = r["roofline"]["bottleneck"]
    kind = max(r["collectives"].items(),
               key=lambda kv: kv[1]["bytes"])[0] if r["collectives"] else "-"
    if b == "collective":
        return (f"dominant {kind}: overlap deeper / reduce payload "
                f"(bf16 reduce, chunked ring)")
    if b == "memory":
        return "fuse producers into consumers; fewer f32 intermediates; remat policy"
    return "larger per-step tiles; reduce remat recompute"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--section", default="both",
                    choices=["dryrun", "roofline", "both"])
    args = ap.parse_args()
    rows = load(args.dir)
    if args.section in ("dryrun", "both"):
        print("### Dry-run table\n")
        print(dryrun_table(rows))
        print()
    if args.section in ("roofline", "both"):
        print("### Roofline table\n")
        print(roofline_table(rows))


if __name__ == "__main__":
    main()
