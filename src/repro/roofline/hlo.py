"""Collective-byte extraction from compiled (post-SPMD) HLO text.

cost_analysis() has FLOPs and HBM bytes but NOT collective traffic, so we
parse `compiled.as_text()`: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op line carries its per-device output shape
and replica groups; ICI bytes-per-device follow from the collective's ring
cost (costmodel.ring_collective_bytes).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

# e.g.  %all-gather.12 = bf16[16,1024,512]{2,1,0} all-gather(...), replica_groups=...
_OP_RE = re.compile(
    r"=\s+(?:\(?)((?:\w+\[[\d,]*\][^ ]*\s*,?\s*)+)\)?\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(([^)]*)\)")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUP_RE2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUP_RE2.search(line)          # iota form [n_groups,group_size]
    if m:
        return int(m.group(2))
    m = _GROUP_RE.search(line)           # explicit first group {0,1,...}
    if m:
        return len([t for t in m.group(1).split(",") if t.strip() != ""])
    return default


@dataclasses.dataclass
class CollectiveStats:
    """Per-device ICI traffic, bytes."""
    by_kind: dict
    total_bytes: float
    op_count: int

    def summary(self) -> str:
        parts = [f"{k}: {v/1e6:.1f} MB ({c} ops)"
                 for k, (v, c) in sorted(self.by_kind.items())]
        return "; ".join(parts) or "none"


def collective_bytes(hlo_text: str, *, default_group: int = 2) -> CollectiveStats:
    """Sum per-device ICI bytes over all collective ops in the module.

    Ring-model per-device traffic for a payload of per-device size S over a
    group of size N: AG: S*(N-1) [S = per-device input shard = out/N];
    RS: S_in*(N-1)/N; AR: 2*S_in*(N-1)/N; A2A: S*(N-1)/N; permute: S.
    """
    by_kind = defaultdict(lambda: [0.0, 0])
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shapes, kind, operands = m.group(1), m.group(2), m.group(3)
        kind = kind.replace("-start", "")
        out_bytes = _shape_bytes(shapes)
        # XLA:CPU promotes bf16 reductions to f32 (convert -> all-reduce f32
        # -> convert). The TPU target reduces in bf16, so count the true
        # payload when the reduction is convert-fed.
        if (kind in ("all-reduce", "reduce-scatter")
                and "convert" in operands and "f32[" in shapes):
            out_bytes *= 0.5
        n = max(_group_size(line, default_group), 1)
        if n == 1:
            continue
        if kind == "all-gather":
            moved = out_bytes * (n - 1) / n          # out = gathered
        elif kind == "all-reduce":
            moved = 2.0 * out_bytes * (n - 1) / n
        elif kind == "reduce-scatter":
            moved = out_bytes * (n - 1)              # out = scattered shard
        elif kind == "all-to-all":
            moved = out_bytes * (n - 1) / n
        else:                                        # collective-permute
            moved = out_bytes
        by_kind[kind][0] += moved
        by_kind[kind][1] += 1
    total = sum(v for v, _ in by_kind.values())
    count = sum(c for _, c in by_kind.values())
    return CollectiveStats(by_kind=dict(by_kind), total_bytes=total,
                           op_count=count)
