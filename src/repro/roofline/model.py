"""Three-term roofline from the compiled dry-run artifact (assignment
ROOFLINE ANALYSIS):

    compute    = HLO_FLOPs / (chips × peak)         [chips divide: HLO is the
    memory     = HLO_bytes / HBM_bw                  per-device module already,
    collective = coll_bytes / (links × link_bw)      so no chip division]

Note cost_analysis() of an SPMD-partitioned module reports the PER-DEVICE
program, so its flops/bytes are already per-chip; the assignment's
"/(chips × ...)" is satisfied by construction. MODEL_FLOPS = 6·N·D
(6·N_active·D for MoE) measures how much of the compiled compute is useful.
"""

from __future__ import annotations

import dataclasses

from repro.core.costmodel import TPU_V5E, HardwareSpec
from repro.roofline.hlo import CollectiveStats


@dataclasses.dataclass
class Roofline:
    arch: str
    cell: str
    mesh: str
    flops: float               # per-device HLO flops
    hbm_bytes: float           # per-device HLO bytes accessed
    coll_bytes: float          # per-device ICI bytes
    model_flops_per_device: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    useful_ratio: float        # MODEL_FLOPS / HLO_FLOPS (per device)
    roofline_fraction: float   # t_bottleneck_ideal / t_total_lower_bound
    coll_detail: str = ""

    def row(self) -> str:
        return (f"| {self.arch} | {self.cell} | {self.mesh} "
                f"| {self.t_compute*1e3:.2f} | {self.t_memory*1e3:.2f} "
                f"| {self.t_collective*1e3:.2f} | {self.bottleneck} "
                f"| {self.useful_ratio:.2f} | {self.roofline_fraction:.2f} |")


def build(arch: str, cell: str, mesh_name: str, *, flops: float,
          hbm_bytes: float, coll: CollectiveStats,
          model_flops_total: float, n_chips: int,
          hw: HardwareSpec = TPU_V5E, ici_links: int = 1,
          args_bytes: float = 0.0) -> Roofline:
    t_comp = flops / hw.peak_flops_bf16
    t_mem = hbm_bytes / hw.hbm_bandwidth
    t_coll = coll.total_bytes / (hw.ici_bandwidth * ici_links)
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf_dev = model_flops_total / n_chips
    useful = mf_dev / flops if flops > 0 else 0.0
    # roofline fraction: ideal step time (useful compute, or — for
    # memory-bound inference — touching every persistent byte once) over the
    # dominant compiled term.
    ideal = max(mf_dev / hw.peak_flops_bf16,
                args_bytes / hw.hbm_bandwidth)
    lower = max(terms.values())
    frac = ideal / lower if lower > 0 else 0.0
    return Roofline(arch=arch, cell=cell, mesh=mesh_name, flops=flops,
                    hbm_bytes=hbm_bytes, coll_bytes=coll.total_bytes,
                    model_flops_per_device=mf_dev, t_compute=t_comp,
                    t_memory=t_mem, t_collective=t_coll,
                    bottleneck=bottleneck, useful_ratio=useful,
                    roofline_fraction=min(frac, 1.0),
                    coll_detail=coll.summary())


def model_flops(cfg, cell) -> float:
    """6·N·D training flops (fwd+bwd); decode/prefill: 2·N·D forward-only.
    N = active params, D = tokens processed by the step."""
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * cell.global_batch
