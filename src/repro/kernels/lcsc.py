"""The LCSC program template (paper §3.2.3 / Appendix D), TPU form.

The paper structures every multi-GPU kernel as four specialized workers —
loader / consumer / storer / communicator — wired through semaphores. On TPU
the warpgroup specialization maps to issue streams of one core (DESIGN §2):

  loader        -> async local copies HBM->VMEM (pltpu.make_async_copy)
  consumer      -> MXU/VPU compute on VMEM refs
  storer        -> async copies VMEM->HBM (local or the output PGL slot)
  communicator  -> one-way ICI RDMA + semaphore signaling (pk_comm primitives)

`lcsc_kernel(...)` assembles the steady-state ring schedule the paper's
template automates: per step, the communicator *starts* the next transfer
first, the consumer computes on the current buffer while it flies, the storer
drains results, and the step closes on the per-hop DMA semaphores — the
intra-kernel overlap pattern of kernels/collective_matmul.py, factored out.

Each worker is a callback taking an `LCSCCtx`; users write only per-tile
logic, mirroring the paper's "<50 LOC of device code" claim.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.core.comms import collective_id

from repro.kernels.pk_comm import pk_neighbor_barrier, pk_store_async


@dataclasses.dataclass
class LCSCCtx:
    """Everything a worker callback may touch at step i."""
    step: Any                 # traced loop index
    n_dev: int
    my_id: Any
    left: Any
    right: Any
    in_refs: tuple            # kernel operand refs (ANY/HBM)
    out_ref: Any              # output ref (ANY/HBM)
    bufs: tuple               # VMEM scratch refs
    send_sem: Any             # per-hop DMA semaphore array
    recv_sem: Any
    copy_sem: Any

    def local_copy(self, src, dst):
        cp = pltpu.make_async_copy(src, dst, self.copy_sem)
        cp.start()
        cp.wait()

    def remote_store(self, src, dst):
        """communicator: one-way RDMA to the right neighbor, hop `step`.
        Returns the descriptor — the template waits it at step close."""
        return pk_store_async(src, dst, self.send_sem.at[self.step],
                              self.recv_sem.at[self.step], self.right)


def lcsc_kernel(*, n_steps_from_ndev: Callable[[int], int],
                communicator: Callable[[LCSCCtx], Any] | None,
                loader: Callable[[LCSCCtx], None] | None,
                consumer: Callable[[LCSCCtx], None] | None,
                storer: Callable[[LCSCCtx], None] | None,
                prologue: Callable[[LCSCCtx], None] | None = None):
    """Build a Pallas kernel body from LCSC worker callbacks."""

    def body(axis_name, n_dev, in_refs, out_ref, bufs, send_sem, recv_sem,
             copy_sem):
        my = lax.axis_index(axis_name)
        ctx = LCSCCtx(step=jnp.int32(0), n_dev=n_dev, my_id=my,
                      left=lax.rem(my + n_dev - 1, jnp.int32(n_dev)),
                      right=lax.rem(my + 1, jnp.int32(n_dev)),
                      in_refs=in_refs, out_ref=out_ref, bufs=bufs,
                      send_sem=send_sem, recv_sem=recv_sem,
                      copy_sem=copy_sem)
        pk_neighbor_barrier(axis_name)
        if prologue is not None:
            prologue(ctx)

        def step_fn(i, _):
            c = dataclasses.replace(ctx, step=i)
            rdma = communicator(c) if communicator is not None else None
            if loader is not None:
                loader(c)
            if consumer is not None:
                consumer(c)
            if storer is not None:
                storer(c)
            if rdma is not None:
                rdma.wait()          # close the hop on its own semaphores
            return 0

        lax.fori_loop(0, n_steps_from_ndev(n_dev), step_fn, 0)

    return body


# ---------------------------------------------------------------------------
# Demo: ring all-gather expressed on the template (8 lines of worker logic) —
# equivalent to kernels/pk_comm.ring_all_gather.
# ---------------------------------------------------------------------------

def lcsc_ring_all_gather(x, axis_name: str, *, interpret=True):
    n_dev = compat.axis_size(axis_name)

    def prologue(c):             # stage the local shard into my PGL slot
        c.local_copy(c.in_refs[0], c.out_ref.at[c.my_id])

    def communicator(c):         # forward the shard received `step` hops ago
        slot = lax.rem(c.my_id - c.step + n_dev, jnp.int32(n_dev))
        return c.remote_store(c.out_ref.at[slot], c.out_ref.at[slot])

    body = lcsc_kernel(n_steps_from_ndev=lambda n: n - 1,
                       communicator=communicator, loader=None, consumer=None,
                       storer=None, prologue=prologue)

    def kernel(x_ref, out_ref, send_sem, recv_sem, copy_sem):
        body(axis_name, n_dev, (x_ref,), out_ref, (), send_sem, recv_sem,
             copy_sem)

    return pl.pallas_call(
        kernel,
        in_specs=[pl.BlockSpec(memory_space=compat.ANY)],
        out_specs=pl.BlockSpec(memory_space=compat.ANY),
        out_shape=jax.ShapeDtypeStruct((n_dev, *x.shape), x.dtype),
        scratch_shapes=[pltpu.SemaphoreType.DMA((n_dev - 1,)),
                        pltpu.SemaphoreType.DMA((n_dev - 1,)),
                        pltpu.SemaphoreType.DMA],
        compiler_params=compat.CompilerParams(collective_id=collective_id("lcsc_ring_all_gather")),
        interpret=compat.interpret_params() if interpret else False,
    )(x)
