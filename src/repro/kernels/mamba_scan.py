"""Chunked selective-scan kernel (Mamba-1). Grid = (B, n_chunks) with the
chunk dim sequential; the (D, N) SSM state carries across chunks in VMEM
scratch, so nothing of size (B, S, D, N) ever exists — HBM traffic is the
inputs/outputs only, matching the memory-term analysis in DESIGN §5.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _scan_kernel(dt_ref, b_ref, c_ref, x_ref, a_ref, h0_ref, y_ref,
                 hlast_ref, h_ref, *, cs: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = h0_ref[0]

    a = a_ref[...]                                   # (D, N) f32 (-exp(A_log))
    dt = dt_ref[0].astype(jnp.float32)               # (cs, D)
    b = b_ref[0].astype(jnp.float32)                 # (cs, N)
    c = c_ref[0].astype(jnp.float32)                 # (cs, N)
    x = x_ref[0].astype(jnp.float32)                 # (cs, D)

    def step(t, h):
        dt_t = jax.lax.dynamic_slice_in_dim(dt, t, 1, 0)    # (1, D)
        b_t = jax.lax.dynamic_slice_in_dim(b, t, 1, 0)      # (1, N)
        c_t = jax.lax.dynamic_slice_in_dim(c, t, 1, 0)
        x_t = jax.lax.dynamic_slice_in_dim(x, t, 1, 0)
        a_bar = jnp.exp(dt_t.T * a)                         # (D, N)
        bx = (dt_t * x_t).T * b_t                           # (D, N)
        h = a_bar * h + bx
        y_t = jnp.sum(h * c_t, axis=1, keepdims=True).T     # (1, D)
        y_ref[0, pl.dslice(t, 1), :] = y_t.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, cs, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ci == n_chunks - 1)
    def _final():
        hlast_ref[0] = h


def mamba_scan(dt, b_ssm, c_ssm, x, a, h0, *, chunk: int = 128,
               interpret: bool = False):
    """dt, x: (B, S, D); b_ssm, c_ssm: (B, S, N); a: (D, N) f32;
    h0: (B, D, N) f32. Returns (y (B, S, D) f32, h_last (B, D, N))."""
    bsz, s, d = dt.shape
    n = b_ssm.shape[-1]
    if s % chunk != 0:
        chunk = s
    grid = (bsz, s // chunk)
    y, h_last = pl.pallas_call(
        functools.partial(_scan_kernel, cs=chunk, n_chunks=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, d), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((d, n), lambda bi, ci: (0, 0)),
            pl.BlockSpec((1, d, n), lambda bi, ci: (bi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, d, n), lambda bi, ci: (bi, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((bsz, s, d), jnp.float32),
                   jax.ShapeDtypeStruct((bsz, d, n), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((d, n), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(dt, b_ssm, c_ssm, x, a, h0)
    return y, h_last
