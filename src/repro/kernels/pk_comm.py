"""ParallelKittens primitives on TPU (paper §3.2.2) + pure-communication ring
kernels built from them (paper Fig. 6/15/16 workloads).

The eight primitives, mapped per DESIGN.md §2:

  GPU (paper)            TPU (here)
  store_async        ->  pk_store_async      (make_async_remote_copy)
  store_add_async    ->  pk_store_async + accumulate-on-arrival (no remote
                         atomics over ICI; the receiver adds — see
                         ring_reduce_scatter)
  reduce             ->  accumulate-on-arrival ring step (no in-network
                         reduction on ICI; DESIGN §2.1)
  all_reduce         ->  composed reduce_scatter + all_gather (ops.py)
  signal             ->  pk_signal           (semaphore_signal w/ device_id)
  signal_all         ->  loop of pk_signal (no multicast fabric)
  wait               ->  pk_wait             (semaphore_wait)
  barrier            ->  pk_neighbor_barrier / pk_global_barrier

Design-overhead principles carried over (paper §3.1.4): destination buffers
are pre-allocated kernel outputs/scratch (PGL slots) — transfers are one-way,
there is no staging copy and no sender/receiver rendezvous beyond the initial
barrier; completion is a DMA-semaphore count, not a two-way handshake.

All kernels run under shard_map and are validated cross-device in TPU
interpret mode (pltpu.InterpretParams), which emulates per-device semaphores
and remote DMAs faithfully on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.core.comms import collective_id
from repro.core.schedule import fit_chunks


# ---------------------------------------------------------------------------
# Primitives (used inside Pallas kernels)
# ---------------------------------------------------------------------------

def pk_store_async(src_ref, dst_ref, send_sem, recv_sem, dst_dev):
    """store_async(dst, src, coord): one-way async tile store into a peer's
    pre-allocated PGL slot. Returns the descriptor (call .wait_send()/.wait()).
    Single-issue (one scalar-core instruction), so compute overlaps freely —
    the TMA property the paper builds intra-SM overlap on."""
    rdma = pltpu.make_async_remote_copy(
        src_ref=src_ref, dst_ref=dst_ref, send_sem=send_sem,
        recv_sem=recv_sem, device_id=(dst_dev,),
        device_id_type=pltpu.DeviceIdType.MESH)
    rdma.start()
    return rdma


def pk_store_chunked(src_ref, dst_ref, send_sems, recv_sems, dst_dev, *,
                     n_chunks: int, chunk_rows: int):
    """``store_async`` at sub-chunk granularity: one one-way RDMA per row
    chunk of the payload, each ordered by its own (send, recv) pair from the
    supplied per-chunk semaphore rows (shape ``(n_chunks,)``). The chunk loop
    is static, so the scalar core issues every descriptor back-to-back and
    chunk c is on the wire before the consumer's chunk-c compute runs — the
    seam the fused kernels build their sub-shard overlap on. Still one-way:
    per-chunk semaphores extend the hop discipline, they do not add a
    rendezvous. Returns the descriptors (static list; ``.wait()`` each to
    block on send+recv completion)."""
    if n_chunks <= 1:
        return [pk_store_async(src_ref, dst_ref, send_sems.at[0],
                               recv_sems.at[0], dst_dev)]
    out = []
    for c in range(n_chunks):
        rows = pl.dslice(c * chunk_rows, chunk_rows)
        out.append(pk_store_async(src_ref.at[rows], dst_ref.at[rows],
                                  send_sems.at[c], recv_sems.at[c], dst_dev))
    return out


def pk_signal(sem, dst_dev, inc: int = 1):
    """signal(bar, coord, dev_idx, val)."""
    pltpu.semaphore_signal(sem, inc, device_id=(dst_dev,),
                           device_id_type=pltpu.DeviceIdType.MESH)


def pk_signal_all(sem, n_dev: int, inc: int = 1):
    """signal_all — no NVSwitch multicast on ICI: loop of unicasts."""
    for d in range(n_dev):
        pltpu.semaphore_signal(sem, inc, device_id=(jnp.int32(d),),
                               device_id_type=pltpu.DeviceIdType.MESH)


def pk_wait(sem, expected: int = 1):
    """wait(bar, coord, dev_idx, expected)."""
    pltpu.semaphore_wait(sem, expected)


def pk_neighbor_barrier(axis_name: str, sem=None):
    """barrier with both ring neighbors — required before the first RDMA of a
    ring schedule so landing buffers are live (paper's barrier primitive)."""
    n = compat.axis_size(axis_name)
    my = lax.axis_index(axis_name)
    left = lax.rem(my + n - 1, jnp.int32(n))
    right = lax.rem(my + 1, jnp.int32(n))
    sem = pltpu.get_barrier_semaphore() if sem is None else sem
    pltpu.semaphore_signal(sem, 1, device_id=(left,),
                           device_id_type=pltpu.DeviceIdType.MESH)
    pltpu.semaphore_signal(sem, 1, device_id=(right,),
                           device_id_type=pltpu.DeviceIdType.MESH)
    pltpu.semaphore_wait(sem, 2)


# ---------------------------------------------------------------------------
# Ring all-gather kernel (paper Fig. 15 workload)
# ---------------------------------------------------------------------------

def _ag_kernel(x_ref, out_ref, send_sem, recv_sem, copy_sem, *,
               axis_name: str, n_dev: int, n_chunks: int, chunk_rows: int):
    """Per-hop semaphores: a bare DMA-semaphore *count* only proves that SOME
    transfer landed, not the one this hop forwards — under out-of-order
    delivery that is a real data race (caught by InterpretParams
    detect_races). recv_sem[i, c] is signaled exclusively by the hop-i
    chunk-c transfer, so waiting on it orders the ring correctly with zero
    extra messages — the PK one-way-sync principle (paper §3.1.4) preserved
    at sub-chunk granularity."""
    my = lax.axis_index(axis_name)
    right = lax.rem(my + 1, jnp.int32(n_dev))
    pk_neighbor_barrier(axis_name)

    # local shard -> my PGL slot (pre-allocated destination, no staging)
    local = pltpu.make_async_copy(x_ref, out_ref.at[my], copy_sem)
    local.start()
    local.wait()

    def hop(i, _):
        # forward the shard received i hops ago (origin my - i)
        slot = lax.rem(my - i + n_dev, jnp.int32(n_dev))
        rdmas = pk_store_chunked(out_ref.at[slot], out_ref.at[slot],
                                 send_sem.at[i], recv_sem.at[i], right,
                                 n_chunks=n_chunks, chunk_rows=chunk_rows)
        for r in rdmas:
            r.wait()
        return 0

    lax.fori_loop(0, n_dev - 1, hop, 0)


def ring_all_gather(x, axis_name: str, *, mesh=None, n_chunks: int = 1,
                    interpret=True):
    """x: (blk, ...) local shard -> (n_dev, blk, ...) full array, via one-way
    RDMA hops into pre-allocated slots. Call inside shard_map. ``n_chunks``
    splits each hop's payload into row sub-chunks (largest-divisor fallback
    via ``fit_chunks`` — never a shape constraint); results are bit-identical
    to the 1-chunk schedule."""
    n_dev = compat.axis_size(axis_name)
    n_chunks = fit_chunks(x.shape[0], n_chunks) if x.ndim else 1
    chunk_rows = (x.shape[0] // n_chunks) if x.ndim else 0
    out_shape = jax.ShapeDtypeStruct((n_dev, *x.shape), x.dtype)
    return pl.pallas_call(
        functools.partial(_ag_kernel, axis_name=axis_name, n_dev=n_dev,
                          n_chunks=n_chunks, chunk_rows=chunk_rows),
        in_specs=[pl.BlockSpec(memory_space=compat.ANY)],
        out_specs=pl.BlockSpec(memory_space=compat.ANY),
        out_shape=out_shape,
        scratch_shapes=[pltpu.SemaphoreType.DMA((n_dev - 1, n_chunks)),
                        pltpu.SemaphoreType.DMA((n_dev - 1, n_chunks)),
                        pltpu.SemaphoreType.DMA],
        compiler_params=compat.CompilerParams(collective_id=collective_id("ring_all_gather")),
        interpret=compat.interpret_params() if interpret else False,
    )(x)


# ---------------------------------------------------------------------------
# Ring reduce-scatter kernel — accumulate-on-arrival (the TPU re-derivation
# of in-network reduction; paper Fig. 16 workload + §3.1.3 GEMM+AR analysis)
# ---------------------------------------------------------------------------

def _rs_kernel(x_ref, out_ref, landing, acc_v, tmp_v, send_sem, recv_sem,
               cap_sem, copy_sem, *, axis_name: str, n_dev: int,
               n_chunks: int, chunk_rows: int):
    """Accumulate-and-forward ring. Two sync obligations, both one-way
    (paper §3.1.4 — no rendezvous):
      * per-hop (and per-chunk) recv semaphores order data arrival;
      * cap_sem[slot] is the consumer's ack that a landing slot was read —
        a fast sender may otherwise lap a slow receiver by two hops and
        overwrite an unconsumed slot (WAR hazard). The ack stays per-slot:
        the consumer reads the whole slot at once, so chunking the data
        path does not chunk the capacity ack."""
    my = lax.axis_index(axis_name)
    left = lax.rem(my + n_dev - 1, jnp.int32(n_dev))
    right = lax.rem(my + 1, jnp.int32(n_dev))
    pk_neighbor_barrier(axis_name)

    # acc = my partial for block (my+1)
    first = pltpu.make_async_copy(x_ref.at[lax.rem(my + 1, jnp.int32(n_dev))],
                                  acc_v, copy_sem)
    first.start()
    first.wait()

    def hop(i, _):
        slot = lax.rem(i, 2)
        # Reusing a slot (hop i shares it with hop i-2): wait for the
        # consumer's ack before overwriting.
        @pl.when(i >= 3)
        def _ack():
            pk_wait(cap_sem.at[slot], 1)
        # one-way send of the running accumulator to the left neighbor's
        # pre-allocated landing slot; per-hop/per-chunk semaphores order
        # the ring
        rdmas = pk_store_chunked(acc_v, landing.at[slot], send_sem.at[i - 1],
                                 recv_sem.at[i - 1], left,
                                 n_chunks=n_chunks, chunk_rows=chunk_rows)
        for r in rdmas:
            r.wait()
        # accumulate on arrival: landing + my partial for block (my+1+i)
        blk = lax.rem(my + 1 + i, jnp.int32(n_dev))
        cp_in = pltpu.make_async_copy(landing.at[slot], acc_v, copy_sem)
        cp_in.start()
        cp_l = pltpu.make_async_copy(x_ref.at[blk], tmp_v, copy_sem)
        cp_l.start()
        cp_in.wait()
        cp_l.wait()
        acc_v[...] = acc_v[...] + tmp_v[...]

        # landing[slot] consumed -> ack the producer (my right neighbor);
        # only when some future hop will actually reuse the slot, so all
        # semaphores drain to zero by kernel exit.
        @pl.when(i <= n_dev - 3)
        def _consumed():
            pk_signal(cap_sem.at[slot], right)
        return 0

    lax.fori_loop(1, n_dev, hop, 0, unroll=False)
    done = pltpu.make_async_copy(acc_v, out_ref, copy_sem)
    done.start()
    done.wait()


def ring_reduce_scatter(x, axis_name: str, *, n_chunks: int = 1,
                        interpret=True):
    """x: (n_dev, blk, ...) per-destination partials -> (blk, ...) reduced
    shard for this device. Accumulate-and-forward ring; landing buffers are
    double-buffered PGL scratch slots (no staging copies). ``n_chunks``
    splits each hop's payload into row sub-chunks (``fit_chunks`` fallback);
    the accumulation order is untouched, so results stay bit-identical to
    the 1-chunk schedule."""
    n_dev = compat.axis_size(axis_name)
    blk_shape = x.shape[1:]
    n_chunks = fit_chunks(blk_shape[0], n_chunks) if blk_shape else 1
    chunk_rows = (blk_shape[0] // n_chunks) if blk_shape else 0
    return pl.pallas_call(
        functools.partial(_rs_kernel, axis_name=axis_name, n_dev=n_dev,
                          n_chunks=n_chunks, chunk_rows=chunk_rows),
        in_specs=[pl.BlockSpec(memory_space=compat.ANY)],
        out_specs=pl.BlockSpec(memory_space=compat.ANY),
        out_shape=jax.ShapeDtypeStruct(blk_shape, x.dtype),
        scratch_shapes=[compat.hbm_scratch((2, *blk_shape), x.dtype),
                        pltpu.VMEM(blk_shape, x.dtype),
                        pltpu.VMEM(blk_shape, x.dtype),
                        pltpu.SemaphoreType.DMA((n_dev - 1, n_chunks)),
                        pltpu.SemaphoreType.DMA((n_dev - 1, n_chunks)),
                        pltpu.SemaphoreType.REGULAR((2,)),
                        pltpu.SemaphoreType.DMA],
        compiler_params=compat.CompilerParams(collective_id=collective_id("ring_reduce_scatter")),
        interpret=compat.interpret_params() if interpret else False,
    )(x)


# ---------------------------------------------------------------------------
# One-shot P2P (paper Fig. 2 microbenchmark granularity study)
# ---------------------------------------------------------------------------

def _p2p_kernel(x_ref, out_ref, send_sem, recv_sem, *, axis_name, n_dev):
    my = lax.axis_index(axis_name)
    right = lax.rem(my + 1, jnp.int32(n_dev))
    pk_neighbor_barrier(axis_name)
    rdma = pk_store_async(x_ref, out_ref, send_sem, recv_sem, right)
    rdma.wait()


def p2p_ring_shift(x, axis_name: str, *, interpret=True):
    """Single-hop one-way RDMA (store_async) to the right neighbor."""
    n_dev = compat.axis_size(axis_name)
    return pl.pallas_call(
        functools.partial(_p2p_kernel, axis_name=axis_name, n_dev=n_dev),
        in_specs=[pl.BlockSpec(memory_space=compat.ANY)],
        out_specs=pl.BlockSpec(memory_space=compat.ANY),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.SemaphoreType.DMA, pltpu.SemaphoreType.DMA],
        compiler_params=compat.CompilerParams(collective_id=collective_id("p2p_ring_shift")),
        interpret=compat.interpret_params() if interpret else False,
    )(x)
