"""Fused collective×GEMM kernels — the paper's flagship workloads (Fig. 7/8,
Table 3) as single Pallas kernels with **intra-kernel overlap**: the scalar
core issues the next ring RDMA, then the MXU computes the current shard while
the transfer flies. This is the TPU realization of the paper's intra-SM
overlapping (§3.1.3): the "communication warp" is the scalar core + ICI DMA
engine, and it costs zero MXU occupancy.

Chunk pipeline (``core/schedule.ChunkSchedule``, Syncopate's chunk-centric
thesis): every ring hop is additionally split into ``n_chunks`` row
sub-chunks. The scalar core issues chunk c's one-way RDMA *ahead of* the
chunk GEMM it overlaps, so the first output rows are computed (AG×GEMM) or
on the wire (GEMM×RS/AR) while the rest of the hop's payload is still
flying — the pipeline fill shrinks from one shard transfer to one chunk
transfer. Chunks slice the payload's row dim only, so the per-row K
reduction order is untouched and every chunk count is **bit-identical** to
the 1-chunk schedule (enforced by tests/test_fused_chunks.py). Requested
counts that do not divide the payload rows degrade via ``fit_chunks`` —
chunking is never a shape constraint.

Communication code in each kernel is ~12 lines (start / wait / signal),
mirroring the paper's <50-LOC claim; everything else is the same GEMM a
single-device kernel would have.

Synchronization discipline (see kernels/pk_comm.py for the derivation):
per-hop × per-chunk send/recv DMA semaphores order arrivals; cap_sem acks
guard double-buffer reuse and stay per-slot (the consumer frees a whole
slot, so the capacity ack does not chunk). All one-way — no rendezvous
(paper §3.1.4).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.core.comms import collective_id
from repro.core.schedule import fit_chunks

from repro.kernels.pk_comm import (pk_neighbor_barrier, pk_signal,
                                   pk_store_async, pk_store_chunked, pk_wait)


# ---------------------------------------------------------------------------
# Fused all-gather × GEMM (paper Fig. 7)
# ---------------------------------------------------------------------------

def _ag_mm_kernel(x_ref, w_ref, out_ref, buf, w_v, y_v, send_sem, recv_sem,
                  cap_sem, copy_sem, *, axis_name: str, n_dev: int,
                  n_chunks: int, m_chunk: int):
    my = lax.axis_index(axis_name)
    right = lax.rem(my + 1, jnp.int32(n_dev))
    left = lax.rem(my + n_dev - 1, jnp.int32(n_dev))
    pk_neighbor_barrier(axis_name)

    cp_x = pltpu.make_async_copy(x_ref, buf.at[0], copy_sem)
    cp_x.start()
    cp_w = pltpu.make_async_copy(w_ref, w_v, copy_sem)
    cp_w.start()
    cp_x.wait()
    cp_w.wait()

    def step(i, _):
        cur = lax.rem(i, 2)
        nxt = lax.rem(i + 1, 2)

        @pl.when(jnp.logical_and(i < n_dev - 1, i >= 2))
        def _reuse_ack():           # right must have consumed slot `nxt`
            pk_wait(cap_sem.at[nxt], 1)

        # Chunk pipeline: chunk c of the next shard goes on the wire, THEN
        # the MXU computes chunk c of the current shard — each chunk's DMA
        # is issued ahead of the chunk GEMM it overlaps.
        for c in range(n_chunks):
            rows = pl.dslice(c * m_chunk, m_chunk)

            @pl.when(i < n_dev - 1)
            def _send(rows=rows, c=c):
                pk_store_async(buf.at[cur].at[rows], buf.at[nxt].at[rows],
                               send_sem.at[i, c], recv_sem.at[i, c], right)

            sl = slice(c * m_chunk, (c + 1) * m_chunk)
            y_v[sl] = jax.lax.dot(buf.at[cur][sl], w_v[...],
                                  preferred_element_type=jnp.float32
                                  ).astype(y_v.dtype)

        src = lax.rem(my - i + n_dev, jnp.int32(n_dev))
        st = pltpu.make_async_copy(y_v, out_ref.at[src], copy_sem)
        st.start()
        st.wait()

        @pl.when(i < n_dev - 1)
        def _wait():
            # recreate the matching descriptors to wait send+recv of hop i
            for c in range(n_chunks):
                rows = pl.dslice(c * m_chunk, m_chunk)
                pltpu.make_async_remote_copy(
                    src_ref=buf.at[cur].at[rows], dst_ref=buf.at[nxt].at[rows],
                    send_sem=send_sem.at[i, c], recv_sem=recv_sem.at[i, c],
                    device_id=(right,),
                    device_id_type=pltpu.DeviceIdType.MESH).wait()

        @pl.when(jnp.logical_and(i >= 1, i <= n_dev - 3))
        def _consumed():            # buf[cur] free (dot done + send done)
            pk_signal(cap_sem.at[cur], left)
        return 0

    lax.fori_loop(0, n_dev, step, 0)


def ag_matmul_fused(x, w, axis_name: str, *, n_chunks: int = 1,
                    interpret=True):
    """x: (m_loc, k) row shard; w: (k, n) local weight. Returns
    (n_dev*m_loc, n) — all-gather fused into the GEMM. Call inside shard_map.
    ``n_chunks`` splits each hop into row sub-chunks (largest-divisor
    ``fit_chunks`` fallback); bit-identical to the 1-chunk schedule.
    Whole-operand VMEM residency: sized for benchmark/validation shapes; the
    production path tiles K via kernels/matmul.py blocking (DESIGN §5)."""
    n_dev = compat.axis_size(axis_name)
    m_loc, k = x.shape
    n = w.shape[1]
    n_chunks = fit_chunks(m_loc, n_chunks)
    m_chunk = m_loc // n_chunks
    return pl.pallas_call(
        functools.partial(_ag_mm_kernel, axis_name=axis_name, n_dev=n_dev,
                          n_chunks=n_chunks, m_chunk=m_chunk),
        in_specs=[pl.BlockSpec(memory_space=compat.ANY),
                  pl.BlockSpec(memory_space=compat.ANY)],
        out_specs=pl.BlockSpec(memory_space=compat.ANY),
        out_shape=jax.ShapeDtypeStruct((n_dev, m_loc, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((2, m_loc, k), x.dtype),
                        pltpu.VMEM((k, n), w.dtype),
                        pltpu.VMEM((m_loc, n), x.dtype),
                        pltpu.SemaphoreType.DMA((n_dev - 1, n_chunks)),
                        pltpu.SemaphoreType.DMA((n_dev - 1, n_chunks)),
                        pltpu.SemaphoreType.REGULAR((2,)),
                        pltpu.SemaphoreType.DMA],
        compiler_params=compat.CompilerParams(collective_id=collective_id("ag_matmul_fused")),
        interpret=compat.interpret_params() if interpret else False,
    )(x, w)


# ---------------------------------------------------------------------------
# Fused GEMM × reduce-scatter (paper Fig. 8 / Table 3)
# ---------------------------------------------------------------------------

def _rs_ring(x_ref, landing, acc_v, p_v, l_v, x_v, w_v, send_sem, recv_sem,
             cap_sem, copy_sem, *, axis_name: str, n_dev: int, m_blk: int,
             n_chunks: int, m_chunk: int):
    """The accumulate-and-forward GEMM×RS ring, shared by the RS and AR
    kernels. On return ``acc_v`` holds this device's fully reduced block."""
    my = lax.axis_index(axis_name)
    left = lax.rem(my + n_dev - 1, jnp.int32(n_dev))
    right = lax.rem(my + 1, jnp.int32(n_dev))

    def load_block(b):
        cp = pltpu.make_async_copy(x_ref.at[pl.dslice(b * m_blk, m_blk)],
                                   x_v, copy_sem)
        cp.start()
        cp.wait()

    # step 0: acc = my partial for block (my+1)
    load_block(lax.rem(my + 1, jnp.int32(n_dev)))
    acc_v[...] = jax.lax.dot(x_v[...], w_v[...],
                             preferred_element_type=jnp.float32)

    def step(i, _):
        slot = lax.rem(i, 2)

        @pl.when(i >= 3)
        def _reuse_ack():
            pk_wait(cap_sem.at[slot], 1)

        def send_chunk(c):
            # one-way, into the left neighbor's pre-allocated landing slot
            rows = pl.dslice(c * m_chunk, m_chunk)
            return pk_store_async(acc_v.at[rows], landing.at[slot].at[rows],
                                  send_sem.at[i - 1, c],
                                  recv_sem.at[i - 1, c], left)

        # Chunk 0 of the accumulator is on the wire before anything else;
        # the x-block HBM read and every chunk GEMM then overlap the
        # remaining chunk transfers — chunk c+1's DMA is issued ahead of
        # chunk c's dot. The paper's hiding condition K >= s*R/(2*B)
        # decides if the dots fully cover the transfers
        # (costmodel.hiding_threshold_k).
        rdmas = [send_chunk(0)]
        load_block(lax.rem(my + 1 + i, jnp.int32(n_dev)))
        for c in range(n_chunks):
            if c + 1 < n_chunks:
                rdmas.append(send_chunk(c + 1))
            sl = slice(c * m_chunk, (c + 1) * m_chunk)
            p_v[sl] = jax.lax.dot(x_v[sl], w_v[...],
                                  preferred_element_type=jnp.float32)
        for r in rdmas:
            r.wait()
        cp_l = pltpu.make_async_copy(landing.at[slot], l_v, copy_sem)
        cp_l.start()
        cp_l.wait()
        acc_v[...] = p_v[...] + l_v[...]

        @pl.when(i <= n_dev - 3)
        def _consumed():
            pk_signal(cap_sem.at[slot], right)
        return 0

    lax.fori_loop(1, n_dev, step, 0)


def _mm_rs_kernel(x_ref, w_ref, out_ref, landing, acc_v, p_v, l_v, x_v, w_v,
                  send_sem, recv_sem, cap_sem, copy_sem, *,
                  axis_name: str, n_dev: int, m_blk: int, n_chunks: int,
                  m_chunk: int):
    pk_neighbor_barrier(axis_name)
    cp_w = pltpu.make_async_copy(w_ref, w_v, copy_sem)
    cp_w.start()
    cp_w.wait()
    _rs_ring(x_ref, landing, acc_v, p_v, l_v, x_v, w_v, send_sem, recv_sem,
             cap_sem, copy_sem, axis_name=axis_name, n_dev=n_dev, m_blk=m_blk,
             n_chunks=n_chunks, m_chunk=m_chunk)
    st = pltpu.make_async_copy(acc_v, out_ref, copy_sem)
    st.start()
    st.wait()


def matmul_rs_fused(x, w, axis_name: str, *, n_chunks: int = 1,
                    interpret=True):
    """x: (m, k_loc); w: (k_loc, n) (K sharded over the axis). Returns the
    reduce-scattered (m/n_dev, n) fp32 shard. Call inside shard_map.
    ``n_chunks`` splits each hop's accumulator payload into row sub-chunks
    (``fit_chunks`` fallback); bit-identical to the 1-chunk schedule."""
    n_dev = compat.axis_size(axis_name)
    m, k_loc = x.shape
    n = w.shape[1]
    assert m % n_dev == 0
    m_blk = m // n_dev
    n_chunks = fit_chunks(m_blk, n_chunks)
    m_chunk = m_blk // n_chunks
    return pl.pallas_call(
        functools.partial(_mm_rs_kernel, axis_name=axis_name, n_dev=n_dev,
                          m_blk=m_blk, n_chunks=n_chunks, m_chunk=m_chunk),
        in_specs=[pl.BlockSpec(memory_space=compat.ANY),
                  pl.BlockSpec(memory_space=compat.ANY)],
        out_specs=pl.BlockSpec(memory_space=compat.ANY),
        out_shape=jax.ShapeDtypeStruct((m_blk, n), jnp.float32),
        scratch_shapes=[compat.hbm_scratch((2, m_blk, n), jnp.float32),
                        pltpu.VMEM((m_blk, n), jnp.float32),
                        pltpu.VMEM((m_blk, n), jnp.float32),
                        pltpu.VMEM((m_blk, n), jnp.float32),
                        pltpu.VMEM((m_blk, k_loc), x.dtype),
                        pltpu.VMEM((k_loc, n), w.dtype),
                        pltpu.SemaphoreType.DMA((n_dev - 1, n_chunks)),
                        pltpu.SemaphoreType.DMA((n_dev - 1, n_chunks)),
                        pltpu.SemaphoreType.REGULAR((2,)),
                        pltpu.SemaphoreType.DMA],
        compiler_params=compat.CompilerParams(collective_id=collective_id("matmul_rs_fused")),
        interpret=compat.interpret_params() if interpret else False,
    )(x, w)


# ---------------------------------------------------------------------------
# Fused GEMM × all-reduce — the §3.1.3 re-derivation (AR = RS ∘ AG on the
# same ring), still ONE kernel: the reduce-scatter ring above, then a
# chunked all-gather of the reduced blocks without leaving the kernel.
# ---------------------------------------------------------------------------

def _mm_ar_kernel(x_ref, w_ref, out_ref, landing, acc_v, p_v, l_v, x_v, w_v,
                  send_sem, recv_sem, ag_send, ag_recv, cap_sem, copy_sem, *,
                  axis_name: str, n_dev: int, m_blk: int, n_chunks: int,
                  m_chunk: int):
    my = lax.axis_index(axis_name)
    right = lax.rem(my + 1, jnp.int32(n_dev))
    pk_neighbor_barrier(axis_name)
    cp_w = pltpu.make_async_copy(w_ref, w_v, copy_sem)
    cp_w.start()
    cp_w.wait()
    _rs_ring(x_ref, landing, acc_v, p_v, l_v, x_v, w_v, send_sem, recv_sem,
             cap_sem, copy_sem, axis_name=axis_name, n_dev=n_dev, m_blk=m_blk,
             n_chunks=n_chunks, m_chunk=m_chunk)

    # publish my reduced block into my PGL slot, then ring-gather the rest —
    # same hop/chunk discipline as _ag_kernel, no rendezvous: out_ref slots
    # are pre-allocated kernel outputs, live since the opening barrier.
    st = pltpu.make_async_copy(acc_v, out_ref.at[my], copy_sem)
    st.start()
    st.wait()

    def ag_hop(j, _):
        # forward the reduced block received j hops ago (origin my - j)
        slot = lax.rem(my - j + n_dev, jnp.int32(n_dev))
        rdmas = pk_store_chunked(out_ref.at[slot], out_ref.at[slot],
                                 ag_send.at[j], ag_recv.at[j], right,
                                 n_chunks=n_chunks, chunk_rows=m_chunk)
        for r in rdmas:
            r.wait()
        return 0

    lax.fori_loop(0, n_dev - 1, ag_hop, 0)


def matmul_ar_fused(x, w, axis_name: str, *, n_chunks: int = 1,
                    interpret=True):
    """x: (m, k_loc); w: (k_loc, n) (K sharded over the axis). Returns the
    all-reduced (n_dev, m/n_dev, n) fp32 blocks (reshape to (m, n) outside).
    Call inside shard_map. One kernel end to end: the GEMM×RS ring followed
    by an in-kernel chunked all-gather of the reduced blocks — the trailing
    gather's hops reuse the chunk pipeline, so no second launch and no bulk
    re-entry into XLA. Bit-identical to the 1-chunk schedule."""
    n_dev = compat.axis_size(axis_name)
    m, k_loc = x.shape
    n = w.shape[1]
    assert m % n_dev == 0
    m_blk = m // n_dev
    n_chunks = fit_chunks(m_blk, n_chunks)
    m_chunk = m_blk // n_chunks
    return pl.pallas_call(
        functools.partial(_mm_ar_kernel, axis_name=axis_name, n_dev=n_dev,
                          m_blk=m_blk, n_chunks=n_chunks, m_chunk=m_chunk),
        in_specs=[pl.BlockSpec(memory_space=compat.ANY),
                  pl.BlockSpec(memory_space=compat.ANY)],
        out_specs=pl.BlockSpec(memory_space=compat.ANY),
        out_shape=jax.ShapeDtypeStruct((n_dev, m_blk, n), jnp.float32),
        scratch_shapes=[compat.hbm_scratch((2, m_blk, n), jnp.float32),
                        pltpu.VMEM((m_blk, n), jnp.float32),
                        pltpu.VMEM((m_blk, n), jnp.float32),
                        pltpu.VMEM((m_blk, n), jnp.float32),
                        pltpu.VMEM((m_blk, k_loc), x.dtype),
                        pltpu.VMEM((k_loc, n), w.dtype),
                        pltpu.SemaphoreType.DMA((n_dev - 1, n_chunks)),
                        pltpu.SemaphoreType.DMA((n_dev - 1, n_chunks)),
                        pltpu.SemaphoreType.DMA((n_dev - 1, n_chunks)),
                        pltpu.SemaphoreType.DMA((n_dev - 1, n_chunks)),
                        pltpu.SemaphoreType.REGULAR((2,)),
                        pltpu.SemaphoreType.DMA],
        compiler_params=compat.CompilerParams(collective_id=collective_id("matmul_ar_fused")),
        interpret=compat.interpret_params() if interpret else False,
    )(x, w)
