"""Fused collective×GEMM kernels — the paper's flagship workloads (Fig. 7/8,
Table 3) as single Pallas kernels with **intra-kernel overlap**: the scalar
core issues the next ring RDMA, then the MXU computes the current shard while
the transfer flies. This is the TPU realization of the paper's intra-SM
overlapping (§3.1.3): the "communication warp" is the scalar core + ICI DMA
engine, and it costs zero MXU occupancy.

Communication code in each kernel is ~12 lines (start / wait / signal),
mirroring the paper's <50-LOC claim; everything else is the same GEMM a
single-device kernel would have.

Synchronization discipline (see kernels/pk_comm.py for the derivation):
per-hop send/recv DMA semaphores order arrivals; cap_sem acks guard
double-buffer reuse. All one-way — no rendezvous (paper §3.1.4).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.core.comms import collective_id

from repro.kernels.pk_comm import (pk_neighbor_barrier, pk_signal,
                                   pk_store_async, pk_wait)


# ---------------------------------------------------------------------------
# Fused all-gather × GEMM (paper Fig. 7)
# ---------------------------------------------------------------------------

def _ag_mm_kernel(x_ref, w_ref, out_ref, buf, w_v, y_v, send_sem, recv_sem,
                  cap_sem, copy_sem, *, axis_name: str, n_dev: int):
    my = lax.axis_index(axis_name)
    right = lax.rem(my + 1, jnp.int32(n_dev))
    left = lax.rem(my + n_dev - 1, jnp.int32(n_dev))
    pk_neighbor_barrier(axis_name)

    cp_x = pltpu.make_async_copy(x_ref, buf.at[0], copy_sem)
    cp_x.start()
    cp_w = pltpu.make_async_copy(w_ref, w_v, copy_sem)
    cp_w.start()
    cp_x.wait()
    cp_w.wait()

    def step(i, _):
        cur = lax.rem(i, 2)
        nxt = lax.rem(i + 1, 2)

        @pl.when(jnp.logical_and(i < n_dev - 1, i >= 2))
        def _reuse_ack():           # right must have consumed slot `nxt`
            pk_wait(cap_sem.at[nxt], 1)

        @pl.when(i < n_dev - 1)
        def _send():                # next shard in flight...
            pk_store_async(buf.at[cur], buf.at[nxt], send_sem.at[i],
                           recv_sem.at[i], right)

        # ...while the MXU computes the current shard (intra-kernel overlap)
        y_v[...] = jax.lax.dot(buf[cur], w_v[...],
                               preferred_element_type=jnp.float32
                               ).astype(y_v.dtype)
        src = lax.rem(my - i + n_dev, jnp.int32(n_dev))
        st = pltpu.make_async_copy(y_v, out_ref.at[src], copy_sem)
        st.start()
        st.wait()

        @pl.when(i < n_dev - 1)
        def _wait():
            # recreate the matching descriptor to wait send+recv of hop i
            pltpu.make_async_remote_copy(
                src_ref=buf.at[cur], dst_ref=buf.at[nxt],
                send_sem=send_sem.at[i], recv_sem=recv_sem.at[i],
                device_id=(right,),
                device_id_type=pltpu.DeviceIdType.MESH).wait()

        @pl.when(jnp.logical_and(i >= 1, i <= n_dev - 3))
        def _consumed():            # buf[cur] free (dot done + send done)
            pk_signal(cap_sem.at[cur], left)
        return 0

    lax.fori_loop(0, n_dev, step, 0)


def ag_matmul_fused(x, w, axis_name: str, *, interpret=True):
    """x: (m_loc, k) row shard; w: (k, n) local weight. Returns
    (n_dev*m_loc, n) — all-gather fused into the GEMM. Call inside shard_map.
    Whole-operand VMEM residency: sized for benchmark/validation shapes; the
    production path tiles K via kernels/matmul.py blocking (DESIGN §5)."""
    n_dev = compat.axis_size(axis_name)
    m_loc, k = x.shape
    n = w.shape[1]
    return pl.pallas_call(
        functools.partial(_ag_mm_kernel, axis_name=axis_name, n_dev=n_dev),
        in_specs=[pl.BlockSpec(memory_space=compat.ANY),
                  pl.BlockSpec(memory_space=compat.ANY)],
        out_specs=pl.BlockSpec(memory_space=compat.ANY),
        out_shape=jax.ShapeDtypeStruct((n_dev, m_loc, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((2, m_loc, k), x.dtype),
                        pltpu.VMEM((k, n), w.dtype),
                        pltpu.VMEM((m_loc, n), x.dtype),
                        pltpu.SemaphoreType.DMA((n_dev - 1,)),
                        pltpu.SemaphoreType.DMA((n_dev - 1,)),
                        pltpu.SemaphoreType.REGULAR((2,)),
                        pltpu.SemaphoreType.DMA],
        compiler_params=compat.CompilerParams(collective_id=collective_id("ag_matmul_fused")),
        interpret=compat.interpret_params() if interpret else False,
    )(x, w)


# ---------------------------------------------------------------------------
# Fused GEMM × reduce-scatter (paper Fig. 8 / Table 3)
# ---------------------------------------------------------------------------

def _mm_rs_kernel(x_ref, w_ref, out_ref, landing, acc_v, p_v, l_v, x_v, w_v,
                  send_sem, recv_sem, cap_sem, copy_sem, *,
                  axis_name: str, n_dev: int, m_blk: int):
    my = lax.axis_index(axis_name)
    left = lax.rem(my + n_dev - 1, jnp.int32(n_dev))
    right = lax.rem(my + 1, jnp.int32(n_dev))
    pk_neighbor_barrier(axis_name)

    cp_w = pltpu.make_async_copy(w_ref, w_v, copy_sem)
    cp_w.start()
    cp_w.wait()

    def load_block(b):
        cp = pltpu.make_async_copy(x_ref.at[pl.dslice(b * m_blk, m_blk)],
                                   x_v, copy_sem)
        cp.start()
        cp.wait()

    # step 0: acc = my partial for block (my+1)
    load_block(lax.rem(my + 1, jnp.int32(n_dev)))
    acc_v[...] = jax.lax.dot(x_v[...], w_v[...],
                             preferred_element_type=jnp.float32)

    def step(i, _):
        slot = lax.rem(i, 2)

        @pl.when(i >= 3)
        def _reuse_ack():
            pk_wait(cap_sem.at[slot], 1)

        # forward the accumulator (one-way, pre-allocated landing slot)...
        rdma = pk_store_async(acc_v, landing.at[slot], send_sem.at[i - 1],
                              recv_sem.at[i - 1], left)

        # ...while the MXU computes the next partial block (overlap): the
        # paper's hiding condition K >= s*R/(2*B) decides if this dot fully
        # covers the transfer (costmodel.hiding_threshold_k).
        load_block(lax.rem(my + 1 + i, jnp.int32(n_dev)))
        p_v[...] = jax.lax.dot(x_v[...], w_v[...],
                               preferred_element_type=jnp.float32)
        rdma.wait()
        cp_l = pltpu.make_async_copy(landing.at[slot], l_v, copy_sem)
        cp_l.start()
        cp_l.wait()
        acc_v[...] = p_v[...] + l_v[...]

        @pl.when(i <= n_dev - 3)
        def _consumed():
            pk_signal(cap_sem.at[slot], right)
        return 0

    lax.fori_loop(1, n_dev, step, 0)
    st = pltpu.make_async_copy(acc_v, out_ref, copy_sem)
    st.start()
    st.wait()


def matmul_rs_fused(x, w, axis_name: str, *, interpret=True):
    """x: (m, k_loc); w: (k_loc, n) (K sharded over the axis). Returns the
    reduce-scattered (m/n_dev, n) fp32 shard. Call inside shard_map."""
    n_dev = compat.axis_size(axis_name)
    m, k_loc = x.shape
    n = w.shape[1]
    assert m % n_dev == 0
    m_blk = m // n_dev
    return pl.pallas_call(
        functools.partial(_mm_rs_kernel, axis_name=axis_name, n_dev=n_dev,
                          m_blk=m_blk),
        in_specs=[pl.BlockSpec(memory_space=compat.ANY),
                  pl.BlockSpec(memory_space=compat.ANY)],
        out_specs=pl.BlockSpec(memory_space=compat.ANY),
        out_shape=jax.ShapeDtypeStruct((m_blk, n), jnp.float32),
        scratch_shapes=[compat.hbm_scratch((2, m_blk, n), jnp.float32),
                        pltpu.VMEM((m_blk, n), jnp.float32),
                        pltpu.VMEM((m_blk, n), jnp.float32),
                        pltpu.VMEM((m_blk, n), jnp.float32),
                        pltpu.VMEM((m_blk, k_loc), x.dtype),
                        pltpu.VMEM((k_loc, n), w.dtype),
                        pltpu.SemaphoreType.DMA((n_dev - 1,)),
                        pltpu.SemaphoreType.DMA((n_dev - 1,)),
                        pltpu.SemaphoreType.REGULAR((2,)),
                        pltpu.SemaphoreType.DMA],
        compiler_params=compat.CompilerParams(collective_id=collective_id("matmul_rs_fused")),
        interpret=compat.interpret_params() if interpret else False,
    )(x, w)
