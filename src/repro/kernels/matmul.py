"""MXU-tiled matmul kernel — the local-compute building block of every PK
fused kernel. Explicit BlockSpec VMEM tiling, fp32 accumulation scratch,
(parallel, parallel, arbitrary) grid so the K reduction stays sequential.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _mm_kernel(x_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(x, w, *, bm: int = 128, bn: int = 128, bk: int = 128,
           interpret: bool = False):
    """x: (M, K) @ w: (K, N). Dims must tile by (bm, bn, bk) — MXU-aligned
    multiples of 128 (DESIGN §5); ops.matmul pads if needed."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0, \
        (x.shape, w.shape, bm, bn, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_mm_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                  pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)
