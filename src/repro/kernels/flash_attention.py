"""FlashAttention kernel (online softmax, causal + sliding-window), TPU
BlockSpec tiling. Grid = (B*H, q_blocks, kv_blocks) with the kv dim
sequential; m/l/acc live in VMEM scratch across kv steps. Fully-masked
kv blocks are skipped with pl.when — on TPU this is a real branch, so SWA
compute scales with the window, not the sequence (paper §3.1.3: scheduling
decides what work exists, not just where it runs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, window: int | None,
               blq: int, blk: int, kv_steps: int):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = qi * blq
    k_lo = ki * blk
    # Block-level schedule: skip blocks with no visible entries.
    run = jnp.bool_(True)
    if causal:
        run &= k_lo <= q_lo + blq - 1
    if window is not None:
        run &= k_lo + blk - 1 > q_lo - window

    @pl.when(run)
    def _block():
        q = q_ref[0]                                   # (blq, d)
        k = k_ref[0]
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        rows = q_lo + jax.lax.broadcasted_iota(jnp.int32, (blq, blk), 0)
        cols = k_lo + jax.lax.broadcasted_iota(jnp.int32, (blq, blk), 1)
        keep = jnp.ones((blq, blk), jnp.bool_)
        if causal:
            keep &= cols <= rows
        if window is not None:
            keep &= cols > rows - window
        s = jnp.where(keep, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)

    @pl.when(ki == kv_steps - 1)
    def _store():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    scale: float | None = None, blq: int = 128,
                    blk: int = 128, interpret: bool = False):
    """q, k, v: (B, H, S, D) (equal head counts; ops handles GQA).
    S must tile by blq/blk; D MXU-aligned (ops pads)."""
    b, h, s, d = q.shape
    skv = k.shape[2]
    assert s % blq == 0 and skv % blk == 0, (s, skv, blq, blk)
    scale = scale if scale is not None else d ** -0.5
    grid = (b * h, s // blq, skv // blk)
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, skv, d)
    vf = v.reshape(b * h, skv, d)
    out = pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, causal=causal,
                          window=window, blq=blq, blk=blk, kv_steps=grid[2]),
        grid=grid,
        in_specs=[pl.BlockSpec((1, blq, d), lambda bh, i, j: (bh, i, 0)),
                  pl.BlockSpec((1, blk, d), lambda bh, i, j: (bh, j, 0)),
                  pl.BlockSpec((1, blk, d), lambda bh, i, j: (bh, j, 0))],
        out_specs=pl.BlockSpec((1, blq, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((blq, 1), jnp.float32),
                        pltpu.VMEM((blq, 1), jnp.float32),
                        pltpu.VMEM((blq, d), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
