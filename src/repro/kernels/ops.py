"""jit'd public wrappers for the Pallas kernels: padding to MXU-aligned
tiles, GQA head handling, interpret-mode dispatch (CPU validation vs TPU
target), and the composed collectives (all_reduce = RS ∘ AG).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import compat
from repro.kernels.collective_matmul import (ag_matmul_fused, matmul_ar_fused,
                                             matmul_rs_fused)
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.grouped_matmul import grouped_matmul as _gmm
from repro.kernels.mamba_scan import mamba_scan as _mscan
from repro.kernels.matmul import matmul as _mm
from repro.kernels.pk_comm import (p2p_ring_shift, ring_all_gather,
                                   ring_reduce_scatter)


def _on_tpu() -> bool:
    return not compat.default_interpret()


def _pad_to(x, mult: int, axis: int):
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x, s
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), s


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(x, w, *, bm=128, bn=128, bk=128, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    x, m0 = _pad_to(x, bm, 0)
    x, _ = _pad_to(x, bk, 1)
    w, _ = _pad_to(w, bk, 0)
    w, n0 = _pad_to(w, bn, 1)
    out = _mm(x, w, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:m0, :n0]


@functools.partial(jax.jit, static_argnames=("causal", "window", "blq",
                                             "blk", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, blq=128, blk=128,
                    interpret=None):
    """q: (B, Hq, S, D); k, v: (B, Hkv, S, D). GQA: kv heads repeated."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    hq, hkv = q.shape[1], k.shape[1]
    if hq != hkv:
        k = jnp.repeat(k, hq // hkv, axis=1)
        v = jnp.repeat(v, hq // hkv, axis=1)
    d0 = q.shape[-1]
    scale = d0 ** -0.5
    q, s0 = _pad_to(q, blq, 2)
    k, _ = _pad_to(k, blk, 2)
    v, _ = _pad_to(v, blk, 2)
    # kv padding correctness: padded cols are masked by causality for every
    # real q row (col > row); non-causal callers must pass aligned S.
    assert causal or k.shape[2] == s0, "non-causal needs blk-aligned S"
    q, _ = _pad_to(q, 128, 3)
    k, _ = _pad_to(k, 128, 3)
    v, _ = _pad_to(v, 128, 3)
    out = _flash(q, k, v, causal=causal, window=window, scale=scale,
                 blq=blq, blk=blk, interpret=interpret)
    return out[:, :, :s0, :d0]


@functools.partial(jax.jit, static_argnames=("bc", "bf", "bk", "interpret"))
def grouped_matmul(x, w, *, bc=128, bf=128, bk=128, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    x, c0 = _pad_to(x, bc, 1)
    x, _ = _pad_to(x, bk, 2)
    w, _ = _pad_to(w, bk, 1)
    w, f0 = _pad_to(w, bf, 2)
    out = _gmm(x, w, bc=bc, bf=bf, bk=bk, interpret=interpret)
    return out[:, :c0, :f0]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba_scan(dt, b_ssm, c_ssm, x, a, h0, *, chunk=128, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _mscan(dt, b_ssm, c_ssm, x, a, h0, chunk=chunk,
                  interpret=interpret)


# --- PK collectives (call inside shard_map) ---
#
# ``n_chunks`` on the GEMM×collectives is the ChunkSchedule seam: the count
# resolved by ``CommContext.gemm_chunk_schedule`` (explicit > RunConfig >
# measured table > analytic fused cost term) lands here and is fitted to the
# payload rows by the kernel wrappers (``fit_chunks`` — never a constraint).

def pk_all_gather(x, axis_name, *, n_chunks=1, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return ring_all_gather(x, axis_name, n_chunks=n_chunks,
                           interpret=interpret)


def pk_reduce_scatter(x, axis_name, *, n_chunks=1, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return ring_reduce_scatter(x, axis_name, n_chunks=n_chunks,
                               interpret=interpret)


def pk_all_reduce(x, axis_name, *, n_chunks=1, interpret=None):
    """all_reduce = reduce_scatter ∘ all_gather (no in-network reduction on
    ICI — DESIGN §2.1; same 2(N-1)/N per-device traffic as switch-offload)."""
    n = compat.axis_size(axis_name)
    blk, rem = divmod(x.shape[0], n)
    if rem != 0:  # pad leading dim to a multiple of n
        x = jnp.pad(x, [(0, n - rem)] + [(0, 0)] * (x.ndim - 1))
        blk = x.shape[0] // n
    parts = x.reshape(n, blk, *x.shape[1:])
    rs = pk_reduce_scatter(parts, axis_name, n_chunks=n_chunks,
                           interpret=interpret)
    ag = pk_all_gather(rs, axis_name, n_chunks=n_chunks, interpret=interpret)
    out = ag.reshape(n * blk, *x.shape[1:])
    return out[:x.shape[0] - (n - rem if rem else 0)] if rem else out


def pk_ring_shift(x, axis_name, *, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return p2p_ring_shift(x, axis_name, interpret=interpret)


def pk_ag_matmul(x, w, axis_name, *, n_chunks=1, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    out = ag_matmul_fused(x, w, axis_name, n_chunks=n_chunks,
                          interpret=interpret)
    return out.reshape(-1, w.shape[1])


def pk_matmul_rs(x, w, axis_name, *, n_chunks=1, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return matmul_rs_fused(x, w, axis_name, n_chunks=n_chunks,
                           interpret=interpret)


def pk_matmul_ar(x, w, axis_name, *, n_chunks=1, interpret=None):
    """Fused GEMM×all-reduce: one kernel (RS ring + in-kernel gather of the
    reduced blocks). Returns (m, n) fp32."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    out = matmul_ar_fused(x, w, axis_name, n_chunks=n_chunks,
                          interpret=interpret)
    return out.reshape(-1, w.shape[1])
