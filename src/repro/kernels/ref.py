"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def matmul_ref(x, w):
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """q,k,v: (B, H, S, D) (same head count — GQA repeat happens in ops)."""
    b, h, s, d = q.shape
    scale = scale if scale is not None else d ** -0.5
    s_ = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                    preferred_element_type=jnp.float32) * scale
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(k.shape[2])[None, :]
    keep = jnp.ones((s, k.shape[2]), bool)
    if causal:
        keep &= ki <= qi
    if window is not None:
        keep &= ki > qi - window
    s_ = jnp.where(keep, s_, NEG_INF)
    p = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def grouped_matmul_ref(x, w):
    """x: (E, C, d); w: (E, d, f) -> (E, C, f)."""
    return jnp.einsum("ecd,edf->ecf", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def mamba_scan_ref(dt, b_ssm, c_ssm, x, a, h0):
    """Sequential reference of the diagonal selective scan.

    dt, x: (B, S, D); b_ssm, c_ssm: (B, S, N); a: (D, N); h0: (B, D, N).
    Returns (y (B, S, D) f32, h_last)."""
    a_bar = jnp.exp(dt.astype(jnp.float32)[..., None] * a[None, None])
    bx = (dt[..., None] * b_ssm[:, :, None, :] * x[..., None]).astype(jnp.float32)

    def step(h, args):
        ab, bb, c = args
        h = ab * h + bb
        y = jnp.einsum("bdn,bn->bd", h, c.astype(jnp.float32))
        return h, y

    h_last, ys = jax.lax.scan(
        step, h0, (a_bar.swapaxes(0, 1), bx.swapaxes(0, 1),
                   c_ssm.swapaxes(0, 1)))
    return ys.swapaxes(0, 1), h_last


# --- distributed oracles (global-array semantics; used with shard_map) ---

def all_gather_ref(x_global):
    """Identity at the global level: the kernel gathers shards so every
    device holds the full array."""
    return x_global


def reduce_scatter_ref(x_global):
    """x_global: (n_dev, n_dev, blk...) — device d holds partials x[d];
    result shard d = sum_j x[j, d]."""
    return x_global.sum(axis=0)


def ag_matmul_ref(x, w):
    return matmul_ref(x, w)


def matmul_rs_ref(x, w):
    """Global semantics of GEMM+RS: plain matmul; sharding splits rows."""
    return matmul_ref(x, w)


def matmul_ar_ref(x, w):
    """Global semantics of GEMM+AR: plain matmul, replicated on every
    device. f32 out (the fused kernel accumulates and ships f32)."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)
