# Pallas TPU kernels for the paper's compute hot-spots + the PK communication
# primitives. Each kernel: <name>.py (pl.pallas_call + BlockSpec), wrapped in
# ops.py, oracled in ref.py. Communication kernels (pk_comm, collective_matmul)
# are validated cross-device in TPU interpret mode under shard_map.
from repro.kernels import ref
