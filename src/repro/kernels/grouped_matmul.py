"""Grouped (batched-expert) matmul kernel — the MoE expert GEMM
(paper §4.3). x: (E, C, d) capacity-gathered tokens per expert,
w: (E, d, f) expert weights. Grid (e, c_tile, f_tile, k_tile) with fp32
accumulation; the expert dim is a parallel grid axis so experts map onto
separate cores/steps without host-side loops.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(x_ref[0], w_ref[0],
                                preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == k_steps - 1)
    def _store():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def grouped_matmul(x, w, *, bc: int = 128, bf: int = 128, bk: int = 128,
                   interpret: bool = False):
    """(E, C, d) @ (E, d, f) -> (E, C, f); C/f/d must tile (ops pads)."""
    e, c, d = x.shape
    e2, d2, f = w.shape
    assert e == e2 and d == d2 and c % bc == 0 and f % bf == 0 and d % bk == 0
    grid = (e, c // bc, f // bf, d // bk)
    return pl.pallas_call(
        functools.partial(_gmm_kernel, k_steps=grid[3]),
        grid=grid,
        in_specs=[pl.BlockSpec((1, bc, bk), lambda ei, ci, fi, ki: (ei, ci, ki)),
                  pl.BlockSpec((1, bk, bf), lambda ei, ci, fi, ki: (ei, ki, fi))],
        out_specs=pl.BlockSpec((1, bc, bf), lambda ei, ci, fi, ki: (ei, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w)
