"""Low-precision wire formats: per-block int8 quantization with f32 scales.

The paper's transfer analysis (§3.1.1) prices ``t_comm`` linearly in payload
bytes, so halving the element width halves the wire time of every ring hop
— the cheapest ~2x available once the chunk pipeline already hides what it
can. This module is the single quantization implementation for both wire
paths in the repo:

* the ring GEMM-collectives (``core/comms.py`` ``wire="int8"``): each
  travelling sub-chunk is quantized per-row into int8 blocks with f32
  scales, shifted as an (int8 payload, f32 scales) pair, and
  dequantize-accumulated in f32 on arrival — the send-ahead double-buffered
  schedule is untouched, quantize/dequantize are just per-chunk stages on
  it;
* the manual-DP gradient compressor (``optim/compress.py``), which
  re-exports ``quant_dequant`` / ``ErrorFeedbackInt8`` from here so the two
  paths cannot drift.

Block layout: blocks are cut along the LAST axis (per row). Ring chunk
schedules slice the payload along *m* (rows), so every row's scale groups
are identical regardless of the chunk count — quantized values are
bit-exact across ``n_chunks`` ∈ {1, 2, 4}, preserving the rings'
bit-identical-to-1-chunk contract at the quantized level.

``WireFormat`` is the descriptor threaded through ``RunConfig.comm_wire`` →
``CommContext.wire`` → the ring impls, and priced by
``costmodel``/``autotune`` under the existing ``b{dtype_bytes}`` island
keys.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

#: default quantization block (elements sharing one f32 scale).
BLOCK = 256

#: int8 symmetric range.
QMAX = 127.0

#: scale floor — keeps all-zero blocks from dividing by zero.
SCALE_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class WireFormat:
    """Descriptor for the on-wire element format of a transfer schedule.

    ``dtype_bytes`` is the payload element width the cost model and the
    calibration tables key on (``island_key(..., dtype_bytes)`` →
    ``b{dtype_bytes}`` rows); ``block`` is the per-row quantization block
    (elements per f32 scale); ``stochastic_round`` selects unbiased
    stochastic rounding instead of round-to-nearest (the GEMM+AR option —
    repeated quantized reductions then average out instead of drifting).
    """

    name: str
    dtype_bytes: int
    block: int = BLOCK
    stochastic_round: bool = False

    @property
    def quantized(self) -> bool:
        return self.dtype_bytes < 2

    @property
    def bytes_per_element(self) -> float:
        """Effective wire bytes per payload element, scales included.

        int8 blocks ship one f32 scale per ``block`` elements:
        1 + 4/256 = 1.015625 B/elem at the default block.
        """
        if not self.quantized:
            return float(self.dtype_bytes)
        return self.dtype_bytes + 4.0 / self.block


#: the formats ``resolve_wire`` accepts by name. "bf16" is the identity
#: wire (payload ships in its own dtype); "int8" is round-to-nearest block
#: quantization; "int8_sr" adds stochastic rounding (GEMM+AR option).
WIRE_FORMATS: dict[str, WireFormat] = {
    "bf16": WireFormat("bf16", dtype_bytes=2),
    "int8": WireFormat("int8", dtype_bytes=1),
    "int8_sr": WireFormat("int8_sr", dtype_bytes=1, stochastic_round=True),
}


def resolve_wire(wire: Any) -> WireFormat | None:
    """Map a user-facing wire spec to a ``WireFormat`` (or None).

    Accepts None (full-precision: no wire transform), a registry name, or
    a ``WireFormat`` instance. The "bf16" identity format resolves to None
    so call sites can treat "no quantization" uniformly.
    """
    if wire is None:
        return None
    if isinstance(wire, WireFormat):
        return wire if wire.quantized else None
    if isinstance(wire, str):
        try:
            fmt = WIRE_FORMATS[wire]
        except KeyError:
            raise ValueError(
                f"unknown wire format {wire!r}; expected one of "
                f"{sorted(WIRE_FORMATS)}") from None
        return fmt if fmt.quantized else None
    raise TypeError(f"wire must be None, a name, or a WireFormat; "
                    f"got {type(wire).__name__}")


def wire_dtype_bytes(wire: Any, dtype_bytes: int = 2) -> int:
    """Element width a transfer keyed on ``wire`` ships (falls back to the
    tensor's own ``dtype_bytes`` for the identity wire)."""
    fmt = resolve_wire(wire)
    return fmt.dtype_bytes if fmt is not None else int(dtype_bytes)


def wire_payload_bytes(n_elems: float, wire: Any,
                       dtype_bytes: int = 2) -> float:
    """On-wire bytes for ``n_elems`` payload elements, scale planes
    included — the single payload-bytes formula shared by the cost model
    and the manual-DP compressor."""
    fmt = resolve_wire(wire)
    if fmt is None:
        return float(n_elems) * float(dtype_bytes)
    return float(n_elems) * fmt.bytes_per_element


# ---------------------------------------------------------------------------
# Per-block quantize / dequantize
# ---------------------------------------------------------------------------


def _blocked(x: jax.Array, block: int) -> tuple[jax.Array, int]:
    """(x padded+reshaped to (..., nb, block), original last-dim extent)."""
    cols = x.shape[-1]
    pad = (-cols) % block
    if pad:
        widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
        x = jnp.pad(x, widths)
    return x.reshape(*x.shape[:-1], -1, block), cols


def quantize_blocks(x: jax.Array, *, block: int = BLOCK,
                    stochastic_key: jax.Array | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """Quantize ``x`` to int8 in blocks along the last axis.

    Returns ``(q, scales)`` with ``q`` int8 of shape ``(*x.shape[:-1], nb,
    block)`` and ``scales`` f32 of shape ``(*x.shape[:-1], nb, 1)`` (kept
    dim for broadcasting), where ``nb = ceil(x.shape[-1] / block)``. The
    last axis is zero-padded to a block multiple; padding quantizes to 0
    and is dropped by ``dequantize_blocks``.

    With ``stochastic_key`` the round is stochastic (``floor(v + u)``,
    u ~ U[0, 1)) — unbiased, so repeated quantized reductions average out.
    """
    fp = _blocked(x.astype(jnp.float32), block)[0]
    scales = jnp.max(jnp.abs(fp), axis=-1, keepdims=True) / QMAX
    scales = jnp.maximum(scales, SCALE_EPS)
    v = fp / scales
    if stochastic_key is not None:
        v = jnp.floor(v + jax.random.uniform(stochastic_key, fp.shape))
    else:
        v = jnp.round(v)
    q = jnp.clip(v, -QMAX, QMAX).astype(jnp.int8)
    return q, scales


def dequantize_blocks(q: jax.Array, scales: jax.Array,
                      cols: int) -> jax.Array:
    """Inverse of ``quantize_blocks``: f32 of shape ``(*q.shape[:-2],
    cols)`` (block padding stripped)."""
    full = q.astype(jnp.float32) * scales
    return full.reshape(*q.shape[:-2], -1)[..., :cols]


def quant_dequant(x: jax.Array, *, block: int = BLOCK,
                  stochastic_key: jax.Array | None = None) -> jax.Array:
    """Round-trip ``x`` through per-block int8 (flattened layout).

    This is the promoted ``optim/compress._quant_dequant``: the array is
    flattened before blocking, so blocks span row boundaries — right for
    gradient compression where the tensor shape is incidental, wrong for
    wire payloads where rows must quantize identically across chunk counts
    (use ``quantize_blocks`` on the 2-D payload there).
    """
    flat = x.astype(jnp.float32).reshape(1, -1)
    q, scales = quantize_blocks(flat, block=block,
                                stochastic_key=stochastic_key)
    return dequantize_blocks(q, scales, flat.shape[-1]).reshape(x.shape)


# ---------------------------------------------------------------------------
# Error feedback
# ---------------------------------------------------------------------------


class EFState(NamedTuple):
    """Error-feedback residual accumulator (one f32 leaf per gradient)."""

    residual: Any


class ErrorFeedbackInt8:
    """EF-SGD style compressor: add the residual, quantize, carry the new
    residual. Unbiased in the long run even with round-to-nearest — the
    quantization error is fed back instead of dropped, so repeated
    applications converge on the true accumulated value.
    """

    def __init__(self, *, block: int = BLOCK):
        self.block = block

    def init(self, params: Any) -> EFState:
        return EFState(residual=jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def transform(self, grads: Any, state: EFState) -> tuple[Any, EFState]:
        corrected = jax.tree_util.tree_map(
            lambda g, r: g.astype(jnp.float32) + r, grads, state.residual)
        deq = jax.tree_util.tree_map(
            lambda c: quant_dequant(c, block=self.block), corrected)
        residual = jax.tree_util.tree_map(
            lambda c, d: c - d, corrected, deq)
        return deq, EFState(residual=residual)
