# ParallelKittens core — the paper's contribution as composable JAX modules.
#
# costmodel  — paper §3.1.1 cost model + TPU v5e constants
# pgl        — Parallel Global Layout (paper §3.2.1)
# collectives— overlapped GEMM×collective ops + baselines (paper §4.1)
# ring_attention / ulysses — sequence parallelism (paper §4.2)
# moe        — expert parallelism (paper §4.3)
# schedule   — overlap policy search (paper §3.1.3 SM-partitioning analogue)
# autotune   — empirical calibration: measured correction factors + dispatch
#              tables for CommContext(policy="measured") (imported lazily by
#              comms — not re-exported here to keep import time flat)
#
# comms      — the unified CommContext entry point (policy-driven dispatch)
# template   — the unified Island template (paper §3.2): declarative
#              shard_map islands with derived specs, FSDP gathers, fallback
#              predicate and trace-free plan() reports
#
# The Pallas-level twins of these (device-initiated RDMA, semaphores, the
# LCSC template) live in repro.kernels.pk_comm / repro.kernels.collective_matmul.

from repro.core import costmodel
from repro.core.pgl import PGL, barrier_pgl
from repro.core.comms import (
    CommContext, collective_id, register_collective,
    all_gather_matmul_baseline, pk_all_gather_matmul,
    matmul_reduce_scatter_baseline, pk_matmul_reduce_scatter,
    matmul_all_reduce_baseline, pk_matmul_all_reduce,
    all_to_all_baseline, pk_all_to_all, pk_psum_ring, ring_shift,
)
from repro.core.ring_attention import (
    pk_ring_attention, ring_attention_baseline, ssm_entry_states,
)
from repro.core.ulysses import pk_ulysses_attention, ulysses_attention_baseline
from repro.core.moe import (
    pk_moe_replicated, pk_moe_a2a, moe_reference_dense, ep_tp_split, capacity,
    DispatchPlan, dispatch_plan,
)
from repro.core.schedule import (OverlapPolicy, choose_a2a_chunks,
                                 choose_gemm_collective)
from repro.core.template import (Comm, Gather, Island, IslandPlan,
                                 comm_context, render_plans)
