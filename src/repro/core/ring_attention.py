"""Sequence-parallel attention (paper §4.2), JAX/shard_map level.

Ring Attention: KV shards rotate around the ring; each device runs blockwise
(online-softmax) attention on the shard it holds while the *next* shard is in
flight. The paper's "remote cache reuse" fix (§3.1.3) — bulk-prefetching the
next KV block into local HBM with dedicated communication SMs instead of
letting every block re-read over the interconnect — maps here to the landing
buffer the ppermute writes into, transferred once per hop by the ICI DMA
engines while the MXU computes.

Also contains the SSM analogue: sequence-parallel state passing for Mamba
(ring of (D,N) boundary states instead of KV blocks).

All functions are called INSIDE shard_map with `axis_name` bound; sequence is
sharded over that axis, heads/batch are local.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from repro import compat

from repro.core.comms import CommContext

NEG_INF = -1e30


def _grouped_scores(q, k, scale):
    """q: (B, Hkv, G, Sq, D); k: (B, Hkv, Skv, D) -> (B, Hkv, G, Sq, Skv)."""
    return jnp.einsum("bkgqd,bksd->bkgqs", q, k,
                      preferred_element_type=jnp.float32) * scale


def _block_update(q, k, v, m, l, o, *, scale, mask=None):
    """One online-softmax accumulation step (FlashAttention rule).

    Shapes: q (B,Hkv,G,Sq,D); k,v (B,Hkv,Skv,D); m,l (B,Hkv,G,Sq) f32;
    o (B,Hkv,G,Sq,D) f32.
    """
    s = _grouped_scores(q, k, scale)
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m - m_new)
    l_new = l * alpha + p.sum(axis=-1)
    o_new = o * alpha[..., None] + jnp.einsum(
        "bkgqs,bksd->bkgqd", p, v.astype(jnp.float32),
        preferred_element_type=jnp.float32)
    return m_new, l_new, o_new


def _causal_block_mask(sq: int, skv: int, q_offset, kv_offset,
                       window: int | None = None):
    """True = keep. Global-position causal (+ optional sliding window)."""
    qi = q_offset + jnp.arange(sq)[:, None]
    ki = kv_offset + jnp.arange(skv)[None, :]
    keep = ki <= qi
    if window is not None:
        keep = keep & (ki > qi - window)
    return keep  # (Sq, Skv)


def pk_ring_attention(q, k, v, axis_name: str, *, causal: bool = True,
                      window: int | None = None, scale: float | None = None,
                      ctx: CommContext | None = None):
    """q: (B, Hq, S_loc, D); k, v: (B, Hkv, S_loc, D), sequence sharded over
    `axis_name`. Returns (B, Hq, S_loc, D) in q.dtype.

    Per ring step i the held KV block originates from device (d - i) % n
    (right-going ring). Causal scheduling: blocks with src > d contribute
    nothing and are skipped via lax.switch (real branch on TPU — the paper's
    "communication pattern can reduce transfer size" point shows up here as
    skipped *compute*; transfers still go all the way around to keep the ring
    uniform).
    """
    ctx = ctx if ctx is not None else CommContext(axis_name=axis_name)
    n = compat.axis_size(axis_name)
    d = lax.axis_index(axis_name)
    b, hq, s_loc, dim = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    scale = scale if scale is not None else dim ** -0.5

    qg = q.reshape(b, hkv, g, s_loc, dim)
    m = jnp.full((b, hkv, g, s_loc), NEG_INF, jnp.float32)
    l = jnp.zeros((b, hkv, g, s_loc), jnp.float32)
    o = jnp.zeros((b, hkv, g, s_loc, dim), jnp.float32)

    kv = (k, v)
    for i in range(n):
        src = (d - i) % n
        k_i, v_i = kv
        # Start the next hop before consuming the current block so the
        # transfer overlaps this step's attention compute.
        if i < n - 1:
            kv = ctx.ring_shift(kv)

        def full_block(args):
            m_, l_, o_ = args
            if window is None:
                return _block_update(qg, k_i, v_i, m_, l_, o_, scale=scale)
            mask = _causal_block_mask(s_loc, s_loc, d * s_loc, src * s_loc,
                                      window)
            return _block_update(qg, k_i, v_i, m_, l_, o_, scale=scale,
                                 mask=mask)

        def diag_block(args):
            m_, l_, o_ = args
            mask = _causal_block_mask(s_loc, s_loc, d * s_loc, src * s_loc,
                                      window)
            return _block_update(qg, k_i, v_i, m_, l_, o_, scale=scale,
                                 mask=mask)

        def skip_block(args):
            return args

        if causal:
            case = jnp.where(src < d, 0, jnp.where(src == d, 1, 2))
            m, l, o = lax.switch(case, [full_block, diag_block, skip_block],
                                 (m, l, o))
        else:
            m, l, o = full_block((m, l, o))

    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, s_loc, dim).astype(q.dtype)


def ring_attention_baseline(q, k, v, axis_name: str, *, causal: bool = True,
                            window: int | None = None,
                            scale: float | None = None):
    """Non-overlapped baseline: bulk all-gather of the full K/V (the NCCL-ish
    schedule the paper's xDiT baseline reduces to), then one local attention
    over the full sequence."""
    d = lax.axis_index(axis_name)
    s_loc = q.shape[2]
    k_full = lax.all_gather(k, axis_name, axis=2, tiled=True)
    v_full = lax.all_gather(v, axis_name, axis=2, tiled=True)
    b, hq, _, dim = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    scale = scale if scale is not None else dim ** -0.5
    qg = q.reshape(b, hkv, g, s_loc, dim)
    s = _grouped_scores(qg, k_full, scale)
    if causal or window is not None:
        mask = _causal_block_mask(s_loc, k_full.shape[2], d * s_loc, 0,
                                  window if window is not None else None)
        if not causal:  # window-only (bidirectional) — not used by our archs
            mask = mask | (jnp.arange(k_full.shape[2])[None, :] >
                           d * s_loc + jnp.arange(s_loc)[:, None])
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", p, v_full.astype(jnp.float32))
    return out.reshape(b, hq, s_loc, dim).astype(q.dtype)


# ---------------------------------------------------------------------------
# Sequence-parallel SSM: ring state passing (the ring-attention analogue for
# attention-free layers; DESIGN §6, falcon-mamba row).
# ---------------------------------------------------------------------------

def ssm_entry_states(chunk_decay, chunk_exit, axis_name: str,
                     ctx: CommContext | None = None):
    """Sequence-parallel linear-SSM state exchange.

    For a diagonal SSM ``h_t = a_t * h_{t-1} + b_t``, a sequence chunk acts on
    its entry state as an affine map ``h_out = A * h_in + S`` where A is the
    chunk's total decay and S its exit-from-zero state. Each device d needs
    ``h_entry_d`` — the composition of all chunks j < d applied to zero.

    This runs an exclusive scan over the device (sequence) axis as a ring
    pipeline of n-1 hops, each forwarding the running composition
    ``(A_window, S_window)`` one hop right. Device d reads its answer at hop
    i == d, when the incoming window is exactly [0 .. d-1]. Payload per hop is
    one (..., D, N) state pair — negligible ICI traffic, so all heavy chunk
    compute stays fully parallel (the SSM analogue of Ring Attention).
    """
    ctx = ctx if ctx is not None else CommContext(axis_name=axis_name)
    n = compat.axis_size(axis_name)
    d = lax.axis_index(axis_name)
    h_entry = jnp.zeros_like(chunk_exit)
    cA, cS = chunk_decay, chunk_exit          # window [d, d]
    for i in range(1, n):
        cA_in, cS_in = ctx.ring_shift((cA, cS))  # window [d-i .. d-1]
        h_entry = jnp.where(d == i, cS_in, h_entry)
        # compose: incoming window first, then our chunk -> window [d-i .. d]
        cA, cS = chunk_decay * cA_in, chunk_decay * cS_in + chunk_exit
    return h_entry
