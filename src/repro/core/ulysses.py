"""DeepSpeed-Ulysses sequence parallelism (paper §4.2, Fig. 11).

Everything outside self-attention is sequence-sharded; attention itself is
head-sharded. The re-sharding is a 4-D (B, S, H, D) all-to-all along inner
(non-leading) dimensions — the exact case where NCCL forces reshape+copy
staging (paper App. B, Fig. 17) and where PK's fine-grained a2a wins. On TPU,
`lax.all_to_all` already operates on strided layouts with no host-side
reshape; the PK refinement is *chunking* the a2a so attention on early head
chunks overlaps the transfer of later ones.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat

from repro.core.comms import CommContext
from repro.core.ring_attention import _causal_block_mask, NEG_INF


def _local_attention(q, k, v, *, causal, window, scale, q_offset=0):
    """q: (B, Hq, S, D); k, v: (B, Hkv, Skv, D). Full (non-ring) attention."""
    b, hq, s, dim = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    scale = scale if scale is not None else dim ** -0.5
    qg = q.reshape(b, hkv, g, s, dim)
    sc = jnp.einsum("bkgqd,bksd->bkgqs", qg, k,
                    preferred_element_type=jnp.float32) * scale
    if causal or window is not None:
        mask = _causal_block_mask(s, k.shape[2], q_offset, 0, window)
        sc = jnp.where(mask, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, s, dim).astype(q.dtype)


def _repeat_kv_to(k, n_target_heads):
    hkv = k.shape[1]
    if hkv >= n_target_heads:
        return k
    assert n_target_heads % hkv == 0
    return jnp.repeat(k, n_target_heads // hkv, axis=1)


def pk_ulysses_attention(q, k, v, axis_name: str, *, causal: bool = True,
                         window: int | None = None, scale: float | None = None,
                         n_chunks: int = 1, ctx: CommContext | None = None):
    """q: (B, Hq, S_loc, D); k, v: (B, Hkv, S_loc, D), sequence sharded.

    a2a reshards to head-sharded full-sequence, attends, reshards back. If
    Hkv < axis size (GQA), KV heads are repeated to the axis size first
    (Megatron-style replication; DESIGN §4). The a2a goes through
    ``CommContext.all_to_all`` — chunked when `n_chunks` > 1 so attention on
    early head chunks overlaps the transfer of later ones.
    """
    ctx = ctx if ctx is not None else CommContext(axis_name=axis_name)
    n = compat.axis_size(axis_name)
    b, hq, s_loc, dim = q.shape
    assert hq % n == 0, (hq, n)
    kr = _repeat_kv_to(k, max(k.shape[1], n))
    vr = _repeat_kv_to(v, max(v.shape[1], n))
    # (B, H, S_loc, D): split head dim across axis, gather sequence.
    q_h = ctx.all_to_all(q, split_axis=1, concat_axis=2, n_chunks=n_chunks)
    k_h = ctx.all_to_all(kr, split_axis=1, concat_axis=2, n_chunks=n_chunks)
    v_h = ctx.all_to_all(vr, split_axis=1, concat_axis=2, n_chunks=n_chunks)
    out_h = _local_attention(q_h, k_h, v_h, causal=causal, window=window,
                             scale=scale)
    # Back: split sequence, gather heads.
    return ctx.all_to_all(out_h, split_axis=2, concat_axis=1,
                          n_chunks=n_chunks)


def ulysses_attention_baseline(q, k, v, axis_name: str, *, causal: bool = True,
                               window: int | None = None,
                               scale: float | None = None):
    """The YunChang-style baseline: same math, single bulk a2a (n_chunks=1);
    kept separate so benchmarks can report both sides of paper Fig. 11."""
    return pk_ulysses_attention(q, k, v, axis_name, causal=causal,
                                window=window, scale=scale, n_chunks=1)
