"""Unified PK island template — the paper's §3.2 programming template as a
declarative JAX object.

The paper's headline claim is that eight communication primitives plus **one
load/compute/store/comm scaffold** suffice for peak multi-GPU kernels, making
each overlapped workload <50 LoC. ``CommContext`` (repro.core.comms) is the
comm half of that claim; this module is the scaffold half: every overlapped
``shard_map`` island in the model stack — MLP, attention out-projection,
ring/Ulysses attention, MoE, sharded decode, GPipe — is *declared* as an
:class:`Island` instead of hand-rolling spec construction, FSDP weight
gathering, CommContext injection, fallback switching and ``shard_map``
wrapping at each site.

An ``Island`` is declared with:

* **named inputs** (``{"x": P(...), "w": P(...)}``) and **out_specs** — the
  logical shardings, derived from ``ShardingRules`` at the call site, never
  hand-threaded through a ``shard_map`` call;
* an optional **FSDP-gather set** (``gathers={"w1": Gather(dim=0, size=d)}``)
  — weights all-gathered *inside* the island so XLA overlaps the gather with
  the previous chunk's compute (ZeRO-3), one implementation instead of six;
* a **body** ``body(ctx, **inputs)`` that receives a ready
  :class:`~repro.core.comms.CommContext` whose backend pin / policy /
  calibration are threaded from ``RunConfig``;
* a **fallback predicate** (single-device mesh, tp-divisibility constraints,
  ``pk_overlap=False`` / ``reference_mode``) routing to a dense **reference**
  implementation with identical semantics;
* an optional :class:`Comm` descriptor naming the island's dominant
  collective, from which :meth:`Island.plan` derives a trace-free report —
  chosen backend, chunk count, predicted hidden fraction — so a whole forward
  pass's overlap schedule is inspectable before anything runs.

Adding a new overlapped workload is declaring one Island (README has the
walkthrough)::

    island = Island(
        "my_op", rules=rules, run=run,
        inputs={"x": P(bspec, None), "w": rules.w2d(k, n, tp_dim=0)},
        out_specs=P(bspec, None),
        gathers={"w": Gather(dim=1, size=n)},
        body=lambda ctx, x, w: ctx.matmul_all_reduce(x, w),
        reference=lambda x, w: x @ w,
        divisible=((n, rules.tp),),
        comm=Comm("matmul_all_reduce", m=m, n=n, k=k))
    y = island(x=x, w=w)          # shard_map island, or dense fallback
    print(island.plan())          # backend/chunks/hidden fraction, trace-free

This module is the **only** place in the PK-overlap paths allowed to call
``compat.shard_map`` directly (guarded by tests/test_template.py; the
calibration micro-bench harness in core/autotune.py is the one documented
exception).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P  # noqa: F401  (re-export for call sites)

from repro import compat
from repro.core import costmodel as cm
from repro.core.autotune import island_key as _island_key
from repro.core.comms import GEMM_OP_KIND, OP_BACKENDS, CommContext
from repro.core.schedule import a2a_chunk_axis, choose_a2a_chunks

__all__ = ["Island", "Gather", "Comm", "IslandPlan", "comm_context",
           "maybe_allgather", "render_plans", "plan_overrides",
           "island_override", "record_guard_trip", "take_guard_trips"]


# ---------------------------------------------------------------------------
# Island boundary guards (RunConfig.island_guards). The check itself is a
# jit-compatible finite-reduction over the island's float inputs/outputs;
# trips land in this process-wide registry via jax.debug.callback and are
# drained once per engine step (the fleet steps replicas serially, so the
# plain dict needs no locking). re-exported by runtime.health.
# ---------------------------------------------------------------------------

_GUARD_TRIPS: dict[str, int] = {}


def record_guard_trip(island: str, ok) -> None:
    """Guard callback target: count a trip when ``ok`` is False."""
    if not bool(ok):
        _GUARD_TRIPS[island] = _GUARD_TRIPS.get(island, 0) + 1


def take_guard_trips() -> dict[str, int]:
    """Drain the guard-trip registry: {island: trips since last drain}."""
    out = dict(_GUARD_TRIPS)
    _GUARD_TRIPS.clear()
    return out


def _boundary_guard(name: str, args, out):
    """Emit one finite-check over every float leaf of an island's inputs and
    outputs. Cheap (a single fused all-isfinite reduction per leaf) and
    jit-compatible; the verdict leaves the trace through a debug callback."""
    leaves = [a for a in jax.tree.leaves((args, out))
              if hasattr(a, "dtype") and jnp.issubdtype(a.dtype, jnp.floating)]
    if not leaves:
        return out
    ok = jnp.all(jnp.stack([jnp.all(jnp.isfinite(a)) for a in leaves]))
    jax.debug.callback(functools.partial(record_guard_trip, name), ok)
    return out


def _axes_size(mesh, axes) -> int:
    """Product of the named mesh axes (1 for None/empty)."""
    if mesh is None or axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return math.prod(mesh.shape[a] for a in axes)


def comm_context(run, axis: str, mesh=None, **overrides) -> CommContext:
    """The single CommContext construction point for every island (DESIGN §3):
    ``run.comm_backend`` pins one backend for A/B runs, ``run.comm_policy`` /
    ``run.calibration_path`` select the analytic vs measured cost source.
    ``run=None`` gives the default policy context (benchmarks, tests)."""
    kw: dict[str, Any] = {"axis_name": axis, "mesh": mesh}
    if run is not None:
        kw.update(backend=run.comm_backend, allow_bidir=run.pk_bidirectional,
                  policy=run.comm_policy, calibration=run.calibration_path,
                  chunks=run.comm_chunks,
                  wire=getattr(run, "comm_wire", None))
    kw.update(overrides)
    return CommContext(**kw)


def maybe_allgather(w, axes, dim: int, full_size: int):
    """FSDP (ZeRO-3) weight gather inside an island: all-gather `dim` of `w`
    up to `full_size` over the fsdp axes. No-op for ``axes=None`` / ``w=None``
    / already-full weights — safe to declare unconditionally."""
    if w is None or axes is None:
        return w
    names = (axes,) if isinstance(axes, str) else tuple(axes)
    for a in names:
        if w.ndim > dim and w.shape[dim] < full_size:
            w = jax.lax.all_gather(w, a, axis=dim, tiled=True)
    return w


@dataclasses.dataclass(frozen=True)
class Gather:
    """FSDP all-gather instruction for one island input: gather `dim` back to
    `size` over the rules' fsdp axes before the body runs."""
    dim: int
    size: int


@dataclasses.dataclass(frozen=True)
class Comm:
    """Declaration of an island's dominant collective, for :meth:`Island.plan`.

    GEMM×collective ops carry the global GEMM coordinates (m, n, k) the
    §3.1.1 cost model dispatches on; ``all_to_all`` carries the payload bytes
    the chunk policy needs; ``backend`` records a call-site pin (e.g. the MoE
    ring combine) so the plan reports what actually runs.
    """
    op: str
    m: int = 0
    n: int = 0
    k: int = 0
    payload_bytes: float = 0.0
    dtype_bytes: int = 2
    n_chunks: int | None = None
    chunk_dim: str | None = None
    backend: str | None = None
    downstream_compute_s: float = 0.0
    #: local payload shape + a2a axes, so plan() can fit the chunk count to
    #: the splittable bystander dims exactly like pk_all_to_all will
    shape: tuple[int, ...] | None = None
    split_axis: int | None = None
    concat_axis: int | None = None
    #: where a declared n_chunks/backend came from ("measured" when the
    #: builder resolved it from calibration rows, e.g. the auto Ulysses a2a
    #: chunk count) — plan() reports it instead of re-deriving
    source: str | None = None


@dataclasses.dataclass(frozen=True)
class IslandPlan:
    """Trace-free overlap report for one island (paper §3.1.3 decision).

    ``source`` records where the hidden fraction and chunk count came from:
    ``"analytic"`` (the cost model's prediction) or ``"measured"`` (this
    island's — or the global — calibration rows on a calibrated mesh).
    """
    island: str
    axis: Any
    axis_size: int
    fallback: bool
    reason: str
    op: str | None = None
    backend: str | None = None
    n_chunks: int | None = None
    chunk_dim: str | None = None
    hidden_fraction: float | None = None
    source: str = "analytic"
    #: on-wire element format of the island's ring payloads ("int8"/"int8_sr"
    #: when RunConfig.comm_wire quantizes them, else "bf16"); None for
    #: non-ring backends and non-GEMM ops, where no wire transform applies
    wire: str | None = None

    def asdict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        if self.fallback:
            return f"{self.island:<14} -> dense fallback ({self.reason})"
        hf = ("-" if self.hidden_fraction is None
              else f"{self.hidden_fraction:.2f}")
        return (f"{self.island:<14} op={self.op or '-':<22} "
                f"backend={self.backend or '-':<10} "
                f"chunks={self.n_chunks or 1:<3} hidden={hf:<5} "
                f"wire={self.wire or '-':<8} src={self.source}")


def render_plans(plans: Sequence[IslandPlan]) -> str:
    """One-line-per-island overlap schedule table (launchers print this)."""
    head = "island         overlap schedule (backend / chunks / hidden frac)"
    return "\n".join([head, "-" * len(head)] + [str(p) for p in plans])


def plan_overrides(plans: Sequence[IslandPlan]) -> tuple:
    """Freeze resolved plans into ``RunConfig.island_overrides`` entries.

    Each non-fallback plan with a resolved backend becomes one
    ``(island_name, backend, chunks)`` entry; ``chunks`` is normalized to
    what the consumer expects — *sub-chunks per ring step* for the
    chunk-pipelined GEMM×collectives (``Island.make_context`` threads it
    into ``CommContext.chunks``), the *total* chunk count for ``all_to_all``
    islands (the a2a builders consume it directly). This is the serving
    engine's plan-to-context seam: evaluate ``island_plans()`` once per
    shape bucket, freeze the decisions here, and every island the bucket's
    jitted step builds runs exactly the schedule its plan reported.
    """
    out = []
    for p in plans:
        if p.fallback or p.backend is None:
            continue
        if p.op in GEMM_OP_KIND:
            chunks = None
            if (p.backend in ("ring", "ring_bidir", "fused")
                    and p.n_chunks):
                chunks = max(1, p.n_chunks // max(p.axis_size, 1))
        elif p.op == "all_to_all":
            chunks = p.n_chunks
        else:
            # psum / ring_shift / ...: the backend choice is the whole
            # decision; chunk counts there are structural (axis size)
            chunks = None
        out.append((p.island, p.backend, chunks))
    return tuple(out)


def island_override(run, name: str) -> tuple | None:
    """The ``(backend, chunks, source)`` override
    ``RunConfig.island_overrides`` carries for island ``name``, or None.
    Later entries win (a re-resolved plan appended to an existing tuple
    supersedes the stale one — the seam the runtime HealthMonitor's
    demotions layer through, as 4-tuples whose source is ``"health"``;
    plain plan entries report source ``"plan"``)."""
    entries = getattr(run, "island_overrides", ()) if run is not None else ()
    hit = None
    for entry in entries:
        if entry and entry[0] == name:
            hit = (entry[1], entry[2] if len(entry) > 2 else None,
                   entry[3] if len(entry) > 3 else "plan")
    return hit


class Island:
    """One declarative overlapped shard_map island (see module docstring).

    Construction is cheap and trace-free; ``__call__(**arrays)`` compiles the
    ``shard_map`` (or routes to the dense reference), ``plan()`` reports the
    overlap schedule without tracing anything.
    """

    def __init__(self, name: str, *, body: Callable | None = None,
                 inputs: Mapping[str, Any] | None = None,
                 out_specs: Any = None,
                 rules=None, mesh=None, axis=None, run=None,
                 reference: Callable | None = None,
                 gathers: Mapping[str, Gather] | None = None,
                 enable: bool = True,
                 gather_axes: Any = None,
                 divisible: Sequence[tuple[int, Any]] = (),
                 fallback_axes: Any = None,
                 comm: Comm | None = None,
                 hw: cm.HardwareSpec | None = None,
                 ctx_kwargs: Mapping[str, Any] | None = None):
        self.name = name
        self.rules = rules
        self.mesh = mesh if mesh is not None else (
            rules.mesh if rules is not None else None)
        self.axis = axis if axis is not None else (
            rules.tp if rules is not None else None)
        self.run = run
        self.body = body
        self.inputs = dict(inputs or {})
        self.out_specs = out_specs
        self.reference = reference
        self.gathers = dict(gathers or {})
        self.gather_axes = gather_axes if gather_axes is not None else (
            rules.fsdp_axes if rules is not None else None)
        self.enable = enable
        self.divisible = tuple(divisible)
        self.fallback_axes = fallback_axes if fallback_axes is not None \
            else self.axis
        self.comm = comm
        self.hw = hw
        self.ctx_kwargs = dict(ctx_kwargs or {})

    # -- fallback predicate ------------------------------------------------

    @property
    def axis_size(self) -> int:
        return _axes_size(self.mesh, self.axis)

    def fallback_reason(self) -> str | None:
        """Why this island routes to the dense reference (None = it runs as a
        shard_map island). The paper-template predicate: reference mode,
        single device, and per-island tp-divisibility constraints."""
        if self.mesh is None:
            return "no mesh (single-process reference mode)"
        if self.run is not None and getattr(self.run, "reference_mode", False):
            return "RunConfig.reference_mode"
        if not self.enable:
            return "disabled by RunConfig"
        if self.mesh.devices.size == 1:
            return "single-device mesh"
        if _axes_size(self.mesh, self.fallback_axes) == 1:
            return f"axis {self.fallback_axes!r} has size 1"
        for size, axes in self.divisible:
            n = _axes_size(self.mesh, axes)
            if n and size % n != 0:
                return (f"size {size} not divisible by axis {axes!r} "
                        f"(= {n})")
        return None

    # -- execution ---------------------------------------------------------

    @property
    def island_key(self) -> str | None:
        """The calibration-row key this island dispatches as (None when no
        ``Comm`` is declared): ``autotune.island_key(name, op, dtype)``.
        ``calibrate --per-island`` tags measured rows with it; the context
        built below prefers those rows over the global shape grid."""
        if self.comm is None:
            return None
        return _island_key(self.name, self.comm.op, self.comm.dtype_bytes)

    def make_context(self) -> CommContext:
        kw = dict(self.ctx_kwargs)
        if self.hw is not None:
            kw.setdefault("hw", self.hw)
        kw.setdefault("island", self.island_key)
        # RunConfig.island_overrides: a frozen plan decision for THIS island
        # (serving engine buckets). The backend becomes a context pin and a
        # GEMM sub-chunk count the context default, so the bucket's step
        # runs exactly what its recorded plan reported. Explicit ctx_kwargs
        # at the declaration site still win (setdefault).
        ov = island_override(self.run, self.name)
        if ov is not None:
            be, chunks, _src = ov
            if be is not None:
                kw.setdefault("backend", be)
            if (chunks is not None and self.comm is not None
                    and self.comm.op in GEMM_OP_KIND):
                kw.setdefault("chunks", chunks)
        # scripted comms-level payload fault (RunConfig.comm_fault, set by
        # the serving engine while a CommFaultPlan corrupt/bitflip event is
        # active): thread (kind, hop) to the ring collectives when this
        # island is the target ("*" targets every island)
        ft = getattr(self.run, "comm_fault", None) \
            if self.run is not None else None
        if ft is not None and ft[1] in ("*", self.name):
            kw.setdefault("fault",
                          (ft[0], ft[2] if len(ft) > 2 else 0))
        # a declared Comm.n_chunks becomes the context's chunk default, so
        # the body's GEMM-collective calls run the schedule plan() reports
        # without every call site re-passing n_chunks=. The global A/B knob
        # (RunConfig.comm_chunks) still wins when set.
        if (self.comm is not None and self.comm.n_chunks is not None
                and self.comm.op in GEMM_OP_KIND
                and (self.run is None
                     or getattr(self.run, "comm_chunks", None) is None)):
            kw.setdefault("chunks", self.comm.n_chunks)
        return comm_context(self.run, self.axis, mesh=self.mesh, **kw)

    def __call__(self, **arrays):
        if set(arrays) != set(self.inputs) and self.fallback_reason() is None:
            raise TypeError(
                f"island {self.name!r} declared inputs "
                f"{sorted(self.inputs)}, got {sorted(arrays)}")
        reason = self.fallback_reason()
        if reason is not None:
            if self.reference is None:
                raise ValueError(
                    f"island {self.name!r} must fall back ({reason}) but "
                    "declares no dense reference")
            return self.reference(**arrays)
        names = list(self.inputs)
        ctx = self.make_context()
        gather_axes = self.gather_axes

        def shard_body(*args):
            kw = dict(zip(names, args))
            for n, g in self.gathers.items():
                kw[n] = maybe_allgather(kw[n], gather_axes, g.dim, g.size)
            return self.body(ctx, **kw)

        f = compat.shard_map(
            shard_body, mesh=self.mesh,
            in_specs=tuple(self.inputs[n] for n in names),
            out_specs=self.out_specs, check_vma=False)
        args = tuple(arrays[n] for n in names)
        out = f(*args)
        if self.run is not None and getattr(self.run, "island_guards", False):
            # guard at the island BOUNDARY — outside the shard_map, inside
            # the enclosing jit — so one check covers the re-assembled
            # logical arrays on every backend
            out = _boundary_guard(self.name, args, out)
        return out

    # -- introspection -----------------------------------------------------

    def _measured_hidden(self, ctx: CommContext, backend: str,
                         kind: str) -> float | None:
        """Measured hidden fraction for the chosen backend, or None.

        On a calibrated mesh the bulk row is the serial GEMM-then-collective
        baseline and the ring row the overlapped schedule, so the time the
        ring saved over bulk IS the hidden communication:
        ``(us_bulk - us_ring) / t_comm`` clamped to [0, 1], with ``t_comm``
        priced on the calibrated (measured-bandwidth) spec. A measured
        *bulk* decision (the table showed bulk winning) reports 0.0 — still
        a measurement, not a prediction. Island-keyed rows are preferred;
        the generic shape grid is the fallback. None — no usable
        measurement — leaves the plan on the analytic prediction. The fused
        backend participates the same way once ``calibrate --per-island``
        has swept fused×chunks rows: its delta over the bulk row is the
        overlap the single-kernel pipeline actually achieved.
        """
        if backend not in ("bulk", "ring", "ring_bidir", "fused"):
            return None
        table = ctx.active_calibration()
        if table is None or self.comm is None:
            return None
        c = self.comm
        n_dev = self.axis_size
        ring_be = backend if backend != "bulk" else "ring"
        # both sides of the delta must come from the SAME tier: an island
        # ring row minus a global-grid bulk row is a cross-layout subtraction
        # (another tier's layout is not evidence about this one)
        tiers: list[dict[str, Any]] = []
        if self.island_key is not None:
            tiers.append({"island": self.island_key, "island_only": True})
        tiers.append({"island": None})
        us_ov = us_bulk = None
        for sel in tiers:
            kw: dict[str, Any] = dict(axis_size=n_dev,
                                      dtype_bytes=c.dtype_bytes, **sel)
            us_ov = table.measured_us(c.op, ring_be, c.m, c.n, c.k, **kw)
            us_bulk = table.measured_us(c.op, "bulk", c.m, c.n, c.k, **kw)
            if us_ov is not None and us_bulk is not None:
                break
        if us_ov is None or us_bulk is None:
            return None
        if backend == "bulk":
            return 0.0          # nothing overlaps, by measurement
        # a quantized wire shrinks the denominator: T_comm is what the ring
        # actually ships (int8 payload + f32 scale planes), not the tensor's
        # own width — the repriced hidden fraction the plan reports
        fmt = ctx.wire_format()
        elem_bytes = (fmt.bytes_per_element if fmt is not None
                      else c.dtype_bytes)
        shard = (cm.collective_tensor_bytes(c.m, c.n, c.k, 1, kind)
                 * elem_bytes / max(n_dev, 1))
        # same T_comm convention as choose_gemm_collective: the
        # bidirectional ring moves the payload over two link-pairs
        t_comm_us = cm.transfer_cost(
            cm.ring_collective_bytes(shard, n_dev, kind),
            ctx.effective_hw(),
            links=2 if backend == "ring_bidir" else 1) * 1e6
        if t_comm_us <= 0:
            return None
        return max(0.0, min(1.0, (us_bulk - us_ov) / t_comm_us))

    def plan(self) -> IslandPlan:
        """The trace-free §3.1.3 schedule decision this island will make:
        which backend the policy (or a pin) resolves to, the chunk count and
        the predicted hidden fraction of T_comm — or the fallback reason."""
        reason = self.fallback_reason()
        base = IslandPlan(self.name, self.axis, self.axis_size,
                          fallback=reason is not None,
                          reason=reason or "",
                          op=self.comm.op if self.comm else None)
        if reason is not None or self.comm is None:
            return base
        c = self.comm
        ctx = self.make_context()
        if c.op in GEMM_OP_KIND:
            n_dev = self.axis_size
            # Mirror the runtime dispatch's shape rules exactly, so the plan
            # can never report a schedule CommContext would refuse to run:
            # ring RS/AR needs m divisible by the axis (auto() returns bulk,
            # context pins degrade via _shape_guard); the bidirectional AG
            # ring additionally needs >= 2 local rows to split across the
            # two directions (odd shards split unevenly); the fused Pallas
            # kernel is auto-picked only on a real TPU with the
            # (approximate, coordinate-derived) operand footprint in VMEM.
            ring_ok = c.op == "all_gather_matmul" or c.m % n_dev == 0
            m_loc = c.m // n_dev if c.m % n_dev == 0 else c.m
            fused_ok = False
            if jax.default_backend() == "tpu" and not ctx._interpret_mode():
                x_rows = m_loc if c.op == "all_gather_matmul" else c.m
                footprint = ((x_rows + c.n) * c.k * c.dtype_bytes
                             + max(m_loc, 1) * c.n * 4)
                fused_ok = footprint <= ctx.hw.vmem_bytes
            if c.backend is not None:
                # call-site pin: the body passes backend= explicitly, which
                # the runtime enforces — a shape violation RAISES there
                # rather than degrading, so report the pin and say so.
                backend = c.backend
                reason = f"pinned backend={c.backend}" if ring_ok or \
                    backend == "bulk" else (
                        f"pinned backend={c.backend} violates m % axis == 0 "
                        "— the runtime raises ValueError for this call")
            elif ctx.backend in OP_BACKENDS.get(c.op, ()):
                backend = ctx.backend       # context pin (RunConfig A/B run)
                if backend != "bulk" and not ring_ok:
                    backend = "bulk"        # the _shape_guard degradation
                elif (backend == "ring_bidir" and n_dev % 2 == 0
                        and m_loc < 2):
                    backend = "ring"
                reason = f"context pin -> {backend}"
            elif not ring_ok:
                backend = "bulk"
                reason = f"m={c.m} not divisible by axis size {n_dev} -> bulk"
            else:
                backend = ctx.auto_gemm_backend(
                    c.op, c.m, c.n, c.k, dtype_bytes=c.dtype_bytes,
                    fused_ok=fused_ok, bidir_ok=(m_loc >= 2))
                reason = None
            pol = ctx.gemm_policy(c.m, c.n, c.k, kind=GEMM_OP_KIND[c.op],
                                  dtype_bytes=c.dtype_bytes)
            if backend in ("ring", "ring_bidir", "fused"):
                # chunk-pipeline schedule, resolved through the SAME context
                # the body receives (make_context threads Comm.n_chunks into
                # ctx.chunks, RunConfig.comm_chunks winning): context default
                # > measured chunk sweep (island-keyed rows first) >
                # analytic argmin (fused-pipeline cost term for the fused
                # kernels) — plan and runtime cannot diverge
                sched = ctx.gemm_chunk_schedule(
                    c.op, c.m, c.n, c.k, backend=backend,
                    dtype_bytes=c.dtype_bytes, chunk_dim=c.chunk_dim)
                n_chunks = n_dev * sched.n_chunks   # ring steps × sub-chunks
                chunk_dim = sched.chunk_dim
                hidden = pol.hidden_fraction
                source = "measured" if sched.source == "measured" \
                    else "analytic"
            else:
                n_chunks = c.n_chunks if c.n_chunks is not None else 1
                chunk_dim, hidden, source = None, 0.0, "analytic"
            meas = self._measured_hidden(ctx, backend, GEMM_OP_KIND[c.op])
            if meas is not None:
                hidden, source = meas, "measured"
            ov = island_override(self.run, self.name)
            if ov is not None and ov[2] == "health" and ov[0] == backend:
                # a runtime HealthMonitor demotion is the decision on
                # record, layered above plan/measured dispatch
                source = "health"
                reason = f"health demotion -> {backend}"
            fmt = ctx.wire_format()
            wire = None
            if backend in ("ring", "ring_bidir"):
                # only the ring schedules implement the quantized wire; bulk
                # and fused ship full precision whatever the config says
                wire = fmt.name if fmt is not None else "bf16"
            return dataclasses.replace(
                base, backend=backend, n_chunks=n_chunks,
                chunk_dim=chunk_dim, hidden_fraction=hidden, source=source,
                wire=wire,
                reason=reason if reason is not None else pol.reason)
        if c.op == "all_to_all":
            source = c.source or "analytic"
            if c.n_chunks is not None:
                n_chunks = c.n_chunks
            elif c.shape is not None:
                # measured-first resolution, same method the builders use
                # for the auto chunk count (calibrate --per-island a2a rows)
                sched = ctx.a2a_chunk_schedule(
                    c.shape, c.split_axis, c.concat_axis,
                    dtype_bytes=c.dtype_bytes,
                    downstream_compute_s=c.downstream_compute_s)
                n_chunks, source = sched.n_chunks, sched.source
            else:
                n_chunks = choose_a2a_chunks(
                    c.payload_bytes, axis_size=self.axis_size,
                    downstream_compute_s=c.downstream_compute_s,
                    hw=ctx.effective_hw(), shape=c.shape,
                    split_axis=c.split_axis, concat_axis=c.concat_axis)
            if n_chunks > 1 and c.shape is not None:
                # mirror pk_all_to_all's bystander-dim fitting so the plan
                # never reports a chunking the runtime would bulk away
                fit = a2a_chunk_axis(c.shape, c.split_axis, c.concat_axis,
                                     n_chunks)
                n_chunks = fit[1] if fit is not None else 1
            backend = c.backend if c.backend is not None else \
                ("chunked" if n_chunks > 1 else "bulk")
            if backend == "chunked" and n_chunks <= 1:
                backend = "bulk"    # the declared chunking cannot split
            hidden = 1.0 - 1.0 / n_chunks if n_chunks > 1 else 0.0
            return dataclasses.replace(
                base, backend=backend, n_chunks=n_chunks,
                hidden_fraction=hidden, source=source,
                reason=f"a2a chunk policy -> {n_chunks} chunks")
        # psum / ring_shift / all_gather / reduce_scatter: the backend is
        # either pinned at the call site or bulk; per-hop overlap of the ring
        # schedules is structural (n-1 hops hide under per-step compute).
        backend = c.backend
        if backend is None and ctx.backend in OP_BACKENDS.get(c.op, ()):
            backend = ctx.backend
        backend = backend or "bulk"
        n_chunks = c.n_chunks if c.n_chunks is not None else (
            self.axis_size if backend != "bulk" else 1)
        return dataclasses.replace(
            base, backend=backend, n_chunks=n_chunks,
            reason=f"{c.op} via {backend}")

    def __repr__(self) -> str:
        return (f"Island({self.name!r}, axis={self.axis!r}, "
                f"inputs={list(self.inputs)}, "
                f"fallback={self.fallback_reason()!r})")
