"""Overlap schedule selection (paper §3.1.3 "SM partitioning", TPU form).

On GPUs the knob is how many SMs to dedicate to communication; on TPU the ICI
DMA engines are free, so the knobs become (a) whether to decompose a bulk
collective into a ring pipeline at all, (b) the chunk count, and (c) whether
to use the bidirectional ring (2 link-pairs). This module picks them from the
paper's cost model — the analytic analogue of PK's runtime SM-split search.

Two levels of granularity:

* ``choose_gemm_collective`` — ring vs bulk vs bidirectional ring, the
  step-level decision (one GEMM + one shift per ring step);
* ``choose_gemm_chunks`` — the chunk-pipeline refinement: how many
  double-buffered sub-chunks each ring step is split into, so step *i*'s
  shift overlaps step *i−1*'s GEMM at sub-shard granularity (Syncopate's
  chunk-centric scheduling, arXiv 2601.20595). The count is the argmin of
  ``costmodel.chunk_pipeline_cost`` — priced on measured link/GEMM constants
  when the spec is calibrated.

Chunked schedules never *reject* shapes: ``fit_chunks`` degrades a requested
count to the largest divisor the chunked sub-shape supports, so divisibility
is validated against the sub-shape, not the full shard.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import costmodel as cm

#: cost-model kind -> the dimension the chunk pipeline slices. AG+GEMM moves
#: the (m, k) input around the ring, so its chunks cut the travelling shard's
#: rows; RS/AR move the (m, n) output block, whose rows are likewise the
#: payload dim. "n" (slicing the GEMM's output columns / w's columns) is the
#: explicit-override alternative for shapes whose m extent will not split.
GEMM_CHUNK_DIM = {"all_gather": "m", "reduce_scatter": "m", "all_reduce": "m"}

#: candidate sub-chunk counts the scheduler searches (per ring step).
CHUNK_CANDIDATES = (1, 2, 4, 8)


def fit_chunks(extent: int, n_chunks: int) -> int:
    """Largest divisor of ``extent`` that is <= ``n_chunks`` (always >= 1).

    The non-divisible fallback for every chunked schedule: a chunk count that
    does not divide the chunked sub-shape degrades to the nearest one that
    does instead of raising — chunking is an optimization, never a new shape
    constraint.
    """
    if extent <= 0:
        return 1
    c = max(1, min(n_chunks, extent))
    while extent % c:
        c -= 1
    return c


@dataclasses.dataclass(frozen=True)
class ChunkSchedule:
    """The chunk-pipeline decision for one GEMM×collective call."""

    n_chunks: int            # sub-chunks per ring step (1 = classic ring)
    chunk_dim: str           # "m" | "n" — which GEMM dim the chunks slice
    reason: str
    source: str = "analytic"   # "analytic" | "measured" | "explicit"


@dataclasses.dataclass(frozen=True)
class OverlapPolicy:
    strategy: str            # "none" | "ring" | "ring_bidir"
    n_chunks: int
    hidden_fraction: float   # predicted fraction of T_comm hidden
    reason: str

    @property
    def enabled(self) -> bool:
        return self.strategy != "none"


def choose_gemm_collective(m: int, n: int, k: int, *, axis_size: int,
                           kind: str, dtype_bytes: int = 2,
                           hw: cm.HardwareSpec = cm.TPU_V5E,
                           allow_bidir: bool = True,
                           wire_bytes: float | None = None) -> OverlapPolicy:
    """Pick the schedule for a fused GEMM×collective.

    The paper's hiding condition (§3.1.3): per-ring-step compute must cover the
    per-step transfer. For GEMM+RS with N steps, step compute = 2*m*n*k/N
    flops, step transfer = (m/N)*n*s bytes -> hidden iff K >= s*R/(2*B*links).

    A quantized wire (``wire_bytes``) shrinks s: the transfer side of the
    hiding condition is priced at the on-wire element width (scales
    included) while compute stays at the tensor's own dtype — so shapes
    whose bf16 ring was only partially hidden can become fully hidden at
    half the wire bytes.
    """
    if axis_size <= 1:
        return OverlapPolicy("none", 1, 1.0, "single device on axis")
    links = 2 if (allow_bidir and axis_size % 2 == 0) else 1
    k_eff = k * axis_size if kind == "all_gather" else k
    elem_bytes = float(dtype_bytes) if wire_bytes is None else float(wire_bytes)
    threshold = cm.hiding_threshold_k(max(int(math.ceil(elem_bytes)), 1),
                                      hw, links=links)
    t_comp = cm.gemm_cost(m, n, k_eff, dtype_bytes, hw)
    shard_bytes = m * n * elem_bytes / axis_size
    t_comm = cm.transfer_cost(
        cm.ring_collective_bytes(shard_bytes, axis_size, kind), hw, links=links)
    if wire_bytes is not None:
        t_comm += 2.0 * cm.quantize_cost(
            cm.ring_collective_bytes(shard_bytes / elem_bytes, axis_size,
                                     kind),
            hw, src_bytes=dtype_bytes, wire_bytes=elem_bytes)
    if t_comm == 0.0:
        return OverlapPolicy("none", 1, 1.0, "no transfer")
    hidden = min(1.0, t_comp / t_comm)
    if t_comp < 20 * hw.remote_sync_s * axis_size:
        # Sync overhead of the decomposed schedule would dominate the GEMM —
        # the paper's "small problem sizes" regime where Flux/CUTLASS fall
        # below the non-overlapped baseline (Fig. 7). Stay bulk.
        return OverlapPolicy("none", 1, 0.0,
                             f"GEMM too small vs sync cost (t_comp={t_comp:.2e}s)")
    strategy = "ring_bidir" if links == 2 else "ring"
    reason = (f"K_eff={k_eff} vs hiding threshold {threshold} "
              f"({'fully' if k_eff >= threshold else 'partially'} hidden; "
              f"hidden_frac={hidden:.2f})")
    return OverlapPolicy(strategy, axis_size, hidden, reason)


def choose_gemm_chunks(m: int, n: int, k: int, *, axis_size: int, kind: str,
                       dtype_bytes: int = 2,
                       hw: cm.HardwareSpec = cm.TPU_V5E,
                       candidates=CHUNK_CANDIDATES,
                       wire_bytes: float | None = None,
                       fused: bool = False) -> ChunkSchedule:
    """Sub-chunk count + chunk dimension for a chunk-pipelined ring.

    Argmin of ``costmodel.chunk_pipeline_cost`` over ``candidates``: more
    chunks shrink the pipeline fill (the first chunk's exposed transfer) but
    pay per-chunk launch + sync overhead — on a calibrated spec both sides
    are priced on *measured* constants, so a mesh with expensive hops (the
    CPU-emulated one) resolves to 1 chunk while a real ICI mesh with cheap
    sync resolves to more. Call sites degrade the count to the chunked
    sub-shape's largest divisor via ``fit_chunks``.

    ``fused=True`` prices the single-kernel Pallas pipeline with
    ``costmodel.fused_pipeline_cost`` instead: one launch, VMEM-resident
    operands, local-sync chunk handoffs. Its argmin usually sits at a finer
    chunk count than the jax-level ring for the same shape, which is the
    point of the fused path. Fused kernels ship full precision, so
    ``wire_bytes`` is ignored there.
    """
    dim = GEMM_CHUNK_DIM[kind]
    if axis_size <= 1:
        return ChunkSchedule(1, dim, "single device on axis")
    best, best_t = 1, float("inf")
    for c in candidates:
        if fused:
            t = cm.fused_pipeline_cost(m, n, k, axis_size=axis_size,
                                       sub_chunks=c, dtype_bytes=dtype_bytes,
                                       kind=kind, hw=hw).total
        else:
            t = cm.chunk_pipeline_cost(m, n, k, axis_size=axis_size,
                                       sub_chunks=c, dtype_bytes=dtype_bytes,
                                       kind=kind, hw=hw,
                                       wire_bytes=wire_bytes).total
        if t < best_t:
            best, best_t = c, t
    model = "fused_pipeline_cost" if fused else "chunk_pipeline_cost"
    return ChunkSchedule(
        best, dim,
        f"argmin of {model} over {tuple(candidates)} "
        f"-> {best} (t={best_t:.2e}s)")


def a2a_chunk_axis(shape, split_axis: int, concat_axis: int,
                   n_chunks: int) -> tuple[int, int] | None:
    """(axis, fitted chunk count) for a chunked all-to-all, or None.

    Chunks are cut along a bystander dim (neither split nor concat) so the
    chunked op stays bit-identical to bulk. The requested count is validated
    against the *chunked sub-shape*: a dim that `n_chunks` does not divide
    degrades to its largest feasible divisor instead of rejecting the config
    (the old behavior — requiring the full dim to divide exactly — bulked
    legal chunked configs). Returns None only when no bystander dim can be
    split at all.
    """
    best: tuple[int, int] | None = None
    for d, extent in enumerate(shape):
        if d in (split_axis, concat_axis) or extent <= 1:
            continue
        c = fit_chunks(extent, n_chunks)
        if c > 1 and (best is None or c > best[1]):
            best = (d, c)
    return best


def choose_a2a_chunks(payload_bytes: float, *, axis_size: int,
                      downstream_compute_s: float,
                      hw: cm.HardwareSpec = cm.TPU_V5E,
                      shape=None, split_axis: int | None = None,
                      concat_axis: int | None = None) -> int:
    """Chunk count for a2a×compute overlap (Ulysses / MoE dispatch). More
    chunks -> finer overlap but more per-chunk launch+sync overhead; choose
    the largest count whose per-chunk overhead stays <10% of chunk time.

    When ``shape`` (with ``split_axis``/``concat_axis``) is given, the chosen
    count is additionally fitted to what the payload's bystander dims can
    actually split into — validation against the chunked sub-shape, so the
    policy never reports a chunking the op would have to bulk away.
    """
    t_comm = cm.transfer_cost(
        cm.ring_collective_bytes(payload_bytes, axis_size, "all_to_all"), hw)
    if t_comm <= 0:
        return 1
    best = 1
    for c in (2, 4, 8):
        per_chunk = max(t_comm, downstream_compute_s) / c
        if per_chunk > 10 * (hw.kernel_launch_s + hw.remote_sync_s):
            best = c
    if best > 1 and shape is not None:
        fit = a2a_chunk_axis(shape, split_axis, concat_axis, best)
        best = fit[1] if fit is not None else 1
    return best
