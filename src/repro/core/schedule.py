"""Overlap schedule selection (paper §3.1.3 "SM partitioning", TPU form).

On GPUs the knob is how many SMs to dedicate to communication; on TPU the ICI
DMA engines are free, so the knobs become (a) whether to decompose a bulk
collective into a ring pipeline at all, (b) the chunk count, and (c) whether
to use the bidirectional ring (2 link-pairs). This module picks them from the
paper's cost model — the analytic analogue of PK's runtime SM-split search.
"""

from __future__ import annotations

import dataclasses

from repro.core import costmodel as cm


@dataclasses.dataclass(frozen=True)
class OverlapPolicy:
    strategy: str            # "none" | "ring" | "ring_bidir"
    n_chunks: int
    hidden_fraction: float   # predicted fraction of T_comm hidden
    reason: str

    @property
    def enabled(self) -> bool:
        return self.strategy != "none"


def choose_gemm_collective(m: int, n: int, k: int, *, axis_size: int,
                           kind: str, dtype_bytes: int = 2,
                           hw: cm.HardwareSpec = cm.TPU_V5E,
                           allow_bidir: bool = True) -> OverlapPolicy:
    """Pick the schedule for a fused GEMM×collective.

    The paper's hiding condition (§3.1.3): per-ring-step compute must cover the
    per-step transfer. For GEMM+RS with N steps, step compute = 2*m*n*k/N
    flops, step transfer = (m/N)*n*s bytes -> hidden iff K >= s*R/(2*B*links).
    """
    if axis_size <= 1:
        return OverlapPolicy("none", 1, 1.0, "single device on axis")
    links = 2 if (allow_bidir and axis_size % 2 == 0) else 1
    k_eff = k * axis_size if kind == "all_gather" else k
    threshold = cm.hiding_threshold_k(dtype_bytes, hw, links=links)
    t_comp = cm.gemm_cost(m, n, k_eff, dtype_bytes, hw)
    shard_bytes = m * n * dtype_bytes / axis_size
    t_comm = cm.transfer_cost(
        cm.ring_collective_bytes(shard_bytes, axis_size, kind), hw, links=links)
    if t_comm == 0.0:
        return OverlapPolicy("none", 1, 1.0, "no transfer")
    hidden = min(1.0, t_comp / t_comm)
    if t_comp < 20 * hw.remote_sync_s * axis_size:
        # Sync overhead of the decomposed schedule would dominate the GEMM —
        # the paper's "small problem sizes" regime where Flux/CUTLASS fall
        # below the non-overlapped baseline (Fig. 7). Stay bulk.
        return OverlapPolicy("none", 1, 0.0,
                             f"GEMM too small vs sync cost (t_comp={t_comp:.2e}s)")
    strategy = "ring_bidir" if links == 2 else "ring"
    reason = (f"K_eff={k_eff} vs hiding threshold {threshold} "
              f"({'fully' if k_eff >= threshold else 'partially'} hidden; "
              f"hidden_frac={hidden:.2f})")
    return OverlapPolicy(strategy, axis_size, hidden, reason)


def choose_a2a_chunks(payload_bytes: float, *, axis_size: int,
                      downstream_compute_s: float,
                      hw: cm.HardwareSpec = cm.TPU_V5E) -> int:
    """Chunk count for a2a×compute overlap (Ulysses / MoE dispatch). More
    chunks -> finer overlap but more per-chunk launch+sync overhead; choose
    the largest count whose per-chunk overhead stays <10% of chunk time."""
    t_comm = cm.transfer_cost(
        cm.ring_collective_bytes(payload_bytes, axis_size, "all_to_all"), hw)
    if t_comm <= 0:
        return 1
    best = 1
    for c in (2, 4, 8):
        per_chunk = max(t_comm, downstream_compute_s) / c
        if per_chunk > 10 * (hw.kernel_launch_s + hw.remote_sync_s):
            best = c
    return best
