"""MoE expert-weight layout conversion: device-major PGL <-> logical.

Device-major (runtime) layout over a model axis of size M with ep·tp_ff = M:
  w1/w3: (M, E_loc, d, ff_loc)   rank r -> experts [(r//tp_ff)·E_loc, ...),
  w2:    (M, E_loc, ff_loc, d)          ff slice (r % tp_ff)·ff_loc.
Logical layout: (E, d, ff) / (E, ff, d).

Checkpoints store device-major; elastic restore onto a different mesh goes
device-major(M1) -> logical -> device-major(M2) on host (runtime/elastic.py).
"""

from __future__ import annotations

import numpy as np

from repro.core.moe import ep_tp_split


def dm_to_logical(w: np.ndarray, n_experts: int, *, w2: bool = False):
    """(M, E_loc, A, B) -> logical (E, d, ff) [or (E, ff, d) if w2]."""
    m, e_loc = w.shape[0], w.shape[1]
    ep, tp_ff = ep_tp_split(n_experts, m)
    assert e_loc == n_experts // ep, (w.shape, n_experts)
    if not w2:  # (M, E_loc, d, ff_loc)
        d, ff_loc = w.shape[2], w.shape[3]
        x = w.reshape(ep, tp_ff, e_loc, d, ff_loc)
        x = np.transpose(x, (0, 2, 3, 1, 4))          # (ep,E_loc,d,tp,ff_loc)
        return x.reshape(n_experts, d, tp_ff * ff_loc)
    ff_loc, d = w.shape[2], w.shape[3]
    x = w.reshape(ep, tp_ff, e_loc, ff_loc, d)
    x = np.transpose(x, (0, 2, 1, 3, 4))              # (ep,E_loc,tp,ff_loc,d)
    return x.reshape(n_experts, tp_ff * ff_loc, d)


def logical_to_dm(w: np.ndarray, model_size: int, *, w2: bool = False):
    """logical (E, d, ff) [or (E, ff, d)] -> (M, E_loc, ...)."""
    e = w.shape[0]
    ep, tp_ff = ep_tp_split(e, model_size)
    e_loc = e // ep
    if not w2:
        d, ff = w.shape[1], w.shape[2]
        ff_loc = ff // tp_ff
        x = w.reshape(ep, e_loc, d, tp_ff, ff_loc)
        x = np.transpose(x, (0, 3, 1, 2, 4))          # (ep,tp,E_loc,d,ff_loc)
        return x.reshape(model_size, e_loc, d, ff_loc)
    ff, d = w.shape[1], w.shape[2]
    ff_loc = ff // tp_ff
    x = w.reshape(ep, e_loc, tp_ff, ff_loc, d)
    x = np.transpose(x, (0, 2, 1, 3, 4))              # (ep,tp,E_loc,ff_loc,d)
    return x.reshape(model_size, e_loc, ff_loc, d)
