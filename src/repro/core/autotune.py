"""Empirical autotuner: measured correction factors for the §3.1.1 cost model.

The paper's thesis is that the analytic decomposition

    T_kernel = T_launch + max(T_comp, T_mem, T_comm) + T_non_overlap + T_sync

plus a runtime resource-split search picks the optimal overlap schedule.
``core/costmodel.py`` supplies the analytic half from datasheet constants;
this module closes the loop the way chunk-centric autotuners do (Syncopate,
arXiv 2601.20595): it **micro-benchmarks every registered comm backend on the
live mesh**, fits the measurements back into per-``HardwareSpec`` correction
factors, and persists them as a versioned JSON *calibration table* that
``CommContext(policy="measured")`` dispatches from.

Three layers:

``calibrate(mesh=...)``
    Runs the micro-benchmarks (link latency/bandwidth sweep, local GEMM
    efficiency probe, per-op × per-backend × shape-grid timings) and returns
    a ``CalibrationTable``.
``CalibrationTable``
    The persisted artifact: a ``Fingerprint`` of the machine it was measured
    on, fitted ``corrections`` (achieved ICI bandwidth, real
    ``remote_sync_s``, sustained GEMM efficiency, launch overhead) and the
    raw per-shape ``measurements``. ``table.spec(hw)`` yields a corrected
    ``HardwareSpec`` for the analytic model; ``table.best_backend(...)``
    answers dispatch queries directly from the measurements.
``find_table(hw_name)``
    Resolution used by ``CommContext``: the user cache
    (``~/.cache/repro/autotune-<hw>-<jax>.json``) first, then the in-repo
    seed tables under ``core/calibrations/`` (``tpu_v5e`` analytic seed,
    ``cpu_emulated`` measured on the 8-device emulated mesh). Tables whose
    fingerprint does not match the live process are ignored — the measured
    policy then degrades to analytic instead of dispatching from someone
    else's machine.

CLI (``python -m repro.autotune``): ``calibrate`` / ``show`` / ``diff``.
"""

from __future__ import annotations

import dataclasses
import functools
import json
import math
import os
import time
import warnings
from pathlib import Path
from typing import Any, Sequence

SCHEMA = "repro-autotune/v1"
SCHEMA_VERSION = 1


def island_key(name: str, op: str, dtype_bytes: int = 2) -> str:
    """Stable calibration-row key for one island's declared collective.

    Derived from the island's name plus the ``Comm`` coordinates that change
    which backend wins (op kind and element width — the layout-bearing
    parts); NOT from (m, n, k), which stays a lookup coordinate so nearby
    shapes can share rows. Two islands with different layouts/dtypes at the
    same (m, n, k) get different keys and can dispatch differently.
    """
    return f"{name}|{op}|b{int(dtype_bytes)}"


@dataclasses.dataclass(frozen=True)
class IslandSweep:
    """One island's coordinates for the ``calibrate(islands=...)`` sweep:
    the exact (op, m, n, k, dtype) its ``CommContext`` dispatch queries
    with, plus the key the measured rows are tagged with.

    GEMM×collective islands carry the global GEMM (m, n, k). ``all_to_all``
    islands (Ulysses re-sharding, MoE dispatch) additionally carry the local
    payload ``shape`` and split/concat axes; their (m, n, k) follow the
    ``CommContext.a2a_coords`` convention (payload elements, split extent,
    concat extent) so the sweep's rows land exactly where the a2a chunk
    policy queries."""

    island: str            # island_key(...) the rows carry
    op: str                # a GEMM_OPS member or "all_to_all"
    m: int
    n: int
    k: int
    dtype_bytes: int = 2
    shape: tuple[int, ...] | None = None    # a2a local payload shape
    split_axis: int | None = None
    concat_axis: int | None = None

#: ops the calibrator sweeps; mirrors comms.OP_BACKENDS keys it can measure.
GEMM_OPS = ("all_gather_matmul", "matmul_reduce_scatter", "matmul_all_reduce")
DEFAULT_OPS = GEMM_OPS + ("psum",)

#: per-device square sizes for the GEMM-op grid. "tiny" keeps test runtime
#: in check on the emulated mesh; "small" is the CLI default there; "full"
#: is sized for a real TPU slice.
GRIDS: dict[str, tuple[int, ...]] = {
    "tiny": (128,),
    "small": (128, 256, 512),
    "full": (256, 512, 1024, 2048, 4096),
}

_SEED_DIR = Path(__file__).parent / "calibrations"


def cache_dir() -> Path:
    env = os.environ.get("REPRO_CACHE_DIR")
    if env:
        return Path(env).expanduser()
    return Path("~/.cache/repro").expanduser()


# ---------------------------------------------------------------------------
# Fingerprint
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Fingerprint:
    """Identity of (spec being corrected, software, devices) for one table."""

    hw: str             # HardwareSpec.name the corrections apply to
    jax_version: str
    backend: str        # jax.default_backend() at measurement time
    device_kind: str
    n_devices: int

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Fingerprint":
        return cls(hw=d["hw"], jax_version=d["jax_version"],
                   backend=d["backend"], device_kind=d["device_kind"],
                   n_devices=int(d["n_devices"]))

    @staticmethod
    def _jax_mm(v: str) -> str:
        return ".".join(v.split(".")[:2])

    def compatible(self, live: "Fingerprint", *, strict: bool = False) -> bool:
        """Can a table stamped `self` serve a process that looks like `live`?

        Non-strict (the dispatch default) requires the same corrected spec,
        jax backend, device kind and jax major.minor — the quantities the
        corrections actually depend on. Strict additionally pins the exact
        jax version and device count (used by ``diff`` to refuse
        apples-to-oranges comparisons).
        """
        base = (self.hw == live.hw and self.backend == live.backend
                and self.device_kind == live.device_kind
                and self._jax_mm(self.jax_version)
                == self._jax_mm(live.jax_version))
        if not strict:
            return base
        return (base and self.jax_version == live.jax_version
                and self.n_devices == live.n_devices)


def live_fingerprint(hw_name: str, mesh=None) -> Fingerprint:
    """Fingerprint of the current process (and optionally one mesh)."""
    import jax

    from repro.launch.mesh import device_fingerprint

    d = device_fingerprint(mesh)
    return Fingerprint(hw=hw_name, jax_version=jax.__version__,
                       backend=d["backend"], device_kind=d["device_kind"],
                       n_devices=d["n_devices"])


def cache_path(fp: Fingerprint) -> Path:
    """``~/.cache/repro/autotune-<hw>-<jax>.json`` for this fingerprint."""
    return cache_dir() / f"autotune-{fp.hw}-{fp.jax_version}.json"


# ---------------------------------------------------------------------------
# CalibrationTable
# ---------------------------------------------------------------------------


@dataclasses.dataclass(eq=False)
class CalibrationTable:
    """Measured corrections + raw micro-benchmark rows for one machine.

    ``corrections`` maps ``HardwareSpec`` field names to fitted values
    (subset of: ``ici_bandwidth``, ``remote_sync_s``, ``gemm_efficiency``,
    ``kernel_launch_s``). ``measurements`` rows are
    ``{op, backend, axis_size, m, n, k, us}`` with (m, n, k) the *global*
    GEMM shape — the same coordinates ``CommContext.auto_gemm_backend``
    receives, so dispatch lookups need no shape translation.
    """

    fingerprint: Fingerprint
    corrections: dict[str, float]
    measurements: list[dict] = dataclasses.field(default_factory=list)
    version: int = SCHEMA_VERSION
    created: str = ""
    notes: str = ""

    # -- persistence -------------------------------------------------------

    def to_json(self) -> dict:
        return {
            "schema": SCHEMA,
            "version": self.version,
            "created": self.created,
            "notes": self.notes,
            "fingerprint": self.fingerprint.to_dict(),
            "corrections": dict(self.corrections),
            "measurements": list(self.measurements),
        }

    @classmethod
    def from_json(cls, doc: dict) -> "CalibrationTable":
        if doc.get("schema") != SCHEMA:
            raise ValueError(
                f"not a {SCHEMA} document (schema={doc.get('schema')!r})")
        return cls(fingerprint=Fingerprint.from_dict(doc["fingerprint"]),
                   corrections={k: float(v)
                                for k, v in doc["corrections"].items()},
                   measurements=list(doc.get("measurements", [])),
                   version=int(doc.get("version", SCHEMA_VERSION)),
                   created=doc.get("created", ""),
                   notes=doc.get("notes", ""))

    def save(self, path: str | Path) -> Path:
        path = Path(path).expanduser()
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_json(), indent=1, sort_keys=True))
        return path

    @classmethod
    def load(cls, path: str | Path) -> "CalibrationTable":
        return cls.from_json(json.loads(Path(path).expanduser().read_text()))

    # -- consumption -------------------------------------------------------

    def spec(self, base):
        """`base` HardwareSpec with this table's corrections applied."""
        return base.calibrated(**self.corrections)

    @staticmethod
    def _log_dist(m: int, n: int, k: int, row_m, row_n, row_k) -> float:
        return max(abs(math.log(max(m, 1) / max(row_m, 1))),
                   abs(math.log(max(n, 1) / max(row_n, 1))),
                   abs(math.log(max(k, 1) / max(row_k, 1))))

    def _rows_for(self, op: str, *, island: str | None,
                  axis_size: int | None, dtype_bytes: int | None,
                  island_only: bool = False):
        """Measurement rows of `op` usable for a dispatch query, in island
        precedence order: rows tagged with the caller's island key first,
        then the global (untagged) rows as a fallback tier. Rows tagged with
        a *different* island never match — another island's layout is not
        evidence about this one. Yields at most two non-empty tiers;
        ``island_only`` drops the global fallback tier (callers that must
        not mix measurements across tiers, e.g. a hidden-fraction delta)."""
        tiers: list[list[dict]] = [[], []]
        for row in self.measurements:
            if row["op"] != op:
                continue
            if axis_size is not None and row["axis_size"] != axis_size:
                continue
            if dtype_bytes is not None:
                row_b = row.get("dtype_bytes")
                if row_b is None:
                    # Legacy rows predate the dtype axis. They were measured
                    # on full-precision payloads, so they may serve any
                    # full-precision query — but never a quantized-wire
                    # (sub-2-byte) one: a b1 lookup resolving onto a
                    # bf16-era measurement would report the unquantized
                    # ring's time as the int8 ring's.
                    if dtype_bytes < 2:
                        continue
                elif row_b != dtype_bytes:
                    continue
            tag = row.get("island")
            if tag is None:
                tiers[1].append(row)
            elif island is not None and tag == island:
                tiers[0].append(row)
        if island_only and island is not None:
            tiers = tiers[:1]
        return [t for t in tiers if t]

    def _argmin_at_nearest(self, tier, m: int, n: int, k: int,
                           key_of_row, max_ratio: float):
        """argmin of ``us`` over ``key_of_row(row)`` groups at the single
        nearest (m, n, k) grid point where >= 2 distinct keys were measured
        (one key is not a comparison), or None past ``max_ratio`` log
        distance. Shared by ``best_backend`` (key = backend) and
        ``best_chunks`` (key = n_chunks) so their distance/tie-break/
        extrapolation rules can never diverge."""
        pts: dict[tuple, dict] = {}
        for row in tier:
            key = key_of_row(row)
            if key is None:
                continue
            point = (row["m"], row["n"], row["k"])
            timed = pts.setdefault(point, {})
            timed[key] = min(timed.get(key, math.inf), float(row["us"]))
        best_point, best_d = None, math.inf
        for point, timed in pts.items():
            if len(timed) < 2:
                continue
            d = self._log_dist(m, n, k, *point)
            if d < best_d:
                best_point, best_d = point, d
        if best_point is None or best_d > math.log(max_ratio):
            return None
        timed = pts[best_point]
        return min(timed, key=timed.get)

    def measured_us(self, op: str, backend: str, m: int, n: int, k: int,
                    *, axis_size: int | None = None,
                    dtype_bytes: int | None = None,
                    island: str | None = None, island_only: bool = False,
                    max_ratio: float = 4.0) -> float | None:
        """Interpolated measurement for (op, backend) at the nearest grid
        point, or None when the closest point is further than ``max_ratio``
        away in every-dimension log distance (extrapolating a microbench
        across >4x in shape is how analytic models go wrong in the first
        place — refuse, and let the caller fall back to analytic).

        ``dtype_bytes`` filters to rows measured at that element width: a
        bf16 ring's measured win (half the bytes of an f32-promoted bulk
        collective) does not transfer to an f32 payload. Rows without a
        recorded dtype (older tables) match any full-precision width but
        never a quantized-wire query (``dtype_bytes < 2``). ``island`` prefers
        rows calibrated for that island key (``calibrate --per-island``)
        and falls back to the global rows (``island_only`` disables the
        fallback).
        """
        for tier in self._rows_for(op, island=island, axis_size=axis_size,
                                   dtype_bytes=dtype_bytes,
                                   island_only=island_only):
            best, best_d = None, math.inf
            for row in tier:
                if row["backend"] != backend:
                    continue
                d = self._log_dist(m, n, k, row["m"], row["n"], row["k"])
                # equal-distance tie (e.g. chunk-count variants at one grid
                # point): the backend's best configuration represents it
                if d < best_d or (best is not None and d == best_d
                                  and float(row["us"]) < float(best["us"])):
                    best, best_d = row, d
            if best is not None and best_d <= math.log(max_ratio):
                return float(best["us"])
        return None

    def best_backend(self, op: str, m: int, n: int, k: int, *,
                     allowed: Sequence[str],
                     axis_size: int | None = None,
                     dtype_bytes: int | None = None,
                     island: str | None = None,
                     max_ratio: float = 4.0) -> str | None:
        """argmin over measured backends of `op` near (m, n, k), restricted
        to `allowed` (the caller's shape/VMEM-feasible set).

        Backends are only compared **at a single shared grid point** — the
        nearest (m, n, k) where at least two allowed backends were measured.
        Comparing each backend's own nearest point would let a backend the
        sweep only captured at a much smaller shape "win" on shape size
        rather than speed (a skipped grid point would then pin the slower
        backend). ``island`` restricts the comparison to that island's
        calibrated rows first (two islands with different layouts can then
        resolve differently at the same shape), falling back to the global
        rows. None when no shared point is within ``max_ratio`` log
        distance — the caller falls back to the analytic policy."""
        for tier in self._rows_for(op, island=island, axis_size=axis_size,
                                   dtype_bytes=dtype_bytes):
            best = self._argmin_at_nearest(
                tier, m, n, k,
                lambda r: r["backend"] if r["backend"] in allowed else None,
                max_ratio)
            if best is not None:
                return best
        return None

    def best_chunks(self, op: str, backend: str, m: int, n: int, k: int, *,
                    axis_size: int | None = None,
                    dtype_bytes: int | None = None,
                    island: str | None = None,
                    max_ratio: float = 4.0) -> int | None:
        """Measured sub-chunk count for a chunk-pipelined ring: the argmin-us
        ``n_chunks`` among this (op, backend)'s rows at the nearest grid
        point where at least two distinct chunk counts were measured (one
        count is not a comparison — return None and let the analytic chunk
        scheduler decide). Same island-first/global-fallback precedence as
        ``best_backend``."""
        for tier in self._rows_for(op, island=island, axis_size=axis_size,
                                   dtype_bytes=dtype_bytes):
            best = self._argmin_at_nearest(
                tier, m, n, k,
                lambda r: (int(r.get("n_chunks", 1) or 1)
                           if r["backend"] == backend else None),
                max_ratio)
            if best is not None:
                return best
        return None

    def ops_covered(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for row in self.measurements:
            out[row["op"]] = out.get(row["op"], 0) + 1
        return out


# ---------------------------------------------------------------------------
# Table resolution (cache -> in-repo seeds), used by CommContext.
# ---------------------------------------------------------------------------

_warned: set[str] = set()


def _warn_once(key: str, msg: str) -> None:
    if key not in _warned:
        _warned.add(key)
        warnings.warn(msg, stacklevel=3)


def _candidate_paths(fp: Fingerprint) -> list[Path]:
    paths = [cache_path(fp)]
    if _SEED_DIR.is_dir():
        paths.extend(sorted(_SEED_DIR.glob("*.json")))
    return paths


def find_table(hw_name: str) -> CalibrationTable | None:
    """The calibration table ``policy="measured"|"auto"`` dispatches from.

    Search order: the user cache for this (hw, jax) pair, then the checked-in
    seed tables. The first table whose fingerprint is compatible with the
    live process wins; incompatible and unreadable tables are skipped (the
    latter with a one-shot warning naming the file).
    """
    live = live_fingerprint(hw_name)
    for path in _candidate_paths(live):
        if not path.is_file():
            continue
        try:
            table = CalibrationTable.load(path)
        except (ValueError, KeyError, json.JSONDecodeError) as e:
            _warn_once(f"unreadable:{path}",
                       f"ignoring unreadable calibration table {path}: {e}")
            continue
        if table.fingerprint.compatible(live):
            return table
    return None


def clear_caches() -> None:
    """Reset memoized lookups (tests; after writing a new cache table)."""
    _load_cached.cache_clear()
    _find_cached.cache_clear()
    _live_cached.cache_clear()
    _warned.clear()


_load_cached = functools.lru_cache(maxsize=32)(CalibrationTable.load)
_find_cached = functools.lru_cache(maxsize=8)(find_table)
# the process-wide fingerprint never changes within a process; memoized so
# every policy-routed collective doesn't re-read jax.devices() at trace time
_live_cached = functools.lru_cache(maxsize=8)(live_fingerprint)


def resolve_table(calibration: Any, hw_name: str,
                  policy: str) -> CalibrationTable | None:
    """Map a ``CommContext`` (policy, calibration) pair to a usable table.

    ``calibration`` may be a ``CalibrationTable``, a path, or None (search
    cache + seeds). Under ``policy="measured"`` a missing or
    fingerprint-mismatched table warns once and returns None — the context
    then runs the analytic policy, which is always available; under
    ``"auto"`` the same fallback is silent by design.
    """
    if policy == "analytic":
        return None
    if policy not in ("measured", "auto"):
        raise ValueError(
            f"unknown comm policy {policy!r}; expected 'analytic', "
            "'measured' or 'auto'")
    table: CalibrationTable | None
    if isinstance(calibration, CalibrationTable):
        table = calibration
    elif calibration is not None:
        try:
            table = _load_cached(str(calibration))
        except (OSError, ValueError, KeyError, json.JSONDecodeError) as e:
            # an EXPLICITLY configured path that doesn't load warns under
            # "auto" too — auto's silence covers implicit search misses,
            # not a broken user-supplied argument
            _warn_once(f"load:{calibration}",
                       f"calibration table {calibration!r} could not be "
                       f"loaded ({e}); falling back to analytic costs")
            return None
    else:
        table = _find_cached(hw_name)
        if table is None:
            if policy == "measured":
                _warn_once(f"missing:{hw_name}",
                           "policy='measured' but no calibration table found "
                           f"for hw={hw_name!r} (searched "
                           f"{cache_path(live_fingerprint(hw_name))} and "
                           f"{_SEED_DIR}); run `python -m repro.autotune "
                           "calibrate`; falling back to analytic costs")
            return None
    live = _live_cached(hw_name)
    if not table.fingerprint.compatible(live):
        # like the unreadable-path case above: an explicitly supplied table
        # that gets rejected warns under "auto" too — only tables found by
        # the implicit cache/seed search are silently skipped there
        if policy == "measured" or calibration is not None:
            _warn_once(f"fingerprint:{hw_name}:{table.fingerprint}",
                       f"calibration table fingerprint {table.fingerprint} "
                       f"does not match this process {live}; falling back "
                       "to analytic costs")
        return None
    if policy == "measured":
        # fingerprints match, but measurements can rot in place (driver or
        # thermal changes the fingerprint cannot see): surface age once —
        # the table is still USED, staleness is a warning, not a rejection
        age = table_age_days(table)
        if age is not None and age > STALE_AFTER_DAYS:
            _warn_once(f"stale:{hw_name}",
                       f"calibration table for hw={hw_name!r} is "
                       f"{age:.0f} days old (> {STALE_AFTER_DAYS:.0f}); "
                       "its measurements may no longer reflect this machine "
                       "— re-run `python -m repro.autotune calibrate`, or "
                       "audit with `python -m repro.autotune check`")
    return table


# ---------------------------------------------------------------------------
# Staleness audit (the `python -m repro.autotune check` backend)
# ---------------------------------------------------------------------------

#: age past which a fingerprint-compatible table warns under
#: ``policy="measured"`` (and fails ``repro.autotune check``)
STALE_AFTER_DAYS = 30.0


def table_age_days(table: CalibrationTable) -> float | None:
    """Days since the table's ``created`` stamp; None when unparseable."""
    if not table.created:
        return None
    try:
        t = time.mktime(time.strptime(table.created, "%Y-%m-%dT%H:%M:%S"))
    except ValueError:
        return None
    return max(0.0, (time.time() - t) / 86400.0)


def staleness(table: CalibrationTable, *,
              max_age_days: float = STALE_AFTER_DAYS,
              drift_threshold: float = 0.5, probe: bool = True,
              reps: int = 3) -> list[str]:
    """Reasons this table should be re-calibrated; empty = looks fresh.

    Two independent checks: the ``created`` stamp's age against
    ``max_age_days``, and (with ``probe``) a quick spot re-measurement of
    the machine-local corrections — kernel launch overhead and sustained
    GEMM efficiency — against the table's fitted values at
    ``drift_threshold`` relative drift. The spot probe runs two tiny jitted
    micro-benchmarks, not a re-calibration.
    """
    msgs: list[str] = []
    age = table_age_days(table)
    if age is None:
        msgs.append("table has no parseable 'created' timestamp — age "
                    "cannot be checked")
    elif age > max_age_days:
        msgs.append(f"table is {age:.0f} days old "
                    f"(threshold {max_age_days:.0f})")
    if not probe:
        return msgs
    from repro.core import costmodel as cm

    hw = getattr(cm, table.fingerprint.hw.upper(), cm.TPU_V5E)
    probes = {"kernel_launch_s": _measure_launch(reps),
              "gemm_efficiency": _measure_gemm_efficiency(hw, reps)}
    for key, now in probes.items():
        old = table.corrections.get(key)
        if not old:
            continue
        drift = abs(now - old) / old
        if drift > drift_threshold:
            msgs.append(f"{key} drifted {drift * 100:.0f}% vs spot probe "
                        f"(table {old:.3g}, now {now:.3g}; threshold "
                        f"{drift_threshold * 100:.0f}%)")
    return msgs


# ---------------------------------------------------------------------------
# Micro-benchmarks
# ---------------------------------------------------------------------------


def _timeit(fn, *args, reps: int = 3, warmup: int = 1) -> float:
    """Median seconds per call (same protocol as benchmarks/common.timeit,
    duplicated here so `src/` never imports the benchmarks package)."""
    import jax

    out = None
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _fit_line(xs: Sequence[float], ys: Sequence[float]) -> tuple[float, float]:
    """Least-squares y = a + b*x; returns (a, b)."""
    n = len(xs)
    mx = sum(xs) / n
    my = sum(ys) / n
    sxx = sum((x - mx) ** 2 for x in xs)
    if sxx == 0.0:
        return my, 0.0
    b = sum((x - mx) * (y - my) for x, y in zip(xs, ys)) / sxx
    return my - b * mx, b


def _measure_link(mesh, axis_name: str, reps: int) -> tuple[float, float]:
    """(achieved bytes/s per link-direction, per-hop overhead seconds).

    Times a one-hop ``ppermute`` ring rotation over a payload sweep and fits
    t = overhead + bytes/B. The intercept is everything the analytic model
    books under T_launch + T_sync for one remote hop; the slope is the
    *achieved* link bandwidth, contention included.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.core.comms import ring_shift

    sizes = (2 ** 14, 2 ** 18, 2 ** 22)        # 16 KiB .. 4 MiB per device
    xs, ys = [], []
    for nbytes in sizes:
        n_el = nbytes // 4
        x = jnp.ones((mesh.shape[axis_name], n_el), jnp.float32)
        f = jax.jit(compat.shard_map(
            lambda t: ring_shift(t, axis_name),
            mesh=mesh, in_specs=P(axis_name), out_specs=P(axis_name),
            check_vma=False))
        t = _timeit(f, x, reps=reps)
        xs.append(float(nbytes))
        ys.append(t)
    overhead, inv_bw = _fit_line(xs, ys)
    bw = (1.0 / inv_bw) if inv_bw > 0 else xs[-1] / max(ys[-1], 1e-12)
    return max(bw, 1.0), max(overhead, 1e-9)


def _measure_gemm_efficiency(hw, reps: int) -> float:
    """Sustained local-GEMM fraction of ``hw.peak_flops_bf16``.

    Probed in bf16 — the dtype the peak is quoted for and the calibrated
    ops run in; an f32 probe would understate the MXU severalfold on real
    hardware. On the CPU-emulated mesh the result is far below 1.0 — that
    is the point: the measured policy then prices compute at what the
    machine actually delivers instead of the datasheet number.
    """
    import jax
    import jax.numpy as jnp

    n = 512
    a = jnp.ones((n, n), jnp.bfloat16)
    f = jax.jit(lambda a: a @ a)
    t = _timeit(f, a, reps=reps)
    achieved = 2.0 * n ** 3 / max(t, 1e-12)
    return min(max(achieved / hw.peak_flops_bf16, 1e-9), 1.0)


def _measure_launch(reps: int) -> float:
    """Dispatch overhead of a trivial jitted op (T_launch analogue)."""
    import jax
    import jax.numpy as jnp

    x = jnp.ones((8,), jnp.float32)
    f = jax.jit(lambda t: t + 1.0)
    return max(_timeit(f, x, reps=reps), 1e-9)


def _gemm_case(op: str, nsz: int, n_dev: int):
    """(global operand arrays, in_specs, out_specs, (m, n, k)) for one grid
    point of `op`, mirroring the paper-figure shapes in benchmarks/.

    (m, n, k) MUST be the exact coordinates ``CommContext``'s dispatch
    queries with (``auto_gemm_backend``'s arguments): for AG+GEMM that is
    the gathered GEMM's global m (= the sharded array's global rows,
    m_loc * n_dev); for RS/AR it is (global m, n, local k). Rows stored in
    any other coordinate system would never be found by ``measured_us``'s
    4x-log-distance lookup and the measured policy would silently never
    activate (tests pin this coupling).
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    if op == "all_gather_matmul":
        x = jax.random.normal(jax.random.PRNGKey(0), (nsz, nsz // 4),
                              jnp.bfloat16)
        w = jax.random.normal(jax.random.PRNGKey(1), (nsz // 4, nsz // 4),
                              jnp.bfloat16)
        # sharded rows: m_loc = nsz / n_dev, so dispatch sees m = nsz
        return ((x, w), (P("x"), P()), P(), (nsz, nsz // 4, nsz // 4))
    x = jax.random.normal(jax.random.PRNGKey(0), (nsz, n_dev * (nsz // 8)),
                          jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1),
                          (n_dev * (nsz // 8), nsz // 4), jnp.bfloat16)
    out = (P("x", None) if op == "matmul_reduce_scatter" else P())
    return ((x, w), (P(None, "x"), P("x", None)), out,
            (nsz, nsz // 4, nsz // 8))


def _feasible(op: str, backend: str, n_dev: int, nsz: int,
              available: Sequence[str]) -> bool:
    if backend not in available:
        return False
    if backend == "ring_bidir":
        # the bidirectional ring splits the local shard across the two
        # directions: needs at least 2 local rows (uneven splits are fine)
        # and an even device count — on an odd axis the impl silently runs
        # the unidirectional ring, which would mislabel the measured rows
        return (op == "all_gather_matmul" and n_dev % 2 == 0
                and nsz // n_dev >= 2)
    if backend == "fused":
        # interpret-mode fused kernels are orders of magnitude slower than
        # the thing they emulate; timing them off-TPU would poison the table
        import jax
        return jax.default_backend() == "tpu"
    return True


def _sweep_gemm_ops(ctx, mesh, axis_name: str, sizes: Sequence[int],
                    reps: int, log, *, dtype_bytes: int = 2) -> list[dict]:
    """One pass over the GEMM-op grid at one wire width.

    ``dtype_bytes=2`` is the classic bf16 sweep. ``dtype_bytes=1`` measures
    the *int8 wire*: operands stay bf16 but ring backends run with
    ``wire="int8"`` (quantize → int8+scales ring → dequantize), and the bulk
    baseline is timed unquantized but recorded under the same ``b1`` width —
    so a ``dtype_bytes=1`` dispatch query compares the int8 ring against the
    full-precision bulk it would actually be replacing. The fused backend is
    excluded at b1 (fused kernels ship full precision; timing one under a
    quantized-wire label would poison the table)."""
    import jax
    from functools import partial

    from repro import compat

    n_dev = mesh.shape[axis_name]
    wire = "int8" if dtype_bytes == 1 else None
    rows: list[dict] = []
    for op in GEMM_OPS:
        avail = ctx.available_backends(op)
        for nsz in sizes:
            args, in_specs, out_specs, (m, n, k) = _gemm_case(op, nsz, n_dev)
            for be in ("bulk", "ring", "ring_bidir", "fused"):
                if be == "fused" and wire is not None:
                    continue
                if not _feasible(op, be, n_dev, nsz, avail):
                    continue
                # the global grid pins the classic 1-chunk ring; chunk-count
                # variants are swept per island (calibrate --per-island)
                fn = jax.jit(compat.shard_map(
                    partial(getattr(ctx, op), backend=be, n_chunks=1,
                            wire=wire),
                    mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    check_vma=False))
                try:
                    t = _timeit(fn, *args, reps=reps)
                except Exception as e:  # noqa: BLE001 — skip, don't abort
                    log(f"  {op}/{be}/N={nsz}/b{dtype_bytes}: SKIPPED "
                        f"({type(e).__name__})")
                    continue
                rows.append({"op": op, "backend": be, "axis_size": n_dev,
                             "m": m, "n": n, "k": k,
                             "dtype_bytes": dtype_bytes,
                             "n_chunks": 1, "us": t * 1e6})
                log(f"  {op}/{be}/N={nsz}/b{dtype_bytes}: {t * 1e6:.1f} us")
    return rows


#: sub-chunk counts the per-island sweep measures for each ring backend.
ISLAND_CHUNK_SWEEP = (1, 2, 4)

#: sub-chunk counts the per-island sweep measures for the fused backend —
#: one more octave than the rings: in-kernel chunk handoffs are a scalar-core
#: descriptor issue + local semaphore wait, so the fused pipeline tolerates
#: (and usually prefers) finer chunking (``costmodel.fused_pipeline_cost``).
ISLAND_FUSED_CHUNK_SWEEP = (1, 2, 4, 8)


def island_sweep_cases(sw: IslandSweep, n_dev: int,
                       available: Sequence[str]) -> list[tuple[str, int]]:
    """The (backend, n_chunks) grid the per-island GEMM sweep times for one
    island — exposed as a pure function so the fused-inclusion rules are
    unit-testable without running a calibration.

    * ``bulk`` is always timed, at 1 chunk (no pipeline to sub-chunk);
    * ``ring`` sweeps :data:`ISLAND_CHUNK_SWEEP`; ``ring_bidir`` joins it for
      AG×GEMM on an even axis with >= 2 local rows;
    * ``fused`` sweeps :data:`ISLAND_FUSED_CHUNK_SWEEP` when feasible (real
      TPU only — interpret-mode timings would poison the table) and the
      sweep is full-precision: fused kernels do not ship a quantized wire,
      so b1 sweeps exclude them the same way the global grid does.
    """
    backends = ["bulk", "ring"]
    if (sw.op == "all_gather_matmul" and sw.m // n_dev >= 2
            and n_dev % 2 == 0):
        backends.append("ring_bidir")
    if sw.dtype_bytes != 1 and _feasible(sw.op, "fused", n_dev, sw.m,
                                         available):
        backends.append("fused")
    cases: list[tuple[str, int]] = []
    for be in backends:
        if be == "bulk":
            counts: Sequence[int] = (1,)
        elif be == "fused":
            counts = ISLAND_FUSED_CHUNK_SWEEP
        else:
            counts = ISLAND_CHUNK_SWEEP
        cases += [(be, c) for c in counts]
    return cases


def _sweep_a2a_island(ctx, mesh, axis_name: str, sw: IslandSweep,
                      reps: int, log) -> list[dict]:
    """Measure bulk vs chunked all-to-all at one island's exact payload
    shape, tagging rows with its key — the a2a analogue of the GEMM island
    sweep. Rows are stored under the ``CommContext.a2a_coords`` (m, n, k)
    so ``a2a_chunk_schedule`` finds them."""
    import jax
    import jax.numpy as jnp
    from functools import partial

    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.core.schedule import a2a_chunk_axis

    n_dev = mesh.shape[axis_name]
    shape = tuple(sw.shape)
    sa, ca = sw.split_axis, sw.concat_axis
    if shape[sa] % n_dev != 0:
        log(f"  island {sw.island}: a2a split dim {shape[sa]} not divisible "
            f"by {n_dev}-device axis, skipped")
        return []
    dtype = jnp.bfloat16 if sw.dtype_bytes == 2 else jnp.float32
    gshape = list(shape)
    gshape[ca] *= n_dev                     # concat dim sharded on input
    x = jax.random.normal(jax.random.PRNGKey(0), tuple(gshape), dtype)
    in_specs = P(*[axis_name if d == ca else None for d in range(len(shape))])
    out_specs = P(*[axis_name if d == sa else None for d in range(len(shape))])
    cases = [("bulk", 1)]
    seen = {1}
    for c in ISLAND_CHUNK_SWEEP[1:]:
        fit = a2a_chunk_axis(shape, sa, ca, c)
        if fit is None or fit[1] in seen:
            continue
        seen.add(fit[1])
        cases.append(("chunked", fit[1]))
    rows: list[dict] = []
    for be, c in cases:
        fn = jax.jit(compat.shard_map(
            partial(ctx.all_to_all, split_axis=sa, concat_axis=ca,
                    backend=be, n_chunks=c),
            mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False))
        try:
            t = _timeit(fn, x, reps=reps)
        except Exception as e:  # noqa: BLE001 — skip, don't abort
            log(f"  {sw.island}/{be}/c={c}: SKIPPED ({type(e).__name__})")
            continue
        rows.append({"op": "all_to_all", "backend": be, "axis_size": n_dev,
                     "m": sw.m, "n": sw.n, "k": sw.k,
                     "dtype_bytes": sw.dtype_bytes, "n_chunks": c,
                     "island": sw.island, "us": t * 1e6})
        log(f"  {sw.island}/{be}/c={c}: {t * 1e6:.1f} us")
    return rows


def _sweep_islands(ctx, mesh, axis_name: str, sweeps: Sequence[IslandSweep],
                   reps: int, log) -> list[dict]:
    """Measure every feasible backend × chunk count at each island's exact
    declared coordinates, tagging the rows with the island key so
    ``CommContext(island=...)`` dispatch (and ``Island.plan()``) prefers
    them over the generic shape grid."""
    import jax
    import jax.numpy as jnp
    from functools import partial

    from jax.sharding import PartitionSpec as P

    from repro import compat

    n_dev = mesh.shape[axis_name]
    rows: list[dict] = []
    for sw in sweeps:
        if sw.op == "all_to_all" and sw.shape is not None:
            rows += _sweep_a2a_island(ctx, mesh, axis_name, sw, reps, log)
            continue
        if sw.op not in GEMM_OPS:
            log(f"  island {sw.island}: op {sw.op} not sweepable, skipped")
            continue
        if sw.m % n_dev != 0:
            log(f"  island {sw.island}: m={sw.m} not divisible by "
                f"{n_dev}-device axis, skipped")
            continue
        # dtype_bytes=1 is the int8-wire sweep: bf16 operands, ring backends
        # run quantized (wire="int8"), bulk timed unquantized under the same
        # b1 key — the comparison measured dispatch actually makes.
        wire = "int8" if sw.dtype_bytes == 1 else None
        dtype = jnp.float32 if sw.dtype_bytes == 4 else jnp.bfloat16
        if sw.op == "all_gather_matmul":
            x = jax.random.normal(jax.random.PRNGKey(0), (sw.m, sw.k), dtype)
            w = jax.random.normal(jax.random.PRNGKey(1), (sw.k, sw.n), dtype)
            in_specs, out_specs = (P(axis_name), P()), P()
        else:
            x = jax.random.normal(jax.random.PRNGKey(0),
                                  (sw.m, n_dev * sw.k), dtype)
            w = jax.random.normal(jax.random.PRNGKey(1),
                                  (n_dev * sw.k, sw.n), dtype)
            in_specs = (P(None, axis_name), P(axis_name, None))
            out_specs = (P(axis_name, None)
                         if sw.op == "matmul_reduce_scatter" else P())
        for be, c in island_sweep_cases(sw, n_dev,
                                        ctx.available_backends(sw.op)):
            fused = be == "fused"
            fn = jax.jit(compat.shard_map(
                partial(getattr(ctx, sw.op), backend=be, n_chunks=c,
                        wire=None if fused else wire),
                mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_vma=False))
            try:
                t = _timeit(fn, x, w, reps=reps)
            except Exception as e:  # noqa: BLE001 — skip, don't abort
                log(f"  {sw.island}/{be}/c={c}: SKIPPED "
                    f"({type(e).__name__})")
                continue
            rows.append({"op": sw.op, "backend": be, "axis_size": n_dev,
                         "m": sw.m, "n": sw.n, "k": sw.k,
                         "dtype_bytes": sw.dtype_bytes, "n_chunks": c,
                         "island": sw.island, "us": t * 1e6})
            log(f"  {sw.island}/{be}/c={c}: {t * 1e6:.1f} us")
    return rows


def _sweep_psum(ctx, mesh, axis_name: str, sizes: Sequence[int],
                reps: int, log) -> list[dict]:
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat

    n_dev = mesh.shape[axis_name]
    rows: list[dict] = []
    for nsz in sizes:
        x = jnp.ones((n_dev * n_dev, nsz), jnp.bfloat16)
        for be in ("bulk", "ring"):
            fn = jax.jit(compat.shard_map(
                lambda t, be=be: ctx.psum(t[0], backend=be)[None],
                mesh=mesh, in_specs=P(axis_name), out_specs=P(axis_name),
                check_vma=False))
            xs = x.reshape(n_dev, n_dev, nsz)
            try:
                t = _timeit(fn, xs, reps=reps)
            except Exception as e:  # noqa: BLE001
                log(f"  psum/{be}/N={nsz}: SKIPPED ({type(e).__name__})")
                continue
            rows.append({"op": "psum", "backend": be, "axis_size": n_dev,
                         "m": n_dev, "n": nsz, "k": 1, "dtype_bytes": 2,
                         "us": t * 1e6})
            log(f"  psum/{be}/N={nsz}: {t * 1e6:.1f} us")
    return rows


def calibrate(mesh=None, *, axis_name: str = "x", hw=None,
              grid: str | Sequence[int] = "small", reps: int = 3,
              notes: str = "", verbose: bool = False,
              islands: Sequence[IslandSweep] = (),
              dtypes: Sequence[int] = (2,)) -> CalibrationTable:
    """Run the full micro-benchmark suite and fit a ``CalibrationTable``.

    With ``mesh=None`` a 1-D mesh over every visible device is built. The
    returned table is NOT saved; callers pick the destination
    (``table.save(autotune.cache_path(table.fingerprint))`` for the user
    cache the measured policy searches). ``islands`` adds per-island sweeps
    (backend × chunk count at each island's exact declared coordinates,
    rows tagged with the island key) — the CLI derives them from a model
    config via ``calibrate --per-island``. ``dtypes`` is the wire-width
    axis: each entry runs the GEMM-op grid once at that element width
    (``2`` = bf16; ``1`` = int8 wire — ring backends quantized, bulk
    baseline unquantized but recorded under ``b1`` — so measured dispatch
    can conclude int8-ring beats bf16-bulk from the table alone).
    """
    from repro.core import costmodel as cm
    from repro.core.comms import CommContext
    from repro.launch.mesh import make_mesh

    hw = hw if hw is not None else cm.TPU_V5E
    log = print if verbose else (lambda *_: None)
    if mesh is None:
        import jax
        mesh = make_mesh((len(jax.devices()),), (axis_name,))
    sizes = GRIDS[grid] if isinstance(grid, str) else tuple(grid)
    # Pin each microbench's backend explicitly (policy stays analytic here —
    # a measured policy would consult the very table being built).
    ctx = CommContext(axis_name=axis_name, mesh=mesh, hw=hw)

    log(f"calibrating on {mesh.shape} mesh, grid={sizes} ...")
    bw, hop_overhead = _measure_link(mesh, axis_name, reps)
    log(f"  link: {bw / 1e9:.3f} GB/s achieved, "
        f"{hop_overhead * 1e6:.1f} us/hop overhead")
    eff = _measure_gemm_efficiency(hw, reps)
    log(f"  gemm: {eff:.2e} of {hw.name} peak sustained")
    launch = _measure_launch(reps)
    log(f"  launch: {launch * 1e6:.1f} us")

    rows: list[dict] = []
    for db in dtypes:
        rows += _sweep_gemm_ops(ctx, mesh, axis_name, sizes, reps, log,
                                dtype_bytes=int(db))
    rows += _sweep_psum(ctx, mesh, axis_name, sizes, reps, log)
    if islands:
        log(f"per-island sweep ({len(tuple(islands))} islands) ...")
        rows += _sweep_islands(ctx, mesh, axis_name, tuple(islands), reps,
                               log)

    return CalibrationTable(
        fingerprint=live_fingerprint(hw.name, mesh),
        corrections={
            "ici_bandwidth": bw,
            "remote_sync_s": hop_overhead,
            "gemm_efficiency": eff,
            "kernel_launch_s": launch,
        },
        measurements=rows,
        created=time.strftime("%Y-%m-%dT%H:%M:%S"),
        notes=notes,
    )
