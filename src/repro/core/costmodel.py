"""ParallelKittens cost model (paper §3.1.1), adapted to TPU v5e.

    T_kernel = T_launch + max(T_comp, T_mem, T_comm) + T_non_overlap + T_sync

Each T is derived from work sizes and achievable bandwidths. The model drives
two things in this framework:

  * the overlap *schedule* search (``core/schedule.py``) — e.g. the paper's
    communication-hiding condition ``K >= s*R/(2*B)`` (paper §3.1.3), re-derived
    for ICI bandwidth;
  * the roofline report (``roofline/model.py``) — the same three terms computed
    from the *compiled* HLO instead of analytically.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Per-chip hardware constants."""

    name: str
    peak_flops_bf16: float      # FLOP/s
    hbm_bandwidth: float        # bytes/s
    ici_bandwidth: float        # bytes/s per link direction
    ici_links: int              # usable ICI links per chip (2-D torus: 4)
    hbm_bytes: float            # HBM capacity in bytes
    vmem_bytes: float           # VMEM per core
    # Empirical-ish overheads (used for T_launch / T_sync terms).
    kernel_launch_s: float = 2e-6
    local_sync_s: float = 64e-9       # paper: intra-SM mbarrier ~64 ns
    remote_sync_s: float = 1.5e-6     # cross-chip semaphore signal visibility
    # Fraction of peak the MXU sustains on a dense GEMM. The analytic default
    # is the paper's ~90%; ``repro.core.autotune`` replaces it (and
    # ici_bandwidth / remote_sync_s) with measured values via ``calibrated``.
    gemm_efficiency: float = 0.9

    def calibrated(self, **overrides: float) -> "HardwareSpec":
        """A copy of this spec with measured correction factors applied.

        ``repro.core.autotune.CalibrationTable.spec`` calls this with the
        fitted ``ici_bandwidth`` / ``remote_sync_s`` / ``gemm_efficiency``
        (and optionally ``kernel_launch_s``) so the §3.1.1 cost model runs
        on achieved rather than datasheet numbers. Unknown field names are
        rejected by ``dataclasses.replace``.
        """
        return dataclasses.replace(self, **overrides)


# Grading constants given by the assignment: 197 TFLOP/s bf16, 819 GB/s HBM,
# ~50 GB/s per ICI link.
TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    ici_bandwidth=50e9,
    ici_links=4,
    hbm_bytes=16e9,
    vmem_bytes=64 * 2**20 // 4,  # 16 MiB usable working budget per core
)

# The paper's running example, kept for validating the analysis against the
# paper's own numbers (Table 3: hiding threshold K ~ 2197 on H100).
H100_SXM = HardwareSpec(
    name="h100_sxm",
    peak_flops_bf16=989e12,
    hbm_bandwidth=3.35e12,
    ici_bandwidth=450e9,   # NVLink unidirectional
    ici_links=1,
    hbm_bytes=80e9,
    vmem_bytes=227 * 2**10,
)


@dataclasses.dataclass(frozen=True)
class KernelCost:
    """The paper's decomposition for one kernel invocation (seconds)."""

    t_launch: float
    t_comp: float
    t_mem: float
    t_comm: float
    t_non_overlap: float
    t_sync: float

    @property
    def total(self) -> float:
        return (self.t_launch + max(self.t_comp, self.t_mem, self.t_comm)
                + self.t_non_overlap + self.t_sync)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_comp, "memory": self.t_mem,
                 "collective": self.t_comm}
        return max(terms, key=terms.get)


def gemm_cost(m: int, n: int, k: int, dtype_bytes: int,
              hw: HardwareSpec = TPU_V5E, *,
              efficiency: float | None = None) -> float:
    """Seconds for a local GEMM at `efficiency` of peak.

    ``efficiency=None`` (the default) reads ``hw.gemm_efficiency``, so a
    calibrated spec automatically prices GEMMs at the *achieved* rate.
    """
    if efficiency is None:
        efficiency = hw.gemm_efficiency
    flops = 2.0 * m * n * k
    return flops / (hw.peak_flops_bf16 * efficiency)


def transfer_cost(nbytes: float, hw: HardwareSpec = TPU_V5E,
                  *, links: int = 1) -> float:
    """Seconds to move nbytes over `links` ICI link-directions."""
    return nbytes / (hw.ici_bandwidth * links)


def hiding_threshold_k(dtype_bytes: int, hw: HardwareSpec = TPU_V5E,
                       *, links: int = 1) -> int:
    """Paper §3.1.3: GEMM+RS communication is fully hidden when

        T_comp_tile >= T_comm_tile  <=>  K >= s*R / (2*B)

    For BF16 on H100 (s=2, R=989e12, B=450e9) the paper derives K >= 2197;
    on v5e with one ring link-pair this gives K >= 3940.
    """
    return math.ceil(dtype_bytes * hw.peak_flops_bf16
                     / (2.0 * hw.ici_bandwidth * links))


def ring_collective_bytes(shard_bytes: float, n_devices: int,
                          kind: str) -> float:
    """Per-device ICI traffic for ring collectives over an axis of size N.

    `shard_bytes` is the size of ONE shard (the unit each device owns).
    """
    if n_devices <= 1:
        return 0.0
    if kind in ("all_gather", "reduce_scatter"):
        return shard_bytes * (n_devices - 1)
    if kind == "all_reduce":  # RS + AG
        return 2.0 * shard_bytes * (n_devices - 1)
    if kind == "all_to_all":
        return shard_bytes * (n_devices - 1) / n_devices
    if kind == "ppermute":
        return shard_bytes
    raise ValueError(f"unknown collective kind: {kind}")


def collective_tensor_bytes(m: int, n: int, k: int, dtype_bytes: int,
                            kind: str) -> float:
    """Size of the tensor a GEMM×collective actually moves: AG+GEMM gathers
    the (m, k) *input*; RS/AR reduce the (m, n) *output*. Pricing AG on the
    output would be off by n/k whenever the projection changes width."""
    return (m * k if kind == "all_gather" else m * n) * dtype_bytes


#: pre-rename alias (the benchmarks/plan code used the private name)
_collective_tensor_bytes = collective_tensor_bytes


def quantize_cost(n_elems: float, hw: HardwareSpec = TPU_V5E, *,
                  src_bytes: float = 2.0, wire_bytes: float = 1.0) -> float:
    """Seconds for one quantize (or dequantize) pass over ``n_elems``.

    The quantize kernel is HBM-bound: it streams the full-precision operand
    in and the packed payload + scales out (symmetrically for dequantize),
    so its cost is the round-trip bytes over HBM bandwidth plus a launch.
    This is the extra term a quantized wire adds to the ring schedule —
    ``t_comm`` shrinks by ``src_bytes / wire_bytes`` but every moved element
    pays this pass on both ends of the hop.
    """
    return (hw.kernel_launch_s
            + n_elems * (src_bytes + wire_bytes) / hw.hbm_bandwidth)


def bulk_gemm_collective_cost(
    m: int, n: int, k: int, *, axis_size: int, dtype_bytes: int = 2,
    kind: str = "reduce_scatter", hw: HardwareSpec = TPU_V5E,
) -> KernelCost:
    """Analytic cost of the NON-overlapped baseline (GEMM, then collective).

    Nothing hides: the collective's transfer time is booked as
    ``t_non_overlap`` so ``KernelCost.total`` adds it serially after the
    GEMM. This is what the benchmark harness predicts for ``backend="bulk"``
    rows; the gap to ``overlapped_gemm_collective_cost`` is the predicted
    win the measured rows are checked against.
    """
    t_comp = gemm_cost(m, n, k, dtype_bytes, hw)
    out_bytes = m * n * dtype_bytes
    comm_bytes = ring_collective_bytes(
        _collective_tensor_bytes(m, n, k, dtype_bytes, kind)
        / max(axis_size, 1), axis_size, kind)
    t_comm = transfer_cost(comm_bytes, hw)
    t_mem = ((m * k + k * n) * dtype_bytes + out_bytes) / hw.hbm_bandwidth
    return KernelCost(t_launch=2.0 * hw.kernel_launch_s, t_comp=t_comp,
                      t_mem=t_mem, t_comm=0.0, t_non_overlap=t_comm,
                      t_sync=hw.remote_sync_s * max(axis_size - 1, 0))


def overlapped_gemm_collective_cost(
    m: int, n: int, k: int, *, axis_size: int, dtype_bytes: int = 2,
    kind: str = "reduce_scatter", n_chunks: int = 1,
    hw: HardwareSpec = TPU_V5E, wire_bytes: float | None = None,
) -> KernelCost:
    """Analytic cost of a chunked overlapped GEMM×collective (PK schedule).

    Models the decomposed ring schedule: the collective for chunk i+1 runs on
    the ICI DMA engines while chunk i's GEMM runs on the MXU. With C chunks the
    non-overlapped residue is one chunk's transfer (pipeline fill).

    ``wire_bytes`` prices a quantized wire: the ring payload travels at that
    (possibly fractional — scales included) element width instead of
    ``dtype_bytes``, and every moved element pays ``quantize_cost`` on both
    ends of the hop, booked under ``t_non_overlap`` (the quantize kernel
    runs on the VPU/HBM path serially with the chunk handoff, not under the
    GEMM). The compute and HBM terms stay at the tensor's own width.
    """
    t_comp = gemm_cost(m, n, k, dtype_bytes, hw)
    out_bytes = m * n * dtype_bytes
    elem_bytes = float(dtype_bytes) if wire_bytes is None else float(wire_bytes)
    moved_elems = (_collective_tensor_bytes(m, n, k, 1, kind)
                   / max(axis_size, 1))
    comm_bytes = ring_collective_bytes(moved_elems * elem_bytes,
                                       axis_size, kind)
    t_comm = transfer_cost(comm_bytes, hw)
    # HBM traffic: read A, B once; write C once (chunking re-reads one operand).
    t_mem = ((m * k + k * n) * dtype_bytes * max(1, n_chunks // 4 + 1)
             + out_bytes) / hw.hbm_bandwidth
    fill = t_comm / max(n_chunks, 1)
    if wire_bytes is not None:
        # quantize on send + dequantize on receive for every element moved
        n_hop_elems = ring_collective_bytes(moved_elems, axis_size, kind)
        fill += 2.0 * quantize_cost(n_hop_elems, hw, src_bytes=dtype_bytes,
                                    wire_bytes=elem_bytes)
    t_sync = 2.0 * n_chunks * hw.remote_sync_s * max(axis_size - 1, 0)
    return KernelCost(t_launch=hw.kernel_launch_s, t_comp=t_comp, t_mem=t_mem,
                      t_comm=t_comm, t_non_overlap=fill, t_sync=t_sync)


def fused_pipeline_cost(
    m: int, n: int, k: int, *, axis_size: int, sub_chunks: int,
    dtype_bytes: int = 2, kind: str = "reduce_scatter",
    hw: HardwareSpec = TPU_V5E,
) -> KernelCost:
    """Cost of the chunk-pipelined *fused* single-kernel schedule.

    Same pipeline geometry as ``chunk_pipeline_cost`` — every ring hop is
    split into ``sub_chunks`` double-buffered payloads whose DMA is issued
    ahead of the chunk GEMM — but priced for the in-kernel regime the fused
    Pallas path runs in:

      * one kernel launch total (the jax-level ring re-enters the runtime
        per chunked step, so its launch term hides inside XLA's schedule;
        the fused kernel pays exactly one ``t_launch``);
      * operands are VMEM-resident for the kernel's lifetime, so chunking
        never re-reads an operand from HBM — ``t_mem`` is a single pass
        regardless of chunk count;
      * per-chunk synchronization is a scalar-core DMA-descriptor issue plus
        a local semaphore wait (``local_sync_s``), not a cross-chip
        launch-visible handoff: only the first chunk of each hop pays
        ``remote_sync_s`` (the one-way cap-sem ack), the rest ride the
        already-open channel.

    The last point is the paper's thesis in cost-model form: the fused path
    tolerates much finer chunking than the jax-level rings, so its argmin
    sits at a higher chunk count for the same shape. Fused kernels ship
    full-precision payloads, so there is no ``wire_bytes`` axis here.
    """
    total = max(axis_size, 1) * max(sub_chunks, 1)
    t_comp = gemm_cost(m, n, k, dtype_bytes, hw)
    out_bytes = m * n * dtype_bytes
    comm_bytes = ring_collective_bytes(
        _collective_tensor_bytes(m, n, k, dtype_bytes, kind)
        / max(axis_size, 1), axis_size, kind)
    t_comm = transfer_cost(comm_bytes, hw)
    t_mem = ((m * k + k * n) * dtype_bytes + out_bytes) / hw.hbm_bandwidth
    fill = t_comm / max(total, 1)
    hops = max(axis_size - 1, 0) * (2 if kind == "all_reduce" else 1)
    t_sync = hops * (hw.remote_sync_s
                     + max(sub_chunks, 1) * hw.local_sync_s)
    return KernelCost(t_launch=hw.kernel_launch_s, t_comp=t_comp, t_mem=t_mem,
                      t_comm=t_comm, t_non_overlap=fill, t_sync=t_sync)


def chunk_pipeline_cost(
    m: int, n: int, k: int, *, axis_size: int, sub_chunks: int,
    dtype_bytes: int = 2, kind: str = "reduce_scatter",
    hw: HardwareSpec = TPU_V5E, wire_bytes: float | None = None,
) -> KernelCost:
    """Cost of the chunk-pipelined ring schedule (paper Fig. 2/11 regime).

    Each of the ``axis_size`` ring steps is split into ``sub_chunks``
    double-buffered chunks: chunk j's transfer for step i+1 is issued before
    step i's chunk GEMMs consume their operands, so the pipeline fill shrinks
    to one *chunk* transfer while per-chunk sync overhead grows linearly.
    ``core.schedule.choose_gemm_chunks`` takes the argmin of this total over
    candidate chunk counts — on a calibrated spec the tradeoff is priced on
    *measured* link bandwidth, sync and GEMM-efficiency constants.

    The sync term is per chunk-HOP: the ring makes ``axis_size - 1`` hops
    (2x for the AR re-derivation's trailing gather) and every hop moves
    ``sub_chunks`` independently-synchronized payloads — one semaphore pair
    each. (``overlapped_gemm_collective_cost``'s generic term additionally
    scales every chunk by the whole axis, which over-penalizes fine chunking
    by a factor of ``axis_size``.)
    """
    total = max(axis_size, 1) * max(sub_chunks, 1)
    base = overlapped_gemm_collective_cost(
        m, n, k, axis_size=axis_size, dtype_bytes=dtype_bytes, kind=kind,
        n_chunks=total, hw=hw, wire_bytes=wire_bytes)
    hops = max(axis_size - 1, 0) * (2 if kind == "all_reduce" else 1)
    return dataclasses.replace(
        base, t_sync=hops * max(sub_chunks, 1) * hw.remote_sync_s)
