"""DEPRECATED module kept for import compatibility.

The overlapped collective×compute operators moved to ``repro.core.comms``,
which also provides the policy-driven ``CommContext`` entry point that new
code should use instead of these free functions:

    from repro.core.comms import CommContext
    ctx = CommContext(axis_name="model", mesh=mesh)
    y = ctx.all_gather_matmul(x, w)          # was: pk_all_gather_matmul(...)

The full old-name -> new-call migration table lives in README.md
("Migrating from the old free functions"); the backend-selection precedence
rules (per-call override > context pin > cost-model policy) are documented
in the ``repro.core.comms`` module docstring, and the analytic-vs-measured
cost sources in docs/ARCHITECTURE.md.

Importing names from here keeps working but emits a DeprecationWarning.
"""

from __future__ import annotations

import warnings

from repro.core import comms as _comms

_MOVED = (
    "all_gather_matmul_baseline", "pk_all_gather_matmul",
    "matmul_reduce_scatter_baseline", "pk_matmul_reduce_scatter",
    "matmul_all_reduce_baseline", "pk_matmul_all_reduce",
    "all_to_all_baseline", "pk_all_to_all", "pk_psum_ring", "ring_shift",
    # internals some callers poked at
    "_perm_right", "_perm_left", "_axis_info",
)

__all__ = list(_MOVED)


def __getattr__(name: str):
    if name in _MOVED:
        warnings.warn(
            f"repro.core.collectives.{name} moved to repro.core.comms; "
            "prefer the CommContext API (repro.core.comms.CommContext)",
            DeprecationWarning, stacklevel=2)
        return getattr(_comms, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
