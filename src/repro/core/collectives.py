"""PK overlapped collective×compute operators (paper §4.1), JAX/shard_map level.

Each operator comes in two flavors:

  * ``*_baseline`` — the non-overlapped reference (bulk XLA collective followed
    by the GEMM), the analogue of the paper's cuBLAS+NCCL baseline;
  * ``pk_*`` — the ParallelKittens schedule: the collective is decomposed into
    per-shard ``lax.ppermute`` steps that are *data-independent* of the current
    GEMM chunk, so XLA's scheduler runs them on the ICI DMA engines while the
    MXU computes the previous chunk. This is the TPU realization of the paper's
    inter-SM overlapping (§3.1.3): TPU DMA engines are the "communication SMs",
    and they cost zero compute occupancy.

All ``pk_*`` functions MUST be called inside ``shard_map`` with `axis_name`
bound. Ring direction conventions:

  * "send right": perm (j -> j+1); after i hops device d holds shard (d-i)%n.
  * "send left":  perm (j -> j-1); after i hops device d holds shard (d+i)%n.

The bidirectional variants split the payload in half and run both directions
concurrently — on a 2-D torus this uses two link-pairs and halves T_comm
(a beyond-paper optimization; recorded separately in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax


def _perm_right(n: int):
    return [(j, (j + 1) % n) for j in range(n)]


def _perm_left(n: int):
    return [(j, (j - 1) % n) for j in range(n)]


def _axis_info(axis_name):
    n = lax.axis_size(axis_name)
    d = lax.axis_index(axis_name)
    return n, d


# ---------------------------------------------------------------------------
# AG + GEMM (paper Fig. 7) — tensor-parallel first projection.
# ---------------------------------------------------------------------------

def all_gather_matmul_baseline(x: jax.Array, w: jax.Array, axis_name: str,
                               *, preferred=jnp.float32) -> jax.Array:
    """x: (m_loc, k) row-sharded over axis; w: (k, n_loc) local TP shard.
    Returns (m, n_loc): bulk all-gather then a single GEMM."""
    x_full = lax.all_gather(x, axis_name, axis=0, tiled=True)
    return jnp.dot(x_full, w, preferred_element_type=preferred).astype(x.dtype)


def pk_all_gather_matmul(x: jax.Array, w: jax.Array, axis_name: str, *,
                         bidirectional: bool = False,
                         preferred=jnp.float32) -> jax.Array:
    """Overlapped AG+GEMM: rotate x shards around the ring; GEMM each shard on
    arrival. The ppermute for step i+1 is independent of step i's GEMM, so the
    transfer hides under compute (paper §3.1.3 intra-/inter-SM overlap)."""
    n, d = _axis_info(axis_name)
    m_loc, _ = x.shape
    n_out = w.shape[1]
    out = jnp.zeros((n * m_loc, n_out), dtype=x.dtype)

    if not bidirectional or n % 2 != 0:
        cur = x
        for i in range(n):
            src = (d - i) % n  # owner of the shard currently held
            y = jnp.dot(cur, w, preferred_element_type=preferred).astype(x.dtype)
            out = lax.dynamic_update_slice(out, y, (src * m_loc, 0))
            if i < n - 1:
                cur = lax.ppermute(cur, axis_name, _perm_right(n))
        return out

    # Bidirectional: each device's shard is split in half; the top halves
    # travel the right-going ring, the bottom halves the left-going ring.
    # Each of the n-1 hops moves half a shard per direction over two
    # link-pairs, halving T_comm versus the unidirectional ring.
    assert m_loc % 2 == 0, m_loc
    half = m_loc // 2
    cur_r, cur_l = jnp.split(x, 2, axis=0)
    for i in range(n):
        src_r = (d - i) % n  # right-ring: after i hops we hold (d-i)'s half
        src_l = (d + i) % n
        y_r = jnp.dot(cur_r, w, preferred_element_type=preferred).astype(x.dtype)
        out = lax.dynamic_update_slice(out, y_r, (src_r * m_loc, 0))
        y_l = jnp.dot(cur_l, w, preferred_element_type=preferred).astype(x.dtype)
        out = lax.dynamic_update_slice(out, y_l, (src_l * m_loc + half, 0))
        if i < n - 1:
            cur_r = lax.ppermute(cur_r, axis_name, _perm_right(n))
            cur_l = lax.ppermute(cur_l, axis_name, _perm_left(n))
    return out


# ---------------------------------------------------------------------------
# GEMM + reduce-scatter (paper Fig. 8 / Table 3) — TP second projection.
# ---------------------------------------------------------------------------

def matmul_reduce_scatter_baseline(x: jax.Array, w: jax.Array, axis_name: str,
                                   *, preferred=jnp.float32) -> jax.Array:
    """x: (m, k_loc); w: (k_loc, n). Returns (m_loc, n) = RS(x @ w).
    Bulk: full partial GEMM then one reduce-scatter."""
    partial = jnp.dot(x, w, preferred_element_type=preferred)
    out = lax.psum_scatter(partial, axis_name, scatter_dimension=0, tiled=True)
    return out.astype(x.dtype)


def pk_matmul_reduce_scatter(x: jax.Array, w: jax.Array, axis_name: str, *,
                             preferred=jnp.float32) -> jax.Array:
    """Overlapped GEMM+RS (accumulate-and-forward ring).

    At step i, device d computes the partial block destined for device
    (d+1+i) % n, adds the accumulator arriving from the right, and forwards
    left. The final step computes d's own block — no trailing permute. The
    per-step GEMM hides the per-step transfer whenever K >= s*R/(2*B)
    (costmodel.hiding_threshold_k)."""
    n, d = _axis_info(axis_name)
    m = x.shape[0]
    assert m % n == 0, (m, n)
    m_blk = m // n

    def partial_block(b):
        xb = lax.dynamic_slice_in_dim(x, b * m_blk, m_blk, axis=0)
        return jnp.dot(xb, w, preferred_element_type=preferred)

    # the ring payload travels in the activation dtype (bf16): half the ICI
    # bytes of an f32 accumulator; each hop's add still runs in f32
    acc = partial_block((d + 1) % n).astype(x.dtype)
    for i in range(1, n):
        acc = lax.ppermute(acc, axis_name, _perm_left(n))
        acc = (acc.astype(preferred)
               + partial_block((d + 1 + i) % n)).astype(x.dtype)
    return acc


# ---------------------------------------------------------------------------
# GEMM + all-reduce (paper Fig. 9).
# ---------------------------------------------------------------------------

def matmul_all_reduce_baseline(x: jax.Array, w: jax.Array, axis_name: str,
                               *, preferred=jnp.float32) -> jax.Array:
    partial = jnp.dot(x, w, preferred_element_type=preferred)
    return lax.psum(partial, axis_name).astype(x.dtype)


def pk_matmul_all_reduce(x: jax.Array, w: jax.Array, axis_name: str, *,
                         preferred=jnp.float32) -> jax.Array:
    """Overlapped GEMM+AR. TPU ICI has no in-network reduction (DESIGN §2.1),
    so the paper's switch-offloaded AR is re-derived as overlapped
    RS(accumulate-on-arrival) + AG: same 2*(N-1)/N per-device traffic, and the
    RS half hides under the GEMM."""
    n, _ = _axis_info(axis_name)
    rs = pk_matmul_reduce_scatter(x, w, axis_name, preferred=preferred)
    return lax.all_gather(rs, axis_name, axis=0, tiled=True)


# ---------------------------------------------------------------------------
# Fine-grained all-to-all (paper Fig. 11 / 17) — Ulysses-style head<->sequence
# re-sharding without host-side reshape/copy.
# ---------------------------------------------------------------------------

def all_to_all_baseline(x: jax.Array, axis_name: str, *, split_axis: int,
                        concat_axis: int) -> jax.Array:
    return lax.all_to_all(x, axis_name, split_axis=split_axis,
                          concat_axis=concat_axis, tiled=True)


def pk_all_to_all(x: jax.Array, axis_name: str, *, split_axis: int,
                  concat_axis: int, n_chunks: int = 1) -> jax.Array:
    """Chunked a2a: splitting the payload lets downstream compute start on the
    first chunk while later chunks are still in flight (inter-SM analogue).
    With n_chunks=1 this is the native tiled all-to-all, which — unlike NCCL
    (paper §4.2) — already operates on the strided layout with no reshape.

    Chunks are cut along a *bystander* dim (neither split nor concat) so the
    chunked result is bit-identical to the bulk op."""
    if n_chunks == 1:
        return all_to_all_baseline(x, axis_name, split_axis=split_axis,
                                   concat_axis=concat_axis)
    chunk_axis = next((d for d in range(x.ndim)
                       if d not in (split_axis, concat_axis)
                       and x.shape[d] % n_chunks == 0 and x.shape[d] > 1),
                      None)
    if chunk_axis is None:
        return all_to_all_baseline(x, axis_name, split_axis=split_axis,
                                   concat_axis=concat_axis)
    chunks = jnp.split(x, n_chunks, axis=chunk_axis)
    outs = [lax.all_to_all(c, axis_name, split_axis=split_axis,
                           concat_axis=concat_axis, tiled=True) for c in chunks]
    return jnp.concatenate(outs, axis=chunk_axis)


def pk_psum_ring(y: jax.Array, axis_name: str) -> jax.Array:
    """all-reduce as an explicit accumulate-and-forward ring (RS) + ring AG,
    built from ppermutes — the TPU re-derivation of the paper's in-network
    AR (DESIGN §2.1): same 2(N-1)/N per-device traffic, but the payload
    keeps its dtype (XLA:CPU promotes bf16 all-reduce to f32 — 2x bytes)
    and each hop is independently overlappable with compute."""
    n, d = _axis_info(axis_name)
    lead = y.shape[0]
    if n == 1:
        return y
    if lead % n != 0:
        return lax.psum(y, axis_name)
    blk = lead // n
    parts = y.reshape(n, blk, *y.shape[1:])
    acc = parts[(d + 1) % n]
    for i in range(1, n):
        acc = lax.ppermute(acc, axis_name, _perm_left(n))
        acc = acc + parts[(d + 1 + i) % n]
    out = lax.all_gather(acc, axis_name, axis=0, tiled=True)
    return out.reshape(y.shape)


# ---------------------------------------------------------------------------
# Ring shift — the PK `store_async`-to-neighbor pattern at jax level; the
# Pallas-level twin lives in kernels/pk_comm.py.
# ---------------------------------------------------------------------------

def ring_shift(x, axis_name: str, *, reverse: bool = False):
    """One-hop ring rotation of a pytree (KV blocks in ring attention, SSM
    states in sequence-parallel Mamba)."""
    n = lax.axis_size(axis_name)
    perm = _perm_left(n) if reverse else _perm_right(n)
    return jax.tree_util.tree_map(
        lambda t: lax.ppermute(t, axis_name, perm), x)
