"""REMOVED module — ``repro.core.collectives`` has no members anymore.

The overlapped collective×compute operators moved to ``repro.core.comms`` two
releases ago (with a DeprecationWarning shim for one release); the shim is
now gone. New code should use the policy-driven context, or — for whole
overlapped workloads — declare a ``repro.core.template.Island``:

    from repro.core.comms import CommContext
    ctx = CommContext(axis_name="model", mesh=mesh)
    y = ctx.all_gather_matmul(x, w)          # was: pk_all_gather_matmul(...)

The free functions remain importable from their canonical home
(``repro.core.comms`` / ``repro.core``); backend-selection precedence is
documented in the ``repro.core.comms`` module docstring, and the island
template in ``repro.core.template`` / docs/ARCHITECTURE.md.

This stub raises ``ImportError`` with that migration message for one release
so stale imports fail loudly instead of with a bare AttributeError; the
module itself will be deleted next release.
"""

from __future__ import annotations

_MOVED = (
    "all_gather_matmul_baseline", "pk_all_gather_matmul",
    "matmul_reduce_scatter_baseline", "pk_matmul_reduce_scatter",
    "matmul_all_reduce_baseline", "pk_matmul_all_reduce",
    "all_to_all_baseline", "pk_all_to_all", "pk_psum_ring", "ring_shift",
    # internals some callers poked at
    "_perm_right", "_perm_left", "_axis_info",
)

_MSG = ("repro.core.collectives was removed: import {name!r} from "
        "repro.core.comms (or use repro.core.comms.CommContext / "
        "repro.core.template.Island — see README.md 'The CommContext API')")


def __getattr__(name: str):
    if name in _MOVED:
        # ImportError (not AttributeError) so `from ... import name` shows
        # the migration message at the import site.
        raise ImportError(_MSG.format(name=name))
    # Unknown / protocol attributes (__path__, hasattr probes, import-star
    # machinery) get the normal missing-attribute behavior.
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
