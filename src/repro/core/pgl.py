"""Parallel Global Layout (PGL) — paper §3.2.1, adapted to JAX/TPU.

On GPUs, a PGL is a set of identically-shaped buffers allocated on every
device, addressable from kernels by (device, tile-coordinate). The CUDA
plumbing the paper needs to build it (IPC handles, VMM, multicast objects —
paper Appendices E/F) is subsumed on TPU by XLA's SPMD runtime: a PGL is a
named, mesh-sharded array whose leading axis is the device axis.

Two views exist:

  * **jax level** — ``PGL.zeros()/shape_dtype()`` produce an array (or
    ShapeDtypeStruct) with sharding ``P(axis_name, ...)``; inside a
    ``shard_map`` each device sees exactly its local slab, which is what a PK
    kernel addresses.
  * **Pallas level** — ``kernels/pk_comm.py`` passes the local slab with
    ``memory_space=ANY`` (HBM) into ``pl.pallas_call`` and uses
    ``pltpu.make_async_remote_copy`` / ``semaphore_*`` to implement the eight
    PK primitives against peer slabs.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class PGL:
    """A logically-global buffer with one identical slab per device on `axis`.

    Global shape is ``(axis_size, *local_shape)``; device d owns slab d.
    """

    name: str
    mesh: Mesh
    axis: str
    local_shape: tuple[int, ...]
    dtype: jnp.dtype = jnp.bfloat16

    @property
    def axis_size(self) -> int:
        return self.mesh.shape[self.axis]

    @property
    def global_shape(self) -> tuple[int, ...]:
        return (self.axis_size, *self.local_shape)

    @property
    def sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P(self.axis, *([None] * len(self.local_shape))))

    @property
    def spec(self) -> P:
        return P(self.axis, *([None] * len(self.local_shape)))

    def shape_dtype(self) -> jax.ShapeDtypeStruct:
        """Allocation-free stand-in (dry-run path)."""
        return jax.ShapeDtypeStruct(self.global_shape, self.dtype,
                                    sharding=self.sharding)

    def zeros(self) -> jax.Array:
        return jax.device_put(
            jnp.zeros(self.global_shape, self.dtype), self.sharding)

    def from_array(self, x: jax.Array) -> jax.Array:
        assert x.shape == self.global_shape, (x.shape, self.global_shape)
        return jax.device_put(x.astype(self.dtype), self.sharding)


def barrier_pgl(name: str, mesh: Mesh, axis: str,
                n_slots: int = 1) -> PGL:
    """Integer barrier/flag buffer, one slot-vector per device (paper's
    ``barrier_t``: a PGL of integers used by signal/wait)."""
    return PGL(name=name, mesh=mesh, axis=axis, local_shape=(n_slots,),
               dtype=jnp.int32)
