"""Unified policy-driven communication API — ``CommContext``.

The paper's core claim is that eight primitives and **one programming
template** suffice for peak multi-GPU kernels. This module is that template's
host-side face: every communication op the repo implements is reachable
through one object, and *which* implementation runs is decided by the §3.1.1
cost model at trace time — not hardcoded at each call site.

    from repro.core.comms import CommContext

    ctx = CommContext(axis_name="model", mesh=mesh)        # construct once
    y = ctx.matmul_reduce_scatter(x, w)                    # policy-routed
    y = ctx.matmul_reduce_scatter(x, w, backend="ring")    # explicit override

Ops (uniform signature: operands, then ``backend=None`` plus op kwargs):

    ==============================  =======================================
    op                              backends
    ==============================  =======================================
    ``all_gather_matmul(x, w)``     bulk | ring | ring_bidir | fused
    ``matmul_reduce_scatter(x, w)`` bulk | ring | fused
    ``matmul_all_reduce(x, w)``     bulk | ring | fused
    ``all_to_all(x)``               bulk | chunked
    ``psum(x)``                     bulk | ring
    ``all_gather(x)``               bulk | fused
    ``reduce_scatter(x)``           bulk | fused
    ``ring_shift(x)``               bulk | fused
    ==============================  =======================================

``bulk``    — the non-overlapped XLA collective (paper's cuBLAS+NCCL analogue)
``ring``    — chunk-pipelined ``ppermute`` ring; every ring step is split
              into ``n_chunks`` double-buffered chunks (send-ahead: step
              i+1's shifts are issued before step i's chunk GEMMs), so
              transfers hide under the MXU at sub-shard granularity
``ring_bidir`` — both ring directions at once (2 link-pairs, halves T_comm);
              multi-chunk per step per direction, uneven shards split
              ceil/floor
``chunked`` — payload split so downstream compute overlaps later chunks
``fused``   — single Pallas kernel with intra-kernel RDMA overlap (LCSC
              template; needs a TPU backend or TPU interpret mode). Each
              ring hop is itself chunk-pipelined: per-chunk one-way DMAs
              issued ahead of the chunk GEMM, same ``ChunkSchedule``
              resolution as the jax-level rings but priced with
              ``costmodel.fused_pipeline_cost`` (in-kernel sync is cheap,
              so the argmin sits at finer chunks)

The GEMM×collective ops take ``n_chunks=``/``chunk_dim=`` knobs; left unset,
the chunk count resolves via ``CommContext.gemm_chunk_schedule`` (context
default -> measured chunk sweep -> ``schedule.choose_gemm_chunks``), and any
count is fitted to the chunked sub-shape's largest divisor — chunking never
adds a shape constraint. Under the measured policy, lookups prefer
calibration rows tagged with this context's ``island`` key (see
``repro.core.autotune.island_key``; ``calibrate --per-island`` produces
them), so different islands can dispatch differently at the same shape.

Backend-selection precedence (highest to lowest)
------------------------------------------------

1. **Per-call override** — ``ctx.matmul_reduce_scatter(x, w,
   backend="ring")``. Always wins. If the named backend's shape constraint
   is violated (e.g. ``m`` not divisible by the axis for a ring), this is
   treated as a caller bug and raises ``ValueError`` with the constraint
   spelled out; it never silently measures a different backend.
2. **Context pin** — ``CommContext(backend="ring")`` (what
   ``RunConfig.comm_backend`` sets for A/B runs). Applies to every call on
   the context, with two deliberate softenings: a pinned backend the called
   op does not implement (e.g. ``ring_bidir`` pinned, ``matmul_all_reduce``
   called) falls back to the policy for that op, and a pinned backend whose
   shape constraint fails (decode-shaped GEMMs) degrades to ``bulk`` the
   way the policy would — so one pin cannot crash a whole run. A typo'd
   pin (not a backend of *any* op) still raises.
3. **Policy** (``backend=None``) — the §3.1.1 cost model decides; see below.

Dispatch rules (``backend=None``): GEMM×collective ops go through
``schedule.choose_gemm_collective`` — bulk when the GEMM is too small to
cover the ring's sync overhead, ``ring_bidir`` when the axis is even and
bidirectional rings are allowed, ``ring`` otherwise, ``fused`` on a real TPU
when the operands fit VMEM. ``all_to_all`` picks its chunk count from
``schedule.choose_a2a_chunks``.

Analytic vs measured costs (``policy=``)
----------------------------------------

The policy's cost source is itself a knob. ``policy="analytic"`` (default)
prices schedules from ``hw``'s datasheet constants. ``policy="measured"``
dispatches from a ``repro.core.autotune`` calibration table — micro-bench
measurements of every backend on *this* machine — and only falls back to
the analytic model (with a warning) when no table matches the machine's
fingerprint or the requested shape is too far off the calibrated grid.
``policy="auto"`` is the same fallback, silent. Produce a table with
``python -m repro.autotune calibrate``; see docs/ARCHITECTURE.md for the
full calibration loop.

This module also owns the **collective-id allocator**: every Pallas
communication kernel gets its ``CompilerParams(collective_id=...)`` from
``collective_id(name)`` instead of a hand-numbered constant, so two kernels
can never collide on a barrier-semaphore id.

The jax-level implementations (formerly ``repro.core.collectives``; that
module is now a removed stub raising ImportError) live at the bottom of this
module and are re-exported from ``repro.core``. Whole overlapped workloads
should be declared through the unified island template
(``repro.core.template.Island``), which threads a ready ``CommContext`` into
the island body.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.core import costmodel as cm
from repro.core.quant import (WireFormat, dequantize_blocks, quantize_blocks,
                              resolve_wire, wire_dtype_bytes)
from repro.core.schedule import (GEMM_CHUNK_DIM, ChunkSchedule, OverlapPolicy,
                                 a2a_chunk_axis, choose_a2a_chunks,
                                 choose_gemm_chunks, choose_gemm_collective,
                                 fit_chunks)

__all__ = [
    "CommContext", "collective_id", "register_collective", "OP_BACKENDS",
    "GEMM_OP_KIND",
    # jax-level implementations (canonical home since the comms redesign)
    "all_gather_matmul_baseline", "pk_all_gather_matmul",
    "matmul_reduce_scatter_baseline", "pk_matmul_reduce_scatter",
    "matmul_all_reduce_baseline", "pk_matmul_all_reduce",
    "all_to_all_baseline", "pk_all_to_all", "pk_psum_ring", "ring_shift",
]


# ---------------------------------------------------------------------------
# Central collective-id allocator (replaces hand-numbered 0..5 constants).
# ---------------------------------------------------------------------------

_COLLECTIVE_IDS: dict[str, int] = {}

# Registered eagerly, in a fixed order, so every process of an SPMD job
# assigns identical ids no matter which kernel it happens to trace first.
_CANONICAL_KERNELS = (
    "ring_all_gather",
    "ring_reduce_scatter",
    "p2p_ring_shift",
    "ag_matmul_fused",
    "matmul_rs_fused",
    "lcsc_ring_all_gather",
)


def register_collective(name: str) -> int:
    """Assign the next id to a named collective kernel.

    MUST be called at kernel-definition (module import) time: import order
    is deterministic across the processes of an SPMD job, trace order is
    not — two hosts tracing conditionally-reached kernels in different
    orders would otherwise map the same id to different kernels."""
    if name not in _COLLECTIVE_IDS:
        _COLLECTIVE_IDS[name] = len(_COLLECTIVE_IDS)
    return _COLLECTIVE_IDS[name]


def collective_id(name: str) -> int:
    """Process-wide stable ``collective_id`` for a registered kernel.

    Pallas requires concurrently-running collective kernels to carry distinct
    ids (they select the barrier semaphore). Hand-numbering them across files
    is a collision waiting to happen; kernels call this instead. Unregistered
    names are an error — silently allocating here would hand out
    trace-order-dependent ids, the exact cross-process mismatch this
    allocator exists to prevent."""
    if name not in _COLLECTIVE_IDS:
        raise KeyError(
            f"collective kernel {name!r} is not registered; call "
            "repro.core.comms.register_collective(name) at module import "
            "time (trace-time allocation would give different ids on "
            "different SPMD processes)")
    return _COLLECTIVE_IDS[name]


def registered_collectives() -> dict[str, int]:
    """Snapshot of the current name -> id assignment (diagnostics/tests)."""
    return dict(_COLLECTIVE_IDS)


for _name in _CANONICAL_KERNELS:
    register_collective(_name)


# ---------------------------------------------------------------------------
# The op/backend registry. "fused" backends additionally require
# compat.tpu_kernels_supported() at run time.
# ---------------------------------------------------------------------------

OP_BACKENDS: dict[str, tuple[str, ...]] = {
    "all_gather_matmul": ("bulk", "ring", "ring_bidir", "fused"),
    "matmul_reduce_scatter": ("bulk", "ring", "fused"),
    "matmul_all_reduce": ("bulk", "ring", "fused"),
    "all_to_all": ("bulk", "chunked"),
    "psum": ("bulk", "ring"),
    "all_gather": ("bulk", "fused"),
    "reduce_scatter": ("bulk", "fused"),
    "ring_shift": ("bulk", "fused"),
}

_FUSED = ("fused",)

_ALL_BACKENDS = {b for bs in OP_BACKENDS.values() for b in bs}

#: GEMM×collective op -> cost-model "kind" (the §3.1.3 schedule coordinate).
#: Single source for dispatch here, Island.plan() and the benchmarks.
GEMM_OP_KIND = {"all_gather_matmul": "all_gather",
                "matmul_reduce_scatter": "reduce_scatter",
                "matmul_all_reduce": "all_reduce"}


@dataclasses.dataclass(frozen=True)
class CommContext:
    """One handle for every overlapped collective over a mesh axis.

    Construct once per (mesh, axis); methods are safe to call both at the
    jit level and inside ``shard_map`` (with ``axis_name`` bound). When
    ``mesh`` is None the context must be used inside ``shard_map`` so the
    axis size can be read from the binding.

    ``backend`` set here applies to every call (benchmarks pin "bulk" /
    "ring" to measure both sides); per-call ``backend=`` overrides even that.
    ``interpret`` controls Pallas interpret-mode dispatch for the fused
    kernels: None = interpret everywhere but a real TPU.
    """

    axis_name: str
    mesh: Any = None
    hw: cm.HardwareSpec = cm.TPU_V5E
    backend: str | None = None
    interpret: bool | None = None
    allow_bidir: bool = True
    #: "analytic" prices schedules from ``hw``'s datasheet constants;
    #: "measured" dispatches from a ``repro.core.autotune`` calibration
    #: table (falling back to analytic, with a warning, when none matches
    #: this machine); "auto" is "measured when a matching table exists,
    #: analytic otherwise", silently.
    policy: str = "analytic"
    #: a ``CalibrationTable``, a path to one, or None (= search the user
    #: cache then the in-repo seed tables). Ignored under policy="analytic".
    calibration: Any = None
    #: island key (``autotune.island_key(...)``) this context dispatches as.
    #: Measured lookups prefer calibration rows tagged with this key and fall
    #: back to the global (untagged) rows — two islands with different
    #: layouts/dtypes can then resolve to different backends at the same
    #: (m, n, k). None = global rows only.
    island: str | None = None
    #: context-wide default sub-chunk count for the chunk-pipelined ring
    #: GEMM×collectives (``RunConfig.comm_chunks``). None = per-call kwarg,
    #: else measured table, else the analytic chunk scheduler.
    chunks: int | None = None
    #: on-wire element format for the ring GEMM×collectives
    #: (``RunConfig.comm_wire``): None/"bf16" ships payloads in their own
    #: dtype; "int8" quantizes each travelling sub-chunk per-row into int8
    #: blocks + f32 scales (quantize → ring-shift → dequantize-accumulate
    #: in f32); "int8_sr" adds stochastic rounding (GEMM+AR option). Bulk
    #: and fused backends ignore the wire — it is a property of the ring
    #: transfer schedule, and the measured question the dtype axis answers
    #: is precisely "int8-ring vs bf16-bulk".
    wire: Any = None
    #: scripted comms-level payload fault (runtime/health.py): a
    #: ``(kind, hop)`` pair with kind "corrupt" (NaN the whole hop payload)
    #: or "bitflip" (NaN one element), applied to the ring GEMM×collectives'
    #: hop ``hop`` after its ppermute. Trace-time-static test seam — None
    #: everywhere outside scripted fault injection. Bulk and fused backends
    #: ignore it (only ring transfers have hops to poison).
    fault: Any = None

    def wire_format(self, override: Any = None) -> WireFormat | None:
        """Resolved quantized ``WireFormat`` for a call (per-call ``wire=``
        override first, then the context default), or None when the wire is
        full-precision."""
        return resolve_wire(override if override is not None else self.wire)

    # -- introspection -----------------------------------------------------

    @property
    def axis_size(self) -> int:
        if self.mesh is not None:
            return self.mesh.shape[self.axis_name]
        return compat.axis_size(self.axis_name)

    def available_backends(self, op: str) -> tuple[str, ...]:
        """Backends of `op` that can actually execute in this process.

        Example::

            >>> CommContext(axis_name="x").available_backends("psum")
            ('bulk', 'ring')
        """
        names = OP_BACKENDS[op]
        if compat.tpu_kernels_supported():
            return names
        return tuple(b for b in names if b not in _FUSED)

    def active_calibration(self):
        """The ``CalibrationTable`` this context's policy dispatches from,
        or None when the policy is analytic (explicitly, or by fallback
        because no table matches this machine's fingerprint)."""
        from repro.core import autotune
        return autotune.resolve_table(self.calibration, self.hw.name,
                                      self.policy)

    def effective_hw(self) -> cm.HardwareSpec:
        """``hw`` with measured correction factors applied when the measured
        policy is active — the spec every cost-model query below runs on."""
        table = self.active_calibration()
        return table.spec(self.hw) if table is not None else self.hw

    # -- dispatch plumbing -------------------------------------------------

    def _interpret_mode(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return compat.default_interpret()

    def _resolve(self, op: str, override: str | None, auto) -> str:
        be = override if override is not None else self.backend
        if be in (None, "auto"):
            be = auto()
        elif override is None and be not in OP_BACKENDS[op]:
            # A context-wide pin (RunConfig.comm_backend) names a real
            # backend that this particular op doesn't implement (e.g.
            # "ring_bidir" pinned, matmul_all_reduce called): fall back to
            # the policy for this op rather than crashing the whole run.
            # Unknown names are still an error — a typo'd pin must not
            # silently run the policy everywhere.
            if be not in _ALL_BACKENDS:
                raise ValueError(
                    f"unknown backend {be!r}; known backends: "
                    f"{sorted(_ALL_BACKENDS)}")
            be = auto()
        if be not in OP_BACKENDS[op]:
            raise ValueError(
                f"op {op!r} has no backend {be!r}; "
                f"available: {OP_BACKENDS[op]}")
        if be in _FUSED and not compat.tpu_kernels_supported():
            raise NotImplementedError(
                f"backend 'fused' for {op!r} needs a TPU backend or a JAX "
                "with pltpu.InterpretParams (TPU interpret mode)")
        return be

    def _shape_guard(self, op: str, be: str, override: str | None,
                     ok: bool, constraint: str, fallback: str = "bulk") -> str:
        """Ring/fused schedules have shape divisibility requirements the
        bulk path doesn't. A per-call ``backend=`` that violates one is a
        caller bug — raise with the constraint spelled out (not the bare
        ``assert`` inside the impl). A context-pinned backend (e.g.
        ``RunConfig.comm_backend`` A/B runs) degrades to `fallback` instead,
        the way the policy does, so decode-shaped calls keep working."""
        if ok or be == fallback:
            return be
        if override is not None:
            raise ValueError(
                f"{op}(backend={be!r}) requires {constraint} "
                f"(axis {self.axis_name!r} has size {self.axis_size})")
        return fallback

    def _prefer_fused(self, *operands, out_bytes: int) -> bool:
        """Auto-pick the fused Pallas kernel only on a real TPU and only when
        the whole-operand VMEM residency the kernels assume actually fits."""
        if jax.default_backend() != "tpu" or self._interpret_mode():
            return False
        footprint = sum(x.size * x.dtype.itemsize for x in operands) + out_bytes
        return footprint <= self.hw.vmem_bytes

    def gemm_policy(self, m: int, n: int, k: int, *, kind: str,
                    dtype_bytes: int = 2, hw: cm.HardwareSpec | None = None,
                    wire: Any = None) -> OverlapPolicy:
        """The §3.1.3 schedule decision for a fused GEMM×collective of global
        GEMM shape (m, n, k) over this context's axis. Pure / trace-free.

        ``hw=None`` prices on ``effective_hw()``; callers that already hold
        the resolved spec (``auto_gemm_backend``) pass it to avoid resolving
        the calibration table twice per dispatch.

        Only the AG+GEMM op implements the bidirectional ring, so only the
        "all_gather" kind may credit the cost model with the second
        link-pair — otherwise hidden_fraction would be 2x optimistic for
        RS/AR and the policy would report a strategy no backend implements.

        A quantized wire reprices only the ring's transfer side: the hiding
        condition is evaluated at the on-wire element width (scales and
        quantize-kernel term included), so the hidden fraction the plan
        reports reflects what the int8 payload actually ships.
        """
        allow_bidir = self.allow_bidir and kind == "all_gather"
        fmt = self.wire_format(wire)
        return choose_gemm_collective(
            m, n, k, axis_size=self.axis_size, kind=kind,
            dtype_bytes=dtype_bytes,
            hw=hw if hw is not None else self.effective_hw(),
            allow_bidir=allow_bidir,
            wire_bytes=fmt.bytes_per_element if fmt is not None else None)

    _GEMM_KIND = GEMM_OP_KIND

    def auto_gemm_backend(self, op: str, m: int, n: int, k: int, *,
                          dtype_bytes: int = 2, fused_ok: bool = False,
                          bidir_ok: bool = True, wire: Any = None) -> str:
        """The backend ``backend=None`` resolves to for a GEMM×collective of
        global shape (m, n, k) — the policy mapping itself, trace-free, so
        dispatch is unit-testable without running the GEMM. ``fused_ok`` /
        ``bidir_ok`` carry the operand-level constraints (VMEM fit, even
        local rows) the real call sites compute from their arrays.

        Under the measured policy, backends with calibration measurements
        near (m, n, k) are compared on *measured* microseconds and the
        analytic model is only consulted when the table has no usable
        coverage (shape too far off the calibrated grid, or fewer than two
        feasible backends measured). A quantized ``wire`` moves the lookup
        to that width's rows (``dtype_bytes=1`` → the ``b1`` island keys a
        ``calibrate --dtype int8`` sweep produced) — the rows there pit the
        int8 ring against the still-full-precision bulk collective, so the
        measured argmin answers exactly "does int8-ring beat bf16-bulk"."""
        fmt = self.wire_format(wire)
        q_bytes = fmt.dtype_bytes if fmt is not None else dtype_bytes
        table = self.active_calibration()
        if table is not None:
            allowed = ["bulk", "ring"]
            if (op == "all_gather_matmul" and bidir_ok and self.allow_bidir
                    and self.axis_size % 2 == 0):
                allowed.append("ring_bidir")
            if fused_ok:
                allowed.append("fused")
            best = table.best_backend(op, m, n, k, allowed=allowed,
                                      axis_size=self.axis_size,
                                      dtype_bytes=q_bytes,
                                      island=self.island)
            if best is not None:
                return best
        pol = self.gemm_policy(
            m, n, k, kind=self._GEMM_KIND[op], dtype_bytes=dtype_bytes,
            hw=table.spec(self.hw) if table is not None else self.hw,
            wire=wire)
        if not pol.enabled:
            return "bulk"
        if fused_ok and fmt is None:
            # the fused Pallas kernels ship full-precision payloads; under a
            # quantized wire the ring schedules are the ones that actually
            # put int8 on the links
            return "fused"
        if (op == "all_gather_matmul" and pol.strategy == "ring_bidir"
                and bidir_ok):
            return "ring_bidir"
        return "ring"

    def gemm_chunk_schedule(self, op: str, m: int, n: int, k: int, *,
                            backend: str, dtype_bytes: int = 2,
                            n_chunks: int | None = None,
                            chunk_dim: str | None = None,
                            wire: Any = None) -> ChunkSchedule:
        """The chunk-pipeline decision for a resolved GEMM×collective call.

        Precedence: explicit per-call ``n_chunks`` > the context-wide
        ``chunks`` default (``RunConfig.comm_chunks``) > chunk counts
        *measured* in the calibration table (island-keyed rows first) > the
        analytic argmin. Bulk takes no sub-chunks — its whole point is the
        monolithic collective. Ring backends price the analytic tier with
        ``schedule.choose_gemm_chunks``; the fused single-kernel pipeline
        prices it with the ``fused=True`` variant
        (``costmodel.fused_pipeline_cost``: one launch, VMEM-resident
        operands, local-sync chunk handoffs), whose argmin usually sits at a
        finer count. The returned count is a request; the impls fit it to
        the chunked sub-shape's largest divisor (never a new shape
        constraint).

        A quantized ``wire`` pins ``chunk_dim="m"``: blocks are quantized
        per row (along the last axis), so row chunks leave every scale group
        intact — the quantized values stay bit-exact across chunk counts —
        while column chunks would re-cut the blocks per chunk. It also moves
        the measured chunk lookup to the wire's ``b{dtype_bytes}`` rows and
        reprices the analytic argmin at the on-wire element width. The fused
        kernels ship full precision, so their schedule ignores ``wire``
        (chunk rows always slice "m" — the payload's row dim).
        """
        kind = self._GEMM_KIND[op]
        fused = backend == "fused"
        fmt = self.wire_format(wire) if not fused else None
        if fmt is not None or fused:
            # per-row scale groups survive only row chunking; the fused
            # kernels likewise chunk the payload's rows (see docstring)
            chunk_dim = "m"
        dim = chunk_dim if chunk_dim is not None else GEMM_CHUNK_DIM[kind]
        if backend not in ("ring", "ring_bidir", "fused"):
            return ChunkSchedule(1, dim, f"{backend} path takes no sub-chunks")
        if n_chunks is not None:
            return ChunkSchedule(max(1, n_chunks), dim, "per-call n_chunks=",
                                 source="explicit")
        if self.chunks is not None:
            return ChunkSchedule(max(1, self.chunks), dim,
                                 "context chunks= (RunConfig.comm_chunks)",
                                 source="explicit")
        q_bytes = fmt.dtype_bytes if fmt is not None else dtype_bytes
        table = self.active_calibration()
        if table is not None:
            c = table.best_chunks(op, backend, m, n, k,
                                  axis_size=self.axis_size,
                                  dtype_bytes=q_bytes,
                                  island=self.island)
            if c is not None:
                return ChunkSchedule(c, dim, "measured chunk sweep argmin",
                                     source="measured")
        sched = choose_gemm_chunks(
            m, n, k, axis_size=self.axis_size, kind=kind,
            dtype_bytes=dtype_bytes, hw=self.effective_hw(),
            wire_bytes=fmt.bytes_per_element if fmt is not None else None,
            fused=fused)
        return sched if chunk_dim is None else dataclasses.replace(
            sched, chunk_dim=chunk_dim)

    @staticmethod
    def a2a_coords(shape, split_axis: int, concat_axis: int
                   ) -> tuple[int, int, int]:
        """The (m, n, k) lookup coordinates an ``all_to_all`` calibration
        row is stored/queried under: (local payload elements, split-dim
        extent, concat-dim extent). One convention shared by the per-island
        calibration sweep and every dispatch query, the same way the GEMM
        rows share ``auto_gemm_backend``'s coordinates — rows stored in any
        other system would never be found."""
        return (int(math.prod(shape)), int(shape[split_axis]),
                int(shape[concat_axis]))

    def a2a_chunk_schedule(self, shape, split_axis: int, concat_axis: int, *,
                           dtype_bytes: int = 2,
                           downstream_compute_s: float = 0.0
                           ) -> ChunkSchedule:
        """Chunk count for an ``all_to_all`` of local payload ``shape``.

        Measured-first: when the calibration table carries a2a rows near
        :meth:`a2a_coords` (``calibrate --per-island`` sweeps the Ulysses /
        MoE dispatch islands; island-keyed rows preferred), the bulk-vs-
        chunked decision and the chunk count are the measured argmin;
        otherwise the analytic ``schedule.choose_a2a_chunks`` policy
        answers. The count is always fitted to the payload's splittable
        bystander dims, exactly like ``pk_all_to_all`` will."""
        m, n, k = self.a2a_coords(shape, split_axis, concat_axis)
        table = self.active_calibration()
        if table is not None:
            be = table.best_backend("all_to_all", m, n, k,
                                    allowed=("bulk", "chunked"),
                                    axis_size=self.axis_size,
                                    dtype_bytes=dtype_bytes,
                                    island=self.island)
            if be == "bulk":
                return ChunkSchedule(1, "a2a", "measured: bulk a2a wins",
                                     source="measured")
            if be == "chunked":
                c = table.best_chunks("all_to_all", "chunked", m, n, k,
                                      axis_size=self.axis_size,
                                      dtype_bytes=dtype_bytes,
                                      island=self.island)
                c = c if c is not None else 2
                fit = a2a_chunk_axis(shape, split_axis, concat_axis, c)
                if fit is not None and fit[1] > 1:
                    return ChunkSchedule(fit[1], "a2a",
                                         "measured chunk sweep argmin",
                                         source="measured")
                return ChunkSchedule(1, "a2a",
                                     "measured chunked win, but no "
                                     "bystander dim splits", source="measured")
        c = choose_a2a_chunks(
            math.prod(shape) * dtype_bytes, axis_size=self.axis_size,
            downstream_compute_s=downstream_compute_s,
            hw=self.effective_hw(), shape=shape, split_axis=split_axis,
            concat_axis=concat_axis)
        return ChunkSchedule(c, "a2a",
                             f"choose_a2a_chunks -> {c}", source="analytic")

    # -- GEMM × collective ops --------------------------------------------

    def all_gather_matmul(self, x, w, *, backend: str | None = None,
                          n_chunks: int | None = None,
                          chunk_dim: str | None = None,
                          wire: Any = None,
                          preferred=jnp.float32):
        """x: (m_loc, k) row-sharded; w: (k, n_loc) local. -> (m, n_loc).

        The tensor-parallel first projection (paper Fig. 7): gather the
        row-sharded activations while the GEMM consumes already-arrived
        shards. ``backend="ring_bidir"`` additionally needs ``m_loc >= 2``
        (the shard is split across the two ring directions — unevenly when
        odd; the guard validates the chunked sub-shape, not full-shard
        parity). ``n_chunks``/``chunk_dim`` select the chunk-pipeline
        granularity of the ring schedules (None = scheduler/measured table).
        ``wire`` (per-call override of the context default) selects the
        on-wire format of the ring payloads: "int8" quantizes each
        travelling shard chunk once per-row and ships (int8, f32-scale)
        pairs around the ring, dequantizing for each arrival's GEMM.

        Example (inside ``shard_map`` with axis ``"model"`` bound)::

            ctx = CommContext(axis_name="model", mesh=mesh)
            # x: (seq/n_dev, d_model) per device; w: (d_model, d_ff/n_dev)
            y = ctx.all_gather_matmul(x, w)          # policy-routed
            y = ctx.all_gather_matmul(x, w, backend="ring", n_chunks=4)
        """
        n_dev = self.axis_size
        m_loc, k = x.shape
        n_out = w.shape[1]
        fmt = self.wire_format(wire)

        def auto() -> str:
            return self.auto_gemm_backend(
                "all_gather_matmul", m_loc * n_dev, n_out, k,
                dtype_bytes=x.dtype.itemsize,
                fused_ok=self._prefer_fused(
                    x, w, out_bytes=m_loc * n_dev * n_out * 4),
                bidir_ok=(m_loc >= 2), wire=fmt)

        be = self._resolve("all_gather_matmul", backend, auto)
        if be == "ring_bidir":
            be = self._shape_guard(
                "all_gather_matmul", be, backend,
                ok=(m_loc >= 2 or n_dev % 2 != 0),
                constraint="at least 2 local rows to split across the two "
                           "ring directions (m_loc >= 2)",
                fallback="ring")
        if be == "bulk":
            return all_gather_matmul_baseline(x, w, self.axis_name,
                                              preferred=preferred)
        if be in ("ring", "ring_bidir"):
            sched = self.gemm_chunk_schedule(
                "all_gather_matmul", m_loc * n_dev, n_out, k, backend=be,
                dtype_bytes=x.dtype.itemsize, n_chunks=n_chunks,
                chunk_dim=chunk_dim, wire=fmt)
            return pk_all_gather_matmul(x, w, self.axis_name,
                                        bidirectional=(be == "ring_bidir"),
                                        n_chunks=sched.n_chunks,
                                        chunk_dim=sched.chunk_dim,
                                        wire=fmt, fault=self.fault,
                                        preferred=preferred)
        from repro.kernels import ops
        sched = self.gemm_chunk_schedule(
            "all_gather_matmul", m_loc * n_dev, n_out, k, backend="fused",
            dtype_bytes=x.dtype.itemsize, n_chunks=n_chunks,
            chunk_dim=chunk_dim)
        return ops.pk_ag_matmul(x, w, self.axis_name,
                                n_chunks=sched.n_chunks,
                                interpret=self._interpret_mode()
                                ).astype(x.dtype)

    def matmul_reduce_scatter(self, x, w, *, backend: str | None = None,
                              n_chunks: int | None = None,
                              chunk_dim: str | None = None,
                              wire: Any = None,
                              preferred=jnp.float32):
        """x: (m, k_loc); w: (k_loc, n). -> (m_loc, n) = RS(x @ w).

        The tensor-parallel second projection (paper Fig. 8): each device
        holds a K-shard, partial products are reduce-scattered. The ring
        backend computes per-destination blocks and accumulates them around
        the ring, hiding each hop under the next block's GEMM; it requires
        ``m`` divisible by the axis size.

        Example::

            ctx = CommContext(axis_name="model", mesh=mesh)
            # x: (seq, d_ff/n_dev); w: (d_ff/n_dev, d_model)
            y = ctx.matmul_reduce_scatter(x, w)      # -> (seq/n_dev, d_model)
        """
        n_dev = self.axis_size
        m, k_loc = x.shape
        n_out = w.shape[1]
        fmt = self.wire_format(wire)

        def auto() -> str:
            if m % n_dev != 0:
                return "bulk"            # ring needs m divisible by the axis
            return self.auto_gemm_backend(
                "matmul_reduce_scatter", m, n_out, k_loc,
                dtype_bytes=x.dtype.itemsize,
                fused_ok=self._prefer_fused(
                    x, w, out_bytes=(m // n_dev) * n_out * 4), wire=fmt)

        be = self._resolve("matmul_reduce_scatter", backend, auto)
        if be != "bulk":
            be = self._shape_guard(
                "matmul_reduce_scatter", be, backend, ok=(m % n_dev == 0),
                constraint="m divisible by the axis size")
        if be == "bulk":
            return matmul_reduce_scatter_baseline(x, w, self.axis_name,
                                                  preferred=preferred)
        if be == "ring":
            sched = self.gemm_chunk_schedule(
                "matmul_reduce_scatter", m, n_out, k_loc, backend=be,
                dtype_bytes=x.dtype.itemsize, n_chunks=n_chunks,
                chunk_dim=chunk_dim, wire=fmt)
            return pk_matmul_reduce_scatter(x, w, self.axis_name,
                                            n_chunks=sched.n_chunks,
                                            chunk_dim=sched.chunk_dim,
                                            wire=fmt, fault=self.fault,
                                            preferred=preferred)
        from repro.kernels import ops
        sched = self.gemm_chunk_schedule(
            "matmul_reduce_scatter", m, n_out, k_loc, backend="fused",
            dtype_bytes=x.dtype.itemsize, n_chunks=n_chunks,
            chunk_dim=chunk_dim)
        return ops.pk_matmul_rs(x, w, self.axis_name,
                                n_chunks=sched.n_chunks,
                                interpret=self._interpret_mode()
                                ).astype(x.dtype)

    def matmul_all_reduce(self, x, w, *, backend: str | None = None,
                          n_chunks: int | None = None,
                          chunk_dim: str | None = None,
                          wire: Any = None,
                          preferred=jnp.float32):
        """x: (m, k_loc); w: (k_loc, n). -> (m, n) = AR(x @ w).

        Paper Fig. 9. ICI has no in-network reduction, so the overlapped
        backends realize AR as RS (hidden under the GEMM) + AG — same
        2(N-1)/N per-device bytes. Ring needs ``m`` divisible by the axis.

        Example::

            ctx = CommContext(axis_name="model", mesh=mesh)
            y = ctx.matmul_all_reduce(x, w)              # replicated (m, n)
            y = ctx.matmul_all_reduce(x, w, backend="bulk")  # A/B baseline
        """
        n_dev = self.axis_size
        m, k_loc = x.shape
        n_out = w.shape[1]
        fmt = self.wire_format(wire)

        def auto() -> str:
            if m % n_dev != 0:
                return "bulk"
            return self.auto_gemm_backend(
                "matmul_all_reduce", m, n_out, k_loc,
                dtype_bytes=x.dtype.itemsize,
                fused_ok=self._prefer_fused(
                    x, w, out_bytes=(m // n_dev) * n_out * 4), wire=fmt)

        be = self._resolve("matmul_all_reduce", backend, auto)
        if be != "bulk":
            be = self._shape_guard(
                "matmul_all_reduce", be, backend, ok=(m % n_dev == 0),
                constraint="m divisible by the axis size")
        if be == "bulk":
            return matmul_all_reduce_baseline(x, w, self.axis_name,
                                              preferred=preferred)
        if be == "ring":
            sched = self.gemm_chunk_schedule(
                "matmul_all_reduce", m, n_out, k_loc, backend=be,
                dtype_bytes=x.dtype.itemsize, n_chunks=n_chunks,
                chunk_dim=chunk_dim, wire=fmt)
            return pk_matmul_all_reduce(x, w, self.axis_name,
                                        n_chunks=sched.n_chunks,
                                        chunk_dim=sched.chunk_dim,
                                        wire=fmt, fault=self.fault,
                                        preferred=preferred)
        from repro.kernels import ops
        sched = self.gemm_chunk_schedule(
            "matmul_all_reduce", m, n_out, k_loc, backend="fused",
            dtype_bytes=x.dtype.itemsize, n_chunks=n_chunks,
            chunk_dim=chunk_dim)
        # one kernel end to end (RS ring + in-kernel gather) — the old
        # pk_matmul_rs + lax.all_gather composition re-entered XLA for the
        # trailing gather, forfeiting the fused path's single-launch win
        return ops.pk_matmul_ar(x, w, self.axis_name,
                                n_chunks=sched.n_chunks,
                                interpret=self._interpret_mode()
                                ).astype(x.dtype)

    # -- data-movement ops -------------------------------------------------

    def all_to_all(self, x, *, split_axis: int, concat_axis: int,
                   backend: str | None = None, n_chunks: int | None = None,
                   downstream_compute_s: float = 0.0):
        """Re-sharding all-to-all; "chunked" overlaps downstream compute.

        Paper Fig. 11/17 (Ulysses head↔sequence re-sharding, MoE dispatch).
        ``downstream_compute_s`` tells the chunk policy how much compute is
        available to hide later chunks under; ``n_chunks`` forces the count.
        Chunks are cut along a bystander dim, so results are bit-identical
        to bulk.

        Example (Ulysses: seq-sharded -> head-sharded)::

            ctx = CommContext(axis_name="sp", mesh=mesh)
            # q: (b, heads, seq/n_dev, hd) -> (b, heads/n_dev, seq, hd)
            q = ctx.all_to_all(q, split_axis=1, concat_axis=2)
        """

        def auto_chunks() -> int:
            # the policy validates against the chunked sub-shape: counts no
            # bystander dim can split degrade (or drop to bulk) here rather
            # than inside the impl
            return choose_a2a_chunks(
                x.size * x.dtype.itemsize, axis_size=self.axis_size,
                downstream_compute_s=downstream_compute_s,
                hw=self.effective_hw(), shape=x.shape,
                split_axis=split_axis, concat_axis=concat_axis)

        def auto() -> str:
            if n_chunks is not None:
                return "chunked" if n_chunks > 1 else "bulk"
            return "chunked" if auto_chunks() > 1 else "bulk"

        be = self._resolve("all_to_all", backend, auto)
        if be == "bulk":
            return all_to_all_baseline(x, self.axis_name,
                                       split_axis=split_axis,
                                       concat_axis=concat_axis)
        c = n_chunks if n_chunks is not None else auto_chunks()
        return pk_all_to_all(x, self.axis_name, split_axis=split_axis,
                             concat_axis=concat_axis, n_chunks=max(c, 2))

    def psum(self, x, *, backend: str | None = None):
        """All-reduce. "ring" keeps the payload in its dtype (bf16 halves the
        bytes vs XLA's f32-promoted psum) and each hop overlaps compute;
        it requires ``x.shape[0]`` divisible by the axis size.

        Example (MoE combine across experts)::

            ctx = CommContext(axis_name="expert", mesh=mesh)
            y = ctx.psum(partial_outputs)            # policy-routed
            y = ctx.psum(partial_outputs, backend="ring")
        """

        def auto() -> str:
            ring_ok = x.ndim >= 1 and x.shape[0] % self.axis_size == 0
            table = self.active_calibration()
            if table is not None and ring_ok:
                best = table.best_backend(
                    "psum", x.shape[0],
                    max(x.size // max(x.shape[0], 1), 1), 1,
                    allowed=("bulk", "ring"), axis_size=self.axis_size,
                    dtype_bytes=x.dtype.itemsize, island=self.island)
                if best is not None:
                    return best
            if ring_ok and x.dtype == jnp.bfloat16:
                return "ring"
            return "bulk"

        be = self._resolve("psum", backend, auto)
        if be == "ring":
            be = self._shape_guard(
                "psum", be, backend,
                ok=(x.ndim >= 1 and x.shape[0] % self.axis_size == 0),
                constraint="shape[0] divisible by the axis size")
        if be == "bulk":
            return lax.psum(x, self.axis_name)
        return pk_psum_ring(x, self.axis_name)

    def all_gather(self, x, *, axis: int = 0, backend: str | None = None):
        """Tiled all-gather along `axis`.

        Example (FSDP param gather before a block)::

            ctx = CommContext(axis_name="data", mesh=mesh)
            w_full = ctx.all_gather(w_shard)                  # axis 0
            w_full = ctx.all_gather(w_shard, backend="fused") # Pallas kernel
        """
        be = self._resolve("all_gather", backend, lambda: "bulk")
        if be == "bulk":
            return lax.all_gather(x, self.axis_name, axis=axis, tiled=True)
        from repro.kernels import ops
        stacked = ops.pk_all_gather(x, self.axis_name,
                                    interpret=self._interpret_mode())
        return jnp.concatenate([stacked[i] for i in range(self.axis_size)],
                               axis=axis)

    def reduce_scatter(self, x, *, axis: int = 0,
                       backend: str | None = None):
        """Tiled reduce-scatter along `axis`.

        Example (FSDP gradient shard-reduce)::

            ctx = CommContext(axis_name="data", mesh=mesh)
            g_shard = ctx.reduce_scatter(grads)   # (n*d, ...) -> (d, ...)
        """
        be = self._resolve("reduce_scatter", backend, lambda: "bulk")
        if be == "bulk":
            return lax.psum_scatter(x, self.axis_name,
                                    scatter_dimension=axis, tiled=True)
        if axis != 0:
            raise ValueError("fused reduce_scatter supports axis=0 only")
        n_dev = self.axis_size
        from repro.kernels import ops
        parts = x.reshape(n_dev, x.shape[0] // n_dev, *x.shape[1:])
        return ops.pk_reduce_scatter(parts, self.axis_name,
                                     interpret=self._interpret_mode())

    def ring_shift(self, x, *, reverse: bool = False,
                   backend: str | None = None):
        """One-hop ring rotation of a pytree (KV blocks in ring attention,
        SSM boundary states in sequence-parallel Mamba).

        Example (ring attention inner loop)::

            ctx = CommContext(axis_name="sp", mesh=mesh)
            kv = ctx.ring_shift({"k": k, "v": v})    # device d -> d+1
        """
        be = self._resolve("ring_shift", backend, lambda: "bulk")
        if be == "bulk":
            return ring_shift(x, self.axis_name, reverse=reverse)
        if reverse:
            raise ValueError("fused ring_shift sends right only")
        from repro.kernels import ops
        return jax.tree_util.tree_map(
            lambda t: ops.pk_ring_shift(t, self.axis_name,
                                        interpret=self._interpret_mode()), x)


# ---------------------------------------------------------------------------
# jax-level implementations (paper §4.1), moved here from core/collectives.
# Each pk_* function MUST be called inside shard_map with `axis_name` bound.
# Ring direction conventions:
#   "send right": perm (j -> j+1); after i hops device d holds shard (d-i)%n.
#   "send left":  perm (j -> j-1); after i hops device d holds shard (d+i)%n.
# ---------------------------------------------------------------------------


def _perm_right(n: int):
    return [(j, (j + 1) % n) for j in range(n)]


def _perm_left(n: int):
    return [(j, (j - 1) % n) for j in range(n)]


def _axis_info(axis_name):
    n = compat.axis_size(axis_name)
    d = lax.axis_index(axis_name)
    return n, d


# -- chunk plumbing shared by the ring schedules -----------------------------

def _wire_sr_key(wire: WireFormat | None, axis_name: str, salt: int):
    """Deterministic per-device stochastic-rounding key for a quantized
    ring, or None for round-to-nearest wires. Derived from a fixed seed +
    the device's ring position + a per-op salt, so every retrace of the
    same schedule rounds identically (reproducible runs) while no two
    devices or hops share noise."""
    if wire is None or not wire.stochastic_round:
        return None
    key = jax.random.fold_in(jax.random.PRNGKey(1729), salt)
    return jax.random.fold_in(key, lax.axis_index(axis_name))


def _poison_hop(fault, hop: int, t: jax.Array) -> jax.Array:
    """Scripted payload fault (``CommContext.fault``): corrupt ``t`` when
    ``fault`` = (kind, hop') targets ring hop ``hop``. "corrupt" NaNs the
    whole payload, "bitflip" a single element. Float payloads only — a
    quantized wire's int8 payload is poisoned through its f32 scales at
    the call site. Trace-time static: no fault, no extra ops."""
    if fault is None or fault[1] != hop:
        return t
    if not jnp.issubdtype(t.dtype, jnp.floating):
        return t
    if fault[0] == "bitflip":
        return t.at[(0,) * t.ndim].set(jnp.nan)
    return jnp.full_like(t, jnp.nan)


def _row_chunks(t: jax.Array, n_chunks: int) -> list[jax.Array]:
    """Split `t` into `n_chunks` row chunks (fitted to a divisor of the row
    count — the non-divisible fallback validates the chunked sub-shape)."""
    c = fit_chunks(t.shape[0], n_chunks)
    if c == 1:
        return [t]
    return list(jnp.split(t, c, axis=0))


def _col_chunks(t: jax.Array, n_chunks: int) -> list[jax.Array]:
    c = fit_chunks(t.shape[1], n_chunks)
    if c == 1:
        return [t]
    return list(jnp.split(t, c, axis=1))


# -- AG + GEMM (paper Fig. 7) — tensor-parallel first projection. -----------

def all_gather_matmul_baseline(x: jax.Array, w: jax.Array, axis_name: str,
                               *, preferred=jnp.float32) -> jax.Array:
    """x: (m_loc, k) row-sharded over axis; w: (k, n_loc) local TP shard.
    Returns (m, n_loc): bulk all-gather then a single GEMM."""
    x_full = lax.all_gather(x, axis_name, axis=0, tiled=True)
    return jnp.dot(x_full, w, preferred_element_type=preferred).astype(x.dtype)


def _ag_ring_lane(x, w, out, axis_name, *, n, d, row0: int, m_stride: int,
                  reverse: bool, n_chunks: int, chunk_dim: str, preferred,
                  wire: WireFormat | None = None, fault=None):
    """One direction of the chunk-pipelined AG+GEMM ring.

    The travelling shard is split into chunks (rows for chunk_dim="m",
    GEMM output columns for "n"); every step issues the *next* step's
    ppermutes before the current chunk GEMMs consume their operands
    (double-buffered send-ahead), so the per-chunk shifts hide under the
    per-chunk GEMMs at sub-shard granularity.

    With a quantized ``wire``, each travelling chunk is quantized ONCE
    per-row before the first hop and travels the whole ring as an
    (int8 payload, f32 scales) pair; every arrival — the device's own
    chunk included, so ring == bulk-quantized-AG exactly — is dequantized
    to f32 for its GEMM. Because blocks are per-row and chunks slice rows,
    the dequantized values are bit-exact across chunk counts.
    """
    perm = _perm_left(n) if reverse else _perm_right(n)
    if chunk_dim == "n":
        w_chunks = _col_chunks(w, n_chunks)
        cur = [x]
    else:
        w_chunks = [w]
        cur = _row_chunks(x, n_chunks)
    k_cols = x.shape[1]
    if wire is not None:
        key = _wire_sr_key(wire, axis_name, salt=1 if reverse else 0)
        cur = [quantize_blocks(
                   t, block=wire.block,
                   stochastic_key=(None if key is None
                                   else jax.random.fold_in(key, j)))
               for j, t in enumerate(cur)]
    for i in range(n):
        src = (d + i) % n if reverse else (d - i) % n
        # send-ahead: step i+1's shifts are issued before step i's GEMMs,
        # which depend only on the already-held chunks
        if i < n - 1:
            if wire is None:
                nxt = [_poison_hop(fault, i, lax.ppermute(t, axis_name, perm))
                       for t in cur]
            else:
                nxt = [(lax.ppermute(q, axis_name, perm),
                        _poison_hop(fault, i,
                                    lax.ppermute(s, axis_name, perm)))
                       for q, s in cur]
        else:
            nxt = cur
        r = 0
        for t in cur:
            if wire is not None:
                q, s = t
                rows = q.shape[0]
                t = dequantize_blocks(q, s, k_cols)
            else:
                rows = t.shape[0]
            col = 0
            for wc in w_chunks:
                y = jnp.dot(t, wc,
                            preferred_element_type=preferred).astype(x.dtype)
                out = lax.dynamic_update_slice(
                    out, y, (src * m_stride + row0 + r, col))
                col += wc.shape[1]
            r += rows
        cur = nxt
    return out


def pk_all_gather_matmul(x: jax.Array, w: jax.Array, axis_name: str, *,
                         bidirectional: bool = False, n_chunks: int = 1,
                         chunk_dim: str = "m", wire: WireFormat | None = None,
                         fault=None, preferred=jnp.float32) -> jax.Array:
    """Chunk-pipelined AG+GEMM: rotate x shards around the ring; GEMM each
    chunk on arrival. Each ring step is split into `n_chunks` double-buffered
    chunks whose shifts for step i+1 are issued before step i's GEMMs (paper
    §3.1.3 intra-/inter-SM overlap at sub-shard granularity). Chunk counts
    that do not divide the chunked sub-shape degrade to its largest divisor;
    results are bit-identical to the unchunked ring for any count.

    ``bidirectional`` splits the shard across the two ring directions (two
    link-pairs, halving T_comm). The split no longer requires an even
    ``m_loc``: an odd shard splits unevenly (ceil right, floor left) — the
    chunked sub-shapes are what must be sliceable, not the full shard.

    ``wire`` (a quantized ``core.quant.WireFormat``) ships the travelling
    shards as int8 blocks + f32 scales; every consumer — including the
    local device's own shard — sees the dequantized values, so the result
    equals a bulk all-gather of the per-row-quantized input bit-for-bit,
    for any chunk count."""
    n, d = _axis_info(axis_name)
    wire = wire if (wire is not None and wire.quantized) else None
    m_loc, _ = x.shape
    n_out = w.shape[1]
    out = jnp.zeros((n * m_loc, n_out), dtype=x.dtype)

    if not bidirectional or n % 2 != 0 or m_loc < 2:
        return _ag_ring_lane(x, w, out, axis_name, n=n, d=d, row0=0,
                             m_stride=m_loc, reverse=False, n_chunks=n_chunks,
                             chunk_dim=chunk_dim, preferred=preferred,
                             wire=wire, fault=fault)

    # Bidirectional: the shard's top rows travel the right-going ring, the
    # bottom rows the left-going ring — each of the n-1 hops moves part of a
    # shard per direction over two link-pairs, halving T_comm versus the
    # unidirectional ring. Odd m_loc splits ceil/floor (every device uses the
    # same static split, so the ppermute payloads stay uniform).
    h_r = (m_loc + 1) // 2
    x_r, x_l = x[:h_r], x[h_r:]
    out = _ag_ring_lane(x_r, w, out, axis_name, n=n, d=d, row0=0,
                        m_stride=m_loc, reverse=False, n_chunks=n_chunks,
                        chunk_dim=chunk_dim, preferred=preferred, wire=wire,
                        fault=fault)
    return _ag_ring_lane(x_l, w, out, axis_name, n=n, d=d, row0=h_r,
                         m_stride=m_loc, reverse=True, n_chunks=n_chunks,
                         chunk_dim=chunk_dim, preferred=preferred, wire=wire,
                         fault=fault)


# -- GEMM + reduce-scatter (paper Fig. 8 / Table 3) — TP second projection. --

def matmul_reduce_scatter_baseline(x: jax.Array, w: jax.Array, axis_name: str,
                                   *, preferred=jnp.float32) -> jax.Array:
    """x: (m, k_loc); w: (k_loc, n). Returns (m_loc, n) = RS(x @ w).
    Bulk: full partial GEMM then one reduce-scatter."""
    partial = jnp.dot(x, w, preferred_element_type=preferred)
    out = lax.psum_scatter(partial, axis_name, scatter_dimension=0, tiled=True)
    return out.astype(x.dtype)


def pk_matmul_reduce_scatter(x: jax.Array, w: jax.Array, axis_name: str, *,
                             n_chunks: int = 1, chunk_dim: str = "m",
                             wire: WireFormat | None = None,
                             fault=None, preferred=jnp.float32) -> jax.Array:
    """Chunk-pipelined GEMM+RS (accumulate-and-forward ring).

    At step i, device d computes the partial block destined for device
    (d+1+i) % n, adds the accumulator arriving from the right, and forwards
    left. The final step computes d's own block — no trailing permute. The
    per-step GEMM hides the per-step transfer whenever K >= s*R/(2*B)
    (costmodel.hiding_threshold_k).

    With ``n_chunks`` > 1 the per-destination block travels as independent
    chunks (rows for chunk_dim="m", output columns for "n"): chunk j's shift
    is issued before chunk j+1's GEMM is consumed, so the per-chunk hops hide
    under per-chunk compute at sub-block granularity. Chunk counts are fitted
    to the chunked sub-shape (largest divisor), and every count is
    bit-identical to the unchunked ring (GEMM rows/columns are independent
    and the accumulation order around the ring is unchanged).

    A quantized ``wire`` replaces the bf16 accumulator hop with quantize →
    ring-shift (int8 payload + f32 scales) → dequantize-accumulate in f32:
    the accumulator stays f32 between hops locally and only crosses the
    link quantized. ``chunk_dim`` is forced to "m" — blocks are per-row, so
    row chunks keep every scale group intact and the quantized values stay
    bit-exact across chunk counts (column chunks would re-cut the blocks).
    """
    n, d = _axis_info(axis_name)
    wire = wire if (wire is not None and wire.quantized) else None
    if wire is not None:
        chunk_dim = "m"
    m = x.shape[0]
    assert m % n == 0, (m, n)
    m_blk = m // n
    n_out = w.shape[1]

    if chunk_dim == "n":
        c = fit_chunks(n_out, n_chunks)
        w_chunks = _col_chunks(w, c)

        def partial_chunk(b, j):
            xb = lax.dynamic_slice_in_dim(x, b * m_blk, m_blk, axis=0)
            return jnp.dot(xb, w_chunks[j], preferred_element_type=preferred)
    else:
        c = fit_chunks(m_blk, n_chunks)
        sub = m_blk // c

        def partial_chunk(b, j):
            xb = lax.dynamic_slice_in_dim(x, b * m_blk + j * sub, sub, axis=0)
            return jnp.dot(xb, w, preferred_element_type=preferred)

    if wire is not None:
        key = _wire_sr_key(wire, axis_name, salt=2)
        accs = [partial_chunk((d + 1) % n, j).astype(jnp.float32)
                for j in range(c)]
        for i in range(1, n):
            # quantize each chunk accumulator, ship the (int8, scales) pair;
            # send-ahead: all chunk shifts are issued before this step's GEMMs
            qs = [quantize_blocks(
                      a, block=wire.block,
                      stochastic_key=(None if key is None else
                                      jax.random.fold_in(key, i * c + j)))
                  for j, a in enumerate(accs)]
            qs = [(lax.ppermute(q, axis_name, _perm_left(n)),
                   _poison_hop(fault, i - 1,
                               lax.ppermute(s, axis_name, _perm_left(n))))
                  for q, s in qs]
            accs = [dequantize_blocks(q, s, n_out)
                    + partial_chunk((d + 1 + i) % n, j).astype(jnp.float32)
                    for j, (q, s) in enumerate(qs)]
        accs = [a.astype(x.dtype) for a in accs]
        return accs[0] if c == 1 else jnp.concatenate(accs, axis=0)

    # the ring payload travels in the activation dtype (bf16): half the ICI
    # bytes of an f32 accumulator; each hop's add still runs in f32
    accs = [partial_chunk((d + 1) % n, j).astype(x.dtype) for j in range(c)]
    for i in range(1, n):
        # send-ahead: all chunk shifts are issued before this step's GEMMs
        accs = [_poison_hop(fault, i - 1,
                            lax.ppermute(a, axis_name, _perm_left(n)))
                for a in accs]
        accs = [(a.astype(preferred)
                 + partial_chunk((d + 1 + i) % n, j)).astype(x.dtype)
                for j, a in enumerate(accs)]
    if c == 1:
        return accs[0]
    return jnp.concatenate(accs, axis=1 if chunk_dim == "n" else 0)


# -- GEMM + all-reduce (paper Fig. 9). ---------------------------------------

def matmul_all_reduce_baseline(x: jax.Array, w: jax.Array, axis_name: str,
                               *, preferred=jnp.float32) -> jax.Array:
    partial = jnp.dot(x, w, preferred_element_type=preferred)
    return lax.psum(partial, axis_name).astype(x.dtype)


def pk_matmul_all_reduce(x: jax.Array, w: jax.Array, axis_name: str, *,
                         n_chunks: int = 1, chunk_dim: str = "m",
                         wire: WireFormat | None = None,
                         fault=None, preferred=jnp.float32) -> jax.Array:
    """Overlapped GEMM+AR. TPU ICI has no in-network reduction (DESIGN §2.1),
    so the paper's switch-offloaded AR is re-derived as overlapped
    RS(accumulate-on-arrival) + AG: same 2*(N-1)/N per-device traffic, and the
    RS half hides under the GEMM. ``n_chunks``/``chunk_dim`` chunk-pipeline
    the RS half (see ``pk_matmul_reduce_scatter``).

    A quantized ``wire`` applies to BOTH halves: the RS hops ship quantized
    accumulators, and the trailing gather ships each device's reduced shard
    as one more (int8, f32 scales) pair, dequantized after the gather —
    ``wire.stochastic_round`` ("int8_sr") makes every quantize on the path
    unbiased, the GEMM+AR mode where repeated reductions must not drift."""
    n, _ = _axis_info(axis_name)
    wire = wire if (wire is not None and wire.quantized) else None
    rs = pk_matmul_reduce_scatter(x, w, axis_name, n_chunks=n_chunks,
                                  chunk_dim=chunk_dim, wire=wire,
                                  fault=fault, preferred=preferred)
    if wire is None:
        return lax.all_gather(rs, axis_name, axis=0, tiled=True)
    key = _wire_sr_key(wire, axis_name, salt=3)
    q, s = quantize_blocks(rs.astype(jnp.float32), block=wire.block,
                           stochastic_key=key)
    q = lax.all_gather(q, axis_name, axis=0, tiled=True)
    s = lax.all_gather(s, axis_name, axis=0, tiled=True)
    return dequantize_blocks(q, s, rs.shape[-1]).astype(rs.dtype)


# -- Fine-grained all-to-all (paper Fig. 11 / 17). ----------------------------

def all_to_all_baseline(x: jax.Array, axis_name: str, *, split_axis: int,
                        concat_axis: int) -> jax.Array:
    return lax.all_to_all(x, axis_name, split_axis=split_axis,
                          concat_axis=concat_axis, tiled=True)


def pk_all_to_all(x: jax.Array, axis_name: str, *, split_axis: int,
                  concat_axis: int, n_chunks: int = 1) -> jax.Array:
    """Chunked a2a: splitting the payload lets downstream compute start on the
    first chunk while later chunks are still in flight (inter-SM analogue).
    With n_chunks=1 this is the native tiled all-to-all, which — unlike NCCL
    (paper §4.2) — already operates on the strided layout with no reshape.

    Chunks are cut along a *bystander* dim (neither split nor concat) so the
    chunked result is bit-identical to the bulk op. The requested count is
    validated against the chunked sub-shape (``schedule.a2a_chunk_axis``): a
    count no bystander dim divides exactly degrades to the largest feasible
    divisor instead of silently bulking the whole transfer."""
    if n_chunks == 1:
        return all_to_all_baseline(x, axis_name, split_axis=split_axis,
                                   concat_axis=concat_axis)
    fit = a2a_chunk_axis(x.shape, split_axis, concat_axis, n_chunks)
    if fit is None:
        return all_to_all_baseline(x, axis_name, split_axis=split_axis,
                                   concat_axis=concat_axis)
    chunk_axis, c = fit
    chunks = jnp.split(x, c, axis=chunk_axis)
    outs = [lax.all_to_all(t, axis_name, split_axis=split_axis,
                           concat_axis=concat_axis, tiled=True) for t in chunks]
    return jnp.concatenate(outs, axis=chunk_axis)


def pk_psum_ring(y: jax.Array, axis_name: str) -> jax.Array:
    """all-reduce as an explicit accumulate-and-forward ring (RS) + ring AG,
    built from ppermutes — the TPU re-derivation of the paper's in-network
    AR (DESIGN §2.1): same 2(N-1)/N per-device traffic, but the payload
    keeps its dtype (XLA:CPU promotes bf16 all-reduce to f32 — 2x bytes)
    and each hop is independently overlappable with compute."""
    n, d = _axis_info(axis_name)
    lead = y.shape[0]
    if n == 1:
        return y
    if lead % n != 0:
        return lax.psum(y, axis_name)
    blk = lead // n
    parts = y.reshape(n, blk, *y.shape[1:])
    acc = parts[(d + 1) % n]
    for i in range(1, n):
        acc = lax.ppermute(acc, axis_name, _perm_left(n))
        acc = acc + parts[(d + 1 + i) % n]
    out = lax.all_gather(acc, axis_name, axis=0, tiled=True)
    return out.reshape(y.shape)


# -- Ring shift — the PK `store_async`-to-neighbor pattern at jax level. -----

def ring_shift(x, axis_name: str, *, reverse: bool = False):
    """One-hop ring rotation of a pytree (KV blocks in ring attention, SSM
    states in sequence-parallel Mamba)."""
    n = compat.axis_size(axis_name)
    perm = _perm_left(n) if reverse else _perm_right(n)
    return jax.tree_util.tree_map(
        lambda t: lax.ppermute(t, axis_name, perm), x)
