"""Expert parallelism (paper §4.3, Fig. 12) — capacity-based MoE with two
dispatch strategies:

* ``replicated`` (beyond-paper default, DESIGN §4): activations are already
  replicated across the `model` axis after attention (Megatron residual
  stream), so each model-rank simply computes *the experts it owns* on the
  tokens routed to them — dispatch is a local gather, and combine rides the
  same psum the TP-FFN would need anyway. EP adds **zero** extra collectives.
  EP×TP hybrid: ``ep = gcd(E, model)``, ``tp_ff = model // ep`` — rank r owns
  experts ``[(r // tp_ff) * E/ep, ...)`` with an ``ff / tp_ff`` hidden slice.

* ``a2a`` (paper-faithful): GShard-style dispatch — tokens are all-to-all'd to
  the data-rank that owns their expert, expert GEMM, all-to-all back. The PK
  schedule chunks the dispatch so expert GEMM on chunk i overlaps the
  transfer of chunk i+1 (the paper's Comet comparison).

Expert weights for the replicated strategy are stored **device-major** —
``(model_size, E_loc, d, ff_loc)`` sharded ``P('model')`` — i.e. a PGL over
the model axis (core/pgl.py), mirroring the paper's symmetric allocation.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat

from repro.core.comms import CommContext


def ep_tp_split(n_experts: int, model_size: int) -> tuple[int, int]:
    """(ep, tp_ff): expert-parallel degree and per-expert FFN TP degree."""
    ep = math.gcd(n_experts, model_size)
    return ep, model_size // ep


def capacity(n_tokens: int, n_experts: int, top_k: int,
             capacity_factor: float) -> int:
    return max(1, math.ceil(n_tokens * top_k / n_experts * capacity_factor))


class DispatchPlan(NamedTuple):
    """The gating/capacity decision shared by every MoE variant.

    One computation (here) instead of one per dispatch branch: the
    replicated, resident-2D-TP (serve) and a2a paths all chunk their
    capacity loop from the same plan, so a `run.moe_chunks` change can never
    make the trace- and serve-path chunking diverge."""
    cap: int          # per-expert capacity (tokens), clamped to n_tokens
    chunk: int        # tokens per overlap chunk of the capacity loop
    n_chunks: int     # cap // chunk (1 when cap is not chunkable)


def dispatch_plan(n_tokens: int, *, n_experts: int, top_k: int,
                  capacity_factor: float, n_chunks: int = 1) -> DispatchPlan:
    """Capacity + chunking for `n_tokens` routed tokens. `n_chunks` > 1 is
    honored only when it divides the capacity (otherwise one bulk chunk —
    a ragged tail chunk would change the top-k tie-breaking order)."""
    cap = min(capacity(n_tokens, n_experts, top_k, capacity_factor), n_tokens)
    chunk = cap // n_chunks if n_chunks > 1 and cap % n_chunks == 0 else cap
    return DispatchPlan(cap=cap, chunk=chunk, n_chunks=cap // chunk)


class RouterOut(NamedTuple):
    probs: jax.Array      # (T, E) f32
    top_vals: jax.Array   # (T, K) f32
    top_idx: jax.Array    # (T, K) i32


def route(x: jax.Array, router_w: jax.Array, *, top_k: int,
          norm_topk: bool = True) -> RouterOut:
    logits = jnp.dot(x.astype(jnp.float32), router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = lax.top_k(probs, top_k)
    if norm_topk:
        top_vals = top_vals / jnp.maximum(
            top_vals.sum(axis=-1, keepdims=True), 1e-9)
    return RouterOut(probs, top_vals, top_idx.astype(jnp.int32))


def aux_load_balance_loss(r: RouterOut, n_experts: int) -> jax.Array:
    """Switch-style load balance loss (fraction × mean prob per expert)."""
    t = r.top_idx.shape[0]
    frac = jnp.zeros((n_experts,), jnp.float32).at[
        r.top_idx.reshape(-1)].add(1.0) / (t * r.top_idx.shape[1])
    mean_p = r.probs.mean(axis=0)
    return n_experts * jnp.sum(frac * mean_p)


def _local_gates(r: RouterOut, e0, e_loc: int) -> jax.Array:
    """(E_loc, T) combined gate weight of each token for each owned expert."""
    e_ids = e0 + jnp.arange(e_loc)                       # (E_loc,)
    hit = (r.top_idx[:, :, None] == e_ids[None, None, :])  # (T, K, E_loc)
    return jnp.einsum("tke,tk->et", hit.astype(jnp.float32), r.top_vals)


def _expert_ffn(x_sel, w1, w3, w2, *, act=jax.nn.silu):
    """x_sel: (E_loc, C, d); w1/w3: (E_loc, d, f); w2: (E_loc, f, d)."""
    h = jnp.einsum("ecd,edf->ecf", x_sel, w1,
                   preferred_element_type=jnp.float32)
    if w3 is not None:
        h3 = jnp.einsum("ecd,edf->ecf", x_sel, w3,
                        preferred_element_type=jnp.float32)
        h = act(h) * h3
    else:
        h = act(h)
    return jnp.einsum("ecf,efd->ecd", h.astype(x_sel.dtype), w2,
                      preferred_element_type=jnp.float32)


def pk_moe_replicated(x, router_w, w1, w3, w2, *, axis_name: str,
                      n_experts: int, top_k: int,
                      capacity_factor: float = 1.25, norm_topk: bool = True,
                      n_chunks: int = 1, ring_combine: bool = False,
                      plan: DispatchPlan | None = None,
                      ctx: CommContext | None = None):
    """Replicated-dispatch MoE. Call INSIDE shard_map with `axis_name` bound.

    x: (T, d) tokens (replicated over axis). w1/w3: (E_loc, d, ff_loc),
    w2: (E_loc, ff_loc, d) — this rank's device-major slice. Returns
    ((T, d) output, aux_loss). `plan` carries the shared gating/capacity
    decision (repro.models.layers.moe_island computes it once for all
    variants); when None it is derived here from `n_chunks`.
    """
    model_size = compat.axis_size(axis_name)
    r_idx = lax.axis_index(axis_name)
    ep, tp_ff = ep_tp_split(n_experts, model_size)
    e_loc = n_experts // ep
    assert w1.shape[0] == e_loc, (w1.shape, e_loc)
    t = x.shape[0]
    if plan is None:
        plan = dispatch_plan(t, n_experts=n_experts, top_k=top_k,
                             capacity_factor=capacity_factor,
                             n_chunks=n_chunks)
    assert plan.cap <= t, (plan, t)
    cap = plan.cap

    r = route(x, router_w, top_k=top_k, norm_topk=norm_topk)
    e0 = (r_idx // tp_ff) * e_loc
    gates = _local_gates(r, e0, e_loc)                  # (E_loc, T)
    sel_gate, sel_idx = lax.top_k(gates, cap)           # (E_loc, C)
    valid = (sel_gate > 0).astype(jnp.float32)

    y = jnp.zeros((t, x.shape[1]), jnp.float32)
    c_chunk = plan.chunk
    for ci in range(plan.n_chunks):
        sl = slice(ci * c_chunk, (ci + 1) * c_chunk)
        idx_c = sel_idx[:, sl]
        x_sel = jnp.take(x, idx_c.reshape(-1), axis=0).reshape(
            e_loc, c_chunk, x.shape[1])
        out_c = _expert_ffn(x_sel, w1, w3, w2)
        wgt = (sel_gate[:, sl] * valid[:, sl])[..., None]
        y = y.at[idx_c.reshape(-1)].add((out_c * wgt).reshape(-1, x.shape[1]))

    # One psum folds together both the E_loc partition across ep groups and
    # the ff_loc partial sums across the tp_ff subgroups. Reduce in the
    # activation dtype (bf16): halves the dominant EP collective vs f32.
    ctx = ctx if ctx is not None else CommContext(axis_name=axis_name)
    y = ctx.psum(y.astype(x.dtype),
                 backend="ring" if ring_combine else "bulk")
    return y, aux_load_balance_loss(r, n_experts)


def pk_moe_a2a(x, router_w, w1, w3, w2, *, axis_name: str, n_experts: int,
               top_k: int, capacity_factor: float = 1.25,
               norm_topk: bool = True, n_chunks: int = 1,
               plan: DispatchPlan | None = None,
               ctx: CommContext | None = None):
    """Paper-faithful a2a-dispatch MoE (GShard schedule) over `axis_name`
    (typically the data axis). Experts sharded E_loc = E / axis_size; w1/w3:
    (E_loc, d, ff), w2: (E_loc, ff, d). Tokens x: (T, d) local to this rank.

    n_chunks > 1 splits the capacity dim so chunk i's expert GEMM overlaps
    chunk i+1's all-to-all (the PK schedule; n_chunks=1 is the bulk baseline).
    Chunking comes from the same shared `DispatchPlan` as the replicated
    strategy.
    """
    ctx = ctx if ctx is not None else CommContext(axis_name=axis_name)
    n = compat.axis_size(axis_name)
    assert n_experts % n == 0, (n_experts, n)
    e_loc = n_experts // n
    t, d = x.shape
    if plan is None:
        plan = dispatch_plan(t, n_experts=n_experts, top_k=top_k,
                             capacity_factor=capacity_factor,
                             n_chunks=n_chunks)
    c_send = plan.cap

    r = route(x, router_w, top_k=top_k, norm_topk=norm_topk)
    gates = _local_gates(r, 0, n_experts)               # (E, T)
    sel_gate, sel_idx = lax.top_k(gates, c_send)        # (E, C)
    valid = sel_gate > 0

    def chunk_fwd(sl):
        idx_c = sel_idx[:, sl]                          # (E, Cc)
        cc = idx_c.shape[1]
        # Dispatch tensor, destination-rank major: [dst, local_expert, slot].
        x_send = jnp.take(x, idx_c.reshape(-1), axis=0).reshape(
            n, e_loc, cc, d)
        # tiled a2a with split==concat==0 is the "transpose" collective:
        # dim0 becomes the SOURCE rank, payload = tokens for MY experts.
        # (bulk per chunk: the overlap granularity is the capacity loop)
        x_recv = ctx.all_to_all(x_send, split_axis=0, concat_axis=0,
                                backend="bulk")
        x_mine = x_recv.transpose(1, 0, 2, 3).reshape(e_loc, n * cc, d)
        out = _expert_ffn(x_mine.astype(x.dtype), w1, w3, w2)  # (E_loc,n*Cc,d)
        out = (out.astype(x.dtype).reshape(e_loc, n, cc, d)
               .transpose(1, 0, 2, 3))                  # back to [src, j, c]
        back = ctx.all_to_all(out, split_axis=0, concat_axis=0,
                              backend="bulk")           # [owner_rank, j, c]
        y_back = back.reshape(n_experts, cc, d)         # e = r*e_loc + j ✓
        wgt = (sel_gate[:, sl] * valid[:, sl].astype(jnp.float32))[..., None]
        return idx_c, y_back.astype(jnp.float32) * wgt

    y = jnp.zeros((t, d), jnp.float32)
    c_chunk = plan.chunk
    for ci in range(plan.n_chunks):
        idx_c, contrib = chunk_fwd(slice(ci * c_chunk, (ci + 1) * c_chunk))
        y = y.at[idx_c.reshape(-1)].add(contrib.reshape(-1, d))
    return y.astype(x.dtype), aux_load_balance_loss(r, n_experts)


def moe_reference_dense(x, router_w, w1_full, w3_full, w2_full, *,
                        n_experts: int, top_k: int, norm_topk: bool = True):
    """Oracle: every expert on every token, masked combine — no capacity drop.
    Used by tests to bound the capacity-induced error of the parallel paths
    and to check exact equality when capacity_factor covers all tokens."""
    r = route(x, router_w, top_k=top_k, norm_topk=norm_topk)
    outs = _expert_ffn(jnp.broadcast_to(x, (n_experts, *x.shape)),
                       w1_full, w3_full, w2_full)        # (E, T, d)
    gates = _local_gates(r, 0, n_experts)                # (E, T)
    y = jnp.einsum("etd,et->td", outs, gates)
    return y.astype(x.dtype), aux_load_balance_loss(r, n_experts)
