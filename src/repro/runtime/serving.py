"""Template-driven continuous-batching serving engine.

The ROADMAP's serving batcher: a request queue with shape-bucketed
admission, prefill/decode interleaving over one shared slot-cache, and a
per-bucket jitted step cache — where *every* bucket's step program is built
from the unified ``core.template.Island`` declarations with that bucket's
resolved overlap plan threaded back into its ``CommContext``.

The plan loop (the point of the whole engine)::

    per bucket:  island_plans(cfg, run, rules, batch, seq, phase=...)
                      │  trace-free: backend / chunks / hidden fraction,
                      │  measured on a calibrated mesh (island rows first)
                      ▼
                 plan_overrides(plans)  ──►  RunConfig.island_overrides
                      │
                      ▼
                 jit(prefill_step | decode_step)   ← Island.make_context()
                      pins each island to exactly the schedule its
                      recorded plan reported

Prefill buckets run the full-sequence cache-building forward at
(prefill_batch, bucket_len) — their GEMM islands see m = B_loc·L — while the
decode bucket's one-token step sees m = B_loc·1, so on a calibrated mesh the
two can (and do) resolve to different backends or chunk counts for the SAME
island. That is Syncopate's chunk-centric observation applied to serving:
per-phase chunk choices are where overlap wins or dies.

Scheduling: prefill-priority. Each engine step is either one prefill of a
bucket group (up to ``ServeConfig.prefill_batch`` queued requests sharing a
bucket, padded with inert slots so each bucket compiles exactly one program)
or one decode tick over the whole slot pool. Slots hold sequences at
different depths — the decode step runs with a per-slot position vector
(``cache_template(slot_pos=True)``), stale cache masked by ``ki < pos``.

Determinism: admission, eviction, and token choice (greedy argmax) are pure
functions of the submitted trace; ``events`` records every admit/retire so
scheduling regressions are diffable. Continuous-batched outputs are
bit-identical to sequential (one-request-at-a-time) processing — pinned by
tests/test_serving.py on the emulated meshes.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, RunConfig, ServeConfig
from repro.core.template import IslandPlan, plan_overrides, render_plans
from repro.models import transformer as T
from repro.models.layers import island_plans
from repro.models.sharding import ShardingRules
from repro.runtime.straggler import StepTimer, StragglerWatchdog
from repro.train.step import make_prefill_cache_step, make_serve_step

__all__ = ["Request", "Completion", "BucketPlan", "ServingEngine",
           "resolve_serving_plans", "render_serving_plans",
           "serving_plan_record"]


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request. ``prompt`` is the token ids; generation is
    greedy argmax for ``max_new_tokens`` tokens (no EOS in the synthetic
    vocab — length is the stop condition)."""

    rid: int
    prompt: tuple[int, ...]
    max_new_tokens: int


@dataclasses.dataclass
class Completion:
    rid: int
    prompt_len: int
    bucket: int
    tokens: list[int]
    admitted_step: int
    finished_step: int
    slot: int


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """The resolved overlap schedule for one bucket's step program."""

    phase: str                       # "prefill" | "decode"
    bucket: int                      # padded prompt length / pool size
    batch: int
    seq: int
    plans: tuple[IslandPlan, ...]
    overrides: tuple                 # frozen RunConfig.island_overrides

    def asdict(self) -> dict:
        return {"phase": self.phase, "bucket": self.bucket,
                "batch": self.batch, "seq": self.seq,
                "islands": [p.asdict() for p in self.plans],
                "overrides": [list(o) for o in self.overrides]}


def padded_s_max(serve: ServeConfig, rules: ShardingRules | None) -> int:
    """The slot-cache length: worst prompt + generation, rounded up so the
    sequence-sharded KV cache divides the tp axis (extra tail slots are
    never attended — decode masks ``ki < pos``)."""
    tp = rules.mesh.shape[rules.tp] if rules is not None else 1
    return -(-serve.s_max // tp) * tp


def resolve_serving_plans(cfg: ArchConfig, run: RunConfig,
                          rules: ShardingRules | None,
                          serve: ServeConfig) -> dict[str, BucketPlan]:
    """Evaluate ``island_plans()`` per shape bucket: one prefill entry per
    bucket edge (at the bucket's exact (prefill_batch, L) coordinates) plus
    the decode pool's one-token entry. The returned overrides are what the
    engine threads into each bucket's jitted step."""
    out: dict[str, BucketPlan] = {}
    for edge in serve.bucket_edges:
        plans = tuple(island_plans(cfg, run, rules,
                                   batch=serve.prefill_batch, seq=edge,
                                   phase="prefill"))
        out[f"prefill@{edge}"] = BucketPlan(
            "prefill", edge, serve.prefill_batch, edge, plans,
            plan_overrides(plans))
    plans = tuple(island_plans(cfg, run, rules, batch=serve.max_batch,
                               seq=padded_s_max(serve, rules),
                               phase="decode"))
    out["decode"] = BucketPlan("decode", serve.max_batch, serve.max_batch,
                               1, plans, plan_overrides(plans))
    return out


def render_serving_plans(table: dict[str, BucketPlan]) -> str:
    """Printable per-bucket island table (the serve CLI shows this)."""
    lines = []
    for name, bp in table.items():
        lines.append(f"[{name}] batch={bp.batch} seq={bp.seq}")
        lines.append(render_plans(bp.plans))
    return "\n".join(lines)


def serving_plan_record(cfg: ArchConfig, run: RunConfig,
                        rules: ShardingRules | None,
                        serve: ServeConfig) -> dict:
    """JSON-able per-bucket plan table (dry-run artifact / plan diffing):
    resolves the full serving schedule without building the engine, so plan
    regressions are reviewable from the artifact alone."""
    table = resolve_serving_plans(cfg, run, rules, serve)
    return {"config": {"max_batch": serve.max_batch,
                       "prefill_batch": serve.prefill_batch,
                       "bucket_edges": list(serve.bucket_edges),
                       "max_new_tokens": serve.max_new_tokens,
                       "queue_policy": serve.queue_policy},
            "comm_policy": run.comm_policy,
            "buckets": {name: bp.asdict() for name, bp in table.items()}}


@dataclasses.dataclass
class _Slot:
    rid: int
    last_token: int
    remaining: int
    tokens: list[int]
    admitted_step: int
    bucket: int
    prompt_len: int


class ServingEngine:
    """Continuous-batching engine over one (cfg, run, rules, params).

    The caller owns parameter construction/sharding (see
    ``launch.serve.build_engine``); the engine owns the slot cache, the
    request queue, the per-bucket jitted step cache, and the schedule.
    """

    def __init__(self, cfg: ArchConfig, run: RunConfig,
                 rules: ShardingRules | None, params,
                 serve: ServeConfig | None = None):
        self.cfg = cfg
        self.serve = serve if serve is not None else ServeConfig()
        if cfg.encoder_decoder:
            raise NotImplementedError(
                "the continuous-batching engine covers decoder-only models")
        if any(sp.mixer == "mamba" for sp in cfg.layer_pattern()) \
                and not self.serve.exact_buckets:
            raise ValueError(
                "SSM state cannot mask right-padded prompts; use "
                "ServeConfig(exact_buckets=True) for SSM/hybrid archs")
        self.base_run = run
        self.rules = rules
        self.params = params
        # --- per-bucket plan resolution (the startup plan loop) ----------
        self.bucket_plans = resolve_serving_plans(cfg, run, rules, self.serve)
        self._runs = {name: dataclasses.replace(run,
                                                island_overrides=bp.overrides)
                      for name, bp in self.bucket_plans.items()}
        # --- decode pool state -------------------------------------------
        b = self.serve.max_batch
        self.s_max = padded_s_max(self.serve, rules)
        self._cache_tmpl = T.cache_template(cfg, self._runs["decode"], rules,
                                            batch=b, s_max=self.s_max,
                                            slot_pos=True)
        self.cache = self._sharded_zeros(self._cache_tmpl)
        self._decode_fn = jax.jit(
            make_serve_step(cfg, self._runs["decode"], rules),
            donate_argnums=(1,))
        self._prefill_fns: dict[int, Any] = {}     # bucket L -> jitted step
        self._prefill_tmpls: dict[int, Any] = {}
        self._static_fns: dict[tuple[int, int], tuple] = {}
        # --- host-side scheduler state -----------------------------------
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: list[_Slot | None] = [None] * b
        self.completions: dict[int, Completion] = {}
        self.events: list[tuple] = []
        self.step_no = 0
        self.step_kinds: list[str] = []
        self.watchdog = StragglerWatchdog()
        self.step_times: list[float] = []
        self.tokens_generated = 0
        self._next_rid = 0

    # -- plumbing ----------------------------------------------------------

    def _sharded_zeros(self, tmpl):
        tree = jax.tree.map(
            lambda pd: jnp.zeros(pd.shape, pd.dtype), tmpl,
            is_leaf=lambda x: isinstance(x, T.PD))
        if self.rules is None:
            return tree
        specs = T.param_specs(tmpl)
        return jax.tree.map(
            lambda x, s: jax.device_put(x, self.rules.named(s)), tree, specs)

    def _recommit_cache(self, cache):
        """Re-pin the slot cache to its declared shardings after host-side
        scatter updates (``.at[slots].set`` results default-commit)."""
        if self.rules is None:
            return cache
        specs = T.param_specs(self._cache_tmpl)
        return jax.tree.map(
            lambda x, s: jax.device_put(x, self.rules.named(s)), cache, specs)

    def _greedy(self, logits) -> np.ndarray:
        """Next token per slot — the ONE sampling rule both the engine and
        the static baseline use, so batched-vs-sequential equivalence is a
        scheduling property, not a sampling accident."""
        return np.asarray(
            jnp.argmax(logits[:, -1, :self.cfg.vocab_size], axis=-1),
            dtype=np.int32)

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefill_fns:
            name = f"prefill@{bucket}"
            if name not in self.bucket_plans:
                # exact_buckets: lengths inside the largest edge that are
                # not pre-declared resolve their plan on first use
                run = self.base_run
                plans = tuple(island_plans(
                    self.cfg, run, self.rules, batch=self.serve.prefill_batch,
                    seq=bucket, phase="prefill"))
                self.bucket_plans[name] = BucketPlan(
                    "prefill", bucket, self.serve.prefill_batch, bucket,
                    plans, plan_overrides(plans))
                self._runs[name] = dataclasses.replace(
                    run, island_overrides=self.bucket_plans[name].overrides)
            run = self._runs[name]
            self._prefill_fns[bucket] = jax.jit(
                make_prefill_cache_step(self.cfg, run, self.rules),
                donate_argnums=(1,))
            self._prefill_tmpls[bucket] = T.cache_template(
                self.cfg, run, self.rules, batch=self.serve.prefill_batch,
                s_max=self.s_max, slot_pos=True)
        return self._prefill_fns[bucket]

    @property
    def compiled_buckets(self) -> list[int]:
        """Prefill buckets a step has been jitted for (the jit cache)."""
        return sorted(self._prefill_fns)

    # -- request intake ----------------------------------------------------

    def submit(self, prompt: Sequence[int], max_new_tokens: int | None = None,
               rid: int | None = None) -> int:
        prompt = tuple(int(t) for t in prompt)
        if not prompt:
            raise ValueError("empty prompt")
        self.serve.bucket_for(len(prompt))       # validate length up front
        mx = max_new_tokens if max_new_tokens is not None \
            else self.serve.max_new_tokens
        if not 1 <= mx <= self.serve.max_new_tokens:
            # the slot cache is sized for bucket + max_new_tokens; a longer
            # generation would walk pos past s_max and silently drop K/V
            raise ValueError(
                f"max_new_tokens must be in [1, "
                f"{self.serve.max_new_tokens}] (ServeConfig sized the "
                f"cache); got {mx}")
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        self.queue.append(Request(rid, prompt, mx))
        return rid

    # -- scheduling --------------------------------------------------------

    def _next_group(self):
        """(bucket, requests, slot_ids) to prefill next, or None.

        Prefill-priority: whenever slots are free and the queue is
        non-empty, admit. ``fcfs`` takes only the contiguous same-bucket
        prefix behind the queue head; ``bucket-greedy`` scans the whole
        queue for head-bucket requests (may reorder across buckets).
        """
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free or not self.queue:
            return None
        cap = min(len(free), self.serve.prefill_batch)
        head_bucket = self.serve.bucket_for(len(self.queue[0].prompt))
        group = []
        if self.serve.queue_policy == "fcfs":
            for r in self.queue:
                if len(group) == cap or \
                        self.serve.bucket_for(len(r.prompt)) != head_bucket:
                    break
                group.append(r)
        else:                                    # bucket-greedy
            for r in self.queue:
                if len(group) == cap:
                    break
                if self.serve.bucket_for(len(r.prompt)) == head_bucket:
                    group.append(r)
        for r in group:
            self.queue.remove(r)
        return head_bucket, group, free[:len(group)]

    def _prefill(self, bucket: int, reqs: list[Request],
                 slot_ids: list[int]) -> None:
        g = self.serve.prefill_batch
        fn = self._prefill_fn(bucket)
        tokens = np.zeros((g, bucket), np.int32)
        lens = np.ones((g,), np.int32)           # inert pad slots: 1 token
        for i, r in enumerate(reqs):
            tokens[i, :len(r.prompt)] = r.prompt
            lens[i] = len(r.prompt)
        gcache = self._sharded_zeros(self._prefill_tmpls[bucket])
        logits, gcache = fn(self.params, gcache, jnp.asarray(tokens),
                            jnp.asarray(lens))
        first = self._greedy(logits)
        idx = np.asarray(slot_ids)

        def scatter(dst, src):
            if dst.ndim == 1:                    # pos: batch is dim 0
                return dst.at[idx].set(src[:len(reqs)])
            return dst.at[:, idx].set(src[:, :len(reqs)])

        self.cache = self._recommit_cache(
            jax.tree.map(scatter, self.cache, gcache))
        for i, (r, slot) in enumerate(zip(reqs, slot_ids)):
            self.slots[slot] = _Slot(
                rid=r.rid, last_token=int(first[i]),
                remaining=r.max_new_tokens - 1,
                tokens=[int(first[i])], admitted_step=self.step_no,
                bucket=bucket, prompt_len=len(r.prompt))
            self.events.append(("admit", self.step_no, r.rid, slot, bucket))
            self.tokens_generated += 1
            if self.slots[slot].remaining == 0:
                self._retire(slot)

    def _retire(self, slot: int) -> None:
        s = self.slots[slot]
        self.completions[s.rid] = Completion(
            rid=s.rid, prompt_len=s.prompt_len, bucket=s.bucket,
            tokens=list(s.tokens), admitted_step=s.admitted_step,
            finished_step=self.step_no, slot=slot)
        self.events.append(("retire", self.step_no, s.rid, slot))
        self.slots[slot] = None

    def _decode_tick(self) -> None:
        tokens = np.zeros((self.serve.max_batch, 1), np.int32)
        for i, s in enumerate(self.slots):
            if s is not None:
                tokens[i, 0] = s.last_token
        logits, self.cache = self._decode_fn(self.params, self.cache,
                                             jnp.asarray(tokens))
        nxt = self._greedy(logits)
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            s.last_token = int(nxt[i])
            s.tokens.append(s.last_token)
            s.remaining -= 1
            self.tokens_generated += 1
            if s.remaining == 0:
                self._retire(i)

    def step(self) -> str | None:
        """One engine step: a bucket prefill when admission is possible,
        else a decode tick over the pool. Returns the step kind, or None
        when fully idle."""
        active = any(s is not None for s in self.slots)
        group = self._next_group()
        if group is None and not active:
            return None
        with StepTimer() as t:
            if group is not None:
                self._prefill(*group)
                kind = "prefill"
            else:
                self._decode_tick()
                kind = "decode"
        self.step_no += 1
        self.step_kinds.append(kind)
        self.step_times.append(t.dt)
        if self.watchdog.record(self.step_no, t.dt):
            print(f"[serve] STRAGGLER step {self.step_no} ({kind}): "
                  f"{t.dt:.3f}s (deadline {self.watchdog.deadline:.3f}s)")
        return kind

    def run(self, requests=None, max_steps: int = 100_000
            ) -> list[Completion]:
        """Drain the queue (plus ``requests``, submitted first) to
        completion; returns the completions finished during THIS call, in
        submission (rid) order. ``self.completions`` keeps the full
        history across calls."""
        done_before = set(self.completions)
        for r in requests or ():
            if isinstance(r, Request):
                self.submit(r.prompt, r.max_new_tokens, rid=r.rid)
            else:
                self.submit(r)
        for _ in range(max_steps):
            if self.step() is None:
                break
        else:
            raise RuntimeError(f"engine did not drain in {max_steps} steps")
        return [self.completions[k] for k in sorted(self.completions)
                if k not in done_before]

    # -- static baseline + stats ------------------------------------------

    def _static_step_fns(self, n: int, bucket: int) -> tuple:
        """Jitted (prefill, decode, cache template) for a static batch of
        ``n`` at ``bucket`` — cached so repeated static runs (warm-up then
        timing, the bench harness) hit jax's trace cache instead of
        recompiling fresh wrappers every call."""
        key = (n, bucket)
        if key not in self._static_fns:
            run = self.base_run
            pre_plans = tuple(island_plans(self.cfg, run, self.rules,
                                           batch=n, seq=bucket,
                                           phase="prefill"))
            dec_plans = tuple(island_plans(self.cfg, run, self.rules,
                                           batch=n, seq=self.s_max,
                                           phase="decode"))
            run_pre = dataclasses.replace(
                run, island_overrides=plan_overrides(pre_plans))
            run_dec = dataclasses.replace(
                run, island_overrides=plan_overrides(dec_plans))
            tmpl = T.cache_template(self.cfg, run_dec, self.rules, batch=n,
                                    s_max=self.s_max, slot_pos=True)
            self._static_fns[key] = (
                jax.jit(make_prefill_cache_step(self.cfg, run_pre,
                                                self.rules),
                        donate_argnums=(1,)),
                jax.jit(make_serve_step(self.cfg, run_dec, self.rules),
                        donate_argnums=(1,)),
                tmpl)
        return self._static_fns[key]

    def generate_static(self, prompts: Sequence[Sequence[int]],
                        max_new_tokens: int | None = None) -> list[list[int]]:
        """Static-batch baseline: every prompt padded to ONE bucket,
        prefilled as a single batch, decoded in lockstep until the longest
        request finishes (shorter ones over-decode and are trimmed) — the
        throughput bar continuous batching is measured against. Uses the
        same prefill/decode math and greedy rule as the engine."""
        mx = max_new_tokens if max_new_tokens is not None \
            else self.serve.max_new_tokens
        if not 1 <= mx <= self.serve.max_new_tokens:
            raise ValueError(
                f"max_new_tokens must be in [1, "
                f"{self.serve.max_new_tokens}] (ServeConfig sized the "
                f"cache); got {mx}")
        n = len(prompts)
        bucket = self.serve.bucket_for(max(len(p) for p in prompts))
        if any(sp.mixer == "mamba" for sp in self.cfg.layer_pattern()) \
                and any(len(p) != bucket for p in prompts):
            # same invariant the engine's __init__ guard protects: the SSM
            # recurrent state scans right-padding it cannot mask
            raise ValueError(
                "static SSM batches require uniform prompt lengths equal "
                f"to the bucket ({bucket}); got "
                f"{sorted({len(p) for p in prompts})}")
        prefill, decode, tmpl = self._static_step_fns(n, bucket)
        cache = self._sharded_zeros(tmpl)
        tokens = np.zeros((n, bucket), np.int32)
        lens = np.zeros((n,), np.int32)
        for i, p in enumerate(prompts):
            tokens[i, :len(p)] = list(p)
            lens[i] = len(p)
        logits, cache = prefill(self.params, cache, jnp.asarray(tokens),
                                jnp.asarray(lens))
        last = self._greedy(logits)
        out = [[int(t)] for t in last]
        for _ in range(mx - 1):
            logits, cache = decode(self.params, cache,
                                   jnp.asarray(last[:, None]))
            last = self._greedy(logits)
            for i in range(n):
                out[i].append(int(last[i]))
        return [seq[:mx] for seq in out]

    def stats(self) -> dict:
        total = sum(self.step_times)
        return {
            "steps": self.step_no,
            "prefill_steps": self.step_kinds.count("prefill"),
            "decode_steps": self.step_kinds.count("decode"),
            "tokens_generated": self.tokens_generated,
            "wall_s": total,
            "tokens_per_s": self.tokens_generated / total if total else 0.0,
            "straggler_events": len(self.watchdog.events),
            "compiled_buckets": self.compiled_buckets,
        }
