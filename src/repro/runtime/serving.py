"""Template-driven continuous-batching serving engine.

The ROADMAP's serving batcher: a request queue with shape-bucketed
admission, prefill/decode interleaving over one shared slot-cache, and a
per-bucket jitted step cache — where *every* bucket's step program is built
from the unified ``core.template.Island`` declarations with that bucket's
resolved overlap plan threaded back into its ``CommContext``.

The plan loop (the point of the whole engine)::

    per bucket:  island_plans(cfg, run, rules, batch, seq, phase=...)
                      │  trace-free: backend / chunks / hidden fraction,
                      │  measured on a calibrated mesh (island rows first)
                      ▼
                 plan_overrides(plans)  ──►  RunConfig.island_overrides
                      │
                      ▼
                 jit(prefill_step | decode_step)   ← Island.make_context()
                      pins each island to exactly the schedule its
                      recorded plan reported

Prefill buckets run the full-sequence cache-building forward at
(prefill_batch, bucket_len) — their GEMM islands see m = B_loc·L — while the
decode bucket's one-token step sees m = B_loc·1, so on a calibrated mesh the
two can (and do) resolve to different backends or chunk counts for the SAME
island. That is Syncopate's chunk-centric observation applied to serving:
per-phase chunk choices are where overlap wins or dies.

Scheduling: prefill-priority. Each engine step is either one prefill of a
bucket group (up to ``ServeConfig.prefill_batch`` queued requests sharing a
bucket, padded with inert slots so each bucket compiles exactly one program)
or one decode tick over the whole slot pool. Slots hold sequences at
different depths — the decode step runs with a per-slot position vector
(``cache_template(slot_pos=True)``), stale cache masked by ``ki < pos``.

Memory: ``ServeConfig.cache_layout`` picks between the dense per-slot slab
and the paged pool (``runtime/paging.py``): a fixed page pool + per-slot
block tables + a host-side refcounting allocator, with copy-on-write prefix
sharing and page-aligned chunked prefill (``prefill_chunk``) so a long
prompt's prefill is split across engine steps and decode ticks interleave
mid-prefill. Admission in paged mode allocates a request's full page span up
front; pool exhaustion surfaces as admission backpressure (the step decodes
instead, draining pages), never as an error.

Determinism: admission, eviction, and token choice (greedy argmax) are pure
functions of the submitted trace; ``events`` records every admit/retire
(with cache-memory metrics) plus every prefill chunk so scheduling
regressions are diffable. Continuous-batched outputs are bit-identical to
sequential (one-request-at-a-time) processing — pinned by
tests/test_serving.py on the emulated meshes, for both cache layouts.

Fleet hooks (``runtime/fleet.py`` drives N engines as replicas):

* ``run(step_budget=k)`` — cooperative stepping: run at most ``k`` engine
  steps and return, so an external driver can interleave replicas
  deterministically (``step()`` itself stays public for one-at-a-time
  drivers);
* ``drain()`` — stop admitting; in-flight slots finish, queued requests are
  handed back via ``take_queued()`` (the fleet router requeues them);
* ``take_undone()`` — kill support: pop EVERY not-yet-completed request
  (queued + in-flight slots + mid-prefill job rows) exactly once, in rid
  order, so the router can requeue a dead replica's work;
* ``load()`` — router feedback: queued + live slots + mid-prefill rows;
* ``inject_step_delay(dt)`` — tests/fault plans inflate the next recorded
  step time (feeds the watchdog and the fleet's straggler signal without
  wall-clock sleeps);
* ``prefix_match_len(prompt)`` — cache-affinity routing feedback: longest
  prefix the paged ``PrefixCache`` already holds for this prompt.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, RunConfig, ServeConfig
from repro.core.template import IslandPlan, plan_overrides, render_plans
from repro.models import transformer as T
from repro.models.layers import island_plans
from repro.models.sharding import ShardingRules
from repro.runtime import paging
from repro.runtime.health import (COMM_FAULT_KINDS, PAYLOAD_FAULT_KINDS,
                                  CommFaultPlan, HealthMonitor,
                                  demotion_ladder, take_guard_trips)
from repro.runtime.straggler import StepTimer, StragglerWatchdog
from repro.train.step import (make_paged_prefill_step,
                              make_prefill_cache_step, make_serve_step)

__all__ = ["Request", "Completion", "BucketPlan", "ServingEngine",
           "resolve_serving_plans", "render_serving_plans",
           "serving_plan_record"]


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request. ``prompt`` is the token ids; generation is
    greedy argmax for ``max_new_tokens`` tokens (no EOS in the synthetic
    vocab — length is the stop condition)."""

    rid: int
    prompt: tuple[int, ...]
    max_new_tokens: int


@dataclasses.dataclass
class Completion:
    rid: int
    prompt_len: int
    bucket: int
    tokens: list[int]
    admitted_step: int
    finished_step: int
    slot: int


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """The resolved overlap schedule for one bucket's step program."""

    phase: str                       # "prefill" | "decode"
    bucket: int                      # padded prompt length / pool size
    batch: int
    seq: int
    plans: tuple[IslandPlan, ...]
    overrides: tuple                 # frozen RunConfig.island_overrides

    def asdict(self) -> dict:
        return {"phase": self.phase, "bucket": self.bucket,
                "batch": self.batch, "seq": self.seq,
                "islands": [p.asdict() for p in self.plans],
                "overrides": [list(o) for o in self.overrides]}


def padded_s_max(serve: ServeConfig, rules: ShardingRules | None) -> int:
    """The slot-cache length: worst prompt + generation, rounded up so the
    sequence-sharded KV cache divides the tp axis (extra tail slots are
    never attended — decode masks ``ki < pos``)."""
    tp = rules.mesh.shape[rules.tp] if rules is not None else 1
    return -(-serve.s_max // tp) * tp


def resolve_page_geometry(serve: ServeConfig,
                          rules: ShardingRules | None) -> paging.PageGeometry:
    """The engine's page-pool geometry for this (serve, mesh) pair — page
    size padded to the tp stripe, pool partitioned with the slot batch."""
    tp = rules.mesh.shape[rules.tp] if rules is not None else 1
    return paging.resolve_page_geometry(
        serve, s_max=padded_s_max(serve, rules), tp_size=tp,
        n_partitions=paging.page_partitions(rules, serve.max_batch))


def resolve_serving_plans(cfg: ArchConfig, run: RunConfig,
                          rules: ShardingRules | None,
                          serve: ServeConfig) -> dict[str, BucketPlan]:
    """Evaluate ``island_plans()`` per shape bucket: one prefill entry per
    bucket edge (at the bucket's exact (prefill_batch, L) coordinates) plus
    the decode pool's one-token entry. The returned overrides are what the
    engine threads into each bucket's jitted step.

    Paged layout: the decode entry resolves the paged decode island (same
    ``decode_attn`` name and Comm coordinates, so frozen plans carry over),
    and with ``prefill_chunk`` set every bucket shares ONE chunk-shaped
    prefill program — the inventory collapses to a single
    ``prefill@chunk{cl}`` entry at (prefill_batch, chunk) coordinates."""
    paged = serve.cache_layout == "paged"
    ps = resolve_page_geometry(serve, rules).page_size if paged else 0
    out: dict[str, BucketPlan] = {}
    if paged and serve.prefill_chunk:
        cl = serve.prefill_chunk
        plans = tuple(island_plans(cfg, run, rules,
                                   batch=serve.prefill_batch, seq=cl,
                                   phase="prefill", page_size=ps))
        out[f"prefill@chunk{cl}"] = BucketPlan(
            "prefill", cl, serve.prefill_batch, cl, plans,
            plan_overrides(plans))
    else:
        for edge in serve.bucket_edges:
            plans = tuple(island_plans(cfg, run, rules,
                                       batch=serve.prefill_batch, seq=edge,
                                       phase="prefill", page_size=ps))
            out[f"prefill@{edge}"] = BucketPlan(
                "prefill", edge, serve.prefill_batch, edge, plans,
                plan_overrides(plans))
    plans = tuple(island_plans(cfg, run, rules, batch=serve.max_batch,
                               seq=padded_s_max(serve, rules),
                               phase="decode", page_size=ps))
    out["decode"] = BucketPlan("decode", serve.max_batch, serve.max_batch,
                               1, plans, plan_overrides(plans))
    return out


def render_serving_plans(table: dict[str, BucketPlan]) -> str:
    """Printable per-bucket island table (the serve CLI shows this)."""
    lines = []
    for name, bp in table.items():
        lines.append(f"[{name}] batch={bp.batch} seq={bp.seq}")
        lines.append(render_plans(bp.plans))
    return "\n".join(lines)


def serving_plan_record(cfg: ArchConfig, run: RunConfig,
                        rules: ShardingRules | None,
                        serve: ServeConfig) -> dict:
    """JSON-able per-bucket plan table (dry-run artifact / plan diffing):
    resolves the full serving schedule without building the engine, so plan
    regressions are reviewable from the artifact alone."""
    table = resolve_serving_plans(cfg, run, rules, serve)
    s_max = padded_s_max(serve, rules)
    kv_dt = serve.kv_dtype
    cache: dict[str, Any] = {"layout": serve.cache_layout,
                             "s_max": s_max,
                             "kv_dtype": kv_dt,
                             # f32 scale-plane bytes per cached position
                             # (0 in bf16 — no scale leaves exist)
                             "scale_bytes_per_pos": (
                                 cfg.n_layers * cfg.n_kv_heads * 2 * 4
                                 if kv_dt == "int8" else 0),
                             "slab_bytes": paging.slab_hbm_bytes(
                                 cfg, serve.max_batch, s_max,
                                 kv_dtype=kv_dt)}
    if serve.cache_layout == "paged":
        geom = resolve_page_geometry(serve, rules)
        cache.update({
            "page_size": geom.page_size, "n_pages": geom.n_pages,
            "pages_per_slot": geom.pages_per_slot,
            "n_partitions": geom.n_partitions,
            "prefill_chunk": serve.prefill_chunk,
            "pool_bytes": paging.pool_hbm_bytes(cfg, geom, kv_dtype=kv_dt),
            # per-bucket resident-slot capacity at full span (L + max_new)
            "resident_capacity": {
                str(e): geom.resident_capacity(e + serve.max_new_tokens,
                                               serve.max_batch)
                for e in serve.bucket_edges}})
    else:
        cache["resident_capacity"] = {str(e): serve.max_batch
                                      for e in serve.bucket_edges}
    return {"config": {"max_batch": serve.max_batch,
                       "prefill_batch": serve.prefill_batch,
                       "bucket_edges": list(serve.bucket_edges),
                       "max_new_tokens": serve.max_new_tokens,
                       "queue_policy": serve.queue_policy},
            "comm_policy": run.comm_policy,
            "comm_wire": run.comm_wire or "bf16",
            "cache": cache,
            "buckets": {name: bp.asdict() for name, bp in table.items()}}


@dataclasses.dataclass
class _Slot:
    rid: int
    last_token: int
    remaining: int
    tokens: list[int]
    admitted_step: int
    bucket: int
    prompt_len: int


@dataclasses.dataclass
class _PrefillJob:
    """One in-flight chunked paged prefill: a bucket group whose chunks run
    across engine steps (decode ticks interleave between them). Group rows
    are partition-aligned — row ``p*rows_per_part + i`` computes on dp shard
    ``p`` and writes that shard's pool partition."""

    bucket: int
    chunk_len: int
    n_chunks: int                    # ceil(bucket / chunk_len)
    next_chunk: int                  # resumes past fully-shared chunks
    end_chunk: int                   # last chunk any row needs
    reqs: list                       # Request | None per group row
    slot_ids: list                   # int | None per group row
    tokens: np.ndarray               # (G, n_chunks*cl) right-padded prompts
    lens: np.ndarray                 # (G,) real lengths (1 for pad rows)
    write_from: np.ndarray           # (G,) shared-prefix write floor
    group_bt: np.ndarray             # (G, pages_per_slot) global page ids
    pages: list                      # per row: owned page list (refs held)
    shared: list                     # per row: leading shared-page count
    logit_chunk: list                # per row: chunk containing L-1
    first_token: list                # per row: captured greedy first token
    poisoned: list                   # per row: non-finite logits seen
    started_step: int


class ServingEngine:
    """Continuous-batching engine over one (cfg, run, rules, params).

    The caller owns parameter construction/sharding (see
    ``launch.serve.build_engine``); the engine owns the slot cache, the
    request queue, the per-bucket jitted step cache, and the schedule.
    """

    def __init__(self, cfg: ArchConfig, run: RunConfig,
                 rules: ShardingRules | None, params,
                 serve: ServeConfig | None = None,
                 comm_faults: CommFaultPlan | str | None = None):
        self.cfg = cfg
        self.serve = serve if serve is not None else ServeConfig()
        if cfg.encoder_decoder:
            raise NotImplementedError(
                "the continuous-batching engine covers decoder-only models")
        if any(sp.mixer == "mamba" for sp in cfg.layer_pattern()) \
                and not self.serve.exact_buckets:
            raise ValueError(
                "SSM state cannot mask right-padded prompts; use "
                "ServeConfig(exact_buckets=True) for SSM/hybrid archs")
        self.base_run = run
        self.rules = rules
        self.params = params
        # --- per-bucket plan resolution (the startup plan loop) ----------
        self.bucket_plans = resolve_serving_plans(cfg, run, rules, self.serve)
        self._runs = {name: dataclasses.replace(run,
                                                island_overrides=bp.overrides)
                      for name, bp in self.bucket_plans.items()}
        # --- decode pool state -------------------------------------------
        b = self.serve.max_batch
        self.s_max = padded_s_max(self.serve, rules)
        self.paged = self.serve.cache_layout == "paged"
        if self.paged:
            self.geom = resolve_page_geometry(self.serve, rules)
            if self.serve.prefill_batch % self.geom.n_partitions:
                raise ValueError(
                    f"paged prefill groups are partition-aligned: "
                    f"prefill_batch ({self.serve.prefill_batch}) must be a "
                    f"multiple of the pool partition count "
                    f"({self.geom.n_partitions})")
            self._cache_tmpl = paging.paged_cache_template(
                cfg, self._runs["decode"], rules, batch=b, geom=self.geom,
                kv_dtype=self.serve.kv_dtype)
            self.cache = self._sharded_zeros(self._cache_tmpl)
            # block tables start fully unmapped (-1), never all-zeros: a
            # zero row would alias every free slot onto physical page 0
            self._commit_leaf("block_tables",
                              jnp.full((b, self.geom.pages_per_slot), -1,
                                       jnp.int32))
            self.allocator = paging.PageAllocator(self.geom)
            self.prefix = paging.PrefixCache(self.allocator)
            # MoE capacity dropping makes K/V depend on batch composition,
            # so a donor's pages are not reusable bit-for-bit — disable
            # sharing there (chunked prefill itself is still fine)
            self._share_ok = all(sp.mlp == "dense"
                                 for sp in cfg.layer_pattern())
            self._bt_host = np.full((b, self.geom.pages_per_slot), -1,
                                    np.int32)
            self._slot_pages: list[list[int] | None] = [None] * b
        else:
            self.geom = None
            self._cache_tmpl = T.cache_template(
                cfg, self._runs["decode"], rules, batch=b, s_max=self.s_max,
                slot_pos=True, kv_dtype=self.serve.kv_dtype)
            self.cache = self._sharded_zeros(self._cache_tmpl)
        self._job: _PrefillJob | None = None
        self._decode_fn = jax.jit(
            make_serve_step(cfg, self._runs["decode"], rules),
            donate_argnums=(1,))
        self._prefill_fns: dict[int, Any] = {}     # bucket L -> jitted step
        self._prefill_tmpls: dict[int, Any] = {}
        self._static_fns: dict[tuple[int, int], tuple] = {}
        # --- host-side scheduler state -----------------------------------
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: list[_Slot | None] = [None] * b
        self.completions: dict[int, Completion] = {}
        self.events: list[tuple] = []
        self.step_no = 0
        self.step_kinds: list[str] = []
        self.watchdog = StragglerWatchdog()
        self.step_times: list[float] = []
        self.tokens_generated = 0
        self._next_rid = 0
        # fleet hooks: original Request per live rid (so a killed replica's
        # in-flight work can be requeued), admission gate, injected delay
        self._requests: dict[int, Request] = {}
        self.draining = False
        self._injected_delay = 0.0
        # cache-memory accounting (both layouts track peak residency)
        self.prefix_hits = 0
        self.shared_pages_reused = 0
        self.cow_copies = 0
        self.admission_blocked = 0
        self._peak_pages = 0
        self._peak_slots = 0
        # --- runtime health (runtime/health.py) ---------------------------
        if isinstance(comm_faults, str):
            comm_faults = CommFaultPlan.parse(comm_faults)
        self.comm_faults = comm_faults if comm_faults is not None \
            else CommFaultPlan()
        self._active_faults: list[dict] = []
        self._current_fault: tuple | None = None   # (kind, island, hop)
        self._fault_fns: dict[tuple, Any] = {}     # faulted-trace jit cache
        self._base_plans = dict(self.bucket_plans)  # pristine, pre-health
        self._hov: tuple = ()                      # live health overrides
        self._retries: dict[int, int] = {}
        self._not_before: dict[int, int] = {}      # retry backoff gate
        self._submit_step: dict[int, int] = {}
        self.quarantined: dict[int, dict] = {}
        self.expired: dict[int, dict] = {}
        self.health: HealthMonitor | None = None
        self._health_ev_seen = 0
        if self.serve.health_monitor:
            self.health = HealthMonitor(
                self._health_ladders(),
                factor=self.serve.health_factor,
                demote_after=self.serve.health_demote_after,
                probation=self.serve.health_probation)

    # -- plumbing ----------------------------------------------------------

    def _sharded_zeros(self, tmpl):
        tree = jax.tree.map(
            lambda pd: jnp.zeros(pd.shape, pd.dtype), tmpl,
            is_leaf=lambda x: isinstance(x, T.PD))
        if self.rules is None:
            return tree
        specs = T.param_specs(tmpl)
        return jax.tree.map(
            lambda x, s: jax.device_put(x, self.rules.named(s)), tree, specs)

    def _recommit_cache(self, cache):
        """Re-pin the slot cache to its declared shardings after host-side
        scatter updates (``.at[slots].set`` results default-commit)."""
        if self.rules is None:
            return cache
        specs = T.param_specs(self._cache_tmpl)
        return jax.tree.map(
            lambda x, s: jax.device_put(x, self.rules.named(s)), cache, specs)

    def _commit_leaf(self, name: str, val) -> None:
        """Replace ONE top-level cache leaf, re-pinned to its sharding —
        cheaper than recommitting the whole pool for block-table edits."""
        if self.rules is not None:
            spec = T.param_specs(self._cache_tmpl)[name]
            val = jax.device_put(val, self.rules.named(spec))
        self.cache = {**self.cache, name: val}

    def _mem_metrics(self) -> dict:
        """Cache-memory snapshot attached to every admit/retire event."""
        live = sum(s is not None for s in self.slots)
        self._peak_slots = max(self._peak_slots, live)
        m: dict[str, Any] = {"resident_slots": live}
        if self.paged:
            rp = self.allocator.resident_pages
            self._peak_pages = max(self._peak_pages, rp)
            m["resident_pages"] = rp
            m["free_pages"] = self.geom.n_pages - rp
        return m

    def _greedy(self, logits) -> np.ndarray:
        """Next token per slot — the ONE sampling rule both the engine and
        the static baseline use, so batched-vs-sequential equivalence is a
        scheduling property, not a sampling accident."""
        return np.asarray(
            jnp.argmax(logits[:, -1, :self.cfg.vocab_size], axis=-1),
            dtype=np.int32)

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefill_fns:
            name = f"prefill@{bucket}"
            if name not in self.bucket_plans:
                # exact_buckets: lengths inside the largest edge that are
                # not pre-declared resolve their plan on first use
                run = self.base_run
                plans = tuple(island_plans(
                    self.cfg, run, self.rules, batch=self.serve.prefill_batch,
                    seq=bucket, phase="prefill"))
                self.bucket_plans[name] = BucketPlan(
                    "prefill", bucket, self.serve.prefill_batch, bucket,
                    plans, plan_overrides(plans))
                self._base_plans[name] = self.bucket_plans[name]
                # live health demotions layer above the fresh plan too
                self._runs[name] = dataclasses.replace(
                    run, island_overrides=(
                        self.bucket_plans[name].overrides + self._hov))
            run = self._runs[name]
            self._prefill_fns[bucket] = jax.jit(
                make_prefill_cache_step(self.cfg, run, self.rules),
                donate_argnums=(1,))
            self._prefill_tmpls[bucket] = T.cache_template(
                self.cfg, run, self.rules, batch=self.serve.prefill_batch,
                s_max=self.s_max, slot_pos=True,
                kv_dtype=self.serve.kv_dtype)
        return self._prefill_fns[bucket]

    def _paged_prefill_fn(self, bucket: int):
        """Jitted chunk program, keyed by chunk length: with
        ``prefill_chunk`` set every bucket shares ONE (G, cl) program; in
        single-shot paged mode (chunk = bucket) it is per-bucket like the
        slab path."""
        cl = self.serve.prefill_chunk or bucket
        if cl not in self._prefill_fns:
            name = (f"prefill@chunk{cl}" if self.serve.prefill_chunk
                    else f"prefill@{bucket}")
            if name not in self.bucket_plans:
                run = self.base_run
                plans = tuple(island_plans(
                    self.cfg, run, self.rules,
                    batch=self.serve.prefill_batch, seq=cl, phase="prefill",
                    page_size=self.geom.page_size))
                self.bucket_plans[name] = BucketPlan(
                    "prefill", bucket, self.serve.prefill_batch, cl,
                    plans, plan_overrides(plans))
                self._base_plans[name] = self.bucket_plans[name]
                self._runs[name] = dataclasses.replace(
                    run, island_overrides=(
                        self.bucket_plans[name].overrides + self._hov))
            self._prefill_fns[cl] = jax.jit(
                make_paged_prefill_step(self.cfg, self._runs[name],
                                        self.rules),
                donate_argnums=(1,))
        return self._prefill_fns[cl]

    @property
    def compiled_buckets(self) -> list[int]:
        """Prefill buckets a step has been jitted for (the jit cache)."""
        return sorted(self._prefill_fns)

    # -- runtime health ----------------------------------------------------

    def _health_ladders(self) -> dict:
        """island -> demotion ladder, from the planned backends. The first
        bucket declaring an island wins (the ladders only need the backend
        family, which is stable across buckets)."""
        ladders: dict[str, tuple] = {}
        for bp in self.bucket_plans.values():
            for p in bp.plans:
                if p.fallback or p.backend is None or p.island in ladders:
                    continue
                lad = demotion_ladder(p.backend)
                if lad:
                    ladders[p.island] = lad
        return ladders

    def inject_comm_fault(self, kind: str, island: str, ticks: int = 1,
                          hop: int = 0, stall_dt: float = 1.0) -> None:
        """Activate a comms-level fault NOW, for ``ticks`` engine steps —
        the fleet's (and the scripted ``CommFaultPlan``'s) entry point."""
        if kind not in COMM_FAULT_KINDS:
            raise ValueError(f"unknown comm fault kind {kind!r}; one of "
                             f"{COMM_FAULT_KINDS}")
        self._active_faults.append({"kind": kind, "island": island,
                                    "hop": int(hop),
                                    "remaining": max(1, int(ticks)),
                                    "stall_dt": float(stall_dt)})
        self.events.append(("comm_fault", self.step_no, kind, island,
                            max(1, int(ticks))))
        if kind == "linkdown" and self.health is not None:
            if self.health.link_down(island, self.step_no):
                self._refresh_health_overrides()

    def _fire_comm_faults(self) -> None:
        """Activate scripted events for the step ABOUT to run, then pick the
        payload fault (if any) this step's traces must carry."""
        for ev in self.comm_faults.at(self.step_no + 1):
            self.inject_comm_fault(ev.kind, ev.island, ticks=ev.ticks,
                                   hop=ev.hop, stall_dt=ev.stall_dt)
        self._current_fault = None
        for f in self._active_faults:
            if f["kind"] in PAYLOAD_FAULT_KINDS:
                self._current_fault = (f["kind"], f["island"], f["hop"])
                break

    def _tick_comm_faults(self) -> None:
        still = []
        for f in self._active_faults:
            f["remaining"] -= 1
            if f["remaining"] > 0:
                still.append(f)
            else:
                self.events.append(("comm_fault_end", self.step_no,
                                    f["kind"], f["island"]))
                if f["kind"] == "linkdown" and self.health is not None:
                    # link restored; re-promotion earns its way back through
                    # the probation window, not instantly
                    self.health.link_up(f["island"], self.step_no)
        self._active_faults = still

    def _faulted_fn(self, key: tuple, fault: tuple):
        """Jitted step variant whose ``RunConfig.comm_fault`` poisons the
        targeted ring hop (trace-time static) — cached per (program, fault)
        so repeated fault ticks reuse the compiled program."""
        k = (key, fault)
        if k not in self._fault_fns:
            phase, bucket = key
            if phase == "decode":
                run = dataclasses.replace(self._runs["decode"],
                                          comm_fault=fault)
                self._fault_fns[k] = jax.jit(
                    make_serve_step(self.cfg, run, self.rules),
                    donate_argnums=(1,))
            elif phase == "paged":
                self._paged_prefill_fn(bucket)     # materialize the plan
                cl = self.serve.prefill_chunk or bucket
                name = (f"prefill@chunk{cl}" if self.serve.prefill_chunk
                        else f"prefill@{bucket}")
                run = dataclasses.replace(self._runs[name], comm_fault=fault)
                self._fault_fns[k] = jax.jit(
                    make_paged_prefill_step(self.cfg, run, self.rules),
                    donate_argnums=(1,))
            else:
                self._prefill_fn(bucket)           # materialize the plan
                run = dataclasses.replace(self._runs[f"prefill@{bucket}"],
                                          comm_fault=fault)
                self._fault_fns[k] = jax.jit(
                    make_prefill_cache_step(self.cfg, run, self.rules),
                    donate_argnums=(1,))
        return self._fault_fns[k]

    def _stall_applies(self, island: str, kind: str) -> bool:
        """A scripted link stall penalizes a step only while the island's
        CURRENT effective backend still rides the slow link (a ring-family
        schedule). A health demotion to bulk routes around it — which is
        exactly the throughput recovery the monitor's demotion buys."""
        names = ([n for n, bp in self.bucket_plans.items()
                  if bp.phase == "prefill"] if kind == "prefill"
                 else ["decode"])
        for n in names:
            for p in self.bucket_plans[n].plans:
                if p.fallback or p.island != island:
                    continue
                # health overrides patch bucket_plans live, so p.backend
                # already reflects any demotion
                if p.backend in ("ring", "ring_bidir", "chunked", "fused"):
                    return True
        return False

    def _refresh_health_overrides(self) -> None:
        """Re-layer the monitor's demotions (source ``"health"``) above every
        bucket's frozen plan overrides, patch the live plan records, and
        re-jit the step programs. The calibration table and the measured
        dispatch below this layer are never touched — promotion is just the
        override disappearing."""
        hov = self.health.overrides() if self.health is not None else ()
        self._hov = hov
        by_island = {o[0]: o for o in hov}
        for name, base in self._base_plans.items():
            plans = tuple(
                dataclasses.replace(
                    p, backend=by_island[p.island][1],
                    n_chunks=(by_island[p.island][2]
                              if by_island[p.island][2] is not None
                              else p.n_chunks),
                    source="health",
                    reason=f"health demotion -> {by_island[p.island][1]}")
                if (not p.fallback and p.island in by_island) else p
                for p in base.plans)
            ov = base.overrides + hov
            self.bucket_plans[name] = dataclasses.replace(
                base, plans=plans, overrides=ov)
            self._runs[name] = dataclasses.replace(
                self.base_run, island_overrides=ov)
        self._decode_fn = jax.jit(
            make_serve_step(self.cfg, self._runs["decode"], self.rules),
            donate_argnums=(1,))
        self._prefill_fns.clear()
        self._fault_fns.clear()

    def _drain_health_events(self) -> None:
        if self.health is None:
            return
        for ev in self.health.events[self._health_ev_seen:]:
            self.events.append(("health_" + ev[0],) + tuple(ev[1:]))
        self._health_ev_seen = len(self.health.events)

    def plan_record(self) -> dict:
        """LIVE per-bucket plan table — unlike ``serving_plan_record()``,
        which re-resolves from config, this reflects runtime health
        demotions (``src=health`` islands and the layered overrides)."""
        return {"buckets": {n: bp.asdict()
                            for n, bp in self.bucket_plans.items()},
                "health_overrides": [list(o) for o in self._hov]}

    def _finite_rows(self, logits) -> np.ndarray:
        """(B,) bool per batch row: the final-position logits are all
        finite — the poison detector (NaN and ±inf both trip it)."""
        v = self.cfg.vocab_size
        return np.asarray(jnp.isfinite(
            jnp.max(jnp.abs(logits[:, -1, :v]), axis=-1)))

    def _poisoned(self, req: Request, reason: str) -> None:
        """Retry-with-backoff, or quarantine once retries are exhausted."""
        attempt = self._retries.get(req.rid, 0)
        if attempt < self.serve.max_retries:
            self._retries[req.rid] = attempt + 1
            self._not_before[req.rid] = (
                self.step_no + self.serve.retry_backoff * (2 ** attempt))
            self.queue.append(req)
            self.events.append(("retry", self.step_no, req.rid, attempt + 1))
        else:
            self.quarantined[req.rid] = {"prompt_len": len(req.prompt),
                                         "step": self.step_no,
                                         "reason": reason}
            self._requests.pop(req.rid, None)
            self.events.append(("quarantine", self.step_no, req.rid))

    def _evict_slot(self, slot: int) -> None:
        """Drop a live slot WITHOUT a completion (quarantine/deadline).
        Slab cache rows left behind are inert — the next admission into the
        slot overwrites every position it will ever attend to."""
        s = self.slots[slot]
        self._requests.pop(s.rid, None)
        self.slots[slot] = None
        if self.paged:
            self._bt_host[slot] = -1
            self._commit_leaf("block_tables",
                              self.cache["block_tables"].at[slot].set(-1))
            self.allocator.release(self._slot_pages[slot] or [])
            self._slot_pages[slot] = None

    def _expire_deadlines(self) -> None:
        dl = self.serve.deadline_steps
        if not dl:
            return
        for r in [r for r in self.queue
                  if self.step_no - self._submit_step.get(r.rid,
                                                          self.step_no) >= dl]:
            self.queue.remove(r)
            self._requests.pop(r.rid, None)
            self.expired[r.rid] = {"tokens": [], "step": self.step_no,
                                   "where": "queued"}
            self.events.append(("deadline", self.step_no, r.rid))
        for i, s in enumerate(self.slots):
            if s is not None and (self.step_no - self._submit_step.get(
                    s.rid, self.step_no)) >= dl:
                self.expired[s.rid] = {"tokens": list(s.tokens),
                                       "step": self.step_no, "where": "slot"}
                self.events.append(("deadline", self.step_no, s.rid))
                self._evict_slot(i)

    # -- request intake ----------------------------------------------------

    def submit(self, prompt: Sequence[int], max_new_tokens: int | None = None,
               rid: int | None = None) -> int:
        prompt = tuple(int(t) for t in prompt)
        if not prompt:
            raise ValueError("empty prompt")
        self.serve.bucket_for(len(prompt))       # validate length up front
        mx = max_new_tokens if max_new_tokens is not None \
            else self.serve.max_new_tokens
        if not 1 <= mx <= self.serve.max_new_tokens:
            # the slot cache is sized for bucket + max_new_tokens; a longer
            # generation would walk pos past s_max and silently drop K/V
            raise ValueError(
                f"max_new_tokens must be in [1, "
                f"{self.serve.max_new_tokens}] (ServeConfig sized the "
                f"cache); got {mx}")
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        req = Request(rid, prompt, mx)
        self._requests[rid] = req
        self._submit_step[rid] = self.step_no    # deadline clock starts now
        self.queue.append(req)
        return rid

    # -- fleet hooks -------------------------------------------------------

    @property
    def pending(self) -> bool:
        """True while any submitted request has not completed yet."""
        return (bool(self.queue) or self._job is not None
                or any(s is not None for s in self.slots))

    def drain(self) -> None:
        """Stop admitting: queued requests stay queued (the fleet router
        takes them via ``take_queued``), in-flight slots finish normally."""
        if not self.draining:
            self.draining = True
            self.events.append(("drain", self.step_no))

    def take_queued(self) -> list[Request]:
        """Pop every queued (not yet admitted) request, in queue order —
        the drain-snapshot hook: nothing on-device references these."""
        out = list(self.queue)
        self.queue.clear()
        return out

    def take_undone(self) -> list[Request]:
        """Pop EVERY not-yet-completed request — queued, mid-prefill job
        rows, and in-flight decode slots — exactly once, in rid order. The
        kill hook: the engine is dead afterwards (its device state is
        abandoned), the returned originals are what the router requeues."""
        undone: dict[int, Request] = {r.rid: r for r in self.queue}
        self.queue.clear()
        if self._job is not None:
            for r in self._job.reqs:
                if r is not None:
                    undone[r.rid] = r
            self._job = None
        for i, s in enumerate(self.slots):
            if s is not None:
                undone[s.rid] = self._requests[s.rid]
                self.slots[i] = None
        return [undone[k] for k in sorted(undone)]

    def load(self) -> int:
        """Router feedback: queued + live decode slots + mid-prefill job
        rows — everything this replica still owes compute to."""
        job_rows = 0 if self._job is None \
            else sum(r is not None for r in self._job.reqs)
        return (len(self.queue) + sum(s is not None for s in self.slots)
                + job_rows)

    def inject_step_delay(self, dt: float) -> None:
        """Inflate the NEXT recorded step time by ``dt`` seconds (fault
        injection: feeds the watchdog and the fleet straggler signal
        deterministically, without a wall-clock sleep)."""
        self._injected_delay += dt

    def prefix_match_len(self, prompt: Sequence[int]) -> int:
        """Longest prefix of ``prompt`` the paged ``PrefixCache`` already
        holds (0 for the slab layout or when sharing is disabled) — the
        feedback the fleet's cache-affinity router steers on."""
        if not self.paged or not self._share_ok:
            return 0
        prompt = tuple(int(t) for t in prompt)
        sched = ("chunk", self.serve.prefill_chunk
                 or self.serve.bucket_for(len(prompt)))
        return max(self.prefix.lookup(p, prompt, sched)[0]
                   for p in range(self.geom.n_partitions))

    # -- scheduling --------------------------------------------------------

    def _next_group(self):
        """(bucket, requests, slot_ids) to prefill next, or None.

        Prefill-priority: whenever slots are free and the queue is
        non-empty, admit. ``fcfs`` takes only the contiguous same-bucket
        prefix behind the queue head; ``bucket-greedy`` scans the whole
        queue for head-bucket requests (may reorder across buckets).
        """
        free = [i for i, s in enumerate(self.slots) if s is None]
        # retry backoff: a poisoned request sits out until its gate opens
        eligible = [r for r in self.queue
                    if self._not_before.get(r.rid, 0) <= self.step_no]
        if self.draining or not free or not eligible:
            return None
        cap = min(len(free), self.serve.prefill_batch)
        head_bucket = self.serve.bucket_for(len(eligible[0].prompt))
        group = []
        if self.serve.queue_policy == "fcfs":
            for r in eligible:
                if len(group) == cap or \
                        self.serve.bucket_for(len(r.prompt)) != head_bucket:
                    break
                group.append(r)
        else:                                    # bucket-greedy
            for r in eligible:
                if len(group) == cap:
                    break
                if self.serve.bucket_for(len(r.prompt)) == head_bucket:
                    group.append(r)
        for r in group:
            self.queue.remove(r)
        return head_bucket, group, free[:len(group)]

    # -- paged scheduling --------------------------------------------------

    def _next_group_paged(self):
        """Paged admission: place queued head-bucket requests into
        partition-aligned group rows, allocating each request's FULL page
        span (prompt + max_new — no mid-decode allocation) up front, with
        prefix-share lookup against the registry. Stops at the first
        request that fits nowhere (strict order → deterministic
        backpressure); returns (bucket, placements) or None. Each placement
        is (request, slot, row, pages, n_shared, cow_src, write_from)."""
        if self.draining or self._job is not None or not self.queue:
            return None
        eligible = [r for r in self.queue
                    if self._not_before.get(r.rid, 0) <= self.step_no]
        if not eligible:
            return None
        geom, serve = self.geom, self.serve
        b_loc = serve.max_batch // geom.n_partitions
        rows_per_part = serve.prefill_batch // geom.n_partitions
        free = {p: [i for i in range(p * b_loc, (p + 1) * b_loc)
                    if self.slots[i] is None]
                for p in range(geom.n_partitions)}
        if not any(free.values()):
            return None
        head_bucket = serve.bucket_for(len(eligible[0].prompt))
        if serve.queue_policy == "fcfs":
            cands = []
            for r in eligible:
                if serve.bucket_for(len(r.prompt)) != head_bucket:
                    break
                cands.append(r)
        else:                                    # bucket-greedy
            cands = [r for r in eligible
                     if serve.bucket_for(len(r.prompt)) == head_bucket]
        sched = ("chunk", serve.prefill_chunk or head_bucket)
        placements, used = [], {p: 0 for p in range(geom.n_partitions)}
        blocked = False
        for r in cands:
            if len(placements) == serve.prefill_batch:
                break
            need = geom.pages_for(len(r.prompt) + r.max_new_tokens)
            placed = False
            for p in range(geom.n_partitions):
                if not free[p] or used[p] >= rows_per_part:
                    continue
                shared, cow_src, wf = [], None, 0
                if self._share_ok:
                    m, ent = self.prefix.lookup(p, r.prompt, sched)
                    if ent is not None and m:
                        nfull = m // geom.page_size
                        shared = list(ent.pages[:nfull])
                        if m % geom.page_size and nfull < len(ent.pages):
                            cow_src = ent.pages[nfull]
                        wf = m
                # retain BEFORE the eviction loop so evicting the donor
                # entry cannot free the pages we are about to share
                self.allocator.retain(shared)
                if cow_src is not None:
                    self.allocator.retain([cow_src])
                while True:
                    fresh = self.allocator.alloc(p, need - len(shared))
                    if fresh is not None or not self.prefix.evict_one(p):
                        break
                if fresh is None:
                    self.allocator.release(shared)
                    if cow_src is not None:
                        self.allocator.release([cow_src])
                    continue
                slot = free[p].pop(0)
                row = p * rows_per_part + used[p]
                used[p] += 1
                placements.append((r, slot, row, shared + fresh,
                                   len(shared), cow_src, wf))
                placed = True
                break
            if not placed:
                blocked = True
                break
        if not placements:
            if blocked:
                self.admission_blocked += 1
            return None
        for pl in placements:
            self.queue.remove(pl[0])
        return head_bucket, placements

    def _start_prefill_job(self, bucket: int, placements: list) -> None:
        geom, serve = self.geom, self.serve
        g = serve.prefill_batch
        cl = serve.prefill_chunk or bucket
        n_chunks = -(-bucket // cl)
        job = _PrefillJob(
            bucket=bucket, chunk_len=cl, n_chunks=n_chunks,
            next_chunk=n_chunks - 1, end_chunk=0,
            reqs=[None] * g, slot_ids=[None] * g,
            tokens=np.zeros((g, n_chunks * cl), np.int32),
            lens=np.ones((g,), np.int32),
            write_from=np.zeros((g,), np.int32),
            group_bt=np.full((g, geom.pages_per_slot), -1, np.int32),
            pages=[[] for _ in range(g)], shared=[0] * g,
            logit_chunk=[0] * g, first_token=[None] * g,
            poisoned=[False] * g,
            started_step=self.step_no)
        copies = []
        for (r, slot, row, pages, nsh, cow_src, wf) in placements:
            length = len(r.prompt)
            job.reqs[row], job.slot_ids[row] = r, slot
            job.tokens[row, :length] = r.prompt
            job.lens[row] = length
            job.write_from[row] = wf
            job.group_bt[row, :len(pages)] = pages
            job.pages[row], job.shared[row] = pages, nsh
            if cow_src is not None:
                # boundary page: device-copy donor -> first fresh page
                copies.append((cow_src, pages[nsh]))
                self.cow_copies += 1
            if wf:
                self.prefix_hits += 1
                self.shared_pages_reused += nsh
            lc = (length - 1) // cl
            job.logit_chunk[row] = lc
            job.end_chunk = max(job.end_chunk, lc)
            # a fully-shared prefix still owes the logits chunk (min)
            job.next_chunk = min(job.next_chunk, min(wf // cl, lc))
        if copies:
            self._cow_device_copy(copies)
        for (_, _, _, _, _, cow_src, _) in placements:
            if cow_src is not None:
                self.allocator.release([cow_src])   # admission's temp retain
        self._job = job

    def _cow_device_copy(self, copies: list[tuple[int, int]]) -> None:
        """Copy donor boundary pages into fresh ones across every layer's
        K/V pool (page dim is axis 1: (periods, pages, Hkv, page, hd)).
        src and dst always share a partition, so the copy is shard-local."""
        src = jnp.asarray([s for s, _ in copies])
        dst = jnp.asarray([d for _, d in copies])
        blocks = jax.tree.map(lambda x: x.at[:, dst].set(x[:, src]),
                              self.cache["blocks"])
        self.cache = self._recommit_cache({**self.cache, "blocks": blocks})

    def _prefill_chunk_step(self) -> None:
        """Run the job's next chunk; the live cache's block-table rows stay
        at the -1 sentinel until ``_finish_prefill_job`` commits, so decode
        ticks interleaved between chunks cannot touch half-built pages."""
        job = self._job
        c = job.next_chunk
        c0 = c * job.chunk_len
        fn = self._paged_prefill_fn(job.bucket)
        if self._current_fault is not None:
            fn = self._faulted_fn(("paged", job.bucket), self._current_fault)
        logits, self.cache = fn(
            self.params, self.cache,
            jnp.asarray(job.tokens[:, c0:c0 + job.chunk_len]),
            jnp.asarray(job.group_bt), jnp.asarray(job.lens),
            jnp.asarray(c0, jnp.int32), jnp.asarray(job.write_from))
        finite = self._finite_rows(logits)
        first = self._greedy(logits)
        for row, r in enumerate(job.reqs):
            if r is not None and job.logit_chunk[row] == c:
                if not finite[row]:
                    job.poisoned[row] = True
                job.first_token[row] = int(first[row])
        self.events.append(
            ("prefill_chunk", self.step_no,
             tuple(r.rid for r in job.reqs if r is not None),
             c, job.n_chunks))
        job.next_chunk += 1
        if job.next_chunk > job.end_chunk:
            self._finish_prefill_job()

    def _finish_prefill_job(self) -> None:
        """Last chunk done: commit block-table rows + positions into the
        live cache, open the slots, register prompts for prefix sharing."""
        job, geom = self._job, self.geom
        self._job = None
        # poisoned rows never commit: block-table rows stay -1, their pages
        # go back to the pool, and the request retries or quarantines
        for i in [i for i, r in enumerate(job.reqs)
                  if r is not None and job.poisoned[i]]:
            self.allocator.release(job.pages[i])
            self._poisoned(job.reqs[i], "prefill_nonfinite")
        rows = [i for i, r in enumerate(job.reqs)
                if r is not None and not job.poisoned[i]]
        if not rows:
            return
        idx = np.asarray([job.slot_ids[i] for i in rows])
        for i in rows:
            self._bt_host[job.slot_ids[i]] = job.group_bt[i]
        self._commit_leaf("block_tables",
                          self.cache["block_tables"]
                          .at[idx].set(jnp.asarray(job.group_bt[rows])))
        self._commit_leaf("pos", self.cache["pos"]
                          .at[idx].set(jnp.asarray(job.lens[rows])))
        for i in rows:
            r, slot = job.reqs[i], job.slot_ids[i]
            self._slot_pages[slot] = job.pages[i]
            if self._share_ok:
                part = geom.slot_partition(slot, self.serve.max_batch)
                self.prefix.register(
                    part, r.prompt,
                    job.pages[i][:geom.pages_for(len(r.prompt))],
                    ("chunk", job.chunk_len))
            tok = job.first_token[i]
            self.slots[slot] = _Slot(
                rid=r.rid, last_token=tok, remaining=r.max_new_tokens - 1,
                tokens=[tok], admitted_step=job.started_step,
                bucket=job.bucket, prompt_len=len(r.prompt))
            self.tokens_generated += 1
            self.events.append(("admit", self.step_no, r.rid, slot,
                                job.bucket, self._mem_metrics()))
            if self.slots[slot].remaining == 0:
                self._retire(slot)

    def _prefill(self, bucket: int, reqs: list[Request],
                 slot_ids: list[int]) -> None:
        g = self.serve.prefill_batch
        fn = self._prefill_fn(bucket)
        if self._current_fault is not None:
            fn = self._faulted_fn(("prefill", bucket), self._current_fault)
        tokens = np.zeros((g, bucket), np.int32)
        lens = np.ones((g,), np.int32)           # inert pad slots: 1 token
        for i, r in enumerate(reqs):
            tokens[i, :len(r.prompt)] = r.prompt
            lens[i] = len(r.prompt)
        gcache = self._sharded_zeros(self._prefill_tmpls[bucket])
        logits, gcache = fn(self.params, gcache, jnp.asarray(tokens),
                            jnp.asarray(lens))
        finite = self._finite_rows(logits)
        first = self._greedy(logits)
        # only finite rows scatter into the live cache and open slots —
        # poisoned rows retry or quarantine, and because every slot's cache
        # row is independent the survivors' tokens are unaffected
        ok = [i for i in range(len(reqs)) if finite[i]]
        bad = [i for i in range(len(reqs)) if not finite[i]]
        if ok:
            idx = np.asarray([slot_ids[i] for i in ok])
            rows = np.asarray(ok)

            def scatter(dst, src):
                if dst.ndim == 1:                # pos: batch is dim 0
                    return dst.at[idx].set(src[rows])
                return dst.at[:, idx].set(src[:, rows])

            self.cache = self._recommit_cache(
                jax.tree.map(scatter, self.cache, gcache))
        for i in ok:
            r, slot = reqs[i], slot_ids[i]
            self.slots[slot] = _Slot(
                rid=r.rid, last_token=int(first[i]),
                remaining=r.max_new_tokens - 1,
                tokens=[int(first[i])], admitted_step=self.step_no,
                bucket=bucket, prompt_len=len(r.prompt))
            self.events.append(("admit", self.step_no, r.rid, slot, bucket,
                                self._mem_metrics()))
            self.tokens_generated += 1
            if self.slots[slot].remaining == 0:
                self._retire(slot)
        for i in bad:
            self._poisoned(reqs[i], "prefill_nonfinite")

    def _retire(self, slot: int) -> None:
        s = self.slots[slot]
        self._requests.pop(s.rid, None)
        self.completions[s.rid] = Completion(
            rid=s.rid, prompt_len=s.prompt_len, bucket=s.bucket,
            tokens=list(s.tokens), admitted_step=s.admitted_step,
            finished_step=self.step_no, slot=slot)
        self.slots[slot] = None
        if self.paged:
            # unmap BEFORE releasing: a freed page may be re-allocated next
            # step, and this slot keeps decoding inertly (writes must hit
            # the -1 sentinel and drop, never a recycled page)
            self._bt_host[slot] = -1
            self._commit_leaf("block_tables",
                              self.cache["block_tables"].at[slot].set(-1))
            self.allocator.release(self._slot_pages[slot] or [])
            self._slot_pages[slot] = None
        self.events.append(("retire", self.step_no, s.rid, slot,
                            self._mem_metrics()))

    def _decode_tick(self) -> None:
        tokens = np.zeros((self.serve.max_batch, 1), np.int32)
        for i, s in enumerate(self.slots):
            if s is not None:
                tokens[i, 0] = s.last_token
        fn = self._decode_fn
        if self._current_fault is not None:
            fn = self._faulted_fn(("decode", 0), self._current_fault)
        logits, self.cache = fn(self.params, self.cache,
                                jnp.asarray(tokens))
        finite = self._finite_rows(logits)
        nxt = self._greedy(logits)
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            if not finite[i]:
                # mid-decode poison: the slot's cache row may hold corrupted
                # K/V, so quarantine directly — no retry, since replaying the
                # partial generation cannot be trusted from poisoned state
                self.quarantined[s.rid] = {"prompt_len": s.prompt_len,
                                           "step": self.step_no,
                                           "reason": "decode_nonfinite"}
                self.events.append(("quarantine", self.step_no, s.rid))
                self._evict_slot(i)
                continue
            s.last_token = int(nxt[i])
            s.tokens.append(s.last_token)
            s.remaining -= 1
            self.tokens_generated += 1
            if s.remaining == 0:
                self._retire(i)

    def step(self) -> str | None:
        """One engine step: a bucket prefill when admission is possible,
        else a decode tick over the pool. Returns the step kind, or None
        when fully idle.

        Paged mode runs ONE prefill chunk per prefill step and alternates
        with decode ticks while a job is in flight (chunk, decode, chunk,
        ...), so decode latency is bounded by a chunk — the whole point of
        chunked prefill. Pool exhaustion shows up here as "no group" with a
        non-empty queue: the step decodes instead, draining pages."""
        self._fire_comm_faults()
        self._expire_deadlines()
        active = any(s is not None for s in self.slots)
        if self.paged:
            group = self._next_group_paged()
            if group is None and self._job is None and not active:
                if self.queue and not self.draining:
                    if any(self._not_before.get(r.rid, 0) <= self.step_no
                           for r in self.queue):
                        raise RuntimeError(
                            "paged admission deadlock: queue non-empty but "
                            "no slots/pages can ever free (pool undersized?)")
                    # every queued request is backing off after a retry —
                    # burn an idle step so the gates can open
                    return self._record_step("idle", 0.0)
                return None
            with StepTimer() as t:
                if group is not None:
                    self._start_prefill_job(*group)
                    self._prefill_chunk_step()
                    kind = "prefill"
                elif self._job is not None and not (
                        active and self.step_kinds
                        and self.step_kinds[-1] == "prefill"):
                    self._prefill_chunk_step()
                    kind = "prefill"
                else:
                    self._decode_tick()
                    kind = "decode"
            return self._record_step(kind, t.dt)
        group = self._next_group()
        if group is None and not active:
            if self.queue and not self.draining:
                # all queued requests are in retry backoff: idle-tick
                return self._record_step("idle", 0.0)
            return None
        with StepTimer() as t:
            if group is not None:
                self._prefill(*group)
                kind = "prefill"
            else:
                self._decode_tick()
                kind = "decode"
        return self._record_step(kind, t.dt)

    def _record_step(self, kind: str, dt: float) -> str:
        """Shared step accounting: injected fault delay folds into the
        recorded time (watchdog + fleet feed see it; no wall-clock sleep),
        scripted link stalls attribute their synthetic hop time to the
        targeted island's health feed, boundary-guard trips drain into the
        event log, and the health monitor's verdicts re-layer the plans."""
        dt += self._injected_delay
        self._injected_delay = 0.0
        # scripted stall: synthetic per-hop time, only while the island's
        # CURRENT backend still rides the slow link (post-demotion steps
        # route around it — the throughput recovery the monitor buys)
        stall: dict[str, float] = {}
        if kind in ("prefill", "decode"):
            for f in self._active_faults:
                if f["kind"] == "stall" and \
                        self._stall_applies(f["island"], kind):
                    stall[f["island"]] = (stall.get(f["island"], 0.0)
                                          + f["stall_dt"])
        dt += sum(stall.values())
        self.step_no += 1
        self.step_kinds.append(kind)
        self.step_times.append(dt)
        if kind != "idle" and self.watchdog.record(self.step_no, dt):
            print(f"[serve] STRAGGLER step {self.step_no} ({kind}): "
                  f"{dt:.3f}s (deadline {self.watchdog.deadline:.3f}s)")
        # island boundary guards that tripped during this step's device work
        changed = False
        for island, n in sorted(take_guard_trips().items()):
            self.events.append(("guard_trip", self.step_no, island, n))
            if self.health is not None:
                changed |= self.health.guard_trip(island, self.step_no)
        # per-island health feed: shared base time + this island's stall cut
        if self.health is not None and kind in ("prefill", "decode"):
            base = dt - sum(stall.values())
            for island in self.health.islands:
                changed |= self.health.record(
                    island, self.step_no, base + stall.get(island, 0.0))
        if changed:
            self._refresh_health_overrides()
        self._drain_health_events()
        self._tick_comm_faults()
        return kind

    def run(self, requests=None, max_steps: int = 100_000,
            step_budget: int | None = None) -> list[Completion]:
        """Drain the queue (plus ``requests``, submitted first) to
        completion; returns the completions finished during THIS call, in
        submission (rid) order. ``self.completions`` keeps the full
        history across calls.

        ``step_budget`` makes the call cooperative: run at most that many
        engine steps and return whatever finished, leaving the rest pending
        — an external driver (the fleet) interleaves replicas by calling
        each with a small budget in a deterministic rotation. A budgeted
        call never raises on an undrained queue."""
        done_before = set(self.completions)
        for r in requests or ():
            if isinstance(r, Request):
                self.submit(r.prompt, r.max_new_tokens, rid=r.rid)
            else:
                self.submit(r)
        limit = max_steps if step_budget is None else min(max_steps,
                                                          step_budget)
        drained = False
        for _ in range(limit):
            if self.step() is None:
                drained = True
                break
        if not drained and step_budget is None:
            raise RuntimeError(f"engine did not drain in {max_steps} steps")
        return [self.completions[k] for k in sorted(self.completions)
                if k not in done_before]

    # -- static baseline + stats ------------------------------------------

    def _static_step_fns(self, n: int, bucket: int) -> tuple:
        """Jitted (prefill, decode, cache template) for a static batch of
        ``n`` at ``bucket`` — cached so repeated static runs (warm-up then
        timing, the bench harness) hit jax's trace cache instead of
        recompiling fresh wrappers every call."""
        key = (n, bucket)
        if key not in self._static_fns:
            run = self.base_run
            pre_plans = tuple(island_plans(self.cfg, run, self.rules,
                                           batch=n, seq=bucket,
                                           phase="prefill"))
            dec_plans = tuple(island_plans(self.cfg, run, self.rules,
                                           batch=n, seq=self.s_max,
                                           phase="decode"))
            run_pre = dataclasses.replace(
                run, island_overrides=plan_overrides(pre_plans))
            run_dec = dataclasses.replace(
                run, island_overrides=plan_overrides(dec_plans))
            tmpl = T.cache_template(self.cfg, run_dec, self.rules, batch=n,
                                    s_max=self.s_max, slot_pos=True,
                                    kv_dtype=self.serve.kv_dtype)
            self._static_fns[key] = (
                jax.jit(make_prefill_cache_step(self.cfg, run_pre,
                                                self.rules),
                        donate_argnums=(1,)),
                jax.jit(make_serve_step(self.cfg, run_dec, self.rules),
                        donate_argnums=(1,)),
                tmpl)
        return self._static_fns[key]

    def generate_static(self, prompts: Sequence[Sequence[int]],
                        max_new_tokens: int | None = None) -> list[list[int]]:
        """Static-batch baseline: every prompt padded to ONE bucket,
        prefilled as a single batch, decoded in lockstep until the longest
        request finishes (shorter ones over-decode and are trimmed) — the
        throughput bar continuous batching is measured against. Uses the
        same prefill/decode math and greedy rule as the engine."""
        mx = max_new_tokens if max_new_tokens is not None \
            else self.serve.max_new_tokens
        if not 1 <= mx <= self.serve.max_new_tokens:
            raise ValueError(
                f"max_new_tokens must be in [1, "
                f"{self.serve.max_new_tokens}] (ServeConfig sized the "
                f"cache); got {mx}")
        n = len(prompts)
        bucket = self.serve.bucket_for(max(len(p) for p in prompts))
        if any(sp.mixer == "mamba" for sp in self.cfg.layer_pattern()) \
                and any(len(p) != bucket for p in prompts):
            # same invariant the engine's __init__ guard protects: the SSM
            # recurrent state scans right-padding it cannot mask
            raise ValueError(
                "static SSM batches require uniform prompt lengths equal "
                f"to the bucket ({bucket}); got "
                f"{sorted({len(p) for p in prompts})}")
        prefill, decode, tmpl = self._static_step_fns(n, bucket)
        cache = self._sharded_zeros(tmpl)
        tokens = np.zeros((n, bucket), np.int32)
        lens = np.zeros((n,), np.int32)
        for i, p in enumerate(prompts):
            tokens[i, :len(p)] = list(p)
            lens[i] = len(p)
        logits, cache = prefill(self.params, cache, jnp.asarray(tokens),
                                jnp.asarray(lens))
        last = self._greedy(logits)
        out = [[int(t)] for t in last]
        for _ in range(mx - 1):
            logits, cache = decode(self.params, cache,
                                   jnp.asarray(last[:, None]))
            last = self._greedy(logits)
            for i in range(n):
                out[i].append(int(last[i]))
        return [seq[:mx] for seq in out]

    def cache_stats(self) -> dict:
        """Cache-memory story: layout, pool bytes vs the slab equivalent,
        residency peaks, prefix-sharing and backpressure counters."""
        slab = paging.slab_hbm_bytes(self.cfg, self.serve.max_batch,
                                     self.s_max,
                                     kv_dtype=self.serve.kv_dtype)
        out: dict[str, Any] = {
            "layout": self.serve.cache_layout,
            "kv_dtype": self.serve.kv_dtype,
            "peak_resident_slots": self._peak_slots,
            "slab_bytes": slab,
        }
        if not self.paged:
            out["hbm_bytes"] = slab
            return out
        g = self.geom
        out.update({
            "hbm_bytes": paging.pool_hbm_bytes(self.cfg, g,
                                               kv_dtype=self.serve.kv_dtype),
            "page_size": g.page_size, "n_pages": g.n_pages,
            "pages_per_slot": g.pages_per_slot,
            "n_partitions": g.n_partitions,
            "resident_pages": self.allocator.resident_pages,
            "peak_resident_pages": self._peak_pages,
            "peak_pool_occupancy": self._peak_pages / g.n_pages,
            "prefix_hits": self.prefix_hits,
            "shared_pages_reused": self.shared_pages_reused,
            "cow_copies": self.cow_copies,
            "admission_blocked": self.admission_blocked,
        })
        return out

    def stats(self) -> dict:
        total = sum(self.step_times)
        return {
            "steps": self.step_no,
            "prefill_steps": self.step_kinds.count("prefill"),
            "decode_steps": self.step_kinds.count("decode"),
            "idle_steps": self.step_kinds.count("idle"),
            "tokens_generated": self.tokens_generated,
            "wall_s": total,
            "tokens_per_s": self.tokens_generated / total if total else 0.0,
            "straggler_events": len(self.watchdog.events),
            "compiled_buckets": self.compiled_buckets,
            "cache": self.cache_stats(),
            "quarantined": len(self.quarantined),
            "expired": len(self.expired),
            "retries": sum(self._retries.values()),
            "guard_trips": sum(e[0] == "guard_trip" for e in self.events),
            "health_demotions": (
                sum(e[0] == "demote" for e in self.health.events)
                if self.health is not None else 0),
        }
