"""Runtime health: scripted comm faults + island health monitoring.

Three cooperating pieces, all consumed by ``runtime.serving.ServingEngine``
(and, one level up, by ``runtime.fleet.ServingFleet`` through the extended
fault grammar):

* ``CommFaultPlan`` — a deterministic scripted plan of *comms-level* faults
  (``kind:island@step[xticks]``), the same spec-grammar discipline as the
  fleet's replica-level ``FaultPlan``. Kinds:

  - ``corrupt``  — NaN the targeted island's ring-hop payload (whole hop);
  - ``bitflip``  — NaN a single element of the hop payload;
  - ``stall``    — add synthetic per-hop stall time to the island's steps
                   while its dispatch still runs a ring-family backend
                   (demoting to ``bulk`` routes around the slow link, which
                   is exactly what the monitor's recovery claim tests);
  - ``linkdown`` — mark the island's link down: the monitor force-demotes
                   the island to the bottom rung until the event expires.

  ``corrupt``/``bitflip`` are realised at trace level: the engine re-jits
  the affected step with ``RunConfig.comm_fault`` set, and
  ``core.comms``' ring collectives poison the hop payload after the
  ppermute. ``stall``/``linkdown`` never touch jit — they are host-side
  timing/dispatch semantics.

* ``HealthMonitor`` — per-island EMA drift tracking reusing the
  ``StragglerWatchdog`` machinery. On ``demote_after`` consecutive flagged
  samples (or immediately on a guard trip / linkdown) the island's backend
  is demoted one rung down its ladder (ring_bidir -> ring -> bulk,
  chunked -> 1-chunk bulk); after ``probation`` consecutive clean samples
  it is re-promoted one rung. Every demotion doubles that island's
  effective probation window — hysteresis against flapping. The monitor
  expresses its decisions as ``island_overrides`` 4-tuples tagged
  ``"health"``, layered *above* measured dispatch: the calibration table
  is never mutated.

* Guard-trip drain — re-exported from ``core.template``'s registry (the
  guards themselves are ``jax.debug.callback`` finite-checks at the island
  boundary, emitted when ``RunConfig.island_guards`` is set).
"""

from __future__ import annotations

import dataclasses

from repro.core.template import (record_guard_trip,  # noqa: F401 (re-export)
                                 take_guard_trips)
from repro.runtime.straggler import StragglerWatchdog

COMM_FAULT_KINDS = ("corrupt", "bitflip", "stall", "linkdown")

# payload faults are trace-level (engine re-jits with RunConfig.comm_fault);
# the rest are host-side semantics
PAYLOAD_FAULT_KINDS = ("corrupt", "bitflip")


@dataclasses.dataclass(frozen=True)
class CommFaultEvent:
    """One scripted comms-level fault: at engine step ``step``, apply
    ``kind`` to ``island`` for ``ticks`` consecutive steps."""

    kind: str
    island: str
    step: int
    ticks: int = 1
    hop: int = 0                 # ring hop index for payload faults
    stall_dt: float = 1.0        # synthetic seconds per step for "stall"

    def __post_init__(self):
        if self.kind not in COMM_FAULT_KINDS:
            raise ValueError(f"unknown comm fault kind {self.kind!r}; "
                             f"one of {COMM_FAULT_KINDS}")
        if not self.island:
            raise ValueError("comm fault needs an island name ('*' = all)")
        if self.step < 1:
            raise ValueError(f"comm fault step must be >= 1, got {self.step}")
        if self.ticks < 1:
            raise ValueError(f"comm fault ticks must be >= 1, got {self.ticks}")
        if self.hop < 0:
            raise ValueError(f"comm fault hop must be >= 0, got {self.hop}")


@dataclasses.dataclass(frozen=True)
class CommFaultPlan:
    """Deterministic comms-fault schedule, ``FaultPlan``'s sibling.

    Spec grammar (comma/semicolon/space separated)::

        kind:island@step[xticks]

        corrupt:mlp@3          NaN mlp's ring hop payload at engine step 3
        stall:mlp@5x6          stall mlp's link for steps 5..10
        linkdown:attn_out@2x4  mark attn_out's link down for steps 2..5

    Duplicate events (same kind+island+step) and contradictory events
    (two payload faults on one island at one step) are rejected with
    named errors at parse time, not silently merged.
    """

    events: tuple[CommFaultEvent, ...] = ()

    @classmethod
    def parse(cls, spec: str) -> "CommFaultPlan":
        events = []
        for item in spec.replace(";", ",").replace(" ", ",").split(","):
            if not item:
                continue
            try:
                kind, rest = item.split(":", 1)
                island, sloc = rest.split("@", 1)
                ticks = 1
                if "x" in sloc:
                    sloc, tloc = sloc.split("x", 1)
                    ticks = int(tloc)
                events.append(CommFaultEvent(kind=kind, island=island,
                                             step=int(sloc), ticks=ticks))
            except ValueError as e:
                raise ValueError(
                    f"bad comm fault spec {item!r} (want "
                    f"kind:island@step[xticks], kind in {COMM_FAULT_KINDS}): "
                    f"{e}") from e
        return cls(events=cls._checked(events))

    @staticmethod
    def _checked(events) -> tuple[CommFaultEvent, ...]:
        seen = set()
        payload_at = {}
        for ev in events:
            key = (ev.kind, ev.island, ev.step)
            if key in seen:
                raise ValueError(
                    f"duplicate fault event: {ev.kind}:{ev.island}@{ev.step} "
                    "appears more than once")
            seen.add(key)
            if ev.kind in PAYLOAD_FAULT_KINDS:
                prior = payload_at.get((ev.island, ev.step))
                if prior is not None:
                    raise ValueError(
                        f"contradictory fault events: {prior} and {ev.kind} "
                        f"both target {ev.island}@{ev.step} — one hop "
                        "payload cannot be poisoned two ways")
                payload_at[(ev.island, ev.step)] = ev.kind
        return tuple(sorted(events, key=lambda e: (e.step, e.island, e.kind)))

    def at(self, step: int) -> list[CommFaultEvent]:
        return [e for e in self.events if e.step == step]


# ---------------------------------------------------------------------------
# Health monitor
# ---------------------------------------------------------------------------


def demotion_ladder(backend: str, n_chunks: int | None = None):
    """Rungs below a planned backend, most-capable first. Each rung is a
    ``(backend, n_chunks)`` pair; ``None`` chunks = leave to dispatch."""
    if backend == "ring_bidir":
        return (("ring", n_chunks), ("bulk", None))
    if backend in ("ring", "fused"):
        return (("bulk", None),)
    if backend == "chunked":
        # chunked a2a -> the 1-chunk bulk exchange
        return (("bulk", 1),)
    return ()


@dataclasses.dataclass
class _IslandHealth:
    ladder: tuple                  # ((backend, chunks), ...) below planned
    level: int = 0                 # 0 = planned backend, len(ladder) = bottom
    bad: int = 0                   # consecutive flagged samples
    clean: int = 0                 # consecutive clean samples
    demotions: int = 0             # lifetime demotions (hysteresis doubling)
    forced_down: bool = False      # linkdown pins the bottom rung


class HealthMonitor:
    """Per-island drift detector + backend demotion state machine.

    ``record(island, step, dt)`` feeds one step timing for one island; the
    flagging rule is ``StragglerWatchdog``'s (dt > factor * EMA after
    ``min_samples`` warm-up samples). Returns True when the island changed
    rung — the caller (serving engine) then re-layers ``overrides()`` onto
    its per-bucket RunConfigs and re-jits.

    State machine per island::

        planned --(demote_after consecutive flags | guard trip)--> rung+1
        rung>0 --(probation * 2**(demotions-1) consecutive clean)--> rung-1
        linkdown --> bottom rung pinned until link_up, then probation

    EMA feeds reset on every rung transition: timings measured under the
    old backend are not evidence about the new one.
    """

    def __init__(self, ladders: dict, *, factor: float = 3.0,
                 demote_after: int = 2, probation: int = 6,
                 ema_decay: float = 0.9, min_samples: int = 3,
                 expected: dict | None = None):
        self.factor = factor
        self.demote_after = demote_after
        self.probation = probation
        self.ema_decay = ema_decay
        self.min_samples = min_samples
        self._state = {name: _IslandHealth(ladder=tuple(ladder))
                       for name, ladder in ladders.items()}
        self._feeds = {name: self._fresh_feed(expected and expected.get(name))
                       for name in self._state}
        self.events: list[tuple] = []

    def _fresh_feed(self, expected_dt=None) -> StragglerWatchdog:
        feed = StragglerWatchdog(factor=self.factor,
                                 ema_decay=self.ema_decay,
                                 min_samples=self.min_samples)
        if expected_dt:
            # seed the EMA from the calibrated expectation so drift is
            # measured against what dispatch promised, not a cold start
            feed.ema, feed.n = float(expected_dt), 1
        return feed

    # -- queries ------------------------------------------------------------

    @property
    def islands(self):
        return tuple(self._state)

    def level(self, island: str) -> int:
        return self._state[island].level

    def rung(self, island: str):
        """(backend, chunks) the island currently runs, or None = planned."""
        st = self._state[island]
        return None if st.level == 0 else st.ladder[st.level - 1]

    def overrides(self) -> tuple:
        """``island_overrides`` 4-tuples for every demoted island, tagged
        with source ``"health"`` so plan records show ``src=health``."""
        out = []
        for name, st in self._state.items():
            if st.level > 0:
                be, chunks = st.ladder[st.level - 1]
                out.append((name, be, chunks, "health"))
        return tuple(out)

    def _probation_for(self, st: _IslandHealth) -> int:
        return self.probation * (2 ** max(0, st.demotions - 1))

    # -- transitions ---------------------------------------------------------

    def record(self, island: str, step: int, dt: float) -> bool:
        """Feed one sample; True iff the island changed rung."""
        st = self._state.get(island)
        if st is None:
            return False
        flagged = self._feeds[island].record(step, dt)
        if st.forced_down:
            return False
        if flagged:
            st.bad += 1
            st.clean = 0
            if st.bad >= self.demote_after:
                return self._demote(island, step, "drift")
        else:
            st.clean += 1
            st.bad = 0
            if st.level > 0 and st.clean >= self._probation_for(st):
                return self._promote(island, step)
        return False

    def guard_trip(self, island: str, step: int) -> bool:
        """A finite-check tripped at this island's boundary: demote now."""
        st = self._state.get(island)
        if st is None or st.forced_down:
            return False
        return self._demote(island, step, "guard")

    def link_down(self, island: str, step: int) -> bool:
        """Pin the island to the bottom rung until ``link_up``."""
        st = self._state.get(island)
        if st is None or not st.ladder or st.forced_down:
            return False
        st.forced_down = True
        changed = st.level != len(st.ladder)
        if changed:
            st.level = len(st.ladder)
            st.demotions += 1
            self._feeds[island] = self._fresh_feed()
            be, _ = st.ladder[st.level - 1]
            self.events.append(("demote", step, island, be, "linkdown"))
        st.bad = st.clean = 0
        return changed

    def link_up(self, island: str, step: int) -> None:
        """Link restored: unpin; promotion now runs through probation."""
        st = self._state.get(island)
        if st is None or not st.forced_down:
            return
        st.forced_down = False
        st.bad = st.clean = 0
        self._feeds[island] = self._fresh_feed()
        self.events.append(("link_up", step, island))

    def _demote(self, island: str, step: int, reason: str) -> bool:
        st = self._state[island]
        st.bad = st.clean = 0
        if st.level >= len(st.ladder):
            return False               # already at the bottom rung
        st.level += 1
        st.demotions += 1
        self._feeds[island] = self._fresh_feed()
        be, _ = st.ladder[st.level - 1]
        self.events.append(("demote", step, island, be, reason))
        return True

    def _promote(self, island: str, step: int) -> bool:
        st = self._state[island]
        st.bad = st.clean = 0
        st.level -= 1
        self._feeds[island] = self._fresh_feed()
        be = "planned" if st.level == 0 else st.ladder[st.level - 1][0]
        self.events.append(("promote", step, island, be))
        return True


__all__ = [
    "COMM_FAULT_KINDS",
    "PAYLOAD_FAULT_KINDS",
    "CommFaultEvent",
    "CommFaultPlan",
    "HealthMonitor",
    "demotion_ladder",
    "record_guard_trip",
    "take_guard_trips",
]
