"""Elastic scaling: restore a checkpoint onto a different mesh.

Most parameters are stored in logical layout, so elasticity is just
device_put with the new mesh's NamedShardings. The one mesh-dependent layout
is the MoE device-major PGL (model-axis size baked into dim 0) — converted
through the logical layout on host (core/moe_layout.py).
"""

from __future__ import annotations


import jax
import numpy as np

from repro.configs.base import ArchConfig, RunConfig
from repro.core.moe_layout import dm_to_logical, logical_to_dm
from repro.ckpt.manager import CheckpointManager
from repro.models import transformer as T
from repro.models.sharding import ShardingRules


def moe_converter(cfg: ArchConfig, old_m: int, new_m: int):
    """Per-leaf converter for CheckpointManager.restore: reshapes MoE
    device-major expert weights from model-axis size old_m to new_m."""
    if old_m == new_m or not cfg.is_moe:
        return None

    def convert(key: str, arr: np.ndarray) -> np.ndarray:
        leaf = key.split("/")[-1]
        if "moe" not in key or leaf not in ("w1", "w2", "w3"):
            return arr
        # stacked over periods: (P, M, E_loc, ...) -> convert per period
        out = []
        for p in range(arr.shape[0]):
            logical = dm_to_logical(arr[p], cfg.n_experts, w2=(leaf == "w2"))
            out.append(logical_to_dm(logical, new_m, w2=(leaf == "w2")))
        return np.stack(out)

    return convert


def elastic_restore(ckpt_dir: str, cfg: ArchConfig, run: RunConfig,
                    new_mesh, *, old_model_size: int, template=None):
    """Load the newest checkpoint and place it on `new_mesh` (any size whose
    axes divide the sharded dims). Returns (state_pytree, extra)."""
    rules = ShardingRules(new_mesh, run)
    tmpl = T.param_template(cfg, run, rules) if template is None else template
    params_abs = T.abstract_params(tmpl)
    specs = T.param_specs(tmpl)
    shardings = jax.tree.map(
        lambda s: jax.sharding.NamedSharding(new_mesh, s), specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    mgr = CheckpointManager(ckpt_dir)
    new_m = new_mesh.shape[run.tp_axis]
    conv = moe_converter(cfg, old_model_size, new_m)
    return mgr.restore(params_abs, shardings=shardings, convert=conv)
