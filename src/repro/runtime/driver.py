"""Fault-tolerant training driver: auto-resume from the newest committed
checkpoint, periodic async saves, straggler watchdog, crash-injection hooks
for tests. Designed so a pod-scale launcher (one process per host) can wrap
it directly — all state that must survive a restart lives in the checkpoint
(params, optimizer moments, step, data cursor).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax

from repro.ckpt.manager import CheckpointManager
from repro.runtime.straggler import StepTimer, StragglerWatchdog


@dataclasses.dataclass
class DriverConfig:
    total_steps: int
    ckpt_every: int = 50
    log_every: int = 10
    keep_checkpoints: int = 3
    async_checkpoint: bool = True
    straggler_factor: float = 3.0


class TrainDriver:
    def __init__(self, *, train_step: Callable, state, data,
                 ckpt_dir: str, cfg: DriverConfig,
                 state_shardings=None,
                 fault_hook: Callable[[int], None] | None = None):
        self.train_step = train_step
        self.data = data
        self.cfg = cfg
        self.ckpt = CheckpointManager(ckpt_dir, keep=cfg.keep_checkpoints,
                                      async_save=cfg.async_checkpoint)
        self.watchdog = StragglerWatchdog(factor=cfg.straggler_factor)
        self.fault_hook = fault_hook
        self.metrics_log: list[dict] = []

        # ---- auto-resume: newest committed checkpoint wins ----
        restored, extra = self.ckpt.restore(state, shardings=state_shardings)
        if restored is not None:
            self.state = restored
            self.start_step = int(extra["step"])
            print(f"[driver] resumed from step {self.start_step}")
        else:
            self.state = state
            self.start_step = 0

    def run(self):
        cfg = self.cfg
        step = self.start_step
        while step < cfg.total_steps:
            if self.fault_hook is not None:
                self.fault_hook(step)      # tests: raise to simulate a crash
            batch = self.data.batch(step)
            with StepTimer() as t:
                self.state, metrics = self.train_step(self.state, batch)
                jax.block_until_ready(metrics["loss"])
            step += 1
            if self.watchdog.record(step, t.dt):
                print(f"[driver] STRAGGLER step {step}: {t.dt:.3f}s "
                      f"(deadline {self.watchdog.deadline:.3f}s) — "
                      f"mitigation: requeue/exclude host (simulated)")
            if step % cfg.log_every == 0 or step == cfg.total_steps:
                m = {k: float(v) for k, v in metrics.items()}
                m["step_time_s"] = t.dt
                m["step"] = step
                self.metrics_log.append(m)
                print(f"[driver] step {step}: loss {m['loss']:.4f} "
                      f"({t.dt*1e3:.0f} ms)")
            if step % cfg.ckpt_every == 0:
                self.ckpt.save(step, self.state, {"data_cursor": step})
        self.ckpt.save(cfg.total_steps, self.state,
                       {"data_cursor": cfg.total_steps}, block=True)
        self.ckpt.wait()
        return self.state, self.metrics_log
