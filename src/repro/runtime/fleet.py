"""Multi-replica elastic serving fleet: N data-parallel ``ServingEngine``
replicas behind a deterministic router, with scripted fault injection.

The ROADMAP's "heavy traffic from millions of users" north star needs more
than one fast engine: it needs throughput that scales with replica count and
tail latency that survives losing a replica. This module is that layer, kept
deliberately in-process (replicas are plain ``ServingEngine`` objects on
no-mesh or disjoint sub-meshes) so every scheduling decision is a pure
function of the submitted trace and the fault plan — testable to the token.

Feedback-driven routing (the CUCo / resource-aware-overlap argument one
level up from per-island measured dispatch): admission steers off
*measured* per-replica state, re-read every fleet step —

* queue depth + live slots + mid-prefill rows (``engine.load()``),
* jitted-bucket warmth (``engine.compiled_buckets``),
* tokens/s (``engine.stats()``),
* prefix-cache occupancy (``engine.prefix_match_len`` /
  ``engine.cache_stats()``).

Policies: ``fcfs`` (fixed rotation over healthy replicas), ``least-loaded``
(argmin load, lowest index breaks ties), ``cache-affinity`` (route to the
replica whose paged ``PrefixCache`` holds the longest prefix; least-loaded
among equals and when nothing matches).

Straggler-aware stealing: each replica's fleet turn records one sample into
a ``FleetWatchdog`` feed. A replica flagged by its own deadline, by the
cross-replica EMA-vs-median rule, or currently serving a scripted stall has
its *queued* (never in-flight) requests pulled back to the fleet backlog
and re-routed to healthy peers.

Elasticity — the drain / kill / rejoin lifecycle::

    drain r   stop admitting on r; queued requests return to the backlog;
              in-flight slots finish; params snapshot to the fleet
              checkpoint (the rejoin seed), tagged with r's tp size
    kill r    harvest r's finished completions FIRST, then take_undone()
              pops every not-yet-completed request exactly once (queued +
              mid-prefill rows + live slots) onto the backlog front; the
              engine object is dropped
    rejoin r  rebuild via the replica factory, restore params from the
              fleet checkpoint (``elastic_restore`` when the new engine has
              a mesh — possibly a *different* mesh than the snapshot's, the
              MoE device-major layout converted in transit), fresh
              watchdog feed

Faults are scripted, not raced: ``FaultPlan.parse("kill:1@5 delay:0@3x4")``
fires events at exact fleet steps, and injected delays fold into recorded
step times (``engine.inject_step_delay``) rather than sleeping, so a fault
run is exactly reproducible. The acceptance invariant (tests/test_fleet.py)
is that a kill-one-replica run completes every submitted request exactly
once, token-identical to the no-fault run — which holds because engine
outputs are batch-composition independent (pinned by tests/test_serving.py)
and the router requeues lost work exactly once.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Sequence

from repro.configs.base import FleetConfig
from repro.runtime.serving import Completion, Request, ServingEngine
from repro.runtime.straggler import FleetWatchdog, StepTimer

__all__ = ["FaultEvent", "FaultPlan", "ServingFleet"]


# --------------------------------------------------------------------------
# fault plans
# --------------------------------------------------------------------------

_COMM_KINDS = ("corrupt", "bitflip", "stall", "linkdown")
_KINDS = ("kill", "delay", "drain", "rejoin") + _COMM_KINDS


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scripted fault: do ``kind`` to ``replica`` at fleet ``step``.

    ``ticks`` is kind-specific: for ``delay`` it is how many fleet ticks the
    replica stalls (its turns pass without engine steps, each recording a
    synthetic ``FleetConfig.stall_dt`` watchdog sample); for comms-level
    kinds it is how many ENGINE steps the fault stays active on the target
    replica; other kinds ignore it.

    Comms-level kinds (``runtime.health.COMM_FAULT_KINDS``) target one
    island INSIDE a replica — spec location ``replica.island``, e.g.
    ``linkdown:1.mlp@4`` or ``corrupt:0.attn_out@2`` — and are delivered via
    ``ServingEngine.inject_comm_fault``."""

    kind: str
    replica: int
    step: int
    ticks: int = 0
    island: str | None = None

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {_KINDS}")
        if self.replica < 0 or self.step < 0:
            raise ValueError(f"replica/step must be >= 0: {self}")
        if self.kind == "delay" and self.ticks < 1:
            raise ValueError(f"delay needs ticks >= 1 (spec 'xK'): {self}")
        if self.kind in _COMM_KINDS and not self.island:
            raise ValueError(
                f"comm fault {self.kind!r} targets an island inside the "
                f"replica: spec location is replica.island "
                f"(e.g. {self.kind}:1.mlp@4)")
        if self.kind not in _COMM_KINDS and self.island:
            raise ValueError(
                f"replica-level fault {self.kind!r} takes no island "
                f"(got {self.replica}.{self.island})")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An ordered script of ``FaultEvent``s, parseable from the CLI spec
    ``kind:replica[.island]@step[xticks]`` (comma/space/semicolon
    separated)::

        FaultPlan.parse("kill:1@5, rejoin:1@9")
        FaultPlan.parse("delay:0@3x4 drain:2@7")
        FaultPlan.parse("linkdown:1.mlp@4x3 corrupt:0.attn_out@2")

    Duplicate events (same kind+target+step) and contradictory pairs at one
    (replica, step) — ``kill`` plus anything else, ``rejoin`` plus
    ``drain``, or two payload poisons on one island — are rejected with
    named errors at parse time rather than silently racing at fire time.
    """

    events: tuple[FaultEvent, ...] = ()

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        evs = []
        for item in spec.replace(";", ",").replace(" ", ",").split(","):
            item = item.strip()
            if not item:
                continue
            try:
                kind, rest = item.split(":", 1)
                rloc, sloc = rest.split("@", 1)
                island = None
                if "." in rloc:
                    rloc, island = rloc.split(".", 1)
                ticks = 0
                if "x" in sloc:
                    sloc, t = sloc.split("x", 1)
                    ticks = int(t)
                evs.append(FaultEvent(kind, int(rloc), int(sloc), ticks,
                                      island=island))
            except ValueError as e:
                raise ValueError(
                    f"bad fault spec {item!r} (want kind:replica[.island]"
                    f"@step[xticks], kind in {_KINDS}): {e}") from e
        return cls(cls._checked(evs))

    @staticmethod
    def _checked(evs) -> tuple[FaultEvent, ...]:
        seen = set()
        by_loc: dict[tuple, list] = {}
        for ev in evs:
            key = (ev.kind, ev.replica, ev.island, ev.step)
            if key in seen:
                raise ValueError(
                    f"duplicate fault event: {ev.kind}:{ev.replica}"
                    f"{'.' + ev.island if ev.island else ''}@{ev.step} "
                    "appears more than once")
            seen.add(key)
            by_loc.setdefault((ev.replica, ev.step), []).append(ev)
        for (rep, step), group in by_loc.items():
            kinds = [e.kind for e in group]
            if "kill" in kinds and len(group) > 1:
                raise ValueError(
                    f"contradictory fault events at replica {rep} step "
                    f"{step}: kill cannot combine with {sorted(kinds)}")
            if "rejoin" in kinds and "drain" in kinds:
                raise ValueError(
                    f"contradictory fault events at replica {rep} step "
                    f"{step}: rejoin and drain cancel each other")
            payload = {}
            for e in group:
                if e.kind in ("corrupt", "bitflip"):
                    prior = payload.get(e.island)
                    if prior is not None:
                        raise ValueError(
                            f"contradictory fault events: {prior} and "
                            f"{e.kind} both poison replica {rep} island "
                            f"{e.island!r} at step {step}")
                    payload[e.island] = e.kind
        return tuple(sorted(evs, key=lambda e: (e.step, e.replica)))

    def at(self, step: int) -> list[FaultEvent]:
        return [e for e in self.events if e.step == step]

    def rejoin_after(self, step: int) -> bool:
        return any(e.kind == "rejoin" and e.step >= step
                   for e in self.events)


# --------------------------------------------------------------------------
# fleet
# --------------------------------------------------------------------------


@dataclasses.dataclass
class _Replica:
    idx: int
    engine: ServingEngine | None
    alive: bool = True
    draining: bool = False
    stall: int = 0                   # remaining scripted stall ticks


class ServingFleet:
    """N serving replicas behind one deterministic router.

    ``factory(i) -> ServingEngine`` builds replica ``i`` — all replicas must
    share the same ``ServeConfig`` and parameters (data-parallel serving:
    any replica can serve any request). The fleet owns the request backlog;
    replicas only ever see requests the router assigned to them.

    One ``step()`` is: fire scripted faults → steal from flagged/stalled
    replicas → route the backlog → give each live replica one turn of at
    most ``FleetConfig.step_budget`` engine steps (index order — the
    deterministic interleave) → harvest completions.
    """

    def __init__(self, factory: Callable[[int], ServingEngine],
                 fleet: FleetConfig | None = None,
                 fault_plan: FaultPlan | None = None,
                 ckpt_dir: str | None = None):
        self.factory = factory
        self.cfg = fleet if fleet is not None else FleetConfig()
        self.plan = fault_plan if fault_plan is not None else FaultPlan()
        self.replicas = [_Replica(i, factory(i))
                         for i in range(self.cfg.n_replicas)]
        self._serve = self.replicas[0].engine.serve
        self.watchdog = FleetWatchdog(self.cfg.n_replicas,
                                      factor=self.cfg.steal_factor)
        self.backlog: collections.deque[Request] = collections.deque()
        self.completions: dict[int, Completion] = {}
        self.assignments: list[tuple] = []   # (step, rid, replica, reason)
        self.events: list[tuple] = []
        self.step_no = 0
        self.step_times: list[float] = []
        self.steals = 0
        self.requeued = 0
        self._next_rid = 0
        self._rr = 0                         # fcfs rotation cursor
        # checkpoint-backed rejoin: lazy manager, created on first drain
        self._ckpt_dir = ckpt_dir
        self._ckpt = None
        self._ckpt_tp = 1                    # tp size the snapshot was cut at
        self._ckpt_no = 0

    # -- intake ------------------------------------------------------------

    def submit(self, prompt: Sequence[int],
               max_new_tokens: int | None = None,
               rid: int | None = None) -> int:
        """Queue a request on the FLEET backlog (routing happens at the
        next ``step()``). Validation mirrors ``ServingEngine.submit``."""
        prompt = tuple(int(t) for t in prompt)
        if not prompt:
            raise ValueError("empty prompt")
        self._serve.bucket_for(len(prompt))
        mx = max_new_tokens if max_new_tokens is not None \
            else self._serve.max_new_tokens
        if not 1 <= mx <= self._serve.max_new_tokens:
            raise ValueError(
                f"max_new_tokens must be in [1, "
                f"{self._serve.max_new_tokens}]; got {mx}")
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        self.backlog.append(Request(rid, prompt, mx))
        return rid

    # -- lifecycle ---------------------------------------------------------

    def drain(self, idx: int) -> None:
        """Stop admitting on replica ``idx``: queued requests return to the
        backlog front, in-flight slots finish on their own, and the
        replica's params are snapshotted as the fleet's rejoin seed."""
        rep = self.replicas[idx]
        if not rep.alive or rep.draining:
            return
        rep.draining = True
        rep.engine.drain()
        if self._ckpt_dir is not None:
            self._snapshot(rep.engine)
        queued = rep.engine.take_queued()
        self.requeued += len(queued)
        self.backlog.extendleft(reversed(queued))
        self.events.append(("drain", self.step_no, idx,
                            tuple(r.rid for r in queued)))

    def kill(self, idx: int) -> None:
        """Drop replica ``idx`` mid-step. Finished completions are
        harvested FIRST (they survive — completions live on the host), then
        every not-yet-completed request is popped exactly once and requeued
        at the backlog front, so lost work re-routes before new work."""
        rep = self.replicas[idx]
        if not rep.alive:
            return
        self._harvest_replica(rep)
        undone = rep.engine.take_undone()
        self.requeued += len(undone)
        self.backlog.extendleft(reversed(undone))
        rep.alive = False
        rep.draining = False
        rep.stall = 0
        rep.engine = None
        self.events.append(("kill", self.step_no, idx,
                            tuple(r.rid for r in undone)))

    def delay(self, idx: int, ticks: int) -> None:
        """Stall replica ``idx`` for ``ticks`` fleet turns: its turns pass
        without engine steps, each recording a synthetic
        ``FleetConfig.stall_dt`` sample — the deterministic straggler."""
        rep = self.replicas[idx]
        if rep.alive:
            rep.stall += ticks
            self.events.append(("delay", self.step_no, idx, ticks))

    def rejoin(self, idx: int,
               factory: Callable[[int], ServingEngine] | None = None) -> None:
        """Bring a dead (or drained) replica back: rebuild the engine via
        the factory — possibly on a DIFFERENT mesh than the fleet started
        with — and restore params from the fleet checkpoint when one was
        cut (``elastic_restore`` re-places logical-layout params and
        converts the MoE device-major layout to the new tp size)."""
        rep = self.replicas[idx]
        eng = (factory or self.factory)(idx)
        params = self._restored_params(eng)
        if params is not None:
            eng.params = params
        rep.engine = eng
        rep.alive = True
        rep.draining = False
        rep.stall = 0
        self.watchdog.reset(idx)
        self.events.append(("rejoin", self.step_no, idx))

    def _snapshot(self, engine: ServingEngine) -> None:
        from repro.ckpt.manager import CheckpointManager
        if self._ckpt is None:
            self._ckpt = CheckpointManager(self._ckpt_dir, async_save=False)
        self._ckpt_tp = (engine.rules.mesh.shape[engine.base_run.tp_axis]
                         if engine.rules is not None else 1)
        self._ckpt_no += 1
        self._ckpt.save(self._ckpt_no, engine.params)
        self.events.append(("snapshot", self.step_no, self._ckpt_no))

    def _restored_params(self, eng: ServingEngine):
        if self._ckpt is None:
            return None
        if eng.rules is not None:
            from repro.runtime.elastic import elastic_restore
            params, _ = elastic_restore(
                str(self._ckpt.dir), eng.cfg, eng.base_run, eng.rules.mesh,
                old_model_size=self._ckpt_tp)
            return params
        import jax.numpy as jnp
        import jax
        params, _ = self._ckpt.restore(eng.params)
        return (None if params is None
                else jax.tree.map(jnp.asarray, params))

    # -- routing -----------------------------------------------------------

    def _flagged(self) -> set[int]:
        live = [r.idx for r in self.replicas if r.alive]
        return set(self.watchdog.stragglers(live)) if self.cfg.steal \
            else set()

    def _healthy(self, flagged: set[int]) -> list[_Replica]:
        return [r for r in self.replicas
                if r.alive and not r.draining and r.stall == 0
                and r.idx not in flagged]

    def _steal(self, flagged: set[int]) -> None:
        """Pull QUEUED (never in-flight) requests off stalled/flagged
        replicas back onto the backlog front — only when a healthy
        destination exists, else stealing would just bounce them back."""
        for rep in self.replicas:
            if not rep.alive or not (rep.stall > 0 or rep.idx in flagged):
                continue
            if not rep.engine.queue:
                continue
            if not any(h.idx != rep.idx for h in self._healthy(flagged)):
                continue
            stolen = rep.engine.take_queued()
            self.steals += 1
            self.requeued += len(stolen)
            self.backlog.extendleft(reversed(stolen))
            self.events.append(("steal", self.step_no, rep.idx,
                                tuple(r.rid for r in stolen)))

    def _route(self, flagged: set[int]) -> None:
        """Assign the whole backlog, head first. Per-request feedback
        (load, prefix match) is re-read per pick, so a burst spreads out
        instead of dogpiling the replica that was least loaded at step
        start. No healthy candidate → the backlog waits (stalls expire,
        drains finish, rejoin events fire)."""
        while self.backlog:
            cands = self._healthy(flagged)
            if not cands:
                return
            req = self.backlog.popleft()
            rep, reason = self._pick(req, cands)
            rep.engine.submit(req.prompt, req.max_new_tokens, rid=req.rid)
            self.assignments.append((self.step_no, req.rid, rep.idx, reason))

    def _pick(self, req: Request,
              cands: list[_Replica]) -> tuple[_Replica, str]:
        if self.cfg.router == "fcfs":
            rep = cands[self._rr % len(cands)]
            self._rr += 1
            return rep, "fcfs"
        loads = {r.idx: r.engine.load() for r in cands}
        if self.cfg.router == "cache-affinity":
            match = {r.idx: r.engine.prefix_match_len(req.prompt)
                     for r in cands}
            best = max(match.values())
            if best > 0:
                hit = [r for r in cands if match[r.idx] == best]
                rep = min(hit, key=lambda r: (loads[r.idx], r.idx))
                return rep, f"affinity:{best}"
        rep = min(cands, key=lambda r: (loads[r.idx], r.idx))
        return rep, f"least-loaded:{loads[rep.idx]}"

    # -- stepping ----------------------------------------------------------

    def _fire(self, ev: FaultEvent) -> None:
        if ev.kind in _COMM_KINDS:
            rep = self.replicas[ev.replica]
            if rep.alive:
                rep.engine.inject_comm_fault(ev.kind, ev.island,
                                             ticks=ev.ticks or 1)
                self.events.append(("comm_fault", self.step_no, ev.replica,
                                    ev.kind, ev.island))
            return
        {"kill": lambda: self.kill(ev.replica),
         "drain": lambda: self.drain(ev.replica),
         "rejoin": lambda: self.rejoin(ev.replica),
         "delay": lambda: self.delay(ev.replica, ev.ticks)}[ev.kind]()

    def step(self) -> bool:
        """One fleet step; returns True if any replica made progress (ran
        engine steps or burned a stall tick)."""
        for ev in self.plan.at(self.step_no):
            self._fire(ev)
        flagged = self._flagged()
        if self.cfg.steal:
            self._steal(flagged)
        self._route(flagged)
        progressed = False
        with StepTimer() as t:
            for rep in self.replicas:
                if not rep.alive:
                    continue
                if rep.stall > 0:
                    rep.stall -= 1
                    self.watchdog.record(rep.idx, self.step_no,
                                         self.cfg.stall_dt)
                    self.events.append(("stall", self.step_no, rep.idx))
                    progressed = True
                    continue
                n0 = rep.engine.step_no
                rep.engine.run(step_budget=self.cfg.step_budget)
                ran = rep.engine.step_no - n0
                if ran:
                    self.watchdog.record(
                        rep.idx, self.step_no,
                        sum(rep.engine.step_times[-ran:]))
                    progressed = True
        self._harvest()
        self.step_times.append(t.dt)
        self.step_no += 1
        return progressed

    def _harvest_replica(self, rep: _Replica) -> None:
        for rid, c in rep.engine.completions.items():
            if rid not in self.completions:
                self.completions[rid] = c
                self.events.append(("complete", self.step_no, rep.idx, rid))

    def _harvest(self) -> None:
        for rep in self.replicas:
            if rep.alive:
                self._harvest_replica(rep)

    @property
    def pending(self) -> bool:
        return bool(self.backlog) or any(
            rep.alive and rep.engine.pending for rep in self.replicas)

    def _check_liveness(self) -> None:
        if self.plan.rejoin_after(self.step_no):
            return                       # a scripted rejoin can still save us
        if not any(rep.alive for rep in self.replicas):
            raise RuntimeError(
                "fleet dead: every replica killed with work pending and no "
                "rejoin scheduled")
        if self.backlog and not any(rep.alive and not rep.draining
                                    for rep in self.replicas):
            raise RuntimeError(
                "fleet backlog unroutable: every live replica is draining "
                "and no rejoin is scheduled")

    def run(self, requests=None, max_steps: int = 100_000) -> list[Completion]:
        """Drain the backlog (plus ``requests``, submitted first) through
        the fleet; returns completions finished during THIS call in rid
        order. Deterministic: same trace + same fault plan → same
        assignment log, same completions, token for token."""
        done_before = set(self.completions)
        for r in requests or ():
            if isinstance(r, Request):
                self.submit(r.prompt, r.max_new_tokens, rid=r.rid)
            else:
                self.submit(r)
        for _ in range(max_steps):
            if not self.pending and not self.plan.rejoin_after(self.step_no):
                break
            self._check_liveness()
            self.step()
            if not self.pending and not self.plan.rejoin_after(self.step_no):
                break
        else:
            raise RuntimeError(f"fleet did not drain in {max_steps} steps")
        return [self.completions[k] for k in sorted(self.completions)
                if k not in done_before]

    # -- feedback / stats --------------------------------------------------

    def replica_feedback(self) -> dict[int, dict]:
        """The router's live per-replica view — what admission steers on."""
        out: dict[int, dict] = {}
        for rep in self.replicas:
            if not rep.alive:
                out[rep.idx] = {"alive": False}
                continue
            eng = rep.engine
            s = eng.stats()
            out[rep.idx] = {
                "alive": True, "draining": rep.draining,
                "stalled": rep.stall,
                "queue_depth": len(eng.queue), "load": eng.load(),
                "jitted_buckets": s["compiled_buckets"],
                "tokens_per_s": s["tokens_per_s"],
                "watchdog_ema": self.watchdog.ema(rep.idx),
                "cache": eng.cache_stats(),
            }
        return out

    def stats(self) -> dict[str, Any]:
        total = sum(self.step_times)
        useful = sum(len(c.tokens) for c in self.completions.values())
        return {
            "replicas": self.cfg.n_replicas,
            "live": sum(r.alive for r in self.replicas),
            "router": self.cfg.router,
            "fleet_steps": self.step_no,
            "wall_s": total,
            "completed": len(self.completions),
            "useful_tokens": useful,
            "tokens_per_s": useful / total if total else 0.0,
            "steals": self.steals,
            "requeued": self.requeued,
            "assignments": len(self.assignments),
            "per_replica": self.replica_feedback(),
        }
