"""Paged KV-cache subsystem: page pool geometry, allocator, prefix sharing.

The slab cache (`models/transformer.cache_template`) gives every slot a dense
``padded_s_max`` strip, so HBM — not compute — caps concurrent users. The
paged layout replaces the per-slot strips with one fixed pool of
``page_size``-token pages plus a per-slot block table mapping logical pages
to physical ones:

* **Pool**: per attention layer, ``(n_periods, n_pages, Hkv, page, hd)``.
  The page *interior* is striped over the tp axis exactly like the slab's
  sequence dim (``page`` is rounded up to a multiple of |tp|), so the paged
  decode/prefill islands keep the slab path's shard-local writes and
  flash-decode logsumexp merge. The *page* dim is sharded over the dp axes
  whenever the slot batch is (mirroring ``ShardingRules.kv_cache``): each dp
  shard owns a private partition of the pool serving its local slots. Block
  tables hold **global** physical ids (partition ``d`` owns the contiguous
  id range ``[d*ppp, (d+1)*ppp)``); the shard_map body subtracts its dp
  base, and the dense fallback path indexes the global pool as-is.
* **Block tables**: ``(batch, pages_per_slot)`` int32, sharded like ``pos``;
  ``-1`` marks an unmapped logical page. The engine keeps a replicated host
  mirror and scatters rows in at admission / out at retirement, so a
  mid-prefill or free slot's row is all ``-1`` and every decode-step write
  against it is dropped (``.at[...].set(mode="drop")``) — that is what lets
  decode ticks interleave between a long prompt's prefill chunks without
  corrupting pages the chunk program is still filling.
* **Allocator**: host-side free list per partition with refcounted pages.
  Admission allocates the full ``ceil((L + max_new) / page)`` span up front
  (no mid-decode allocation → no preemption); exhaustion surfaces as
  admission backpressure, not an error.
* **Prefix sharing**: completed prefills register ``(prompt, pages)`` in a
  small per-partition registry. A later prompt sharing a prefix retains the
  donor's full pages (refcount++, zero copies) and copy-on-writes the
  boundary page; only positions ``>= write_from`` are (re)written, which is
  sound because causal attention makes K/V at position t a pure function of
  ``tokens[:t+1]``. Registry pages are released lazily when allocation
  pressure demands it.
"""

from __future__ import annotations

import dataclasses
import heapq

from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, RunConfig, ServeConfig
from repro.models.sharding import ShardingRules, axes_size


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Geometry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PageGeometry:
    """Resolved paged-pool shape: all sizes already padded for the mesh."""
    page_size: int           # tokens per page (multiple of |tp|)
    n_pages: int             # pool pages TOTAL (multiple of n_partitions)
    pages_per_slot: int      # block-table width (covers padded_s_max)
    n_partitions: int        # dp partitions of the pool (1 = shared pool)

    @property
    def pages_per_partition(self) -> int:
        return self.n_pages // self.n_partitions

    def pages_for(self, n_tokens: int) -> int:
        """Physical pages covering ``n_tokens`` cache positions."""
        return -(-max(n_tokens, 0) // self.page_size)

    def slot_partition(self, slot: int, max_batch: int) -> int:
        """Pool partition owning ``slot`` (contiguous batch sharding)."""
        return slot // (max_batch // self.n_partitions)

    def resident_capacity(self, n_tokens: int, max_batch: int) -> int:
        """How many ``n_tokens``-position requests fit resident at once."""
        need = max(self.pages_for(n_tokens), 1)
        per_part = self.pages_per_partition // need
        return min(per_part * self.n_partitions, max_batch)


def resolve_page_geometry(serve: ServeConfig, *, s_max: int,
                          tp_size: int = 1,
                          n_partitions: int = 1) -> PageGeometry:
    """Pad the user's page knobs to the mesh: page_size up to a multiple of
    |tp| (even stripes), n_pages up to a multiple of the dp partition count,
    defaulting (``n_pages=0``) to the slab-equivalent pool of ``max_batch``
    slots' worth of pages. ``s_max`` is the engine's *padded* cache length."""
    ps = _round_up(serve.page_size, max(tp_size, 1))
    pages_per_slot = -(-s_max // ps)
    n_pages = serve.n_pages or serve.max_batch * pages_per_slot
    n_pages = _round_up(n_pages, max(n_partitions, 1))
    geom = PageGeometry(page_size=ps, n_pages=n_pages,
                        pages_per_slot=pages_per_slot,
                        n_partitions=max(n_partitions, 1))
    if geom.pages_per_partition < pages_per_slot:
        raise ValueError(
            f"page pool too small: {geom.pages_per_partition} pages per "
            f"partition cannot hold one worst-case request "
            f"({pages_per_slot} pages of {ps} tokens)")
    if serve.prefill_chunk and serve.prefill_chunk % ps:
        raise ValueError(
            f"prefill_chunk ({serve.prefill_chunk}) must be a multiple of "
            f"the padded page size ({ps}; page_size {serve.page_size} was "
            f"rounded up to the tp axis size {tp_size}) — pick a page_size "
            f"that is already a multiple of |tp|")
    return geom


# ---------------------------------------------------------------------------
# Allocator
# ---------------------------------------------------------------------------

class PageAllocator:
    """Host-side refcounting page allocator over per-partition free lists.

    Physical ids are GLOBAL pool indices; partition ``d`` owns the
    contiguous range ``[d*ppp, (d+1)*ppp)``, so the dp-sharded pool's
    shard_map body recovers its local index by subtracting
    ``axis_index(dp) * ppp`` and the island *fallback* (dense reference on
    the global pool) indexes with the ids as-is. Allocation is
    all-or-nothing (returns None on exhaustion → admission backpressure)
    and deterministic (lowest free id first)."""

    def __init__(self, geom: PageGeometry):
        self.geom = geom
        ppp = geom.pages_per_partition
        self._free: list[list[int]] = [
            list(range(d * ppp, (d + 1) * ppp))
            for d in range(geom.n_partitions)]
        for f in self._free:
            heapq.heapify(f)
        self._ref: dict[int, int] = {}

    def free_pages(self, part: int) -> int:
        return len(self._free[part])

    @property
    def resident_pages(self) -> int:
        return self.geom.n_pages - sum(len(f) for f in self._free)

    def alloc(self, part: int, n: int) -> list[int] | None:
        """n fresh pages (refcount 1) from ``part``, or None if exhausted."""
        free = self._free[part]
        if n > len(free):
            return None
        pages = [heapq.heappop(free) for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        return pages

    def retain(self, pages) -> None:
        """refcount++ on already-allocated pages (prefix sharing)."""
        for p in pages:
            self._ref[p] += 1

    def release(self, pages) -> int:
        """refcount--; pages hitting zero return to their partition's free
        list. Returns how many pages were actually freed."""
        ppp = self.geom.pages_per_partition
        freed = 0
        for p in pages:
            self._ref[p] -= 1
            if self._ref[p] == 0:
                del self._ref[p]
                heapq.heappush(self._free[p // ppp], p)
                freed += 1
        return freed

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)


# ---------------------------------------------------------------------------
# Prefix sharing registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _PrefixEntry:
    tokens: tuple[int, ...]
    pages: tuple[int, ...]       # global ids, ceil(len/page) of them
    schedule: object             # chunk-schedule key; share only when equal


class PrefixCache:
    """Per-partition registry of completed prefills for CoW prefix sharing.

    ``register`` retains the prompt's pages so they outlive the slot;
    ``lookup`` returns the best (longest common prefix) donor; ``evict_one``
    releases the oldest entry — the engine calls it when allocation fails,
    so registry history never causes spurious admission backpressure."""

    def __init__(self, allocator: PageAllocator, *, max_entries: int = 8):
        self.alloc = allocator
        self.max_entries = max_entries
        self._entries: list[list[_PrefixEntry]] = \
            [[] for _ in range(allocator.geom.n_partitions)]

    def register(self, part: int, tokens, pages, schedule) -> None:
        tokens = tuple(int(t) for t in tokens)
        if not tokens or not pages:
            return
        ent = self._entries[part]
        if any(e.tokens == tokens and e.schedule == schedule for e in ent):
            return
        self.alloc.retain(pages)
        ent.append(_PrefixEntry(tokens, tuple(pages), schedule))
        while len(ent) > self.max_entries:
            self._release(ent.pop(0))

    def lookup(self, part: int, tokens, schedule):
        """Longest-common-prefix donor: (match_len, entry) or (0, None)."""
        tokens = tuple(int(t) for t in tokens)
        best_m, best_e = 0, None
        for e in self._entries[part]:
            if e.schedule != schedule:
                continue
            m = 0
            for a, b in zip(tokens, e.tokens):
                if a != b:
                    break
                m += 1
            if m > best_m:
                best_m, best_e = m, e
        return best_m, best_e

    def evict_one(self, part: int) -> bool:
        ent = self._entries[part]
        if not ent:
            return False
        self._release(ent.pop(0))
        return True

    def _release(self, e: _PrefixEntry) -> None:
        self.alloc.release(e.pages)

    def __len__(self) -> int:
        return sum(len(e) for e in self._entries)


# ---------------------------------------------------------------------------
# Paged cache template
# ---------------------------------------------------------------------------

def page_partitions(rules: ShardingRules | None, batch: int) -> int:
    """dp partitions of the page pool — mirrors the slot-batch sharding:
    the pool partitions exactly when ``pos``/block-table rows shard, so a
    slot's pages always live on the dp shard that computes it."""
    if rules is None or rules.dim(batch, rules.dp) is None:
        return 1
    return axes_size(rules.mesh, rules.dp)


def paged_kv_pool_spec(rules: ShardingRules | None, n_kv: int, batch: int,
                       geom: PageGeometry) -> P:
    """(N_pages, Hkv, page, hd): pages over dp (iff the batch shards), page
    interior over tp — the paged twin of ``ShardingRules.kv_cache``."""
    if rules is None:
        return P(None, None, None, None)
    part = rules.dp if geom.n_partitions > 1 else None
    if not rules.run.decode_seq_shard:
        return P(part, rules.dim(n_kv, rules.tp), None, None)
    return P(part, None, rules.tp, None)


def paged_cache_template(cfg: ArchConfig, run: RunConfig,
                         rules: ShardingRules | None, *, batch: int,
                         geom: PageGeometry, kv_dtype: str = "bf16") -> dict:
    """PD tree for the paged decode cache: per-layer page pools, per-slot
    block tables (−1 = unmapped; the engine fills rows at admission) and the
    per-slot position vector. Attention-only architectures — SSM recurrent
    state has no paged equivalent here.

    ``kv_dtype="int8"`` stores the pools as int8 plus per-(page-position,
    head) f32 scale pools (``k_scale``/``v_scale``, the pool shape minus hd);
    the paged islands quantize on write and dequantize on gather."""
    from repro.models.transformer import DTYPES, PD

    if cfg.encoder_decoder:
        raise ValueError("paged cache_layout does not cover encoder-decoder")
    if any(sp.mixer != "attn" for sp in cfg.layer_pattern()):
        raise ValueError(
            f"cache_layout='paged' requires a pure-attention architecture; "
            f"{cfg.name} has SSM layers whose recurrent state cannot be "
            f"paged (use the slab layout / exact_buckets)")
    import jax.numpy as jnp
    dt = DTYPES[cfg.dtype]
    kv_dt = {"bf16": dt, "int8": jnp.int8}[kv_dtype]
    hkv, hd, np_ = cfg.n_kv_heads, cfg.hd, cfg.n_periods
    pool_spec = paged_kv_pool_spec(rules, hkv, batch, geom)
    bspec = rules.dim(batch, rules.dp) if rules else None
    tree = {
        "pos": PD((batch,), P(bspec), "zeros", jnp.int32),
        "block_tables": PD((batch, geom.pages_per_slot), P(bspec, None),
                           "zeros", jnp.int32),
        "blocks": {},
    }
    shape = (np_, geom.n_pages, hkv, geom.page_size, hd)
    for i, _spec in enumerate(cfg.layer_pattern()):
        kv = {
            "k": PD(shape, P(None, *pool_spec), "zeros", kv_dt),
            "v": PD(shape, P(None, *pool_spec), "zeros", kv_dt),
        }
        if kv_dtype == "int8":
            sspec = P(None, *pool_spec[:3])
            kv["k_scale"] = PD(shape[:-1], sspec, "zeros", jnp.float32)
            kv["v_scale"] = PD(shape[:-1], sspec, "zeros", jnp.float32)
        tree["blocks"][f"pos{i}"] = kv
    return tree


def _kv_bytes_per_pos(cfg: ArchConfig, kv_dtype: str) -> int:
    """K/V cache bytes per cached position (all layers, both K and V).

    int8 pays 1 byte/element plus one f32 scale per (position, head, K|V)."""
    if kv_dtype == "int8":
        return cfg.n_layers * cfg.n_kv_heads * 2 * (cfg.hd + 4)
    dt_bytes = 2 if cfg.dtype == "bfloat16" else 4
    return cfg.n_layers * cfg.n_kv_heads * cfg.hd * 2 * dt_bytes


def pool_hbm_bytes(cfg: ArchConfig, geom: PageGeometry,
                   kv_dtype: str = "bf16") -> int:
    """Total K/V pool bytes (all layers, both K and V, incl. int8 scales)."""
    return geom.n_pages * geom.page_size * _kv_bytes_per_pos(cfg, kv_dtype)


def slab_hbm_bytes(cfg: ArchConfig, batch: int, s_max: int,
                   kv_dtype: str = "bf16") -> int:
    """Slab-equivalent K/V bytes for the same slot count — the denominator
    of the paged-vs-slab memory story in stats()/fig_serving."""
    return batch * s_max * _kv_bytes_per_pos(cfg, kv_dtype)
