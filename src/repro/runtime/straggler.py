"""Straggler watchdog: per-step wall-time EMA with a deadline multiple.

On a real pod this drives mitigation (preempt + re-slot the slow host, or
drop to the checkpoint and exclude it — runtime/elastic.py); in this
container the detection logic is what we can exercise (tests inject delays).

``FleetWatchdog`` is the multi-replica feed (runtime/fleet.py): one
``StragglerWatchdog`` per serving replica, plus a cross-replica comparison —
a replica whose step-time EMA exceeds ``factor`` x the median EMA of its
live peers is a *fleet* straggler even if its own per-step deadline never
fires (a uniformly-slow replica looks healthy to itself). The fleet router
steals queued requests from flagged replicas.
"""

from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class StragglerWatchdog:
    factor: float = 3.0          # deadline = factor × EMA
    ema_decay: float = 0.9
    min_samples: int = 5
    ema: float = 0.0
    n: int = 0
    events: list = dataclasses.field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        """Record a step time; returns True if this step is a straggler."""
        is_straggler = (self.n >= self.min_samples
                        and dt > self.factor * self.ema)
        if is_straggler:
            self.events.append({"step": step, "dt": dt, "ema": self.ema})
        else:  # stragglers don't poison the EMA
            self.ema = (dt if self.n == 0
                        else self.ema_decay * self.ema
                        + (1 - self.ema_decay) * dt)
            self.n += 1
        return is_straggler

    @property
    def deadline(self) -> float:
        return self.factor * self.ema if self.n >= self.min_samples else float("inf")


class FleetWatchdog:
    """Per-replica straggler feed for the serving fleet.

    Each replica's fleet turn records ONE sample (the wall time of the
    engine steps it ran, plus any injected fault delay) into that replica's
    ``StragglerWatchdog``. ``stragglers()`` then flags a replica when

    * its own watchdog flagged the most recent sample (deadline blown), or
    * its EMA exceeds ``factor`` x the median EMA across the live replicas
      (relative slowness its own deadline cannot see).

    ``min_samples=1`` on the per-replica feeds: a replica's very first
    sample seeds its EMA, so scripted delays are visible immediately.
    """

    def __init__(self, n_replicas: int, factor: float = 3.0,
                 ema_decay: float = 0.9):
        self.factor = factor
        self.ema_decay = ema_decay
        self.feeds = {r: StragglerWatchdog(factor=factor,
                                           ema_decay=ema_decay,
                                           min_samples=1)
                      for r in range(n_replicas)}
        self._last_flag = {r: False for r in range(n_replicas)}

    def record(self, replica: int, step: int, dt: float) -> bool:
        flagged = self.feeds[replica].record(step, dt)
        self._last_flag[replica] = flagged
        return flagged

    def reset(self, replica: int) -> None:
        """Fresh feed for a rejoining replica (its old EMA is meaningless
        after a restore)."""
        self.feeds[replica] = StragglerWatchdog(factor=self.factor,
                                                ema_decay=self.ema_decay,
                                                min_samples=1)
        self._last_flag[replica] = False

    def ema(self, replica: int) -> float:
        return self.feeds[replica].ema

    def stragglers(self, live=None) -> list[int]:
        rs = sorted(self.feeds if live is None else live)
        emas = sorted(self.feeds[r].ema for r in rs if self.feeds[r].n > 0)
        med = emas[len(emas) // 2] if emas else 0.0
        out = []
        for r in rs:
            feed = self.feeds[r]
            if self._last_flag[r] or (len(emas) >= 2 and med > 0.0
                                      and feed.n > 0
                                      and feed.ema > self.factor * med):
                out.append(r)
        return out


class StepTimer:
    def __init__(self):
        self.t0 = None

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0
