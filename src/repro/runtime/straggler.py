"""Straggler watchdog: per-step wall-time EMA with a deadline multiple.

On a real pod this drives mitigation (preempt + re-slot the slow host, or
drop to the checkpoint and exclude it — runtime/elastic.py); in this
container the detection logic is what we can exercise (tests inject delays).
"""

from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class StragglerWatchdog:
    factor: float = 3.0          # deadline = factor × EMA
    ema_decay: float = 0.9
    min_samples: int = 5
    ema: float = 0.0
    n: int = 0
    events: list = dataclasses.field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        """Record a step time; returns True if this step is a straggler."""
        is_straggler = (self.n >= self.min_samples
                        and dt > self.factor * self.ema)
        if is_straggler:
            self.events.append({"step": step, "dt": dt, "ema": self.ema})
        else:  # stragglers don't poison the EMA
            self.ema = (dt if self.n == 0
                        else self.ema_decay * self.ema
                        + (1 - self.ema_decay) * dt)
            self.n += 1
        return is_straggler

    @property
    def deadline(self) -> float:
        return self.factor * self.ema if self.n >= self.min_samples else float("inf")


class StepTimer:
    def __init__(self):
        self.t0 = None

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0
