"""GPipe pipeline parallelism over a `pipe` mesh axis (DESIGN §4).

Each pipeline rank holds one stage's parameters; microbatch activations flow
stage-to-stage as one-hop `ppermute`s — the PK `store_async`-to-neighbor
pattern (each handoff is a pre-allocated one-way transfer, and on TPU the
hop overlaps the next microbatch's stage compute on the ICI DMA engines).

Schedule: plain GPipe — M microbatches over S stages in M+S-1 ticks; bubble
fraction (S-1)/(M+S-1). Every rank executes every tick (idle ranks compute on
garbage and their output is masked), which keeps the SPMD program uniform.

The graded production meshes are DP×TP (per the assignment); this module is
the optional PP feature, exercised by tests/test_pipeline.py on a small mesh.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.template import Comm, Island


def gpipe_apply(stage_fn: Callable, stage_params, x_mb, axis_name: str):
    """Run `stage_fn(params_stage, x) -> y` over all pipeline stages.

    Call INSIDE shard_map with `axis_name` bound; `stage_params` is this
    rank's stage slice; `x_mb`: (M, mb, ...) microbatched input (same value on
    every rank; only stage 0 consumes it). Returns (M, mb, ...) outputs,
    valid on the LAST stage (replicate/collect at the caller).
    Activations must keep a constant shape across stages (residual-stream
    models do)."""
    n = compat.axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    m = x_mb.shape[0]
    perm = [(j, (j + 1) % n) for j in range(n)]
    carry = jnp.zeros_like(x_mb[0])
    outs = jnp.zeros_like(x_mb)

    for t in range(m + n - 1):
        inj = x_mb[t] if t < m else jnp.zeros_like(x_mb[0])
        inp = jnp.where(idx == 0, inj, carry)
        out = stage_fn(stage_params, inp)
        # last stage's tick-t output is microbatch t-(n-1)
        mb_idx = t - (n - 1)
        if mb_idx >= 0:
            outs = lax.cond(
                idx == n - 1,
                lambda o: lax.dynamic_update_index_in_dim(o, out, mb_idx, 0),
                lambda o: o, outs)
        # one-hop handoff to the next stage (PK one-way neighbor store)
        carry = lax.ppermute(out, axis_name, perm)
    return outs


def gpipe_island(stage_fn: Callable, mesh, *, n_microbatches: int,
                 n_stages: int | None = None,
                 axis_name: str = "pipe", run=None) -> Island:
    """The GPipe pipeline as a unified-template Island (jit-level entry).

    Declared inputs: ``stage_params`` — per-stage parameters stacked on a
    leading stage dim, sharded over `axis_name` (each rank sees its slab);
    ``x_mb`` — (M, mb, ...) microbatched input, replicated. When the stage
    count exceeds the axis size, each rank holds a contiguous slab of
    "virtual" stages and composes them sequentially inside its pipeline
    tick (interleaving-free virtual pipelining) — stages are never silently
    dropped. The body runs :func:`gpipe_apply` and psum-broadcasts the last
    rank's outputs so the result is valid everywhere. Fallback (single
    device / reference mode) runs the stages sequentially — same math, no
    pipeline.
    """
    n = mesh.shape[axis_name] if mesh is not None else 1

    def body(ctx, stage_params, x_mb):
        n_loc = jax.tree.leaves(stage_params)[0].shape[0]

        def local_stages(slab, x):
            # rank r holds contiguous stages [r*n_loc, (r+1)*n_loc): compose
            # them in order within this rank's tick
            h = x
            for i in range(n_loc):
                h = stage_fn(jax.tree.map(lambda a: a[i], slab), h)
            return h

        outs = gpipe_apply(local_stages, stage_params, x_mb, axis_name)
        idx = lax.axis_index(axis_name)
        # outputs are valid on the LAST stage only (bubble masking); psum of
        # the masked tensor broadcasts them without a dedicated collective
        return lax.psum(jnp.where(idx == n - 1, outs, 0.0), axis_name)

    def reference(stage_params, x_mb):
        n_stages = jax.tree.leaves(stage_params)[0].shape[0]
        h = x_mb
        for i in range(n_stages):
            h = stage_fn(jax.tree.map(lambda a: a[i], stage_params), h)
        return h

    return Island(
        "gpipe", mesh=mesh, axis=axis_name, run=run,
        inputs={"stage_params": P(axis_name), "x_mb": P()},
        out_specs=P(),
        body=body, reference=reference,
        # a stage count the pipe axis doesn't divide cannot shard: route it
        # to the sequential reference (readable plan reason) instead of a
        # low-level shard_map error
        divisible=((n_stages, axis_name),) if n_stages is not None else (),
        comm=Comm("ring_shift", backend="bulk",
                  n_chunks=n_microbatches + n - 1))


def gpipe_forward(stage_fn: Callable, stage_params, x_mb, mesh, *,
                  axis_name: str = "pipe", run=None):
    """Run the GPipe Island: (M, mb, ...) -> (M, mb, ...) outputs replicated
    on every rank (the convenience entry the launchers/tests use)."""
    n_stages = jax.tree.leaves(stage_params)[0].shape[0]
    island = gpipe_island(stage_fn, mesh, n_microbatches=x_mb.shape[0],
                          n_stages=n_stages, axis_name=axis_name, run=run)
    return island(stage_params=stage_params, x_mb=x_mb)


def gpipe_loss(stage_fn, loss_fn, stage_params, x_mb, targets_mb,
               axis_name: str):
    """Forward through the pipe + loss on the last stage, broadcast to all
    ranks (differentiable; the backward flows the pipe in reverse via the
    ppermute transposes)."""
    n = compat.axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    outs = gpipe_apply(stage_fn, stage_params, x_mb, axis_name)
    per_mb = loss_fn(outs, targets_mb)
    loss = jnp.where(idx == n - 1, per_mb, 0.0)
    return lax.psum(loss, axis_name)
