"""GPipe pipeline parallelism over a `pipe` mesh axis (DESIGN §4).

Each pipeline rank holds one stage's parameters; microbatch activations flow
stage-to-stage as one-hop `ppermute`s — the PK `store_async`-to-neighbor
pattern (each handoff is a pre-allocated one-way transfer, and on TPU the
hop overlaps the next microbatch's stage compute on the ICI DMA engines).

Schedule: plain GPipe — M microbatches over S stages in M+S-1 ticks; bubble
fraction (S-1)/(M+S-1). Every rank executes every tick (idle ranks compute on
garbage and their output is masked), which keeps the SPMD program uniform.

The graded production meshes are DP×TP (per the assignment); this module is
the optional PP feature, exercised by tests/test_pipeline.py on a small mesh.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat


def gpipe_apply(stage_fn: Callable, stage_params, x_mb, axis_name: str):
    """Run `stage_fn(params_stage, x) -> y` over all pipeline stages.

    Call INSIDE shard_map with `axis_name` bound; `stage_params` is this
    rank's stage slice; `x_mb`: (M, mb, ...) microbatched input (same value on
    every rank; only stage 0 consumes it). Returns (M, mb, ...) outputs,
    valid on the LAST stage (replicate/collect at the caller).
    Activations must keep a constant shape across stages (residual-stream
    models do)."""
    n = compat.axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    m = x_mb.shape[0]
    perm = [(j, (j + 1) % n) for j in range(n)]
    carry = jnp.zeros_like(x_mb[0])
    outs = jnp.zeros_like(x_mb)

    for t in range(m + n - 1):
        inj = x_mb[t] if t < m else jnp.zeros_like(x_mb[0])
        inp = jnp.where(idx == 0, inj, carry)
        out = stage_fn(stage_params, inp)
        # last stage's tick-t output is microbatch t-(n-1)
        mb_idx = t - (n - 1)
        if mb_idx >= 0:
            outs = lax.cond(
                idx == n - 1,
                lambda o: lax.dynamic_update_index_in_dim(o, out, mb_idx, 0),
                lambda o: o, outs)
        # one-hop handoff to the next stage (PK one-way neighbor store)
        carry = lax.ppermute(out, axis_name, perm)
    return outs


def gpipe_loss(stage_fn, loss_fn, stage_params, x_mb, targets_mb,
               axis_name: str):
    """Forward through the pipe + loss on the last stage, broadcast to all
    ranks (differentiable; the backward flows the pipe in reverse via the
    ppermute transposes)."""
    n = compat.axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    outs = gpipe_apply(stage_fn, stage_params, x_mb, axis_name)
    per_mb = loss_fn(outs, targets_mb)
    loss = jnp.where(idx == n - 1, per_mb, 0.0)
    return lax.psum(loss, axis_name)
