"""Training / serving step factories: grad accumulation (microbatching),
optimizer update with donation-friendly state threading, optional gradient
compression hook, and the serve (decode) step used by the inference cells.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig, RunConfig
from repro.models import transformer as T
from repro.models.sharding import ShardingRules
from repro.optim.adamw import AdamW, AdamWState


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def make_train_step(cfg: ArchConfig, run: RunConfig,
                    rules: ShardingRules | None, optimizer: AdamW,
                    grad_transform: Callable | None = None):
    """Returns train_step(state, batch) -> (state, metrics).

    Microbatching: batch leading dim is split into run.microbatches chunks and
    gradients are accumulated with lax.scan — bounds activation memory while
    keeping one optimizer update per step. grad_transform (e.g. int8
    compression with error feedback, optim/compress.py) is applied to the
    accumulated gradient before the update."""

    def loss_fn(params, mb):
        loss, metrics = T.forward_train(params, mb, cfg, run, rules)
        return loss, metrics

    def train_step(state: TrainState, batch):
        nm = run.microbatches
        if nm > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(nm, b // nm, *x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def accum(carry, mb):
                g_acc, l_acc = carry
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / nm, g_acc, g)
                return (g_acc, l_acc + loss / nm), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
            (grads, loss), _ = lax.scan(accum, (g0, jnp.zeros(())), mbs)
        else:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state.params, batch)

        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt, gnorm = optimizer.update(grads, state.opt, state.params)
        return TrainState(params, opt), {"loss": loss, "grad_norm": gnorm,
                                         "step": opt.step}

    return train_step


def make_serve_step(cfg: ArchConfig, run: RunConfig,
                    rules: ShardingRules | None, *, long_ctx: bool = False):
    """Returns serve_step(params, cache, tokens) -> (logits, cache) — one new
    token against a pre-filled KV/SSM cache (the decode_* shape cells)."""
    if cfg.encoder_decoder:
        def serve_step(params, cache, tokens):
            return T.decode_step_encdec(params, cache, tokens, cfg, run, rules)
    else:
        def serve_step(params, cache, tokens):
            return T.decode_step(params, cache, tokens, cfg, run, rules,
                                 long_ctx=long_ctx)
    return serve_step


def make_prefill_step(cfg: ArchConfig, run: RunConfig,
                      rules: ShardingRules | None):
    def prefill_step(params, batch):
        return T.forward_prefill(params, batch, cfg, run, rules)
    return prefill_step


def make_prefill_cache_step(cfg: ArchConfig, run: RunConfig,
                            rules: ShardingRules | None):
    """Returns prefill(params, cache, tokens, prompt_lens) -> (logits, cache)
    — the batched cache-building prefill (one full-sequence forward, K/V and
    SSM state written into the decode cache). The serving engine jits one of
    these per (prompt bucket × group size), each with the bucket's resolved
    island plans threaded through ``run.island_overrides``."""
    def prefill_step(params, cache, tokens, prompt_lens):
        return T.prefill_step(params, cache, tokens, prompt_lens, cfg, run,
                              rules)
    return prefill_step


def make_paged_prefill_step(cfg: ArchConfig, run: RunConfig,
                            rules: ShardingRules | None):
    """Returns prefill(params, cache, tokens, block_tables, prompt_lens,
    chunk_start, write_from) -> (logits, cache) — one chunk of paged
    cache-building prefill against the page pool (runtime/paging.py). The
    serving engine jits exactly one of these per chunk length: with
    ``prefill_chunk`` set, every bucket shares the same (G, cl) program and
    only the chunk count varies — prefill becomes just another chunked
    schedule the engine interleaves with decode ticks."""
    def prefill_step(params, cache, tokens, block_tables, prompt_lens,
                     chunk_start, write_from):
        return T.prefill_paged_step(params, cache, tokens, block_tables,
                                    prompt_lens, chunk_start, write_from,
                                    cfg, run, rules)
    return prefill_step
