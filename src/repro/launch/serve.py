"""Serving launcher: batched prefill + decode loop.

`python -m repro.launch.serve --arch tinyllama-1.1b --reduced --tokens 32`
runs a real batched generation on local devices and reports tokens/s.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.core.template import render_plans
from repro.launch import specs as SP
from repro.launch.mesh import make_mesh
from repro.models import transformer as T
from repro.models.layers import island_plans
from repro.models.sharding import ShardingRules
from repro.train.step import make_serve_step


def generate(arch: str, *, reduced: bool, batch: int, prompt_len: int,
             gen_tokens: int, mesh_shape=None, mesh_axes=("data", "model"),
             seed: int = 0, greedy: bool = True,
             comm_policy: str = "analytic", comm_chunks: int | None = None):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    mesh = make_mesh(mesh_shape, mesh_axes) if mesh_shape else None
    run = RunConfig(dp_axes=("data",), fsdp=False,
                    decode_seq_shard=mesh is not None,
                    comm_policy=comm_policy, comm_chunks=comm_chunks)
    rules = ShardingRules(mesh, run) if mesh is not None else None

    tmpl = T.param_template(cfg, run, rules)
    params = T.init_params(tmpl, jax.random.PRNGKey(seed), cfg.d_model)
    if rules is not None:
        params = jax.tree.map(jax.device_put, params,
                              SP.named(mesh, T.param_specs(tmpl)))

    s_max = prompt_len + gen_tokens
    if rules is not None:
        # the whole serving pass's overlap schedule, before anything traces
        print(render_plans(island_plans(cfg, run, rules, batch=batch,
                                        seq=s_max)))
    ct = T.cache_template(cfg, run, rules, batch=batch, s_max=s_max,
                          enc_len=prompt_len if cfg.encoder_decoder else 0)
    cache = T.init_params(ct, jax.random.PRNGKey(1), cfg.d_model)
    if rules is not None:
        cache = jax.tree.map(jax.device_put, cache,
                             SP.named(mesh, T.param_specs(ct)))

    step = jax.jit(make_serve_step(cfg, run, rules), donate_argnums=(1,))
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, (batch, 1), 0, cfg.vocab_size)
    if cfg.encoder_decoder:
        enc = jnp.ones((batch, prompt_len, cfg.d_model), jnp.bfloat16)
        # precompute stub cross KV = zeros already in cache; fine for perf
    # prefill simulation: feed prompt tokens one by one (correct but simple —
    # a production prefill uses forward_prefill; exercised in tests)
    out_tokens = []
    t0 = time.perf_counter()
    for i in range(prompt_len + gen_tokens):
        logits, cache = step(params, cache, tokens)
        tokens = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)[:, None] \
            .astype(jnp.int32)
        if i >= prompt_len:
            out_tokens.append(tokens)
    jax.block_until_ready(tokens)
    dt = time.perf_counter() - t0
    total = batch * (prompt_len + gen_tokens)
    print(f"[serve] {arch}: {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s, batch={batch})")
    return jnp.concatenate(out_tokens, axis=1) if out_tokens else None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--mesh-shape", type=int, nargs="*", default=None)
    ap.add_argument("--comm-policy", default="analytic",
                    choices=["analytic", "measured", "auto"])
    ap.add_argument("--comm-chunks", type=int, default=None)
    args = ap.parse_args()
    generate(args.arch, reduced=args.reduced, batch=args.batch,
             prompt_len=args.prompt_len, gen_tokens=args.tokens,
             mesh_shape=args.mesh_shape, comm_policy=args.comm_policy,
             comm_chunks=args.comm_chunks)


if __name__ == "__main__":
    main()
