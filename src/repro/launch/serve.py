"""Serving launcher — a thin CLI over the continuous-batching engine
(``repro.runtime.serving``).

    # static batch (the classic throughput run)
    python -m repro.launch.serve --arch tinyllama-1.1b --reduced \
        --mesh-shape 1 8 --batch 8 --prompt-len 8 --tokens 16

    # continuous batching over a synthetic request trace
    python -m repro.launch.serve --arch tinyllama-1.1b --reduced \
        --mesh-shape 1 8 --mode continuous --requests 12 --tokens 16

    # a 2-replica fleet with a scripted kill + rejoin
    python -m repro.launch.serve --arch tinyllama-1.1b --reduced \
        --mode continuous --replicas 2 --router least-loaded \
        --fault-plan "kill:1@4 rejoin:1@8" --requests 12

``--replicas N`` (N > 1) wraps N engine replicas in a
``runtime.fleet.ServingFleet`` behind the chosen ``--router`` policy;
``--fault-plan`` injects scripted kill/delay/drain/rejoin events
(``kind:replica@step[xticks]``).

Both modes print the per-bucket serving plan table (island backend / chunks
/ hidden fraction, measured on a calibrated mesh) before anything traces —
the engine consumes exactly those plans via ``RunConfig.island_overrides``.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import RunConfig, ServeConfig
from repro.launch import specs as SP
from repro.launch.mesh import make_mesh
from repro.models import transformer as T
from repro.models.sharding import ShardingRules
from repro.runtime.serving import ServingEngine, render_serving_plans


def build_engine(arch: str, *, reduced: bool = True, mesh_shape=None,
                 mesh_axes=("data", "model"), serve: ServeConfig | None = None,
                 seed: int = 0, comm_policy: str = "analytic",
                 comm_chunks: int | None = None,
                 run_overrides: dict | None = None,
                 comm_faults=None) -> ServingEngine:
    """Config -> params -> ServingEngine, on local devices (CPU-emulated or
    a real slice). The tests and the bench harness build engines through
    this, so there is exactly one construction path. ``comm_faults`` is a
    ``runtime.health.CommFaultPlan`` (or its spec string) of scripted
    comms-level faults."""
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    mesh = make_mesh(mesh_shape, mesh_axes) if mesh_shape else None
    kw = dict(dp_axes=tuple(a for a in (mesh_axes or ()) if a != "model")
              or ("data",),
              fsdp=False, decode_seq_shard=mesh is not None,
              comm_policy=comm_policy, comm_chunks=comm_chunks)
    kw.update(run_overrides or {})
    run = RunConfig(**kw)
    rules = ShardingRules(mesh, run) if mesh is not None else None
    tmpl = T.param_template(cfg, run, rules)
    params = T.init_params(tmpl, jax.random.PRNGKey(seed), cfg.d_model)
    if rules is not None:
        params = jax.tree.map(jax.device_put, params,
                              SP.named(mesh, T.param_specs(tmpl)))
    if serve is None:
        ssm = any(sp.mixer == "mamba" for sp in cfg.layer_pattern())
        serve = ServeConfig(exact_buckets=ssm)
    return ServingEngine(cfg, run, rules, params, serve,
                         comm_faults=comm_faults)


def synthetic_trace(n_requests: int, serve: ServeConfig, vocab: int,
                    seed: int = 0):
    """Deterministic mixed-bucket request trace: prompt lengths drawn over
    the bucket range, token ids over the vocab."""
    rng = np.random.RandomState(seed)
    lo = 2
    hi = serve.bucket_edges[-1]
    out = []
    for _ in range(n_requests):
        n = int(rng.randint(lo, hi + 1))
        out.append(tuple(int(t) for t in rng.randint(0, vocab, size=n)))
    return out


def generate(arch: str, *, reduced: bool, batch: int, prompt_len: int,
             gen_tokens: int, mesh_shape=None, mesh_axes=("data", "model"),
             seed: int = 0, greedy: bool = True,
             comm_policy: str = "analytic", comm_chunks: int | None = None,
             comm_wire: str | None = None, kv_dtype: str = "bf16"):
    """Static-batch generation (the legacy entry point, now one engine
    call): `batch` synthetic prompts of `prompt_len` tokens, prefilled as
    one batch and decoded in lockstep. Returns the (batch, gen_tokens)
    generated ids and prints tokens/s."""
    import time

    import jax.numpy as jnp

    # exact_buckets: uniform static prompts never pad, and it keeps the
    # engine's SSM right-padding guard satisfied for mamba archs
    serve = ServeConfig(bucket_edges=(max(prompt_len, 2),),
                        max_new_tokens=gen_tokens,
                        max_batch=batch, prefill_batch=min(batch, 8),
                        exact_buckets=True, kv_dtype=kv_dtype)
    eng = build_engine(arch, reduced=reduced, mesh_shape=mesh_shape,
                       mesh_axes=mesh_axes, serve=serve, seed=seed,
                       comm_policy=comm_policy, comm_chunks=comm_chunks,
                       run_overrides={"comm_wire": comm_wire})
    if eng.rules is not None:
        print(f"[plan] comm_policy={comm_policy}")
        print(render_serving_plans(eng.bucket_plans))
    rng = np.random.RandomState(seed)
    prompts = [tuple(int(t) for t in
                     rng.randint(0, eng.cfg.vocab_size, size=prompt_len))
               for _ in range(batch)]
    t0 = time.perf_counter()
    out = eng.generate_static(prompts, gen_tokens)
    dt = time.perf_counter() - t0
    total = batch * (prompt_len + gen_tokens)
    print(f"[serve] {arch}: {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s, batch={batch})")
    return jnp.asarray(out, jnp.int32)


def serve_fleet(args, serve: ServeConfig) -> None:
    """Continuous mode with ``--replicas > 1``: a ServingFleet over
    identical engine replicas (same arch/serve/seed — data-parallel), with
    optional scripted faults, ending in the fleet + per-replica stats."""
    from repro.configs.base import FleetConfig
    from repro.runtime.fleet import FaultPlan, ServingFleet

    def factory(i: int) -> ServingEngine:
        overrides = {"comm_wire": args.comm_wire,
                     "island_guards": args.island_guards}
        if args.comm_backend:
            overrides["comm_backend"] = args.comm_backend
        return build_engine(args.arch, reduced=args.reduced,
                            mesh_shape=args.mesh_shape, serve=serve,
                            seed=args.seed, comm_policy=args.comm_policy,
                            comm_chunks=args.comm_chunks,
                            run_overrides=overrides)

    plan = FaultPlan.parse(args.fault_plan) if args.fault_plan else None
    fleet = ServingFleet(
        factory, FleetConfig(n_replicas=args.replicas, router=args.router),
        fault_plan=plan, ckpt_dir=args.ckpt_dir)
    trace = synthetic_trace(args.requests, serve,
                            fleet.replicas[0].engine.cfg.vocab_size,
                            seed=args.seed)
    done = fleet.run(trace)
    st = fleet.stats()
    print(f"[fleet] {args.arch} x{st['replicas']} ({st['router']}): "
          f"{len(done)} requests, {st['useful_tokens']} tokens in "
          f"{st['wall_s']:.2f}s ({st['tokens_per_s']:.1f} tok/s; "
          f"{st['fleet_steps']} fleet steps, {st['assignments']} routed, "
          f"{st['steals']} steals, {st['requeued']} requeued, "
          f"{st['live']}/{st['replicas']} live)")
    for idx, fb in sorted(st["per_replica"].items()):
        if not fb["alive"]:
            print(f"[fleet]   r{idx}: dead")
            continue
        print(f"[fleet]   r{idx}: load={fb['load']} "
              f"queue={fb['queue_depth']} "
              f"tok/s={fb['tokens_per_s']:.1f} "
              f"buckets={fb['jitted_buckets']} "
              f"ema={fb['watchdog_ema']:.3f}"
              + (" (draining)" if fb["draining"] else ""))
    if args.fault_plan:
        kinds = [e[0] for e in fleet.events
                 if e[0] in ("kill", "drain", "rejoin", "delay", "stall",
                             "steal", "snapshot", "comm_fault")]
        print(f"[fleet] fault events fired: {kinds}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", default="static",
                    choices=["static", "continuous"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8,
                    help="continuous mode: synthetic trace length")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--prefill-batch", type=int, default=4)
    ap.add_argument("--bucket-edges", type=int, nargs="*", default=None)
    ap.add_argument("--queue-policy", default="fcfs",
                    choices=["fcfs", "bucket-greedy"])
    ap.add_argument("--cache-layout", default="slab",
                    choices=["slab", "paged"])
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged layout: tokens per KV page")
    ap.add_argument("--n-pages", type=int, default=0,
                    help="paged layout: pool pages (0 = slab-equivalent)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="paged layout: split prefill into page-aligned "
                         "chunks so decode ticks interleave (0 = off)")
    ap.add_argument("--mesh-shape", type=int, nargs="*", default=None)
    ap.add_argument("--comm-policy", default="analytic",
                    choices=["analytic", "measured", "auto"])
    ap.add_argument("--comm-chunks", type=int, default=None)
    ap.add_argument("--comm-wire", default=None,
                    choices=["bf16", "int8", "int8_sr"],
                    help="GEMM-collective ring wire format (int8 ships "
                         "quantized sub-chunks + f32 scales)")
    ap.add_argument("--kv-dtype", default="bf16", choices=["bf16", "int8"],
                    help="KV-cache storage dtype: int8 quantizes on write "
                         "with per-(token, head) f32 scales, roughly "
                         "halving cache HBM")
    ap.add_argument("--replicas", type=int, default=1,
                    help="continuous mode: >1 runs a ServingFleet of "
                         "data-parallel engine replicas")
    ap.add_argument("--router", default="least-loaded",
                    choices=["fcfs", "least-loaded", "cache-affinity"])
    ap.add_argument("--fault-plan", default=None,
                    help="scripted fleet faults, e.g. 'kill:1@4 rejoin:1@8', "
                         "'delay:0@2x3', or comms-level "
                         "'linkdown:1.mlp@4x3' "
                         "(kind:replica[.island]@step[xticks])")
    ap.add_argument("--comm-fault-plan", default=None,
                    help="single-engine scripted comms faults, e.g. "
                         "'corrupt:mlp@3 stall:mlp@5x6' "
                         "(kind:island@step[xticks])")
    ap.add_argument("--comm-backend", default=None,
                    help="pin every GEMM island's collective backend "
                         "(e.g. ring), bypassing dispatch — mostly for "
                         "fault drills")
    ap.add_argument("--island-guards", action="store_true",
                    help="jit-compatible finite-checks on island "
                         "inputs/outputs; trips feed the health monitor")
    ap.add_argument("--health-monitor", action="store_true",
                    help="per-island EMA health monitor: demote a drifting "
                         "island's backend with hysteresis, re-promote "
                         "after probation")
    ap.add_argument("--ckpt-dir", default=None,
                    help="fleet: snapshot/rejoin checkpoint directory")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.mode == "static":
        generate(args.arch, reduced=args.reduced, batch=args.batch,
                 prompt_len=args.prompt_len, gen_tokens=args.tokens,
                 mesh_shape=args.mesh_shape, comm_policy=args.comm_policy,
                 comm_chunks=args.comm_chunks, seed=args.seed,
                 comm_wire=args.comm_wire, kv_dtype=args.kv_dtype)
        return

    edges = tuple(args.bucket_edges) if args.bucket_edges else (8, 16, 32)
    serve = ServeConfig(max_batch=args.max_batch,
                        prefill_batch=args.prefill_batch,
                        bucket_edges=edges, max_new_tokens=args.tokens,
                        queue_policy=args.queue_policy,
                        cache_layout=args.cache_layout,
                        page_size=args.page_size, n_pages=args.n_pages,
                        prefill_chunk=args.prefill_chunk,
                        kv_dtype=args.kv_dtype,
                        health_monitor=args.health_monitor)
    if args.replicas > 1:
        serve_fleet(args, serve)
        return
    overrides = {"comm_wire": args.comm_wire,
                 "island_guards": args.island_guards}
    if args.comm_backend:
        overrides["comm_backend"] = args.comm_backend
    eng = build_engine(args.arch, reduced=args.reduced,
                       mesh_shape=args.mesh_shape, serve=serve,
                       seed=args.seed, comm_policy=args.comm_policy,
                       comm_chunks=args.comm_chunks,
                       run_overrides=overrides,
                       comm_faults=args.comm_fault_plan)
    if eng.rules is not None:
        print(f"[plan] comm_policy={args.comm_policy}")
        print(render_serving_plans(eng.bucket_plans))
    if eng.paged:
        g = eng.geom
        print(f"[cache] paged: page={g.page_size} pool={g.n_pages} pages "
              f"x {g.n_partitions} partitions "
              f"(chunk={serve.prefill_chunk or 'off'})")
    trace = synthetic_trace(args.requests, serve, eng.cfg.vocab_size,
                            seed=args.seed)
    done = eng.run(trace)
    st = eng.stats()
    print(f"[serve] {args.arch}: {len(done)} requests, "
          f"{st['tokens_generated']} tokens in {st['wall_s']:.2f}s "
          f"({st['tokens_per_s']:.1f} tok/s; "
          f"{st['prefill_steps']} prefill + {st['decode_steps']} decode "
          f"steps; buckets jitted: {st['compiled_buckets']})")
    cs = st["cache"]
    line = (f"[cache] layout={cs['layout']} kv={cs['kv_dtype']} "
            f"hbm={cs['hbm_bytes']/1e6:.1f}MB "
            f"(slab-equivalent {cs['slab_bytes']/1e6:.1f}MB) "
            f"peak_slots={cs['peak_resident_slots']}")
    if cs["layout"] == "paged":
        line += (f" peak_pages={cs['peak_resident_pages']}/{cs['n_pages']} "
                 f"prefix_hits={cs['prefix_hits']} "
                 f"shared_pages={cs['shared_pages_reused']} "
                 f"cow={cs['cow_copies']} "
                 f"blocked={cs['admission_blocked']}")
    print(line)
    if args.comm_fault_plan or args.island_guards or args.health_monitor:
        print(f"[health] quarantined={st['quarantined']} "
              f"retries={st['retries']} guard_trips={st['guard_trips']} "
              f"demotions={st['health_demotions']} "
              f"idle_steps={st['idle_steps']}")
        kinds = [e[0] for e in eng.events
                 if e[0] in ("comm_fault", "comm_fault_end", "guard_trip",
                             "retry", "quarantine", "deadline",
                             "health_demote", "health_promote",
                             "health_link_up")]
        print(f"[health] events fired: {kinds}")
        if eng.health is not None and any(
                o[3] == "health" for o in eng.plan_record()
                ["health_overrides"]):
            print("[health] live overrides: "
                  f"{eng.plan_record()['health_overrides']}")


if __name__ == "__main__":
    main()
