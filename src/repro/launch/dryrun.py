import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run (assignment MULTI-POD DRY-RUN): lower + compile every
# (architecture × input-shape) cell on the production meshes, print
# memory_analysis / cost_analysis, and record roofline terms.
#
# NOTE: the two lines above MUST run before any other import — jax locks the
# device count at first init.

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, cells_for, get_config  # noqa: E402
from repro.configs.base import RunConfig  # noqa: E402
from repro.launch import specs as SP  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.layers import island_plans  # noqa: E402
from repro.models.sharding import ShardingRules  # noqa: E402
from repro.optim.adamw import AdamW  # noqa: E402
from repro.roofline import hlo as HLO  # noqa: E402
from repro.roofline import model as RM  # noqa: E402
from repro.train.step import make_serve_step, make_train_step  # noqa: E402


def run_config_for(cfg, *, multi_pod: bool, pk_overlap: bool = True,
                   microbatches: int | None = None,
                   serving: bool = False) -> RunConfig:
    big = cfg.param_count() > 100e9
    # Serving: keep weights resident (TP-only) when they fit half of HBM on
    # the model axis — FSDP weight all-gathers per decoded token would
    # otherwise dominate T_comm (observed: 4.7 GB/token on a 20 B dense
    # model). The >=300 B archs must stay FSDP-sharded even when serving.
    fits_tp_only = cfg.param_count() * 2 <= 0.5 * 16e9 * 16
    # microbatches must divide the per-dp-rank batch: a per-microbatch global
    # batch smaller than the dp size forces XLA to replicate activations
    # (observed: 152 GB/device on jamba multi-pod before this cap).
    dp_size = 32 if multi_pod else 16
    mb_cap = max(1, 256 // dp_size)
    mb = microbatches if microbatches is not None else (16 if big else 8)
    return RunConfig(
        dp_axes=("pod", "data") if multi_pod else ("data",),
        fsdp=not (serving and fits_tp_only),
        pk_overlap=pk_overlap,
        microbatches=min(mb, mb_cap),
        optimizer_moment_dtype="bfloat16" if big else "float32",
    )


def _lower_one(cfg, cell, mesh, run, rules):
    """Build and lower the cell's step function. Returns lowered."""
    if cell.kind == "train":
        moment_dtype = (jnp.bfloat16 if run.optimizer_moment_dtype ==
                        "bfloat16" else jnp.float32)
        state, sspecs = SP.train_state_specs(cfg, run, rules, moment_dtype)
        batch, bspecs = SP.batch_specs(cfg, cell, rules)
        opt = AdamW(moment_dtype=moment_dtype)
        step = make_train_step(cfg, run, rules, opt)
        jitted = jax.jit(step,
                         in_shardings=(SP.named(mesh, sspecs),
                                       SP.named(mesh, bspecs)),
                         donate_argnums=(0,))
        lowered = jitted.lower(state, batch)
    elif cell.kind == "prefill":
        from repro.train.step import make_prefill_step
        tmpl_state, sspecs = SP.train_state_specs(cfg, run, rules)
        params, pspecs = tmpl_state.params, sspecs.params
        batch, bspecs = SP.batch_specs(cfg, cell, rules)
        step = make_prefill_step(cfg, run, rules)
        jitted = jax.jit(step, in_shardings=(SP.named(mesh, pspecs),
                                             SP.named(mesh, bspecs)))
        lowered = jitted.lower(params, batch)
    else:  # decode
        long_ctx = cell.name == "long_500k"
        (params, cache, tokens), (pspecs, cspecs, tspec) = SP.decode_specs(
            cfg, run, rules, cell)
        step = make_serve_step(cfg, run, rules, long_ctx=long_ctx)
        jitted = jax.jit(step,
                         in_shardings=(SP.named(mesh, pspecs),
                                       SP.named(mesh, cspecs),
                                       SP.named(mesh, {"t": tspec})["t"]),
                         donate_argnums=(1,))
        lowered = jitted.lower(params, cache, tokens)
    return lowered


def _cost_of(compiled):
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # older jax: one dict per device
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    coll = HLO.collective_bytes(hlo)
    del hlo
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)), coll)


def lower_cell(arch: str, cell_name: str, *, multi_pod: bool,
               pk_overlap: bool = True, microbatches: int | None = None,
               calibrate: bool = True, run_overrides: dict | None = None):
    """Lower + compile one (arch × cell × mesh). Returns result dict.

    Cost-term calibration: XLA's cost_analysis counts while-loop (scan)
    bodies ONCE, so a depth-L scanned model under-reports flops by ~L×. We
    lower two shallow variants (1 and 2 periods, microbatches=1, inner scans
    unchunked so no other loops exist) — every cost term is exactly linear in
    the period count, so:  cost(L) = cost(1p) + (cost(2p)-cost(1p))·(L-1).
    The full compile still proves memory/sharding and is what ships."""
    cfg = get_config(arch)
    cell = SHAPES[cell_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    run = run_config_for(cfg, multi_pod=multi_pod, pk_overlap=pk_overlap,
                         microbatches=microbatches,
                         serving=cell.kind == "decode")
    if run_overrides:
        run = dataclasses.replace(run, **run_overrides)
    rules = ShardingRules(mesh, run)
    n_chips = 512 if multi_pod else 256

    t0 = time.time()
    lowered = _lower_one(cfg, cell, mesh, run, rules)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    raw_flops, raw_bytes, raw_coll = _cost_of(compiled)

    if calibrate:
        pat = len(cfg.layer_pattern())
        cal_run = dataclasses.replace(
            run, microbatches=1, loss_chunk=cell.seq_len,
            ssm_chunk=cell.seq_len, scan_layers=False)
        points = []
        for k in (1, 2):
            cfg_k = dataclasses.replace(
                cfg, n_layers=k * pat,
                n_encoder_layers=(k * pat if cfg.encoder_decoder else 0))
            comp_k = _lower_one(cfg_k, cell, mesh, cal_run, rules).compile()
            points.append(_cost_of(comp_k))
            del comp_k
        (f1, b1, c1), (f2, b2, c2) = points
        L = cfg.n_periods
        flops = f1 + (f2 - f1) * (L - 1)
        bytes_acc = b1 + (b2 - b1) * (L - 1)
        coll_kinds = {}
        for kind in set(c1.by_kind) | set(c2.by_kind):
            v1, n1 = c1.by_kind.get(kind, (0.0, 0))
            v2, n2 = c2.by_kind.get(kind, (0.0, 0))
            coll_kinds[kind] = [max(v1 + (v2 - v1) * (L - 1), 0.0),
                                n1 + (n2 - n1) * (L - 1)]
        coll = HLO.CollectiveStats(
            by_kind=coll_kinds,
            total_bytes=sum(v for v, _ in coll_kinds.values()),
            op_count=int(sum(c for _, c in coll_kinds.values())))
    else:
        flops, bytes_acc, coll = raw_flops, raw_bytes, raw_coll

    mf = RM.model_flops(cfg, cell)
    roof = RM.build(arch, cell_name, mesh_name, flops=flops,
                    hbm_bytes=bytes_acc, coll=coll, model_flops_total=mf,
                    n_chips=n_chips, args_bytes=mem.argument_size_in_bytes)

    result = {
        "arch": arch, "cell": cell_name, "mesh": mesh_name,
        "parser_version": 2,
        "kind": cell.kind, "pk_overlap": pk_overlap,
        "microbatches": run.microbatches,
        "t_lower_s": round(t_lower, 1), "t_compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            # memory_analysis reports the per-device SPMD module directly
            "peak_per_device_gb": round(
                (mem.argument_size_in_bytes + mem.temp_size_in_bytes) / 1e9,
                3),
        },
        "cost": {"flops": flops, "bytes_accessed": bytes_acc,
                 "raw_flops_uncorrected": raw_flops,
                 "raw_bytes_uncorrected": raw_bytes},
        "collectives": {k: {"bytes": v, "ops": c}
                        for k, (v, c) in coll.by_kind.items()},
        "collective_bytes_total": coll.total_bytes,
        # trace-free overlap schedule every PK island picked for this cell
        # (each plan records backend / chunks / chunk_dim / hidden fraction
        # and whether those came from a calibration table or the analytic
        # model — the "source" field)
        "comm_policy": run.comm_policy,
        "comm_wire": run.comm_wire or "bf16",
        "islands": [p.asdict() for p in island_plans(
            cfg, run, rules, batch=cell.global_batch, seq=cell.seq_len)],
        "roofline": dataclasses.asdict(roof),
    }
    if cell.kind == "decode":
        # the CONTINUOUS-BATCHING schedule for this cell: the per-bucket
        # island table the serving engine would resolve at startup (prefill
        # buckets at full-sequence coordinates, the decode pool at one
        # token), diffable without running the engine
        from repro.configs.base import ServeConfig
        from repro.runtime.serving import serving_plan_record
        edges = tuple(sorted({max(cell.seq_len // 4, 1),
                              max(cell.seq_len // 2, 1), cell.seq_len}))
        serve = ServeConfig(max_batch=cell.global_batch,
                            prefill_batch=min(cell.global_batch, 32),
                            bucket_edges=edges,
                            max_new_tokens=min(cell.seq_len, 128))
        result["serving"] = serving_plan_record(cfg, run, rules, serve)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--cell", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-pk", action="store_true",
                    help="baseline without PK overlapped collectives")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--set", action="append", default=[],
                    help="RunConfig override key=json (e.g. "
                         "--set save_collectives=true)")
    args = ap.parse_args()
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = json.loads(v)

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        cells = cells_for(arch) if args.cell == "all" else [args.cell]
        for cell in cells:
            if cell not in cells_for(arch):
                print(f"SKIP {arch} × {cell} (DESIGN §6 inapplicable)")
                n_skip += 1
                continue
            for multi_pod in meshes:
                mesh_name = "2x16x16" if multi_pod else "16x16"
                suffix = ("_nopk" if args.no_pk else "") + args.tag
                fn = outdir / f"{arch}__{cell}__{mesh_name}{suffix}.json"
                if fn.exists() and not args.force:
                    print(f"CACHED {fn.name}")
                    n_ok += 1
                    continue
                print(f"=== {arch} × {cell} × {mesh_name} "
                      f"(pk={not args.no_pk}) ===", flush=True)
                try:
                    res = lower_cell(arch, cell, multi_pod=multi_pod,
                                     pk_overlap=not args.no_pk,
                                     microbatches=args.microbatches,
                                     run_overrides=overrides or None)
                    fn.write_text(json.dumps(res, indent=1))
                    m = res["memory"]
                    r = res["roofline"]
                    print(f"  lower {res['t_lower_s']}s compile "
                          f"{res['t_compile_s']}s | "
                          f"args {m['argument_bytes']/1e9:.1f}GB temp "
                          f"{m['temp_bytes']/1e9:.1f}GB | "
                          f"flops/dev {res['cost']['flops']:.2e} | "
                          f"coll {res['collective_bytes_total']/1e6:.0f}MB | "
                          f"bottleneck {r['bottleneck']} "
                          f"roofline {r['roofline_fraction']:.2f}",
                          flush=True)
                    n_ok += 1
                except Exception:
                    n_fail += 1
                    print(f"  FAILED {arch} × {cell} × {mesh_name}")
                    traceback.print_exc()
                finally:
                    jax.clear_caches()
    print(f"\ndry-run complete: {n_ok} ok, {n_fail} failed, {n_skip} skipped")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
