"""Production mesh construction (assignment MULTI-POD DRY-RUN §1).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state. Mesh construction goes through ``repro.compat.make_mesh``
so the ``axis_types`` kwarg is only passed on JAX versions that have it.
"""

from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, examples, elastic reshapes)."""
    return compat.make_mesh(shape, axes)


def device_fingerprint(mesh=None) -> dict:
    """Identity of the devices a calibration was (or would be) taken on.

    ``repro.core.autotune`` stamps every calibration table with this so a
    table measured on one machine is never silently applied to another
    (e.g. a CPU-emulated-mesh table on a real v5e slice). With ``mesh``
    the count reflects that mesh; otherwise the whole process.
    """
    import jax

    devices = list(mesh.devices.flat) if mesh is not None else jax.devices()
    kind = devices[0].device_kind if devices else "unknown"
    return {
        "backend": jax.default_backend(),
        "device_kind": kind,
        "n_devices": len(devices),
    }
