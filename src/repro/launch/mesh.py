"""Production mesh construction (assignment MULTI-POD DRY-RUN §1).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state. Mesh construction goes through ``repro.compat.make_mesh``
so the ``axis_types`` kwarg is only passed on JAX versions that have it.
"""

from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, examples, elastic reshapes)."""
    return compat.make_mesh(shape, axes)
