"""input_specs(): ShapeDtypeStruct stand-ins for every model input of every
(arch × shape-cell), plus the abstract train/serve state — weak-type-correct,
shardable, zero allocation (assignment MULTI-POD DRY-RUN §2).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, RunConfig, ShapeCell
from repro.models import transformer as T
from repro.models.sharding import ShardingRules
from repro.optim.adamw import AdamWState
from repro.train.step import TrainState


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ArchConfig, cell: ShapeCell, rules: ShardingRules):
    """(abstract_batch, batch_shardings) for a train/prefill cell."""
    b, s = cell.global_batch, cell.seq_len
    dp = rules.dp
    batch = {"tokens": _sds((b, s), jnp.int32)}
    specs = {"tokens": P(dp, None)}
    if cell.kind == "train":
        batch["targets"] = _sds((b, s), jnp.int32)
        batch["weights"] = _sds((b, s), jnp.float32)
        specs["targets"] = P(dp, None)
        specs["weights"] = P(dp, None)
    if cfg.frontend == "vision":
        batch["frontend_embeds"] = _sds((b, cfg.n_frontend_tokens, cfg.d_model),
                                        jnp.bfloat16)
        specs["frontend_embeds"] = P(dp, None, None)
    if cfg.encoder_decoder:
        batch["enc_embeds"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
        specs["enc_embeds"] = P(dp, None, None)
    return batch, specs


def train_state_specs(cfg: ArchConfig, run: RunConfig, rules: ShardingRules,
                      moment_dtype=jnp.float32):
    """(abstract TrainState, sharding tree). Moments inherit param specs."""
    tmpl = T.param_template(cfg, run, rules)
    params = T.abstract_params(tmpl)
    pspecs = T.param_specs(tmpl)
    moments = jax.tree.map(lambda p: _sds(p.shape, moment_dtype), params)
    state = TrainState(
        params=params,
        opt=AdamWState(step=_sds((), jnp.int32), m=moments, v=moments))
    specs = TrainState(
        params=pspecs,
        opt=AdamWState(step=P(), m=pspecs, v=pspecs))
    return state, specs


def decode_specs(cfg: ArchConfig, run: RunConfig, rules: ShardingRules,
                 cell: ShapeCell):
    """(abstract (params, cache, tokens), shardings) for a decode cell."""
    b, s = cell.global_batch, cell.seq_len
    long_ctx = cell.name == "long_500k"
    tmpl = T.param_template(cfg, run, rules)
    params = T.abstract_params(tmpl)
    pspecs = T.param_specs(tmpl)
    ct = T.cache_template(cfg, run, rules, batch=b, s_max=s,
                          enc_len=s if cfg.encoder_decoder else 0,
                          long_ctx=long_ctx)
    cache = T.abstract_params(ct)
    cspecs = T.param_specs(ct)
    dp = rules.dp
    tokens = _sds((b, 1), jnp.int32)
    tspec = P(rules.dim(b, dp), None)
    return (params, cache, tokens), (pspecs, cspecs, tspec)


def named(mesh: Mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree, is_leaf=lambda x: isinstance(x, P))
