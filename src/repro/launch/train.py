"""Training launcher: `python -m repro.launch.train --arch <id> [...]`.

Runs end-to-end on whatever devices exist (CPU for local runs, TPU pod when
launched per-host). `--reduced` selects the smoke-scale config; full configs
are intended for real pods (use dryrun.py to validate them without hardware).
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.core.template import render_plans
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import specs as SP
from repro.models.layers import island_plans
from repro.launch.mesh import make_mesh
from repro.models import transformer as T
from repro.models.sharding import ShardingRules
from repro.optim.adamw import AdamW, warmup_cosine
from repro.optim.compress import ErrorFeedbackInt8
from repro.runtime.driver import DriverConfig, TrainDriver
from repro.train.step import TrainState, make_train_step


def build_and_train(arch: str, *, steps: int, reduced: bool, mesh_shape,
                    mesh_axes, batch: int, seq: int, ckpt_dir: str,
                    lr: float = 3e-3, microbatches: int = 1,
                    pk_overlap: bool = True, compress_grads: bool = False,
                    fault_hook=None, seed: int = 0, log_every: int = 10,
                    ckpt_every: int = 50, comm_policy: str = "analytic",
                    comm_chunks: int | None = None, ulysses_chunks: int = 1,
                    comm_wire: str | None = None):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    mesh = make_mesh(mesh_shape, mesh_axes) if mesh_shape else None
    run = RunConfig(dp_axes=tuple(a for a in (mesh_axes or ()) if a != "model")
                    or ("data",),
                    pk_overlap=pk_overlap, microbatches=microbatches,
                    fsdp=mesh is not None, comm_policy=comm_policy,
                    comm_chunks=comm_chunks, ulysses_chunks=ulysses_chunks,
                    comm_wire=comm_wire)
    rules = ShardingRules(mesh, run) if mesh is not None else None
    if rules is not None:
        # the overlap schedule every PK island will pick, before tracing —
        # hidden fractions/chunks are measured when a calibration table
        # matches this machine (src=measured), predicted otherwise
        print(f"[plan] comm_policy={run.comm_policy}")
        print(render_plans(island_plans(cfg, run, rules, batch=batch,
                                        seq=seq)))

    tmpl = T.param_template(cfg, run, rules)
    params = T.init_params(tmpl, jax.random.PRNGKey(seed), cfg.d_model)
    if rules is not None:
        shardings = SP.named(mesh, T.param_specs(tmpl))
        params = jax.tree.map(jax.device_put, params, shardings)

    opt = AdamW(lr=warmup_cosine(lr, max(10, steps // 20), steps),
                weight_decay=0.01)
    state = TrainState(params=params, opt=opt.init(params))

    grad_transform = None
    if compress_grads:
        ef = ErrorFeedbackInt8()
        ef_state = {"s": ef.init(params)}

        def grad_transform(grads):  # noqa: F811
            g, ef_state["s"] = ef.transform(grads, ef_state["s"])
            return g

    step_fn = jax.jit(make_train_step(cfg, run, rules, opt,
                                      grad_transform=grad_transform),
                      donate_argnums=(0,))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                  global_batch=batch, seed=seed),
                       mesh=mesh, dp_axes=run.dp_axes)
    driver = TrainDriver(
        train_step=step_fn, state=state, data=data, ckpt_dir=ckpt_dir,
        cfg=DriverConfig(total_steps=steps, ckpt_every=ckpt_every,
                         log_every=log_every),
        fault_hook=fault_hook)
    return driver.run()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh-shape", type=int, nargs="*", default=None)
    ap.add_argument("--mesh-axes", type=str, nargs="*",
                    default=["data", "model"])
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--no-pk", action="store_true")
    ap.add_argument("--comm-policy", default="analytic",
                    choices=["analytic", "measured", "auto"],
                    help="cost source for comm backend dispatch "
                         "(measured needs a calibration table)")
    ap.add_argument("--comm-chunks", type=int, default=None,
                    help="force the ring GEMM-collective sub-chunk count "
                         "(default: scheduler/measured table)")
    ap.add_argument("--ulysses-chunks", type=int, default=1,
                    help="a2a chunk count for the Ulysses attention island")
    ap.add_argument("--comm-wire", default=None,
                    choices=["bf16", "int8", "int8_sr"],
                    help="GEMM-collective ring wire format: int8 ships "
                         "quantized sub-chunks + f32 scales (int8_sr adds "
                         "stochastic rounding); default full precision")
    args = ap.parse_args()
    build_and_train(args.arch, steps=args.steps, reduced=args.reduced,
                    mesh_shape=args.mesh_shape, mesh_axes=args.mesh_axes,
                    batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
                    lr=args.lr, microbatches=args.microbatches,
                    pk_overlap=not args.no_pk,
                    compress_grads=args.compress_grads,
                    comm_policy=args.comm_policy,
                    comm_chunks=args.comm_chunks,
                    ulysses_chunks=args.ulysses_chunks,
                    comm_wire=args.comm_wire)


if __name__ == "__main__":
    main()
