"""AdamW with ZeRO-style sharded states: moments inherit the (already fully
sharded FSDP×TP) parameter sharding, so optimizer memory scales 1/chips.
`moment_dtype=bfloat16` halves it again for the ≥300 B archs (DESIGN §3).
Global-norm clipping included.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0
    moment_dtype: Any = jnp.float32

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, self.moment_dtype)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.ones((), jnp.float32)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))

        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
            u = (m_new / c1) / (jnp.sqrt(v_new / c2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * u
            return (p_new.astype(p.dtype), m_new.astype(self.moment_dtype),
                    v_new.astype(self.moment_dtype))

        out = jax.tree.map(upd, params, grads, state.m, state.v)
        p_new = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        m_new = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        v_new = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return p_new, AdamWState(step, m_new, v_new), gnorm


def warmup_cosine(peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 *
                         (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(s < warmup, warm, cos)
    return lr
