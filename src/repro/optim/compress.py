"""Gradient compression for the DP all-reduce (distributed-optimization
substrate): int8 block-quantized gradients with error feedback.

The quantization implementation lives in ``core/quant.py`` — the same
per-block int8 + f32-scales format the ring collectives ship on the wire
(``wire="int8"``) — and this module re-exports it so the two paths cannot
drift. Two layers remain here:

  * `ErrorFeedbackInt8.transform(grads)` — quantize→dequantize with residual
    carry (applied before the optimizer; numerically models the compressed
    collective; used by make_train_step's grad_transform hook).
  * `compressed_psum(x, axis)` — the actual comm-level primitive for the
    manual-DP (shard_map) path: int8 payload + per-block f32 scales, summed
    in int32. Its payload bytes come from the shared ``WireFormat``
    descriptor (``COMPRESS_WIRE.bytes_per_element`` ≈ 1.016 B/elem vs 4 for
    f32 — paper §3.1 "T_comm = S/B": shrink S when B is the constraint).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.quant import (BLOCK, QMAX, SCALE_EPS, EFState,  # noqa: F401
                              ErrorFeedbackInt8, WIRE_FORMATS, WireFormat,
                              quant_dequant, wire_payload_bytes)

#: the wire format the manual-DP compressed all-reduce ships — identical to
#: the ring collectives' "int8" wire, so both paths price payloads off one
#: descriptor.
COMPRESS_WIRE: WireFormat = WIRE_FORMATS["int8"]


def _quant_dequant(g: jax.Array) -> jax.Array:
    """Thin re-export of ``core.quant.quant_dequant`` (historical name)."""
    return quant_dequant(g, block=BLOCK)


def compressed_payload_bytes(n_elems: float) -> float:
    """On-wire bytes ``compressed_psum`` ships for ``n_elems`` gradient
    elements (int8 payload + one f32 scale per block, via the shared
    ``WireFormat`` math)."""
    return wire_payload_bytes(n_elems, COMPRESS_WIRE)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8 all-reduce with per-block scales (call inside shard_map).

    Payload: int8 blocks + one f32 scale per 256 elements
    (``COMPRESS_WIRE.bytes_per_element`` ≈ 1.016 B/elem vs 4 for f32). The
    sum happens in int32 after rescaling to the axis-max scale, so the
    result is exact w.r.t. the quantized values."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % COMPRESS_WIRE.block
    fp = jnp.pad(flat, (0, pad)).reshape(-1, COMPRESS_WIRE.block)
    scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True) / QMAX
    scale = jnp.maximum(lax.pmax(scale, axis_name), SCALE_EPS)  # shared scale
    q = jnp.clip(jnp.round(fp / scale), -QMAX, QMAX).astype(jnp.int8)
    # int8 payload summed in int32 (n<=2^8 ranks cannot overflow int32)
    total = lax.psum(q.astype(jnp.int32), axis_name)
    out = (total.astype(jnp.float32) * scale).reshape(-1)[:flat.size]
    return out.reshape(x.shape).astype(x.dtype)
