"""Gradient compression for the DP all-reduce (distributed-optimization
substrate): int8 block-quantized gradients with error feedback.

Two layers:
  * `ErrorFeedbackInt8.transform(grads)` — quantize→dequantize with residual
    carry (applied before the optimizer; numerically models the compressed
    collective; used by make_train_step's grad_transform hook).
  * `compressed_psum(x, axis)` — the actual comm-level primitive for the
    manual-DP (shard_map) path: int8 payload + per-block f32 scales, summed
    in int32. Cuts DP gradient traffic 4× vs f32 / 2× vs bf16 (paper §3.1
    "T_comm = S/B": shrink S when B is the constraint).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

BLOCK = 256


class EFState(NamedTuple):
    residual: Any


def _quant_dequant(g: jax.Array) -> jax.Array:
    flat = g.reshape(-1)
    pad = (-flat.size) % BLOCK
    fp = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(fp / scale), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)[:flat.size]
    return deq.reshape(g.shape)


class ErrorFeedbackInt8:
    """g' = Q(g + r); r <- (g + r) - g'. The residual makes the compression
    unbiased over time (error-feedback SGD convergence argument)."""

    def init(self, params) -> EFState:
        return EFState(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def transform(self, grads, state: EFState):
        def one(g, r):
            corrected = g.astype(jnp.float32) + r
            deq = _quant_dequant(corrected)
            return deq, corrected - deq

        out = jax.tree.map(one, grads, state.residual)
        new_g = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_r = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_g, EFState(new_r)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8 all-reduce with per-block scales (call inside shard_map).

    Payload: int8 blocks + one f32 scale per 256 elements = 4.0625 B/elem ->
    1.02 B/elem vs 4 (f32). The sum happens in int32 after rescaling to the
    axis-max scale, so the result is exact w.r.t. the quantized values."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    fp = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(lax.pmax(scale, axis_name), 1e-12)  # shared scale
    q = jnp.clip(jnp.round(fp / scale), -127, 127).astype(jnp.int8)
    # int8 payload summed in int32 (n<=2^8 ranks cannot overflow int32)
    total = lax.psum(q.astype(jnp.int32), axis_name)
    out = (total.astype(jnp.float32) * scale).reshape(-1)[:flat.size]
    return out.reshape(x.shape).astype(x.dtype)
