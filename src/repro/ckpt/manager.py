"""Checkpointing: atomic (tmp+rename), async-save thread, keep-N GC, and
elastic restore (device_put onto a different mesh — arrays are stored in
logical layout, so resharding is just a placement change; MoE device-major
expert weights are converted through their logical layout, see
core/moe_layout.py).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Callable

import jax
import numpy as np


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    """Sharded-pytree checkpoints with crash-safe commits.

    save(): writes every leaf as .npy under <dir>/tmp_step_N/, fsyncs, then
    atomically renames to step_N — a torn write can never be mistaken for a
    complete checkpoint (the restart path simply uses the newest committed
    step). async=True moves host I/O off the training thread (the paper's
    overlap principle applied to the checkpoint path).
    """

    def __init__(self, directory: str | os.PathLike, *, keep: int = 3,
                 async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    # ---------------- save ----------------

    def save(self, step: int, state, extra: dict | None = None,
             *, block: bool = False):
        # snapshot to host BEFORE handing to the writer thread
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        self.wait()
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_state, extra or {}),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host_state, extra or {})

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state, extra: dict):
        tmp = self.dir / f"tmp_step_{step:08d}"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten(host_state)
        manifest = {"step": step, "extra": extra, "leaves": {}}
        for key, arr in flat.items():
            safe = key.replace("/", "__")
            true_dtype = str(arr.dtype)
            if true_dtype == "bfloat16":      # npy has no bf16: store bits
                arr = arr.view(np.uint16)
            np.save(tmp / f"{safe}.npy", arr)
            manifest["leaves"][key] = {"file": f"{safe}.npy",
                                       "shape": list(arr.shape),
                                       "dtype": true_dtype}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():                           # re-save of same step
            shutil.rmtree(final)
        os.replace(tmp, final)                       # atomic commit
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---------------- restore ----------------

    def all_steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*"))

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, *, step: int | None = None,
                shardings=None, convert: Callable | None = None):
        """Restore into the structure of `template` (a pytree of arrays or
        ShapeDtypeStructs). `shardings`: optional matching pytree of
        NamedSharding for elastic placement on a (possibly different) mesh.
        `convert(key, array)`: optional per-leaf layout converter."""
        step = self.latest_step() if step is None else step
        if step is None:
            return None, None
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat_tmpl = _flatten(template)
        out_flat = {}
        for key in flat_tmpl:
            meta = manifest["leaves"][key]
            arr = np.load(d / meta["file"])
            if meta["dtype"] == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            if convert is not None:
                arr = convert(key, arr)
            out_flat[key] = arr
        # rebuild in template order
        leaves_order = list(_flatten(template).keys())
        paths = jax.tree_util.tree_leaves_with_path(template)
        treedef = jax.tree.structure(template)
        rebuilt = jax.tree.unflatten(
            treedef, [out_flat[k] for k in leaves_order])
        if shardings is not None:
            rebuilt = jax.tree.map(
                lambda a, s: jax.device_put(a, s), rebuilt, shardings)
        return rebuilt, {"step": step, **manifest.get("extra", {})}
