"""CLI for the empirical autotuner (``repro.core.autotune``).

    python -m repro.autotune calibrate [--devices N] [--grid tiny|small|full]
                                       [--out PATH] [--notes TEXT] [--reps R]
    python -m repro.autotune show [PATH]
    python -m repro.autotune diff A [B]
    python -m repro.autotune check [PATH] [--max-age-days D] [--drift F]
                                   [--no-probe] [--reps R]

``calibrate`` micro-benchmarks every comm backend on the live mesh and saves
the fitted table (default: the user cache ``CommContext(policy="measured")``
searches, ``~/.cache/repro/autotune-<hw>-<jax>.json``). ``show`` prints a
table (the resolved dispatch table when no path is given). ``diff`` compares
two tables — or, with one argument, a table against the analytic constants —
so a re-calibration's drift is reviewable before it lands in the cache.
``check`` audits a table for staleness: its ``created`` age against a
threshold plus a quick spot re-probe of the machine-local corrections
(launch overhead, GEMM efficiency); exit 1 when the table looks stale.

``--devices`` forces the CPU-emulated mesh size and must be handled before
jax initializes, which is why this module only imports jax inside ``main``.
"""

from __future__ import annotations

import argparse
import os
import sys


def _pct(new: float, old: float) -> str:
    if old == 0:
        return "n/a"
    return f"{(new - old) / old * 100.0:+.1f}%"


def _fmt_corrections(corr: dict, base) -> list[str]:
    analytic = {
        "ici_bandwidth": base.ici_bandwidth,
        "remote_sync_s": base.remote_sync_s,
        "gemm_efficiency": base.gemm_efficiency,
        "kernel_launch_s": base.kernel_launch_s,
    }
    lines = [f"  {'field':<18} {'measured':>12} {'analytic':>12} {'drift':>9}"]
    for k, v in sorted(corr.items()):
        a = analytic.get(k)
        lines.append(f"  {k:<18} {v:>12.4g} "
                     f"{a if a is None else format(a, '>12.4g')} "
                     f"{_pct(v, a) if a is not None else '':>9}")
    return lines


def _show(table, base) -> None:
    fp = table.fingerprint
    print(f"schema v{table.version}  created {table.created or '?'}  "
          f"notes: {table.notes or '-'}")
    print(f"fingerprint: hw={fp.hw} jax={fp.jax_version} "
          f"backend={fp.backend} kind={fp.device_kind!r} "
          f"devices={fp.n_devices}")
    print("corrections:")
    print("\n".join(_fmt_corrections(table.corrections, base)))
    cov = table.ops_covered()
    print(f"measurements: {len(table.measurements)} rows over "
          f"{len(cov)} ops ({', '.join(f'{k}:{v}' for k, v in sorted(cov.items()))})")
    for row in table.measurements:
        extra = ""
        if row.get("n_chunks", 1) not in (None, 1):
            extra += f" chunks={row['n_chunks']}"
        if row.get("island"):
            extra += f" island={row['island']}"
        print(f"  {row['op']}/{row['backend']}"
              f"  axis={row['axis_size']} m={row['m']} n={row['n']} "
              f"k={row['k']}  {row['us']:.1f} us{extra}")


def _island_sweeps(args):
    """IslandSweep specs for ``calibrate --per-island``: build the model's
    island inventory on a (1, n_devices) mesh and keep every active
    GEMM-collective island's declared coordinates."""
    import jax

    from repro.configs import get_config
    from repro.configs.base import RunConfig
    from repro.launch.mesh import make_mesh
    from repro.models.layers import island_comm_sweeps
    from repro.models.sharding import ShardingRules

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_mesh((1, len(jax.devices())), ("data", "model"))
    # enable every GEMM island so each one gets measured rows; a run that
    # keeps attn_out dense simply never queries its key. --sp-attention
    # ulysses additionally sweeps the a2a re-sharding island; --phase
    # prefill|decode sweeps one serving bucket's inventory at its exact
    # coordinates (the serving engine's per-bucket dispatch rows).
    run = RunConfig(dp_axes=("data",), fsdp=False, pk_attn_out_island=True,
                    sp_attention=args.sp_attention)
    rules = ShardingRules(mesh, run)
    sweeps = island_comm_sweeps(cfg, run, rules, batch=args.batch,
                                seq=args.seq, phase=args.phase)
    if not sweeps:
        print("warning: --per-island found no active comm islands "
              f"for {cfg.name} on this mesh", file=sys.stderr)
    return sweeps


def int8_island_sweeps(islands):
    """Re-key GEMM island sweeps at the int8 wire width (the ``--dtype
    both``/``int8`` dtype axis): same declared (m, n, k), rows land under the
    island's ``…|b1`` key so per-island measured dispatch resolves when the
    run sets ``comm_wire="int8"`` — paired with the full-precision ``…|b2``
    rows the original sweeps emit. The b1 sweep itself excludes the fused
    backend (``island_sweep_cases``: fused kernels ship full precision)."""
    import dataclasses

    from repro.core import autotune

    return [
        dataclasses.replace(
            sw, island=sw.island.rsplit("|", 1)[0] + "|b1",
            dtype_bytes=1)
        for sw in islands
        if sw.op in autotune.GEMM_OPS and sw.dtype_bytes != 1]


def cmd_calibrate(args) -> int:
    from repro.core import autotune, costmodel

    hw = getattr(costmodel, args.hw.upper())
    dtypes = {"bf16": (2,), "int8": (1,), "both": (2, 1)}[args.dtype]
    islands = list(_island_sweeps(args)) if args.per_island else []
    if 1 in dtypes and islands:
        islands += int8_island_sweeps(islands)
    table = autotune.calibrate(grid=args.grid, reps=args.reps, hw=hw,
                               notes=args.notes, verbose=True,
                               islands=islands, dtypes=dtypes)
    out = args.out or autotune.cache_path(table.fingerprint)
    path = table.save(out)
    autotune.clear_caches()
    print(f"\nwrote {path}")
    print("CommContext(policy='measured') will now dispatch from it on "
          "this machine.")
    if islands:
        keys = sorted({r["island"] for r in table.measurements
                       if r.get("island")})
        print(f"island-keyed rows: {', '.join(keys) or 'none measured'}")
    return 0


def cmd_show(args) -> int:
    from repro.core import autotune, costmodel

    if args.path:
        table = autotune.CalibrationTable.load(args.path)
    else:
        table = autotune.find_table(costmodel.TPU_V5E.name)
        if table is None:
            print("no calibration table found (searched "
                  f"{autotune.cache_path(autotune.live_fingerprint(costmodel.TPU_V5E.name))} "
                  "and the in-repo seeds); run `python -m repro.autotune "
                  "calibrate`", file=sys.stderr)
            return 1
    _show(table, costmodel.TPU_V5E)
    return 0


def cmd_diff(args) -> int:
    from repro.core import autotune, costmodel

    a = autotune.CalibrationTable.load(args.a)
    base = getattr(costmodel, a.fingerprint.hw.upper(), costmodel.TPU_V5E)
    if args.b is None:
        # one-sided: measured vs the analytic spec it corrects
        print(f"{args.a} vs analytic {base.name}:")
        print("\n".join(_fmt_corrections(a.corrections, base)))
        return 0
    b = autotune.CalibrationTable.load(args.b)
    if not a.fingerprint.compatible(b.fingerprint):
        print(f"fingerprints are incompatible:\n  A: {a.fingerprint}\n"
              f"  B: {b.fingerprint}", file=sys.stderr)
        return 1
    print(f"{'field':<18} {'A':>12} {'B':>12} {'B vs A':>9}")
    for k in sorted(set(a.corrections) | set(b.corrections)):
        va, vb = a.corrections.get(k), b.corrections.get(k)
        if va is None or vb is None:
            print(f"{k:<18} {'—' if va is None else format(va, '.4g'):>12} "
                  f"{'—' if vb is None else format(vb, '.4g'):>12}")
            continue
        print(f"{k:<18} {va:>12.4g} {vb:>12.4g} {_pct(vb, va):>9}")
    shared = 0
    drifts = []
    for row in a.measurements:
        us_b = b.measured_us(row["op"], row["backend"], row["m"], row["n"],
                             row["k"], axis_size=row["axis_size"],
                             max_ratio=1.01)
        if us_b is None:
            continue
        shared += 1
        drifts.append(abs(us_b - row["us"]) / max(row["us"], 1e-9))
    if shared:
        print(f"measurements: {shared} shared grid points, median drift "
              f"{sorted(drifts)[len(drifts) // 2] * 100:.1f}%")
    return 0


def cmd_check(args) -> int:
    from repro.core import autotune, costmodel

    if args.path:
        table = autotune.CalibrationTable.load(args.path)
        where = args.path
    else:
        table = autotune.find_table(costmodel.TPU_V5E.name)
        if table is None:
            print("no calibration table found to check; run "
                  "`python -m repro.autotune calibrate`", file=sys.stderr)
            return 1
        where = "resolved table"
    age = autotune.table_age_days(table)
    print(f"checking {where}: created {table.created or '?'}"
          f"{f' ({age:.1f} days ago)' if age is not None else ''}")
    msgs = autotune.staleness(table, max_age_days=args.max_age_days,
                              drift_threshold=args.drift,
                              probe=not args.no_probe, reps=args.reps)
    if not msgs:
        print("table looks fresh (age and spot-probe drift within "
              "thresholds)" if not args.no_probe
              else "table age within threshold (spot probe skipped)")
        return 0
    for m in msgs:
        print(f"STALE: {m}", file=sys.stderr)
    print("re-run `python -m repro.autotune calibrate` to refresh",
          file=sys.stderr)
    return 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.autotune",
        description="measure, inspect and compare comm calibration tables")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("calibrate", help="micro-benchmark this machine")
    p.add_argument("--devices", type=int, default=None,
                   help="force an emulated CPU mesh of this many devices")
    p.add_argument("--grid", default="small", choices=_grid_names())
    p.add_argument("--reps", type=int, default=3)
    p.add_argument("--hw", default="tpu_v5e",
                   help="HardwareSpec constant to correct (tpu_v5e/h100_sxm)")
    p.add_argument("--out", default=None,
                   help="destination (default: the user cache path)")
    p.add_argument("--notes", default="")
    p.add_argument("--dtype", default="bf16",
                   choices=["bf16", "int8", "both"],
                   help="wire-width axis: bf16 sweeps full precision (b2 "
                        "rows); int8 sweeps the quantized ring wire (b1 "
                        "rows: ring backends run wire='int8', the bulk "
                        "baseline is timed unquantized under the same b1 "
                        "key); both runs the grid twice")
    p.add_argument("--per-island", action="store_true",
                   help="additionally sweep backend x chunk count at every "
                        "active GEMM-collective island's declared (m, n, k) "
                        "(rings x {1,2,4}; on TPU also the fused kernels x "
                        "{1,2,4,8}), tagging rows with the island key so "
                        "dispatch and Island.plan() become per-island "
                        "measured")
    p.add_argument("--arch", default="tinyllama-1.1b",
                   help="model whose islands --per-island sweeps")
    p.add_argument("--reduced", action="store_true",
                   help="use the smoke-scale config for --per-island")
    p.add_argument("--batch", type=int, default=8,
                   help="--per-island global batch")
    p.add_argument("--seq", type=int, default=128,
                   help="--per-island sequence length")
    p.add_argument("--sp-attention", default="ring",
                   choices=["ring", "ulysses", "none"],
                   help="--per-island: attention SP mode (ulysses adds the "
                        "a2a re-sharding island to the sweep)")
    p.add_argument("--phase", default="all",
                   choices=["all", "prefill", "decode"],
                   help="--per-island: sweep one serving bucket's island "
                        "inventory (prefill: full-seq shapes at --seq; "
                        "decode: one-token shapes)")
    p.set_defaults(fn=cmd_calibrate)

    p = sub.add_parser("show", help="print a table (default: the resolved one)")
    p.add_argument("path", nargs="?", default=None)
    p.set_defaults(fn=cmd_show)

    p = sub.add_parser("diff", help="compare two tables (or one vs analytic)")
    p.add_argument("a")
    p.add_argument("b", nargs="?", default=None)
    p.set_defaults(fn=cmd_diff)

    p = sub.add_parser("check",
                       help="audit a table for staleness (age + spot probe)")
    p.add_argument("path", nargs="?", default=None)
    p.add_argument("--max-age-days", type=float, default=30.0,
                   help="warn when 'created' is older than this (default 30)")
    p.add_argument("--drift", type=float, default=0.5,
                   help="relative drift vs the spot probe that counts as "
                        "stale (default 0.5)")
    p.add_argument("--no-probe", action="store_true",
                   help="age check only; skip the micro-benchmark probe")
    p.add_argument("--reps", type=int, default=3)
    p.set_defaults(fn=cmd_check)

    args = ap.parse_args(argv)
    if getattr(args, "devices", None):
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + f" --xla_force_host_platform_device_count"
                        f"={args.devices}").strip()
        if "jax" in sys.modules:
            import jax
            if len(jax.devices()) != args.devices:
                print(f"warning: jax already initialized with "
                      f"{len(jax.devices())} devices; --devices ignored",
                      file=sys.stderr)
    return args.fn(args)


def _grid_names():
    # repro.core.autotune never imports jax at module level, so pulling the
    # grid names here cannot defeat the --devices XLA_FLAGS handling below.
    from repro.core.autotune import GRIDS
    return sorted(GRIDS)


if __name__ == "__main__":
    raise SystemExit(main())
