"""JAX version compatibility layer.

The codebase targets the current JAX API (``jax.shard_map``,
``jax.sharding.AxisType``, ``pltpu.CompilerParams`` /
``pltpu.InterpretParams``); older releases (e.g. 0.4.x) spell these
differently or lack them entirely. Every use of a version-sensitive symbol
goes through this module so the rest of the tree stays on the modern
spelling.

Exports
-------
shard_map(f, *, mesh, in_specs, out_specs, check_vma)
    ``jax.shard_map`` when present, else ``jax.experimental.shard_map``
    with ``check_vma`` mapped onto the old ``check_rep`` flag.
make_mesh(shape, axes)
    ``jax.make_mesh`` with explicit ``AxisType.Auto`` axis types when the
    running JAX supports them, and without the kwarg when it does not.
CompilerParams / interpret_params() / ANY / hbm_scratch()
    Pallas-TPU naming shims.
HAS_TPU_INTERPRET
    True iff this JAX ships the TPU interpret mode (per-device semaphore +
    remote-DMA emulation on CPU). The Pallas communication kernels need it
    to run anywhere but a real TPU.
default_interpret()
    The interpret-mode default every kernel wrapper shares: interpret off
    on a real TPU, on elsewhere.
"""

from __future__ import annotations

import jax
from jax.experimental import pallas as pl  # noqa: F401  (re-export surface)
from jax.experimental.pallas import tpu as pltpu

# --- mesh construction ------------------------------------------------------

try:
    _AXIS_TYPE_AUTO = jax.sharding.AxisType.Auto
except AttributeError:          # jax < 0.5: meshes have no axis types
    _AXIS_TYPE_AUTO = None

HAS_AXIS_TYPES = _AXIS_TYPE_AUTO is not None


def make_mesh(shape, axes, *, devices=None):
    """``jax.make_mesh`` across JAX versions (axis_types feature-detected)."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if HAS_AXIS_TYPES:
        kwargs["axis_types"] = (_AXIS_TYPE_AUTO,) * len(tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)


# --- named-axis helpers -----------------------------------------------------

from jax import lax as _lax

if hasattr(_lax, "axis_size"):
    axis_size = _lax.axis_size
else:                           # jax < 0.6: psum of a literal folds statically
    def axis_size(axis_name):
        return _lax.psum(1, axis_name)


# --- shard_map --------------------------------------------------------------

if hasattr(jax, "shard_map"):
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
else:                           # jax < 0.6: experimental, check_rep spelling
    from jax.experimental.shard_map import shard_map as _shard_map_legacy

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return _shard_map_legacy(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma)


# --- Pallas TPU naming ------------------------------------------------------

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

_InterpretParams = getattr(pltpu, "InterpretParams", None) \
    or getattr(pltpu, "TPUInterpretParams", None)

#: True iff pltpu ships the TPU interpret mode (semaphore/remote-DMA
#: emulation). Without it the communication kernels only run on real TPUs.
HAS_TPU_INTERPRET = _InterpretParams is not None

if hasattr(pltpu, "MemorySpace"):
    ANY = pltpu.MemorySpace.ANY
    _HBM = getattr(pltpu.MemorySpace, "HBM", pltpu.MemorySpace.ANY)
else:                           # jax < 0.6: TPUMemorySpace enum
    ANY = pltpu.TPUMemorySpace.ANY
    _HBM = getattr(pltpu.TPUMemorySpace, "HBM", pltpu.TPUMemorySpace.ANY)


def interpret_params(**kwargs):
    """TPU InterpretParams, or a clear error on JAX builds without it."""
    if _InterpretParams is None:
        raise NotImplementedError(
            "This JAX build has no pltpu.InterpretParams — the Pallas "
            "communication kernels can only run on a real TPU backend.")
    return _InterpretParams(**kwargs)


def hbm_scratch(shape, dtype):
    """HBM-resident kernel scratch (landing buffers for ring kernels)."""
    return _HBM(tuple(shape), dtype)


def default_interpret() -> bool:
    """Kernels run compiled on TPU, interpreted everywhere else."""
    return jax.default_backend() != "tpu"


def tpu_kernels_supported() -> bool:
    """Can the Pallas communication kernels execute here at all?"""
    return jax.default_backend() == "tpu" or HAS_TPU_INTERPRET
