#!/usr/bin/env sh
# Tier-1 gate (ROADMAP.md): every PR runs exactly this.
set -eu
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -x -q "$@"
