#!/usr/bin/env sh
# Tier-1 gate (ROADMAP.md): every PR runs exactly the pytest line below.
set -eu
cd "$(dirname "$0")/.."

# Stage 0: lint (`make lint`, ruff config in pyproject.toml). Blocking when
# ruff is installed; `make lint` itself skips gracefully when it is not
# (the container has no network for installs).
make lint

# Stage 1 (blocking): the tier-1 pytest gate.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

# Stage 2 (non-blocking): the benchmark harness + regression check
# (`make bench`). A perf regression or harness breakage warns loudly but
# does not fail the gate — the blocking regression gate is `make bench`
# itself. Skip with REPRO_BENCH=0 (e.g. quick local iterations).
if [ "${REPRO_BENCH:-1}" != "0" ]; then
    if ! make bench; then
        echo "WARNING: benchmark stage failed or regressed (non-blocking;" \
             "run 'make bench' for details)" >&2
    fi
fi

# Stage 3 (non-blocking): the continuous-batching serving engine over a
# tiny synthetic trace (`make serve-smoke`) — catches engine/CLI breakage
# the unit suite might miss. Skip with REPRO_SERVE=0.
if [ "${REPRO_SERVE:-1}" != "0" ]; then
    if ! make serve-smoke; then
        echo "WARNING: serve-smoke stage failed (non-blocking; run" \
             "'make serve-smoke' for details)" >&2
    fi
fi

# Stage 4 (non-blocking): the multi-replica fleet smoke (`make
# fleet-smoke`: scripted drain/kill/rejoin over a 2-replica fleet) plus the
# slow randomized-trace fuzz (`pytest -m slow`; excluded from tier-1 by the
# pyproject addopts, and a no-op skip when hypothesis is absent). Skip with
# REPRO_FLEET=0.
if [ "${REPRO_FLEET:-1}" != "0" ]; then
    if ! make fleet-smoke; then
        echo "WARNING: fleet-smoke stage failed (non-blocking; run" \
             "'make fleet-smoke' for details)" >&2
    fi
    if ! make fuzz; then
        echo "WARNING: slow fuzz stage failed (non-blocking; run" \
             "'make fuzz' for details)" >&2
    fi
fi

# Stage 5 (non-blocking, opt-in): the Pallas kernel smoke (`make
# kernels-smoke`): the matmul/attention kernel suite plus the ring and
# chunk-pipelined fused collective kernels — redundant with stage 1 on
# this container (the interpret-gated tests skip), so it is opt-in for
# machines where the kernels actually execute. Enable with REPRO_KERNELS=1.
if [ "${REPRO_KERNELS:-0}" = "1" ]; then
    if ! make kernels-smoke; then
        echo "WARNING: kernels-smoke stage failed (non-blocking; run" \
             "'make kernels-smoke' for details)" >&2
    fi
fi

# Stage 6 (non-blocking): the runtime-health smoke (`make health-smoke`:
# scripted corrupt + stall comm faults with island guards and the health
# monitor on — exercises guard trips, quarantine, and backend demotion
# through the serve CLI). Skip with REPRO_HEALTH=0.
if [ "${REPRO_HEALTH:-1}" != "0" ]; then
    if ! make health-smoke; then
        echo "WARNING: health-smoke stage failed (non-blocking; run" \
             "'make health-smoke' for details)" >&2
    fi
fi
