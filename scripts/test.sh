#!/usr/bin/env sh
# Tier-1 gate (ROADMAP.md): every PR runs exactly this pytest line.
set -eu
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

# Second stage (non-blocking): the benchmark harness + regression check
# (`make bench`). A perf regression or harness breakage warns loudly but
# does not fail the gate — the blocking regression gate is `make bench`
# itself. Skip with REPRO_BENCH=0 (e.g. quick local iterations).
if [ "${REPRO_BENCH:-1}" != "0" ]; then
    if ! make bench; then
        echo "WARNING: benchmark stage failed or regressed (non-blocking;" \
             "run 'make bench' for details)" >&2
    fi
fi
