#!/usr/bin/env python
"""Validate a BENCH_comms.json artifact and gate perf regressions.

    python scripts/check_bench.py BENCH_comms.json \
        [--baseline benchmarks/BENCH_baseline.json] [--threshold 0.25]

Exit 1 when the artifact is malformed (schema ``repro-bench/v1``), any
figure failed, or any figure's median per-row slowdown vs the checked-in
baseline exceeds the threshold (default 25%, per-figure median so one noisy
row on the emulated mesh cannot fail the gate alone). Figures present in the
baseline but missing from the run count as regressions — silently dropping a
figure is how perf trajectories rot.

To accept an intentional change: re-run ``make bench`` and copy the fresh
artifact over ``benchmarks/BENCH_baseline.json``.

Importable pieces (used by tests): ``validate_schema(doc)``,
``compare(doc, baseline, threshold)``.
"""

from __future__ import annotations

import argparse
import json
import sys

SCHEMA = "repro-bench/v1"

_FIGURE_KEYS = {"figure": str, "status": str, "rows": list}
_ROW_KEYS = {"name": str, "us_per_call": (int, float)}


def validate_schema(doc) -> list[str]:
    """Schema errors in `doc` ([] when valid)."""
    errors: list[str] = []
    if not isinstance(doc, dict):
        return [f"top level must be an object, got {type(doc).__name__}"]
    if doc.get("schema") != SCHEMA:
        errors.append(f"schema must be {SCHEMA!r}, got {doc.get('schema')!r}")
    for key in ("created", "jax_version", "backend", "figures"):
        if key not in doc:
            errors.append(f"missing top-level key {key!r}")
    figures = doc.get("figures", [])
    if not isinstance(figures, list):
        return errors + ["'figures' must be a list"]
    seen = set()
    for i, fig in enumerate(figures):
        where = f"figures[{i}]"
        if not isinstance(fig, dict):
            errors.append(f"{where} must be an object")
            continue
        for key, typ in _FIGURE_KEYS.items():
            if not isinstance(fig.get(key), typ):
                errors.append(f"{where}.{key} must be {typ.__name__}")
        name = fig.get("figure")
        if name in seen:
            errors.append(f"{where}: duplicate figure {name!r}")
        seen.add(name)
        if fig.get("status") not in ("ok", "failed"):
            errors.append(f"{where}.status must be 'ok' or 'failed'")
        if fig.get("status") == "failed" and not fig.get("error"):
            errors.append(f"{where}: failed figure must carry 'error'")
        for j, r in enumerate(fig.get("rows") or []):
            if not isinstance(r, dict):
                errors.append(f"{where}.rows[{j}] must be an object")
                continue
            for key, typ in _ROW_KEYS.items():
                if not isinstance(r.get(key), typ):
                    errors.append(f"{where}.rows[{j}].{key} must be numeric"
                                  if key == "us_per_call"
                                  else f"{where}.rows[{j}].{key} missing")
            pe = r.get("pred_err")
            if pe is not None and not isinstance(pe, (int, float)):
                errors.append(f"{where}.rows[{j}].pred_err must be numeric "
                              "or null")
            isl = r.get("island")
            if isl is not None and not isinstance(isl, str):
                errors.append(f"{where}.rows[{j}].island must be a string "
                              "or null")
            tps = r.get("tokens_per_s")
            if tps is not None and not isinstance(tps, (int, float)):
                errors.append(f"{where}.rows[{j}].tokens_per_s must be "
                              "numeric or null")
            cl = r.get("cache_layout")
            if cl is not None and cl not in ("slab", "paged"):
                errors.append(f"{where}.rows[{j}].cache_layout must be "
                              "'slab', 'paged' or null")
            wr = r.get("wire")
            if wr is not None and not isinstance(wr, str):
                errors.append(f"{where}.rows[{j}].wire must be a string "
                              "or null")
            db = r.get("dtype_bytes")
            if db is not None and (isinstance(db, bool)
                                   or not isinstance(db, int)):
                errors.append(f"{where}.rows[{j}].dtype_bytes must be an "
                              "integer or null")
            md = r.get("mode")
            if md is not None and not isinstance(md, str):
                errors.append(f"{where}.rows[{j}].mode must be a string "
                              "or null")
            sc = r.get("sub_chunks")
            if sc is not None and (isinstance(sc, bool)
                                   or not isinstance(sc, int) or sc < 1):
                errors.append(f"{where}.rows[{j}].sub_chunks must be a "
                              "positive integer or null")
            cs = r.get("chunks_src")
            if cs is not None and cs not in ("explicit", "measured",
                                             "analytic"):
                errors.append(f"{where}.rows[{j}].chunks_src must be "
                              "'explicit', 'measured', 'analytic' or null")
    return errors


def _by_figure(doc) -> dict[str, dict]:
    return {f["figure"]: f for f in doc.get("figures", [])}


def compare(doc, baseline, threshold: float = 0.25) -> list[str]:
    """Regressions of `doc` vs `baseline` ([] when clean).

    Per figure: match rows by name, compute each row's us ratio (new/old),
    regress when the MEDIAN ratio exceeds 1 + threshold. Failed or missing
    figures that were ok in the baseline always regress.
    """
    problems: list[str] = []
    new = _by_figure(doc)
    for name, base_fig in _by_figure(baseline).items():
        if base_fig["status"] != "ok":
            continue
        fig = new.get(name)
        if fig is None:
            problems.append(f"{name}: present in baseline but not in run")
            continue
        if fig["status"] != "ok":
            problems.append(f"{name}: failed ({fig.get('error')})")
            continue
        base_rows = {r["name"]: r["us_per_call"] for r in base_fig["rows"]}
        ratios = sorted(
            r["us_per_call"] / base_rows[r["name"]]
            for r in fig["rows"]
            if r["name"] in base_rows and base_rows[r["name"]] > 0)
        if not ratios:
            continue
        med = ratios[len(ratios) // 2]
        if med > 1.0 + threshold:
            problems.append(
                f"{name}: median slowdown {med:.2f}x over "
                f"{len(ratios)} rows (threshold {1.0 + threshold:.2f}x)")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("artifact")
    ap.add_argument("--baseline", default="benchmarks/BENCH_baseline.json")
    ap.add_argument("--threshold", type=float, default=0.25)
    args = ap.parse_args(argv)

    try:
        doc = json.load(open(args.artifact))
    except (OSError, json.JSONDecodeError) as e:
        print(f"MALFORMED: cannot read {args.artifact}: {e}", file=sys.stderr)
        return 1
    errors = validate_schema(doc)
    if errors:
        print(f"MALFORMED: {args.artifact} fails {SCHEMA} validation:",
              file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        return 1

    failed = [f["figure"] for f in doc["figures"] if f["status"] != "ok"]
    if failed:
        print(f"FAILED figures: {', '.join(failed)}", file=sys.stderr)

    problems: list[str] = []
    try:
        baseline = json.load(open(args.baseline))
    except OSError:
        print(f"note: no baseline at {args.baseline}; schema check only",
              file=sys.stderr)
        baseline = None
    if baseline is not None:
        if errs := validate_schema(baseline):
            print(f"MALFORMED baseline {args.baseline}: {errs[0]}",
                  file=sys.stderr)
            return 1
        problems = compare(doc, baseline, args.threshold)
        for p in problems:
            print(f"REGRESSION: {p}", file=sys.stderr)

    n_fig = len(doc["figures"])
    errs = [f.get("pred_err_median") for f in doc["figures"]
            if f.get("pred_err_median") is not None]
    med = sorted(errs)[len(errs) // 2] if errs else None
    print(f"bench check: {n_fig - len(failed)}/{n_fig} figures ok, "
          f"{len(problems)} regression(s)"
          + (f", median |pred err| {med * 100:.0f}%" if med is not None
             else ""))
    return 1 if (failed or problems) else 0


if __name__ == "__main__":
    raise SystemExit(main())
