# Developer entry points. `make test` is the tier-1 gate from ROADMAP.md.
PY ?= python

.PHONY: test test-full lint bench bench-baseline calibrate quickstart deps \
        serve-smoke fleet-smoke health-smoke kernels-smoke fuzz

deps:
	$(PY) -m pip install -r requirements.txt

lint:               # ruff gate (config in pyproject.toml); skips when ruff
	@if $(PY) -m ruff --version >/dev/null 2>&1; then \
	    $(PY) -m ruff check src tests benchmarks scripts examples; \
	else \
	    echo "lint: ruff not installed — skipping (pip install ruff)"; \
	fi

test:
	./scripts/test.sh

test-full:          # no -x: full failure report
	PYTHONPATH=src $(PY) -m pytest -q

bench:              # harness (CSV + BENCH_comms.json) then schema/regression gate
	PYTHONPATH=src $(PY) -m benchmarks.run --json BENCH_comms.json
	PYTHONPATH=src $(PY) scripts/check_bench.py BENCH_comms.json \
	    --baseline benchmarks/BENCH_baseline.json

bench-baseline:     # accept the current numbers as the new checked-in baseline
	PYTHONPATH=src $(PY) -m benchmarks.run --json benchmarks/BENCH_baseline.json

calibrate:          # measure this machine into the autotune cache
	PYTHONPATH=src $(PY) -m repro.autotune calibrate

serve-smoke:        # continuous-batching engine over a tiny synthetic trace
	XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
	    $(PY) -m repro.launch.serve --arch tinyllama-1.1b --reduced \
	    --mode continuous --mesh-shape 1 8 --requests 6 --tokens 4 \
	    --max-batch 4 --prefill-batch 2 --bucket-edges 8 16 \
	    --comm-policy auto

fleet-smoke:        # 2-replica fleet with a scripted kill + rejoin
	PYTHONPATH=src $(PY) -m repro.launch.serve --arch tinyllama-1.1b \
	    --reduced --mode continuous --replicas 2 --router least-loaded \
	    --fault-plan "drain:1@1 kill:1@3 rejoin:1@5" \
	    --ckpt-dir /tmp/repro-fleet-ckpt --requests 8 --tokens 4 \
	    --max-batch 4 --prefill-batch 2 --bucket-edges 8 16

health-smoke:       # scripted comm faults: guards + monitor + quarantine
	XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
	    $(PY) -m repro.launch.serve --arch tinyllama-1.1b --reduced \
	    --mode continuous --mesh-shape 1 8 --comm-backend ring \
	    --island-guards --health-monitor \
	    --comm-fault-plan "corrupt:mlp@1 stall:mlp@3x4" \
	    --requests 8 --tokens 4 --max-batch 4 --prefill-batch 2 \
	    --bucket-edges 8

kernels-smoke:      # Pallas kernel suites incl. the chunk-pipelined fused
	            # collectives (interpret-mode tests skip cleanly on JAX
	            # builds without pltpu.InterpretParams; on TPU they run
	            # against the hardware)
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	    PYTHONPATH=src $(PY) -m pytest -q -rs tests/test_kernels.py \
	    tests/test_pk_comm.py tests/test_fused_chunks.py

fuzz:               # slow randomized/property tests (uses hypothesis if installed)
	PYTHONPATH=src $(PY) -m pytest -q -m slow tests/test_property.py

quickstart:
	PYTHONPATH=src $(PY) examples/quickstart.py
