# Developer entry points. `make test` is the tier-1 gate from ROADMAP.md.
PY ?= python

.PHONY: test test-full bench quickstart deps

deps:
	$(PY) -m pip install -r requirements.txt

test:
	./scripts/test.sh

test-full:          # no -x: full failure report
	PYTHONPATH=src $(PY) -m pytest -q

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

quickstart:
	PYTHONPATH=src $(PY) examples/quickstart.py
