# Developer entry points. `make test` is the tier-1 gate from ROADMAP.md.
PY ?= python

.PHONY: test test-full lint bench bench-baseline calibrate quickstart deps

deps:
	$(PY) -m pip install -r requirements.txt

lint:               # ruff gate (config in pyproject.toml); skips when ruff
	@if $(PY) -m ruff --version >/dev/null 2>&1; then \
	    $(PY) -m ruff check src tests benchmarks scripts examples; \
	else \
	    echo "lint: ruff not installed — skipping (pip install ruff)"; \
	fi

test:
	./scripts/test.sh

test-full:          # no -x: full failure report
	PYTHONPATH=src $(PY) -m pytest -q

bench:              # harness (CSV + BENCH_comms.json) then schema/regression gate
	PYTHONPATH=src $(PY) -m benchmarks.run --json BENCH_comms.json
	PYTHONPATH=src $(PY) scripts/check_bench.py BENCH_comms.json \
	    --baseline benchmarks/BENCH_baseline.json

bench-baseline:     # accept the current numbers as the new checked-in baseline
	PYTHONPATH=src $(PY) -m benchmarks.run --json benchmarks/BENCH_baseline.json

calibrate:          # measure this machine into the autotune cache
	PYTHONPATH=src $(PY) -m repro.autotune calibrate

quickstart:
	PYTHONPATH=src $(PY) examples/quickstart.py
