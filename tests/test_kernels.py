"""Per-kernel allclose vs the pure-jnp oracles (ref.py), sweeping shapes and
dtypes, in TPU interpret mode on CPU."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.grouped_matmul import grouped_matmul
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.matmul import matmul
from repro.kernels import ops


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                   (128, 256, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul(m, k, n, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), dtype)
    got = matmul(x, w, interpret=True)
    want = ref.matmul_ref(x, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 8)


def test_matmul_padded_wrapper():
    x = jax.random.normal(jax.random.PRNGKey(0), (100, 200), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (200, 72), jnp.float32)
    got = ops.matmul(x, w, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.matmul_ref(x, w)),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 128),
                                           (False, None)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(causal, window, dtype):
    b, h, s, d = 2, 3, 256, 128
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, s, d), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, h, s, d), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, h, s, d), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_gqa_and_padding():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 200, 48), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 200, 48), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 200, 48), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention_ref(q, jnp.repeat(k, 4, 1), jnp.repeat(v, 4, 1),
                                   causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("e,c,d,f", [(4, 128, 128, 256), (2, 256, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul(e, c, d, f, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (e, c, d), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (e, d, f), dtype)
    got = grouped_matmul(x, w, interpret=True)
    want = ref.grouped_matmul_ref(x, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 8)


@pytest.mark.parametrize("b,s,d,n,chunk", [(2, 256, 64, 8, 64),
                                           (1, 128, 32, 16, 128)])
def test_mamba_scan(b, s, d, n, chunk):
    key = jax.random.PRNGKey(0)
    dt = jax.nn.softplus(jax.random.normal(key, (b, s, d)))
    b_ssm = jax.random.normal(jax.random.PRNGKey(1), (b, s, n))
    c_ssm = jax.random.normal(jax.random.PRNGKey(2), (b, s, n))
    x = jax.random.normal(jax.random.PRNGKey(3), (b, s, d))
    a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(4), (d, n)))
    h0 = jax.random.normal(jax.random.PRNGKey(5), (b, d, n))
    got_y, got_h = mamba_scan(dt, b_ssm, c_ssm, x, a, h0, chunk=chunk,
                              interpret=True)
    want_y, want_h = ref.mamba_scan_ref(dt, b_ssm, c_ssm, x, a, h0)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h),
                               rtol=1e-4, atol=1e-4)
