"""Optimizer, gradient compression, schedules, data pipeline."""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from jax.sharding import PartitionSpec as P

from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim.adamw import AdamW, warmup_cosine
from repro.optim.compress import (ErrorFeedbackInt8, _quant_dequant,
                                  compressed_psum)


def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=None)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.tree.map(lambda w: 2 * w, params)
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_adamw_clipnorm_and_bf16_moments():
    opt = AdamW(lr=1e-2, clip_norm=1.0, moment_dtype=jnp.bfloat16)
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    assert state.m["w"].dtype == jnp.bfloat16
    params2, state, gnorm = opt.update({"w": jnp.full((4,), 100.0)},
                                       state, params)
    assert float(gnorm) == pytest.approx(200.0)
    assert np.all(np.isfinite(np.asarray(params2["w"])))


def test_warmup_cosine():
    lr = warmup_cosine(1.0, warmup=10, total=100)
    assert float(lr(jnp.array(0))) == 0.0
    assert float(lr(jnp.array(10))) == pytest.approx(1.0, abs=0.01)
    assert float(lr(jnp.array(100))) == pytest.approx(0.1, abs=0.02)


def test_quant_dequant_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3
    deq = _quant_dequant(x)
    scale = float(jnp.abs(x).max()) / 127.0
    assert float(jnp.abs(deq - x).max()) <= scale * 0.51 + 1e-6


def test_error_feedback_conservation():
    """compressed + residual == original (+ previous residual), exactly."""
    ef = ErrorFeedbackInt8()
    params = {"w": jnp.zeros((100,))}
    state = ef.init(params)
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (100,))}
    out, state2 = ef.transform(g, state)
    np.testing.assert_allclose(np.asarray(out["w"] + state2.residual["w"]),
                               np.asarray(g["w"]), rtol=1e-6, atol=1e-6)


def test_error_feedback_unbiased_over_time():
    ef = ErrorFeedbackInt8()
    params = {"w": jnp.zeros((64,))}
    state = ef.init(params)
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64,)) * 1e-3}
    acc = jnp.zeros((64,))
    for _ in range(50):
        out, state = ef.transform(g, state)
        acc = acc + out["w"]
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g["w"]),
                               rtol=0.05, atol=1e-5)


def test_compressed_psum(mesh4):
    sm = partial(compat.shard_map, mesh=mesh4, check_vma=False)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 256))
    want = np.asarray(x.sum(axis=0))
    f = jax.jit(sm(lambda x: compressed_psum(x[0], "x")[None],
                   in_specs=P("x"), out_specs=P("x")))
    got = np.asarray(f(x))
    for d in range(4):
        np.testing.assert_allclose(got[d] if got.ndim == 2 else got[d, 0],
                                   want, rtol=0.05, atol=0.05)


def test_data_determinism_and_structure():
    cfg = DataConfig(vocab_size=64, seq_len=32, global_batch=4, seed=7)
    d1, d2 = SyntheticLM(cfg), SyntheticLM(cfg)
    b1, b2 = d1.batch(13), d2.batch(13)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert b1["tokens"].shape == (4, 32)
    # targets are next-token shifted & learnable (mostly rule-following)
    t, y = np.asarray(b1["tokens"]), np.asarray(b1["targets"])
    diffs = (y - t) % cfg.vocab_size
    # per-sequence modal stride should dominate (noise is 2%)
    for i in range(4):
        vals, counts = np.unique(diffs[i], return_counts=True)
        assert counts.max() / diffs.shape[1] > 0.9


def test_train_loss_decreases():
    from repro.launch.train import build_and_train
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        _, log = build_and_train(
            "tinyllama-1.1b", steps=40, reduced=True, mesh_shape=None,
            mesh_axes=None, batch=4, seq=32, ckpt_dir=d, lr=5e-3,
            log_every=1, ckpt_every=100)
    first = np.mean([m["loss"] for m in log[:5]])
    last = np.mean([m["loss"] for m in log[-5:]])
    assert last < first - 0.3, (first, last)
