"""Continuous-batching serving engine (repro.runtime.serving):

* batched-vs-sequential token equivalence (bit-identical) on the 4- and
  8-device emulated meshes, mixed buckets and mixed admission times;
* deterministic bucket admission/eviction under a scripted request trace;
* per-bucket Island plans actually consumed: the decode bucket's jitted
  step runs a different (plan-driven) backend/chunk schedule than the
  prefill bucket's on a calibrated mesh;
* the plan-override plumbing itself (core.template.plan_overrides /
  RunConfig.island_overrides -> CommContext pins).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs import get_config
from repro.configs.base import RunConfig, ServeConfig
from repro.core.template import (Comm, Island, island_override,
                                 plan_overrides)
from repro.models.sharding import ShardingRules
from repro.runtime.serving import resolve_serving_plans, serving_plan_record


def _engine(mesh_shape, serve, arch="tinyllama-1.1b", **kw):
    from repro.launch.serve import build_engine
    return build_engine(arch, reduced=True, mesh_shape=mesh_shape,
                        serve=serve, **kw)


def _trace(serve, vocab, n, seed=0):
    from repro.launch.serve import synthetic_trace
    return synthetic_trace(n, serve, vocab, seed=seed)


SERVE = ServeConfig(max_batch=4, prefill_batch=2, bucket_edges=(8, 16),
                    max_new_tokens=4)


# ---------------------------------------------------------------------------
# Token equivalence: continuous batching == one-request-at-a-time
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mesh_shape", [(2, 2), (2, 4)])
def test_continuous_matches_sequential(mesh_shape):
    eng = _engine(mesh_shape, SERVE)
    trace = _trace(SERVE, eng.cfg.vocab_size, 5)
    done = eng.run(trace)
    assert len(done) == len(trace)
    for c in done:
        assert len(c.tokens) == SERVE.max_new_tokens
        # sequential: a fresh engine, one request, no batching effects
        solo = _engine(mesh_shape, SERVE)
        ref = solo.run([trace[c.rid]])[0]
        assert c.tokens == ref.tokens, (c.rid, c.tokens, ref.tokens)


def test_continuous_matches_static_batch():
    eng = _engine((2, 4), SERVE)
    trace = _trace(SERVE, eng.cfg.vocab_size, 4)
    done = {c.rid: c.tokens for c in eng.run(trace)}
    static = eng.generate_static(trace, SERVE.max_new_tokens)
    for rid, toks in enumerate(static):
        assert done[rid] == toks


def test_engine_no_mesh_dense_fallback():
    """rules=None routes every island to its dense reference; the engine
    must still batch correctly."""
    eng = _engine(None, SERVE)
    trace = _trace(SERVE, eng.cfg.vocab_size, 3)
    done = eng.run(trace)
    solo = _engine(None, SERVE)
    ref = solo.run([trace[1]])
    assert done[1].tokens == ref[0].tokens


# ---------------------------------------------------------------------------
# Admission / eviction determinism under a scripted trace
# ---------------------------------------------------------------------------

def _scripted_trace(vocab):
    # lengths chosen to exercise both buckets and slot reuse
    lens = [5, 12, 3, 8, 16, 2, 7]
    rng = np.random.RandomState(7)
    return [tuple(int(t) for t in rng.randint(0, vocab, size=n))
            for n in lens]


def test_admission_eviction_deterministic():
    runs = []
    for _ in range(2):
        eng = _engine((2, 2), SERVE)
        eng.run(_scripted_trace(eng.cfg.vocab_size))
        runs.append((eng.events, eng.step_kinds,
                     {r: c.tokens for r, c in eng.completions.items()}))
    assert runs[0] == runs[1]
    events = runs[0][0]
    admits = [e for e in events if e[0] == "admit"]
    retires = [e for e in events if e[0] == "retire"]
    assert len(admits) == len(retires) == 7
    # every admit names the right bucket for its prompt length
    lens = [5, 12, 3, 8, 16, 2, 7]
    for (_, _, rid, _, bucket, mem) in admits:
        assert bucket == SERVE.bucket_for(lens[rid])
        assert mem["resident_slots"] >= 1      # memory metrics ride along
    # fcfs: admission order == arrival order
    assert [a[2] for a in admits] == sorted(a[2] for a in admits)
    # slots are reused only after retirement
    live = set()
    for e in events:
        if e[0] == "admit":
            assert e[3] not in live
            live.add(e[3])
        else:
            live.discard(e[3])


def test_bucket_greedy_fills_groups():
    serve = dataclasses.replace(SERVE, queue_policy="bucket-greedy")
    eng = _engine((2, 2), serve)
    # arrival order alternates buckets; greedy groups same-bucket requests
    rng = np.random.RandomState(3)
    prompts = [tuple(int(t) for t in rng.randint(0, eng.cfg.vocab_size,
                                                 size=n))
               for n in (4, 12, 6, 14)]
    eng.run(prompts)
    admits = [e for e in eng.events if e[0] == "admit"]
    first_group = [a for a in admits if a[1] == 0]       # step 0 prefill
    assert {a[2] for a in first_group} == {0, 2}         # both bucket-8 reqs
    # and the engine still completes everything with correct tokens
    solo = _engine((2, 2), serve)
    ref = solo.run([prompts[1]])
    assert eng.completions[1].tokens == ref[0].tokens


def test_exact_buckets_required_for_ssm():
    with pytest.raises(ValueError, match="exact_buckets"):
        _engine(None, SERVE, arch="falcon-mamba-7b")
    serve = dataclasses.replace(SERVE, exact_buckets=True)
    eng = _engine(None, serve, arch="falcon-mamba-7b")
    rng = np.random.RandomState(0)
    prompts = [tuple(int(t) for t in rng.randint(0, eng.cfg.vocab_size,
                                                 size=n)) for n in (5, 5, 3)]
    done = eng.run(prompts)
    solo = _engine(None, serve, arch="falcon-mamba-7b")
    assert done[2].tokens == solo.run([prompts[2]])[0].tokens


# ---------------------------------------------------------------------------
# Per-bucket plans: resolved, recorded, and consumed
# ---------------------------------------------------------------------------

def test_serving_plan_record_shape(mesh22):
    cfg = get_config("tinyllama-1.1b").reduced()
    run = RunConfig(dp_axes=("data",), fsdp=False)
    rules = ShardingRules(mesh22, run)
    rec = serving_plan_record(cfg, run, rules, SERVE)
    assert set(rec["buckets"]) == {"prefill@8", "prefill@16", "decode"}
    assert rec["cache"]["layout"] == "slab"
    assert rec["cache"]["resident_capacity"] == {"8": 4, "16": 4}
    pre = rec["buckets"]["prefill@16"]
    dec = rec["buckets"]["decode"]
    assert pre["phase"] == "prefill" and pre["seq"] == 16
    assert dec["phase"] == "decode" and dec["seq"] == 1
    names_pre = {p["island"] for p in pre["islands"]}
    names_dec = {p["island"] for p in dec["islands"]}
    assert "decode_attn" in names_dec and "decode_attn" not in names_pre
    assert "mlp" in names_pre and "mlp" in names_dec
    # overrides are json-ready [name, backend, chunks] triples
    for name, be, chunks in pre["overrides"]:
        assert isinstance(name, str)


def test_per_bucket_plans_consumed_and_distinct(tmp_path):
    """The acceptance loop: a calibrated mesh where the prefill bucket's
    MLP measures ring-with-2-sub-chunks fastest while the decode bucket's
    tiny GEMM measures bulk fastest. The engine must (a) record those
    distinct plans per bucket and (b) thread them into each bucket's
    CommContext via island_overrides."""
    from repro.core import autotune

    mesh = compat.make_mesh((1, 4), ("data", "model"))
    cfg = get_config("tinyllama-1.1b").reduced()
    serve = ServeConfig(max_batch=8, prefill_batch=4, bucket_edges=(16,),
                        max_new_tokens=4)
    # mlp Comm coordinates on this mesh: prefill (m=4*16=64, n=64, k=32),
    # decode (m=8, n=64, k=32)
    live = autotune.live_fingerprint("tpu_v5e", mesh)
    key = autotune.island_key("mlp", "matmul_all_reduce", 2)

    def rows(m, us_by, n_chunks=1):
        return [{"op": "matmul_all_reduce", "backend": be, "axis_size": 4,
                 "m": m, "n": 64, "k": 32, "dtype_bytes": 2,
                 "n_chunks": n_chunks, "island": key, "us": us}
                for be, us in us_by.items()]

    table = autotune.CalibrationTable(
        fingerprint=live,
        corrections={"ici_bandwidth": 1e8, "remote_sync_s": 1e-6,
                     "gemm_efficiency": 1e-4, "kernel_launch_s": 1e-6},
        measurements=(rows(64, {"bulk": 100.0, "ring": 10.0})
                      + rows(64, {"ring": 4.0}, n_chunks=2)
                      + rows(8, {"bulk": 5.0, "ring": 400.0})))
    path = table.save(tmp_path / "serving-cal.json")
    autotune.clear_caches()
    try:
        eng = _engine((1, 4), serve,
                      comm_policy="measured",
                      run_overrides={"calibration_path": str(path)})
        pre = {p.island: p for p in eng.bucket_plans["prefill@16"].plans}
        dec = {p.island: p for p in eng.bucket_plans["decode"].plans}
        assert pre["mlp"].backend == "ring"
        assert pre["mlp"].source == "measured"
        assert pre["mlp"].n_chunks == 4 * 2          # ring steps x sub-chunks
        assert dec["mlp"].backend == "bulk"
        assert dec["mlp"].source == "measured"
        # distinct backend AND chunk settings across the two buckets
        assert (pre["mlp"].backend, pre["mlp"].n_chunks) != \
            (dec["mlp"].backend, dec["mlp"].n_chunks)
        # ...and the overrides reach the jitted steps' contexts
        assert ("mlp", "ring", 2) in eng.bucket_plans["prefill@16"].overrides
        assert ("mlp", "bulk", None) in eng.bucket_plans["decode"].overrides
        run_pre = eng._runs["prefill@16"]
        run_dec = eng._runs["decode"]
        assert island_override(run_pre, "mlp") == ("ring", 2, "plan")
        assert island_override(run_dec, "mlp") == ("bulk", None, "plan")
        from repro.models.layers import mlp_island
        rules = eng.rules
        ctx_pre = mlp_island(cfg, run_pre, rules, 4, 16).make_context()
        ctx_dec = mlp_island(cfg, run_dec, rules, 8, 1).make_context()
        assert ctx_pre.backend == "ring" and ctx_pre.chunks == 2
        assert ctx_dec.backend == "bulk"
        # and the engine still generates correctly under the overrides
        trace = _trace(serve, eng.cfg.vocab_size, 3)
        done = eng.run(trace)
        solo = _engine((1, 4), serve, comm_policy="measured",
                       run_overrides={"calibration_path": str(path)})
        assert done[0].tokens == solo.run([trace[0]])[0].tokens
    finally:
        autotune.clear_caches()


def test_seeded_calibration_reaches_serving_plans():
    """With the in-repo cpu_emulated seed (8-dev mesh, auto policy) at the
    seed's calibrated coordinates, the prefill bucket's MLP plan is
    MEASURED — the serving table consumes the same rows the launchers do."""
    mesh = compat.make_mesh((1, 8), ("data", "model"))
    cfg = get_config("tinyllama-1.1b").reduced()
    run = RunConfig(dp_axes=("data",), fsdp=False, comm_policy="auto")
    rules = ShardingRules(mesh, run)
    serve = ServeConfig(max_batch=8, prefill_batch=8, bucket_edges=(128,),
                        max_new_tokens=4)
    table = resolve_serving_plans(cfg, run, rules, serve)
    pre = {p.island: p for p in table["prefill@128"].plans}
    # (m, n, k) = (1024, 64, 16) — exactly the seed's mlp island rows
    assert pre["mlp"].source == "measured"
    assert not pre["mlp"].fallback


# ---------------------------------------------------------------------------
# plan_overrides / island_override unit behavior
# ---------------------------------------------------------------------------

def test_plan_overrides_normalization(mesh4):
    isl = Island("ring_isl", mesh=mesh4, axis="x", inputs={"x": P()},
                 out_specs=P(), body=lambda ctx, x: x,
                 comm=Comm("matmul_all_reduce", m=4096, n=4096, k=4096,
                           n_chunks=2))
    plan = isl.plan()
    ovs = plan_overrides([plan])
    assert len(ovs) == 1
    name, be, chunks = ovs[0]
    assert name == "ring_isl" and be == plan.backend
    if plan.backend in ("ring", "ring_bidir"):
        # plan.n_chunks is ring steps x sub-chunks; the override carries
        # sub-chunks (what CommContext.chunks means)
        assert chunks == plan.n_chunks // plan.axis_size == 2
    # fallback plans produce no override
    dense = Island("no_mesh", reference=lambda x: x)
    assert plan_overrides([dense.plan()]) == ()
    # later entries win
    run = RunConfig(island_overrides=(("a", "bulk", None),
                                      ("a", "ring", 4)))
    assert island_override(run, "a") == ("ring", 4, "plan")
    assert island_override(run, "b") is None
    # 4-tuple entries carry an explicit source tag (health demotions)
    run = RunConfig(island_overrides=(("a", "bulk", None, "health"),))
    assert island_override(run, "a") == ("bulk", None, "health")


def test_override_pins_context_and_plan_roundtrip(mesh4):
    run = RunConfig(island_overrides=(("pinned", "ring", 2),))
    isl = Island("pinned", mesh=mesh4, axis="x", run=run,
                 inputs={"x": P()}, out_specs=P(), body=lambda ctx, x: x,
                 comm=Comm("matmul_all_reduce", m=4096, n=4096, k=4096))
    ctx = isl.make_context()
    assert ctx.backend == "ring" and ctx.chunks == 2
    plan = isl.plan()
    assert plan.backend == "ring"
    assert plan.n_chunks == 4 * 2
    # explicit ctx_kwargs at the declaration site still beat the override
    expl = Island("pinned", mesh=mesh4, axis="x", run=run,
                  inputs={"x": P()}, out_specs=P(), body=lambda ctx, x: x,
                  comm=Comm("matmul_all_reduce", m=4096, n=4096, k=4096),
                  ctx_kwargs={"backend": "bulk"})
    assert expl.make_context().backend == "bulk"


# ---------------------------------------------------------------------------
# Vector-pos decode == scalar-pos decode (the pool's core invariant)
# ---------------------------------------------------------------------------

def test_vector_pos_decode_matches_scalar(mesh22):
    from repro.models import transformer as T
    cfg = get_config("tinyllama-1.1b").reduced()
    run = RunConfig(dp_axes=("data",), fsdp=False)
    rules = ShardingRules(mesh22, run)
    params = T.init_params(T.param_template(cfg, run, rules),
                           jax.random.PRNGKey(0), cfg.d_model)
    toks = jax.random.randint(jax.random.PRNGKey(2), (4, 8), 0,
                              cfg.vocab_size)
    lens = jnp.full((4,), 8, jnp.int32)

    def gen(slot_pos):
        ct = T.cache_template(cfg, run, rules, batch=4, s_max=16,
                              slot_pos=slot_pos)
        cache = T.init_params(ct, jax.random.PRNGKey(1), cfg.d_model)
        logits, cache = jax.jit(
            lambda p, c, t, ln: T.prefill_step(p, c, t, ln, cfg, run,
                                               rules))(
            params, cache, toks, lens if slot_pos else 8)
        outs = [logits]
        tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None] \
            .astype(jnp.int32)
        for _ in range(3):
            logits, cache = jax.jit(
                lambda p, c, t: T.decode_step(p, c, t, cfg, run, rules))(
                params, cache, tok)
            outs.append(logits)
            tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None] \
                .astype(jnp.int32)
        return outs

    for a, b in zip(gen(True), gen(False)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
