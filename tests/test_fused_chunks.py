"""Chunk-pipelined fused kernels (the ChunkSchedule -> pk_comm /
collective_matmul seam):

* dispatch precedence for the fused backend — explicit ``n_chunks=`` >
  ``RunConfig.comm_chunks`` > measured fused×chunks rows > the analytic
  ``fused_pipeline_cost`` argmin;
* the fused cost term itself (one launch, local-sync chunk handoffs, a
  finer argmin than the ring's);
* ``calibrate --per-island`` case generation (fused×{1,2,4,8} on TPU only,
  never at the int8 wire width) and the CLI's b1-replica helper;
* ``Island.plan()`` / ``plan_overrides`` / ``serving_plan_record`` carrying
  frozen fused chunk schedules exactly like ring ones;
* on interpret-capable JAX builds: chunked fused kernels bit-identical to
  their 1-chunk selves and allclose to the jnp oracles, for divisible and
  non-divisible (``fit_chunks`` fallback) requested counts.
"""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import autotune, costmodel as cm
from repro.core.comms import CommContext
from repro.core.schedule import choose_gemm_chunks, fit_chunks

N = 4

requires_interpret = pytest.mark.skipif(
    not compat.tpu_kernels_supported(),
    reason="no TPU backend and no pltpu.InterpretParams in this JAX")


def _synthetic(fingerprint, rows, **corr):
    corrections = {"ici_bandwidth": 1e8, "remote_sync_s": 1e-4,
                   "gemm_efficiency": 1e-4, "kernel_launch_s": 1e-5}
    corrections.update(corr)
    return autotune.CalibrationTable(fingerprint=fingerprint,
                                     corrections=corrections,
                                     measurements=rows)


def _fused_rows(op, us_by_chunks, m, n, k, island=None, axis_size=N):
    rows = [{"op": op, "backend": "fused", "axis_size": axis_size,
             "m": m, "n": n, "k": k, "dtype_bytes": 2, "n_chunks": c,
             "us": us} for c, us in us_by_chunks.items()]
    if island is not None:
        for r in rows:
            r["island"] = island
    return rows


@pytest.fixture(autouse=True)
def _fresh_caches():
    autotune.clear_caches()
    yield
    autotune.clear_caches()


# ---------------------------------------------------------------------------
# The fused cost term
# ---------------------------------------------------------------------------

def test_fused_cost_single_launch_and_local_sync():
    """The fused pipeline pays ONE kernel launch regardless of chunk count
    (ring pipelines pay per step) and its sync grows with the cheap
    local-sync constant, so finer chunking stays affordable."""
    hw = cm.TPU_V5E
    one = cm.fused_pipeline_cost(4096, 1024, 512, axis_size=N, sub_chunks=1)
    fine = cm.fused_pipeline_cost(4096, 1024, 512, axis_size=N, sub_chunks=8)
    assert one.t_launch == fine.t_launch == hw.kernel_launch_s
    ring = cm.chunk_pipeline_cost(4096, 1024, 512, axis_size=N, sub_chunks=8)
    # VMEM-resident operands: chunking never re-reads HBM (the jax-level
    # ring's t_mem grows with the count) and chunk handoffs cost local
    # syncs, not remote ones — the two terms that move the fused argmin
    assert fine.t_mem == one.t_mem < ring.t_mem
    assert fine.t_sync < ring.t_sync
    # chunk handoffs are local semaphore waits, not ring rendezvous: the
    # sync delta from 1 -> 8 sub-chunks is hops * 7 local syncs exactly
    hops = N - 1
    assert fine.t_sync - one.t_sync == pytest.approx(
        hops * 7 * hw.local_sync_s)


def test_fused_analytic_argmin_at_least_as_fine_as_ring():
    """With launch overhead amortized and syncs local, the fused argmin sits
    at the same or a finer chunk count than the ring pipeline's."""
    for kind in ("all_gather", "reduce_scatter", "all_reduce"):
        ring = choose_gemm_chunks(4096, 1024, 512, axis_size=N, kind=kind)
        fused = choose_gemm_chunks(4096, 1024, 512, axis_size=N, kind=kind,
                                   fused=True)
        assert fused.n_chunks >= ring.n_chunks, kind
        assert "fused_pipeline_cost" in fused.reason


def test_fit_chunks_degrades_never_rejects():
    assert fit_chunks(16, 4) == 4
    assert fit_chunks(16, 3) == 2       # largest divisor <= request
    assert fit_chunks(7, 4) == 1
    assert fit_chunks(0, 4) == 1


# ---------------------------------------------------------------------------
# Dispatch precedence (explicit > context > measured > analytic)
# ---------------------------------------------------------------------------

def test_fused_chunk_precedence(mesh4):
    live = autotune.live_fingerprint("tpu_v5e", mesh4)
    t = _synthetic(live, _fused_rows("matmul_all_reduce",
                                     {1: 100.0, 2: 60.0, 4: 20.0, 8: 50.0},
                                     256, 64, 16))
    mk = dict(axis_name="x", mesh=mesh4, policy="measured", calibration=t)
    ctx = CommContext(**mk)
    # measured tier: argmin over the fused×chunks rows
    sched = ctx.gemm_chunk_schedule("matmul_all_reduce", 256, 64, 16,
                                    backend="fused")
    assert (sched.n_chunks, sched.source) == (4, "measured")
    assert sched.chunk_dim == "m"       # fused payload chunks are row cuts
    # explicit per-call count beats the table
    sched = ctx.gemm_chunk_schedule("matmul_all_reduce", 256, 64, 16,
                                    backend="fused", n_chunks=3)
    assert (sched.n_chunks, sched.source) == (3, "explicit")
    # context-wide default (RunConfig.comm_chunks) beats the table too
    sched = CommContext(chunks=2, **mk).gemm_chunk_schedule(
        "matmul_all_reduce", 256, 64, 16, backend="fused")
    assert (sched.n_chunks, sched.source) == (2, "explicit")
    # no table -> the analytic fused argmin
    sched = CommContext(axis_name="x", mesh=mesh4).gemm_chunk_schedule(
        "matmul_all_reduce", 4096, 1024, 512, backend="fused")
    assert sched.source == "analytic" and sched.n_chunks >= 1
    # bulk takes no sub-chunks whatever the table says
    sched = ctx.gemm_chunk_schedule("matmul_all_reduce", 256, 64, 16,
                                    backend="bulk")
    assert sched.n_chunks == 1


def test_fused_measured_rows_island_first(mesh4):
    """An island's fused×chunks rows beat the global grid's at the same
    coordinates — same tiering as backend dispatch."""
    live = autotune.live_fingerprint("tpu_v5e", mesh4)
    key = autotune.island_key("mlp", "matmul_all_reduce", 2)
    rows = (_fused_rows("matmul_all_reduce", {1: 100.0, 2: 10.0},
                        256, 64, 16)
            + _fused_rows("matmul_all_reduce", {1: 100.0, 8: 10.0},
                          256, 64, 16, island=key))
    t = _synthetic(live, rows)
    mk = dict(axis_name="x", mesh=mesh4, policy="measured", calibration=t)
    glob = CommContext(**mk).gemm_chunk_schedule(
        "matmul_all_reduce", 256, 64, 16, backend="fused")
    isl = CommContext(island=key, **mk).gemm_chunk_schedule(
        "matmul_all_reduce", 256, 64, 16, backend="fused")
    assert (glob.n_chunks, isl.n_chunks) == (2, 8)
    assert glob.source == isl.source == "measured"


# ---------------------------------------------------------------------------
# Calibration sweep cases (fused×chunks, TPU-gated, full-precision only)
# ---------------------------------------------------------------------------

def _sweep(dtype_bytes=2, op="matmul_all_reduce"):
    return autotune.IslandSweep(
        island=autotune.island_key("mlp", op, dtype_bytes), op=op,
        m=8 * N, n=16, k=8, dtype_bytes=dtype_bytes)


def test_island_sweep_cases_off_tpu_excludes_fused():
    cases = autotune.island_sweep_cases(_sweep(), N,
                                        ("bulk", "ring", "fused"))
    assert ("bulk", 1) in cases
    assert {c for be, c in cases if be == "ring"} \
        == set(autotune.ISLAND_CHUNK_SWEEP)
    assert not any(be == "fused" for be, _ in cases)


def test_island_sweep_cases_on_tpu_sweeps_fused_chunks(monkeypatch):
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    cases = autotune.island_sweep_cases(_sweep(), N,
                                        ("bulk", "ring", "fused"))
    assert {c for be, c in cases if be == "fused"} \
        == set(autotune.ISLAND_FUSED_CHUNK_SWEEP)
    # ... but never at the int8 wire width (fused ships full precision)
    b1 = autotune.island_sweep_cases(_sweep(dtype_bytes=1), N,
                                     ("bulk", "ring", "fused"))
    assert not any(be == "fused" for be, _ in b1)


def test_cli_int8_island_sweeps_replicates_gemm_islands():
    """The CLI's --per-island dtype axis: every full-precision GEMM island
    gets a ``|b1`` twin swept at the int8 wire width; non-GEMM islands and
    already-b1 islands don't."""
    from repro.autotune import int8_island_sweeps
    gemm = _sweep()
    psum = autotune.IslandSweep(island=autotune.island_key("q", "psum", 2),
                                op="psum", m=N, n=16, k=1)
    b1 = _sweep(dtype_bytes=1)
    extra = int8_island_sweeps([gemm, psum, b1])
    assert len(extra) == 1
    tw = extra[0]
    assert tw.dtype_bytes == 1
    assert tw.island == autotune.island_key("mlp", "matmul_all_reduce", 1)
    assert (tw.op, tw.m, tw.n, tw.k) == (gemm.op, gemm.m, gemm.n, gemm.k)


# ---------------------------------------------------------------------------
# plan() / plan_overrides / serving_plan_record carry fused schedules
# ---------------------------------------------------------------------------

def test_plan_reports_measured_fused_chunks(mesh4, tmp_path):
    """A pinned-fused island on a calibrated mesh reports src=measured with
    the chunk count straight from the fused×chunks table rows, and
    plan_overrides freezes sub-chunks-per-step for the bucket contexts."""
    from repro.core.template import Comm, Island, plan_overrides
    live = autotune.live_fingerprint("tpu_v5e", mesh4)
    key = autotune.island_key("mlp", "matmul_all_reduce", 2)
    rows = ([{"op": "matmul_all_reduce", "backend": "bulk", "axis_size": N,
              "m": 64, "n": 64, "k": 32, "dtype_bytes": 2, "n_chunks": 1,
              "island": key, "us": 100.0}]
            + _fused_rows("matmul_all_reduce", {2: 10.0, 4: 4.0},
                          64, 64, 32, island=key))
    path = _synthetic(live, rows).save(tmp_path / "cal.json")
    isl = Island("mlp", mesh=mesh4, axis="x",
                 comm=Comm(op="matmul_all_reduce", m=64, n=64, k=32,
                           backend="fused"),
                 ctx_kwargs={"policy": "measured", "calibration": str(path)})
    p = isl.plan()
    assert p.backend == "fused"
    assert p.source == "measured"
    assert p.n_chunks == N * 4          # ring steps × measured sub-chunks
    assert p.wire is None               # fused ships full precision
    assert 0.0 <= p.hidden_fraction <= 1.0
    assert ("mlp", "fused", 4) in plan_overrides([p])


def test_plan_overrides_normalizes_fused_like_ring():
    from repro.core.template import IslandPlan, plan_overrides
    mk = dict(axis="x", axis_size=N, fallback=False, reason="",
              op="matmul_reduce_scatter")
    ov = plan_overrides([
        IslandPlan(island="a", backend="fused", n_chunks=8, **mk),
        IslandPlan(island="b", backend="ring", n_chunks=8, **mk),
        IslandPlan(island="c", backend="bulk", n_chunks=1, **mk)])
    assert ("a", "fused", 2) in ov and ("b", "ring", 2) in ov
    assert ("c", "bulk", None) in ov


def test_serving_plan_record_carries_fused_schedules(mesh22):
    """A comm_backend=fused A/B run: every GEMM island in the per-bucket
    record reports the fused backend with its resolved chunk schedule, and
    the frozen overrides carry the sub-chunk counts."""
    from repro.configs import get_config
    from repro.configs.base import RunConfig, ServeConfig
    from repro.models.sharding import ShardingRules
    from repro.runtime.serving import serving_plan_record
    cfg = get_config("tinyllama-1.1b").reduced()
    run = RunConfig(dp_axes=("data",), fsdp=False, comm_backend="fused")
    rules = ShardingRules(mesh22, run)
    serve = ServeConfig(max_batch=4, prefill_batch=2, bucket_edges=(16,),
                        max_new_tokens=4)
    rec = serving_plan_record(cfg, run, rules, serve)
    pre = {p["island"]: p for p in rec["buckets"]["prefill@16"]["islands"]}
    mlp = pre["mlp"]
    assert mlp["backend"] == "fused"
    assert mlp["wire"] is None
    n_dev = mlp["axis_size"]
    assert mlp["n_chunks"] % n_dev == 0 and mlp["n_chunks"] >= n_dev
    ov = {tuple(o[:2]): o[2]
          for o in rec["buckets"]["prefill@16"]["overrides"]}
    assert ov[("mlp", "fused")] == mlp["n_chunks"] // n_dev


# ---------------------------------------------------------------------------
# Interpret-mode equivalence: chunked == 1-chunk (bit-identical) == oracle
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sm(mesh4):
    return partial(compat.shard_map, mesh=mesh4, check_vma=False)


@requires_interpret
@pytest.mark.parametrize("n_chunks", [1, 2, 4, 3])
def test_ag_matmul_chunked(sm, n_chunks):
    from repro.kernels import ref
    from repro.kernels.collective_matmul import ag_matmul_fused
    m_loc, k, n_out = 16, 32, 24
    x = jax.random.normal(jax.random.PRNGKey(0), (N * m_loc, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n_out), jnp.float32)

    def run(c):
        f = jax.jit(sm(
            lambda x, w: ag_matmul_fused(x, w, "x", n_chunks=c)
            .reshape(N * m_loc, n_out)[None],
            in_specs=(P("x"), P()), out_specs=P("x")))
        return np.asarray(f(x, w))

    got = run(n_chunks)
    want = np.asarray(ref.ag_matmul_ref(x, w))
    for d in range(N):
        np.testing.assert_allclose(got[d], want, rtol=1e-4, atol=1e-4)
    # same dots over the same sub-slices in the same order: bit-identical
    assert np.array_equal(got, run(1))


@requires_interpret
@pytest.mark.parametrize("n_chunks", [1, 2, 4, 3])
def test_matmul_rs_chunked(sm, n_chunks):
    from repro.kernels import ref
    from repro.kernels.collective_matmul import matmul_rs_fused
    m, k_loc, n_out = 16, 8, 24
    x = jax.random.normal(jax.random.PRNGKey(0), (m, N * k_loc), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (N * k_loc, n_out),
                          jnp.float32)

    def run(c):
        f = jax.jit(sm(lambda x, w: matmul_rs_fused(x, w, "x", n_chunks=c),
                       in_specs=(P(None, "x"), P("x", None)),
                       out_specs=P("x", None)))
        return np.asarray(f(x, w))

    got = run(n_chunks)
    np.testing.assert_allclose(got, np.asarray(ref.matmul_rs_ref(x, w)),
                               rtol=1e-4, atol=1e-4)
    assert np.array_equal(got, run(1))


@requires_interpret
@pytest.mark.parametrize("n_chunks", [1, 2, 4, 3])
def test_matmul_ar_chunked(sm, n_chunks):
    from repro.kernels import ref
    from repro.kernels.collective_matmul import matmul_ar_fused
    m, k_loc, n_out = 16, 8, 24
    x = jax.random.normal(jax.random.PRNGKey(0), (m, N * k_loc), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (N * k_loc, n_out),
                          jnp.float32)

    def run(c):
        f = jax.jit(sm(
            lambda x, w: matmul_ar_fused(x, w, "x", n_chunks=c)
            .reshape(m, n_out)[None],
            in_specs=(P(None, "x"), P("x", None)), out_specs=P("x")))
        return np.asarray(f(x, w))

    got = run(n_chunks)
    want = np.asarray(ref.matmul_ar_ref(x, w))
    for d in range(N):
        np.testing.assert_allclose(got[d], want, rtol=1e-4, atol=1e-4)
    assert np.array_equal(got, run(1))


@requires_interpret
@pytest.mark.parametrize("n_chunks", [2, 4, 3])
def test_ring_collectives_chunked(sm, n_chunks):
    from repro.kernels import ref
    from repro.kernels.pk_comm import ring_all_gather, ring_reduce_scatter
    x = jax.random.normal(jax.random.PRNGKey(0), (N, 8, 16), jnp.float32)
    f = jax.jit(sm(
        lambda x: ring_all_gather(x[0], "x", n_chunks=n_chunks)[None],
        in_specs=P("x"), out_specs=P("x")))
    got = np.asarray(f(x))
    for d in range(N):
        np.testing.assert_allclose(got[d], np.asarray(x))
    xg = jax.random.normal(jax.random.PRNGKey(1), (N, N, 8, 16), jnp.float32)
    g = jax.jit(sm(
        lambda x: ring_reduce_scatter(x[0], "x", n_chunks=n_chunks)[None],
        in_specs=P("x"), out_specs=P("x")))
    np.testing.assert_allclose(np.asarray(g(xg)),
                               np.asarray(ref.reduce_scatter_ref(xg)),
                               rtol=1e-5, atol=1e-5)
