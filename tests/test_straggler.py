"""Straggler watchdog edge cases (repro.runtime.straggler):

* zero-sample behaviour: infinite deadline, nothing flagged, empty median;
* median-of-one: a single live replica is never a *fleet* straggler;
* ``FleetWatchdog.reset`` on rejoin: the stale EMA really is discarded;
* EMA propagation under scripted ``inject_step_delay`` faults.
"""

import math

from repro.configs.base import ServeConfig
from repro.runtime.straggler import FleetWatchdog, StepTimer, StragglerWatchdog


# ---------------------------------------------------------------------------
# StragglerWatchdog
# ---------------------------------------------------------------------------

def test_zero_samples_never_flags():
    wd = StragglerWatchdog(min_samples=5)
    assert wd.deadline == float("inf")
    # even an absurd first sample cannot be a straggler: no baseline yet
    assert not wd.record(0, 1e9)
    assert wd.n == 1 and wd.ema == 1e9
    assert wd.events == []


def test_deadline_infinite_until_min_samples():
    wd = StragglerWatchdog(factor=3.0, min_samples=3)
    for s in range(2):
        wd.record(s, 1.0)
        assert wd.deadline == float("inf")
    wd.record(2, 1.0)
    assert math.isclose(wd.deadline, 3.0)


def test_straggler_does_not_poison_ema():
    wd = StragglerWatchdog(factor=3.0, min_samples=1, ema_decay=0.9)
    wd.record(0, 1.0)
    assert wd.record(1, 100.0)          # flagged
    assert wd.ema == 1.0                # EMA untouched by the outlier
    assert not wd.record(2, 1.0)        # baseline intact afterwards


def test_ema_converges_to_steady_state():
    wd = StragglerWatchdog(min_samples=1, ema_decay=0.5)
    for s in range(30):
        wd.record(s, 2.0)
    assert math.isclose(wd.ema, 2.0, rel_tol=1e-6)


# ---------------------------------------------------------------------------
# FleetWatchdog
# ---------------------------------------------------------------------------

def test_fleet_zero_samples_no_stragglers():
    fw = FleetWatchdog(n_replicas=3)
    assert fw.stragglers() == []
    assert fw.ema(0) == 0.0


def test_fleet_median_of_one_replica():
    # a single live replica has no peers: the median IS its own EMA, so the
    # relative test can never fire and only its own deadline can flag it
    fw = FleetWatchdog(n_replicas=1)
    for s in range(5):
        fw.record(0, s, 1.0)
    assert fw.stragglers() == []
    assert fw.record(0, 5, 100.0)       # own deadline blown
    assert fw.stragglers() == [0]


def test_fleet_median_excludes_dead_replicas():
    fw = FleetWatchdog(n_replicas=3)
    for s in range(3):
        fw.record(0, s, 1.0)
        fw.record(1, s, 1.0)
        fw.record(2, s, 10.0)
    # with all three live, replica 2's EMA is > factor x median(1,1,10)=1
    assert fw.stragglers() == [2]
    # restrict to the live set {2}: no peers to compare against
    assert fw.stragglers(live=[2]) == []


def test_fleet_reset_discards_stale_ema_on_rejoin():
    fw = FleetWatchdog(n_replicas=2)
    for s in range(4):
        fw.record(0, s, 1.0)
        fw.record(1, s, 10.0)
    assert fw.ema(1) > 5.0
    fw.reset(1)
    assert fw.ema(1) == 0.0
    assert fw.feeds[1].n == 0
    assert fw.stragglers() == []        # the flag is cleared too
    # the first post-rejoin sample reseeds the EMA (min_samples=1)
    assert not fw.record(1, 5, 1.0)
    assert fw.ema(1) == 1.0


def test_fleet_ema_under_injected_delay():
    # the serving engine inflates its recorded step time via
    # inject_step_delay; the fleet feed must see the inflated dt
    from repro.launch.serve import build_engine
    serve = ServeConfig(max_batch=2, prefill_batch=1, bucket_edges=(8,),
                        max_new_tokens=2)
    eng = build_engine("tinyllama-1.1b", reduced=True, mesh_shape=(2, 2),
                       serve=serve)
    eng.submit(tuple(range(1, 6)))
    eng.step()                          # prefill, seeds the engine EMA
    base = eng.step_times[-1]
    eng.inject_step_delay(30.0)
    eng.step()
    assert eng.step_times[-1] >= 30.0
    assert eng.step_times[-1] - 30.0 < base * 100  # the dt itself stayed sane
    fw = FleetWatchdog(n_replicas=3)
    fw.record(0, 0, eng.step_times[-2])
    fw.record(1, 0, eng.step_times[-1])
    fw.record(2, 0, eng.step_times[-2])
    assert fw.ema(1) >= 30.0
    # two fast peers: the median EMA is the fast one, replica 1 stands out
    assert fw.stragglers() == [1]


def test_step_timer_measures_elapsed():
    with StepTimer() as t:
        sum(range(1000))
    assert t.dt >= 0.0
