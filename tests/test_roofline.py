"""Roofline machinery: HLO collective parsing and term construction."""


from repro.core.costmodel import TPU_V5E
from repro.roofline.hlo import collective_bytes
from repro.roofline.model import build, model_flops
from repro.configs import get_config
from repro.configs.base import SHAPES

HLO = """
ENTRY %main {
  %ag = bf16[16,1024,512]{2,1,0} all-gather(%a), replica_groups=[16,16]<=[256], dimensions={0}
  %ar.1 = f32[4096,2048]{1,0} all-reduce(%b), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = bf16[256,128]{1,0} reduce-scatter(%c), replica_groups=[32,8]<=[256], dimensions={0}
  %cp-start = bf16[64,64]{1,0} collective-permute-start(%d), source_target_pairs={{0,1}}
  %a2a = f32[8,8,8]{2,1,0} all-to-all(%e), replica_groups={{0,1,2,3,4,5,6,7}}
  %not-a-collective = f32[128]{0} add(%f, %g)
}
"""


def test_collective_bytes_parsing():
    stats = collective_bytes(HLO)
    assert set(stats.by_kind) == {"all-gather", "all-reduce",
                                  "reduce-scatter", "collective-permute",
                                  "all-to-all"}
    ag_b = 16 * 1024 * 512 * 2 * 15 / 16
    ar_b = 2 * 4096 * 2048 * 4 * 3 / 4
    rs_b = 256 * 128 * 2 * 7
    cp_b = 64 * 64 * 2
    a2a_b = 8 * 8 * 8 * 4 * 7 / 8
    assert abs(stats.by_kind["all-gather"][0] - ag_b) < 1
    assert abs(stats.by_kind["all-reduce"][0] - ar_b) < 1
    assert abs(stats.by_kind["reduce-scatter"][0] - rs_b) < 1
    assert abs(stats.by_kind["collective-permute"][0] - cp_b) < 1
    assert abs(stats.by_kind["all-to-all"][0] - a2a_b) < 1
    assert stats.op_count == 5


def test_roofline_build_terms():
    stats = collective_bytes(HLO)
    r = build("archx", "train_4k", "16x16", flops=1e15, hbm_bytes=1e12,
              coll=stats, model_flops_total=200e15, n_chips=256)
    assert abs(r.t_compute - 1e15 / TPU_V5E.peak_flops_bf16) < 1e-9
    assert abs(r.t_memory - 1e12 / TPU_V5E.hbm_bandwidth) < 1e-9
    assert r.bottleneck in ("compute", "memory", "collective")
    assert 0 < r.useful_ratio < 1.0
    assert 0 <= r.roofline_fraction <= 1.0


def test_model_flops_kinds():
    cfg = get_config("tinyllama-1.1b")
    tr = model_flops(cfg, SHAPES["train_4k"])
    pf = model_flops(cfg, SHAPES["prefill_32k"])
    de = model_flops(cfg, SHAPES["decode_32k"])
    assert tr == 6 * cfg.active_param_count() * 256 * 4096
    assert pf == 2 * cfg.active_param_count() * 32 * 32768
    assert de == 2 * cfg.active_param_count() * 128
    # MoE uses active params
    moe = get_config("moonshot-v1-16b-a3b")
    assert model_flops(moe, SHAPES["train_4k"]) < \
        6 * moe.param_count() * 256 * 4096
