"""CommContext: policy dispatch, explicit overrides, backend equivalence,
and the central collective-id allocator (the unified comms API)."""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
import repro.core.comms as comms
from repro.core.comms import (CommContext, OP_BACKENDS, collective_id,
                              register_collective, registered_collectives)

N = 4
BIG = 8192          # comfortably past the v5e hiding threshold / sync cutoff


@pytest.fixture(scope="module")
def sm(mesh4):
    return partial(compat.shard_map, mesh=mesh4, check_vma=False)


@pytest.fixture(scope="module")
def ctx(mesh4):
    return CommContext(axis_name="x", mesh=mesh4)


# ---------------------------------------------------------------------------
# Policy dispatch (trace-free)
# ---------------------------------------------------------------------------

def test_tiny_gemm_dispatches_bulk(ctx):
    """Small problem sizes: decomposed schedules lose to sync overhead
    (paper Fig. 7 small-M regime) — the policy must stay bulk."""
    for op in ("all_gather_matmul", "matmul_reduce_scatter",
               "matmul_all_reduce"):
        assert ctx.auto_gemm_backend(op, 16, 12, 32) == "bulk", op


def test_big_ag_gemm_dispatches_bidir_on_even_axis(ctx):
    assert ctx.auto_gemm_backend("all_gather_matmul", BIG, BIG, BIG) \
        == "ring_bidir"


def test_bidir_respects_constraints(ctx, mesh4):
    # context-level opt-out
    no_bidir = CommContext(axis_name="x", mesh=mesh4, allow_bidir=False)
    assert no_bidir.auto_gemm_backend("all_gather_matmul", BIG, BIG, BIG) \
        == "ring"
    # odd local row count cannot split halves across the two rings
    assert ctx.auto_gemm_backend("all_gather_matmul", BIG, BIG, BIG,
                                 bidir_ok=False) == "ring"


def test_big_gemm_rs_dispatches_ring(ctx):
    # RS/AR have no bidirectional variant: enabled policy maps to "ring"
    assert ctx.auto_gemm_backend("matmul_reduce_scatter", BIG, BIG, BIG) \
        == "ring"
    assert ctx.auto_gemm_backend("matmul_all_reduce", BIG, BIG, BIG) == "ring"
    # ...and the cost model must not credit them with the second link-pair
    # (only AG+GEMM implements the bidirectional ring)
    assert ctx.gemm_policy(BIG, BIG, BIG,
                           kind="reduce_scatter").strategy == "ring"
    assert ctx.gemm_policy(BIG, BIG, BIG,
                           kind="all_gather").strategy == "ring_bidir"


def test_context_pin_degrades_for_unsupported_op(sm, mesh4):
    """RunConfig.comm_backend='ring_bidir' must not crash ops without a
    bidirectional variant: the pin falls back to the policy for that op."""
    pinned = CommContext(axis_name="x", mesh=mesh4, backend="ring_bidir")
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 8 * N))
    w = jax.random.normal(jax.random.PRNGKey(1), (8 * N, 8))
    got = _run(sm, pinned.matmul_all_reduce,
               (P(None, "x"), P("x", None)), P(), x, w)
    np.testing.assert_allclose(got, np.asarray(x @ w), rtol=1e-4, atol=1e-4)
    # a typo'd pin is still an error, not a silent policy run
    typo = CommContext(axis_name="x", mesh=mesh4, backend="rinng")
    with pytest.raises(ValueError, match="unknown backend"):
        typo.matmul_all_reduce(x, w)


def test_psum_ring_override_shape_contract(sm, mesh4, ctx):
    """Per-call backend='ring' with an indivisible leading dim raises (no
    silent bulk measurement); a context pin degrades to bulk."""
    bad = jnp.ones((2 * N + 1, 4))
    with pytest.raises(ValueError, match="divisible by the axis size"):
        ctx.psum(bad, backend="ring")
    pinned = CommContext(axis_name="x", mesh=mesh4, backend="ring")
    got = _run(sm, pinned.psum, P(), P(None), bad)
    np.testing.assert_allclose(got, N * np.asarray(bad))


def test_a2a_policy_chunks_large_payloads(ctx):
    from repro.core.schedule import choose_a2a_chunks
    small = choose_a2a_chunks(2 ** 10, axis_size=N, downstream_compute_s=0.0)
    big = choose_a2a_chunks(2 ** 28, axis_size=N, downstream_compute_s=1e-3)
    assert small == 1
    assert big > 1


def test_registry_and_availability(ctx):
    for op, backends in OP_BACKENDS.items():
        assert "bulk" in backends, op
        avail = ctx.available_backends(op)
        assert set(avail) <= set(backends)
        if not compat.tpu_kernels_supported():
            assert "fused" not in avail


def test_unknown_backend_raises(ctx):
    with pytest.raises(ValueError, match="no backend"):
        ctx.psum(jnp.ones((4,)), backend="nope")


@pytest.mark.skipif(compat.tpu_kernels_supported(),
                    reason="fused kernels available here")
def test_fused_unavailable_raises_cleanly(ctx):
    with pytest.raises(NotImplementedError, match="fused"):
        ctx.all_gather_matmul(jnp.ones((8, 8)), jnp.ones((8, 8)),
                              backend="fused")


# ---------------------------------------------------------------------------
# Explicit override beats both the policy and the context backend
# ---------------------------------------------------------------------------

def test_per_call_override_wins(sm, mesh4, monkeypatch):
    calls = []
    orig = comms.pk_all_gather_matmul

    def spy(*args, **kwargs):
        calls.append(kwargs.get("bidirectional"))
        return orig(*args, **kwargs)

    monkeypatch.setattr(comms, "pk_all_gather_matmul", spy)
    bulk_ctx = CommContext(axis_name="x", mesh=mesh4, backend="bulk")
    x = jax.random.normal(jax.random.PRNGKey(0), (4 * N, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 8))

    # context says bulk -> the ring impl must NOT be called
    f = jax.jit(sm(lambda x, w: bulk_ctx.all_gather_matmul(x, w),
                   in_specs=(P("x"), P()), out_specs=P()))
    f(x, w)
    assert calls == []

    # per-call override says ring -> the ring impl MUST be called
    g = jax.jit(sm(lambda x, w: bulk_ctx.all_gather_matmul(x, w,
                                                           backend="ring"),
                   in_specs=(P("x"), P()), out_specs=P()))
    g(x, w)
    assert calls == [False]


def test_shape_guard_pinned_vs_explicit(sm, mesh4):
    """Decode-shaped GEMMs (m not divisible by the axis): a context-pinned
    ring backend degrades to bulk like the policy does; a per-call override
    raises with the constraint named."""
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 8 * N))   # m=3, axis=4
    w = jax.random.normal(jax.random.PRNGKey(1), (8 * N, 8))
    pinned = CommContext(axis_name="x", mesh=mesh4, backend="ring")
    got = _run(sm, pinned.matmul_all_reduce,
               (P(None, "x"), P("x", None)), P(), x, w)
    np.testing.assert_allclose(got, np.asarray(x @ w), rtol=1e-4, atol=1e-4)

    ctx = CommContext(axis_name="x", mesh=mesh4)
    with pytest.raises(ValueError, match="divisible by the axis size"):
        ctx.matmul_all_reduce(x, w, backend="ring")
    # a single local row cannot split across the two ring directions
    with pytest.raises(ValueError, match="at least 2 local rows"):
        ctx.all_gather_matmul(jax.random.normal(jax.random.PRNGKey(2),
                                                (1, 8)),
                              jnp.ones((8, 8)), backend="ring_bidir")


def test_bidir_odd_m_loc_is_legal(sm, ctx):
    """An odd local row count used to be rejected by the full-shard parity
    guard ("even local row count"); the chunk-pipelined ring validates the
    chunked sub-shape instead and splits the shard unevenly (ceil right,
    floor left), so the config is legal — and exact."""
    x = jax.random.normal(jax.random.PRNGKey(0), (3 * N, 8))   # m_loc = 3
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 6))
    got = _run(sm, partial(ctx.all_gather_matmul, backend="ring_bidir"),
               (P("x"), P()), P(), x, w)
    np.testing.assert_allclose(got, np.asarray(x @ w), rtol=1e-4, atol=1e-4)
    # ...and the policy may now consider bidir for odd local rows
    assert ctx.auto_gemm_backend("all_gather_matmul", BIG + 4, BIG, BIG) \
        in ("ring", "ring_bidir")


# ---------------------------------------------------------------------------
# Numerical equivalence: every available backend of every op == bulk,
# on the 4-device CPU mesh.
# ---------------------------------------------------------------------------

def _run(sm, fn, in_specs, out_specs, *args):
    return np.asarray(jax.jit(sm(fn, in_specs=in_specs,
                                 out_specs=out_specs))(*args))


def test_gemm_ops_backend_equivalence(sm, ctx):
    x_ag = jax.random.normal(jax.random.PRNGKey(0), (8 * N, 16))
    w_ag = jax.random.normal(jax.random.PRNGKey(1), (16, 12))
    x_rs = jax.random.normal(jax.random.PRNGKey(2), (16, 8 * N))
    w_rs = jax.random.normal(jax.random.PRNGKey(3), (8 * N, 12))

    cases = {
        "all_gather_matmul": (ctx.all_gather_matmul, (x_ag, w_ag),
                              (P("x"), P()), P(), x_ag @ w_ag),
        "matmul_reduce_scatter": (ctx.matmul_reduce_scatter, (x_rs, w_rs),
                                  (P(None, "x"), P("x", None)),
                                  P("x", None), x_rs @ w_rs),
        "matmul_all_reduce": (ctx.matmul_all_reduce, (x_rs, w_rs),
                              (P(None, "x"), P("x", None)), P(),
                              x_rs @ w_rs),
    }
    for op, (meth, args, in_specs, out_specs, want) in cases.items():
        for be in ctx.available_backends(op) + (None,):
            got = _run(sm, partial(meth, backend=be), in_specs, out_specs,
                       *args)
            np.testing.assert_allclose(got, np.asarray(want), rtol=1e-4,
                                       atol=1e-4, err_msg=f"{op}/{be}")


@pytest.mark.parametrize("nc", [1, 2, 4, 3])    # 3: non-divisible fallback
def test_gemm_ops_chunked_equivalence(sm, ctx, nc):
    """The chunk-pipelined ring schedules are bit-compatible with the dense
    reference for every chunk count and both chunk dims — including counts
    that do not divide the chunked sub-shape (fitted to a divisor) and the
    bidirectional multi-chunk-per-step variant."""
    x_ag = jax.random.normal(jax.random.PRNGKey(0), (8 * N, 16))
    w_ag = jax.random.normal(jax.random.PRNGKey(1), (16, 12))
    x_rs = jax.random.normal(jax.random.PRNGKey(2), (16, 8 * N))
    w_rs = jax.random.normal(jax.random.PRNGKey(3), (8 * N, 12))

    cases = {
        ("all_gather_matmul", "ring"): (
            ctx.all_gather_matmul, (x_ag, w_ag), (P("x"), P()), P(),
            x_ag @ w_ag),
        ("all_gather_matmul", "ring_bidir"): (
            ctx.all_gather_matmul, (x_ag, w_ag), (P("x"), P()), P(),
            x_ag @ w_ag),
        ("matmul_reduce_scatter", "ring"): (
            ctx.matmul_reduce_scatter, (x_rs, w_rs),
            (P(None, "x"), P("x", None)), P("x", None), x_rs @ w_rs),
        ("matmul_all_reduce", "ring"): (
            ctx.matmul_all_reduce, (x_rs, w_rs),
            (P(None, "x"), P("x", None)), P(), x_rs @ w_rs),
    }
    for (op, be), (meth, args, in_specs, out_specs, want) in cases.items():
        for dim in ("m", "n"):
            got = _run(sm, partial(meth, backend=be, n_chunks=nc,
                                   chunk_dim=dim), in_specs, out_specs,
                       *args)
            np.testing.assert_allclose(got, np.asarray(want), rtol=1e-4,
                                       atol=1e-4,
                                       err_msg=f"{op}/{be}/c={nc}/{dim}")


def test_chunk_schedule_resolution(ctx, mesh4):
    """gemm_chunk_schedule precedence: explicit kwarg > context chunks= >
    analytic scheduler; bulk takes no sub-chunks."""
    s = ctx.gemm_chunk_schedule("matmul_all_reduce", BIG, BIG, BIG,
                                backend="ring", n_chunks=4)
    assert s.n_chunks == 4 and s.source == "explicit"
    pinned = CommContext(axis_name="x", mesh=mesh4, chunks=2)
    s = pinned.gemm_chunk_schedule("matmul_all_reduce", BIG, BIG, BIG,
                                   backend="ring")
    assert s.n_chunks == 2 and s.source == "explicit"
    s = ctx.gemm_chunk_schedule("matmul_all_reduce", BIG, BIG, BIG,
                                backend="bulk", n_chunks=8)
    assert s.n_chunks == 1
    s = ctx.gemm_chunk_schedule("matmul_all_reduce", BIG, BIG, BIG,
                                backend="ring")
    assert s.source == "analytic" and s.n_chunks >= 1
    assert s.chunk_dim == "m"


def test_fit_chunks_fallback():
    from repro.core.schedule import fit_chunks
    assert fit_chunks(8, 3) == 2        # largest divisor <= request
    assert fit_chunks(7, 4) == 1
    assert fit_chunks(8, 16) == 8       # clamped to the extent
    assert fit_chunks(0, 4) == 1


def test_a2a_chunk_policy_validates_sub_shape():
    """choose_a2a_chunks with shape= fits the count to what the bystander
    dims can actually split — a payload whose dims cannot divide the naive
    count no longer silently bulks the whole transfer."""
    from repro.core.schedule import a2a_chunk_axis, choose_a2a_chunks
    big = 2 ** 28
    # dim 3 (size 6) cannot split by 8 but can by 3
    assert a2a_chunk_axis((1, 4, 8, 6), 1, 2, 8) == (3, 6)
    c = choose_a2a_chunks(big, axis_size=N, downstream_compute_s=1e-3,
                          shape=(1, 4, 8, 6), split_axis=1, concat_axis=2)
    assert c > 1 and 6 % c == 0
    # no bystander dim at all -> bulk
    assert choose_a2a_chunks(big, axis_size=N, downstream_compute_s=1e-3,
                             shape=(4, 8), split_axis=0, concat_axis=1) == 1


def test_all_to_all_backend_equivalence(sm, ctx):
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, N * 4, 16))
    want = None
    for be, nc in (("bulk", None), ("chunked", 2), ("chunked", None),
                   (None, 4), (None, None)):
        got = _run(sm, lambda t, be=be, nc=nc: ctx.all_to_all(
            t, split_axis=2, concat_axis=1, backend=be, n_chunks=nc),
            P(None, "x"), P(None, None, "x"), x)
        want = got if want is None else want
        np.testing.assert_allclose(got, want, err_msg=f"a2a/{be}/{nc}")


def test_psum_and_shift_backend_equivalence(sm, ctx):
    y = jax.random.normal(jax.random.PRNGKey(0), (4 * N, 8))
    want = None
    for be in ctx.available_backends("psum") + (None,):
        got = _run(sm, lambda t, be=be: ctx.psum(t, backend=be)[None],
                   P("x"), P("x"), y)
        want = got if want is None else want
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5,
                                   err_msg=f"psum/{be}")

    got = _run(sm, lambda t: ctx.ring_shift(t), P("x"), P("x"), y)
    np.testing.assert_allclose(got, np.asarray(jnp.roll(y, 4 * N // N,
                                                        axis=0)))

    got = _run(sm, lambda t: ctx.all_gather(t), P("x"), P(), y)
    np.testing.assert_allclose(got, np.asarray(y))

    got = _run(sm, lambda t: ctx.reduce_scatter(t), P(), P("x"), y)
    np.testing.assert_allclose(got, np.asarray(N * y), rtol=1e-5)


# ---------------------------------------------------------------------------
# Collective-id allocator
# ---------------------------------------------------------------------------

def test_collective_ids_unique_and_stable():
    ids = registered_collectives()
    assert len(set(ids.values())) == len(ids)          # no collisions
    assert collective_id("ring_all_gather") == ids["ring_all_gather"]
    fresh = register_collective("test_comms_fresh_kernel")
    assert fresh not in ids.values()                   # new name, new id
    assert collective_id("test_comms_fresh_kernel") == fresh  # stable
    assert register_collective("test_comms_fresh_kernel") == fresh


def test_collective_id_rejects_unregistered_names():
    # trace-time allocation would be trace-order-dependent across SPMD
    # processes — lookups of unknown kernels must fail loudly instead
    with pytest.raises(KeyError, match="not registered"):
        collective_id("never_registered_kernel")


def test_canonical_kernels_preregistered():
    ids = registered_collectives()
    for name in ("ring_all_gather", "ring_reduce_scatter", "p2p_ring_shift",
                 "ag_matmul_fused", "matmul_rs_fused",
                 "lcsc_ring_all_gather"):
        assert name in ids


# ---------------------------------------------------------------------------
# Removed module (was a deprecation shim for one release)
# ---------------------------------------------------------------------------

def test_collectives_module_removed_with_migration_message():
    with pytest.raises(ImportError, match="repro.core.comms"):
        from repro.core.collectives import pk_all_to_all  # noqa: F401
