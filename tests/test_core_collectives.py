"""shard_map-level PK overlapped collectives vs bulk baselines vs math."""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from jax.sharding import PartitionSpec as P

from repro.core import (all_gather_matmul_baseline, matmul_all_reduce_baseline,
                        matmul_reduce_scatter_baseline, pk_all_gather_matmul,
                        pk_all_to_all, pk_matmul_all_reduce,
                        pk_matmul_reduce_scatter, ring_shift)

N = 4


@pytest.fixture(scope="module")
def sm(mesh4):
    return partial(compat.shard_map, mesh=mesh4, check_vma=False)


@pytest.mark.parametrize("fn,bidir", [
    (all_gather_matmul_baseline, False),
    (pk_all_gather_matmul, False),
    (pk_all_gather_matmul, True),
])
def test_ag_matmul(sm, fn, bidir):
    m_loc, k, n_out = 8, 16, 12
    x = jax.random.normal(jax.random.PRNGKey(0), (N * m_loc, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n_out))
    kwargs = {"bidirectional": bidir} if fn is pk_all_gather_matmul else {}
    f = jax.jit(sm(lambda x, w: fn(x, w, "x", **kwargs),
                   in_specs=(P("x"), P()), out_specs=P()))
    np.testing.assert_allclose(np.asarray(f(x, w)), np.asarray(x @ w),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("fn", [matmul_reduce_scatter_baseline,
                                pk_matmul_reduce_scatter])
def test_matmul_rs(sm, fn):
    m, k_loc, n_out = 16, 8, 12
    x = jax.random.normal(jax.random.PRNGKey(0), (m, N * k_loc))
    w = jax.random.normal(jax.random.PRNGKey(1), (N * k_loc, n_out))
    f = jax.jit(sm(lambda x, w: fn(x, w, "x"),
                   in_specs=(P(None, "x"), P("x", None)),
                   out_specs=P("x", None)))
    np.testing.assert_allclose(np.asarray(f(x, w)), np.asarray(x @ w),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("fn", [matmul_all_reduce_baseline,
                                pk_matmul_all_reduce])
def test_matmul_ar(sm, fn):
    m, k_loc, n_out = 16, 8, 12
    x = jax.random.normal(jax.random.PRNGKey(0), (m, N * k_loc))
    w = jax.random.normal(jax.random.PRNGKey(1), (N * k_loc, n_out))
    f = jax.jit(sm(lambda x, w: fn(x, w, "x"),
                   in_specs=(P(None, "x"), P("x", None)), out_specs=P()))
    np.testing.assert_allclose(np.asarray(f(x, w)), np.asarray(x @ w),
                               rtol=1e-4, atol=1e-4)


def test_pk_all_to_all_chunked(sm):
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, N * 4, 16))
    ref = jax.jit(sm(lambda x: pk_all_to_all(x, "x", split_axis=2,
                                             concat_axis=1, n_chunks=1),
                     in_specs=P(None, "x"), out_specs=P(None, None, "x")))
    chk = jax.jit(sm(lambda x: pk_all_to_all(x, "x", split_axis=2,
                                             concat_axis=1, n_chunks=2),
                     in_specs=P(None, "x"), out_specs=P(None, None, "x")))
    np.testing.assert_allclose(np.asarray(ref(x)), np.asarray(chk(x)))


def test_ring_shift_pytree(sm):
    x = jnp.arange(N * 3, dtype=jnp.float32).reshape(N, 3)
    f = jax.jit(sm(lambda t: ring_shift({"a": t, "b": 2 * t}, "x"),
                   in_specs=P("x"), out_specs=P("x")))
    out = f(x)
    np.testing.assert_allclose(np.asarray(out["a"]),
                               np.asarray(jnp.roll(x, 1, axis=0)))
    np.testing.assert_allclose(np.asarray(out["b"]),
                               np.asarray(jnp.roll(2 * x, 1, axis=0)))


def test_grad_through_pk_rs(sm):
    """PK rings must be differentiable (used inside the MLP islands)."""
    m, k_loc, n_out = 16, 8, 12
    x = jax.random.normal(jax.random.PRNGKey(0), (m, N * k_loc))
    w = jax.random.normal(jax.random.PRNGKey(1), (N * k_loc, n_out))

    def loss(fn):
        def inner(x, w):
            y = fn(x, w, "x")
            return jax.lax.psum(jnp.sum(y ** 2), "x") / N
        f = sm(inner, in_specs=(P(None, "x"), P("x", None)), out_specs=P())
        return jax.jit(jax.grad(lambda w: f(x, w)))(w)

    g_pk = loss(pk_matmul_reduce_scatter)
    g_base = loss(matmul_reduce_scatter_baseline)
    np.testing.assert_allclose(np.asarray(g_pk), np.asarray(g_base),
                               rtol=1e-3, atol=1e-3)
