"""PK communication kernels: ring all-gather / reduce-scatter / p2p and the
fused collective-matmul kernels, cross-device in TPU interpret mode under
shard_map — including multi-seed runs with race detection (the interpreter
models out-of-order DMA delivery)."""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from jax.sharding import PartitionSpec as P

# The communication kernels need cross-device semaphore/remote-DMA emulation
# (pltpu.InterpretParams) off-TPU; skip cleanly on JAX builds without it.
pytestmark = pytest.mark.skipif(
    not compat.tpu_kernels_supported(),
    reason="no TPU backend and no pltpu.InterpretParams in this JAX")

from repro.kernels import ref
from repro.kernels.collective_matmul import (ag_matmul_fused, matmul_ar_fused,
                                             matmul_rs_fused)
from repro.kernels.pk_comm import (p2p_ring_shift, ring_all_gather,
                                   ring_reduce_scatter)

N = 4


@pytest.fixture(scope="module")
def sm(mesh4):
    return partial(compat.shard_map, mesh=mesh4, check_vma=False)


def test_p2p_ring_shift(sm):
    x = jax.random.normal(jax.random.PRNGKey(0), (N, 8, 16), jnp.float32)
    f = jax.jit(sm(lambda x: p2p_ring_shift(x[0], "x")[None],
                   in_specs=P("x"), out_specs=P("x")))
    np.testing.assert_allclose(np.asarray(f(x)),
                               np.asarray(jnp.roll(x, 1, axis=0)))


def test_ring_all_gather(sm):
    x = jax.random.normal(jax.random.PRNGKey(0), (N, 8, 16), jnp.float32)
    f = jax.jit(sm(lambda x: ring_all_gather(x[0], "x")[None],
                   in_specs=P("x"), out_specs=P("x")))
    got = np.asarray(f(x))           # (dev, slot, 8, 16)
    for d in range(N):
        np.testing.assert_allclose(got[d], np.asarray(x))


def test_ring_reduce_scatter(sm):
    xg = jax.random.normal(jax.random.PRNGKey(0), (N, N, 8, 16), jnp.float32)
    f = jax.jit(sm(lambda x: ring_reduce_scatter(x[0], "x")[None],
                   in_specs=P("x"), out_specs=P("x")))
    np.testing.assert_allclose(np.asarray(f(xg)),
                               np.asarray(ref.reduce_scatter_ref(xg)),
                               rtol=1e-5, atol=1e-5)


def test_ag_matmul_fused(sm):
    m_loc, k, n_out = 16, 32, 24
    x = jax.random.normal(jax.random.PRNGKey(0), (N * m_loc, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n_out), jnp.float32)
    f = jax.jit(sm(
        lambda x, w: ag_matmul_fused(x, w, "x").reshape(N * m_loc, n_out)[None],
        in_specs=(P("x"), P()), out_specs=P("x")))
    got = np.asarray(f(x, w)).reshape(N, N * m_loc, n_out)
    want = np.asarray(ref.ag_matmul_ref(x, w))
    for d in range(N):
        np.testing.assert_allclose(got[d], want, rtol=1e-4, atol=1e-4)


def test_matmul_rs_fused(sm):
    m, k_loc, n_out = 16, 8, 24
    x = jax.random.normal(jax.random.PRNGKey(0), (m, N * k_loc), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (N * k_loc, n_out), jnp.float32)
    f = jax.jit(sm(lambda x, w: matmul_rs_fused(x, w, "x"),
                   in_specs=(P(None, "x"), P("x", None)),
                   out_specs=P("x", None)))
    np.testing.assert_allclose(np.asarray(f(x, w)),
                               np.asarray(ref.matmul_rs_ref(x, w)),
                               rtol=1e-4, atol=1e-4)


def test_matmul_ar_fused(sm):
    """Single-kernel GEMM×all-reduce (RS ring + in-kernel gather of the
    reduced blocks) matches the replicated matmul oracle on every device."""
    m, k_loc, n_out = 16, 8, 24
    x = jax.random.normal(jax.random.PRNGKey(0), (m, N * k_loc), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (N * k_loc, n_out), jnp.float32)
    f = jax.jit(sm(
        lambda x, w: matmul_ar_fused(x, w, "x").reshape(m, n_out)[None],
        in_specs=(P(None, "x"), P("x", None)), out_specs=P("x")))
    got = np.asarray(f(x, w))        # (dev, m, n_out): replica per device
    want = np.asarray(ref.matmul_ar_ref(x, w))
    for d in range(N):
        np.testing.assert_allclose(got[d], want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("n_chunks", [1, 2])
def test_ring_all_gather_race_free(mesh4, seed, n_chunks):
    """Per-hop (and per-sub-chunk) semaphores must order the ring under
    randomized DMA delivery (this catches the count-only synchronization
    bug — see pk_comm.py)."""
    import functools
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu
    from repro.kernels.pk_comm import _ag_kernel

    def ag(x):
        from repro.core.comms import collective_id
        return pl.pallas_call(
            functools.partial(_ag_kernel, axis_name="x", n_dev=N,
                              n_chunks=n_chunks,
                              chunk_rows=x.shape[0] // n_chunks),
            in_specs=[pl.BlockSpec(memory_space=compat.ANY)],
            out_specs=pl.BlockSpec(memory_space=compat.ANY),
            out_shape=jax.ShapeDtypeStruct((N, *x.shape), x.dtype),
            scratch_shapes=[pltpu.SemaphoreType.DMA((N - 1, n_chunks)),
                            pltpu.SemaphoreType.DMA((N - 1, n_chunks)),
                            pltpu.SemaphoreType.DMA],
            compiler_params=compat.CompilerParams(
                collective_id=collective_id("ring_all_gather")),
            interpret=compat.interpret_params(random_seed=seed,
                                              detect_races=True),
        )(x)

    x = jnp.arange(N, dtype=jnp.float32)[:, None, None] * jnp.ones((N, 4, 8))
    f = jax.jit(partial(compat.shard_map, mesh=mesh4, check_vma=False)(
        lambda x: ag(x[0])[None], in_specs=P("x"), out_specs=P("x")))
    got = np.asarray(f(x))
    for d in range(N):
        np.testing.assert_allclose(got[d, :, 0, 0], np.arange(N))


def test_lcsc_template_ring_all_gather(sm):
    """The LCSC template (paper §3.2.3) expressing ring AG in ~8 worker
    lines must match the hand-written kernel."""
    from repro.kernels.lcsc import lcsc_ring_all_gather
    x = jax.random.normal(jax.random.PRNGKey(5), (N, 8, 16), jnp.float32)
    f = jax.jit(sm(lambda x: lcsc_ring_all_gather(x[0], "x")[None],
                   in_specs=P("x"), out_specs=P("x")))
    got = np.asarray(f(x))
    for d in range(N):
        np.testing.assert_allclose(got[d], np.asarray(x))
