"""End-to-end behaviour tests: the public train/serve paths on an emulated
mesh with all substrates active (PK overlap, FSDP, checkpointing)."""

import numpy as np



def test_end_to_end_train_on_mesh(tmp_path):
    from repro.launch.train import build_and_train
    state, log = build_and_train(
        "tinyllama-1.1b", steps=12, reduced=True, mesh_shape=(2, 4),
        mesh_axes=("data", "model"), batch=4, seq=32,
        ckpt_dir=str(tmp_path), lr=3e-3, microbatches=2, log_every=1,
        ckpt_every=6)
    assert log[-1]["step"] == 12
    assert np.isfinite(log[-1]["loss"])
    # checkpoint committed
    from repro.ckpt.manager import CheckpointManager
    assert CheckpointManager(tmp_path).latest_step() == 12


def test_end_to_end_train_compressed_grads(tmp_path):
    from repro.launch.train import build_and_train
    _, log = build_and_train(
        "tinyllama-1.1b", steps=20, reduced=True, mesh_shape=None,
        mesh_axes=None, batch=4, seq=32, ckpt_dir=str(tmp_path), lr=5e-3,
        compress_grads=True, log_every=1, ckpt_every=100)
    first = np.mean([m["loss"] for m in log[:3]])
    last = np.mean([m["loss"] for m in log[-3:]])
    assert last < first, "int8+EF compressed training must still learn"


def test_end_to_end_serve(capsys):
    from repro.launch.serve import generate
    out = generate("tinyllama-1.1b", reduced=True, batch=2, prompt_len=4,
                   gen_tokens=8, mesh_shape=(2, 4))
    assert out.shape == (2, 8)
    assert np.all((np.asarray(out) >= 0))


def test_end_to_end_serve_ssm():
    from repro.launch.serve import generate
    out = generate("falcon-mamba-7b", reduced=True, batch=2, prompt_len=2,
                   gen_tokens=6, mesh_shape=None)
    assert out.shape == (2, 6)


def test_moe_arch_trains_on_mesh(tmp_path):
    from repro.launch.train import build_and_train
    _, log = build_and_train(
        "moonshot-v1-16b-a3b", steps=6, reduced=True, mesh_shape=(2, 4),
        mesh_axes=("data", "model"), batch=4, seq=32,
        ckpt_dir=str(tmp_path), log_every=1, ckpt_every=100)
    assert np.isfinite(log[-1]["loss"])


def test_hybrid_arch_trains_on_mesh(tmp_path):
    from repro.launch.train import build_and_train
    _, log = build_and_train(
        "jamba-1.5-large-398b", steps=4, reduced=True, mesh_shape=(2, 4),
        mesh_axes=("data", "model"), batch=4, seq=32,
        ckpt_dir=str(tmp_path), log_every=1, ckpt_every=100)
    assert np.isfinite(log[-1]["loss"])
