# 8 emulated CPU devices for the shard_map/mesh tests (NOT the dry-run's 512
# — that stays local to launch/dryrun.py per the assignment).
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest


@pytest.fixture(scope="session")
def mesh22():
    return jax.make_mesh((2, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


@pytest.fixture(scope="session")
def mesh4():
    return jax.make_mesh((4,), ("x",),
                         axis_types=(jax.sharding.AxisType.Auto,))


@pytest.fixture(scope="session")
def mesh8():
    return jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
