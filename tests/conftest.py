# 8 emulated CPU devices for the shard_map/mesh tests (NOT the dry-run's 512
# — that stays local to launch/dryrun.py per the assignment).
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest

from repro import compat


@pytest.fixture(scope="session")
def mesh22():
    return compat.make_mesh((2, 2), ("data", "model"))


@pytest.fixture(scope="session")
def mesh4():
    return compat.make_mesh((4,), ("x",))


@pytest.fixture(scope="session")
def mesh8():
    return compat.make_mesh((2, 4), ("data", "model"))
