"""GPipe pipeline parallelism: schedule correctness + gradients."""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.train.pipeline import gpipe_apply, gpipe_loss

N_STAGES = 4


@pytest.fixture(scope="module")
def pipe_mesh():
    return compat.make_mesh((N_STAGES,), ("pipe",))


def _stage_fn(w, x):
    return jnp.tanh(x @ w)


def test_gpipe_matches_sequential(pipe_mesh):
    d, mb, m = 8, 4, 6
    ws = jax.random.normal(jax.random.PRNGKey(0), (N_STAGES, d, d)) * 0.5
    x = jax.random.normal(jax.random.PRNGKey(1), (m, mb, d))

    # sequential reference
    want = x
    for i in range(N_STAGES):
        want = jnp.tanh(want @ ws[i])

    f = jax.jit(compat.shard_map(
        lambda ws_, x_: gpipe_apply(_stage_fn, ws_[0], x_, "pipe"),
        mesh=pipe_mesh, in_specs=(P("pipe"), P()), out_specs=P(None),
        check_vma=False))
    # outputs valid on last stage; out_specs P(None) takes stage 0's copy —
    # collect via the loss path instead: check with explicit gather
    g = jax.jit(compat.shard_map(
        lambda ws_, x_: jax.lax.all_gather(
            gpipe_apply(_stage_fn, ws_[0], x_, "pipe"), "pipe"),
        mesh=pipe_mesh, in_specs=(P("pipe"), P()), out_specs=P(None),
        check_vma=False))
    gathered = g(ws, x)                      # (n_stages, M, mb, d)
    np.testing.assert_allclose(np.asarray(gathered[-1]), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_gpipe_grads(pipe_mesh):
    d, mb, m = 8, 4, 6
    ws = jax.random.normal(jax.random.PRNGKey(0), (N_STAGES, d, d)) * 0.5
    x = jax.random.normal(jax.random.PRNGKey(1), (m, mb, d))
    tgt = jax.random.normal(jax.random.PRNGKey(2), (m, mb, d))

    def loss_fn(outs, targets):
        return jnp.mean((outs - targets) ** 2)

    piped = jax.jit(jax.grad(lambda w: compat.shard_map(
        lambda ws_, x_, t_: gpipe_loss(_stage_fn, loss_fn, ws_[0], x_, t_,
                                       "pipe"),
        mesh=pipe_mesh, in_specs=(P("pipe"), P(), P()), out_specs=P(),
        check_vma=False)(w, x, tgt)))(ws)

    def seq_loss(w):
        h = x
        for i in range(N_STAGES):
            h = jnp.tanh(h @ w[i])
        return jnp.mean((h - tgt) ** 2)

    want = jax.grad(seq_loss)(ws)
    np.testing.assert_allclose(np.asarray(piped), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
