"""GPipe pipeline parallelism: schedule correctness + gradients."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.train.pipeline import (gpipe_apply, gpipe_forward, gpipe_island,
                                  gpipe_loss)

N_STAGES = 4


@pytest.fixture(scope="module")
def pipe_mesh():
    return compat.make_mesh((N_STAGES,), ("pipe",))


def _stage_fn(w, x):
    return jnp.tanh(x @ w)


def test_gpipe_matches_sequential(pipe_mesh):
    d, mb, m = 8, 4, 6
    ws = jax.random.normal(jax.random.PRNGKey(0), (N_STAGES, d, d)) * 0.5
    x = jax.random.normal(jax.random.PRNGKey(1), (m, mb, d))

    # sequential reference
    want = x
    for i in range(N_STAGES):
        want = jnp.tanh(want @ ws[i])

    f = jax.jit(compat.shard_map(
        lambda ws_, x_: gpipe_apply(_stage_fn, ws_[0], x_, "pipe"),
        mesh=pipe_mesh, in_specs=(P("pipe"), P()), out_specs=P(None),
        check_vma=False))
    # outputs valid on last stage; out_specs P(None) takes stage 0's copy —
    # collect via the loss path instead: check with explicit gather
    g = jax.jit(compat.shard_map(
        lambda ws_, x_: jax.lax.all_gather(
            gpipe_apply(_stage_fn, ws_[0], x_, "pipe"), "pipe"),
        mesh=pipe_mesh, in_specs=(P("pipe"), P()), out_specs=P(None),
        check_vma=False))
    gathered = g(ws, x)                      # (n_stages, M, mb, d)
    np.testing.assert_allclose(np.asarray(gathered[-1]), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_gpipe_grads(pipe_mesh):
    d, mb, m = 8, 4, 6
    ws = jax.random.normal(jax.random.PRNGKey(0), (N_STAGES, d, d)) * 0.5
    x = jax.random.normal(jax.random.PRNGKey(1), (m, mb, d))
    tgt = jax.random.normal(jax.random.PRNGKey(2), (m, mb, d))

    def loss_fn(outs, targets):
        return jnp.mean((outs - targets) ** 2)

    piped = jax.jit(jax.grad(lambda w: compat.shard_map(
        lambda ws_, x_, t_: gpipe_loss(_stage_fn, loss_fn, ws_[0], x_, t_,
                                       "pipe"),
        mesh=pipe_mesh, in_specs=(P("pipe"), P(), P()), out_specs=P(),
        check_vma=False)(w, x, tgt)))(ws)

    def seq_loss(w):
        h = x
        for i in range(N_STAGES):
            h = jnp.tanh(h @ w[i])
        return jnp.mean((h - tgt) ** 2)

    want = jax.grad(seq_loss)(ws)
    np.testing.assert_allclose(np.asarray(piped), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_gpipe_bubble_masking(pipe_mesh):
    """Only the LAST stage ever writes outputs: bubble ticks and
    intermediate-stage compute must leave every other rank's output buffer
    untouched (zeros), and the last stage's buffer must be fully populated
    with no bubble garbage for any microbatch index."""
    d, mb, m = 8, 4, 6
    ws = jax.random.normal(jax.random.PRNGKey(0), (N_STAGES, d, d)) * 0.5
    # non-zero input for every microbatch so leaked bubble compute (which
    # runs on garbage/zeros) is distinguishable from real outputs
    x = jax.random.normal(jax.random.PRNGKey(1), (m, mb, d)) + 1.0

    g = jax.jit(compat.shard_map(
        lambda ws_, x_: jax.lax.all_gather(
            gpipe_apply(_stage_fn, ws_[0], x_, "pipe"), "pipe"),
        mesh=pipe_mesh, in_specs=(P("pipe"), P()), out_specs=P(None),
        check_vma=False))
    gathered = np.asarray(g(ws, x))          # (n_stages, M, mb, d)

    # non-last stages: masked to zero on every tick
    np.testing.assert_array_equal(gathered[:-1],
                                  np.zeros_like(gathered[:-1]))
    # last stage: every microbatch slot written with the sequential result
    want = x
    for i in range(N_STAGES):
        want = jnp.tanh(want @ ws[i])
    np.testing.assert_allclose(gathered[-1], np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert np.all(np.abs(gathered[-1]) > 0)  # no bubble slot left unwritten


def test_gpipe_island_matches_sequential(pipe_mesh):
    """The unified-template GPipe entry (jit-level Island) returns the last
    stage's outputs replicated on every rank."""
    d, mb, m = 8, 4, 6
    ws = jax.random.normal(jax.random.PRNGKey(0), (N_STAGES, d, d)) * 0.5
    x = jax.random.normal(jax.random.PRNGKey(1), (m, mb, d))
    island = gpipe_island(_stage_fn, pipe_mesh, n_microbatches=m)
    assert island.fallback_reason() is None
    out = jax.jit(lambda ws, x: gpipe_forward(_stage_fn, ws, x, pipe_mesh))(
        ws, x)
    want = x
    for i in range(N_STAGES):
        want = jnp.tanh(want @ ws[i])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_gpipe_island_single_device_falls_back():
    """1-stage mesh: the template predicate routes to the sequential
    reference — same math, no pipeline collectives."""
    mesh1 = compat.make_mesh((1,), ("pipe",))
    d, mb, m = 8, 4, 3
    ws = jax.random.normal(jax.random.PRNGKey(0), (3, d, d)) * 0.5
    x = jax.random.normal(jax.random.PRNGKey(1), (m, mb, d))
    island = gpipe_island(_stage_fn, mesh1, n_microbatches=m)
    assert island.fallback_reason() == "single-device mesh"
    out = island(stage_params=ws, x_mb=x)
    want = x
    for i in range(3):
        want = jnp.tanh(want @ ws[i])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_gpipe_island_virtual_stages(pipe_mesh):
    """More stages than pipeline ranks: each rank composes its contiguous
    slab of virtual stages in order — no stage may be silently dropped."""
    d, mb, m, n_stages = 8, 4, 6, 8           # 8 stages on 4 ranks
    ws = jax.random.normal(jax.random.PRNGKey(0), (n_stages, d, d)) * 0.5
    x = jax.random.normal(jax.random.PRNGKey(1), (m, mb, d))
    out = jax.jit(lambda ws, x: gpipe_forward(_stage_fn, ws, x, pipe_mesh))(
        ws, x)
    want = x
    for i in range(n_stages):
        want = jnp.tanh(want @ ws[i])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_gpipe_island_indivisible_stages_fall_back(pipe_mesh):
    """A stage count the pipe axis doesn't divide routes to the sequential
    reference with a readable reason, not a low-level shard_map error."""
    d, mb, m, n_stages = 8, 4, 5, 6               # 6 stages on 4 ranks
    ws = jax.random.normal(jax.random.PRNGKey(0), (n_stages, d, d)) * 0.5
    x = jax.random.normal(jax.random.PRNGKey(1), (m, mb, d))
    island = gpipe_island(_stage_fn, pipe_mesh, n_microbatches=m,
                          n_stages=n_stages)
    assert "not divisible" in island.fallback_reason()
    out = gpipe_forward(_stage_fn, ws, x, pipe_mesh)
    want = x
    for i in range(n_stages):
        want = jnp.tanh(want @ ws[i])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
