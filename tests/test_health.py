"""Runtime health subsystem (repro.runtime.health + serving hooks):

* CommFaultPlan grammar: parse/reject (duplicates, contradictory payload
  faults), CommFaultEvent field validation, demotion ladders;
* HealthMonitor unit transitions: drift -> demote with hysteresis, guard
  trip / linkdown -> instant demote, re-promote after probation with
  exponential backoff, calibration table never consulted;
* acceptance (a): an injected corrupt ring hop is caught by the island
  guards, the poisoned requests are quarantined (or retried to success),
  and the surviving requests' tokens are bit-identical to a no-fault run;
* acceptance (b): a sustained injected stall triggers a backend demotion
  (visible as src=health in the live plan record), throughput recovers
  while the fault is still active, and the backend re-promotes after
  probation without flapping.
"""

import numpy as np
import pytest

from repro.configs.base import RunConfig, ServeConfig
from repro.runtime.health import (CommFaultEvent, CommFaultPlan,
                                  HealthMonitor, demotion_ladder)


def _engine(mesh_shape, serve, arch="tinyllama-1.1b", **kw):
    from repro.launch.serve import build_engine
    return build_engine(arch, reduced=True, mesh_shape=mesh_shape,
                        serve=serve, **kw)


# ---------------------------------------------------------------------------
# CommFaultPlan grammar
# ---------------------------------------------------------------------------

def test_comm_fault_plan_parse_roundtrip():
    plan = CommFaultPlan.parse("corrupt:mlp@1, stall:attn_out@3x4; "
                               "linkdown:mlp@7 bitflip:embed@2")
    assert [(e.kind, e.island, e.step, e.ticks) for e in plan.events] == [
        ("corrupt", "mlp", 1, 1), ("bitflip", "embed", 2, 1),
        ("stall", "attn_out", 3, 4), ("linkdown", "mlp", 7, 1)]
    assert [e.kind for e in plan.at(3)] == ["stall"]
    assert plan.at(4) == []


@pytest.mark.parametrize("bad", ["boom:mlp@1", "corrupt:mlp", "corrupt:@1",
                                 "corrupt:mlp@-1", "stall:mlp@2x0"])
def test_comm_fault_plan_rejects(bad):
    with pytest.raises(ValueError):
        CommFaultPlan.parse(bad)


def test_comm_fault_plan_rejects_duplicates():
    with pytest.raises(ValueError, match="duplicate fault event"):
        CommFaultPlan.parse("corrupt:mlp@1 corrupt:mlp@1")


def test_comm_fault_plan_rejects_contradictory_payload_faults():
    # two different payload corruptions of the same island at the same step
    # cannot both be applied to the jitted step
    with pytest.raises(ValueError, match="contradictory fault events"):
        CommFaultPlan.parse("corrupt:mlp@1 bitflip:mlp@1")


def test_comm_fault_plan_allows_payload_plus_stall():
    plan = CommFaultPlan.parse("corrupt:mlp@1 stall:mlp@1")
    assert len(plan.at(1)) == 2


def test_comm_fault_event_validation():
    with pytest.raises(ValueError):
        CommFaultEvent("nope", "mlp", 1)
    with pytest.raises(ValueError):
        CommFaultEvent("stall", "mlp", 1, ticks=0)
    with pytest.raises(ValueError):
        CommFaultEvent("stall", "mlp", -1)


# ---------------------------------------------------------------------------
# Demotion ladder
# ---------------------------------------------------------------------------

def test_demotion_ladder_ring_bidir():
    assert demotion_ladder("ring_bidir") == (("ring", None), ("bulk", None))


def test_demotion_ladder_ring():
    assert demotion_ladder("ring") == (("bulk", None),)


def test_demotion_ladder_chunked_drops_to_bulk_single_chunk():
    assert demotion_ladder("chunked", 4) == (("bulk", 1),)


def test_demotion_ladder_bulk_has_no_rungs():
    assert demotion_ladder("bulk") == ()


# ---------------------------------------------------------------------------
# HealthMonitor unit transitions (no mesh)
# ---------------------------------------------------------------------------

def _monitor(**kw):
    kw.setdefault("demote_after", 2)
    kw.setdefault("probation", 3)
    kw.setdefault("min_samples", 2)
    return HealthMonitor({"mlp": (("bulk", None),)}, **kw)


def test_monitor_demotes_after_consecutive_drift():
    mon = _monitor()
    for s in range(4):
        mon.record("mlp", s, 1.0)        # seed the EMA
    assert not mon.record("mlp", 4, 50.0)   # 1st flag: hysteresis holds
    assert mon.record("mlp", 5, 50.0)       # 2nd consecutive flag: demote
    assert mon.overrides() == (("mlp", "bulk", None, "health"),)
    assert ("demote", 5, "mlp", "bulk", "drift") in mon.events


def test_monitor_hysteresis_resets_on_clean_sample():
    mon = _monitor()
    for s in range(4):
        mon.record("mlp", s, 1.0)
    assert not mon.record("mlp", 4, 50.0)
    mon.record("mlp", 5, 1.0)               # clean sample resets the count
    assert not mon.record("mlp", 6, 50.0)   # back to 1st flag
    assert mon.overrides() == ()


def test_monitor_guard_trip_demotes_instantly():
    mon = _monitor()
    assert mon.guard_trip("mlp", 0)
    assert mon.rung("mlp") == ("bulk", None)
    assert ("demote", 0, "mlp", "bulk", "guard") in mon.events


def test_monitor_linkdown_and_linkup():
    mon = _monitor()
    assert mon.link_down("mlp", 2)
    assert mon.overrides() == (("mlp", "bulk", None, "health"),)
    mon.link_up("mlp", 5)
    assert ("link_up", 5, "mlp") in mon.events
    # link_up alone does not re-promote: probation still applies
    assert mon.overrides() == (("mlp", "bulk", None, "health"),)


def test_monitor_promotes_after_probation():
    mon = _monitor()
    mon.guard_trip("mlp", 0)
    changed = False
    for s in range(1, 10):
        changed = mon.record("mlp", s, 1.0)
        if changed:
            break
    assert changed and mon.overrides() == ()
    assert any(e[0] == "promote" and e[2] == "mlp" for e in mon.events)


def test_monitor_probation_backs_off_on_repeat_demotion():
    mon = _monitor()
    mon.guard_trip("mlp", 0)
    assert mon._probation_for(mon._state["mlp"]) == 3
    # promote, then demote again: probation doubles
    for s in range(1, 10):
        if mon.record("mlp", s, 1.0):
            break
    mon.guard_trip("mlp", 20)
    assert mon._probation_for(mon._state["mlp"]) == 6


def test_monitor_ignores_unmonitored_islands():
    mon = _monitor()
    assert not mon.record("embed", 0, 100.0)
    assert not mon.guard_trip("embed", 0)
    assert mon.overrides() == ()


# ---------------------------------------------------------------------------
# Acceptance (a): corrupt ring hop -> guards trip, poisoned requests
# quarantined, survivors bit-identical to a no-fault run
# ---------------------------------------------------------------------------

_PROMPTS = [tuple(range(1, 6)), tuple(range(2, 7)),
            tuple(range(3, 8)), tuple(range(4, 9))]


def _ref_tokens(mesh_shape=(1, 8)):
    serve = ServeConfig(max_batch=4, prefill_batch=2, bucket_edges=(8,),
                        max_new_tokens=4)
    eng = _engine(mesh_shape, serve,
                  run_overrides={"comm_backend": "ring"})
    done = eng.run(list(_PROMPTS))
    return {c.rid: tuple(c.tokens) for c in done}


def test_corrupt_hop_quarantines_and_survivors_bit_identical():
    ref = _ref_tokens()
    serve = ServeConfig(max_batch=4, prefill_batch=2, bucket_edges=(8,),
                        max_new_tokens=4, max_retries=0)
    eng = _engine((1, 8), serve,
                  run_overrides={"comm_backend": "ring",
                                 "island_guards": True},
                  comm_faults="corrupt:mlp@1")
    done = eng.run(list(_PROMPTS))
    # the first prefill group (rids 0,1) hits the corrupted step
    assert set(eng.quarantined) == {0, 1}
    for rec in eng.quarantined.values():
        assert rec["reason"] == "prefill_nonfinite"
    # the guards saw the NaN at the faulted island's boundary
    tripped = {e[2] for e in eng.events if e[0] == "guard_trip"}
    assert "mlp" in tripped
    # survivors: tokens bit-identical to the no-fault run
    got = {c.rid: tuple(c.tokens) for c in done}
    assert set(got) == {2, 3}
    for rid in got:
        assert got[rid] == ref[rid], rid
    assert eng.stats()["quarantined"] == 2


def test_corrupt_hop_retry_recovers_all_requests():
    ref = _ref_tokens()
    serve = ServeConfig(max_batch=4, prefill_batch=2, bucket_edges=(8,),
                        max_new_tokens=4, max_retries=1)
    eng = _engine((1, 8), serve,
                  run_overrides={"comm_backend": "ring",
                                 "island_guards": True},
                  comm_faults="corrupt:mlp@1")
    done = eng.run(list(_PROMPTS))
    # the fault is one step long: the retried prefill succeeds
    assert eng._retries == {0: 1, 1: 1}
    assert not eng.quarantined
    got = {c.rid: tuple(c.tokens) for c in done}
    assert got == ref


# ---------------------------------------------------------------------------
# Acceptance (b): sustained stall -> demotion via the override seam,
# recovery, re-promotion after probation, no flapping
# ---------------------------------------------------------------------------

def test_stall_demotes_recovers_and_promotes_without_flapping():
    serve = ServeConfig(max_batch=4, prefill_batch=2, bucket_edges=(8,),
                        max_new_tokens=1, health_monitor=True,
                        health_demote_after=2, health_probation=4)
    # stall_dt dwarfs the compile-time-seeded EMA so the drift detector
    # flags it; ticks=4 outlasts the demote_after hysteresis
    plan = CommFaultPlan(events=(
        CommFaultEvent("stall", "mlp", 3, ticks=4, stall_dt=50.0),))
    eng = _engine((1, 8), serve,
                  run_overrides={"comm_backend": "ring"},
                  comm_faults=plan)
    rng = np.random.RandomState(0)
    for _ in range(20):
        eng.submit(tuple(int(t) for t in
                         rng.randint(1, eng.cfg.vocab_size, size=5)))
    hov_by_step = {}
    dts = {}
    while eng.pending:
        before = len(eng.step_times)
        eng.step()
        hov_by_step[eng.step_no] = eng.plan_record()["health_overrides"]
        if len(eng.step_times) > before:
            dts[eng.step_no] = eng.step_times[-1]

    demotes = [e for e in eng.health.events if e[0] == "demote"]
    promotes = [e for e in eng.health.events if e[0] == "promote"]
    # exactly one demotion and one re-promotion: no flapping
    assert len(demotes) == 1 and len(promotes) == 1
    d = demotes[0]
    assert d[2] == "mlp" and d[3] == "bulk" and d[4] == "drift"
    demote_step, promote_step = demotes[0][1], promotes[0][1]
    assert demote_step < promote_step

    # the demotion is visible as src=health in the live plan record
    assert hov_by_step[demote_step] == [["mlp", "bulk", None, "health"]]
    assert hov_by_step[promote_step] == []

    # throughput recovers while the fault is still active: the first
    # post-demotion step no longer eats the stall
    stalled = [dt for s, dt in dts.items() if s <= demote_step and dt >= 50.0]
    assert stalled, "stall never landed before the demotion"
    recovered = [dt for s, dt in dts.items()
                 if demote_step < s <= demote_step + 2]
    assert recovered and max(recovered) < 5.0

    # probation honoured: clean samples between demote and promote
    assert promote_step - demote_step >= serve.health_probation
    assert eng.stats()["health_demotions"] == 1
