"""Unified PK island template (repro.core.template): fallback predicate vs
dense reference numerics, trace-free plan() reports, and the guard that no
PK-overlap module calls compat.shard_map outside the template."""

import ast
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.core.template import Comm, Gather, Island, render_plans
from repro.models import layers as L
from repro.models.sharding import ShardingRules

SRC = os.path.join(os.path.dirname(__file__), "..", "src", "repro")


# ---------------------------------------------------------------------------
# Island mechanics on a raw mesh
# ---------------------------------------------------------------------------

def _sum_island(mesh, **kw):
    return Island(
        "sum", mesh=mesh, axis="x",
        inputs={"x": P("x")}, out_specs=P(),
        body=lambda ctx, x: jax.lax.psum(jnp.sum(x, axis=0), "x"),
        reference=lambda x: jnp.sum(x, axis=0),
        comm=Comm("psum", backend="bulk"), **kw)


def test_island_runs_and_matches_reference(mesh4):
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
    island = _sum_island(mesh4)
    assert island.fallback_reason() is None
    np.testing.assert_allclose(np.asarray(island(x=x)),
                               np.asarray(jnp.sum(x, axis=0)), rtol=1e-6)


def test_island_single_device_mesh_falls_back():
    mesh1 = compat.make_mesh((1,), ("x",))
    x = jnp.ones((8, 4))
    island = _sum_island(mesh1)
    assert island.fallback_reason() == "single-device mesh"
    plan = island.plan()
    assert plan.fallback and "single-device" in plan.reason
    np.testing.assert_allclose(np.asarray(island(x=x)),
                               np.asarray(jnp.sum(x, axis=0)))


def test_island_no_mesh_falls_back():
    island = Island("bare", reference=lambda x: x + 1)
    assert "no mesh" in island.fallback_reason()
    np.testing.assert_allclose(np.asarray(island(x=jnp.zeros((2,)))),
                               np.ones((2,)))


def test_island_divisibility_falls_back(mesh4):
    island = _sum_island(mesh4, divisible=((6, "x"),))
    assert "not divisible" in island.fallback_reason()
    x = jnp.ones((8, 4))
    np.testing.assert_allclose(np.asarray(island(x=x)),
                               np.asarray(jnp.sum(x, axis=0)))


def test_island_disabled_and_reference_mode(mesh4):
    assert _sum_island(mesh4, enable=False).fallback_reason() == \
        "disabled by RunConfig"
    run = RunConfig(reference_mode=True)
    assert _sum_island(mesh4, run=run).fallback_reason() == \
        "RunConfig.reference_mode"


def test_island_without_reference_raises_on_fallback():
    island = Island("noref", inputs={}, out_specs=P())
    with pytest.raises(ValueError, match="no dense reference"):
        island()


def test_island_rejects_wrong_inputs(mesh4):
    with pytest.raises(TypeError, match="declared inputs"):
        _sum_island(mesh4)(y=jnp.ones((8, 4)))


def test_island_fsdp_gather_inside(mesh4):
    """gathers= declaration reproduces the hand-written ZeRO-3 all-gather:
    w enters row-sharded over "x" and is gathered back inside the island."""
    k, n = 16, 8
    x = jax.random.normal(jax.random.PRNGKey(0), (8, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    island = Island(
        "gemm", mesh=mesh4, axis="x", gather_axes="x",
        inputs={"x": P(), "w": P("x", None)}, out_specs=P(),
        body=lambda ctx, x, w: x @ w,
        gathers={"w": Gather(dim=0, size=k)})
    out = island(x=x, w=w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Model-stack fallback predicate: numerics vs the dense reference (4-dev mesh)
# ---------------------------------------------------------------------------

def _mlp_setup(mesh, d_ff=128, pk_overlap=True):
    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              d_ff=d_ff)
    run = RunConfig(dp_axes=("data",), fsdp=False, pk_overlap=pk_overlap)
    rules = ShardingRules(mesh, run)
    d = cfg.d_model
    p = {"w1": jax.random.normal(jax.random.PRNGKey(1), (d, d_ff),
                                 jnp.float32) * 0.1,
         "w3": jax.random.normal(jax.random.PRNGKey(2), (d, d_ff),
                                 jnp.float32) * 0.1,
         "w2": jax.random.normal(jax.random.PRNGKey(3), (d_ff, d),
                                 jnp.float32) * 0.1}
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, d), jnp.float32)
    return cfg, run, rules, p, x


def _dense_mlp(p, x, cfg):
    act = L.get_act(cfg.act)
    h = act(jnp.einsum("bsd,df->bsf", x, p["w1"])) * \
        jnp.einsum("bsd,df->bsf", x, p["w3"])
    return jnp.einsum("bsf,fd->bsd", h, p["w2"])


def test_mlp_island_active_matches_dense(mesh22):
    cfg, run, rules, p, x = _mlp_setup(mesh22)
    island = L.mlp_island(cfg, run, rules, 4, 8)
    assert island.fallback_reason() is None
    out = jax.jit(lambda p, x: L.mlp_block(p, x, cfg, run, rules))(p, x)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(_dense_mlp(p, x, cfg)),
                               rtol=2e-4, atol=2e-4)


def test_mlp_island_tp_not_dividing_falls_back(mesh22):
    cfg, run, rules, p, x = _mlp_setup(mesh22, d_ff=129)   # 129 % 2 != 0
    island = L.mlp_island(cfg, run, rules, 4, 8)
    assert "not divisible" in island.fallback_reason()
    out = jax.jit(lambda p, x: L.mlp_block(p, x, cfg, run, rules))(p, x)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(_dense_mlp(p, x, cfg)),
                               rtol=2e-4, atol=2e-4)


def test_mlp_island_one_device_mesh_falls_back():
    mesh1 = compat.make_mesh((1, 1), ("data", "model"))
    cfg, run, rules, p, x = _mlp_setup(mesh1)
    island = L.mlp_island(cfg, run, rules, 4, 8)
    assert island.fallback_reason() == "single-device mesh"
    out = jax.jit(lambda p, x: L.mlp_block(p, x, cfg, run, rules))(p, x)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(_dense_mlp(p, x, cfg)),
                               rtol=2e-4, atol=2e-4)


def test_mlp_island_pk_overlap_off_falls_back(mesh22):
    cfg, run, rules, p, x = _mlp_setup(mesh22, pk_overlap=False)
    island = L.mlp_island(cfg, run, rules, 4, 8)
    assert island.fallback_reason() == "disabled by RunConfig"
    out = jax.jit(lambda p, x: L.mlp_block(p, x, cfg, run, rules))(p, x)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(_dense_mlp(p, x, cfg)),
                               rtol=2e-4, atol=2e-4)


def test_reference_mode_forces_dense_same_loss(mesh22):
    """reference_mode routes EVERY island dense and must not change the
    (dense-equivalent) forward math."""
    from repro.models import forward_train, init_params, param_template
    from repro.models.transformer import param_specs
    from jax.sharding import NamedSharding
    cfg = get_config("tinyllama-1.1b").reduced()
    losses = {}
    for ref in (False, True):
        run = RunConfig(dp_axes=("data",), fsdp=True, reference_mode=ref)
        rules = ShardingRules(mesh22, run)
        tmpl = param_template(cfg, run, rules)
        params = init_params(tmpl, jax.random.PRNGKey(0), cfg.d_model)
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh22, s)),
            params, param_specs(tmpl))
        batch = {"tokens": jnp.zeros((4, 32), jnp.int32),
                 "targets": jnp.ones((4, 32), jnp.int32),
                 "weights": jnp.ones((4, 32), jnp.float32)}
        loss, _ = jax.jit(lambda p, bt, run=run, rules=rules:
                          forward_train(p, bt, cfg, run, rules))(params, batch)
        losses[ref] = float(loss)
    assert abs(losses[True] - losses[False]) < 2e-2, losses


# ---------------------------------------------------------------------------
# plan(): the whole forward pass's overlap schedule, trace-free
# ---------------------------------------------------------------------------

def test_island_plans_cover_forward_pass(mesh22):
    cfg = get_config("tinyllama-1.1b").reduced()
    run = RunConfig(dp_axes=("data",), fsdp=True, pk_attn_out_island=True)
    rules = ShardingRules(mesh22, run)
    plans = L.island_plans(cfg, run, rules, batch=4, seq=64)
    by_name = {p.island: p for p in plans}
    for name in ("embed", "attn_ring", "attn_out", "decode_attn", "mlp",
                 "lm_loss"):
        assert name in by_name, sorted(by_name)
    mlp = by_name["mlp"]
    assert not mlp.fallback
    assert mlp.op == "matmul_all_reduce"
    assert mlp.backend in ("bulk", "ring", "ring_bidir", "fused")
    assert mlp.n_chunks >= 1
    assert mlp.hidden_fraction is not None
    assert by_name["decode_attn"].backend == "bulk"     # logsumexp merge
    # render_plans is printable and one line per island (+2 header lines)
    txt = render_plans(plans)
    assert len(txt.splitlines()) == len(plans) + 2


def test_island_plans_moe_and_ulysses(mesh22):
    cfg = get_config("moonshot-v1-16b-a3b").reduced()
    run = RunConfig(dp_axes=("data",), fsdp=True, sp_attention="ulysses",
                    moe_chunks=2)
    rules = ShardingRules(mesh22, run)
    by_name = {p.island: p for p in
               L.island_plans(cfg, run, rules, batch=4, seq=64)}
    assert "moe" in by_name and "attn_ulysses" in by_name
    assert by_name["moe"].op == "psum"
    assert by_name["attn_ulysses"].op == "all_to_all"


def test_island_plans_no_mesh_all_fallback():
    cfg = get_config("tinyllama-1.1b").reduced()
    run = RunConfig()
    plans = L.island_plans(cfg, run, None, batch=4, seq=64)
    assert plans and all(p.fallback for p in plans)


def test_gpipe_island_plan():
    from repro.train.pipeline import gpipe_island
    mesh = compat.make_mesh((4,), ("pipe",))
    island = gpipe_island(lambda w, x: x, mesh, n_microbatches=6)
    plan = island.plan()
    assert not plan.fallback
    assert plan.op == "ring_shift"
    assert plan.n_chunks == 6 + 4 - 1          # M + S - 1 handoff ticks


def test_plan_mirrors_runtime_m_divisibility_guard(mesh4):
    """The trace-free plan must never report a ring schedule the runtime
    dispatch would refuse: RS/AR rings need m divisible by the axis size
    (CommContext.auto() returns bulk there), so plan() must too."""
    big = Comm("matmul_all_reduce", m=9, n=4096, k=4096)   # 9 % 4 != 0
    island = Island("odd_m", mesh=mesh4, axis="x", inputs={"x": P()},
                    out_specs=P(), body=lambda ctx, x: x, comm=big)
    plan = island.plan()
    assert plan.backend == "bulk"
    assert "not divisible" in plan.reason
    # same shape but divisible m: the policy may overlap again
    ok = Island("even_m", mesh=mesh4, axis="x", inputs={"x": P()},
                out_specs=P(), body=lambda ctx, x: x,
                comm=Comm("matmul_all_reduce", m=4096, n=4096, k=4096))
    assert ok.plan().backend in ("ring", "ring_bidir", "bulk", "fused")
    # a context pin degrades exactly like the runtime _shape_guard
    pinned = Island("odd_m_pin", mesh=mesh4, axis="x", inputs={"x": P()},
                    out_specs=P(), body=lambda ctx, x: x, comm=big,
                    ctx_kwargs={"backend": "ring"})
    assert pinned.plan().backend == "bulk"


def test_collectives_stub_preserves_attribute_protocol():
    """hasattr probes and protocol attributes must not explode with
    ImportError; only the known moved names carry the migration message."""
    import repro.core.collectives as stub
    assert not hasattr(stub, "definitely_not_a_collective")
    with pytest.raises(AttributeError):
        stub.not_moved_name
    with pytest.raises(ImportError, match="repro.core.comms"):
        stub.pk_psum_ring


def test_plan_measured_per_island_dispatch(mesh22, tmp_path):
    """Acceptance: on a calibrated mesh, plan() reports MEASURED hidden
    fraction and chunk count for the MLP and attention out-projection
    islands — and island-keyed rows let the two resolve to different
    backends at the SAME (m, n, k)."""
    from repro.core import autotune

    # d_ff == n_heads*head_dim so both islands' GEMM+AR land on the same
    # (m, n, k) = (b_loc*s, d, 32) coordinates with tp=2
    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              d_ff=64)
    b, s = 4, 8
    m, n, k = 16, 64, 32                   # b_loc=2 -> m=16; k=64/2
    live = autotune.live_fingerprint("tpu_v5e", mesh22)
    mlp_key = autotune.island_key("mlp", "matmul_all_reduce", 2)
    attn_key = autotune.island_key("attn_out", "matmul_all_reduce", 2)

    def rows(key, us_by_backend, n_chunks=1):
        return [{"op": "matmul_all_reduce", "backend": be, "axis_size": 2,
                 "m": m, "n": n, "k": k, "dtype_bytes": 2,
                 "n_chunks": n_chunks, "island": key, "us": us}
                for be, us in us_by_backend.items()]

    table = autotune.CalibrationTable(
        fingerprint=live,
        corrections={"ici_bandwidth": 1e8, "remote_sync_s": 1e-6,
                     "gemm_efficiency": 1e-4, "kernel_launch_s": 1e-6},
        measurements=(rows(mlp_key, {"bulk": 100.0, "ring": 10.0})
                      + rows(mlp_key, {"ring": 4.0}, n_chunks=2)
                      + rows(attn_key, {"bulk": 5.0, "ring": 500.0})))
    path = table.save(tmp_path / "island-cal.json")
    autotune.clear_caches()

    run = RunConfig(dp_axes=("data",), fsdp=False, pk_attn_out_island=True,
                    comm_policy="measured", calibration_path=str(path))
    rules = ShardingRules(mesh22, run)
    mlp = L.mlp_island(cfg, run, rules, b, s).plan()
    attn = L.attn_out_island(cfg, run, rules, b, s).plan()

    # same shape, different measured outcome per island
    assert mlp.backend == "ring"
    assert attn.backend == "bulk"
    assert mlp.source == "measured" and attn.source == "measured"
    # measured chunk count: ring@2 chunks (4us) beat ring@1 (10us) ->
    # 2 ring steps x 2 sub-chunks
    assert mlp.n_chunks == 4
    # measured hidden fraction: (100 - 4) us saved vs the priced t_comm,
    # clamped to 1.0 — NOT the analytic prediction
    assert mlp.hidden_fraction == pytest.approx(1.0)
    assert attn.hidden_fraction == 0.0      # bulk, by measurement
    autotune.clear_caches()


def test_plan_analytic_without_table(mesh22, tmp_path, monkeypatch):
    """No calibration anywhere -> the plan stays an honest prediction."""
    from repro.core import autotune
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "empty"))
    monkeypatch.setattr(autotune, "_SEED_DIR", tmp_path / "no-seeds")
    autotune.clear_caches()
    cfg = get_config("tinyllama-1.1b").reduced()
    run = RunConfig(dp_axes=("data",), fsdp=False, comm_policy="auto")
    rules = ShardingRules(mesh22, run)
    plan = L.mlp_island(cfg, run, rules, 4, 64).plan()
    assert plan.source == "analytic"
    autotune.clear_caches()


def test_ulysses_chunks_knob_reaches_island(mesh22):
    """RunConfig.ulysses_chunks threads through sp_attention_island: the
    declared Comm reports the chunked a2a and the island still matches the
    dense reference numerically."""
    cfg = get_config("tinyllama-1.1b").reduced()
    run = RunConfig(dp_axes=("data",), fsdp=False, sp_attention="ulysses",
                    ulysses_chunks=2)
    rules = ShardingRules(mesh22, run)
    plans = {p.island: p for p in
             L.island_plans(cfg, run, rules, batch=4, seq=64)}
    ul = plans["attn_ulysses"]
    assert ul.op == "all_to_all"
    assert ul.backend == "chunked" and ul.n_chunks == 2

    # numerics: chunked a2a attention == the dense reference
    b, s, hq, hkv, hd = 2, 16, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jax.random.normal(jax.random.PRNGKey(0), (b, hq, s, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, s, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s, hd))

    def reference(q, k, v):
        return L._full_attention(q, k, v, causal=True, window=None)

    island = L.sp_attention_island(cfg, run, rules, b, s, causal=True,
                                   reference=reference)
    assert island.fallback_reason() is None
    got = island(q=q, k=k, v=v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(reference(q, k, v)),
                               rtol=2e-4, atol=2e-4)


def test_comm_n_chunks_reaches_runtime_context(mesh4):
    """A declared Comm.n_chunks must be the schedule the BODY runs, not just
    what plan() reports: make_context() threads it as the context's chunk
    default (RunConfig.comm_chunks, the global A/B knob, still wins)."""
    comm = Comm("matmul_all_reduce", m=4096, n=4096, k=4096, n_chunks=4)
    island = Island("declared", mesh=mesh4, axis="x", inputs={"x": P()},
                    out_specs=P(), body=lambda ctx, x: x, comm=comm)
    ctx = island.make_context()
    assert ctx.chunks == 4
    sched = ctx.gemm_chunk_schedule("matmul_all_reduce", 4096, 4096, 4096,
                                    backend="ring")
    assert sched.n_chunks == 4 and sched.source == "explicit"
    # plan() reports the same schedule (ring steps x declared sub-chunks)
    plan = island.plan()
    if plan.backend in ("ring", "ring_bidir"):
        assert plan.n_chunks == island.axis_size * 4
    # the global force beats the declaration
    run = RunConfig(comm_chunks=2)
    forced = Island("forced", mesh=mesh4, axis="x", run=run,
                    inputs={"x": P()}, out_specs=P(),
                    body=lambda ctx, x: x, comm=comm)
    assert forced.make_context().chunks == 2
    # non-GEMM Comm declarations never leak into the GEMM chunk default
    a2a = Island("a2a", mesh=mesh4, axis="x", inputs={"x": P()},
                 out_specs=P(), body=lambda ctx, x: x,
                 comm=Comm("all_to_all", n_chunks=8, payload_bytes=1.0))
    assert a2a.make_context().chunks is None


def test_mlp_plan_respects_backend_pin(mesh22):
    cfg = get_config("tinyllama-1.1b").reduced()
    run = RunConfig(dp_axes=("data",), fsdp=False, comm_backend="ring")
    rules = ShardingRules(mesh22, run)
    plan = L.mlp_island(cfg, run, rules, 4, 64).plan()
    assert plan.backend == "ring"


# ---------------------------------------------------------------------------
# Guard: compat.shard_map is only called from core/template.py in the
# PK-overlap paths (core/autotune.py is the documented exception: its
# shard_map uses are the calibration micro-bench harness, not overlap paths;
# compat.py is the shim definition site).
# ---------------------------------------------------------------------------

_SHARD_MAP_ALLOWED = {
    os.path.normpath(os.path.join(SRC, "compat.py")),
    os.path.normpath(os.path.join(SRC, "core", "template.py")),
    os.path.normpath(os.path.join(SRC, "core", "autotune.py")),
}


def _shard_map_calls(path):
    """Line numbers of ANY reachable use of shard_map: calls, bare
    attribute/name loads (partial(compat.shard_map, ...)), and aliased
    imports (from repro.compat import shard_map as sm) — so the guard can't
    be bypassed by renaming."""
    tree = ast.parse(open(path).read(), filename=path)
    hits = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr == "shard_map":
            hits.append(node.lineno)
        elif isinstance(node, ast.Name) and node.id == "shard_map":
            hits.append(node.lineno)
        elif isinstance(node, ast.ImportFrom):
            for alias in node.names:
                if alias.name == "shard_map":
                    hits.append(node.lineno)
    return sorted(set(hits))


def test_no_shard_map_outside_template():
    violations = []
    for root, _, files in os.walk(SRC):
        for f in sorted(files):
            if not f.endswith(".py"):
                continue
            path = os.path.normpath(os.path.join(root, f))
            if path in _SHARD_MAP_ALLOWED:
                continue
            for lineno in _shard_map_calls(path):
                violations.append(f"{os.path.relpath(path, SRC)}:{lineno}")
    assert not violations, (
        "PK-overlap modules must declare islands via "
        "repro.core.template.Island instead of calling compat.shard_map "
        f"directly; violations: {violations}")


def test_moe_reference_mode_tp_ff_split_exact(mesh8):
    """reference_mode must work (and agree exactly, given capacity covering
    every token) for an EP×TP split with tp_ff > 1: the dense reference
    reconstructs the device-major (M, E_loc, d, ff/tp_ff) layout."""
    import dataclasses as dc
    from repro.models.layers import moe_block
    cfg = dc.replace(get_config("moonshot-v1-16b-a3b").reduced(),
                     n_experts=2, top_k=1, capacity_factor=64.0)
    # mesh8 = (2, 4): tp size 4, E=2 -> ep=2, tp_ff=2
    run = RunConfig(dp_axes=("data",), fsdp=False)
    rules = ShardingRules(mesh8, run)
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    m, tp_ff = 4, 2
    e_loc, ff_loc = e // (m // tp_ff), ff // tp_ff
    key = jax.random.PRNGKey(0)
    p = {"router": jax.random.normal(key, (d, e), jnp.float32),
         "w1": jax.random.normal(jax.random.PRNGKey(1),
                                 (m, e_loc, d, ff_loc), jnp.float32) * 0.1,
         "w3": jax.random.normal(jax.random.PRNGKey(2),
                                 (m, e_loc, d, ff_loc), jnp.float32) * 0.1,
         "w2": jax.random.normal(jax.random.PRNGKey(3),
                                 (m, e_loc, ff_loc, d), jnp.float32) * 0.1}
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 8, d), jnp.float32)

    out_pk, _ = jax.jit(lambda p, x: moe_block(p, x, cfg, run, rules))(p, x)
    ref_run = dataclasses.replace(run, reference_mode=True)
    out_ref, _ = jax.jit(lambda p, x: moe_block(p, x, cfg, ref_run, rules))(
        p, x)
    # capacity_factor=64 covers every routed token -> paths agree exactly
    np.testing.assert_allclose(np.asarray(out_pk), np.asarray(out_ref),
                               rtol=1e-4, atol=1e-4)
