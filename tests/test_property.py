"""Hypothesis property tests on system invariants.

The serving-equivalence fuzz (``-m slow``; excluded from tier-1 by the
pyproject ``addopts``) randomizes whole request traces — prompt lengths,
max_new_tokens, submit times — and checks paged continuous, slab
continuous, and sequential one-at-a-time processing are token-identical.
A violation shrinks to a minimal failing trace."""

import dataclasses
import functools
import math

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import costmodel as cm
from repro.core.moe import capacity, ep_tp_split
from repro.core.moe_layout import dm_to_logical, logical_to_dm
from repro.optim.compress import _quant_dequant
from repro.roofline.hlo import collective_bytes

settings.register_profile("ci", max_examples=50, deadline=None)
settings.load_profile("ci")


@given(e=st.sampled_from([1, 2, 4, 8, 16, 64, 256]),
       m=st.sampled_from([1, 2, 4, 8, 16]))
def test_ep_tp_split_invariants(e, m):
    ep, tp = ep_tp_split(e, m)
    assert ep * tp == m
    assert e % ep == 0


@given(t=st.integers(1, 10_000), e=st.sampled_from([2, 8, 64]),
       k=st.integers(1, 8), cf=st.floats(0.5, 4.0))
def test_capacity_positive_and_sufficient(t, e, k, cf):
    c = capacity(t, e, k, cf)
    assert c >= 1
    assert c * e >= t * k * cf - e  # covers the requested fraction


@given(e=st.sampled_from([4, 8, 16, 64]), m=st.sampled_from([1, 2, 4, 8, 16]),
       d=st.sampled_from([4, 8]), ff=st.sampled_from([16, 32]))
def test_moe_layout_roundtrip_property(e, m, d, ff):
    rng = np.random.default_rng(0)
    logical = rng.normal(size=(e, d, ff)).astype(np.float32)
    dm = logical_to_dm(logical, m)
    ep, tp = ep_tp_split(e, m)
    assert dm.shape == (m, e // ep, d, ff // tp)
    np.testing.assert_array_equal(dm_to_logical(dm, e), logical)


@given(n=st.integers(2, 64), bytes_=st.floats(1.0, 1e9))
def test_ring_collective_bytes_relations(n, bytes_):
    ag = cm.ring_collective_bytes(bytes_, n, "all_gather")
    rs = cm.ring_collective_bytes(bytes_, n, "reduce_scatter")
    ar = cm.ring_collective_bytes(bytes_, n, "all_reduce")
    assert ag == rs
    assert abs(ar - (ag + rs)) < 1e-6        # AR = RS + AG
    assert cm.ring_collective_bytes(bytes_, 1, "all_reduce") == 0.0


@given(s=st.sampled_from([1, 2, 4]), links=st.sampled_from([1, 2]))
def test_hiding_threshold_monotone(s, links):
    """Paper §3.1.3: K* grows with dtype size, shrinks with bandwidth."""
    k1 = cm.hiding_threshold_k(s, cm.TPU_V5E, links=links)
    k2 = cm.hiding_threshold_k(2 * s, cm.TPU_V5E, links=links)
    assert k2 == 2 * k1
    assert cm.hiding_threshold_k(s, cm.TPU_V5E, links=2 * links) < k1
    # paper's H100 number: K ~ 2197 for bf16
    assert cm.hiding_threshold_k(2, cm.H100_SXM) == 2198


@given(x=st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1,
                  max_size=300))
def test_quant_dequant_bounded(x):
    arr = jnp.asarray(x, jnp.float32)
    deq = _quant_dequant(arr)
    # per-block scale bound: |err| <= max|block|/254 + eps
    assert float(jnp.abs(deq - arr).max()) <= \
        float(jnp.abs(arr).max()) / 127.0 * 0.51 + 1e-5


@given(n=st.sampled_from([2, 4, 8, 16]),
       dims=st.lists(st.integers(1, 64), min_size=1, max_size=3),
       kind=st.sampled_from(["all-gather", "all-reduce", "reduce-scatter",
                             "all-to-all", "collective-permute"]))
def test_hlo_parser_property(n, dims, kind):
    shape = ",".join(map(str, dims))
    elems = math.prod(dims)
    groups = "{{" + ",".join(map(str, range(n))) + "}}"
    line = (f"  %x.1 = bf16[{shape}]{{0}} {kind}(%y), "
            f"replica_groups={groups}, dimensions={{0}}")
    stats = collective_bytes(line)
    out_b = elems * 2
    expected = {"all-gather": out_b * (n - 1) / n,
                "all-reduce": 2 * out_b * (n - 1) / n,
                "reduce-scatter": out_b * (n - 1),
                "all-to-all": out_b * (n - 1) / n,
                "collective-permute": out_b}[kind]
    assert abs(stats.total_bytes - expected) < 1e-6
    assert stats.op_count == 1


@given(m=st.sampled_from([256, 4096]), k=st.sampled_from([512, 8192]),
       nn=st.sampled_from([256, 2048]), axis=st.sampled_from([4, 16]))
def test_schedule_policy_sane(m, k, nn, axis):
    from repro.core.schedule import choose_gemm_collective
    pol = choose_gemm_collective(m, nn, k, axis_size=axis,
                                 kind="reduce_scatter")
    assert 0.0 <= pol.hidden_fraction <= 1.0
    if pol.enabled:
        assert pol.n_chunks >= 1


# ---------------------------------------------------------------------------
# Randomized-trace serving equivalence (slow: excluded from tier-1 -x -q)
# ---------------------------------------------------------------------------

_FUZZ_SERVE = None          # built lazily so collection stays import-cheap


def _fuzz_engines():
    """One slab + one paged + one sequential engine, shared across fuzz
    examples (jit caches are the expensive part; engine state carries over
    harmlessly because completions are keyed by fresh rids and the paged
    prefix registry may only ever *reuse* bit-identical pages)."""
    global _FUZZ_SERVE
    from repro.configs.base import ServeConfig
    from repro.launch.serve import build_engine
    _FUZZ_SERVE = ServeConfig(max_batch=4, prefill_batch=2,
                              bucket_edges=(8, 16), max_new_tokens=4)
    paged = dataclasses.replace(_FUZZ_SERVE, cache_layout="paged",
                                page_size=4, prefill_chunk=8)
    mk = functools.partial(build_engine, "tinyllama-1.1b", reduced=True)
    return mk(serve=_FUZZ_SERVE), mk(serve=paged), mk(serve=_FUZZ_SERVE)


@functools.lru_cache(maxsize=1)
def _fuzz_engines_cached():
    return _fuzz_engines()


@st.composite
def _traces(draw):
    """(prompt, max_new, submit_step) triples: mixed buckets, staggered
    arrival — the shapes continuous batching actually reorders around."""
    n = draw(st.integers(1, 5))
    out = []
    for _ in range(n):
        plen = draw(st.integers(2, 16))
        prompt = tuple(draw(st.integers(0, 63)) for _ in range(plen))
        max_new = draw(st.integers(1, 4))
        submit_step = draw(st.integers(0, 6))
        out.append((prompt, max_new, submit_step))
    return out


def _drive(eng, trace):
    """Feed the trace with its staggered submit times through the engine's
    cooperative API; returns index -> generated tokens."""
    if eng.pending:                       # debris from a failed example
        eng.run()
    rid_of = {}
    waiting = sorted(range(len(trace)), key=lambda i: (trace[i][2], i))
    step = 0
    while waiting or eng.pending:
        while waiting and trace[waiting[0]][2] <= step:
            i = waiting.pop(0)
            rid_of[i] = eng.submit(trace[i][0], trace[i][1])
        eng.run(step_budget=1)
        step += 1
        assert step < 500, "fuzz trace did not drain"
    return {i: tuple(eng.completions[r].tokens) for i, r in rid_of.items()}


@pytest.mark.slow
@settings(max_examples=12, deadline=None)
@given(trace=_traces())
def test_random_trace_slab_paged_sequential_identical(trace):
    slab, paged, seq = _fuzz_engines_cached()
    got_slab = _drive(slab, trace)
    got_paged = _drive(paged, trace)
    # sequential reference: one request at a time, in submit order
    got_seq = {}
    for i, (prompt, max_new, _) in enumerate(trace):
        seq.submit(prompt, max_new)
        got_seq[i] = tuple(seq.run()[0].tokens)
    assert got_slab == got_seq
    assert got_paged == got_seq
