"""Empirical autotuner: calibration round-trip, measured-policy dispatch,
graceful analytic fallback, CLI, and the bench-artifact schema."""

import dataclasses
import importlib.util
import json
import sys
import warnings
from pathlib import Path

import pytest

# the harness (benchmarks/) lives next to src/, not inside it
sys.path.insert(0, str(Path(__file__).parent.parent))

from repro.core import autotune
from repro.core.comms import CommContext

N = 4


@pytest.fixture(scope="module")
def table(mesh4):
    """One real (tiny-grid) calibration of the 4-device CPU mesh."""
    return autotune.calibrate(mesh=mesh4, grid="tiny", reps=1)


@pytest.fixture(autouse=True)
def _fresh_caches():
    autotune.clear_caches()
    yield
    autotune.clear_caches()


def _synthetic(fingerprint, rows, **corr):
    corrections = {"ici_bandwidth": 1e8, "remote_sync_s": 1e-4,
                   "gemm_efficiency": 1e-4, "kernel_launch_s": 1e-5}
    corrections.update(corr)
    return autotune.CalibrationTable(fingerprint=fingerprint,
                                     corrections=corrections,
                                     measurements=rows)


def _rows(op, us_by_backend, m, n, k, axis_size=N):
    return [{"op": op, "backend": be, "axis_size": axis_size,
             "m": m, "n": n, "k": k, "us": us}
            for be, us in us_by_backend.items()]


# ---------------------------------------------------------------------------
# Calibration + persistence
# ---------------------------------------------------------------------------

def test_calibrate_covers_registered_backends(table, mesh4):
    cov = table.ops_covered()
    for op in ("all_gather_matmul", "matmul_reduce_scatter",
               "matmul_all_reduce", "psum"):
        assert cov.get(op), f"no measurements for {op}"
    backends = {(r["op"], r["backend"]) for r in table.measurements}
    assert ("all_gather_matmul", "bulk") in backends
    assert ("all_gather_matmul", "ring") in backends
    assert ("all_gather_matmul", "ring_bidir") in backends
    assert ("psum", "ring") in backends
    for key in ("ici_bandwidth", "remote_sync_s", "gemm_efficiency",
                "kernel_launch_s"):
        assert table.corrections[key] > 0, key
    live = autotune.live_fingerprint("tpu_v5e", mesh4)
    assert table.fingerprint.compatible(live, strict=True)


def test_round_trip_through_json(table, tmp_path, mesh4):
    path = table.save(tmp_path / "cal.json")
    loaded = autotune.CalibrationTable.load(path)
    assert loaded.to_json() == table.to_json()

    # the loaded table drives a measured context exactly like the original
    for cal in (loaded, str(path)):
        ctx = CommContext(axis_name="x", mesh=mesh4, policy="measured",
                          calibration=cal)
        active = ctx.active_calibration()
        assert active is not None
        assert active.corrections == table.corrections
        hw = ctx.effective_hw()
        assert hw.ici_bandwidth == pytest.approx(
            table.corrections["ici_bandwidth"])
        assert hw.gemm_efficiency < 1.0
        assert hw is not ctx.hw


def test_rejects_foreign_schema(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text(json.dumps({"schema": "something-else/v9"}))
    with pytest.raises(ValueError, match="repro-autotune"):
        autotune.CalibrationTable.load(p)


def test_measured_us_refuses_far_extrapolation(table):
    row = next(r for r in table.measurements if r["op"] == "psum")
    near = table.measured_us("psum", row["backend"], row["m"], row["n"],
                             row["k"])
    assert near == pytest.approx(row["us"])
    far = table.measured_us("psum", row["backend"], row["m"] * 100,
                            row["n"] * 100, max(row["k"] * 100, 1))
    assert far is None


def test_calibration_rows_match_dispatch_coordinates(mesh4, table):
    """The (m, n, k) calibrate() stores must be the exact coordinates
    auto_gemm_backend queries with — a systematic offset (e.g. recording
    AG rows at m * n_dev) would put every row past the 4x lookup cutoff
    and measured dispatch would silently never activate."""
    for op in ("all_gather_matmul", "matmul_reduce_scatter",
               "matmul_all_reduce"):
        r = next(r for r in table.measurements if r["op"] == op)
        assert table.best_backend(op, r["m"], r["n"], r["k"],
                                  allowed=("bulk", "ring"),
                                  axis_size=N) is not None, op

    # AG end-to-end: a (nsz, nsz//4) global operand sharded over the axis
    # dispatches at m = m_loc * n_dev = nsz — the grid point itself, so the
    # measured argmin (not the analytic policy) must decide
    nsz = 128
    measured = {r["backend"]: r["us"] for r in table.measurements
                if r["op"] == "all_gather_matmul" and r["m"] == nsz}
    assert measured, "tiny grid did not store AG rows at dispatch m"
    ctx = CommContext(axis_name="x", mesh=mesh4, policy="measured",
                      calibration=table)
    assert ctx.auto_gemm_backend("all_gather_matmul", nsz, nsz // 4,
                                 nsz // 4) == min(measured, key=measured.get)


# ---------------------------------------------------------------------------
# Measured-policy dispatch
# ---------------------------------------------------------------------------

def test_measured_policy_overrides_analytic_choice(mesh4):
    """Shapes where the analytic model says bulk (tiny GEMM) dispatch to
    ring when the measurements say ring is faster — and vice versa."""
    live = autotune.live_fingerprint("tpu_v5e", mesh4)
    analytic = CommContext(axis_name="x", mesh=mesh4)

    # analytic: tiny GEMM -> bulk. measured: ring 10x faster -> ring.
    t = _synthetic(live, _rows("matmul_reduce_scatter",
                               {"bulk": 1000.0, "ring": 100.0}, 64, 16, 8))
    ctx = CommContext(axis_name="x", mesh=mesh4, policy="measured",
                      calibration=t)
    assert analytic.auto_gemm_backend("matmul_reduce_scatter", 64, 16, 8) \
        == "bulk"
    assert ctx.auto_gemm_backend("matmul_reduce_scatter", 64, 16, 8) == "ring"

    # analytic: big GEMM -> ring. measured: bulk faster -> bulk.
    big = 8192
    t2 = _synthetic(live, _rows("matmul_reduce_scatter",
                                {"bulk": 50.0, "ring": 500.0}, big, big, big))
    ctx2 = CommContext(axis_name="x", mesh=mesh4, policy="measured",
                       calibration=t2)
    assert analytic.auto_gemm_backend("matmul_reduce_scatter", big, big, big) \
        == "ring"
    assert ctx2.auto_gemm_backend("matmul_reduce_scatter", big, big, big) \
        == "bulk"


def test_measured_dispatch_respects_feasibility(mesh4):
    """A measured win for ring_bidir must not leak to calls whose operands
    cannot split across the two rings (bidir_ok=False)."""
    live = autotune.live_fingerprint("tpu_v5e", mesh4)
    rows = _rows("all_gather_matmul",
                 {"bulk": 900.0, "ring": 500.0, "ring_bidir": 100.0},
                 512, 128, 128)
    ctx = CommContext(axis_name="x", mesh=mesh4, policy="measured",
                      calibration=_synthetic(live, rows))
    assert ctx.auto_gemm_backend("all_gather_matmul", 512, 128, 128) \
        == "ring_bidir"
    assert ctx.auto_gemm_backend("all_gather_matmul", 512, 128, 128,
                                 bidir_ok=False) == "ring"


def test_measured_needs_two_backends(mesh4):
    """One-sided coverage is not a comparison: dispatch falls back to the
    analytic policy rather than echoing the only measured backend."""
    live = autotune.live_fingerprint("tpu_v5e", mesh4)
    t = _synthetic(live, _rows("matmul_reduce_scatter", {"ring": 1.0},
                               64, 16, 8))
    ctx = CommContext(axis_name="x", mesh=mesh4, policy="measured",
                      calibration=t)
    assert ctx.auto_gemm_backend("matmul_reduce_scatter", 64, 16, 8) == "bulk"


def test_measured_dispatch_is_dtype_aware(mesh4):
    """bf16-measured rows must not decide for f32 payloads: ring's measured
    win comes from halving the bytes, which an f32 payload doesn't get."""
    live = autotune.live_fingerprint("tpu_v5e", mesh4)
    rows = _rows("psum", {"bulk": 999.0, "ring": 1.0}, N, 64, 1)
    for r in rows:
        r["dtype_bytes"] = 2
    t = _synthetic(live, rows)
    assert t.best_backend("psum", N, 64, 1, allowed=("bulk", "ring"),
                          axis_size=N, dtype_bytes=2) == "ring"
    assert t.best_backend("psum", N, 64, 1, allowed=("bulk", "ring"),
                          axis_size=N, dtype_bytes=4) is None
    # rows without a recorded dtype (older tables) stay dtype-agnostic
    for r in rows:
        del r["dtype_bytes"]
    assert t.best_backend("psum", N, 64, 1, allowed=("bulk", "ring"),
                          axis_size=N, dtype_bytes=4) == "ring"


def test_measured_psum_dispatch(mesh4, table, monkeypatch):
    """psum's auto backend consults the table: with ring measured 999x
    faster (dtype-agnostic synthetic rows), a f32 payload — which the
    analytic heuristic sends to bulk — dispatches to the ring impl."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    import repro.core.comms as comms
    from repro import compat

    nsz = 64
    live = autotune.live_fingerprint("tpu_v5e", mesh4)
    t = _synthetic(live, _rows("psum", {"bulk": 999.0, "ring": 1.0},
                               N, nsz, 1))
    ctx = CommContext(axis_name="x", mesh=mesh4, policy="measured",
                      calibration=t)

    calls = []
    orig = comms.pk_psum_ring
    monkeypatch.setattr(comms, "pk_psum_ring",
                        lambda *a, **k: calls.append(1) or orig(*a, **k))
    x = jnp.ones((N, N, nsz), jnp.float32)   # per-device payload: (N, nsz)
    compat.shard_map(lambda v: ctx.psum(v[0])[None], mesh=mesh4,
                     in_specs=P("x"), out_specs=P("x"), check_vma=False)(x)
    assert calls, "measured policy did not route psum to the ring impl"


# ---------------------------------------------------------------------------
# Island-keyed dispatch (calibrate --per-island)
# ---------------------------------------------------------------------------

MLP_KEY = autotune.island_key("mlp", "matmul_all_reduce", 2)
ATTN_KEY = autotune.island_key("attn_out", "matmul_all_reduce", 2)


def _island_table(live):
    """Global rows say bulk wins; mlp-island rows say ring wins; attn_out
    rows agree with the global table — all at the same (m, n, k)."""
    rows = _rows("matmul_all_reduce", {"bulk": 10.0, "ring": 100.0},
                 256, 64, 16)
    mlp = _rows("matmul_all_reduce", {"bulk": 100.0, "ring": 10.0},
                256, 64, 16)
    for r in mlp:
        r["island"] = MLP_KEY
    attn = _rows("matmul_all_reduce", {"bulk": 5.0, "ring": 500.0},
                 256, 64, 16)
    for r in attn:
        r["island"] = ATTN_KEY
    return _synthetic(live, rows + mlp + attn)


def test_island_keyed_rows_beat_global(mesh4):
    """Two islands with different layouts can resolve to different backends
    at the SAME (m, n, k): each context prefers its own island's rows and
    only falls back to the global grid when it has none."""
    live = autotune.live_fingerprint("tpu_v5e", mesh4)
    t = _island_table(live)
    mk = dict(mesh=mesh4, policy="measured", calibration=t)
    no_key = CommContext(axis_name="x", **mk)
    mlp = CommContext(axis_name="x", island=MLP_KEY, **mk)
    attn = CommContext(axis_name="x", island=ATTN_KEY, **mk)
    other = CommContext(axis_name="x",
                        island=autotune.island_key("decode", "psum", 4), **mk)
    assert no_key.auto_gemm_backend("matmul_all_reduce", 256, 64, 16) == "bulk"
    assert mlp.auto_gemm_backend("matmul_all_reduce", 256, 64, 16) == "ring"
    assert attn.auto_gemm_backend("matmul_all_reduce", 256, 64, 16) == "bulk"
    # an island with no rows of its own falls back to the global grid
    assert other.auto_gemm_backend("matmul_all_reduce", 256, 64, 16) == "bulk"


def test_island_rows_never_leak_across_islands(mesh4):
    """An island whose key has rows must not see another island's rows as
    evidence — only its own tier, then the global tier."""
    live = autotune.live_fingerprint("tpu_v5e", mesh4)
    mlp = _rows("matmul_all_reduce", {"bulk": 100.0, "ring": 10.0},
                256, 64, 16)
    for r in mlp:
        r["island"] = MLP_KEY
    t = _synthetic(live, mlp)           # island rows ONLY, no global tier
    attn = CommContext(axis_name="x", mesh=mesh4, policy="measured",
                       calibration=t, island=ATTN_KEY)
    # attn_out has no rows and there is no global tier -> analytic fallback
    # (tiny GEMM -> bulk)
    assert attn.auto_gemm_backend("matmul_all_reduce", 256, 64, 16) == "bulk"
    assert t.best_backend("matmul_all_reduce", 256, 64, 16,
                          allowed=("bulk", "ring"), axis_size=N,
                          island=ATTN_KEY) is None


def test_best_chunks_measured(mesh4):
    """best_chunks returns the argmin-us chunk count at the nearest point
    with >= 2 distinct measured counts; one count is not a comparison."""
    live = autotune.live_fingerprint("tpu_v5e", mesh4)
    rows = []
    for c, us in ((1, 100.0), (2, 40.0), (4, 60.0)):
        rows.append({"op": "matmul_reduce_scatter", "backend": "ring",
                     "axis_size": N, "m": 256, "n": 64, "k": 16,
                     "n_chunks": c, "us": us})
    t = _synthetic(live, rows)
    assert t.best_chunks("matmul_reduce_scatter", "ring", 256, 64, 16,
                         axis_size=N) == 2
    one = _synthetic(live, rows[:1])
    assert one.best_chunks("matmul_reduce_scatter", "ring", 256, 64, 16,
                           axis_size=N) is None
    # the measured count feeds the context's chunk resolution
    ctx = CommContext(axis_name="x", mesh=mesh4, policy="measured",
                      calibration=t)
    sched = ctx.gemm_chunk_schedule("matmul_reduce_scatter", 256, 64, 16,
                                    backend="ring")
    assert sched.n_chunks == 2 and sched.source == "measured"


def test_per_island_calibrate_sweeps_and_dispatches(mesh4):
    """calibrate(islands=...) measures backend x chunk rows tagged with the
    island key at the island's exact coordinates, and a context carrying
    that key dispatches from them."""
    sweeps = (autotune.IslandSweep(island=MLP_KEY, op="matmul_all_reduce",
                                   m=8 * N, n=16, k=8),)
    table = autotune.calibrate(mesh=mesh4, grid="tiny", reps=1,
                               islands=sweeps)
    tagged = [r for r in table.measurements if r.get("island") == MLP_KEY]
    assert {r["backend"] for r in tagged} >= {"bulk", "ring"}
    assert {r["n_chunks"] for r in tagged if r["backend"] == "ring"} \
        == set(autotune.ISLAND_CHUNK_SWEEP)
    assert all((r["m"], r["n"], r["k"]) == (8 * N, 16, 8) for r in tagged)
    ctx = CommContext(axis_name="x", mesh=mesh4, policy="measured",
                      calibration=table, island=MLP_KEY)
    # island rows cover >= 2 backends at the exact coordinates: the measured
    # argmin (whichever side won on this machine) decides, not the analytic
    # small-GEMM heuristic
    best = min(((r["backend"], r["us"]) for r in tagged),
               key=lambda be_us: be_us[1])[0]
    assert ctx.auto_gemm_backend("matmul_all_reduce", 8 * N, 16, 8) == best


def test_per_island_calibrate_dtype_axis(mesh4):
    """The --per-island dtype axis (CLI helper + core sweep): a GEMM island
    swept at b2 AND its int8-wire twin produce paired ``…|b2`` / ``…|b1``
    row families at the same coordinates, each tagged with its own
    dtype_bytes — so the b1 dispatch query never reads b2 evidence."""
    from repro.autotune import int8_island_sweeps
    sweeps = [autotune.IslandSweep(island=MLP_KEY, op="matmul_all_reduce",
                                   m=8 * N, n=16, k=8)]
    sweeps += int8_island_sweeps(sweeps)
    b1_key = autotune.island_key("mlp", "matmul_all_reduce", 1)
    assert [sw.island for sw in sweeps] == [MLP_KEY, b1_key]
    table = autotune.calibrate(mesh=mesh4, grid="tiny", reps=1,
                               islands=tuple(sweeps))
    by_key = {key: [r for r in table.measurements
                    if r.get("island") == key]
              for key in (MLP_KEY, b1_key)}
    for key, want_b in ((MLP_KEY, 2), (b1_key, 1)):
        rows = by_key[key]
        assert rows, key
        assert all(r["dtype_bytes"] == want_b for r in rows)
        assert all((r["m"], r["n"], r["k"]) == (8 * N, 16, 8) for r in rows)
        assert {r["backend"] for r in rows} >= {"bulk", "ring"}


def test_measured_us_island_dtype_precedence(mesh4):
    """Same island name calibrated at both widths: each context reads its
    own ``b{dtype}`` family; measured_us at one width never falls through
    to the other width's rows."""
    live = autotune.live_fingerprint("tpu_v5e", mesh4)
    b1_key = autotune.island_key("mlp", "matmul_all_reduce", 1)
    rows = []
    for key, db, us in ((MLP_KEY, 2, 10.0), (b1_key, 1, 99.0)):
        rows.append({"op": "matmul_all_reduce", "backend": "ring",
                     "axis_size": N, "m": 256, "n": 64, "k": 16,
                     "dtype_bytes": db, "n_chunks": 1, "island": key,
                     "us": us})
    t = _synthetic(live, rows)
    assert t.measured_us("matmul_all_reduce", "ring", 256, 64, 16,
                         axis_size=N, dtype_bytes=2,
                         island=MLP_KEY) == 10.0
    assert t.measured_us("matmul_all_reduce", "ring", 256, 64, 16,
                         axis_size=N, dtype_bytes=1,
                         island=b1_key) == 99.0
    # cross-width queries find nothing in the island tier (and there is no
    # matching global row): width is part of the evidence, not a fallback
    assert t.measured_us("matmul_all_reduce", "ring", 256, 64, 16,
                         axis_size=N, dtype_bytes=1, island=MLP_KEY,
                         island_only=True) is None
    assert t.measured_us("matmul_all_reduce", "ring", 256, 64, 16,
                         axis_size=N, dtype_bytes=2, island=b1_key,
                         island_only=True) is None


# ---------------------------------------------------------------------------
# Graceful fallback
# ---------------------------------------------------------------------------

def test_measured_without_table_warns_and_falls_back(mesh4, tmp_path,
                                                     monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "empty"))
    monkeypatch.setattr(autotune, "_SEED_DIR", tmp_path / "no-seeds")
    autotune.clear_caches()
    ctx = CommContext(axis_name="x", mesh=mesh4, policy="measured")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        assert ctx.active_calibration() is None
        be = ctx.auto_gemm_backend("matmul_reduce_scatter", 16, 12, 32)
    assert be == "bulk"        # identical to the analytic policy
    assert any("falling back to analytic" in str(w.message) for w in rec)


def test_unreadable_explicit_path_warns_and_falls_back(mesh4, tmp_path):
    ctx = CommContext(axis_name="x", mesh=mesh4, policy="measured",
                      calibration=str(tmp_path / "missing.json"))
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        assert ctx.active_calibration() is None
    assert any("could not be loaded" in str(w.message) for w in rec)


def test_fingerprint_mismatch_falls_back(mesh4, table):
    foreign = dataclasses.replace(table.fingerprint, backend="tpu",
                                  device_kind="TPU v5 lite")
    t = autotune.CalibrationTable(fingerprint=foreign,
                                  corrections=dict(table.corrections),
                                  measurements=list(table.measurements))
    ctx = CommContext(axis_name="x", mesh=mesh4, policy="measured",
                      calibration=t)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        assert ctx.active_calibration() is None
        assert ctx.effective_hw() is ctx.hw
    assert any("does not match" in str(w.message) for w in rec)
    # an EXPLICITLY supplied table that is rejected warns under "auto" too
    auto_ctx = CommContext(axis_name="x", mesh=mesh4, policy="auto",
                           calibration=t)
    autotune.clear_caches()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        assert auto_ctx.active_calibration() is None
    assert any("does not match" in str(w.message) for w in rec)


def test_auto_policy_silent_on_implicit_miss(mesh4, tmp_path, monkeypatch):
    """auto's silence is reserved for the implicit cache/seed search
    finding nothing — no tables anywhere, no warning."""
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "empty"))
    monkeypatch.setattr(autotune, "_SEED_DIR", tmp_path / "no-seeds")
    autotune.clear_caches()
    ctx = CommContext(axis_name="x", mesh=mesh4, policy="auto")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        assert ctx.active_calibration() is None
    assert not rec


def test_unknown_policy_rejected(mesh4):
    ctx = CommContext(axis_name="x", mesh=mesh4, policy="vibes")
    with pytest.raises(ValueError, match="unknown comm policy"):
        ctx.active_calibration()


def test_auto_policy_finds_cached_table(mesh4, table, tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    autotune.clear_caches()
    marked = autotune.CalibrationTable(
        fingerprint=table.fingerprint,
        corrections={**table.corrections, "gemm_efficiency": 0.123},
        measurements=[], notes="from-the-cache")
    marked.save(autotune.cache_path(table.fingerprint))
    ctx = CommContext(axis_name="x", mesh=mesh4, policy="auto")
    active = ctx.active_calibration()
    assert active is not None
    # the user cache wins over any in-repo seed
    assert active.notes == "from-the-cache"
    assert ctx.effective_hw().gemm_efficiency == pytest.approx(0.123)


def test_shipped_seed_matches_emulated_mesh():
    """The checked-in cpu_emulated seed must keep fingerprint-matching a
    CPU process on the pinned jax version (else the measured policy can
    never activate from a clean checkout)."""
    seed = autotune.CalibrationTable.load(
        Path(autotune._SEED_DIR) / "cpu_emulated.json")
    live = autotune.live_fingerprint("tpu_v5e")
    if live.backend != "cpu":
        pytest.skip("seed table targets the CPU-emulated mesh")
    assert seed.fingerprint.compatible(live)
    assert autotune.find_table("tpu_v5e") is not None


def test_analytic_policy_ignores_tables(mesh4, table):
    ctx = CommContext(axis_name="x", mesh=mesh4, policy="analytic",
                      calibration=table)
    assert ctx.active_calibration() is None
    assert ctx.effective_hw() is ctx.hw


# ---------------------------------------------------------------------------
# Staleness (table age + spot-probe drift)
# ---------------------------------------------------------------------------

def test_table_age_days_parses_created(table):
    t = dataclasses.replace(table, created="2020-01-01T00:00:00")
    assert autotune.table_age_days(t) > 365
    assert autotune.table_age_days(
        dataclasses.replace(table, created="not-a-date")) is None
    assert autotune.table_age_days(
        dataclasses.replace(table, created="")) is None


def test_staleness_flags_old_table(table):
    old = dataclasses.replace(table, created="2020-01-01T00:00:00")
    msgs = autotune.staleness(old, probe=False)
    assert len(msgs) == 1 and "days old" in msgs[0]


def test_staleness_fresh_table_quiet(table):
    import time as _time

    fresh = dataclasses.replace(
        table, created=_time.strftime("%Y-%m-%dT%H:%M:%S"))
    assert autotune.staleness(fresh, probe=False) == []


def test_staleness_spot_probe_catches_drift(table):
    import time as _time

    # a freshly-stamped table whose machine-local corrections are absurd:
    # only the spot probe can catch it
    drifted = autotune.CalibrationTable(
        fingerprint=table.fingerprint,
        corrections={**table.corrections, "kernel_launch_s": 1e4,
                     "gemm_efficiency": 1e9},
        created=_time.strftime("%Y-%m-%dT%H:%M:%S"))
    msgs = autotune.staleness(drifted, reps=1)
    assert any("kernel_launch_s drifted" in m for m in msgs)
    assert any("gemm_efficiency drifted" in m for m in msgs)


def test_measured_policy_warns_once_on_stale_table(mesh4, table):
    stale = dataclasses.replace(table, created="2020-01-01T00:00:00")
    ctx = CommContext(axis_name="x", mesh=mesh4, policy="measured",
                      calibration=stale)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        # the stale table is still USED (measured keeps dispatching on it)
        assert ctx.active_calibration() is stale
        assert ctx.active_calibration() is stale
    stale_warnings = [w for w in rec if "days old" in str(w.message)]
    assert len(stale_warnings) == 1    # warn-once, not per lookup


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_check_stale_and_fresh(table, tmp_path, capsys):
    import time as _time

    from repro.autotune import main

    old = dataclasses.replace(table, created="2020-01-01T00:00:00")
    p_old = old.save(tmp_path / "old.json")
    assert main(["check", str(p_old), "--no-probe"]) == 1
    assert "STALE" in capsys.readouterr().err

    fresh = dataclasses.replace(
        table, created=_time.strftime("%Y-%m-%dT%H:%M:%S"))
    p_fresh = fresh.save(tmp_path / "fresh.json")
    assert main(["check", str(p_fresh), "--no-probe"]) == 0
    assert "within threshold" in capsys.readouterr().out


def test_cli_show_and_diff(table, tmp_path, capsys):
    from repro.autotune import main

    a = table.save(tmp_path / "a.json")
    b_table = autotune.CalibrationTable(
        fingerprint=table.fingerprint,
        corrections={**table.corrections,
                     "ici_bandwidth": table.corrections["ici_bandwidth"] * 2},
        measurements=list(table.measurements))
    b = b_table.save(tmp_path / "b.json")

    assert main(["show", str(a)]) == 0
    out = capsys.readouterr().out
    assert "fingerprint:" in out and "ici_bandwidth" in out

    assert main(["diff", str(a), str(b)]) == 0
    out = capsys.readouterr().out
    assert "+100.0%" in out

    assert main(["diff", str(a)]) == 0          # one-sided: vs analytic
    assert "analytic" in capsys.readouterr().out


def test_cli_diff_refuses_incompatible(table, tmp_path, capsys):
    from repro.autotune import main

    a = table.save(tmp_path / "a.json")
    foreign = autotune.CalibrationTable(
        fingerprint=dataclasses.replace(table.fingerprint, backend="tpu"),
        corrections=dict(table.corrections))
    b = foreign.save(tmp_path / "b.json")
    assert main(["diff", str(a), str(b)]) == 1
    assert "incompatible" in capsys.readouterr().err


def test_cli_calibrate_writes_cache(tmp_path, monkeypatch, capsys):
    from repro.autotune import main

    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    out = tmp_path / "fresh.json"
    assert main(["calibrate", "--grid", "tiny", "--reps", "1",
                 "--out", str(out)]) == 0
    t = autotune.CalibrationTable.load(out)
    assert t.ops_covered()
    assert t.corrections["ici_bandwidth"] > 0


# ---------------------------------------------------------------------------
# Bench artifact schema (scripts/check_bench.py)
# ---------------------------------------------------------------------------

def _load_check_bench():
    path = Path(__file__).parent.parent / "scripts" / "check_bench.py"
    spec = importlib.util.spec_from_file_location("check_bench", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _bench_doc(**overrides):
    doc = {
        "schema": "repro-bench/v1", "created": "2026-01-01T00:00:00",
        "jax_version": "0", "backend": "cpu", "device_kind": "cpu",
        "n_devices": 8, "pred_hw": "tpu_v5e",
        "figures": [
            {"figure": "fig7", "status": "ok", "error": None, "n_rows": 2,
             "pred_err_median": 0.5,
             "rows": [
                 {"name": "fig7/pk/N=512", "us_per_call": 100.0,
                  "derived": "", "predicted_us": 50.0, "pred_err": -0.5},
                 {"name": "fig7/baseline/N=512", "us_per_call": 200.0,
                  "derived": "", "predicted_us": None, "pred_err": None},
             ]},
        ],
    }
    doc.update(overrides)
    return doc


def test_bench_schema_validation():
    cb = _load_check_bench()
    assert cb.validate_schema(_bench_doc()) == []
    assert cb.validate_schema({"schema": "nope"})
    assert cb.validate_schema(_bench_doc(figures=[{"figure": "x"}]))
    bad_row = _bench_doc()
    bad_row["figures"][0]["rows"][0]["us_per_call"] = "fast"
    assert any("us_per_call" in e for e in cb.validate_schema(bad_row))
    failed = _bench_doc()
    failed["figures"][0]["status"] = "failed"
    assert any("error" in e for e in cb.validate_schema(failed))
    # fig_health rows tag the serving condition: string or null only
    moded = _bench_doc()
    moded["figures"][0]["rows"][0]["mode"] = "degraded"
    assert cb.validate_schema(moded) == []
    moded["figures"][0]["rows"][0]["mode"] = 3
    assert any(".mode" in e for e in cb.validate_schema(moded))
    # fig_fused_chunks rows tag the sub-chunk count + schedule source
    chunked = _bench_doc()
    chunked["figures"][0]["rows"][0].update(sub_chunks=4,
                                            chunks_src="measured")
    assert cb.validate_schema(chunked) == []
    chunked["figures"][0]["rows"][0]["sub_chunks"] = 0
    assert any(".sub_chunks" in e for e in cb.validate_schema(chunked))
    chunked["figures"][0]["rows"][0].update(sub_chunks=4,
                                            chunks_src="vibes")
    assert any(".chunks_src" in e for e in cb.validate_schema(chunked))


def test_bench_regression_gate():
    cb = _load_check_bench()
    base = _bench_doc()
    ok = _bench_doc()
    assert cb.compare(ok, base, 0.25) == []
    slow = _bench_doc()
    for r in slow["figures"][0]["rows"]:
        r["us_per_call"] *= 1.5
    assert any("slowdown" in p for p in cb.compare(slow, base, 0.25))
    gone = _bench_doc(figures=[])
    assert any("not in run" in p for p in cb.compare(gone, base, 0.25))
    broke = _bench_doc()
    broke["figures"][0]["status"] = "failed"
    broke["figures"][0]["error"] = "boom"
    assert any("failed" in p for p in cb.compare(broke, base, 0.25))


def test_recorder_emits_valid_schema():
    cb = _load_check_bench()
    from benchmarks.common import Recorder

    rec = Recorder()
    rec.start_figure("figX")
    rec.add("figX/a", 10.0, "", 12.0)
    rec.add("figX/b", 20.0, "", None)
    rec.start_figure("figY")
    rec.fail(RuntimeError("exploded"))
    doc = rec.report()
    assert cb.validate_schema(doc) == []
    figy = doc["figures"][1]
    assert figy["status"] == "failed" and "exploded" in figy["error"]
    figx = doc["figures"][0]
    assert figx["pred_err_median"] == pytest.approx(0.2)


def test_checked_in_baseline_is_valid():
    cb = _load_check_bench()
    path = Path(__file__).parent.parent / "benchmarks" / "BENCH_baseline.json"
    doc = json.loads(path.read_text())
    assert cb.validate_schema(doc) == []
    assert all(f["status"] == "ok" for f in doc["figures"])


def test_best_backend_compares_only_shared_grid_points():
    """A backend measured only at a much smaller shape must not win on
    shape size: backends are compared at one shared (m, n, k) point."""
    fp = autotune.live_fingerprint("tpu_v5e")
    rows = [
        # bulk measured at both sizes; ring only at the small one (the
        # sweep's try/except skipped it) — 60us@128 vs 3000us@512 is not
        # an apples-to-apples race
        {"op": "matmul_all_reduce", "backend": "bulk", "axis_size": N,
         "m": 128, "n": 128, "k": 64, "us": 50.0},
        {"op": "matmul_all_reduce", "backend": "ring", "axis_size": N,
         "m": 128, "n": 128, "k": 64, "us": 60.0},
        {"op": "matmul_all_reduce", "backend": "bulk", "axis_size": N,
         "m": 512, "n": 128, "k": 64, "us": 3000.0},
    ]
    table = _synthetic(fp, rows)
    # querying at the large shape: the only shared point is (128, 128, 64),
    # where bulk wins — ring's small-shape time must not beat bulk's
    # large-shape time
    assert table.best_backend("matmul_all_reduce", 512, 128, 64,
                              allowed=("bulk", "ring"), axis_size=N) == "bulk"
    assert table.best_backend("matmul_all_reduce", 128, 128, 64,
                              allowed=("bulk", "ring"), axis_size=N) == "bulk"
    # a one-sided table (ring rows removed) yields no dispatch at all
    table1 = _synthetic(fp, [r for r in rows if r["backend"] == "bulk"])
    assert table1.best_backend("matmul_all_reduce", 128, 128, 64,
                               allowed=("bulk", "ring"), axis_size=N) is None


# ---------------------------------------------------------------------------
# all-to-all island sweep (Ulysses / MoE dispatch) + measured a2a dispatch
# ---------------------------------------------------------------------------

def _a2a_sweep():
    # local payload (8, 4, 16, 16), split heads (dim 1), concat seq (dim 2)
    shape = (8, 4, 16, 16)
    m, n, k = CommContext.a2a_coords(shape, 1, 2)
    return autotune.IslandSweep(island="attn_ulysses|all_to_all|b2",
                                op="all_to_all", m=m, n=n, k=k,
                                dtype_bytes=2, shape=shape,
                                split_axis=1, concat_axis=2)


def test_calibrate_sweeps_a2a_islands(mesh4):
    table = autotune.calibrate(mesh=mesh4, grid="tiny", reps=1,
                               islands=[_a2a_sweep()])
    rows = [r for r in table.measurements
            if r["op"] == "all_to_all" and r.get("island")]
    assert rows, "a2a island sweep produced no rows"
    backends = {(r["backend"], r["n_chunks"]) for r in rows}
    assert ("bulk", 1) in backends
    assert any(be == "chunked" and c > 1 for be, c in backends)
    sw = _a2a_sweep()
    for r in rows:
        assert (r["m"], r["n"], r["k"]) == (sw.m, sw.n, sw.k)
        assert r["island"] == sw.island


def test_a2a_chunk_schedule_measured_dispatch(mesh4, tmp_path):
    """a2a_chunk_schedule prefers island-keyed measured rows and reports
    the argmin chunk count; without usable rows it answers analytically."""
    sw = _a2a_sweep()
    fp = autotune.live_fingerprint("tpu_v5e", mesh4)

    def r(be, c, us):
        return {"op": "all_to_all", "backend": be, "axis_size": N,
                "m": sw.m, "n": sw.n, "k": sw.k, "dtype_bytes": 2,
                "n_chunks": c, "island": sw.island, "us": us}

    table = _synthetic(fp, [r("bulk", 1, 500.0), r("chunked", 2, 100.0),
                            r("chunked", 4, 300.0)])
    path = Path(table.save(tmp_path / "a2a.json"))
    autotune.clear_caches()
    ctx = CommContext(axis_name="x", mesh=mesh4, policy="measured",
                      calibration=str(path), island=sw.island)
    sched = ctx.a2a_chunk_schedule(sw.shape, 1, 2)
    assert sched.source == "measured"
    assert sched.n_chunks == 2
    # bulk measured fastest -> 1 chunk, still a measurement
    table2 = _synthetic(fp, [r("bulk", 1, 50.0), r("chunked", 2, 100.0),
                             r("chunked", 4, 300.0)])
    path2 = Path(table2.save(tmp_path / "a2a2.json"))
    autotune.clear_caches()
    ctx2 = dataclasses.replace(ctx, calibration=str(path2))
    sched2 = ctx2.a2a_chunk_schedule(sw.shape, 1, 2)
    assert sched2.source == "measured" and sched2.n_chunks == 1
    # no table -> analytic
    ctx3 = CommContext(axis_name="x", mesh=mesh4, policy="analytic")
    assert ctx3.a2a_chunk_schedule(sw.shape, 1, 2).source == "analytic"


def test_ulysses_auto_chunks_consume_a2a_rows(mesh22, tmp_path):
    """RunConfig.ulysses_chunks=0 (auto): the sp attention island resolves
    its a2a chunk count from the island-keyed measured rows, and the chunked
    island still matches the dense reference numerically."""
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.configs.base import RunConfig
    from repro.models import layers as L
    from repro.models.sharding import ShardingRules

    cfg = get_config("tinyllama-1.1b").reduced()
    b, s = 4, 16
    # island payload on this mesh: (b_loc=2, hq=4, s_loc=8, hd=16)
    shape = (2, 4, 8, 16)
    m, n, k = CommContext.a2a_coords(shape, 1, 2)
    key = autotune.island_key("attn_ulysses", "all_to_all", 2)
    fp = autotune.live_fingerprint("tpu_v5e", mesh22)

    def r(be, c, us):
        return {"op": "all_to_all", "backend": be, "axis_size": 2,
                "m": m, "n": n, "k": k, "dtype_bytes": 2, "n_chunks": c,
                "island": key, "us": us}

    table = _synthetic(fp, [r("bulk", 1, 500.0), r("chunked", 2, 100.0),
                            r("chunked", 4, 400.0)])
    path = table.save(tmp_path / "ul.json")
    autotune.clear_caches()
    run = RunConfig(dp_axes=("data",), fsdp=False, sp_attention="ulysses",
                    ulysses_chunks=0, comm_policy="measured",
                    calibration_path=str(path))
    rules = ShardingRules(mesh22, run)
    island = L.sp_attention_island(cfg, run, rules, b, s, causal=True)
    plan = island.plan()
    assert plan.backend == "chunked" and plan.n_chunks == 2
    assert plan.source == "measured"

    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jax.random.normal(jax.random.PRNGKey(0), (b, hq, s, hd))
    kk = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, s, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s, hd))

    def reference(q, k, v):
        return L._full_attention(q, k, v, causal=True, window=None)

    isl = L.sp_attention_island(cfg, run, rules, b, s, causal=True,
                                reference=reference)
    got = isl(q=q, k=kk, v=v)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(reference(q, kk, v)),
                               rtol=2e-4, atol=2e-4)


def test_moe_auto_chunks_resolve(mesh8):
    """RunConfig.moe_chunks=0 (auto) resolves a concrete dispatch-plan
    chunk count (analytic without a table) and the plan stays coherent."""
    from repro.configs import get_config
    from repro.configs.base import RunConfig
    from repro.models import layers as L
    from repro.models.sharding import ShardingRules

    cfg = get_config("moonshot-v1-16b-a3b").reduced()
    run = RunConfig(dp_axes=("data",), fsdp=False, moe_chunks=0)
    rules = ShardingRules(mesh8, run)
    isl = L.moe_island(cfg, run, rules, 4, 8)
    assert isl.comm.n_chunks >= 1
    assert isl.plan().n_chunks >= 1
