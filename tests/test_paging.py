"""Paged KV-cache primitives (repro.runtime.paging), host-side only:

* geometry resolution — tp padding, partition rounding, capacity math;
* PageAllocator — deterministic all-or-nothing allocation, refcounted
  retain/release, partition-local free lists over global ids;
* PrefixCache — longest-common-prefix lookup, schedule gating, retained
  pages surviving donor release, FIFO eviction;
* paged_cache_template — shape/partitioning of the pool tree and the
  attention-only guard.

Engine-level paged behavior (bit-identity, chunk interleave, backpressure)
lives in tests/test_paged_serving.py.
"""

import dataclasses

import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import RunConfig, ServeConfig
from repro.models.sharding import ShardingRules
from repro.runtime import paging

SERVE = ServeConfig(max_batch=4, prefill_batch=2, bucket_edges=(8, 16),
                    max_new_tokens=4, cache_layout="paged", page_size=4)


# ---------------------------------------------------------------------------
# Geometry
# ---------------------------------------------------------------------------

def test_geometry_resolution_and_padding():
    g = paging.resolve_page_geometry(SERVE, s_max=20)
    assert g.page_size == 4 and g.pages_per_slot == 5
    assert g.n_pages == 4 * 5                    # slab-equivalent default
    assert g.pages_for(1) == 1 and g.pages_for(4) == 1 and g.pages_for(5) == 2
    # page interior stripes over tp: page_size pads up to |tp|
    g8 = paging.resolve_page_geometry(
        dataclasses.replace(SERVE, page_size=6), s_max=24, tp_size=4)
    assert g8.page_size == 8
    # pool rounds to the partition count and reports per-partition capacity
    gp = paging.resolve_page_geometry(
        dataclasses.replace(SERVE, n_pages=11), s_max=8, n_partitions=2)
    assert gp.n_pages == 12 and gp.pages_per_partition == 6
    assert gp.slot_partition(0, 4) == 0 and gp.slot_partition(2, 4) == 1
    # capacity: how many full-span requests fit resident at once
    assert gp.resident_capacity(8, 4) == 4       # 2 pages/req, capped by b
    assert gp.resident_capacity(8, 100) == 6


def test_geometry_rejects_undersized_pool_and_misaligned_chunk():
    with pytest.raises(ValueError, match="pool too small"):
        paging.resolve_page_geometry(
            dataclasses.replace(SERVE, n_pages=4), s_max=20)
    with pytest.raises(ValueError, match="prefill_chunk"):
        # tp pads the page 4 -> 8; a chunk of 4 is no longer page-aligned
        paging.resolve_page_geometry(
            dataclasses.replace(SERVE, prefill_chunk=4, page_size=4),
            s_max=20, tp_size=8)
    # the same chunk IS aligned when tp does not pad the page
    paging.resolve_page_geometry(
        dataclasses.replace(SERVE, prefill_chunk=8, page_size=4), s_max=20)


# ---------------------------------------------------------------------------
# Allocator
# ---------------------------------------------------------------------------

def _alloc(n_pages=8, n_partitions=2):
    geom = paging.PageGeometry(page_size=4, n_pages=n_pages,
                               pages_per_slot=2, n_partitions=n_partitions)
    return paging.PageAllocator(geom)


def test_alloc_is_deterministic_and_all_or_nothing():
    a = _alloc()
    assert a.alloc(0, 2) == [0, 1]               # lowest global id first
    assert a.alloc(1, 1) == [4]                  # partition 1 owns [4, 8)
    assert a.alloc(0, 3) is None                 # only 2 left: no partial
    assert a.free_pages(0) == 2
    assert a.resident_pages == 3


def test_refcount_retain_release():
    a = _alloc()
    pages = a.alloc(0, 2)
    a.retain(pages)                              # shared by a second owner
    assert a.release(pages) == 0                 # first owner: still held
    assert a.refcount(pages[0]) == 1
    assert a.release(pages) == 2                 # last owner frees
    assert a.resident_pages == 0
    # release derives the partition from the global id
    p1 = a.alloc(1, 2)
    a.release(p1)
    assert a.free_pages(1) == 4
    # freed pages come back lowest-first
    assert a.alloc(0, 1) == [0]


def test_prefix_cache_lookup_register_evict():
    a = _alloc(n_pages=12, n_partitions=1)
    pc = paging.PrefixCache(a, max_entries=2)
    pages = a.alloc(0, 3)
    pc.register(0, (1, 2, 3, 4, 5, 6, 7, 8, 9), pages, ("chunk", 8))
    # registry retains: donor release must NOT free the pages
    a.release(pages)
    assert a.resident_pages == 3
    m, ent = pc.lookup(0, (1, 2, 3, 4, 5, 99), ("chunk", 8))
    assert m == 5 and ent.pages == tuple(pages)
    # schedule mismatch never matches (different chunk program)
    assert pc.lookup(0, (1, 2, 3), ("chunk", 4)) == (0, None)
    # FIFO eviction releases the oldest entry's pages
    for seed in (50, 60):
        pg = a.alloc(0, 1)
        pc.register(0, (seed,), pg, ("chunk", 8))
        a.release(pg)
    assert len(pc) == 2 and a.refcount(pages[0]) == 0
    assert pc.evict_one(0) and pc.evict_one(0) and not pc.evict_one(0)
    assert a.resident_pages == 0


# ---------------------------------------------------------------------------
# Cache template
# ---------------------------------------------------------------------------

def test_paged_cache_template_shapes(mesh22):
    cfg = get_config("tinyllama-1.1b").reduced()
    run = RunConfig(dp_axes=("data",), fsdp=False)
    rules = ShardingRules(mesh22, run)
    geom = paging.resolve_page_geometry(
        SERVE, s_max=20, tp_size=2,
        n_partitions=paging.page_partitions(rules, SERVE.max_batch))
    assert geom.n_partitions == 2
    tree = paging.paged_cache_template(cfg, run, rules, batch=4, geom=geom)
    assert tree["block_tables"].shape == (4, geom.pages_per_slot)
    k = tree["blocks"]["pos0"]["k"]
    assert k.shape == (cfg.n_periods, geom.n_pages, cfg.n_kv_heads,
                       geom.page_size, cfg.hd)
    # pages over dp, page interior striped over tp
    assert k.spec == P(None, "data", None, "model", None)
    assert tree["block_tables"].spec == P("data", None)
    # pool/slab byte accounting agree at the slab-equivalent default
    assert paging.pool_hbm_bytes(cfg, geom) == \
        paging.slab_hbm_bytes(cfg, SERVE.max_batch, 20)


def test_paged_template_rejects_ssm():
    cfg = get_config("falcon-mamba-7b").reduced()
    run = RunConfig(dp_axes=("data",), fsdp=False)
    geom = paging.resolve_page_geometry(SERVE, s_max=20)
    with pytest.raises(ValueError, match="pure-attention"):
        paging.paged_cache_template(cfg, run, None, batch=4, geom=geom)
