"""Paged serving engine (cache_layout="paged") end-to-end:

* bit-identity: paged continuous == slab continuous == one-request-at-a-
  time sequential, on the (2,2) and (2,4) emulated meshes (dp-partitioned
  pool, tp-striped pages) and the dense no-mesh fallback;
* chunked prefill: decode ticks provably land BETWEEN a long prompt's
  prefill chunks (the event log pins the interleave);
* prefix sharing: share-then-diverge via CoW produces the same tokens as a
  fresh engine, and retirement releases refcounts back to the free list;
* pool exhaustion: admission backpressure (decode drains pages), never an
  error, and every request still completes;
* the memory story: at equal cache HBM the paged pool keeps >= 4x the slab
  engine's resident slots on a short-prompt trace.
"""

import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import RunConfig, ServeConfig
from repro.models.sharding import ShardingRules
from repro.runtime import paging
from repro.runtime.serving import serving_plan_record

SLAB = ServeConfig(max_batch=4, prefill_batch=2, bucket_edges=(8, 16),
                   max_new_tokens=4)
PAGED = dataclasses.replace(SLAB, cache_layout="paged", page_size=4,
                            prefill_chunk=8)


def _engine(mesh_shape, serve, **kw):
    from repro.launch.serve import build_engine
    return build_engine("tinyllama-1.1b", reduced=True,
                        mesh_shape=mesh_shape, serve=serve, **kw)


def _trace(serve, vocab, n, seed=0):
    from repro.launch.serve import synthetic_trace
    return synthetic_trace(n, serve, vocab, seed=seed)


# ---------------------------------------------------------------------------
# Bit-identity across layouts and schedules
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mesh_shape", [None, (2, 2), (2, 4)])
def test_paged_matches_slab_and_sequential(mesh_shape):
    slab = _engine(mesh_shape, SLAB)
    paged = _engine(mesh_shape, PAGED)
    trace = _trace(SLAB, slab.cfg.vocab_size, 5)
    done_slab = {c.rid: c.tokens for c in slab.run(trace)}
    done_paged = {c.rid: c.tokens for c in paged.run(trace)}
    assert done_paged == done_slab
    # and == sequential on a fresh paged engine (no batching effects)
    solo = _engine(mesh_shape, PAGED)
    ref = solo.run([trace[2]])[0]
    assert done_paged[2] == ref.tokens


def test_paged_single_shot_matches_chunked():
    """prefill_chunk=0 (one chunk per bucket) is the same math on a
    different schedule — tokens must not move."""
    single = _engine(None, dataclasses.replace(PAGED, prefill_chunk=0))
    chunked = _engine(None, PAGED)
    trace = _trace(SLAB, single.cfg.vocab_size, 4)
    assert {c.rid: c.tokens for c in single.run(trace)} == \
        {c.rid: c.tokens for c in chunked.run(trace)}


# ---------------------------------------------------------------------------
# Chunked prefill interleaves decode
# ---------------------------------------------------------------------------

def test_decode_ticks_between_prefill_chunks():
    serve = ServeConfig(max_batch=4, prefill_batch=2, bucket_edges=(8, 32),
                        max_new_tokens=6, cache_layout="paged",
                        page_size=4, prefill_chunk=8)
    eng = _engine((2, 2), serve)
    rng = np.random.RandomState(5)
    short = tuple(int(t) for t in rng.randint(0, eng.cfg.vocab_size, 6))
    long = tuple(int(t) for t in rng.randint(0, eng.cfg.vocab_size, 27))
    eng.submit(short)                            # rid 0: admitted first
    eng.submit(long)                             # rid 1: 4 chunks of 8
    done = {c.rid: c for c in eng.run()}
    chunk_steps = [e[1] for e in eng.events
                   if e[0] == "prefill_chunk" and 1 in e[2]]
    assert len(chunk_steps) == 4                 # ceil(27/8) chunks ran
    assert [e[3] for e in eng.events
            if e[0] == "prefill_chunk" and 1 in e[2]] == [0, 1, 2, 3]
    # rid 0 decodes BETWEEN rid 1's chunks: every gap holds a decode step
    gaps = [list(range(a + 1, b)) for a, b in
            zip(chunk_steps, chunk_steps[1:])]
    assert all(any(eng.step_kinds[s] == "decode" for s in gap)
               for gap in gaps), (chunk_steps, eng.step_kinds)
    # the long request is admitted only once its LAST chunk commits
    admit_1 = next(e for e in eng.events
                   if e[0] == "admit" and e[2] == 1)
    assert admit_1[1] == chunk_steps[-1]
    # and the interleaved decode did not corrupt either request
    solo = _engine((2, 2), serve)
    assert done[1].tokens == solo.run([long])[0].tokens
    solo2 = _engine((2, 2), serve)
    assert done[0].tokens == solo2.run([short])[0].tokens


# ---------------------------------------------------------------------------
# Prefix sharing (CoW) and refcount lifecycle
# ---------------------------------------------------------------------------

def test_prefix_share_then_diverge_cow():
    serve = dataclasses.replace(PAGED, prefill_batch=1)
    eng = _engine(None, serve)
    base = tuple(range(1, 12))                   # 11 tokens: 2 full + partial
    eng.run([base])
    fork = base[:10] + (99, 98)                  # diverges inside page 2
    done = eng.run([fork])[0]
    cs = eng.cache_stats()
    assert cs["prefix_hits"] == 1
    assert cs["shared_pages_reused"] == 2        # the full pages
    assert cs["cow_copies"] == 1                 # the boundary page
    # sharing is invisible in the tokens: fresh engine agrees exactly
    fresh = _engine(None, serve)
    assert done.tokens == fresh.run([fork])[0].tokens
    # retirement released every non-registry refcount; draining the
    # registry returns the pool to empty
    for part in range(eng.geom.n_partitions):
        while eng.prefix.evict_one(part):
            pass
    assert eng.allocator.resident_pages == 0


# ---------------------------------------------------------------------------
# Exhaustion -> admission backpressure
# ---------------------------------------------------------------------------

def test_pool_exhaustion_backpressures_admission():
    serve = ServeConfig(max_batch=4, prefill_batch=2, bucket_edges=(8,),
                        max_new_tokens=4, cache_layout="paged",
                        page_size=4, n_pages=6)  # 2 full requests' worth
    eng = _engine(None, serve)
    trace = _trace(serve, eng.cfg.vocab_size, 6, seed=3)
    done = eng.run(trace)
    assert len(done) == len(trace)               # nobody starves
    cs = eng.cache_stats()
    assert cs["admission_blocked"] > 0           # pressure actually hit
    assert cs["peak_resident_pages"] <= 6
    assert cs["peak_resident_slots"] <= 2
    # blocked admissions still produce the exact sequential tokens
    solo = _engine(None, serve)
    assert done[-1].tokens == solo.run([trace[-1]])[0].tokens


# ---------------------------------------------------------------------------
# The memory story: >= 4x resident slots at equal cache HBM
# ---------------------------------------------------------------------------

def test_paged_4x_resident_slots_at_equal_hbm():
    slab = ServeConfig(max_batch=2, prefill_batch=2, bucket_edges=(32,),
                       max_new_tokens=4)
    # pool sized to the SLAB engine's cache bytes: 2 slots x 36 positions
    # = 72 = 18 pages of 4 — but serving 8 slots of short requests
    paged = ServeConfig(max_batch=8, prefill_batch=8, bucket_edges=(32,),
                        max_new_tokens=4, cache_layout="paged",
                        page_size=4, n_pages=18)
    es = _engine(None, slab)
    ep = _engine(None, paged)
    assert paging.pool_hbm_bytes(ep.cfg, ep.geom) == \
        paging.slab_hbm_bytes(es.cfg, slab.max_batch, es.s_max)
    rng = np.random.RandomState(11)
    trace = [tuple(int(t) for t in rng.randint(0, ep.cfg.vocab_size, 4))
             for _ in range(8)]                  # 4+4 tokens -> 2 pages each
    es.run(trace)
    ep.run(trace)
    ss, sp = es.cache_stats(), ep.cache_stats()
    assert ss["hbm_bytes"] == sp["hbm_bytes"]
    assert ss["peak_resident_slots"] == 2
    assert sp["peak_resident_slots"] >= 4 * ss["peak_resident_slots"]


# ---------------------------------------------------------------------------
# Plan record carries the cache geometry
# ---------------------------------------------------------------------------

def test_plan_record_paged_cache_section(mesh22):
    cfg = get_config("tinyllama-1.1b").reduced()
    run = RunConfig(dp_axes=("data",), fsdp=False)
    rules = ShardingRules(mesh22, run)
    rec = serving_plan_record(cfg, run, rules, PAGED)
    cache = rec["cache"]
    assert cache["layout"] == "paged"
    assert cache["page_size"] == 4 and cache["prefill_chunk"] == 8
    assert cache["n_partitions"] == 2
    assert cache["pool_bytes"] == cache["slab_bytes"]  # default pool size
    assert int(cache["resident_capacity"]["8"]) >= 1
    # chunked prefill collapses the prefill inventory to ONE program
    assert set(rec["buckets"]) == {"prefill@chunk8", "decode"}
    assert rec["buckets"]["prefill@chunk8"]["seq"] == 8
    # the paged decode island keeps the slab decode's name (plan reuse)
    names = {p["island"] for p in rec["buckets"]["decode"]["islands"]}
    assert "decode_attn" in names
