"""Per-arch smoke tests (assignment deliverable f): every assigned
architecture instantiates a REDUCED same-family config and runs one
forward/train step + one decode step on CPU, asserting output shapes and
no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, cells_for
from repro.configs.base import RunConfig
from repro.models import (cache_template, decode_step, decode_step_encdec,
                          forward_prefill, forward_train, init_params,
                          param_template)

RUN = RunConfig()


def _batch(cfg, b, s):
    batch = {"tokens": jnp.zeros((b, s), jnp.int32),
             "targets": jnp.ones((b, s), jnp.int32),
             "weights": jnp.ones((b, s), jnp.float32)}
    if cfg.frontend == "vision":
        batch["frontend_embeds"] = jnp.ones(
            (b, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encoder_decoder:
        batch["enc_embeds"] = jnp.ones((b, s, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_train_smoke(arch):
    cfg = get_config(arch).reduced()
    params = init_params(param_template(cfg, RUN, None),
                         jax.random.PRNGKey(0), cfg.d_model)
    loss, metrics = forward_train(params, _batch(cfg, 2, 32), cfg, RUN, None)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_smoke(arch):
    cfg = get_config(arch).reduced()
    params = init_params(param_template(cfg, RUN, None),
                         jax.random.PRNGKey(0), cfg.d_model)
    b, s = 2, 32
    ct = cache_template(cfg, RUN, None, batch=b, s_max=s,
                        enc_len=s if cfg.encoder_decoder else 0)
    cache = init_params(ct, jax.random.PRNGKey(1), cfg.d_model)
    step = decode_step_encdec if cfg.encoder_decoder else decode_step
    logits, cache2 = step(params, cache, jnp.zeros((b, 1), jnp.int32),
                          cfg, RUN, None)
    assert logits.shape == (b, 1, cfg.padded_vocab()), arch
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch
    assert int(cache2["pos"]) == 1


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "falcon-mamba-7b",
                                  "moonshot-v1-16b-a3b", "whisper-medium"])
def test_prefill_smoke(arch):
    cfg = get_config(arch).reduced()
    params = init_params(param_template(cfg, RUN, None),
                         jax.random.PRNGKey(0), cfg.d_model)
    logits = forward_prefill(params, _batch(cfg, 2, 32), cfg, RUN, None)
    assert logits.shape == (2, 1, cfg.padded_vocab())
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


def test_cells_for_skips():
    """DESIGN §6: long_500k only for sub-quadratic archs."""
    assert "long_500k" in cells_for("falcon-mamba-7b")
    assert "long_500k" in cells_for("jamba-1.5-large-398b")
    assert "long_500k" in cells_for("h2o-danube-3-4b")
    for a in ("internlm2-20b", "starcoder2-15b", "tinyllama-1.1b",
              "whisper-medium", "moonshot-v1-16b-a3b", "grok-1-314b",
              "internvl2-26b"):
        assert "long_500k" not in cells_for(a)
    total = sum(len(cells_for(a)) for a in ARCH_IDS)
    assert total == 33


def test_param_counts_match_names():
    approx = {"jamba-1.5-large-398b": 398e9, "grok-1-314b": 314e9,
              "tinyllama-1.1b": 1.1e9, "falcon-mamba-7b": 7.3e9,
              "starcoder2-15b": 15e9, "internlm2-20b": 20e9}
    for arch, want in approx.items():
        got = get_config(arch).param_count()
        assert 0.8 * want < got < 1.25 * want, (arch, got, want)
