"""Sequence parallelism (ring / Ulysses / SSM state ring) and expert
parallelism (replicated + a2a dispatch) vs oracles."""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from jax.sharding import PartitionSpec as P

from repro.core import (moe_reference_dense, pk_moe_a2a, pk_moe_replicated,
                        pk_ring_attention, pk_ulysses_attention,
                        ring_attention_baseline, ssm_entry_states, ep_tp_split)

N = 4


@pytest.fixture(scope="module")
def sm(mesh4):
    return partial(compat.shard_map, mesh=mesh4, check_vma=False)


def _ref_attn(q, k, v, causal=True, window=None):
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, s, d)
    sc = jnp.einsum("bkgqd,bksd->bkgqs", qg, k).astype(jnp.float32) * d ** -0.5
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    keep = jnp.ones((s, s), bool)
    if causal:
        keep = ki <= qi
    if window is not None:
        keep &= ki > qi - window
    sc = jnp.where(keep, sc, -1e30)
    p = jax.nn.softmax(sc, -1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, s, d)


@pytest.mark.parametrize("fn", [pk_ring_attention, ring_attention_baseline,
                                pk_ulysses_attention])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 12),
                                           (False, None)])
def test_sp_attention(sm, fn, causal, window):
    b, hq, hkv, s, d = 2, 8, 2, 32, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (b, hq, s, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, s, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s, d))
    f = jax.jit(sm(lambda q, k, v: fn(q, k, v, "x", causal=causal,
                                      window=window),
                   in_specs=(P(None, None, "x"),) * 3,
                   out_specs=P(None, None, "x")))
    np.testing.assert_allclose(np.asarray(f(q, k, v)),
                               np.asarray(_ref_attn(q, k, v, causal, window)),
                               rtol=1e-4, atol=1e-4)


def test_ring_attention_grad(sm):
    b, hq, hkv, s, d = 1, 4, 2, 16, 8
    q = jax.random.normal(jax.random.PRNGKey(0), (b, hq, s, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, s, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s, d))

    def make(fn):
        f = sm(lambda q, k, v: jax.lax.psum(
            jnp.sum(fn(q, k, v, "x", causal=True) ** 2), "x") / N,
            in_specs=(P(None, None, "x"),) * 3, out_specs=P())
        return jax.jit(jax.grad(lambda q: f(q, k, v)))(q)

    np.testing.assert_allclose(np.asarray(make(pk_ring_attention)),
                               np.asarray(make(ring_attention_baseline)),
                               rtol=1e-3, atol=1e-3)


def test_ssm_entry_states(sm):
    dm, ns = 6, 5
    a = jax.random.uniform(jax.random.PRNGKey(0), (N, dm, ns),
                           minval=0.5, maxval=0.99)
    sx = jax.random.normal(jax.random.PRNGKey(1), (N, dm, ns))
    h = jnp.zeros((dm, ns))
    want = []
    for i in range(N):
        want.append(h)
        h = a[i] * h + sx[i]
    f = jax.jit(sm(lambda a, s: ssm_entry_states(a[0], s[0], "x")[None],
                   in_specs=(P("x"), P("x")), out_specs=P("x")))
    np.testing.assert_allclose(np.asarray(f(a, sx)),
                               np.asarray(jnp.stack(want)), rtol=1e-5,
                               atol=1e-5)


def test_ep_tp_split():
    assert ep_tp_split(64, 16) == (16, 1)
    assert ep_tp_split(16, 16) == (16, 1)
    assert ep_tp_split(8, 16) == (8, 2)
    assert ep_tp_split(4, 16) == (4, 4)
    assert ep_tp_split(8, 1) == (1, 1)


@pytest.mark.parametrize("strategy", ["replicated", "a2a"])
def test_moe_vs_dense_oracle(sm, strategy):
    t, d, ff, e, k = 32, 16, 24, 8, 2
    x = jax.random.normal(jax.random.PRNGKey(0), (t, d))
    wr = jax.random.normal(jax.random.PRNGKey(4), (d, e))
    w1 = jax.random.normal(jax.random.PRNGKey(5), (e, d, ff)) * 0.1
    w3 = jax.random.normal(jax.random.PRNGKey(6), (e, d, ff)) * 0.1
    w2 = jax.random.normal(jax.random.PRNGKey(7), (e, ff, d)) * 0.1
    want, _ = moe_reference_dense(x, wr, w1, w3, w2, n_experts=e, top_k=k)
    dm = lambda w: w.reshape(N, e // N, *w.shape[1:])
    cap = float(e) / k                       # capacity covers every token
    if strategy == "replicated":
        f = jax.jit(sm(lambda x, wr, a, b, c: pk_moe_replicated(
            x, wr, a[0], b[0], c[0], axis_name="x", n_experts=e, top_k=k,
            capacity_factor=cap)[0],
            in_specs=(P(), P(), P("x"), P("x"), P("x")), out_specs=P()))
        got = f(x, wr, dm(w1), dm(w3), dm(w2))
    else:
        f = jax.jit(sm(lambda x, wr, a, b, c: pk_moe_a2a(
            x, wr, a[0], b[0], c[0], axis_name="x", n_experts=e, top_k=k,
            capacity_factor=cap)[0],
            in_specs=(P("x"), P(), P("x"), P("x"), P("x")),
            out_specs=P("x")))
        got = f(x, wr, dm(w1), dm(w3), dm(w2))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_moe_a2a_chunked_matches_bulk(sm):
    t, d, ff, e, k = 32, 16, 24, 8, 2
    x = jax.random.normal(jax.random.PRNGKey(0), (t, d))
    wr = jax.random.normal(jax.random.PRNGKey(4), (d, e))
    w1 = jax.random.normal(jax.random.PRNGKey(5), (e, d, ff)) * 0.1
    w3 = jax.random.normal(jax.random.PRNGKey(6), (e, d, ff)) * 0.1
    w2 = jax.random.normal(jax.random.PRNGKey(7), (e, ff, d)) * 0.1
    dm = lambda w: w.reshape(N, e // N, *w.shape[1:])

    def run(n_chunks):
        f = jax.jit(sm(lambda x, wr, a, b, c: pk_moe_a2a(
            x, wr, a[0], b[0], c[0], axis_name="x", n_experts=e, top_k=k,
            capacity_factor=2.0, n_chunks=n_chunks)[0],
            in_specs=(P("x"), P(), P("x"), P("x"), P("x")),
            out_specs=P("x")))
        return np.asarray(f(x, wr, dm(w1), dm(w3), dm(w2)))

    np.testing.assert_allclose(run(1), run(2), rtol=1e-5, atol=1e-5)
