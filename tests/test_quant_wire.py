"""Low-precision wire formats (core.quant + the quantized ring schedules +
the int8 KV cache):

* per-block int8 round-trip error bounds and the chunk-invariance of the
  per-row block layout;
* quantized ring GEMM-collectives == a bulk-quantized reference BIT-EXACT
  for all 3 ops x chunk counts {1, 2, 4} (the rings' bit-identity contract
  carried to the quantized level);
* bounded wire error vs the full-precision baseline, and error-feedback
  convergence (the residual telescopes, so the accumulated compressed sum
  tracks the true sum to one quantization step);
* int8 KV-cache serving: token-identical to its own sequential baseline on
  slab and paged layouts, logits within a bound of the bf16 cache, and the
  bf16 templates byte-for-byte unchanged by the new axis.
"""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs import get_config
from repro.configs.base import RunConfig, ServeConfig
from repro.core.comms import CommContext
from repro.core.quant import (BLOCK, ErrorFeedbackInt8, WIRE_FORMATS,
                              dequantize_blocks, quant_dequant,
                              quantize_blocks, resolve_wire)

N = 4


@pytest.fixture(scope="module")
def sm(mesh4):
    return partial(compat.shard_map, mesh=mesh4, check_vma=False)


@pytest.fixture(scope="module")
def ctx(mesh4):
    return CommContext(axis_name="x", mesh=mesh4)


def _run(sm, fn, in_specs, out_specs, *args):
    return np.asarray(jax.jit(sm(fn, in_specs=in_specs,
                                 out_specs=out_specs))(*args))


# ---------------------------------------------------------------------------
# quantize_blocks round trip
# ---------------------------------------------------------------------------

def test_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 300)) * 3.0
    got = np.asarray(dequantize_blocks(*quantize_blocks(x), 300))
    # symmetric RTN: |err| <= scale/2 per element, scale = blockmax/127
    fp = np.asarray(x, np.float32)
    pad = np.pad(fp, [(0, 0), (0, (-300) % BLOCK)])
    scales = np.abs(pad.reshape(32, -1, BLOCK)).max(-1) / 127.0
    bound = np.repeat(scales, BLOCK, axis=-1)[:, :300] / 2 + 1e-6
    assert (np.abs(got - fp) <= bound).all()


def test_row_chunk_invariance():
    """Splitting rows then quantizing == quantizing then splitting — the
    property the ring chunk schedules rely on for bit-exactness."""
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 48))
    q, s = quantize_blocks(x)
    for c in (2, 4):
        rows = 16 // c
        for j in range(c):
            qj, sj = quantize_blocks(x[j * rows:(j + 1) * rows])
            np.testing.assert_array_equal(qj, q[j * rows:(j + 1) * rows])
            np.testing.assert_array_equal(sj, s[j * rows:(j + 1) * rows])


def test_wire_format_registry():
    assert set(WIRE_FORMATS) == {"bf16", "int8", "int8_sr"}
    assert resolve_wire(None) is None and resolve_wire("bf16") is None
    fmt = resolve_wire("int8")
    assert fmt.quantized and fmt.dtype_bytes == 1
    assert fmt.bytes_per_element == 1 + 4.0 / fmt.block
    assert resolve_wire("int8_sr").stochastic_round


# ---------------------------------------------------------------------------
# Quantized rings == bulk-quantized reference, bit-exact across chunk counts
# ---------------------------------------------------------------------------

@jax.jit
def _ag_ref(x, w):
    """Bulk-quantized AG+GEMM: every shard quantized per-row once, every
    consumer GEMMs the dequantized values. Jitted so the dots compile the
    same way the shard_map body's do — bit-exact, not merely close."""
    m_loc = x.shape[0] // N
    outs = []
    for d in range(N):
        xs = x[d * m_loc:(d + 1) * m_loc]
        dq = dequantize_blocks(*quantize_blocks(xs), x.shape[1])
        outs.append(jnp.dot(dq, w,
                            preferred_element_type=jnp.float32).astype(
                                x.dtype))
    return jnp.concatenate(outs, 0)


def _rs_sim(x, w):
    """Accumulate-and-forward ring with quantized hops, simulated densely:
    for destination block j the contributions arrive in ring order with a
    quantize->dequantize between consecutive adds."""
    m_blk = x.shape[0] // N
    k_loc = x.shape[1] // N
    n_out = w.shape[1]

    def partial_(j, d):
        xs = x[j * m_blk:(j + 1) * m_blk, d * k_loc:(d + 1) * k_loc]
        return jnp.dot(xs, w[d * k_loc:(d + 1) * k_loc],
                       preferred_element_type=jnp.float32)

    outs = []
    for j in range(N):
        acc = partial_(j, (j - 1) % N)
        for i in range(1, N):
            acc = dequantize_blocks(*quantize_blocks(acc), n_out) \
                + partial_(j, (j - 1 - i) % N)
        outs.append(acc.astype(x.dtype))
    return outs


@jax.jit
def _rs_ref(x, w):
    return jnp.concatenate(_rs_sim(x, w), 0)


@jax.jit
def _ar_ref(x, w):
    """Quantized RS chain + one more quantized hop for the trailing gather."""
    n_out = w.shape[1]
    outs = []
    for blk in _rs_sim(x, w):
        outs.append(dequantize_blocks(
            *quantize_blocks(blk.astype(jnp.float32)), n_out).astype(x.dtype))
    return jnp.concatenate(outs, 0)


@pytest.mark.parametrize("nc", [1, 2, 4])
def test_quantized_rings_bit_exact(sm, ctx, nc):
    x_ag = jax.random.normal(jax.random.PRNGKey(0), (8 * N, 16))
    w_ag = jax.random.normal(jax.random.PRNGKey(1), (16, 12))
    x_rs = jax.random.normal(jax.random.PRNGKey(2), (16, 8 * N))
    w_rs = jax.random.normal(jax.random.PRNGKey(3), (8 * N, 12))

    cases = {
        ("all_gather_matmul", "ring"): (
            ctx.all_gather_matmul, (x_ag, w_ag), (P("x"), P()), P(),
            np.asarray(_ag_ref(x_ag, w_ag))),
        ("all_gather_matmul", "ring_bidir"): (
            ctx.all_gather_matmul, (x_ag, w_ag), (P("x"), P()), P(),
            np.asarray(_ag_ref(x_ag, w_ag))),
        ("matmul_reduce_scatter", "ring"): (
            ctx.matmul_reduce_scatter, (x_rs, w_rs),
            (P(None, "x"), P("x", None)), P("x", None),
            np.asarray(_rs_ref(x_rs, w_rs))),
        ("matmul_all_reduce", "ring"): (
            ctx.matmul_all_reduce, (x_rs, w_rs),
            (P(None, "x"), P("x", None)), P(),
            np.asarray(_ar_ref(x_rs, w_rs))),
    }
    for (op, be), (meth, args, in_specs, out_specs, want) in cases.items():
        got = _run(sm, partial(meth, backend=be, n_chunks=nc, wire="int8"),
                   in_specs, out_specs, *args)
        np.testing.assert_array_equal(got, want,
                                      err_msg=f"{op}/{be}/c={nc}")


def test_quantized_wire_error_bounded(sm, ctx):
    """The int8 wire stays close to the full-precision result — the payoff
    is wire bytes, not semantics."""
    x = jax.random.normal(jax.random.PRNGKey(4), (8 * N, 16))
    w = jax.random.normal(jax.random.PRNGKey(5), (16, 12))
    want = np.asarray(jnp.dot(x, w))
    for wire in ("int8", "int8_sr"):
        got = _run(sm, partial(ctx.all_gather_matmul, backend="ring",
                               wire=wire), (P("x"), P()), P(), x, w)
        np.testing.assert_allclose(got, want, rtol=0.05, atol=0.15,
                                   err_msg=wire)


def test_sr_wire_differs_from_rtn(sm, ctx):
    x = jax.random.normal(jax.random.PRNGKey(6), (8 * N, 16))
    w = jax.random.normal(jax.random.PRNGKey(7), (16, 12))
    a = _run(sm, partial(ctx.all_gather_matmul, backend="ring",
                         wire="int8"), (P("x"), P()), P(), x, w)
    b = _run(sm, partial(ctx.all_gather_matmul, backend="ring",
                         wire="int8_sr"), (P("x"), P()), P(), x, w)
    assert not np.array_equal(a, b)


# ---------------------------------------------------------------------------
# Error feedback
# ---------------------------------------------------------------------------

def test_error_feedback_telescopes():
    """sum_t deq_t = T*g - r_T: the accumulated compressed gradient tracks
    the true sum to within ONE quantization step, however long the run."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(8), (33, 7)) * 0.01}
    ef = ErrorFeedbackInt8()
    state = ef.init(g)
    T = 30
    total = jnp.zeros_like(g["w"], dtype=jnp.float32)
    for _ in range(T):
        deq, state = ef.transform(g, state)
        total = total + deq["w"]
    err = np.abs(np.asarray(total - T * g["w"].astype(jnp.float32)))
    one_step = np.abs(np.asarray(g["w"])).max() * 2 / 127.0 + 1e-7
    assert err.max() <= one_step
    # and plain quant-dequant of a tiny gradient would NOT converge: its
    # one-shot error is already the same order as the signal EF removes
    naive = T * np.asarray(quant_dequant(g["w"]))
    assert err.max() < np.abs(naive - np.asarray(T * g["w"])).max() + 1e-7


# ---------------------------------------------------------------------------
# int8 KV cache
# ---------------------------------------------------------------------------

def _engine(mesh_shape, serve):
    from repro.launch.serve import build_engine
    return build_engine("tinyllama-1.1b", reduced=True,
                        mesh_shape=mesh_shape, serve=serve)


def _trace(serve, vocab, n, seed=0):
    from repro.launch.serve import synthetic_trace
    return synthetic_trace(n, serve, vocab, seed=seed)


SLAB8 = ServeConfig(max_batch=4, prefill_batch=2, bucket_edges=(8, 16),
                    max_new_tokens=4, kv_dtype="int8")
PAGED8 = ServeConfig(max_batch=4, prefill_batch=2, bucket_edges=(8, 16),
                     max_new_tokens=4, cache_layout="paged", page_size=8,
                     prefill_chunk=8, kv_dtype="int8")


@pytest.mark.parametrize("serve", [SLAB8, PAGED8], ids=["slab", "paged"])
def test_int8_kv_matches_own_sequential(serve):
    """Continuous batching with the int8 cache is still deterministic
    batching: token-identical to a fresh engine serving one request."""
    eng = _engine((2, 2), serve)
    trace = _trace(serve, eng.cfg.vocab_size, 4)
    done = eng.run(trace)
    assert len(done) == len(trace)
    for c in done[:2]:
        solo = _engine((2, 2), serve)
        ref = solo.run([trace[c.rid]])[0]
        assert c.tokens == ref.tokens, (c.rid, c.tokens, ref.tokens)


def test_int8_kv_logits_near_bf16():
    """Same params, same prompt: the int8 cache's decode logits stay within
    a quantization-sized bound of the bf16 cache's."""
    from repro.models import transformer as T
    from repro.models.sharding import ShardingRules  # noqa: F401

    cfg = get_config("tinyllama-1.1b").reduced()
    run = RunConfig(dp_axes=("data",), fsdp=False)
    tmpl = T.param_template(cfg, run, None)
    params = T.init_params(tmpl, jax.random.PRNGKey(0), cfg.d_model)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    outs = {}
    for kd in ("bf16", "int8"):
        ct = T.cache_template(cfg, run, None, batch=2, s_max=16,
                              kv_dtype=kd)
        cache = T.init_params(ct, jax.random.PRNGKey(2), cfg.d_model)
        logits, cache = T.prefill_step(params, cache, tokens, 8, cfg, run,
                                       None)
        l2, _ = T.decode_step(params, cache,
                              jnp.argmax(logits[:, -1:], -1).astype(
                                  jnp.int32), cfg, run, None)
        outs[kd] = (np.asarray(logits, np.float32),
                    np.asarray(l2, np.float32))
    for a, b in zip(outs["bf16"], outs["int8"]):
        scale = np.abs(a).max()
        assert np.abs(a - b).max() <= 0.1 * scale + 0.1, \
            (np.abs(a - b).max(), scale)


def test_bf16_templates_unchanged_by_kv_axis():
    """kv_dtype='bf16' must be byte-for-byte the pre-axis tree: no scale
    leaves, identical shapes/dtypes/specs."""
    from repro.models import transformer as T
    from repro.runtime import paging

    cfg = get_config("tinyllama-1.1b").reduced()
    run = RunConfig(dp_axes=("data",), fsdp=False)
    a = T.cache_template(cfg, run, None, batch=2, s_max=16)
    b = T.cache_template(cfg, run, None, batch=2, s_max=16, kv_dtype="bf16")
    assert jax.tree_util.tree_structure(a) == jax.tree_util.tree_structure(b)
    for pa, pb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert pa == pb
    for blk in T.cache_template(cfg, run, None, batch=2, s_max=16,
                                kv_dtype="int8")["blocks"].values():
        assert set(blk) == {"k", "v", "k_scale", "v_scale"}
        assert blk["k"].dtype == jnp.int8
        assert blk["k_scale"].dtype == jnp.float32
        assert blk["k_scale"].shape == blk["k"].shape[:-1]
    geom = paging.resolve_page_geometry(PAGED8, s_max=16)
    pt = paging.paged_cache_template(cfg, run, None, batch=2, geom=geom,
                                     kv_dtype="int8")
    for blk in pt["blocks"].values():
        assert blk["k"].dtype == jnp.int8
        assert blk["k_scale"].shape == blk["k"].shape[:-1]


def test_int8_cache_bytes_shrink():
    from repro.runtime import paging

    cfg = get_config("tinyllama-1.1b").reduced()
    b16 = paging.slab_hbm_bytes(cfg, 4, 64)
    b8 = paging.slab_hbm_bytes(cfg, 4, 64, kv_dtype="int8")
    # (hd + 4 scale bytes) vs 2*hd per (pos, head, K|V)
    assert b8 * (2 * cfg.hd) == b16 * (cfg.hd + 4)
    assert b8 < b16
    geom = paging.resolve_page_geometry(PAGED8, s_max=64)
    assert paging.pool_hbm_bytes(cfg, geom, kv_dtype="int8") < \
        paging.pool_hbm_bytes(cfg, geom)


def test_serve_config_validates_kv_dtype():
    with pytest.raises(ValueError):
        ServeConfig(kv_dtype="fp4")


def test_plan_record_reports_wire_and_kv(mesh22):
    from repro.models.sharding import ShardingRules
    from repro.runtime.serving import serving_plan_record

    cfg = get_config("tinyllama-1.1b").reduced()
    run = RunConfig(dp_axes=("data",), fsdp=False, comm_wire="int8")
    rules = ShardingRules(mesh22, run)
    rec = serving_plan_record(cfg, run, rules, SLAB8)
    assert rec["comm_wire"] == "int8"
    assert rec["cache"]["kv_dtype"] == "int8"
    assert rec["cache"]["scale_bytes_per_pos"] == \
        cfg.n_layers * cfg.n_kv_heads * 2 * 4
    rec16 = serving_plan_record(cfg, RunConfig(dp_axes=("data",),
                                               fsdp=False), rules,
                                ServeConfig())
    assert rec16["comm_wire"] == "bf16"
    assert rec16["cache"]["scale_bytes_per_pos"] == 0
