"""All assigned archs on a 2x2 (data x model) mesh with PK islands: train
forward+grads finite, sharded decode runs, prefill/decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import RunConfig
from repro.models import (cache_template, decode_step, decode_step_encdec,
                          forward_train, init_params, param_template)
from repro.models.sharding import ShardingRules
from repro.models.transformer import param_specs


def _setup(arch, mesh, run):
    cfg = get_config(arch).reduced()
    rules = ShardingRules(mesh, run)
    tmpl = param_template(cfg, run, rules)
    params = init_params(tmpl, jax.random.PRNGKey(0), cfg.d_model)
    params = jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        params, param_specs(tmpl))
    return cfg, rules, params


def _batch(cfg, b, s):
    batch = {"tokens": jnp.zeros((b, s), jnp.int32),
             "targets": jnp.ones((b, s), jnp.int32),
             "weights": jnp.ones((b, s), jnp.float32)}
    if cfg.frontend == "vision":
        batch["frontend_embeds"] = jnp.ones(
            (b, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encoder_decoder:
        batch["enc_embeds"] = jnp.ones((b, s, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_sharded_train_and_decode(arch, mesh22):
    run = RunConfig(dp_axes=("data",), fsdp=True, pk_overlap=True)
    cfg, rules, params = _setup(arch, mesh22, run)
    b, s = 4, 32
    batch = _batch(cfg, b, s)

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p, bt: forward_train(p, bt, cfg, run, rules)[0]))(params, batch)
    assert np.isfinite(float(loss)), arch
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch

    ct = cache_template(cfg, run, rules, batch=b, s_max=s,
                        enc_len=s if cfg.encoder_decoder else 0)
    cache = init_params(ct, jax.random.PRNGKey(1), cfg.d_model)
    cache = jax.tree.map(
        lambda x, sp: jax.device_put(x, NamedSharding(mesh22, sp)),
        cache, param_specs(ct))
    step = decode_step_encdec if cfg.encoder_decoder else decode_step
    logits, _ = jax.jit(lambda p, c, t: step(p, c, t, cfg, run, rules))(
        params, cache, jnp.zeros((b, 1), jnp.int32))
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch


def test_pk_vs_baseline_same_loss(mesh22):
    """PK overlapped islands must not change the math."""
    arch = "tinyllama-1.1b"
    batchd = None
    losses = {}
    for pk in (True, False):
        run = RunConfig(dp_axes=("data",), fsdp=True, pk_overlap=pk)
        cfg, rules, params = _setup(arch, mesh22, run)
        batch = _batch(cfg, 4, 32)
        loss, _ = jax.jit(lambda p, bt, run=run, rules=rules:
                          forward_train(p, bt, cfg, run, rules))(params, batch)
        losses[pk] = float(loss)
    assert abs(losses[True] - losses[False]) < 2e-2, losses


def test_decode_matches_teacher_forcing(mesh22):
    """Token-by-token decode with the sharded KV cache must reproduce the
    prefill (full-forward) logits — the serving-path correctness oracle."""
    from repro.models import forward_prefill
    arch = "tinyllama-1.1b"
    run = RunConfig(dp_axes=("data",), fsdp=False, pk_overlap=False)
    cfg, rules, params = _setup(arch, mesh22, run)
    b, s = 2, 8
    key = jax.random.PRNGKey(3)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)

    logits_full = forward_prefill(params, {"tokens": toks}, cfg, run, rules)

    ct = cache_template(cfg, run, rules, batch=b, s_max=s)
    cache = init_params(ct, jax.random.PRNGKey(1), cfg.d_model)
    cache = jax.tree.map(
        lambda x, sp: jax.device_put(x, NamedSharding(mesh22, sp)),
        cache, param_specs(ct))
    step = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg, run, rules))
    for i in range(s):
        logits_step, cache = step(params, cache, toks[:, i:i + 1])
    np.testing.assert_allclose(
        np.asarray(logits_step[:, 0]), np.asarray(logits_full[:, 0]),
        rtol=2e-2, atol=2e-2)
