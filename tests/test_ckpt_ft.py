"""Fault tolerance: atomic checkpoints, crash->resume equivalence, straggler
watchdog, elastic (mesh-resize) restore including MoE layout conversion."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.ckpt.manager import CheckpointManager
from repro.core.moe_layout import dm_to_logical, logical_to_dm
from repro.runtime.straggler import StragglerWatchdog


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 4)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32),
                       "c": jnp.ones((3,), jnp.bfloat16)}}


def test_ckpt_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    state = _tree()
    mgr.save(10, state, {"note": "x"})
    restored, extra = mgr.restore(state)
    assert extra["step"] == 10 and extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_ckpt_keep_n_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_ckpt_async_and_atomic(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, async_save=True)
    mgr.save(5, _tree())
    mgr.wait()
    assert mgr.latest_step() == 5
    assert not list(tmp_path.glob("tmp_*")), "tmp dir must be renamed away"


def test_crash_resume_equivalence(tmp_path):
    """Train 12 steps with a crash at step 7 + restart == uninterrupted run
    (exactly — data cursor and optimizer state both live in the checkpoint)."""
    from repro.launch.train import build_and_train

    class Crash(Exception):
        pass

    def hook(step):
        if step == 7:
            raise Crash()

    kw = dict(steps=12, reduced=True, mesh_shape=None, mesh_axes=None,
              batch=2, seq=16, lr=1e-3, log_every=1, ckpt_every=3)
    with pytest.raises(Crash):
        build_and_train("tinyllama-1.1b", ckpt_dir=str(tmp_path / "crash"),
                        fault_hook=hook, **kw)
    _, log_resumed = build_and_train(
        "tinyllama-1.1b", ckpt_dir=str(tmp_path / "crash"), **kw)
    _, log_clean = build_and_train(
        "tinyllama-1.1b", ckpt_dir=str(tmp_path / "clean"), **kw)
    assert log_resumed[-1]["step"] == log_clean[-1]["step"] == 12
    np.testing.assert_allclose(log_resumed[-1]["loss"],
                               log_clean[-1]["loss"], rtol=1e-4)


def test_straggler_watchdog():
    wd = StragglerWatchdog(factor=3.0, min_samples=3)
    for i in range(5):
        assert not wd.record(i, 0.10)
    assert wd.record(5, 0.45)          # 4.5x EMA -> straggler
    assert wd.events[0]["step"] == 5
    assert not wd.record(6, 0.11)      # EMA not poisoned by the straggler
    assert abs(wd.ema - 0.10) < 0.02


@pytest.mark.parametrize("e,m1,m2", [(8, 4, 2), (16, 4, 8), (8, 2, 8),
                                     (4, 8, 2)])
def test_moe_layout_roundtrip(e, m1, m2):
    d, ff = 8, 16
    rng = np.random.default_rng(0)
    logical = rng.normal(size=(e, d, ff)).astype(np.float32)
    dm1 = logical_to_dm(logical, m1)
    np.testing.assert_array_equal(dm_to_logical(dm1, e), logical)
    # convert across mesh sizes through logical
    dm2 = logical_to_dm(dm_to_logical(dm1, e), m2)
    np.testing.assert_array_equal(dm_to_logical(dm2, e), logical)
    # w2 orientation
    logical2 = rng.normal(size=(e, ff, d)).astype(np.float32)
    dmw2 = logical_to_dm(logical2, m1, w2=True)
    np.testing.assert_array_equal(dm_to_logical(dmw2, e, w2=True), logical2)


@pytest.mark.parametrize("e,m1,m2", [(6, 4, 2), (6, 2, 4), (12, 8, 2)])
def test_moe_converter_non_dividing_experts(e, m1, m2):
    """moe_converter round-trip at expert counts the model axis does NOT
    divide (ep = gcd(e, m) < m): per-period stacked w1/w2 convert
    m1 -> m2 -> m1 bit-exactly, and non-MoE keys pass through untouched."""
    import types

    from repro.runtime.elastic import moe_converter

    cfg = types.SimpleNamespace(is_moe=True, n_experts=e)
    d, ff = 4, 16                         # ff divisible by every tp_ff here
    rng = np.random.default_rng(0)
    periods = 2
    log_w1 = rng.normal(size=(e, d, ff)).astype(np.float32)
    log_w2 = rng.normal(size=(e, ff, d)).astype(np.float32)
    dm1_w1 = np.stack([logical_to_dm(log_w1, m1) for _ in range(periods)])
    dm1_w2 = np.stack([logical_to_dm(log_w2, m1, w2=True)
                       for _ in range(periods)])

    fwd = moe_converter(cfg, m1, m2)
    bwd = moe_converter(cfg, m2, m1)
    dm2_w1 = fwd("layers/moe/w1", dm1_w1)
    dm2_w2 = fwd("layers/moe/w2", dm1_w2)
    # target layout is exactly logical_to_dm at m2, every period
    for p in range(periods):
        np.testing.assert_array_equal(dm2_w1[p], logical_to_dm(log_w1, m2))
        np.testing.assert_array_equal(
            dm2_w2[p], logical_to_dm(log_w2, m2, w2=True))
    # and the round trip is exact
    np.testing.assert_array_equal(bwd("layers/moe/w1", dm2_w1), dm1_w1)
    np.testing.assert_array_equal(bwd("layers/moe/w2", dm2_w2), dm1_w2)
    # non-MoE keys (and non-w{1,2,3} leaves) pass through untouched
    other = rng.normal(size=(3, 5)).astype(np.float32)
    assert fwd("layers/attn/wq", other) is other
    assert fwd("layers/moe/gate", other) is other


def test_moe_converter_identity_cases():
    import types

    from repro.runtime.elastic import moe_converter

    assert moe_converter(types.SimpleNamespace(is_moe=True, n_experts=8),
                         4, 4) is None
    assert moe_converter(types.SimpleNamespace(is_moe=False, n_experts=0),
                         4, 2) is None


def test_train_driver_direct_resume(tmp_path):
    """TrainDriver unit coverage without the launch wrapper: periodic
    saves, crash at a scripted step, auto-resume from the newest committed
    checkpoint, and bit-identical final state vs an uninterrupted run."""
    from repro.runtime.driver import DriverConfig, TrainDriver

    def train_step(state, batch):
        new = {"w": state["w"] * 0.9 + batch}
        return new, {"loss": jnp.sum(new["w"])}

    class Data:
        def batch(self, step):
            return jnp.float32(step + 1)

    def fresh():
        return {"w": jnp.float32(1.0)}

    cfg = DriverConfig(total_steps=6, ckpt_every=2, log_every=2,
                       async_checkpoint=False)

    class Crash(Exception):
        pass

    def hook(step):
        if step == 4 and not hook.done:
            hook.done = True
            raise Crash()
    hook.done = False

    d1 = TrainDriver(train_step=train_step, state=fresh(), data=Data(),
                     ckpt_dir=str(tmp_path / "a"), cfg=cfg, fault_hook=hook)
    with pytest.raises(Crash):
        d1.run()
    # restart: resumes from step 4's checkpoint, not from scratch
    d2 = TrainDriver(train_step=train_step, state=fresh(), data=Data(),
                     ckpt_dir=str(tmp_path / "a"), cfg=cfg, fault_hook=hook)
    assert d2.start_step == 4
    state_resumed, log = d2.run()
    clean = TrainDriver(train_step=train_step, state=fresh(), data=Data(),
                        ckpt_dir=str(tmp_path / "b"), cfg=cfg)
    state_clean, _ = clean.run()
    np.testing.assert_array_equal(np.asarray(state_resumed["w"]),
                                  np.asarray(state_clean["w"]))
    assert log[-1]["step"] == 6
    # the final checkpoint is committed (atomic rename, no tmp debris)
    assert d2.ckpt.latest_step() == 6
    assert not list((tmp_path / "a").glob("tmp_*"))


def test_elastic_restore_across_meshes(tmp_path):
    """Save params sharded on (1,2), restore onto (2,1) — values identical."""
    from repro.configs import get_config
    from repro.configs.base import RunConfig
    from repro.launch.mesh import make_mesh
    from repro.models import transformer as T
    from repro.models.sharding import ShardingRules
    from repro.runtime.elastic import elastic_restore

    cfg = get_config("moonshot-v1-16b-a3b").reduced()
    run = RunConfig(dp_axes=("data",), fsdp=True)
    mesh1 = make_mesh((1, 2), ("data", "model"))
    rules1 = ShardingRules(mesh1, run)
    tmpl1 = T.param_template(cfg, run, rules1)
    params1 = T.init_params(tmpl1, jax.random.PRNGKey(0), cfg.d_model)

    mgr = CheckpointManager(tmp_path, async_save=False)
    mgr.save(1, params1)

    mesh2 = make_mesh((2, 1), ("data", "model"))
    restored, _ = elastic_restore(str(tmp_path), cfg, run, mesh2,
                                  old_model_size=2)
    # logical equality of MoE weights across layouts
    p1 = jax.tree_util.tree_leaves_with_path(params1)
    flat2 = {"/".join(str(getattr(q, 'key', q)) for q in path): leaf
             for path, leaf in jax.tree_util.tree_leaves_with_path(restored)}
    for path, leaf in p1:
        key = "/".join(str(getattr(q, 'key', q)) for q in path)
        a, b = np.asarray(leaf, np.float32), np.asarray(flat2[key], np.float32)
        if "moe" in key and key.split("/")[-1] in ("w1", "w2", "w3"):
            w2flag = key.endswith("w2")
            la = np.stack([dm_to_logical(a[i], cfg.n_experts, w2=w2flag)
                           for i in range(a.shape[0])])
            lb = np.stack([dm_to_logical(b[i], cfg.n_experts, w2=w2flag)
                           for i in range(b.shape[0])])
            np.testing.assert_array_equal(la, lb)
        else:
            np.testing.assert_array_equal(a, b)
