"""Serving fleet (runtime/fleet.py): deterministic routing, fleet ==
single-engine token identity, the scripted fault matrix (kill mid-decode,
kill mid-prefill-chunk, kill while draining — every request completes
exactly once), straggler stealing, cooperative interleaving, and the
drain-snapshot -> grown-mesh rejoin path.

All replicas are no-mesh engines (dense island fallbacks — the fast path)
except the grown-mesh rejoin test; determinism-sensitive tests disable
stealing so routing never depends on wall-clock watchdog state.
"""

import dataclasses

import pytest

from repro.configs.base import FleetConfig, ServeConfig
from repro.runtime.fleet import FaultEvent, FaultPlan, ServingFleet

SERVE = ServeConfig(max_batch=4, prefill_batch=2, bucket_edges=(8, 16),
                    max_new_tokens=4)
PAGED = dataclasses.replace(SERVE, cache_layout="paged", page_size=4,
                            prefill_chunk=8)


def _engine(serve=SERVE, mesh_shape=None):
    from repro.launch.serve import build_engine
    return build_engine("tinyllama-1.1b", reduced=True,
                        mesh_shape=mesh_shape, serve=serve)


def _factory(serve=SERVE):
    return lambda i: _engine(serve)


def _trace(n, serve=SERVE, seed=3):
    from repro.launch.serve import synthetic_trace
    eng_vocab = 64                  # < any arch vocab; ids are arbitrary
    return synthetic_trace(n, serve, eng_vocab, seed=seed)


def _tokens(completions):
    return {c.rid: tuple(c.tokens) for c in completions}


# ---------------------------------------------------------------------------
# FaultPlan parsing
# ---------------------------------------------------------------------------

def test_fault_plan_parse_roundtrip():
    plan = FaultPlan.parse("kill:1@5, delay:0@3x4; rejoin:1@9 drain:2@7")
    assert plan.events == (
        FaultEvent("delay", 0, 3, 4), FaultEvent("kill", 1, 5),
        FaultEvent("drain", 2, 7), FaultEvent("rejoin", 1, 9))
    assert [e.kind for e in plan.at(5)] == ["kill"]
    assert plan.at(4) == []
    assert plan.rejoin_after(9) and not plan.rejoin_after(10)


@pytest.mark.parametrize("bad", ["boom:0@1", "kill:0", "delay:0@1",
                                 "kill:-1@2", "kill:0@-2"])
def test_fault_plan_rejects(bad):
    with pytest.raises(ValueError):
        FaultPlan.parse(bad)


def test_fault_plan_parses_comm_kinds():
    plan = FaultPlan.parse("linkdown:1.mlp@4x3 corrupt:0.attn_out@2")
    assert plan.events == (
        FaultEvent("corrupt", 0, 2, island="attn_out"),
        FaultEvent("linkdown", 1, 4, 3, island="mlp"))


def test_fault_plan_comm_kind_requires_island():
    with pytest.raises(ValueError, match=r"replica\.island"):
        FaultPlan.parse("corrupt:0@2")


def test_fault_plan_replica_fault_rejects_island():
    with pytest.raises(ValueError, match="takes no island"):
        FaultPlan.parse("kill:0.mlp@2")


def test_fault_plan_rejects_duplicate_comm_event():
    with pytest.raises(ValueError, match="duplicate fault event"):
        FaultPlan.parse("stall:0.mlp@2 stall:0.mlp@2")


def test_fault_plan_rejects_kill_plus_comm():
    with pytest.raises(ValueError, match="contradictory fault events"):
        FaultPlan.parse("kill:0@2 stall:0.mlp@2")


def test_fault_plan_rejects_contradictory_payload_poisons():
    with pytest.raises(ValueError, match="both poison"):
        FaultPlan.parse("corrupt:1.mlp@3 bitflip:1.mlp@3")
    # different islands at the same step are fine
    plan = FaultPlan.parse("corrupt:1.mlp@3 bitflip:1.attn_out@3")
    assert len(plan.at(3)) == 2


def test_fleet_delivers_comm_fault_to_replica_engine():
    trace = _trace(6)
    ref = _tokens(_engine().run(trace))
    plan = FaultPlan.parse("stall:1.mlp@2x2")
    fleet = ServingFleet(_factory(), FleetConfig(n_replicas=2, steal=False),
                         fault_plan=plan)
    done = fleet.run(trace)
    # the fleet fired the event and the target engine recorded it
    assert any(e[:2] == ("comm_fault", 2) and e[2] == 1 and e[3] == "stall"
               for e in fleet.events)
    assert any(e[0] == "comm_fault" and e[2] == "stall" and e[3] == "mlp"
               for e in fleet.replicas[1].engine.events)
    # a stall only inflates recorded step time: tokens are unaffected
    assert _tokens(done) == ref


# ---------------------------------------------------------------------------
# Routing determinism + fleet == single engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("router", ["fcfs", "least-loaded"])
def test_routing_deterministic(router):
    cfg = FleetConfig(n_replicas=2, router=router, steal=False)
    trace = _trace(8)
    logs = []
    for _ in range(2):
        fleet = ServingFleet(_factory(), cfg)
        out = fleet.run(trace)
        assert len(out) == len(trace)
        logs.append((fleet.assignments, _tokens(out)))
    assert logs[0] == logs[1]
    # every request routed exactly once, to a real replica
    rids = [a[1] for a in logs[0][0]]
    assert sorted(rids) == list(range(len(trace)))
    assert {a[2] for a in logs[0][0]} <= {0, 1}


@pytest.mark.parametrize("n", [2, 3])
def test_fleet_matches_single_engine(n):
    trace = _trace(9)
    ref = _tokens(_engine().run(trace))
    fleet = ServingFleet(_factory(), FleetConfig(n_replicas=n))
    assert _tokens(fleet.run(trace)) == ref
    st = fleet.stats()
    assert st["completed"] == len(trace) and st["live"] == n


# ---------------------------------------------------------------------------
# Fault matrix: every submitted request completes exactly once,
# token-identical to the no-fault run
# ---------------------------------------------------------------------------

def test_kill_mid_decode_completes_exactly_once():
    trace = _trace(8)
    ref = _tokens(_engine().run(trace))
    fleet = ServingFleet(_factory(), FleetConfig(n_replicas=2, steal=False))
    for p in trace:
        fleet.submit(p)
    # step until replica 1 has live decode slots, then pull the plug
    for _ in range(50):
        fleet.step()
        rep = fleet.replicas[1]
        if any(s is not None for s in rep.engine.slots):
            break
    else:
        pytest.fail("replica 1 never admitted")
    fleet.kill(1)
    kill_ev = [e for e in fleet.events if e[0] == "kill"]
    assert len(kill_ev) == 1 and len(kill_ev[0][3]) > 0   # work was lost
    out = fleet.run()
    have = _tokens(fleet.completions.values())
    assert have == ref                    # exactly once, token-identical
    assert len(out) + sum(1 for e in fleet.events
                          if e[0] == "complete" and e[1] <= kill_ev[0][1]) \
        == len(trace)


def test_kill_mid_prefill_chunk_completes_exactly_once():
    # two-chunk prompts (len > chunk=8) so a prefill job is in flight
    trace = [tuple(range(2, 14)), tuple(range(3, 15)),
             tuple(range(4, 16)), (5, 6, 7)]
    ref = _tokens(_engine(PAGED).run(trace))
    fleet = ServingFleet(_factory(PAGED),
                         FleetConfig(n_replicas=2, steal=False))
    for p in trace:
        fleet.submit(p)
    for _ in range(50):
        fleet.step()
        if fleet.replicas[1].engine._job is not None:
            break
    else:
        pytest.fail("replica 1 never had a chunked prefill in flight")
    fleet.kill(1)
    fleet.run()
    assert _tokens(fleet.completions.values()) == ref


def test_kill_while_draining_completes_exactly_once(tmp_path):
    trace = _trace(8)
    ref = _tokens(_engine().run(trace))
    fleet = ServingFleet(_factory(), FleetConfig(n_replicas=2, steal=False),
                         ckpt_dir=str(tmp_path))
    for p in trace:
        fleet.submit(p)
    fleet.step()
    fleet.drain(1)                        # queued requests requeue + snapshot
    fleet.step()
    fleet.kill(1)                         # in-flight slots requeue
    fleet.run()
    assert _tokens(fleet.completions.values()) == ref
    kinds = [e[0] for e in fleet.events]
    assert kinds.count("drain") == 1 and kinds.count("kill") == 1
    assert "snapshot" in kinds            # the rejoin seed was cut


def test_scripted_kill_rejoin_via_plan(tmp_path):
    trace = _trace(10)
    ref = _tokens(_engine().run(trace))
    plan = FaultPlan.parse("drain:1@1 kill:1@3 rejoin:1@5")
    fleet = ServingFleet(_factory(), FleetConfig(n_replicas=2, steal=False),
                         fault_plan=plan, ckpt_dir=str(tmp_path))
    assert _tokens(fleet.run(trace)) == ref
    assert fleet.stats()["live"] == 2     # replica 1 is back
    assert fleet.requeued > 0


def test_all_dead_raises_without_rejoin():
    fleet = ServingFleet(_factory(),
                         FleetConfig(n_replicas=2, steal=False),
                         fault_plan=FaultPlan.parse("kill:0@0 kill:1@0"))
    with pytest.raises(RuntimeError, match="rejoin"):
        fleet.run(_trace(4))


# ---------------------------------------------------------------------------
# Straggler stealing
# ---------------------------------------------------------------------------

def test_stealing_from_scripted_slow_replica():
    trace = _trace(8)
    ref = _tokens(_engine().run(trace))
    fleet = ServingFleet(_factory(), FleetConfig(n_replicas=2, steal=True))
    for p in trace:
        fleet.submit(p)
    fleet.step()                          # routes the burst across replicas
    assert len(fleet.replicas[1].engine.queue) > 0   # backlog behind r1
    fleet.delay(1, 8)                     # r1 goes dark for 8 fleet ticks
    fleet.run()
    assert fleet.steals >= 1
    stolen_rids = [rid for e in fleet.events if e[0] == "steal"
                   for rid in e[3]]
    assert stolen_rids
    # every stolen request was re-routed (two assignment entries) and
    # finished on the healthy replica, exactly once
    for rid in stolen_rids:
        routes = [a for a in fleet.assignments if a[1] == rid]
        assert len(routes) == 2 and routes[-1][2] == 0
    assert _tokens(fleet.completions.values()) == ref


# ---------------------------------------------------------------------------
# Cache-affinity routing (paged prefix cache as router feedback)
# ---------------------------------------------------------------------------

def test_cache_affinity_follows_prefix():
    fleet = ServingFleet(_factory(PAGED),
                         FleetConfig(n_replicas=2, router="cache-affinity",
                                     steal=False))
    shared = tuple(range(1, 9))           # one full page-aligned chunk
    first = fleet.run([shared + (20,)])
    home = fleet.assignments[0][2]
    fleet.run([shared + (21,), shared + (22,)])
    aff = [a for a in fleet.assignments if a[3].startswith("affinity")]
    assert len(aff) == 2
    assert all(a[2] == home for a in aff)
    assert len(first) == 1 and len(fleet.completions) == 3


# ---------------------------------------------------------------------------
# Cooperative stepping: interleave order cannot change tokens
# ---------------------------------------------------------------------------

def test_two_interleavings_identical_completions():
    tr_a, tr_b = _trace(4, seed=5), _trace(4, seed=6)

    def run_pair(schedule):
        a, b = _engine(), _engine()
        for p in tr_a:
            a.submit(p)
        for p in tr_b:
            b.submit(p)
        for eng, budget in schedule:
            eng = a if eng == "a" else b
            eng.run(step_budget=budget)
        a.run(), b.run()                  # drain whatever is left
        return _tokens(a.completions.values()), \
            _tokens(b.completions.values())

    # fine-grained alternation vs. run-A-then-B
    fine = run_pair([("a", 1), ("b", 1)] * 30)
    coarse = run_pair([("a", 1000), ("b", 1000)])
    assert fine == coarse


def test_step_budget_is_cooperative():
    eng = _engine()
    for p in _trace(6):
        eng.submit(p)
    done = eng.run(step_budget=1)         # one step cannot finish the queue
    assert eng.pending and len(done) < 6  # ...and does NOT raise
    total = {c.rid for c in done} | {c.rid for c in eng.run()}
    assert total == set(range(6))


# ---------------------------------------------------------------------------
# Drain snapshot -> elastic rejoin onto a GROWN mesh, mid-serving
# ---------------------------------------------------------------------------

def test_drain_snapshot_rejoins_onto_grown_mesh(tmp_path):
    trace = _trace(6)
    ref = _tokens(_engine().run(trace))
    fleet = ServingFleet(_factory(), FleetConfig(n_replicas=2, steal=False),
                         ckpt_dir=str(tmp_path))
    for p in trace[:4]:
        fleet.submit(p)
    fleet.step()
    fleet.drain(1)                        # snapshot cut mid-serving (tp=1)
    fleet.run()                           # replica 1 finishes in-flight
    # rejoin onto a (2,2) mesh: elastic_restore re-places the tp=1
    # snapshot onto the larger mesh's shardings
    fleet.rejoin(1, factory=lambda i: _engine(SERVE, mesh_shape=(2, 2)))
    assert fleet.replicas[1].engine.rules is not None
    rejoin_step = [e for e in fleet.events if e[0] == "rejoin"][0][1]
    out = fleet.run(trace[4:])
    assert _tokens(fleet.completions.values()) == ref
    assert len(out) == 2
    # the restored replica actually served traffic after rejoining
    assert any(a[2] == 1 and a[0] >= rejoin_step for a in fleet.assignments)
    assert fleet.stats()["live"] == 2
